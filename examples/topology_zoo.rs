//! Topology-zoo smoke: every generator family at N = 64, run through
//! **both** engines (sequential simulator + sharded coordinator) and
//! asserted bit-for-bit identical — the generalized-topology analogue of
//! `coordinator_scale`.  CI runs this on every PR (see
//! `.github/workflows/ci.yml`, "topology zoo smoke").
//!
//! Run with: `cargo run --release --example topology_zoo`
//! Env: `ZOO_WORKERS` (default 64), `ZOO_THREADS` (default 4),
//! `ZOO_ITERS` (default 10).

use cq_ggadmm::algs::{AlgSpec, Problem, Run, RunOptions};
use cq_ggadmm::coordinator::{Coordinator, CoordinatorOptions};
use cq_ggadmm::data::synthetic;
use cq_ggadmm::experiments::matrix::default_families;
use cq_ggadmm::graph::gen;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let seed = 17;
    let workers = env_usize("ZOO_WORKERS", 64);
    let threads = env_usize("ZOO_THREADS", 4);
    let iters = env_usize("ZOO_ITERS", 10) as u64;

    let ds = synthetic::linear_dataset(workers * 10, 6, seed);
    let spec = AlgSpec::cq_ggadmm(0.1, 0.85, 0.995, 2);
    println!(
        "{:<16} {:>6} {:>8} {:>12} {:>12} {:>12}",
        "topology", "edges", "dropped", "final gap", "Mbits", "energy (J)"
    );
    for family in default_families() {
        let b = gen::build(&family, workers, seed).unwrap_or_else(|e| panic!("{family}: {e}"));
        assert!(b.topology.is_connected(), "{family}: disconnected");
        assert!(b.topology.is_bipartite_consistent(), "{family}");
        let problem = Problem::new(&ds, &b.topology, 5.0, 0.0, seed);

        let mut sim = Run::new(
            problem.clone(),
            b.topology.clone(),
            spec.clone(),
            RunOptions { seed, ..RunOptions::default() },
        );
        let ts = sim.run(iters);
        let coord = Coordinator::spawn(
            problem,
            b.topology.clone(),
            spec.clone(),
            CoordinatorOptions { seed, threads, ..CoordinatorOptions::default() },
        );
        let tc = coord.run(iters);

        // both engines, bit for bit, on every family
        assert_eq!(ts.points.len(), tc.points.len(), "{family}: trace length");
        for (a, c) in ts.points.iter().zip(&tc.points) {
            assert_eq!(a.cum_rounds, c.cum_rounds, "{family} iter {}", a.iteration);
            assert_eq!(a.cum_bits, c.cum_bits, "{family} iter {}", a.iteration);
            assert_eq!(
                a.loss_gap.to_bits(),
                c.loss_gap.to_bits(),
                "{family} iter {}: loss gap",
                a.iteration
            );
            assert_eq!(
                a.cum_energy_j.to_bits(),
                c.cum_energy_j.to_bits(),
                "{family} iter {}: energy",
                a.iteration
            );
        }
        let last = ts.points.last().expect("non-empty trace");
        assert!(last.loss_gap.is_finite(), "{family}: diverged");
        assert!(last.cum_energy_j.is_finite(), "{family}: energy not finite");
        assert!(last.cum_rounds > 0, "{family}: nothing transmitted");
        println!(
            "{:<16} {:>6} {:>8} {:>12.3e} {:>12.3} {:>12.3e}",
            family.label(),
            b.topology.edges().len(),
            b.dropped_edges,
            last.loss_gap,
            last.cum_bits as f64 / 1e6,
            last.cum_energy_j
        );
    }
    println!("topology zoo OK ({workers} workers, both engines bit-identical)");
}
