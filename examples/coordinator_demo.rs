//! The distributed system demo: CQ-GGADMM as a real system engine — the
//! workers sharded over a fixed-size executor pool (not one OS thread
//! each; see `coordinator_scale` for N = 1024), bit-packed quantized
//! payloads on the (simulated) air.
//!
//! Run with: `cargo run --release --example coordinator_demo`

use cq_ggadmm::algs::{AlgSpec, Problem};
use cq_ggadmm::coordinator::{Coordinator, CoordinatorOptions};
use cq_ggadmm::data;
use cq_ggadmm::graph::Topology;

fn main() {
    let seed = 3;
    let workers = 16;
    let ds = data::synthetic::linear_dataset(800, 25, seed);
    let topo = Topology::random_bipartite(workers, 0.3, seed);
    let problem = Problem::new(&ds, &topo, 10.0, 0.0, seed);
    println!(
        "sharding {workers} workers over {} links; f* = {:.6e}",
        topo.edges().len(),
        problem.f_star
    );

    let spec = AlgSpec::cq_ggadmm(0.1, 0.8, 0.995, 2);
    let coord = Coordinator::spawn(
        problem,
        topo,
        spec,
        CoordinatorOptions { seed, ..CoordinatorOptions::default() },
    );
    let trace = coord.run(150);

    for target in [1e-2, 1e-4, 1e-6] {
        if let Some(p) = trace.first_below(target) {
            println!(
                "reached {target:.0e} after {:>3} iterations, {:>5} broadcasts, {:>8} bits on air",
                p.iteration, p.cum_rounds, p.cum_bits
            );
        }
    }
    let last = trace.points.last().unwrap();
    println!(
        "final: gap={:.3e} consensus={:.3e} energy={:.3e} J",
        last.loss_gap, last.consensus_gap, last.cum_energy_j
    );
    assert!(last.loss_gap < 1e-5, "coordinator demo failed to converge");
    println!("coordinator demo OK");
}
