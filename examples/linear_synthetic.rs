//! End-to-end driver (the repo's full-stack validation): the paper's
//! Figure-2 workload — linear regression, synthetic dataset, N = 24 —
//! executed through **all three layers**: the Rust coordinator drives
//! per-iteration primal updates through the AOT-compiled HLO artifacts
//! (JAX Layer-2 calling the Pallas Layer-1 Gram/update kernels) on the
//! PJRT CPU client, with censoring + quantization + the wireless energy
//! model on the Layer-3 hot path.
//!
//! Requires `make artifacts` first (falls back to the native backend with
//! a warning if `artifacts/manifest.json` is missing).
//!
//! Run with: `cargo run --release --example linear_synthetic`

use cq_ggadmm::experiments::{self, ExecOptions};
use cq_ggadmm::metrics::save_traces;
use cq_ggadmm::solver::Backend;
use std::path::{Path, PathBuf};

fn main() {
    let artifacts = PathBuf::from("artifacts");
    let have_artifacts = artifacts.join("manifest.json").exists();
    let exec = if have_artifacts {
        println!("backend: PJRT (AOT artifacts from {})", artifacts.display());
        ExecOptions {
            backend: Backend::Pjrt,
            artifacts_dir: Some(artifacts),
            ..ExecOptions::default()
        }
    } else {
        eprintln!("warning: artifacts/manifest.json missing; run `make artifacts`. Using native backend.");
        ExecOptions::default()
    };

    let spec = experiments::fig2();
    println!("== {} ==", spec.title);
    let res = experiments::run_figure(&spec, &exec);
    println!("{}", res.summary.render());
    save_traces(&res.traces, Path::new("results/linear_synthetic.csv"))
        .expect("write trace csv");
    println!("loss curves -> results/linear_synthetic.csv");

    // validation: the paper's qualitative claims must hold on this run
    let get = |name: &str| {
        res.traces
            .iter()
            .find(|t| t.algorithm == name)
            .unwrap_or_else(|| panic!("missing trace {name}"))
    };
    let target = spec.target_gap;
    let gg = get("GGADMM").first_below(target).expect("GGADMM converged");
    let cadmm = get("C-ADMM").first_below(target).expect("C-ADMM converged");
    let c = get("C-GGADMM").first_below(target).expect("C-GGADMM converged");
    let cq = get("CQ-GGADMM").first_below(target).expect("CQ-GGADMM converged");

    assert!(
        cadmm.iteration > 2 * gg.iteration,
        "C-ADMM should need many more iterations ({} vs {})",
        cadmm.iteration,
        gg.iteration
    );
    assert!(
        c.cum_rounds < gg.cum_rounds,
        "censoring should reduce communication rounds"
    );
    assert!(
        cq.cum_bits < c.cum_bits && cq.cum_bits < gg.cum_bits / 2,
        "quantization should cut total bits"
    );
    assert!(
        cq.cum_energy_j < gg.cum_energy_j / 5.0 && cq.cum_energy_j < cadmm.cum_energy_j / 100.0,
        "CQ-GGADMM should save orders of magnitude of energy"
    );
    println!("all Figure-2 qualitative claims reproduced — e2e OK");
}
