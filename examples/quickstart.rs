//! Quickstart: decentralized linear regression with CQ-GGADMM.
//!
//! Builds a 12-worker bipartite topology, partitions a synthetic
//! least-squares problem, runs CQ-GGADMM and prints the communication
//! savings against plain GGADMM.
//!
//! Run with: `cargo run --release --example quickstart`

use cq_ggadmm::prelude::*;
use cq_ggadmm::algs::RunOptions;

fn main() {
    // 1. data: 600 samples, d = 20, planted linear model
    let dataset = cq_ggadmm::data::synthetic::linear_dataset(600, 20, 42);

    // 2. topology: 12 workers, connectivity ratio 0.3 (bipartite+connected)
    let topo = Topology::random_bipartite(12, 0.3, 42);
    println!(
        "topology: {} workers, {} edges, heads={:?}",
        topo.n(),
        topo.edges().len(),
        topo.heads()
    );

    // 3. problem: rho tuned to the data scale; f* solved centrally once
    let problem = Problem::linear(dataset, &topo, 10.0);
    println!("centralized optimum f* = {:.6e}", problem.f_star);

    // 4. run GGADMM (full precision) and CQ-GGADMM (censored + quantized)
    let iters = 120;
    let mut plain = Run::new(
        problem.clone(),
        topo.clone(),
        AlgSpec::ggadmm(),
        RunOptions::default(),
    );
    let plain_trace = plain.run(iters);

    let spec = AlgSpec::cq_ggadmm(0.1, 0.8, 0.995, 2);
    let mut cq = Run::new(problem, topo, spec, RunOptions::default());
    let cq_trace = cq.run(iters);

    // 5. compare at 1e-4 objective error
    for trace in [&plain_trace, &cq_trace] {
        match trace.first_below(1e-4) {
            Some(p) => println!(
                "{:>10}: 1e-4 after {:>3} iters | {:>5} transmissions | {:>9} bits | {:.3e} J",
                trace.algorithm, p.iteration, p.cum_rounds, p.cum_bits, p.cum_energy_j
            ),
            None => println!("{:>10}: did not reach 1e-4", trace.algorithm),
        }
    }
    let p = plain_trace.first_below(1e-4).unwrap();
    let q = cq_trace.first_below(1e-4).unwrap();
    println!(
        "CQ-GGADMM saves {:.1}x bits and {:.1}x energy at the same accuracy",
        p.cum_bits as f64 / q.cum_bits as f64,
        p.cum_energy_j / q.cum_energy_j
    );
    assert!(cq_trace.last_gap() < 1e-4, "quickstart failed to converge");
    println!("quickstart OK");
}
