//! Dynamic-network smoke at N = 256: a seeded churn schedule (leaves +
//! warm-started rejoins), a rotating straggler subset, and the
//! bounded-staleness round policy — run through BOTH engines (the
//! sequential simulator and the sharded coordinator) from one shared
//! `ExecutionConfig`, asserting progress and cross-engine bit-identity
//! under faults.  CI runs this on every PR (see
//! `.github/workflows/ci.yml`, "churn smoke").
//!
//! Run with: `cargo run --release --example churn_smoke`
//! Env: `CHURN_WORKERS` (default 256), `CHURN_THREADS` (default 4),
//! `CHURN_ITERS` (default 14).

use cq_ggadmm::algs::{AlgSpec, Problem, Run};
use cq_ggadmm::comm::LinkKind;
use cq_ggadmm::config::ExecutionConfig;
use cq_ggadmm::coordinator::Coordinator;
use cq_ggadmm::data;
use cq_ggadmm::graph::{ChurnSchedule, Topology};
use cq_ggadmm::io::{MemorySink, PersistableEngine};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let seed = 19;
    let workers = env_usize("CHURN_WORKERS", 256);
    let threads = env_usize("CHURN_THREADS", 4);
    let iters = env_usize("CHURN_ITERS", 14) as u64;
    let d = 6;

    let ds = data::synthetic::linear_dataset(workers * 4, d, seed);
    let topo = Topology::random_bipartite(workers, 0.02, seed);
    let problem = Problem::new(&ds, &topo, 10.0, 0.0, seed);

    // ~6% of workers cycle through leave -> warm-started rejoin, a
    // rotating 10% straggler subset injects late slots, and censored
    // workers are force-refreshed after 3 silent rounds
    let churn = ChurnSchedule::generate(workers, iters, 0.06, seed);
    let stragglers =
        LinkKind::Straggler { frac: 0.1, rotate_every: 4, base_s: 8e-4, alpha: 1.3 };
    let exec = ExecutionConfig::default()
        .with_seed(seed)
        .with_threads(threads)
        .with_churn(Some(churn.clone()))
        .with_staleness_bound(Some(3))
        .with_link(Some(stragglers));
    println!(
        "{workers} workers ({} links), {} churn events, stragglers rotating every 4 iters",
        topo.edges().len(),
        churn.events().len()
    );

    let spec = AlgSpec::cq_ggadmm(0.05, 0.9, 0.995, 2);
    let sink = MemorySink::new();
    let mut sim = Run::new(problem.clone(), topo.clone(), spec.clone(), exec.clone());
    sim.start_event_log(Box::new(sink.clone()));
    let mut coord = Coordinator::spawn(problem, topo, spec, exec);
    for _ in 0..iters {
        sim.step();
        coord.step();
    }

    let (ts, tc) = (sim.trace(), coord.trace());
    let first = ts.points.first().expect("trace must not be empty");
    let last = ts.points.last().expect("trace must not be empty");
    println!(
        "iter {:>3}: gap={:.3e}   iter {:>3}: gap={:.3e} rounds={} bits={}",
        first.iteration, first.loss_gap, last.iteration, last.loss_gap, last.cum_rounds,
        last.cum_bits
    );
    assert!(last.loss_gap.is_finite(), "diverged under faults");
    assert!(
        last.loss_gap < first.loss_gap,
        "no progress under churn: {:.3e} -> {:.3e}",
        first.loss_gap,
        last.loss_gap
    );
    assert!(last.cum_rounds > 0, "nothing was transmitted");

    // both engines walked the identical faulted trajectory
    assert_eq!(ts.points.len(), tc.points.len(), "trace length");
    for (a, b) in ts.points.iter().zip(&tc.points) {
        assert_eq!(a.loss_gap.to_bits(), b.loss_gap.to_bits(), "iter {}: loss", a.iteration);
        assert_eq!(a.cum_bits, b.cum_bits, "iter {}: bits", a.iteration);
        assert_eq!(
            a.cum_energy_j.to_bits(),
            b.cum_energy_j.to_bits(),
            "iter {}: energy",
            a.iteration
        );
    }

    // every scheduled transition hit the event stream
    let lines = sink.lines();
    let leaves = lines.iter().filter(|l| l.contains("\"event\":\"worker_leave\"")).count();
    let joins = lines.iter().filter(|l| l.contains("\"event\":\"worker_join\"")).count();
    let expected = churn.events().len() / 2;
    assert_eq!(leaves, expected, "leave events");
    assert_eq!(joins, expected, "join events");
    println!(
        "churn smoke OK ({workers} workers, {leaves} leaves + {joins} rejoins, \
         engines bit-identical)"
    );
}
