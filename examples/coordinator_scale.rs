//! Large-N scale smoke for the sharded coordinator: N = 1024 simulated
//! workers on a small fixed-size executor pool (threads ≪ N) — the
//! workload the seed thread-per-worker engine could not schedule without
//! spawning a thousand OS threads.  CI runs this on every PR (see
//! `.github/workflows/ci.yml`, "coordinator scale smoke").
//!
//! Run with: `cargo run --release --example coordinator_scale`
//! Env: `SCALE_WORKERS` (default 1024), `SCALE_THREADS` (default 4),
//! `SCALE_ITERS` (default 8).

use cq_ggadmm::algs::{AlgSpec, Problem};
use cq_ggadmm::coordinator::{Coordinator, CoordinatorOptions};
use cq_ggadmm::data;
use cq_ggadmm::graph::Topology;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let seed = 9;
    let workers = env_usize("SCALE_WORKERS", 1024);
    let threads = env_usize("SCALE_THREADS", 4);
    let iters = env_usize("SCALE_ITERS", 8) as u64;
    let d = 8;

    let ds = data::synthetic::linear_dataset(workers * 4, d, seed);
    // sparse graph: ~1% connectivity keeps the edge count linear-ish in N
    let topo = Topology::random_bipartite(workers, 0.01, seed);
    let problem = Problem::new(&ds, &topo, 10.0, 0.0, seed);
    println!(
        "sharding {workers} workers ({} links) over a {threads}-thread executor",
        topo.edges().len()
    );

    let spec = AlgSpec::cq_ggadmm(0.05, 0.9, 0.995, 2);
    let coord = Coordinator::spawn(
        problem,
        topo,
        spec,
        CoordinatorOptions { seed, threads, record_every: 1, ..CoordinatorOptions::default() },
    );
    assert!(
        coord.threads() <= cq_ggadmm::parallel::resolve_threads(threads),
        "executor must stay bounded: {} threads for {workers} workers",
        coord.threads()
    );
    let trace = coord.run(iters);

    let first = trace.points.first().expect("trace must not be empty");
    let last = trace.points.last().expect("trace must not be empty");
    println!(
        "iter {:>3}: gap={:.3e} rounds={} bits={}",
        first.iteration, first.loss_gap, first.cum_rounds, first.cum_bits
    );
    println!(
        "iter {:>3}: gap={:.3e} rounds={} bits={} energy={:.3e} J",
        last.iteration, last.loss_gap, last.cum_rounds, last.cum_bits, last.cum_energy_j
    );
    assert!(last.loss_gap.is_finite(), "diverged");
    assert!(
        last.loss_gap < first.loss_gap,
        "no progress at scale: {:.3e} -> {:.3e}",
        first.loss_gap,
        last.loss_gap
    );
    assert!(last.cum_rounds > 0, "nothing was transmitted");
    println!("coordinator scale smoke OK ({workers} workers, {} threads)", threads.max(1));
}
