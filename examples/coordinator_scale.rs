//! Large-N scale smoke for the sharded coordinator: N = 1024 simulated
//! workers on a small fixed-size executor pool (threads ≪ N) — the
//! workload the seed thread-per-worker engine could not schedule without
//! spawning a thousand OS threads.  CI runs this on every PR (see
//! `.github/workflows/ci.yml`, "coordinator scale smoke").
//!
//! The smoke also exercises the persistence layer at scale: the run
//! streams a JSONL event log into a run directory, checkpoints midway,
//! is dropped, and a second coordinator resumed from the bytes on disk
//! must land on the uninterrupted trajectory **bit-for-bit**.
//!
//! Run with: `cargo run --release --example coordinator_scale`
//! Env: `SCALE_WORKERS` (default 1024), `SCALE_THREADS` (default 4),
//! `SCALE_ITERS` (default 8), `SCALE_RUN_BASE` (run-dir base, default
//! a temp dir).

use cq_ggadmm::algs::{AlgSpec, Problem};
use cq_ggadmm::config::ExecutionConfig;
use cq_ggadmm::coordinator::Coordinator;
use cq_ggadmm::data;
use cq_ggadmm::graph::Topology;
use cq_ggadmm::io::{checkpoint, run_with_persistence, JsonlSink, PersistableEngine, RunDir};
use std::path::PathBuf;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let seed = 9;
    let workers = env_usize("SCALE_WORKERS", 1024);
    let threads = env_usize("SCALE_THREADS", 4);
    let iters = env_usize("SCALE_ITERS", 8) as u64;
    let d = 8;

    let ds = data::synthetic::linear_dataset(workers * 4, d, seed);
    // sparse graph: ~1% connectivity keeps the edge count linear-ish in N
    let topo = Topology::random_bipartite(workers, 0.01, seed);
    let problem = Problem::new(&ds, &topo, 10.0, 0.0, seed);
    println!(
        "sharding {workers} workers ({} links) over a {threads}-thread executor",
        topo.edges().len()
    );

    let spec = AlgSpec::cq_ggadmm(0.05, 0.9, 0.995, 2);
    let exec = ExecutionConfig::default().with_seed(seed).with_threads(threads);
    let spawn = || Coordinator::spawn(problem.clone(), topo.clone(), spec.clone(), exec.clone());

    let coord = spawn();
    assert!(
        coord.threads() <= cq_ggadmm::parallel::resolve_threads(threads),
        "executor must stay bounded: {} threads for {workers} workers",
        coord.threads()
    );
    let trace = coord.run(iters);

    let first = trace.points.first().expect("trace must not be empty");
    let last = trace.points.last().expect("trace must not be empty");
    println!(
        "iter {:>3}: gap={:.3e} rounds={} bits={}",
        first.iteration, first.loss_gap, first.cum_rounds, first.cum_bits
    );
    println!(
        "iter {:>3}: gap={:.3e} rounds={} bits={} energy={:.3e} J",
        last.iteration, last.loss_gap, last.cum_rounds, last.cum_bits, last.cum_energy_j
    );
    assert!(last.loss_gap.is_finite(), "diverged");
    assert!(
        last.loss_gap < first.loss_gap,
        "no progress at scale: {:.3e} -> {:.3e}",
        first.loss_gap,
        last.loss_gap
    );
    assert!(last.cum_rounds > 0, "nothing was transmitted");

    // --- kill-and-resume at scale: run K1, drop, resume, finish -------
    let base = std::env::var("SCALE_RUN_BASE")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            std::env::temp_dir().join(format!("cq_scale_runs_{}", std::process::id()))
        });
    let k1 = (iters / 2).max(1);
    let dir = RunDir::create(&base, "coordinator-scale").expect("create run dir");
    let mut interrupted = spawn();
    interrupted.start_event_log(Box::new(
        JsonlSink::create(&dir.events_path()).expect("create event log"),
    ));
    run_with_persistence(&mut interrupted, k1, &dir, 0).expect("first life");
    drop(interrupted); // the "kill": only the run directory survives

    let state = checkpoint::load(&dir.checkpoint_path()).expect("load checkpoint");
    let mut resumed = spawn();
    resumed.restore_state(&state);
    assert_eq!(resumed.iteration(), k1, "resume point");
    resumed.resume_event_log(Box::new(
        JsonlSink::append(&dir.events_path()).expect("append event log"),
    ));
    run_with_persistence(&mut resumed, iters - k1, &dir, 0).expect("second life");

    // bit-for-bit: the resumed trajectory equals the uninterrupted one
    let resumed_trace = resumed.trace();
    assert_eq!(resumed_trace.points.len(), trace.points.len(), "trace length after resume");
    for (a, b) in trace.points.iter().zip(&resumed_trace.points) {
        assert_eq!(
            a.loss_gap.to_bits(),
            b.loss_gap.to_bits(),
            "iter {}: resumed loss diverged",
            a.iteration
        );
        assert_eq!(a.cum_bits, b.cum_bits, "iter {}: resumed bits diverged", a.iteration);
        assert_eq!(
            a.cum_energy_j.to_bits(),
            b.cum_energy_j.to_bits(),
            "iter {}: resumed energy diverged",
            a.iteration
        );
    }
    let events = std::fs::read_to_string(dir.events_path()).expect("read event log");
    let n_records = events.lines().filter(|l| l.contains("\"event\":\"record\"")).count();
    assert_eq!(n_records as u64, iters, "one record event per iteration");
    println!("events -> {}", dir.events_path().display());
    println!(
        "coordinator scale smoke OK ({workers} workers, {} threads, resume bit-identical)",
        threads.max(1)
    );
}
