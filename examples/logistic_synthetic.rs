//! Figure-4 workload: logistic regression on the synthetic dataset
//! (N = 24), all four schemes, through the PJRT artifacts when present
//! (the `logistic_newton` artifact embeds the Pallas fused grad/Hessian
//! kernel inside a fixed-budget Newton/CG solver).
//!
//! Run with: `cargo run --release --example logistic_synthetic`

use cq_ggadmm::experiments::{self, ExecOptions};
use cq_ggadmm::metrics::save_traces;
use cq_ggadmm::solver::Backend;
use std::path::{Path, PathBuf};

fn main() {
    let artifacts = PathBuf::from("artifacts");
    let exec = if artifacts.join("manifest.json").exists() {
        println!("backend: PJRT");
        ExecOptions {
            backend: Backend::Pjrt,
            artifacts_dir: Some(artifacts),
            ..ExecOptions::default()
        }
    } else {
        eprintln!("warning: no artifacts; using native backend");
        ExecOptions::default()
    };

    let mut spec = experiments::fig4();
    // keep the demo snappy; `cq-ggadmm exp --figure fig4` runs the full budget
    spec.iters_alt = 150;
    spec.iters_jacobian = 400;
    println!("== {} ==", spec.title);
    let res = experiments::run_figure(&spec, &exec);
    println!("{}", res.summary.render());
    save_traces(&res.traces, Path::new("results/logistic_synthetic.csv"))
        .expect("write trace csv");

    // the paper's §7.2 observation: censoring alone saves little on
    // logistic tasks, but censoring + quantization wins on bits/energy
    let get = |name: &str| res.traces.iter().find(|t| t.algorithm == name).unwrap();
    let gg = get("GGADMM").first_below(spec.target_gap).expect("GGADMM");
    let cq = get("CQ-GGADMM").first_below(spec.target_gap).expect("CQ-GGADMM");
    assert!(cq.cum_bits * 2 < gg.cum_bits, "CQ must at least halve the bits");
    assert!(cq.cum_energy_j < gg.cum_energy_j, "CQ must cut energy");
    println!("Figure-4 qualitative claims reproduced — OK");
}
