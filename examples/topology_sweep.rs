//! Figure-6 workload + Theorem-2/3 rate study: how graph density shapes
//! convergence.
//!
//! Regenerates the paper's sparse (p=0.2) vs dense (p=0.4) comparison on
//! the Body Fat workload and prints the spectral-constants/rate table
//! across a wider density sweep.
//!
//! Run with: `cargo run --release --example topology_sweep`

use cq_ggadmm::experiments::{self, rates, ExecOptions};
use cq_ggadmm::metrics::save_traces;
use std::path::Path;

fn main() {
    // Figure 6: sparse vs dense on Body Fat
    let spec = experiments::fig6();
    println!("== {} ==", spec.base.title);
    let results = experiments::run_fig6(&spec, &ExecOptions::default());
    let mut all = Vec::new();
    for res in &results {
        println!("\n-- {} --\n{}", res.title, res.summary.render());
        all.extend(res.traces.iter().cloned());
    }
    save_traces(&all, Path::new("results/topology_sweep.csv")).expect("csv");

    // denser graphs must converge in fewer iterations (paper §7.3)
    let first_to = |label_frag: &str, traces: &[cq_ggadmm::metrics::Trace]| {
        traces
            .iter()
            .find(|t| t.algorithm.starts_with("GGADMM") && t.algorithm.contains(label_frag))
            .and_then(|t| t.first_below(1e-4))
            .map(|p| p.iteration)
    };
    let sparse_it = first_to("sparse", &all).expect("sparse GGADMM converged");
    let dense_it = first_to("dense", &all).expect("dense GGADMM converged");
    println!(
        "\nGGADMM iterations to 1e-4: sparse={} dense={} (denser is faster)",
        sparse_it, dense_it
    );
    assert!(dense_it <= sparse_it, "density must not slow convergence");

    // Theorem-2/3 study: empirical rate vs spectral bound across densities
    println!("\n== convergence-rate study (Theorems 2/3) ==");
    let studies = rates::study(&[0.15, 0.3, 0.5, 0.8], 16, 11, 150);
    println!("{}", rates::render(&studies).render());
    for s in &studies {
        assert!(
            s.empirical_rate <= s.bound_rate + 1e-6,
            "empirical rate must beat the conservative bound"
        );
    }
    println!("topology sweep OK");
}
