#!/usr/bin/env python3
"""Diff two bench_hotpath JSON artifacts and gate on regressions.

Usage:
  python3 tools/bench_diff.py [options] BENCH_baseline.json BENCH_hotpath.json

Options:
  --threshold PCT   fail when a common bench regresses by more than PCT
                    percent vs the baseline (default: 60)
  --allow SUBSTR    exempt benches whose name contains SUBSTR from the
                    gate (repeatable; they still appear in the report)
  --report-only     print the table and always exit 0 (the pre-gating
                    behavior)
  --reseed          overwrite the baseline file with the fresh results
                    (stamped with reseed provenance) and exit 0; used by
                    CI to populate an empty baseline from a real run

Prints a per-bench table (baseline ns/op, fresh ns/op, delta) plus the
benches that were added or removed, so the perf trajectory is readable
across PRs straight from the CI log.  The gate exits 1 when any
non-allowlisted common bench regresses past the threshold.  The gate is
skipped (report only, exit 0) when:
  * the baseline has no entries (not yet seeded — CI reseeds it), or
  * the two files disagree on `smoke` (full-mode numbers are not
    comparable to low-rep smoke numbers).

Hard *absolute* perf contracts (SIMD beats scalar, pooled beats serial,
fused beats seed, ...) live inside the bench binary itself as asserted
shootouts; this gate catches *relative drift* between commits.

Schema (bench_hotpath/v1, emitted by rust/benches/bench_hotpath.rs):
  {
    "schema": "bench_hotpath/v1",
    "unit": "ns_per_op",
    "smoke": bool,            # low-rep CI mode (noisier numbers)
    "provenance": str,        # how the file was produced
    "results": {"<bench name>": <ns/op float>, ...}
  }
"""

import argparse
import json
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    schema = doc.get("schema")
    if schema != "bench_hotpath/v1":
        raise SystemExit(f"{path}: unexpected schema {schema!r}")
    results = doc.get("results")
    if not isinstance(results, dict):
        raise SystemExit(f"{path}: missing results object")
    return doc, {k: float(v) for k, v in results.items()}


def reseed(base_path, fresh_doc):
    doc = dict(fresh_doc)
    doc["provenance"] = (
        f"{fresh_doc.get('provenance', 'unknown')} (reseeded via tools/bench_diff.py)"
    )
    with open(base_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    print(f"reseeded {base_path} with {len(doc.get('results', {}))} benches")


def main(argv):
    ap = argparse.ArgumentParser(
        description="diff + regression-gate two bench_hotpath artifacts",
        usage="bench_diff.py [options] BASELINE FRESH",
    )
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--threshold", type=float, default=60.0)
    ap.add_argument("--allow", action="append", default=[])
    ap.add_argument("--report-only", action="store_true")
    ap.add_argument("--reseed", action="store_true")
    args = ap.parse_args(argv[1:])

    base_doc, base = load(args.baseline)
    fresh_doc, fresh = load(args.fresh)

    if args.reseed:
        reseed(args.baseline, fresh_doc)
        return

    print(f"baseline: {args.baseline} (smoke={base_doc.get('smoke')}, {len(base)} benches)")
    print(f"fresh:    {args.fresh} (smoke={fresh_doc.get('smoke')}, {len(fresh)} benches)")

    gating = not args.report_only
    if not base:
        print()
        print("baseline has no entries — gate skipped.  Seed it with")
        print("  python3 tools/bench_diff.py --reseed BENCH_baseline.json BENCH_hotpath.json")
        print("(CI does this automatically on the next main-branch bench run.)")
        gating = False
    elif base_doc.get("smoke") != fresh_doc.get("smoke"):
        print()
        print(
            "warning: smoke-mode mismatch between baseline and fresh run — "
            "numbers are not comparable, gate skipped"
        )
        gating = False

    violations = []
    common = [k for k in fresh if k in base]
    if common:
        width = max(len(k) for k in common)
        print()
        print(f"{'bench':<{width}}  {'baseline':>12}  {'fresh':>12}  {'delta':>8}")
        for k in common:
            b, f = base[k], fresh[k]
            delta = (f - b) / b * 100.0 if b > 0 else float("nan")
            allowed = any(sub in k for sub in args.allow)
            marker = ""
            if gating and delta > args.threshold and not allowed:
                violations.append((k, delta))
                marker = "  <-- REGRESSION"
            elif delta > 25.0:
                marker = "  <-- slower" + (" (allowlisted)" if allowed else "")
            elif delta < -25.0:
                marker = "  <-- faster"
            print(f"{k:<{width}}  {b:>12.0f}  {f:>12.0f}  {delta:>+7.1f}%{marker}")

    added = [k for k in fresh if k not in base]
    removed = [k for k in base if k not in fresh]
    if added:
        print()
        print("new benches (not in baseline):")
        for k in added:
            print(f"  + {k}: {fresh[k]:.0f} ns/op")
    if removed:
        print()
        print("benches missing from the fresh run:")
        for k in removed:
            print(f"  - {k}")

    print()
    if violations:
        print(f"FAIL: {len(violations)} bench(es) regressed past {args.threshold:.0f}%:")
        for k, delta in violations:
            print(f"  {k}: {delta:+.1f}%")
        print("(re-seed the baseline deliberately if the regression is accepted:")
        print(" see EXPERIMENTS.md, 'Re-seeding the benchmark baseline')")
        raise SystemExit(1)
    if gating:
        print(f"gate passed: no common bench regressed past {args.threshold:.0f}%")
    else:
        print("(report only: gate not applied)")


if __name__ == "__main__":
    main(sys.argv)
