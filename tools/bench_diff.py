#!/usr/bin/env python3
"""Report-only diff of two bench_hotpath JSON artifacts.

Usage: python3 tools/bench_diff.py BENCH_baseline.json BENCH_hotpath.json

Prints a per-bench table (baseline ns/op, fresh ns/op, delta) plus the
benches that were added or removed, so the perf trajectory is readable
across PRs straight from the CI log.  This script never fails the build
on a regression — hard perf gates live inside the bench binary itself
(the asserted shootouts); it exits non-zero only on malformed input.

Schema (bench_hotpath/v1, emitted by rust/benches/bench_hotpath.rs):
  {
    "schema": "bench_hotpath/v1",
    "unit": "ns_per_op",
    "smoke": bool,            # low-rep CI mode (noisier numbers)
    "provenance": str,        # how the file was produced
    "results": {"<bench name>": <ns/op float>, ...}
  }
"""

import json
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    schema = doc.get("schema")
    if schema != "bench_hotpath/v1":
        raise SystemExit(f"{path}: unexpected schema {schema!r}")
    results = doc.get("results")
    if not isinstance(results, dict):
        raise SystemExit(f"{path}: missing results object")
    return doc, {k: float(v) for k, v in results.items()}


def main(argv):
    if len(argv) != 3:
        raise SystemExit(__doc__)
    base_doc, base = load(argv[1])
    fresh_doc, fresh = load(argv[2])
    print(f"baseline: {argv[1]} (smoke={base_doc.get('smoke')}, {len(base)} benches)")
    print(f"fresh:    {argv[2]} (smoke={fresh_doc.get('smoke')}, {len(fresh)} benches)")
    if not base:
        print()
        print("baseline has no entries — seed it by copying a full-mode")
        print("BENCH_hotpath.json over BENCH_baseline.json and committing it.")

    common = [k for k in fresh if k in base]
    if common:
        width = max(len(k) for k in common)
        print()
        print(f"{'bench':<{width}}  {'baseline':>12}  {'fresh':>12}  {'delta':>8}")
        for k in common:
            b, f = base[k], fresh[k]
            delta = (f - b) / b * 100.0 if b > 0 else float("nan")
            marker = ""
            if delta > 25.0:
                marker = "  <-- slower"
            elif delta < -25.0:
                marker = "  <-- faster"
            print(f"{k:<{width}}  {b:>12.0f}  {f:>12.0f}  {delta:>+7.1f}%{marker}")

    added = [k for k in fresh if k not in base]
    removed = [k for k in base if k not in fresh]
    if added:
        print()
        print("new benches (not in baseline):")
        for k in added:
            print(f"  + {k}: {fresh[k]:.0f} ns/op")
    if removed:
        print()
        print("benches missing from the fresh run:")
        for k in removed:
            print(f"  - {k}")
    print()
    print("(report only: shootout regressions fail inside the bench binary itself)")


if __name__ == "__main__":
    main(sys.argv)
