#!/usr/bin/env python3
"""Validate a run's JSONL event stream (see rust/src/io/events.rs).

One JSON object per line, discriminated by "event".  Schema v1, v2 and
v3 streams all validate (the run_start's "schema" field selects the
rules):

* run_start     -- schema, algorithm, dataset, workers, d, seed; must be
                   the first line of the stream.
* record        -- iteration, loss_gap, consensus_gap, cum_rounds,
                   cum_bits, cum_energy_j, sim_time_s, committed,
                   censored, worker_bits ([worker, bits] pairs, ascending).
                   v3 multi-block runs add cum_block_bits: cumulative
                   bits per parameter block, non-decreasing and summing
                   to cum_bits (single-block runs omit the key).
* checkpoint    -- iteration, path.
* worker_leave  -- iteration, worker        (v2: churn detached a worker)
* worker_join   -- iteration, worker        (v2: churn re-attached one)
* worker_connect    -- iteration, worker    (v2: networked runs — a
                       worker registered on the TCP server)
* worker_disconnect -- iteration, worker    (v2: networked runs — a
                       connection dropped without a clean goodbye)
* stale_refresh -- iteration, worker, staleness  (v2: bounded-staleness
                   policy force-refreshed a heavily censored worker)

Checks: every line parses, the stream starts with exactly one
run_start, record iterations strictly increase, cumulative counters
never decrease, interval accounting conserves, and worker ids stay
within range.  Conservation is schema-dependent: v1 (static graphs)
requires committed + censored == workers x interval exactly; v2 counts
censoring per gate *attempt*, and workers absent under churn attempt
nothing, so committed + censored <= workers x interval.  The dynamic
event kinds are v2-only — in a v1 stream they are violations.  A
resumed (appended-to) log must validate identically to an uninterrupted
one — that invariant is the point of checkpointed cumulative totals.

Usage: tail_events.py EVENTS.jsonl [EVENTS.jsonl ...]
Exit 0 and a summary per file on success; exit 1 on the first violation.
Stdlib only.
"""

import json
import sys

SCHEMA_VERSIONS = (1, 2, 3)

RUN_START_KEYS = {"event", "schema", "algorithm", "dataset", "workers", "d", "seed"}
MEMBERSHIP_KEYS = {"event", "iteration", "worker"}
STALE_REFRESH_KEYS = {"event", "iteration", "worker", "staleness"}
RECORD_KEYS = {
    "event",
    "iteration",
    "loss_gap",
    "consensus_gap",
    "cum_rounds",
    "cum_bits",
    "cum_energy_j",
    "sim_time_s",
    "committed",
    "censored",
    "worker_bits",
}
CHECKPOINT_KEYS = {"event", "iteration", "path"}


class Violation(Exception):
    pass


def check_keys(obj, required, lineno, optional=frozenset()):
    missing = required - obj.keys()
    if missing:
        raise Violation(f"line {lineno}: missing keys {sorted(missing)}")
    extra = obj.keys() - required - optional
    if extra:
        raise Violation(f"line {lineno}: unknown keys {sorted(extra)}")


def validate(path):
    workers = None
    schema = None
    last_iter = 0
    prev = None  # previous record, for monotonicity and conservation
    counts = {
        "run_start": 0,
        "record": 0,
        "checkpoint": 0,
        "worker_leave": 0,
        "worker_join": 0,
        "worker_connect": 0,
        "worker_disconnect": 0,
        "stale_refresh": 0,
    }
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                raise Violation(f"line {lineno}: blank line in stream")
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                raise Violation(f"line {lineno}: bad JSON ({e})") from e
            if not isinstance(obj, dict) or "event" not in obj:
                raise Violation(f"line {lineno}: not an event object")
            kind = obj["event"]
            if lineno == 1 and kind != "run_start":
                raise Violation(f"line 1: stream must open with run_start, got {kind!r}")
            if kind == "run_start":
                check_keys(obj, RUN_START_KEYS, lineno)
                if lineno != 1:
                    raise Violation(f"line {lineno}: duplicate run_start (resume must append)")
                if obj["schema"] not in SCHEMA_VERSIONS:
                    raise Violation(
                        f"line {lineno}: schema {obj['schema']} not in {SCHEMA_VERSIONS}"
                    )
                schema = obj["schema"]
                if not (isinstance(obj["workers"], int) and obj["workers"] > 0):
                    raise Violation(f"line {lineno}: bad workers {obj['workers']!r}")
                workers = obj["workers"]
            elif kind == "record":
                optional = {"cum_block_bits"} if schema == 3 else frozenset()
                check_keys(obj, RECORD_KEYS, lineno, optional)
                it = obj["iteration"]
                if it <= last_iter:
                    raise Violation(f"line {lineno}: iteration {it} after {last_iter}")
                bits_sum = 0
                last_w = -1
                for pair in obj["worker_bits"]:
                    if not (isinstance(pair, list) and len(pair) == 2):
                        raise Violation(f"line {lineno}: bad worker_bits entry {pair!r}")
                    w, b = pair
                    if not (0 <= w < workers):
                        raise Violation(f"line {lineno}: worker {w} out of range")
                    if w <= last_w:
                        raise Violation(f"line {lineno}: worker_bits not ascending")
                    if b <= 0:
                        raise Violation(f"line {lineno}: non-positive bits for worker {w}")
                    last_w = w
                    bits_sum += b
                slots = workers * (it - last_iter)
                total = obj["committed"] + obj["censored"]
                if schema == 1:
                    # static graph: every worker reaches the gate each
                    # iteration, so the interval conserves exactly
                    if total != slots:
                        raise Violation(
                            f"line {lineno}: committed {obj['committed']} + censored "
                            f"{obj['censored']} != {slots} attempts"
                        )
                elif total > slots:
                    # v2 counts per-attempt: workers absent under churn
                    # attempt nothing, so the interval can undershoot but
                    # never exceed the slot budget
                    raise Violation(
                        f"line {lineno}: committed {obj['committed']} + censored "
                        f"{obj['censored']} > {slots} slots"
                    )
                if prev is not None:
                    for key in ("cum_rounds", "cum_bits", "cum_energy_j", "sim_time_s"):
                        if obj[key] < prev[key]:
                            raise Violation(
                                f"line {lineno}: {key} decreased "
                                f"({prev[key]} -> {obj[key]})"
                            )
                    if obj["cum_bits"] - prev["cum_bits"] != bits_sum:
                        raise Violation(
                            f"line {lineno}: interval bits {bits_sum} != cum_bits delta "
                            f"{obj['cum_bits'] - prev['cum_bits']}"
                        )
                if "cum_block_bits" in obj:
                    blocks = obj["cum_block_bits"]
                    if not (isinstance(blocks, list) and len(blocks) >= 2):
                        raise Violation(
                            f"line {lineno}: cum_block_bits must list >= 2 blocks"
                        )
                    if any(not isinstance(b, (int, float)) or b < 0 for b in blocks):
                        raise Violation(f"line {lineno}: negative cum_block_bits entry")
                    if sum(blocks) != obj["cum_bits"]:
                        raise Violation(
                            f"line {lineno}: cum_block_bits sum {sum(blocks)} != "
                            f"cum_bits {obj['cum_bits']}"
                        )
                    if prev is not None and "cum_block_bits" in prev:
                        pblocks = prev["cum_block_bits"]
                        if len(pblocks) != len(blocks):
                            raise Violation(
                                f"line {lineno}: block count changed "
                                f"({len(pblocks)} -> {len(blocks)})"
                            )
                        for i, (a, b) in enumerate(zip(pblocks, blocks)):
                            if b < a:
                                raise Violation(
                                    f"line {lineno}: cum_block_bits[{i}] decreased "
                                    f"({a} -> {b})"
                                )
                last_iter = it
                prev = obj
            elif kind == "checkpoint":
                check_keys(obj, CHECKPOINT_KEYS, lineno)
                # a checkpoint may land between record strides, but never
                # behind what the stream has already reported
                if obj["iteration"] < last_iter:
                    raise Violation(
                        f"line {lineno}: checkpoint at {obj['iteration']} behind "
                        f"record {last_iter}"
                    )
                if not obj["path"]:
                    raise Violation(f"line {lineno}: empty checkpoint path")
            elif kind in (
                "worker_leave",
                "worker_join",
                "worker_connect",
                "worker_disconnect",
                "stale_refresh",
            ):
                if schema == 1:
                    raise Violation(
                        f"line {lineno}: {kind} is a schema-2 event in a v1 stream"
                    )
                keys = STALE_REFRESH_KEYS if kind == "stale_refresh" else MEMBERSHIP_KEYS
                check_keys(obj, keys, lineno)
                w = obj["worker"]
                if not (0 <= w < workers):
                    raise Violation(f"line {lineno}: worker {w} out of range")
                if obj["iteration"] < last_iter:
                    raise Violation(
                        f"line {lineno}: {kind} at {obj['iteration']} behind "
                        f"record {last_iter}"
                    )
                if kind == "stale_refresh" and obj["staleness"] < 1:
                    raise Violation(f"line {lineno}: stale_refresh staleness < 1")
            else:
                raise Violation(f"line {lineno}: unknown event {kind!r}")
            counts[kind] += 1
    if counts["run_start"] != 1:
        raise Violation("stream has no run_start")
    if counts["record"] == 0:
        raise Violation("stream has no record events")
    return counts, last_iter


def main(argv):
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__.strip())
        return 0 if argv else 1
    for path in argv:
        try:
            counts, last_iter = validate(path)
        except Violation as v:
            print(f"{path}: INVALID — {v}", file=sys.stderr)
            return 1
        except OSError as e:
            print(f"{path}: {e}", file=sys.stderr)
            return 1
        dynamic = ""
        if counts["worker_leave"] or counts["worker_join"] or counts["stale_refresh"]:
            dynamic = (
                f", {counts['worker_leave']} leaves / {counts['worker_join']} joins"
                f" / {counts['stale_refresh']} stale refreshes"
            )
        if counts["worker_connect"] or counts["worker_disconnect"]:
            dynamic += (
                f", {counts['worker_connect']} connects"
                f" / {counts['worker_disconnect']} disconnects"
            )
        print(
            f"{path}: OK — {counts['record']} records to iteration {last_iter}, "
            f"{counts['checkpoint']} checkpoints{dynamic}"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
