//! Ablation benches for the design choices DESIGN.md calls out:
//! component ablation (censoring / quantization / both), penalty `rho`
//! sensitivity, censoring-threshold sensitivity (both extremes of §4),
//! initial bit width, and the Jacobian-vs-alternating schedule split.
//!
//! Run with: `cargo bench --bench bench_ablation`

use cq_ggadmm::experiments::sensitivity as sens;
use std::time::Instant;

fn timed<T>(name: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    println!("[{name}: {:.2}s]", t0.elapsed().as_secs_f64());
    out
}

fn main() {
    let iters = 250;
    let seed = 41;

    let pts = timed("component ablation", || sens::component_ablation(iters, seed));
    println!("{}", sens::render("component", &pts).render());

    let pts = timed("rho sweep", || {
        sens::rho_sweep(&[0.5, 2.0, 10.0, 30.0, 100.0], iters, seed)
    });
    println!("{}", sens::render("rho (GGADMM)", &pts).render());

    let pts = timed("tau0 sweep", || {
        sens::tau0_sweep(&[0.0, 0.05, 0.1, 0.5, 5.0, 50.0], 0.9, iters, seed)
    });
    println!("{}", sens::render("tau0 (C-GGADMM, xi=0.9)", &pts).render());

    let pts = timed("bits0 sweep", || sens::bits_sweep(&[2, 4, 8, 12], iters, seed));
    println!("{}", sens::render("bits0 (CQ-GGADMM)", &pts).render());

    println!("bench_ablation done");
}
