//! Figure benches: regenerate every table/figure of the paper's
//! evaluation (§7) and report wall-clock per series.
//!
//! The offline sandbox has no criterion, so this is a `harness = false`
//! bench binary with first-party timing; it prints the same summary rows
//! the paper's figures encode (loss-gap crossings per scheme on each
//! x-axis) plus Table 1 and the Theorem-2/3 rate study.
//!
//! Run with: `cargo bench --bench bench_figures`
//! Quick mode: `cargo bench --bench bench_figures -- --quick`

use cq_ggadmm::experiments::{self, ExecOptions};
use cq_ggadmm::metrics::save_traces;
use std::path::Path;
use std::time::Instant;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let exec = ExecOptions::default();

    println!("== Table 1: dataset inventory ==");
    println!("{}", experiments::table1().render());

    for id in ["fig2", "fig3", "fig4", "fig5"] {
        let mut spec = experiments::figure_by_id(id).unwrap();
        if quick {
            spec.iters_alt = spec.iters_alt.min(80);
            spec.iters_jacobian = spec.iters_jacobian.min(200);
            spec.target_gap = 1e-2;
        }
        let t0 = Instant::now();
        let res = experiments::run_figure(&spec, &exec);
        let dt = t0.elapsed();
        println!("== {} [{:.2}s] ==", res.title, dt.as_secs_f64());
        println!("{}", res.summary.render());
        let path = format!("results/bench_{}.csv", res.id);
        save_traces(&res.traces, Path::new(&path)).expect("csv");
    }

    {
        let mut spec = experiments::fig6();
        if quick {
            spec.base.iters_alt = 80;
            spec.base.iters_jacobian = 200;
            spec.base.target_gap = 1e-2;
        }
        let t0 = Instant::now();
        let results = experiments::run_fig6(&spec, &exec);
        println!(
            "== {} [{:.2}s] ==",
            spec.base.title,
            t0.elapsed().as_secs_f64()
        );
        for res in &results {
            println!("-- {} --\n{}", res.title, res.summary.render());
        }
    }

    {
        let t0 = Instant::now();
        let iters = if quick { 60 } else { 150 };
        let studies = experiments::rates::study(&[0.15, 0.3, 0.5, 0.8], 16, 11, iters);
        println!(
            "== Theorem 2/3 rate study [{:.2}s] ==",
            t0.elapsed().as_secs_f64()
        );
        println!("{}", experiments::rates::render(&studies).render());
    }

    println!("bench_figures done");
}
