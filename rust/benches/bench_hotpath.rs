//! Hot-path micro-benchmarks (first-party harness; no criterion offline).
//!
//! Covers every stage of the per-iteration pipeline — native (and, with
//! `--features pjrt`, PJRT) subproblem solves, quantization, bit-packing
//! codec, a full GGADMM / CQ-GGADMM iteration at paper scale, and topology
//! generation — prints ns/op, and emits the machine-readable
//! `BENCH_hotpath.json` (name -> ns/op) that the §Perf table in
//! EXPERIMENTS.md is regenerated from.
//!
//! Shootouts assert their wins instead of just reporting:
//! * **codec**: the word-level (u64) packer vs a faithful copy of the
//!   original bit-at-a-time loop on a d=10'000, 8-bit message;
//! * **fused Newton**: `LogisticSolver::update_into` (fused pass, analytic
//!   O(s) Armijo, persistent factor workspace) vs a faithful copy of the
//!   pre-fusion implementation;
//! * **incremental engine**: the censoring-aware run engine vs the
//!   from-scratch recompute path (`RunOptions::incremental = false`) at
//!   paper scale (N=32, d=50) under heavy censoring;
//! * **coordinator**: the sharded-executor coordinator (M workers on a
//!   fixed-size pool) vs a faithful copy of the seed thread-per-worker
//!   engine at N in {64, 256} — the sharded path must win at N = 256;
//! * **transport**: one round of broadcast frames over real loopback
//!   sockets at N in {64, 256}, the networked coordinator's coalesced
//!   one-flush-per-connection policy vs a write+flush per frame;
//! * **blocked linalg**: the cache-blocked `gram` / Cholesky
//!   `factor_into` / `solve_into` kernels vs the retained scalar
//!   references at d in {50, 200, 500};
//! * **kernel tiers at large d**: gram / factor / solve / matvec and the
//!   fused Newton step at d in {1000, 10000}, SIMD tier vs scalar tier
//!   and pooled vs serial (skipped entirely under `BENCH_SMOKE=1` — the
//!   d=10000 legs take minutes);
//! * **figure sweep**: pool-scheduled `run_figure`
//!   (`ExecOptions::sweep_threads`) vs the serial driver (asserted when
//!   the host has >= 4 cores).
//!
//! Run with: `cargo bench --bench bench_hotpath`; set `BENCH_SMOKE=1` for
//! the low-rep CI smoke mode and `BENCH_OUT=<path>` to redirect the JSON
//! (default: `<repo root>/BENCH_hotpath.json`).

use cq_ggadmm::algs::{AlgSpec, Problem, Run, RunOptions};
use cq_ggadmm::data::{partition_uniform, synthetic, Shard};
use cq_ggadmm::graph::Topology;
use cq_ggadmm::io::Json;
use cq_ggadmm::linalg::{Cholesky, Mat};
use cq_ggadmm::quant::{codec, QuantConfig, QuantMessage, Quantizer};
use cq_ggadmm::solver::{LinearSolver, LogisticSolver, SubproblemSolver};
use cq_ggadmm::util::rng::Pcg64;
use std::hint::black_box;
use std::time::Instant;

/// Result collector + timing policy (`BENCH_SMOKE=1` shrinks the timing
/// windows so CI can run the whole suite in seconds).
struct Harness {
    smoke: bool,
    results: Vec<(String, f64)>,
}

impl Harness {
    fn new() -> Harness {
        let smoke = std::env::var("BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
        if smoke {
            println!("(BENCH_SMOKE: low-rep smoke mode)");
        }
        Harness { smoke, results: Vec::new() }
    }

    /// Time `f` over enough repetitions for a stable ns/op estimate.
    fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> f64 {
        let warmup = if self.smoke { 1 } else { 3 };
        for _ in 0..warmup {
            f();
        }
        let window_ms = if self.smoke { 5 } else { 200 };
        let mut reps = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..reps {
                f();
            }
            let dt = t0.elapsed();
            if dt.as_millis() >= window_ms || reps >= 1 << 22 {
                let ns = dt.as_nanos() as f64 / reps as f64;
                self.record(name, ns);
                return ns;
            }
            reps *= 4;
        }
    }

    /// Record an externally measured ns/op (fixed-rep shootouts).
    fn record(&mut self, name: &str, ns: f64) {
        println!("{name:<44} {ns:>12.0} ns/op");
        self.results.push((name.to_string(), ns));
    }

    /// Emit the machine-readable perf trajectory artifact.
    fn write_json(&self) {
        let path = std::env::var("BENCH_OUT").unwrap_or_else(|_| {
            format!("{}/../BENCH_hotpath.json", env!("CARGO_MANIFEST_DIR"))
        });
        let results = Json::Obj(
            self.results
                .iter()
                .map(|(name, ns)| (name.clone(), Json::Num(*ns)))
                .collect(),
        );
        let doc = Json::Obj(vec![
            ("schema".into(), Json::Str("bench_hotpath/v1".into())),
            ("unit".into(), Json::Str("ns_per_op".into())),
            ("smoke".into(), Json::Bool(self.smoke)),
            (
                "provenance".into(),
                Json::Str("cargo bench --bench bench_hotpath".into()),
            ),
            ("results".into(), results),
        ]);
        std::fs::write(&path, doc.render()).expect("write BENCH_hotpath.json");
        println!("wrote {path}");
    }
}

/// Fixed-repetition paired timer for the asserted shootouts: both
/// contenders run the same number of operations in **interleaved**
/// blocks (A, B, A, B, ...), so a noisy scheduler episode lands on both
/// sides instead of one absorbing a whole window; best block per side is
/// returned (important for the short CI smoke runs).
fn min_block_pair_ns<FA: FnMut(), FB: FnMut()>(
    blocks: usize,
    reps: u64,
    mut a: FA,
    mut b: FB,
) -> (f64, f64) {
    let mut best_a = f64::INFINITY;
    let mut best_b = f64::INFINITY;
    for _ in 0..blocks {
        let t0 = Instant::now();
        for _ in 0..reps {
            a();
        }
        best_a = best_a.min(t0.elapsed().as_nanos() as f64 / reps as f64);
        let t0 = Instant::now();
        for _ in 0..reps {
            b();
        }
        best_b = best_b.min(t0.elapsed().as_nanos() as f64 / reps as f64);
    }
    (best_a, best_b)
}

/// The seed repo's bit-at-a-time encoder, kept verbatim as the shootout
/// reference (and as a differential check on the word-level packer).
fn bit_loop_encode(msg: &QuantMessage) -> Vec<u8> {
    fn push_bits(buf: &mut Vec<u8>, bitlen: &mut usize, value: u64, width: u32) {
        for i in 0..width {
            let bit = (value >> i) & 1;
            let byte_idx = *bitlen / 8;
            if byte_idx == buf.len() {
                buf.push(0);
            }
            if bit == 1 {
                buf[byte_idx] |= 1 << (*bitlen % 8);
            }
            *bitlen += 1;
        }
    }
    let mut buf = Vec::with_capacity((msg.payload_bits() as usize).div_ceil(8));
    let mut bitlen = 0usize;
    push_bits(&mut buf, &mut bitlen, (msg.radius as f32).to_bits() as u64, 32);
    push_bits(&mut buf, &mut bitlen, msg.bits as u64, 32);
    for &c in &msg.codes {
        push_bits(&mut buf, &mut bitlen, c as u64, msg.bits);
    }
    buf
}

/// The seed repo's bit-at-a-time decoder (shootout reference).
fn bit_loop_decode(buf: &[u8], d: usize) -> Option<QuantMessage> {
    fn read_bits(buf: &[u8], pos: &mut usize, width: u32) -> Option<u64> {
        let mut out = 0u64;
        for i in 0..width {
            let byte_idx = *pos / 8;
            if byte_idx >= buf.len() {
                return None;
            }
            let bit = (buf[byte_idx] >> (*pos % 8)) & 1;
            out |= (bit as u64) << i;
            *pos += 1;
        }
        Some(out)
    }
    let mut pos = 0usize;
    let radius = f32::from_bits(read_bits(buf, &mut pos, 32)? as u32) as f64;
    let bits = read_bits(buf, &mut pos, 32)? as u32;
    if bits == 0 || bits > 32 || !(radius.is_finite()) || radius < 0.0 {
        return None;
    }
    let mut codes = Vec::with_capacity(d);
    for _ in 0..d {
        codes.push(read_bits(buf, &mut pos, bits)? as u32);
    }
    Some(QuantMessage { codes, radius, bits })
}

/// Codec shootout on the acceptance workload: d=10'000 coordinates at 8
/// bits each (the paper-scale "large model" message).
fn bench_codec_shootout(h: &mut Harness) {
    println!("-- codec shootout: d=10000, 8-bit codes --");
    let d = 10_000usize;
    let codes: Vec<u32> = (0..d as u32)
        .map(|i| i.wrapping_mul(2_654_435_761) & 0xFF)
        .collect();
    let msg = QuantMessage { codes, radius: 1.0, bits: 8 };

    let word_bytes = codec::encode(&msg);
    let ref_bytes = bit_loop_encode(&msg);
    assert_eq!(word_bytes, ref_bytes, "codecs must agree bit-for-bit");
    assert_eq!(bit_loop_decode(&ref_bytes, d).unwrap(), msg);

    let enc_word = h.bench("codec encode d=10k b=8 (word-level)", || {
        black_box(codec::encode(black_box(&msg)));
    });
    let dec_word = h.bench("codec decode d=10k b=8 (word-level)", || {
        black_box(codec::decode(black_box(&word_bytes), d)).unwrap();
    });
    let enc_bit = h.bench("codec encode d=10k b=8 (seed bit-loop)", || {
        black_box(bit_loop_encode(black_box(&msg)));
    });
    let dec_bit = h.bench("codec decode d=10k b=8 (seed bit-loop)", || {
        black_box(bit_loop_decode(black_box(&ref_bytes), d)).unwrap();
    });
    println!(
        "word-level speedup: encode {:.1}x, decode {:.1}x, encode+decode {:.1}x",
        enc_bit / enc_word,
        dec_bit / dec_word,
        (enc_bit + dec_bit) / (enc_word + dec_word)
    );
    assert!(
        enc_word + dec_word < enc_bit + dec_bit,
        "word-level codec must beat the bit-loop on encode+decode \
         ({:.0} vs {:.0} ns)",
        enc_word + dec_word,
        enc_bit + dec_bit
    );
}

// ---------------------------------------------------------------------
// Seed-faithful copy of the pre-fusion logistic Newton solver (the
// shootout reference): per-step probability/Hessian/factor allocations,
// naive (non-unrolled) dot products, and an O(s d) objective evaluation
// per Armijo trial.
// ---------------------------------------------------------------------

fn naive_dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn naive_norm2(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

struct SeedLogisticNewton {
    x: Mat,
    y: Vec<f64>,
    mu0: f64,
    rho: f64,
    rho_dn: f64,
    inv_s: f64,
    tol: f64,
    max_newton: usize,
    lin: Vec<f64>,
    grad: Vec<f64>,
    step: Vec<f64>,
    cand: Vec<f64>,
}

impl SeedLogisticNewton {
    fn new(x: Mat, y: Vec<f64>, mu0: f64, rho: f64, degree: usize) -> SeedLogisticNewton {
        let inv_s = 1.0 / y.len() as f64;
        let d = x.cols();
        SeedLogisticNewton {
            x,
            y,
            mu0,
            rho,
            rho_dn: rho * degree as f64,
            inv_s,
            tol: 1e-10,
            max_newton: 50,
            lin: vec![0.0; d],
            grad: vec![0.0; d],
            step: vec![0.0; d],
            cand: vec![0.0; d],
        }
    }

    fn probs(&self, theta: &[f64]) -> Vec<f64> {
        (0..self.y.len())
            .map(|i| {
                let z = self.y[i] * naive_dot(self.x.row(i), theta);
                1.0 / (1.0 + z.exp())
            })
            .collect()
    }

    fn hess_data(&self, probs: &[f64]) -> Mat {
        let d = self.x.cols();
        let mut h = Mat::zeros(d, d);
        for (i, &p) in probs.iter().enumerate() {
            let w = p * (1.0 - p);
            if w <= 0.0 {
                continue;
            }
            for a in 0..d {
                let wa = w * self.x.row(i)[a];
                if wa == 0.0 {
                    continue;
                }
                let (row, hrow) = (self.x.row(i), h.row_mut(a));
                for b in a..d {
                    hrow[b] += wa * row[b];
                }
            }
        }
        for a in 0..d {
            for b in 0..a {
                h[(a, b)] = h[(b, a)];
            }
        }
        h
    }

    fn loss(&self, theta: &[f64]) -> f64 {
        let mut acc = 0.0;
        for i in 0..self.y.len() {
            let z = self.y[i] * naive_dot(self.x.row(i), theta);
            acc += if z > 0.0 {
                (-z).exp().ln_1p()
            } else {
                -z + z.exp().ln_1p()
            };
        }
        self.inv_s * acc + 0.5 * self.mu0 * naive_dot(theta, theta)
    }

    fn sub_objective(&self, theta: &[f64], lin: &[f64]) -> f64 {
        self.loss(theta) + naive_dot(theta, lin) + 0.5 * self.rho_dn * naive_dot(theta, theta)
    }

    fn update_into(&mut self, alpha: &[f64], nbr_sum: &[f64], theta: &mut [f64]) {
        let d = theta.len();
        for i in 0..d {
            self.lin[i] = alpha[i] - self.rho * nbr_sum[i];
        }
        for _ in 0..self.max_newton {
            let probs = self.probs(theta);
            self.grad.iter_mut().for_each(|g| *g = 0.0);
            for (i, &p) in probs.iter().enumerate() {
                let gscale = -self.y[i] * p;
                let row = self.x.row(i);
                for a in 0..d {
                    self.grad[a] += gscale * row[a];
                }
            }
            for i in 0..d {
                self.grad[i] = self.inv_s * self.grad[i]
                    + self.mu0 * theta[i]
                    + self.lin[i]
                    + self.rho_dn * theta[i];
            }
            let gnorm = naive_norm2(&self.grad);
            if gnorm < self.tol * (1.0 + naive_norm2(theta)) {
                break;
            }
            let hmat = self
                .hess_data(&probs)
                .scale(self.inv_s)
                .add_diag(self.mu0 + self.rho_dn);
            Cholesky::new(&hmat)
                .expect("subproblem Hessian is SPD")
                .solve_into(&self.grad, &mut self.step);
            let f0 = self.sub_objective(theta, &self.lin);
            let slope = naive_dot(&self.grad, &self.step);
            let mut t = 1.0;
            loop {
                for j in 0..d {
                    self.cand[j] = theta[j] - t * self.step[j];
                }
                if self.sub_objective(&self.cand, &self.lin) <= f0 - 1e-4 * t * slope
                    || t < 1e-8
                {
                    theta.copy_from_slice(&self.cand);
                    break;
                }
                t *= 0.5;
            }
        }
    }
}

/// Fused-Newton shootout: the production `LogisticSolver::update_into`
/// must beat the seed implementation on identical cold-start solves.
fn bench_newton_shootout(h: &mut Harness) {
    println!("-- fused Newton shootout: s=200, d=50, cold start --");
    let d = 50;
    let s = 200;
    let mut rng = Pcg64::new(77);
    let mut x = Mat::zeros(s, d);
    for i in 0..s {
        for j in 0..d {
            x[(i, j)] = rng.normal();
        }
    }
    let y: Vec<f64> = (0..s)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
        .collect();
    let alpha = rng.normal_vec(d);
    let nbr = rng.normal_vec(d);
    let mut fused = LogisticSolver::new(x.clone(), y.clone(), 0.01, 0.1, 7);
    let mut seed = SeedLogisticNewton::new(x, y, 0.01, 0.1, 7);

    // both must land on the same minimizer
    let mut theta_fused = vec![0.0; d];
    fused.update_into(&alpha, &nbr, &mut theta_fused);
    let mut theta_seed = vec![0.0; d];
    seed.update_into(&alpha, &nbr, &mut theta_seed);
    for (a, b) in theta_fused.iter().zip(&theta_seed) {
        assert!((a - b).abs() < 1e-6, "fused {a} vs seed {b}");
    }

    let (blocks, reps) = if h.smoke { (4, 8) } else { (3, 60) };
    let mut theta_a = vec![0.0; d];
    let mut theta_b = vec![0.0; d];
    let (fused_ns, seed_ns) = min_block_pair_ns(
        blocks,
        reps,
        || {
            theta_a.iter_mut().for_each(|v| *v = 0.0);
            fused.update_into(black_box(&alpha), black_box(&nbr), black_box(&mut theta_a));
        },
        || {
            theta_b.iter_mut().for_each(|v| *v = 0.0);
            seed.update_into(black_box(&alpha), black_box(&nbr), black_box(&mut theta_b));
        },
    );
    h.record("logistic Newton s=200 d=50 (fused)", fused_ns);
    h.record("logistic Newton s=200 d=50 (seed impl)", seed_ns);
    println!("fused Newton speedup: {:.2}x", seed_ns / fused_ns);
    // smoke mode tolerates shared-runner noise; the full run is strict
    let slack = if h.smoke { 1.25 } else { 1.0 };
    assert!(
        fused_ns < seed_ns * slack,
        "fused Newton update_into must beat the seed implementation \
         ({fused_ns:.0} vs {seed_ns:.0} ns, slack {slack})"
    );
}

/// Incremental-engine shootout at paper scale: N=32, d=50, dense graph,
/// heavy censoring — the from-scratch engine rebuilds every neighbor sum
/// and dual increment each iteration even though almost no link commits.
fn bench_incremental_shootout(h: &mut Harness) {
    println!("-- incremental engine shootout: N=32, d=50, heavy censoring --");
    let n = 32;
    let d = 50;
    let ds = synthetic::linear_dataset(n * 50, d, 31);
    let topo = Topology::random_bipartite(n, 0.6, 31);
    let problem = Problem::new(&ds, &topo, 30.0, 0.0, 31);
    // slow threshold decay keeps the run censored for the whole
    // measurement horizon (first transmissions always commit)
    let spec = AlgSpec::c_ggadmm(1.0, 0.999);
    let mk = |incremental: bool| {
        Run::new(
            problem.clone(),
            topo.clone(),
            spec.clone(),
            RunOptions { record_every: u64::MAX, incremental, ..RunOptions::default() },
        )
    };
    let mut inc = mk(true);
    let mut scr = mk(false);
    // identical trajectories (bit-for-bit; see tests/incremental.rs), so
    // the workloads stay perfectly matched while both advance in lockstep
    let (warmup, blocks, reps) = if h.smoke { (30, 4, 40) } else { (60, 3, 300) };
    for _ in 0..warmup {
        inc.step();
        scr.step();
    }
    let (inc_ns, scr_ns) =
        min_block_pair_ns(blocks, reps, || inc.step(), || scr.step());
    h.record("C-GGADMM iter N=32 d=50 (incremental)", inc_ns);
    h.record("C-GGADMM iter N=32 d=50 (scratch recompute)", scr_ns);
    println!("incremental engine speedup: {:.2}x", scr_ns / inc_ns);
    // smoke mode tolerates shared-runner noise; the full run is strict
    let slack = if h.smoke { 1.25 } else { 1.0 };
    assert!(
        inc_ns < scr_ns * slack,
        "censoring-aware incremental iteration must beat the scratch \
         recompute path ({inc_ns:.0} vs {scr_ns:.0} ns, slack {slack})"
    );
}

/// Blocked-vs-scalar dense kernel shootouts at d in {50, 200, 500}: the
/// SYRK-style Gram product, the right-looking blocked Cholesky and the
/// unit-stride substitution solves against the seed scalar references
/// retained on `Mat`/`Cholesky`.
fn bench_blocked_linalg_shootout(h: &mut Harness) {
    println!("-- blocked linalg shootout: gram / factor / solve --");
    let slack = if h.smoke { 1.25 } else { 1.0 };
    for &d in &[50usize, 200, 500] {
        let mut rng = Pcg64::new(d as u64);
        let mut x = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                x[(i, j)] = rng.normal();
            }
        }
        let spd = x.gram().add_diag(d as f64 * 0.1);
        let b = rng.normal_vec(d);

        // reps sized so one block is a few ms at the largest d; smoke
        // mode keeps enough reps/blocks at small d that a single noisy
        // scheduler episode on a shared runner cannot flip the shootout
        let cubic_reps = if h.smoke {
            (4_000_000 / (d * d * d)).clamp(1, 50) as u64
        } else {
            (40_000_000 / (d * d * d)).clamp(1, 200) as u64
        };
        let blocks = if h.smoke { 4 } else { 3 };

        // gram
        let (blk, sca) = min_block_pair_ns(
            blocks,
            cubic_reps,
            || {
                black_box(black_box(&x).gram());
            },
            || {
                black_box(black_box(&x).gram_scalar());
            },
        );
        h.record(&format!("gram d={d} (blocked)"), blk);
        h.record(&format!("gram d={d} (scalar ref)"), sca);
        println!("gram d={d} speedup: {:.2}x", sca / blk);
        assert!(
            blk < sca * slack,
            "blocked gram must beat scalar at d={d} ({blk:.0} vs {sca:.0} ns, slack {slack})"
        );

        // Cholesky factor_into
        let mut ws_blocked = Cholesky::workspace(d);
        let mut ws_scalar = Cholesky::workspace(d);
        let (blk, sca) = min_block_pair_ns(
            blocks,
            cubic_reps,
            || {
                assert!(ws_blocked.factor_into(black_box(&spd)));
            },
            || {
                assert!(ws_scalar.factor_into_scalar(black_box(&spd)));
            },
        );
        h.record(&format!("cholesky factor_into d={d} (blocked)"), blk);
        h.record(&format!("cholesky factor_into d={d} (scalar ref)"), sca);
        println!("factor_into d={d} speedup: {:.2}x", sca / blk);
        assert!(
            blk < sca * slack,
            "blocked factor_into must beat scalar at d={d} ({blk:.0} vs {sca:.0} ns)"
        );

        // triangular solves (quadratic: scale reps up)
        let solve_reps = if h.smoke {
            cubic_reps * 16
        } else {
            cubic_reps * (d as u64 / 4).max(8)
        };
        let ch = Cholesky::new(&spd).unwrap();
        let mut out_a = vec![0.0; d];
        let mut out_b = vec![0.0; d];
        let (blk, sca) = min_block_pair_ns(
            blocks,
            solve_reps,
            || {
                ch.solve_into(black_box(&b), black_box(&mut out_a));
            },
            || {
                ch.solve_into_scalar(black_box(&b), black_box(&mut out_b));
            },
        );
        h.record(&format!("cholesky solve d={d} (blocked)"), blk);
        h.record(&format!("cholesky solve d={d} (scalar ref)"), sca);
        println!("solve d={d} speedup: {:.2}x", sca / blk);
        assert!(
            blk < sca * slack,
            "blocked solve must beat scalar at d={d} ({blk:.0} vs {sca:.0} ns)"
        );
    }

    // the blocked multi-RHS inverse vs the seed per-column formulation
    let d = 200;
    let mut rng = Pcg64::new(9);
    let mut x = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            x[(i, j)] = rng.normal();
        }
    }
    let spd = x.gram().add_diag(d as f64 * 0.1);
    let ch = Cholesky::new(&spd).unwrap();
    let (blocks, reps) = if h.smoke { (2, 1) } else { (3, 5) };
    let (blk, sca) = min_block_pair_ns(
        blocks,
        reps,
        || {
            black_box(ch.inverse());
        },
        || {
            // seed formulation: one allocated solve per identity column
            let mut inv = Mat::zeros(d, d);
            let mut e = vec![0.0; d];
            for j in 0..d {
                e[j] = 1.0;
                let col = ch.solve(&e);
                e[j] = 0.0;
                for i in 0..d {
                    inv[(i, j)] = col[i];
                }
            }
            black_box(inv);
        },
    );
    h.record("cholesky inverse d=200 (blocked multi-RHS)", blk);
    h.record("cholesky inverse d=200 (per-column)", sca);
    println!("inverse d=200 speedup: {:.2}x", sca / blk);
}

/// Fill an `r x c` matrix with standard normals.
fn random_mat(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    let mut x = Mat::zeros(r, c);
    for i in 0..r {
        for j in 0..c {
            x[(i, j)] = rng.normal();
        }
    }
    x
}

/// Large-dimension kernel-tier shootouts — the acceptance matrix for the
/// SIMD tier: d in {1000, 10000} for gram / Cholesky factor / solve (plus
/// matvec and the fused Newton step at the sizes where they are
/// tractable), asserting both SIMD-vs-scalar (when the host has AVX2+FMA)
/// and pooled-vs-serial (when the host has >= 4 cores).  Minutes of
/// wall-clock at d=10000, so the whole matrix is skipped under
/// `BENCH_SMOKE=1` — run the full `cargo bench --bench bench_hotpath` to
/// exercise it.
fn bench_large_linalg_shootout(h: &mut Harness) {
    use cq_ggadmm::linalg::block::{self, KernelCtx};
    use cq_ggadmm::linalg::{kernel_tier, set_kernel_tier, KernelTier};

    if h.smoke {
        println!("(large-d kernel-tier shootouts skipped under BENCH_SMOKE=1)");
        return;
    }
    println!("-- large-d kernel-tier shootouts: d in {{1000, 10000}} --");
    let simd = KernelTier::vectorized();
    let tier = kernel_tier();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if simd.is_none() {
        println!("(SIMD-vs-scalar assertions skipped: no vectorized tier on this host)");
    }
    if cores < 4 {
        println!("(pooled-vs-serial assertions skipped: only {cores} cores available)");
    }

    // ---------------- d = 1000 ----------------
    let d = 1000usize;
    let x = random_mat(d, d, 1000);
    let mut out_a = Mat::zeros(d, d);
    let mut out_b = Mat::zeros(d, d);

    if let Some(t) = simd {
        let (vec_ns, sca_ns) = min_block_pair_ns(
            3,
            2,
            || block::gram_into_ctx(KernelCtx::serial(t), black_box(&x), &mut out_a),
            || {
                block::gram_into_ctx(
                    KernelCtx::serial(KernelTier::Scalar),
                    black_box(&x),
                    &mut out_b,
                )
            },
        );
        h.record("gram d=1000 (simd serial)", vec_ns);
        h.record("gram d=1000 (scalar serial)", sca_ns);
        println!("gram d=1000 simd speedup: {:.2}x", sca_ns / vec_ns);
        assert!(
            vec_ns < sca_ns,
            "SIMD gram must beat scalar at d=1000 ({vec_ns:.0} vs {sca_ns:.0} ns)"
        );
    }
    let (pool_ns, ser_ns) = min_block_pair_ns(
        3,
        2,
        || block::gram_into_ctx(KernelCtx::with_tier(tier), black_box(&x), &mut out_a),
        || block::gram_into_ctx(KernelCtx::serial(tier), black_box(&x), &mut out_b),
    );
    h.record("gram d=1000 (pooled)", pool_ns);
    h.record("gram d=1000 (serial)", ser_ns);
    println!("gram d=1000 pool speedup: {:.2}x ({cores} cores)", ser_ns / pool_ns);
    if cores >= 4 {
        assert!(
            pool_ns < ser_ns,
            "pooled gram must beat serial at d=1000 on a {cores}-core host \
             ({pool_ns:.0} vs {ser_ns:.0} ns)"
        );
    }

    let spd = x.gram().add_diag(d as f64 * 0.1);
    let mut ws_a = Cholesky::workspace(d);
    let mut ws_b = Cholesky::workspace(d);
    if let Some(t) = simd {
        let (vec_ns, sca_ns) = min_block_pair_ns(
            3,
            2,
            || assert!(ws_a.factor_into_ctx(KernelCtx::serial(t), black_box(&spd))),
            || {
                let ctx = KernelCtx::serial(KernelTier::Scalar);
                assert!(ws_b.factor_into_ctx(ctx, black_box(&spd)));
            },
        );
        h.record("cholesky factor d=1000 (simd serial)", vec_ns);
        h.record("cholesky factor d=1000 (scalar serial)", sca_ns);
        println!("factor d=1000 simd speedup: {:.2}x", sca_ns / vec_ns);
        assert!(
            vec_ns < sca_ns,
            "SIMD factor must beat scalar at d=1000 ({vec_ns:.0} vs {sca_ns:.0} ns)"
        );
    }
    let (pool_ns, ser_ns) = min_block_pair_ns(
        3,
        2,
        || assert!(ws_a.factor_into_ctx(KernelCtx::with_tier(tier), black_box(&spd))),
        || assert!(ws_b.factor_into_ctx(KernelCtx::serial(tier), black_box(&spd))),
    );
    h.record("cholesky factor d=1000 (pooled)", pool_ns);
    h.record("cholesky factor d=1000 (serial)", ser_ns);
    println!("factor d=1000 pool speedup: {:.2}x ({cores} cores)", ser_ns / pool_ns);
    if cores >= 4 {
        assert!(
            pool_ns < ser_ns,
            "pooled factor must beat serial at d=1000 on a {cores}-core host \
             ({pool_ns:.0} vs {ser_ns:.0} ns)"
        );
    }

    if let Some(t) = simd {
        let mut rng = Pcg64::new(1001);
        let b = rng.normal_vec(d);
        let mut sol_a = vec![0.0; d];
        let mut sol_b = vec![0.0; d];
        let (vec_ns, sca_ns) = min_block_pair_ns(
            3,
            64,
            || ws_a.solve_into_with_tier(t, black_box(&b), &mut sol_a),
            || ws_a.solve_into_with_tier(KernelTier::Scalar, black_box(&b), &mut sol_b),
        );
        h.record("cholesky solve d=1000 (simd)", vec_ns);
        h.record("cholesky solve d=1000 (scalar)", sca_ns);
        println!("solve d=1000 simd speedup: {:.2}x", sca_ns / vec_ns);
        assert!(
            vec_ns < sca_ns,
            "SIMD solve must beat scalar at d=1000 ({vec_ns:.0} vs {sca_ns:.0} ns)"
        );
    }

    // fused Newton step at s=1000, d=1000: the whole production solver
    // (blocked matvec margins, weighted-Gram Hessian, blocked Cholesky)
    // under each tier.  The bench binary is single-threaded, so flipping
    // the process-global tier between the paired closures is safe.
    if let Some(t) = simd {
        println!("-- fused Newton tier shootout: s=1000, d=1000, cold start --");
        let xl = random_mat(1000, d, 1002);
        let mut rng = Pcg64::new(1003);
        let yl: Vec<f64> =
            (0..1000).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let alpha = rng.normal_vec(d);
        let nbr = rng.normal_vec(d);
        let mut fused_a = LogisticSolver::new(xl.clone(), yl.clone(), 0.01, 0.1, 7);
        let mut fused_b = LogisticSolver::new(xl, yl, 0.01, 0.1, 7);
        let mut theta_a = vec![0.0; d];
        let mut theta_b = vec![0.0; d];
        let (vec_ns, sca_ns) = min_block_pair_ns(
            2,
            1,
            || {
                set_kernel_tier(t);
                theta_a.iter_mut().for_each(|v| *v = 0.0);
                fused_a.update_into(black_box(&alpha), black_box(&nbr), &mut theta_a);
            },
            || {
                set_kernel_tier(KernelTier::Scalar);
                theta_b.iter_mut().for_each(|v| *v = 0.0);
                fused_b.update_into(black_box(&alpha), black_box(&nbr), &mut theta_b);
            },
        );
        set_kernel_tier(tier);
        h.record("logistic Newton s=1000 d=1000 (simd tier)", vec_ns);
        h.record("logistic Newton s=1000 d=1000 (scalar tier)", sca_ns);
        println!("fused Newton d=1000 simd speedup: {:.2}x", sca_ns / vec_ns);
        assert!(
            vec_ns < sca_ns,
            "SIMD fused Newton must beat scalar at d=1000 ({vec_ns:.0} vs {sca_ns:.0} ns)"
        );
    }
    drop(spd);
    drop(ws_a);
    drop(ws_b);
    drop(out_a);
    drop(out_b);
    drop(x);

    // ---------------- d = 10000 ----------------
    // A full 10k x 10k Gram output would be 800 MB, so the d=10000 gram
    // exercises the long-accumulation axis instead: X is 10000 x 256.
    {
        let x10 = random_mat(10_000, 256, 2000);
        let mut g_a = Mat::zeros(256, 256);
        let mut g_b = Mat::zeros(256, 256);
        if let Some(t) = simd {
            let (vec_ns, sca_ns) = min_block_pair_ns(
                3,
                4,
                || block::gram_into_ctx(KernelCtx::serial(t), black_box(&x10), &mut g_a),
                || {
                    block::gram_into_ctx(
                        KernelCtx::serial(KernelTier::Scalar),
                        black_box(&x10),
                        &mut g_b,
                    )
                },
            );
            h.record("gram 10000x256 (simd serial)", vec_ns);
            h.record("gram 10000x256 (scalar serial)", sca_ns);
            println!("gram 10000x256 simd speedup: {:.2}x", sca_ns / vec_ns);
            assert!(
                vec_ns < sca_ns,
                "SIMD gram must beat scalar at 10000x256 ({vec_ns:.0} vs {sca_ns:.0} ns)"
            );
        }
    }

    // matvec at d=10000 (2048 rows also crosses the pooling threshold)
    {
        let a = random_mat(2048, 10_000, 2001);
        let mut rng = Pcg64::new(2002);
        let v = rng.normal_vec(10_000);
        let mut mv_a = vec![0.0; 2048];
        let mut mv_b = vec![0.0; 2048];
        if let Some(t) = simd {
            let (vec_ns, sca_ns) = min_block_pair_ns(
                3,
                8,
                || block::matvec_into_ctx(KernelCtx::serial(t), black_box(&a), &v, &mut mv_a),
                || {
                    let ctx = KernelCtx::serial(KernelTier::Scalar);
                    block::matvec_into_ctx(ctx, black_box(&a), &v, &mut mv_b);
                },
            );
            h.record("matvec 2048x10000 (simd serial)", vec_ns);
            h.record("matvec 2048x10000 (scalar serial)", sca_ns);
            println!("matvec d=10000 simd speedup: {:.2}x", sca_ns / vec_ns);
            assert!(
                vec_ns < sca_ns,
                "SIMD matvec must beat scalar at d=10000 ({vec_ns:.0} vs {sca_ns:.0} ns)"
            );
        }
        let (pool_ns, ser_ns) = min_block_pair_ns(
            3,
            8,
            || block::matvec_into_ctx(KernelCtx::with_tier(tier), black_box(&a), &v, &mut mv_a),
            || block::matvec_into_ctx(KernelCtx::serial(tier), black_box(&a), &v, &mut mv_b),
        );
        h.record("matvec 2048x10000 (pooled)", pool_ns);
        h.record("matvec 2048x10000 (serial)", ser_ns);
        println!("matvec d=10000 pool speedup: {:.2}x ({cores} cores)", ser_ns / pool_ns);
        if cores >= 4 {
            assert!(
                pool_ns < ser_ns,
                "pooled matvec must beat serial at d=10000 on a {cores}-core host \
                 ({pool_ns:.0} vs {ser_ns:.0} ns)"
            );
        }
    }

    // Cholesky factor + solve at d=10000 (800 MB matrix, ~3e11 flops):
    // each variant is timed once — the run is long enough that a single
    // shot is stable.  The SPD input is a scaled random symmetric matrix
    // plus a dominant diagonal (semicircle radius 1, diagonal 10), built
    // directly because forming it as a Gram product would cost more than
    // the factorization itself.
    {
        let d = 10_000usize;
        let mut rng = Pcg64::new(3000);
        let mut spd = Mat::zeros(d, d);
        let off = 0.5 / (d as f64).sqrt();
        for i in 0..d {
            for j in 0..=i {
                let v = if i == j { 10.0 } else { rng.normal() * off };
                spd[(i, j)] = v;
                spd[(j, i)] = v;
            }
        }
        let mut ws = Cholesky::workspace(d);
        let time_factor = |ws: &mut Cholesky, ctx: KernelCtx, a: &Mat| {
            let t0 = Instant::now();
            assert!(ws.factor_into_ctx(ctx, a));
            t0.elapsed().as_nanos() as f64
        };
        let sca_ns = time_factor(&mut ws, KernelCtx::serial(KernelTier::Scalar), &spd);
        h.record("cholesky factor d=10000 (scalar serial)", sca_ns);
        let mut vec_ns = sca_ns;
        if let Some(t) = simd {
            vec_ns = time_factor(&mut ws, KernelCtx::serial(t), &spd);
            h.record("cholesky factor d=10000 (simd serial)", vec_ns);
            println!("factor d=10000 simd speedup: {:.2}x", sca_ns / vec_ns);
            assert!(
                vec_ns < sca_ns,
                "SIMD factor must beat scalar at d=10000 ({vec_ns:.0} vs {sca_ns:.0} ns)"
            );
        }
        let pool_ns = time_factor(&mut ws, KernelCtx::with_tier(tier), &spd);
        h.record("cholesky factor d=10000 (pooled)", pool_ns);
        println!("factor d=10000 pool speedup: {:.2}x ({cores} cores)", vec_ns / pool_ns);
        if cores >= 4 {
            assert!(
                pool_ns < vec_ns,
                "pooled factor must beat serial at d=10000 on a {cores}-core host \
                 ({pool_ns:.0} vs {vec_ns:.0} ns)"
            );
        }
        drop(spd);

        if let Some(t) = simd {
            let b = rng.normal_vec(d);
            let mut sol_a = vec![0.0; d];
            let mut sol_b = vec![0.0; d];
            let (vec_ns, sca_ns) = min_block_pair_ns(
                3,
                4,
                || ws.solve_into_with_tier(t, black_box(&b), &mut sol_a),
                || ws.solve_into_with_tier(KernelTier::Scalar, black_box(&b), &mut sol_b),
            );
            h.record("cholesky solve d=10000 (simd)", vec_ns);
            h.record("cholesky solve d=10000 (scalar)", sca_ns);
            println!("solve d=10000 simd speedup: {:.2}x", sca_ns / vec_ns);
            assert!(
                vec_ns < sca_ns,
                "SIMD solve must beat scalar at d=10000 ({vec_ns:.0} vs {sca_ns:.0} ns)"
            );
        }
    }
}

/// Figure-sweep shootout: pool-scheduled `run_figure` vs the serial
/// driver on a scaled-down fig2.  Determinism is checked first (the
/// pooled traces must equal the serial ones bit-for-bit); the wall-clock
/// win is asserted when the host has >= 4 cores.
fn bench_sweep_shootout(h: &mut Harness) {
    use cq_ggadmm::experiments::{self, ExecOptions};
    println!("-- figure-sweep shootout: pool-scheduled vs serial driver --");
    let mut spec = experiments::fig2();
    spec.workers = 6;
    spec.iters_alt = if h.smoke { 30 } else { 60 };
    spec.iters_jacobian = if h.smoke { 120 } else { 240 };
    spec.target_gap = 1e-2;
    let serial_exec = ExecOptions { record_every: 10, sweep_threads: 1, ..Default::default() };
    let pooled_exec = ExecOptions { record_every: 10, sweep_threads: 4, ..Default::default() };

    // determinism: pool scheduling must not change a single bit
    let a = experiments::run_figure(&spec, &serial_exec);
    let b = experiments::run_figure(&spec, &pooled_exec);
    for (x, y) in a.traces.iter().zip(&b.traces) {
        assert_eq!(x.algorithm, y.algorithm);
        assert_eq!(x.points.len(), y.points.len());
        for (p, q) in x.points.iter().zip(&y.points) {
            assert_eq!(p.loss_gap.to_bits(), q.loss_gap.to_bits(), "{}", x.algorithm);
            assert_eq!(p.cum_bits, q.cum_bits);
        }
    }

    let blocks = 2;
    let (pooled_ns, serial_ns) = min_block_pair_ns(
        blocks,
        1,
        || {
            black_box(experiments::run_figure(black_box(&spec), &pooled_exec));
        },
        || {
            black_box(experiments::run_figure(black_box(&spec), &serial_exec));
        },
    );
    h.record("figure sweep fig2-small (pooled, 4 jobs)", pooled_ns);
    h.record("figure sweep fig2-small (serial driver)", serial_ns);
    println!("sweep speedup: {:.2}x", serial_ns / pooled_ns);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores >= 4 {
        let slack = if h.smoke { 1.1 } else { 1.0 };
        assert!(
            pooled_ns < serial_ns * slack,
            "pool-scheduled sweep must beat the serial driver on a {cores}-core host \
             ({pooled_ns:.0} vs {serial_ns:.0} ns)"
        );
    } else {
        println!("(sweep shootout assertion skipped: only {cores} cores available)");
    }
}

// ---------------------------------------------------------------------
// Seed-faithful copy of the pre-refactor thread-per-worker coordinator
// (the shootout reference): one OS thread per simulated worker, mpsc
// channels, BTreeMap neighbor state, per-round candidate/payload
// allocations, from-scratch neighbor sums every phase, and f32
// full-precision payloads — everything the sharded executor replaced.
// ---------------------------------------------------------------------

mod seed_coordinator {
    use cq_ggadmm::algs::{AlgSpec, Problem};
    use cq_ggadmm::censor::{gate, CensorConfig, Gate};
    use cq_ggadmm::comm::{CommLog, EnergyModel, Transmission};
    use cq_ggadmm::graph::Topology;
    use cq_ggadmm::quant::{codec, Quantizer};
    use cq_ggadmm::solver::{LinearSolver, SubproblemSolver};
    use cq_ggadmm::util::rng::Pcg64;
    use std::collections::BTreeMap;
    use std::sync::mpsc::{channel, Receiver, Sender};

    #[derive(Clone)]
    enum Payload {
        Full(Vec<u8>),
        Quantized(Vec<u8>),
    }

    impl Payload {
        fn bits(&self, d: usize) -> u64 {
            match self {
                Payload::Full(_) => 32 * d as u64,
                Payload::Quantized(bytes) => codec::decode(bytes, d)
                    .map(|m| m.payload_bits())
                    .unwrap_or((bytes.len() * 8) as u64),
            }
        }
    }

    enum Command {
        Phase { k: u64 },
        Deliver { from: usize, payload: Payload },
        DualUpdate,
        Stop,
    }

    enum Event {
        Broadcast { from: usize, payload: Payload },
        PhaseDone,
        DualDone,
    }

    struct WorkerSetup {
        id: usize,
        d: usize,
        rho: f64,
        neighbors: Vec<usize>,
        solver: Box<dyn SubproblemSolver>,
        censor: Option<CensorConfig>,
        quantizer: Option<Quantizer>,
    }

    fn worker_main(setup: WorkerSetup, rx: Receiver<Command>, tx: Sender<Event>) {
        let WorkerSetup { id, d, rho, neighbors, mut solver, censor, mut quantizer } = setup;
        let mut theta = vec![0.0; d];
        let mut alpha = vec![0.0; d];
        let mut hat_self = vec![0.0; d];
        let mut hat_nbrs: BTreeMap<usize, Vec<f64>> =
            neighbors.iter().map(|&m| (m, vec![0.0; d])).collect();
        let mut transmitted_once = false;
        let mut nbr_sum = vec![0.0; d];
        while let Ok(cmd) = rx.recv() {
            match cmd {
                Command::Phase { k } => {
                    nbr_sum.iter_mut().for_each(|v| *v = 0.0);
                    for v in hat_nbrs.values() {
                        for j in 0..d {
                            nbr_sum[j] += v[j];
                        }
                    }
                    solver.update_into(&alpha, &nbr_sum, &mut theta);
                    let (candidate_hat, payload) = match &mut quantizer {
                        Some(q) => {
                            let (msg, recon) = q.quantize(&theta, &hat_self);
                            (recon, Payload::Quantized(codec::encode(&msg)))
                        }
                        None => {
                            let mut bytes = Vec::with_capacity(theta.len() * 4);
                            for &v in &theta {
                                bytes.extend_from_slice(&(v as f32).to_le_bytes());
                            }
                            (theta.clone(), Payload::Full(bytes))
                        }
                    };
                    let decision = match (&censor, transmitted_once) {
                        (_, false) => Gate::Transmit,
                        (None, _) => Gate::Transmit,
                        (Some(c), true) => gate(c, k, &hat_self, &candidate_hat),
                    };
                    if decision == Gate::Transmit {
                        hat_self = candidate_hat;
                        transmitted_once = true;
                        let _ = tx.send(Event::Broadcast { from: id, payload });
                    }
                    let _ = tx.send(Event::PhaseDone);
                }
                Command::Deliver { from, payload } => {
                    let stored = hat_nbrs.get_mut(&from).expect("non-neighbor");
                    match payload {
                        Payload::Full(bytes) => {
                            *stored = bytes
                                .chunks_exact(4)
                                .map(|c| {
                                    f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64
                                })
                                .collect();
                        }
                        Payload::Quantized(bytes) => {
                            let msg = codec::decode(&bytes, d).expect("bad payload");
                            msg.reconstruct_into(stored);
                        }
                    }
                }
                Command::DualUpdate => {
                    for v in hat_nbrs.values() {
                        for j in 0..d {
                            alpha[j] += rho * (hat_self[j] - v[j]);
                        }
                    }
                    let _ = tx.send(Event::DualDone);
                }
                Command::Stop => break,
            }
        }
    }

    /// The seed leader: spawns one OS thread per worker and plays the
    /// medium over mpsc channels, exactly like the replaced engine.
    pub struct SeedCoordinator {
        topo: Topology,
        d: usize,
        cmd_tx: Vec<Sender<Command>>,
        event_rx: Receiver<Event>,
        handles: Vec<std::thread::JoinHandle<()>>,
        comm: CommLog,
        energy: EnergyModel,
        iter: u64,
    }

    impl SeedCoordinator {
        pub fn spawn(problem: &Problem, topo: &Topology, spec: &AlgSpec) -> SeedCoordinator {
            let n = topo.n();
            let d = problem.d;
            let mut rng = Pcg64::new(7 ^ 0xA16_0001);
            let (event_tx, event_rx) = channel::<Event>();
            let mut cmd_tx = Vec::with_capacity(n);
            let mut handles = Vec::with_capacity(n);
            for i in 0..n {
                let setup = WorkerSetup {
                    id: i,
                    d,
                    rho: problem.rho,
                    neighbors: topo.neighbors(i).to_vec(),
                    solver: Box::new(LinearSolver::from_shard(
                        std::sync::Arc::clone(&problem.shards[i]),
                        problem.rho,
                        topo.degree(i),
                    )),
                    censor: spec.censor,
                    quantizer: spec
                        .quant
                        .as_ref()
                        .map(|q| Quantizer::new(*q, rng.fork(i as u64))),
                };
                let (tx, rx) = channel::<Command>();
                let etx = event_tx.clone();
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("seed-worker-{i}"))
                        .spawn(move || worker_main(setup, rx, etx))
                        .expect("spawn seed worker"),
                );
                cmd_tx.push(tx);
            }
            let energy = EnergyModel::new(
                cq_ggadmm::comm::EnergyParams::default(),
                n,
                spec.concurrent_fraction(),
            );
            SeedCoordinator {
                topo: topo.clone(),
                d,
                cmd_tx,
                event_rx,
                handles,
                comm: CommLog::default(),
                energy,
                iter: 0,
            }
        }

        fn run_phase(&mut self, group: &[usize], k: u64) {
            for &i in group {
                self.cmd_tx[i].send(Command::Phase { k }).expect("send phase");
            }
            let mut done = 0usize;
            let mut broadcasts: Vec<(usize, Payload)> = Vec::new();
            while done < group.len() {
                match self.event_rx.recv().expect("event channel closed") {
                    Event::Broadcast { from, payload } => broadcasts.push((from, payload)),
                    Event::PhaseDone => done += 1,
                    Event::DualDone => panic!("unexpected event"),
                }
            }
            for (from, payload) in broadcasts {
                let bits = payload.bits(self.d);
                let dist = self.topo.max_neighbor_distance(from);
                self.comm.record(Transmission {
                    worker: from,
                    iteration: self.iter,
                    payload_bits: bits,
                    distance_m: dist,
                    energy_j: self.energy.energy_j(bits, dist),
                });
                for &m in self.topo.neighbors(from) {
                    self.cmd_tx[m]
                        .send(Command::Deliver { from, payload: payload.clone() })
                        .expect("deliver");
                }
            }
        }

        pub fn step(&mut self) {
            let k = self.iter + 1;
            let heads = self.topo.heads();
            let tails = self.topo.tails();
            self.run_phase(&heads, k);
            self.run_phase(&tails, k);
            for tx in &self.cmd_tx {
                tx.send(Command::DualUpdate).expect("dual");
            }
            let mut done = 0;
            while done < self.topo.n() {
                if let Event::DualDone = self.event_rx.recv().expect("event") {
                    done += 1;
                }
            }
            self.iter += 1;
        }

        pub fn rounds(&self) -> u64 {
            self.comm.rounds()
        }
    }

    impl Drop for SeedCoordinator {
        fn drop(&mut self) {
            for tx in &self.cmd_tx {
                let _ = tx.send(Command::Stop);
            }
            for h in self.handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// Coordinator throughput shootout: the sharded executor engine vs the
/// seed thread-per-worker engine, CQ-GGADMM at N in {64, 256}.  The
/// sharded path must win at N = 256 — that is the scale where waking
/// hundreds of OS threads per phase dominates the actual math.
fn bench_coordinator_shootout(h: &mut Harness) {
    use cq_ggadmm::coordinator::{Coordinator, CoordinatorOptions};
    println!("-- coordinator shootout: sharded executor vs thread-per-worker --");
    let slack = if h.smoke { 1.25 } else { 1.0 };
    for &n in &[64usize, 256] {
        let d = 20;
        let ds = synthetic::linear_dataset(n * 8, d, 51);
        let topo = Topology::random_bipartite(n, 0.1, 51);
        let problem = Problem::new(&ds, &topo, 10.0, 0.0, 51);
        let spec = AlgSpec::cq_ggadmm(0.05, 0.9, 0.995, 2);

        let mut sharded = Coordinator::spawn(
            problem.clone(),
            topo.clone(),
            spec.clone(),
            CoordinatorOptions { record_every: u64::MAX, ..CoordinatorOptions::default() },
        );
        let mut seed = seed_coordinator::SeedCoordinator::spawn(&problem, &topo, &spec);

        // warm both fleets past the always-transmit first iteration
        for _ in 0..2 {
            sharded.step();
            seed.step();
        }
        let (blocks, reps) = if h.smoke { (3, 2) } else { (3, 10) };
        let (sharded_ns, seed_ns) =
            min_block_pair_ns(blocks, reps, || sharded.step(), || seed.step());
        h.record(
            &format!("coordinator iter N={n} d={d} (sharded executor)"),
            sharded_ns,
        );
        h.record(
            &format!("coordinator iter N={n} d={d} (seed thread-per-worker)"),
            seed_ns,
        );
        println!(
            "N={n}: sharded executor ({} threads) speedup {:.2}x, rounds sharded={} seed={}",
            sharded.threads(),
            seed_ns / sharded_ns,
            sharded.comm().rounds(),
            seed.rounds()
        );
        if n == 256 {
            assert!(
                sharded_ns < seed_ns * slack,
                "sharded coordinator must beat thread-per-worker at N=256 \
                 ({sharded_ns:.0} vs {seed_ns:.0} ns, slack {slack})"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Transport shootout: the networked coordinator's batched-flush policy
// (queue every frame for a connection, then one flush) vs the naive
// write+flush per frame, over real loopback sockets.  Payload shape
// matches a d=50 full-precision broadcast round: 8 frames x 400 bytes
// per connection.
// ---------------------------------------------------------------------

/// N server-side [`Conn`]s paired with N draining client sockets.
struct LoopbackFleet {
    conns: Vec<cq_ggadmm::net::conn::Conn>,
    clients: Vec<std::net::TcpStream>,
}

fn loopback_fleet(n: usize) -> LoopbackFleet {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr");
    let mut conns = Vec::with_capacity(n);
    let mut clients = Vec::with_capacity(n);
    for _ in 0..n {
        let c = std::net::TcpStream::connect(addr).expect("connect");
        let (s, _) = listener.accept().expect("accept");
        conns.push(cq_ggadmm::net::conn::Conn::new(s).expect("conn"));
        c.set_nonblocking(true).expect("nonblocking client");
        clients.push(c);
    }
    LoopbackFleet { conns, clients }
}

/// One broadcast round: `frames` frames to every connection, then drain
/// until every byte has crossed the loopback (reads included in the
/// timed region on both sides, so only the write policy differs).
fn net_round(
    fleet: &mut LoopbackFleet,
    payload: &[u8],
    frames: usize,
    per_frame_flush: bool,
    sink: &mut [u8],
) {
    use cq_ggadmm::net::wire::kind;
    use std::io::Read;
    for c in fleet.conns.iter_mut() {
        for _ in 0..frames {
            let h = c.begin(kind::DELIVER);
            c.payload().extend_from_slice(payload);
            c.end(h);
            if per_frame_flush {
                while !c.flush().expect("flush") {}
            }
        }
    }
    let total = fleet.conns.len() * frames * (payload.len() + 5);
    let mut received = 0usize;
    loop {
        let mut pending = false;
        for c in fleet.conns.iter_mut() {
            if c.has_pending_send() && !c.flush().expect("flush") {
                pending = true;
            }
        }
        for s in fleet.clients.iter_mut() {
            loop {
                match s.read(sink) {
                    Ok(0) => panic!("bench peer closed"),
                    Ok(k) => received += k,
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) => panic!("bench read: {e}"),
                }
            }
        }
        if received == total && !pending {
            break;
        }
    }
}

fn bench_net_shootout(h: &mut Harness) {
    println!("-- net transport shootout: batched flush vs per-frame flush --");
    let slack = if h.smoke { 1.25 } else { 1.0 };
    let frames = 8usize;
    let payload = vec![0u8; 400];
    let mut sink_a = vec![0u8; 1 << 16];
    let mut sink_b = vec![0u8; 1 << 16];
    for &n in &[64usize, 256] {
        let mut batched = loopback_fleet(n);
        let mut naive = loopback_fleet(n);
        // warm both fleets (first rounds grow the persistent buffers)
        net_round(&mut batched, &payload, frames, false, &mut sink_a);
        net_round(&mut naive, &payload, frames, true, &mut sink_b);
        let (blocks, reps) = if h.smoke { (4, 10) } else { (3, 100) };
        let (bat_ns, per_ns) = min_block_pair_ns(
            blocks,
            reps,
            || net_round(&mut batched, &payload, frames, false, &mut sink_a),
            || net_round(&mut naive, &payload, frames, true, &mut sink_b),
        );
        h.record(&format!("net round N={n} 8x400B (batched flush)"), bat_ns);
        h.record(&format!("net round N={n} 8x400B (per-frame flush)"), per_ns);
        println!("N={n}: batched-flush speedup {:.2}x", per_ns / bat_ns);
        assert!(
            bat_ns < per_ns * slack,
            "one coalesced flush per connection must beat a write per frame at N={n} \
             ({bat_ns:.0} vs {per_ns:.0} ns, slack {slack})"
        );
    }
}

#[cfg(feature = "pjrt")]
fn bench_pjrt(
    h: &mut Harness,
    shards: &[Shard],
    shards_l: &[Shard],
    alpha: &[f64],
    nbr: &[f64],
    warm: &[f64],
) {
    let art = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if art.join("manifest.json").exists() {
        let mut plin = cq_ggadmm::runtime::pjrt_solver(
            &art,
            cq_ggadmm::config::Task::Linear,
            &shards[0],
            30.0,
            0.0,
            7,
        )
        .expect("pjrt linear");
        h.bench("PJRT  linear update (s=50,d=50)", || {
            black_box(plin.update(black_box(alpha), black_box(nbr), warm));
        });
        let mut plog = cq_ggadmm::runtime::pjrt_solver(
            &art,
            cq_ggadmm::config::Task::Logistic,
            &shards_l[0],
            0.1,
            0.01,
            7,
        )
        .expect("pjrt logistic");
        h.bench("PJRT  logistic update (s=50,d=50)", || {
            black_box(plog.update(black_box(alpha), black_box(nbr), warm));
        });
    } else {
        println!("(PJRT benches skipped: run `make artifacts`)");
    }
}

#[cfg(not(feature = "pjrt"))]
fn bench_pjrt(_: &mut Harness, _: &[Shard], _: &[Shard], _: &[f64], _: &[f64], _: &[f64]) {
    println!("(PJRT benches skipped: built without the `pjrt` feature)");
}

fn main() {
    println!("== hot-path micro-benchmarks ==");
    let mut h = Harness::new();
    let d = 50;
    let mut rng = Pcg64::new(1);

    // quantizer
    let v = rng.normal_vec(d);
    let reference = vec![0.0; d];
    let mut q = Quantizer::new(QuantConfig::default(), Pcg64::new(2));
    h.bench("quantize d=50", || {
        let mut q2 = q.clone();
        black_box(q2.quantize(black_box(&v), black_box(&reference)));
    });
    let mut recon_buf = vec![0.0; d];
    h.bench("quantize_into d=50 (alloc-free)", || {
        let mut q2 = q.clone();
        black_box(q2.quantize_into(black_box(&v), black_box(&reference), &mut recon_buf));
    });
    let (msg, _) = q.quantize(&v, &reference);
    h.bench("codec encode d=50", || {
        black_box(codec::encode(black_box(&msg)));
    });
    let bytes = codec::encode(&msg);
    h.bench("codec decode d=50", || {
        black_box(codec::decode(black_box(&bytes), d)).unwrap();
    });

    bench_codec_shootout(&mut h);

    // native solvers at paper scale (s=50, d=50)
    let ds = synthetic::linear_dataset(1200, d, 3);
    let shards = partition_uniform(&ds, 24, 3);
    let mut lin = LinearSolver::new(shards[0].x.clone(), shards[0].y.clone(), 30.0, 7);
    let alpha = rng.normal_vec(d);
    let nbr = rng.normal_vec(d);
    let warm = vec![0.0; d];
    h.bench("native linear update (s=50,d=50)", || {
        black_box(lin.update(black_box(&alpha), black_box(&nbr), &warm));
    });
    let mut theta_buf = vec![0.0; d];
    h.bench("native linear update_into (alloc-free)", || {
        lin.update_into(black_box(&alpha), black_box(&nbr), black_box(&mut theta_buf));
    });
    let dsl = synthetic::logistic_dataset(1200, d, 4);
    let shards_l = partition_uniform(&dsl, 24, 4);
    let mut logi =
        LogisticSolver::new(shards_l[0].x.clone(), shards_l[0].y.clone(), 0.01, 0.1, 7);
    h.bench("native logistic update (s=50,d=50)", || {
        black_box(logi.update(black_box(&alpha), black_box(&nbr), &warm));
    });

    bench_newton_shootout(&mut h);

    bench_pjrt(&mut h, &shards, &shards_l, &alpha, &nbr, &warm);

    // full iterations at paper scale, native backend
    let topo = Topology::random_bipartite(24, 0.3, 21);
    let problem = Problem::new(&ds, &topo, 30.0, 0.0, 21);
    let mut run_gg = Run::new(
        problem.clone(),
        topo.clone(),
        AlgSpec::ggadmm(),
        RunOptions { record_every: u64::MAX, ..Default::default() },
    );
    h.bench("full GGADMM iteration (N=24,d=50)", || {
        run_gg.step();
    });
    let mut run_cq = Run::new(
        problem.clone(),
        topo.clone(),
        AlgSpec::cq_ggadmm(0.1, 0.8, 0.995, 2),
        RunOptions { record_every: u64::MAX, ..Default::default() },
    );
    h.bench("full CQ-GGADMM iteration (N=24,d=50)", || {
        run_cq.step();
    });

    bench_incremental_shootout(&mut h);

    bench_coordinator_shootout(&mut h);

    bench_net_shootout(&mut h);

    bench_blocked_linalg_shootout(&mut h);

    bench_large_linalg_shootout(&mut h);

    bench_sweep_shootout(&mut h);

    // threads ablation: fan-out only pays for expensive subproblems, so
    // compare on the logistic workload (Newton-dominated); both variants
    // now dispatch through the persistent pool built in Run::new
    let topo_l = Topology::random_bipartite(24, 0.3, 23);
    let problem_l = Problem::new(&dsl, &topo_l, 0.1, 0.01, 23);
    let mut run_l1 = Run::new(
        problem_l.clone(),
        topo_l.clone(),
        AlgSpec::ggadmm(),
        RunOptions { threads: 1, record_every: u64::MAX, ..Default::default() },
    );
    h.bench("full logistic iteration, 1 thread", || {
        run_l1.step();
    });
    let mut run_l4 = Run::new(
        problem_l,
        topo_l,
        AlgSpec::ggadmm(),
        RunOptions { threads: 4, record_every: u64::MAX, ..Default::default() },
    );
    h.bench("full logistic iteration, 4 threads (pool)", || {
        run_l4.step();
    });
    drop(problem);
    drop(topo);

    // metric recording cost (loss over all shards)
    let topo2 = Topology::random_bipartite(24, 0.3, 22);
    let problem2 = Problem::new(&ds, &topo2, 30.0, 0.0, 22);
    let mut run_rec = Run::new(
        problem2,
        topo2,
        AlgSpec::ggadmm(),
        RunOptions { record_every: 1, ..Default::default() },
    );
    h.bench("GGADMM iteration + trace record", || {
        run_rec.step();
    });

    // topology generation
    h.bench("random_bipartite(24, 0.3)", || {
        black_box(Topology::random_bipartite(24, 0.3, black_box(7)));
    });

    h.write_json();
    println!("bench_hotpath done");
}
