//! Hot-path micro-benchmarks (first-party harness; no criterion offline).
//!
//! Covers every stage of the per-iteration pipeline — native and PJRT
//! subproblem solves, quantization, bit-packing codec, a full GGADMM /
//! CQ-GGADMM iteration at paper scale, and topology generation — and
//! prints ns/op so the §Perf iteration log in EXPERIMENTS.md is
//! regenerable.
//!
//! Run with: `cargo bench --bench bench_hotpath`

use cq_ggadmm::algs::{AlgSpec, Problem, Run, RunOptions};
use cq_ggadmm::data::{partition_uniform, synthetic};
use cq_ggadmm::graph::Topology;
use cq_ggadmm::quant::{codec, QuantConfig, Quantizer};
use cq_ggadmm::solver::{LinearSolver, LogisticSolver, SubproblemSolver};
use cq_ggadmm::util::rng::Pcg64;
use std::hint::black_box;
use std::time::Instant;

/// Time `f` over enough repetitions for a stable ns/op estimate.
fn bench<F: FnMut()>(name: &str, mut f: F) -> f64 {
    // warmup
    for _ in 0..3 {
        f();
    }
    let mut reps = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..reps {
            f();
        }
        let dt = t0.elapsed();
        if dt.as_millis() >= 200 || reps >= 1 << 22 {
            let ns = dt.as_nanos() as f64 / reps as f64;
            println!("{name:<44} {:>12.0} ns/op  ({reps} reps)", ns);
            return ns;
        }
        reps *= 4;
    }
}

fn main() {
    println!("== hot-path micro-benchmarks ==");
    let d = 50;
    let mut rng = Pcg64::new(1);

    // quantizer
    let v = rng.normal_vec(d);
    let reference = vec![0.0; d];
    let mut q = Quantizer::new(QuantConfig::default(), Pcg64::new(2));
    bench("quantize d=50", || {
        let mut q2 = q.clone();
        black_box(q2.quantize(black_box(&v), black_box(&reference)));
    });
    let (msg, _) = q.quantize(&v, &reference);
    bench("codec encode d=50", || {
        black_box(codec::encode(black_box(&msg)));
    });
    let bytes = codec::encode(&msg);
    bench("codec decode d=50", || {
        black_box(codec::decode(black_box(&bytes), d)).unwrap();
    });

    // native solvers at paper scale (s=50, d=50)
    let ds = synthetic::linear_dataset(1200, d, 3);
    let shards = partition_uniform(&ds, 24, 3);
    let mut lin = LinearSolver::new(shards[0].x.clone(), shards[0].y.clone(), 30.0, 7);
    let alpha = rng.normal_vec(d);
    let nbr = rng.normal_vec(d);
    let warm = vec![0.0; d];
    bench("native linear update (s=50,d=50)", || {
        black_box(lin.update(black_box(&alpha), black_box(&nbr), &warm));
    });
    let dsl = synthetic::logistic_dataset(1200, d, 4);
    let shards_l = partition_uniform(&dsl, 24, 4);
    let mut logi =
        LogisticSolver::new(shards_l[0].x.clone(), shards_l[0].y.clone(), 0.01, 0.1, 7);
    bench("native logistic update (s=50,d=50)", || {
        black_box(logi.update(black_box(&alpha), black_box(&nbr), &warm));
    });

    // PJRT solvers (if artifacts are built)
    let art = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if art.join("manifest.json").exists() {
        let mut plin = cq_ggadmm::runtime::pjrt_solver(
            &art,
            cq_ggadmm::config::Task::Linear,
            &shards[0],
            30.0,
            0.0,
            7,
        )
        .expect("pjrt linear");
        bench("PJRT  linear update (s=50,d=50)", || {
            black_box(plin.update(black_box(&alpha), black_box(&nbr), &warm));
        });
        let mut plog = cq_ggadmm::runtime::pjrt_solver(
            &art,
            cq_ggadmm::config::Task::Logistic,
            &shards_l[0],
            0.1,
            0.01,
            7,
        )
        .expect("pjrt logistic");
        bench("PJRT  logistic update (s=50,d=50)", || {
            black_box(plog.update(black_box(&alpha), black_box(&nbr), &warm));
        });
    } else {
        println!("(PJRT benches skipped: run `make artifacts`)");
    }

    // full iterations at paper scale, native backend
    let topo = Topology::random_bipartite(24, 0.3, 21);
    let problem = Problem::new(&ds, &topo, 30.0, 0.0, 21);
    let mut run_gg = Run::new(
        problem.clone(),
        topo.clone(),
        AlgSpec::ggadmm(),
        RunOptions { record_every: u64::MAX, ..Default::default() },
    );
    bench("full GGADMM iteration (N=24,d=50)", || {
        run_gg.step();
    });
    let mut run_cq = Run::new(
        problem.clone(),
        topo.clone(),
        AlgSpec::cq_ggadmm(0.1, 0.8, 0.995, 2),
        RunOptions { record_every: u64::MAX, ..Default::default() },
    );
    bench("full CQ-GGADMM iteration (N=24,d=50)", || {
        run_cq.step();
    });
    // threads ablation: fan-out only pays for expensive subproblems, so
    // compare on the logistic workload (Newton-dominated)
    let topo_l = Topology::random_bipartite(24, 0.3, 23);
    let problem_l = Problem::new(&dsl, &topo_l, 0.1, 0.01, 23);
    let mut run_l1 = Run::new(
        problem_l.clone(),
        topo_l.clone(),
        AlgSpec::ggadmm(),
        RunOptions { threads: 1, record_every: u64::MAX, ..Default::default() },
    );
    bench("full logistic iteration, 1 thread", || {
        run_l1.step();
    });
    let mut run_l4 = Run::new(
        problem_l,
        topo_l,
        AlgSpec::ggadmm(),
        RunOptions { threads: 4, record_every: u64::MAX, ..Default::default() },
    );
    bench("full logistic iteration, 4 threads", || {
        run_l4.step();
    });
    drop(problem);
    drop(topo);

    // metric recording cost (loss over all shards)
    let topo2 = Topology::random_bipartite(24, 0.3, 22);
    let problem2 = Problem::new(&ds, &topo2, 30.0, 0.0, 22);
    let mut run_rec = Run::new(
        problem2,
        topo2,
        AlgSpec::ggadmm(),
        RunOptions { record_every: 1, ..Default::default() },
    );
    bench("GGADMM iteration + trace record", || {
        run_rec.step();
    });

    // topology generation
    bench("random_bipartite(24, 0.3)", || {
        black_box(Topology::random_bipartite(24, 0.3, black_box(7)));
    });

    println!("bench_hotpath done");
}
