//! Hot-path micro-benchmarks (first-party harness; no criterion offline).
//!
//! Covers every stage of the per-iteration pipeline — native (and, with
//! `--features pjrt`, PJRT) subproblem solves, quantization, bit-packing
//! codec, a full GGADMM / CQ-GGADMM iteration at paper scale, and topology
//! generation — and prints ns/op so the §Perf iteration log in
//! EXPERIMENTS.md is regenerable.
//!
//! The codec shootout compares the word-level (u64) packer against a
//! faithful copy of the original bit-at-a-time loop on a d=10'000, 8-bit
//! message — the acceptance workload of the build-system PR.
//!
//! Run with: `cargo bench --bench bench_hotpath`

use cq_ggadmm::algs::{AlgSpec, Problem, Run, RunOptions};
use cq_ggadmm::data::{partition_uniform, synthetic, Shard};
use cq_ggadmm::graph::Topology;
use cq_ggadmm::quant::{codec, QuantConfig, QuantMessage, Quantizer};
use cq_ggadmm::solver::{LinearSolver, LogisticSolver, SubproblemSolver};
use cq_ggadmm::util::rng::Pcg64;
use std::hint::black_box;
use std::time::Instant;

/// Time `f` over enough repetitions for a stable ns/op estimate.
fn bench<F: FnMut()>(name: &str, mut f: F) -> f64 {
    // warmup
    for _ in 0..3 {
        f();
    }
    let mut reps = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..reps {
            f();
        }
        let dt = t0.elapsed();
        if dt.as_millis() >= 200 || reps >= 1 << 22 {
            let ns = dt.as_nanos() as f64 / reps as f64;
            println!("{name:<44} {:>12.0} ns/op  ({reps} reps)", ns);
            return ns;
        }
        reps *= 4;
    }
}

/// The seed repo's bit-at-a-time encoder, kept verbatim as the shootout
/// reference (and as a differential check on the word-level packer).
fn bit_loop_encode(msg: &QuantMessage) -> Vec<u8> {
    fn push_bits(buf: &mut Vec<u8>, bitlen: &mut usize, value: u64, width: u32) {
        for i in 0..width {
            let bit = (value >> i) & 1;
            let byte_idx = *bitlen / 8;
            if byte_idx == buf.len() {
                buf.push(0);
            }
            if bit == 1 {
                buf[byte_idx] |= 1 << (*bitlen % 8);
            }
            *bitlen += 1;
        }
    }
    let mut buf = Vec::with_capacity((msg.payload_bits() as usize).div_ceil(8));
    let mut bitlen = 0usize;
    push_bits(&mut buf, &mut bitlen, (msg.radius as f32).to_bits() as u64, 32);
    push_bits(&mut buf, &mut bitlen, msg.bits as u64, 32);
    for &c in &msg.codes {
        push_bits(&mut buf, &mut bitlen, c as u64, msg.bits);
    }
    buf
}

/// The seed repo's bit-at-a-time decoder (shootout reference).
fn bit_loop_decode(buf: &[u8], d: usize) -> Option<QuantMessage> {
    fn read_bits(buf: &[u8], pos: &mut usize, width: u32) -> Option<u64> {
        let mut out = 0u64;
        for i in 0..width {
            let byte_idx = *pos / 8;
            if byte_idx >= buf.len() {
                return None;
            }
            let bit = (buf[byte_idx] >> (*pos % 8)) & 1;
            out |= (bit as u64) << i;
            *pos += 1;
        }
        Some(out)
    }
    let mut pos = 0usize;
    let radius = f32::from_bits(read_bits(buf, &mut pos, 32)? as u32) as f64;
    let bits = read_bits(buf, &mut pos, 32)? as u32;
    if bits == 0 || bits > 32 || !(radius.is_finite()) || radius < 0.0 {
        return None;
    }
    let mut codes = Vec::with_capacity(d);
    for _ in 0..d {
        codes.push(read_bits(buf, &mut pos, bits)? as u32);
    }
    Some(QuantMessage { codes, radius, bits })
}

/// Codec shootout on the acceptance workload: d=10'000 coordinates at 8
/// bits each (the paper-scale "large model" message).
fn bench_codec_shootout() {
    println!("-- codec shootout: d=10000, 8-bit codes --");
    let d = 10_000usize;
    let codes: Vec<u32> = (0..d as u32)
        .map(|i| i.wrapping_mul(2_654_435_761) & 0xFF)
        .collect();
    let msg = QuantMessage { codes, radius: 1.0, bits: 8 };

    let word_bytes = codec::encode(&msg);
    let ref_bytes = bit_loop_encode(&msg);
    assert_eq!(word_bytes, ref_bytes, "codecs must agree bit-for-bit");
    assert_eq!(bit_loop_decode(&ref_bytes, d).unwrap(), msg);

    let enc_word = bench("codec encode d=10k b=8 (word-level)", || {
        black_box(codec::encode(black_box(&msg)));
    });
    let dec_word = bench("codec decode d=10k b=8 (word-level)", || {
        black_box(codec::decode(black_box(&word_bytes), d)).unwrap();
    });
    let enc_bit = bench("codec encode d=10k b=8 (seed bit-loop)", || {
        black_box(bit_loop_encode(black_box(&msg)));
    });
    let dec_bit = bench("codec decode d=10k b=8 (seed bit-loop)", || {
        black_box(bit_loop_decode(black_box(&ref_bytes), d)).unwrap();
    });
    println!(
        "word-level speedup: encode {:.1}x, decode {:.1}x, encode+decode {:.1}x",
        enc_bit / enc_word,
        dec_bit / dec_word,
        (enc_bit + dec_bit) / (enc_word + dec_word)
    );
    assert!(
        enc_word + dec_word < enc_bit + dec_bit,
        "word-level codec must beat the bit-loop on encode+decode \
         ({:.0} vs {:.0} ns)",
        enc_word + dec_word,
        enc_bit + dec_bit
    );
}

#[cfg(feature = "pjrt")]
fn bench_pjrt(shards: &[Shard], shards_l: &[Shard], alpha: &[f64], nbr: &[f64], warm: &[f64]) {
    let art = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if art.join("manifest.json").exists() {
        let mut plin = cq_ggadmm::runtime::pjrt_solver(
            &art,
            cq_ggadmm::config::Task::Linear,
            &shards[0],
            30.0,
            0.0,
            7,
        )
        .expect("pjrt linear");
        bench("PJRT  linear update (s=50,d=50)", || {
            black_box(plin.update(black_box(alpha), black_box(nbr), warm));
        });
        let mut plog = cq_ggadmm::runtime::pjrt_solver(
            &art,
            cq_ggadmm::config::Task::Logistic,
            &shards_l[0],
            0.1,
            0.01,
            7,
        )
        .expect("pjrt logistic");
        bench("PJRT  logistic update (s=50,d=50)", || {
            black_box(plog.update(black_box(alpha), black_box(nbr), warm));
        });
    } else {
        println!("(PJRT benches skipped: run `make artifacts`)");
    }
}

#[cfg(not(feature = "pjrt"))]
fn bench_pjrt(_: &[Shard], _: &[Shard], _: &[f64], _: &[f64], _: &[f64]) {
    println!("(PJRT benches skipped: built without the `pjrt` feature)");
}

fn main() {
    println!("== hot-path micro-benchmarks ==");
    let d = 50;
    let mut rng = Pcg64::new(1);

    // quantizer
    let v = rng.normal_vec(d);
    let reference = vec![0.0; d];
    let mut q = Quantizer::new(QuantConfig::default(), Pcg64::new(2));
    bench("quantize d=50", || {
        let mut q2 = q.clone();
        black_box(q2.quantize(black_box(&v), black_box(&reference)));
    });
    let mut recon_buf = vec![0.0; d];
    bench("quantize_into d=50 (alloc-free)", || {
        let mut q2 = q.clone();
        black_box(q2.quantize_into(black_box(&v), black_box(&reference), &mut recon_buf));
    });
    let (msg, _) = q.quantize(&v, &reference);
    bench("codec encode d=50", || {
        black_box(codec::encode(black_box(&msg)));
    });
    let bytes = codec::encode(&msg);
    bench("codec decode d=50", || {
        black_box(codec::decode(black_box(&bytes), d)).unwrap();
    });

    bench_codec_shootout();

    // native solvers at paper scale (s=50, d=50)
    let ds = synthetic::linear_dataset(1200, d, 3);
    let shards = partition_uniform(&ds, 24, 3);
    let mut lin = LinearSolver::new(shards[0].x.clone(), shards[0].y.clone(), 30.0, 7);
    let alpha = rng.normal_vec(d);
    let nbr = rng.normal_vec(d);
    let warm = vec![0.0; d];
    bench("native linear update (s=50,d=50)", || {
        black_box(lin.update(black_box(&alpha), black_box(&nbr), &warm));
    });
    let mut theta_buf = vec![0.0; d];
    bench("native linear update_into (alloc-free)", || {
        lin.update_into(black_box(&alpha), black_box(&nbr), black_box(&mut theta_buf));
    });
    let dsl = synthetic::logistic_dataset(1200, d, 4);
    let shards_l = partition_uniform(&dsl, 24, 4);
    let mut logi =
        LogisticSolver::new(shards_l[0].x.clone(), shards_l[0].y.clone(), 0.01, 0.1, 7);
    bench("native logistic update (s=50,d=50)", || {
        black_box(logi.update(black_box(&alpha), black_box(&nbr), &warm));
    });

    bench_pjrt(&shards, &shards_l, &alpha, &nbr, &warm);

    // full iterations at paper scale, native backend
    let topo = Topology::random_bipartite(24, 0.3, 21);
    let problem = Problem::new(&ds, &topo, 30.0, 0.0, 21);
    let mut run_gg = Run::new(
        problem.clone(),
        topo.clone(),
        AlgSpec::ggadmm(),
        RunOptions { record_every: u64::MAX, ..Default::default() },
    );
    bench("full GGADMM iteration (N=24,d=50)", || {
        run_gg.step();
    });
    let mut run_cq = Run::new(
        problem.clone(),
        topo.clone(),
        AlgSpec::cq_ggadmm(0.1, 0.8, 0.995, 2),
        RunOptions { record_every: u64::MAX, ..Default::default() },
    );
    bench("full CQ-GGADMM iteration (N=24,d=50)", || {
        run_cq.step();
    });
    // threads ablation: fan-out only pays for expensive subproblems, so
    // compare on the logistic workload (Newton-dominated)
    let topo_l = Topology::random_bipartite(24, 0.3, 23);
    let problem_l = Problem::new(&dsl, &topo_l, 0.1, 0.01, 23);
    let mut run_l1 = Run::new(
        problem_l.clone(),
        topo_l.clone(),
        AlgSpec::ggadmm(),
        RunOptions { threads: 1, record_every: u64::MAX, ..Default::default() },
    );
    bench("full logistic iteration, 1 thread", || {
        run_l1.step();
    });
    let mut run_l4 = Run::new(
        problem_l,
        topo_l,
        AlgSpec::ggadmm(),
        RunOptions { threads: 4, record_every: u64::MAX, ..Default::default() },
    );
    bench("full logistic iteration, 4 threads", || {
        run_l4.step();
    });
    drop(problem);
    drop(topo);

    // metric recording cost (loss over all shards)
    let topo2 = Topology::random_bipartite(24, 0.3, 22);
    let problem2 = Problem::new(&ds, &topo2, 30.0, 0.0, 22);
    let mut run_rec = Run::new(
        problem2,
        topo2,
        AlgSpec::ggadmm(),
        RunOptions { record_every: 1, ..Default::default() },
    );
    bench("GGADMM iteration + trace record", || {
        run_rec.step();
    });

    // topology generation
    bench("random_bipartite(24, 0.3)", || {
        black_box(Topology::random_bipartite(24, 0.3, black_box(7)));
    });

    println!("bench_hotpath done");
}
