//! Dynamic networks are a **bit-for-bit** cross-engine contract, exactly
//! like the static graphs of tests/coordinator_equivalence.rs.
//!
//! Both engines (sequential simulator, sharded coordinator) consume ONE
//! shared [`ExecutionConfig`] carrying the full fault schedule — a seeded
//! worker-churn schedule, a straggler or time-varying link model, and the
//! bounded-staleness round policy — and must produce identical traces
//! (loss/consensus gaps, rounds, bits, energy), identical simulated
//! clocks, identical membership/staleness bookkeeping, and identical
//! durable checkpoint bytes across all six `AlgSpec` variants at N = 64
//! workers on a 4-thread executor.
//!
//! Why this must hold: churn transitions go through the shared
//! `protocol::apply_churn_event` (fate draws and warm-start averaging in
//! ascending worker order on the leader), straggler membership and Pareto
//! delays come off the same forked link RNG, and the bounded-staleness
//! force flags are pure functions of the per-worker staleness counters —
//! none of it depends on executor scheduling.

use cq_ggadmm::algs::{AlgSpec, Problem, Run};
use cq_ggadmm::comm::LinkKind;
use cq_ggadmm::config::ExecutionConfig;
use cq_ggadmm::coordinator::Coordinator;
use cq_ggadmm::data::synthetic;
use cq_ggadmm::graph::{ChurnSchedule, Topology};
use cq_ggadmm::io::{checkpoint, MemorySink, PersistableEngine};
use cq_ggadmm::metrics::Trace;

/// N = 64 simulated workers on 4 executor threads (N ≫ K: scheduling
/// must not perturb a single bit, even while workers come and go).
const N: usize = 64;
const THREADS: usize = 4;

/// Pin the kernel tier for the whole test binary — engine equivalence is
/// a per-tier contract (see tests/coordinator_equivalence.rs).
fn pin_tier() {
    let t = cq_ggadmm::linalg::kernel_tier();
    cq_ggadmm::linalg::set_kernel_tier(t);
}

fn problem(linear: bool, topo: &Topology, seed: u64) -> Problem {
    let n = topo.n();
    if linear {
        let ds = synthetic::linear_dataset(n * 10, 6, seed);
        Problem::new(&ds, topo, 5.0, 0.0, seed)
    } else {
        let ds = synthetic::logistic_dataset(n * 10, 6, seed);
        Problem::new(&ds, topo, 0.5, 0.05, seed)
    }
}

/// The shared fault schedule: three workers leave early and rejoin
/// mid-run, so the window covers detach, absent rounds, warm-started
/// rejoin, and post-rejoin catch-up under the staleness bound.
fn churn() -> ChurnSchedule {
    ChurnSchedule::parse("3:leave:5 11:join:5 4:leave:20 13:join:20 6:leave:41 16:join:41")
        .expect("static schedule parses")
}

/// A rotating straggler subset whose Pareto delays straddle the slot
/// deadline — some transmissions land, some arrive late and abort.
fn straggler_link() -> LinkKind {
    LinkKind::Straggler { frac: 0.15, rotate_every: 7, base_s: 8e-4, alpha: 1.3 }
}

/// A bursty good/bad link whose phase is driven by the shared simulated
/// clock — drops and extra latency come and go with the bad bursts.
fn timevarying_link() -> LinkKind {
    LinkKind::TimeVarying {
        period_s: 0.02,
        bad_frac: 0.3,
        p_good: 0.05,
        p_bad: 0.6,
        bad_latency_s: 5e-4,
    }
}

fn assert_traces_bit_identical(sim: &Trace, coord: &Trace, what: &str) {
    assert_eq!(sim.points.len(), coord.points.len(), "{what}: trace length");
    for (a, b) in sim.points.iter().zip(&coord.points) {
        let k = a.iteration;
        assert_eq!(a.iteration, b.iteration, "{what} iter {k}");
        assert_eq!(a.cum_rounds, b.cum_rounds, "{what} iter {k}: rounds");
        assert_eq!(a.cum_bits, b.cum_bits, "{what} iter {k}: bits");
        assert_eq!(
            a.loss_gap.to_bits(),
            b.loss_gap.to_bits(),
            "{what} iter {k}: loss gap {:.17e} vs {:.17e}",
            a.loss_gap,
            b.loss_gap
        );
        assert_eq!(
            a.consensus_gap.to_bits(),
            b.consensus_gap.to_bits(),
            "{what} iter {k}: consensus gap"
        );
        assert_eq!(a.cum_energy_j.to_bits(), b.cum_energy_j.to_bits(), "{what} iter {k}: energy");
    }
}

/// Drive both engines step-by-step from ONE shared `ExecutionConfig`
/// under the full fault schedule and compare everything durable:
/// the trace, the simulated clock, the membership/staleness vectors,
/// and the complete serialized checkpoint bytes.
fn lock_dynamic(spec: AlgSpec, topo: Topology, linear: bool, link: LinkKind, seed: u64, iters: u64) {
    pin_tier();
    let p = problem(linear, &topo, seed);
    let what = format!(
        "{} / {} / {}",
        spec.name,
        if linear { "linear" } else { "logistic" },
        link.label()
    );
    let exec = ExecutionConfig::default()
        .with_seed(seed)
        .with_threads(THREADS)
        .with_churn(Some(churn()))
        .with_staleness_bound(Some(3))
        .with_link(Some(link));
    let mut sim = Run::new(p.clone(), topo.clone(), spec.clone(), exec.clone());
    let mut coord = Coordinator::spawn(p, topo, spec, exec);
    for _ in 0..iters {
        sim.step();
        coord.step();
    }
    assert_traces_bit_identical(sim.trace(), coord.trace(), &what);
    let (ss, sc) = (sim.snapshot_state(), coord.snapshot_state());
    assert_eq!(
        ss.medium.sim_time_s.to_bits(),
        sc.medium.sim_time_s.to_bits(),
        "{what}: simulated clock"
    );
    assert_eq!(ss.active, sc.active, "{what}: membership");
    assert_eq!(ss.stale, sc.stale, "{what}: staleness counters");
    // the strongest form: the engines' durable states serialize to the
    // same bytes (cores, quantizer/link RNG positions, totals, trace)
    assert_eq!(
        checkpoint::encode(&ss),
        checkpoint::encode(&sc),
        "{what}: checkpoint bytes diverge"
    );
}

fn bipartite(seed: u64) -> Topology {
    Topology::random_bipartite(N, 0.2, seed)
}

// ---- all six variants under churn + stragglers ----------------------

#[test]
fn ggadmm_faulted_bit_identical() {
    lock_dynamic(AlgSpec::ggadmm(), bipartite(111), true, straggler_link(), 111, 22);
}

#[test]
fn c_ggadmm_faulted_bit_identical() {
    // censor thresholds keep decaying while a worker is absent; both
    // engines must age them identically through the churn window
    lock_dynamic(AlgSpec::c_ggadmm(0.2, 0.85), bipartite(112), true, straggler_link(), 112, 25);
}

#[test]
fn q_ggadmm_faulted_bit_identical() {
    // forced staleness refreshes advance the quantizer exactly like
    // voluntary broadcasts — the forked RNG streams must stay aligned
    lock_dynamic(AlgSpec::q_ggadmm(0.995, 2), bipartite(113), true, straggler_link(), 113, 25);
}

#[test]
fn cq_ggadmm_faulted_bit_identical() {
    lock_dynamic(
        AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2),
        bipartite(114),
        true,
        straggler_link(),
        114,
        25,
    );
}

#[test]
fn c_admm_faulted_bit_identical() {
    lock_dynamic(AlgSpec::c_admm(0.1, 0.9), bipartite(115), true, straggler_link(), 115, 25);
}

#[test]
fn gadmm_chain_faulted_bit_identical() {
    // chain + churn covers the degree-0 freeze: worker 41's lone
    // neighbors detach and reattach without perturbing the clock
    lock_dynamic(AlgSpec::gadmm_chain(), Topology::chain(N), true, straggler_link(), 116, 25);
}

// ---- all six variants under churn + time-varying drops --------------

#[test]
fn ggadmm_timevarying_bit_identical() {
    lock_dynamic(AlgSpec::ggadmm(), bipartite(121), true, timevarying_link(), 121, 22);
}

#[test]
fn c_ggadmm_timevarying_bit_identical() {
    lock_dynamic(AlgSpec::c_ggadmm(0.2, 0.85), bipartite(122), true, timevarying_link(), 122, 25);
}

#[test]
fn q_ggadmm_timevarying_bit_identical() {
    lock_dynamic(AlgSpec::q_ggadmm(0.995, 2), bipartite(123), true, timevarying_link(), 123, 25);
}

#[test]
fn cq_ggadmm_timevarying_bit_identical() {
    lock_dynamic(
        AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2),
        bipartite(124),
        true,
        timevarying_link(),
        124,
        25,
    );
}

#[test]
fn c_admm_timevarying_bit_identical() {
    lock_dynamic(AlgSpec::c_admm(0.1, 0.9), bipartite(125), true, timevarying_link(), 125, 25);
}

#[test]
fn gadmm_chain_timevarying_bit_identical() {
    lock_dynamic(AlgSpec::gadmm_chain(), Topology::chain(N), true, timevarying_link(), 126, 25);
}

// ---- logistic task ---------------------------------------------------

#[test]
fn cq_ggadmm_logistic_faulted_bit_identical() {
    lock_dynamic(
        AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2),
        bipartite(131),
        false,
        straggler_link(),
        131,
        10,
    );
}

// ---- the event streams of both engines match line-for-line ----------

#[test]
fn faulted_event_streams_are_identical() {
    pin_tier();
    let topo = bipartite(141);
    let p = problem(true, &topo, 141);
    let exec = ExecutionConfig::default()
        .with_seed(141)
        .with_threads(THREADS)
        .with_churn(Some(churn()))
        .with_staleness_bound(Some(3))
        .with_link(Some(straggler_link()));
    let spec = AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2);
    let (ms, mc) = (MemorySink::new(), MemorySink::new());
    let mut sim = Run::new(p.clone(), topo.clone(), spec.clone(), exec.clone());
    sim.start_event_log(Box::new(ms.clone()));
    let mut coord = Coordinator::spawn(p, topo, spec, exec);
    coord.start_event_log(Box::new(mc.clone()));
    for _ in 0..20 {
        sim.step();
        coord.step();
    }
    let (ls, lc) = (ms.lines(), mc.lines());
    assert_eq!(ls, lc, "event streams diverge");
    // the schedule's transitions all appear, in order, exactly once
    for (ev, iter, w) in [
        ("worker_leave", 3, 5),
        ("worker_leave", 4, 20),
        ("worker_leave", 6, 41),
        ("worker_join", 11, 5),
        ("worker_join", 13, 20),
        ("worker_join", 16, 41),
    ] {
        let needle = format!("\"event\":\"{ev}\",\"iteration\":{iter},\"worker\":{w}");
        assert_eq!(
            ls.iter().filter(|l| l.contains(&needle)).count(),
            1,
            "missing or duplicated {needle}"
        );
    }
}
