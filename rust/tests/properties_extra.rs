//! Additional cross-module property and edge-case tests widening the
//! suite beyond each module's local unit tests.

use cq_ggadmm::algs::{AlgSpec, Problem, Run, RunOptions};
use cq_ggadmm::comm::{EnergyModel, EnergyParams};
use cq_ggadmm::config::{parse_toml, ExperimentConfig};
use cq_ggadmm::data::synthetic;
use cq_ggadmm::graph::{spectral, Topology};
use cq_ggadmm::linalg::{Cholesky, Lu, Mat};
use cq_ggadmm::quant::{codec, QuantConfig, Quantizer};
use cq_ggadmm::testing::prop::check;
use cq_ggadmm::util::rng::Pcg64;

// ------------------------------------------------------------- linalg ----

fn random_mat(g: &mut cq_ggadmm::testing::prop::Gen, r: usize, c: usize) -> Mat {
    let data = g.normal_vec(r * c);
    Mat::from_vec(r, c, data)
}

#[test]
fn cholesky_and_lu_agree_on_spd_systems() {
    check("chol == lu on SPD", 40, |g| {
        let n = g.usize_in(1, 15);
        let b = random_mat(g, n, n);
        let a = b.t().matmul(&b).add_diag(n as f64 * 0.2);
        let rhs = g.normal_vec(n);
        let x1 = Cholesky::new(&a).expect("spd").solve(&rhs);
        let x2 = Lu::new(&a).expect("nonsingular").solve(&rhs);
        for (p, q) in x1.iter().zip(&x2) {
            assert!((p - q).abs() < 1e-7 * (1.0 + p.abs()), "{p} vs {q}");
        }
    });
}

#[test]
fn matmul_is_associative() {
    check("(AB)C == A(BC)", 30, |g| {
        let (m, k, l, n) = (
            g.usize_in(1, 8),
            g.usize_in(1, 8),
            g.usize_in(1, 8),
            g.usize_in(1, 8),
        );
        let a = random_mat(g, m, k);
        let b = random_mat(g, k, l);
        let c = random_mat(g, l, n);
        let lhs = a.matmul(&b).matmul(&c);
        let rhs = a.matmul(&b.matmul(&c));
        assert!(lhs.sub(&rhs).max_abs() < 1e-9 * (1.0 + lhs.max_abs()));
    });
}

#[test]
fn power_iteration_bounded_by_fro_norm() {
    check("sigma_max <= ||A||_F", 40, |g| {
        let (r, c) = (g.usize_in(1, 10), g.usize_in(1, 10));
        let a = random_mat(g, r, c);
        let s = cq_ggadmm::linalg::power_iteration_sigma_max(&a, 300);
        assert!(s <= a.fro_norm() + 1e-9);
        // and >= |a_ij| for any entry (operator norm dominates entries)
        assert!(s + 1e-9 >= a.max_abs());
    });
}

// --------------------------------------------------------------- quant ----

#[test]
fn quantizer_handles_extreme_magnitudes() {
    check("quantize at extreme scales", 40, |g| {
        let scale = 10f64.powi(g.usize_in(0, 12) as i32 - 6);
        let d = g.usize_in(1, 32);
        let mut q = Quantizer::new(QuantConfig::default(), Pcg64::new(g.u64()));
        let v: Vec<f64> = g.normal_vec(d).iter().map(|x| x * scale).collect();
        let reference = vec![0.0; d];
        let (msg, recon) = q.quantize(&v, &reference);
        let delta = msg.step();
        for (r, t) in recon.iter().zip(&v) {
            assert!((r - t).abs() <= delta * (1.0 + 1e-6), "{r} vs {t} (delta {delta})");
            assert!(r.is_finite());
        }
        // codec roundtrip survives extreme radii
        let back = codec::decode(&codec::encode(&msg), d).unwrap();
        assert_eq!(back, msg);
    });
}

#[test]
fn repeated_quantization_of_fixed_target_converges() {
    // transmitting the same target repeatedly must drive the shared
    // reconstruction to it geometrically (Delta decays by omega)
    check("fixed-point tracking", 20, |g| {
        let d = 16;
        let target = g.normal_vec(d);
        let mut q = Quantizer::new(
            QuantConfig { bits0: 2, omega: 0.9, max_bits: 24 },
            Pcg64::new(g.u64()),
        );
        let mut reference = vec![0.0; d];
        for _ in 0..60 {
            let (_, recon) = q.quantize(&target, &reference);
            reference = recon;
        }
        let err = cq_ggadmm::util::max_abs_diff(&reference, &target);
        assert!(err < 1e-3, "err={err}");
    });
}

// -------------------------------------------------------------- config ----

#[test]
fn toml_parser_edge_cases() {
    // empty doc
    assert!(parse_toml("").is_ok());
    // whitespace and comments only
    assert!(parse_toml("  \n# hi\n\t\n").is_ok());
    // duplicate keys: last wins
    let doc = parse_toml("a = 1\na = 2\n").unwrap();
    assert_eq!(doc.get_f64("", "a").unwrap(), Some(2.0));
    // negative and exponent numbers
    let doc = parse_toml("x = -1.5e-3\n").unwrap();
    assert_eq!(doc.get_f64("", "x").unwrap(), Some(-1.5e-3));
    // empty array
    let doc = parse_toml("v = []\n").unwrap();
    assert!(matches!(
        doc.get("", "v"),
        Some(cq_ggadmm::config::Value::Arr(items)) if items.is_empty()
    ));
}

#[test]
fn experiment_config_root_section_fallback() {
    let cfg = ExperimentConfig::from_toml("workers = 10\nrho = 2.5\n").unwrap();
    assert_eq!(cfg.workers, 10);
    assert_eq!(cfg.rho, 2.5);
}

// ---------------------------------------------------------------- comm ----

#[test]
fn alternating_schedule_gets_double_bandwidth() {
    check("bandwidth split per schedule", 30, |g| {
        let n = g.usize_in(2, 64);
        let p = EnergyParams::default();
        let alt = EnergyModel::new(p, n, 0.5);
        let jac = EnergyModel::new(p, n, 1.0);
        assert!((alt.bandwidth_hz - 2.0 * jac.bandwidth_hz).abs() < 1e-6);
        // same payload costs strictly less energy under the wider share
        let bits = g.usize_in(100, 10_000) as u64;
        let d = g.f64_in(10.0, 500.0);
        assert!(alt.energy_j(bits, d) < jac.energy_j(bits, d));
    });
}

// --------------------------------------------------------------- graph ----

#[test]
fn chain_is_special_case_of_bipartite_machinery() {
    check("chain topologies valid", 20, |g| {
        let n = g.usize_in(2, 40);
        let t = Topology::chain(n);
        assert!(t.is_connected());
        assert!(t.is_bipartite_consistent());
        assert_eq!(t.edges().len(), n - 1);
        // spectral identities hold on chains too
        let m = spectral::matrices(&t);
        let lhs = m.degree.sub(&m.adjacency);
        let rhs = m.m_minus.matmul(&m.m_minus.t()).scale(0.5);
        assert!(lhs.sub(&rhs).max_abs() < 1e-10);
    });
}

#[test]
fn full_bipartite_graph_at_p_one() {
    let t = Topology::random_bipartite(10, 1.0, 3);
    // p=1 gives the complete bipartite graph over the grouping
    assert_eq!(t.edges().len(), t.heads().len() * t.tails().len());
}

// ----------------------------------------------------------------- algs ----

#[test]
fn q_ggadmm_without_censoring_transmits_every_round() {
    let topo = Topology::random_bipartite(8, 0.5, 9);
    let ds = synthetic::linear_dataset(96, 5, 9);
    let p = Problem::new(&ds, &topo, 5.0, 0.0, 9);
    let mut run = Run::new(p, topo, AlgSpec::q_ggadmm(0.995, 2), RunOptions::default());
    for _ in 0..20 {
        run.step();
    }
    assert_eq!(run.comm().rounds(), 8 * 20);
    // and still converges despite 2-bit payloads
    let trace = run.run(200);
    assert!(trace.last_gap() < 1e-6, "gap={:.3e}", trace.last_gap());
}

#[test]
fn seeds_reproduce_stochastic_runs_exactly() {
    check("CQ runs deterministic per seed", 10, |g| {
        let seed = g.u64();
        let topo = Topology::random_bipartite(6, 0.5, 3);
        let ds = synthetic::linear_dataset(72, 4, 3);
        let p = Problem::new(&ds, &topo, 5.0, 0.0, 3);
        let spec = AlgSpec::cq_ggadmm(0.2, 0.85, 0.99, 2);
        let opts = RunOptions { seed, ..RunOptions::default() };
        let mut a = Run::new(p.clone(), topo.clone(), spec.clone(), opts.clone());
        let mut b = Run::new(p, topo, spec, opts);
        let ta = a.run(40);
        let tb = b.run(40);
        for (x, y) in ta.points.iter().zip(&tb.points) {
            assert_eq!(x.loss_gap.to_bits(), y.loss_gap.to_bits());
            assert_eq!(x.cum_bits, y.cum_bits);
        }
    });
}

#[test]
fn energy_accounting_consistent_with_comm_log() {
    let topo = Topology::random_bipartite(8, 0.4, 11);
    let ds = synthetic::linear_dataset(96, 5, 11);
    let p = Problem::new(&ds, &topo, 5.0, 0.0, 11);
    let mut run = Run::new(p, topo, AlgSpec::ggadmm(), RunOptions::default());
    for _ in 0..15 {
        run.step();
    }
    let log = run.comm();
    let sum_energy: f64 = log.transmissions.iter().map(|t| t.energy_j).sum();
    let sum_bits: u64 = log.transmissions.iter().map(|t| t.payload_bits).sum();
    assert!((sum_energy - log.total_energy_j).abs() < 1e-9);
    assert_eq!(sum_bits, log.total_bits);
    let last = run.trace().points.last().unwrap();
    assert_eq!(last.cum_bits, log.total_bits);
    assert!((last.cum_energy_j - log.total_energy_j).abs() < 1e-9);
}

#[test]
fn dgd_is_much_slower_than_ggadmm_per_iteration() {
    // the paper's motivation for second-order methods
    let topo = Topology::random_bipartite(8, 0.5, 13);
    let ds = synthetic::linear_dataset(96, 5, 13);
    let p = Problem::new(&ds, &topo, 5.0, 0.0, 13);
    let mut gg = Run::new(p.clone(), topo.clone(), AlgSpec::ggadmm(), RunOptions::default());
    let tg = gg.run(100);
    let td = cq_ggadmm::algs::dgd::run_dgd(&p, &topo, 0.01, 100, EnergyParams::default());
    let it_g = tg.first_below(1e-3).map(|p| p.iteration).unwrap_or(u64::MAX);
    let it_d = td.first_below(1e-3).map(|p| p.iteration).unwrap_or(u64::MAX);
    assert!(it_g < it_d, "GGADMM {it_g} vs DGD {it_d}");
}

#[test]
fn heavier_erasures_degrade_gracefully() {
    // more failure injection => no crash, slower but monotone recovery
    let topo = Topology::random_bipartite(8, 0.5, 17);
    let ds = synthetic::linear_dataset(96, 5, 17);
    let p = Problem::new(&ds, &topo, 5.0, 0.0, 17);
    let mut gaps = Vec::new();
    for drop_prob in [0.0, 0.2, 0.5] {
        let mut run = Run::new(
            p.clone(),
            topo.clone(),
            AlgSpec::ggadmm(),
            RunOptions { drop_prob, seed: 17, ..RunOptions::default() },
        );
        gaps.push(run.run(150).last_gap());
    }
    assert!(gaps[0] < 1e-8);
    assert!(gaps[1] < 1e-4);
    assert!(gaps[2] < 1e-1, "50% erasures: gap={:.3e}", gaps[2]);
}
