//! Cross-module property tests of the paper's analytical invariants,
//! using the first-party prop harness on full runs.

use cq_ggadmm::algs::{AlgSpec, Problem, Run, RunOptions, Schedule};
use cq_ggadmm::censor::CensorConfig;
use cq_ggadmm::data::synthetic;
use cq_ggadmm::graph::Topology;
use cq_ggadmm::quant::QuantConfig;
use cq_ggadmm::testing::prop::check;

fn random_problem(g: &mut cq_ggadmm::testing::prop::Gen) -> (Problem, Topology) {
    let n = g.usize_in(4, 12);
    let d = g.usize_in(2, 8);
    let seed = g.u64();
    let topo = Topology::random_bipartite(n, g.f64_in(0.2, 0.8), seed);
    let ds = synthetic::linear_dataset(n * 12, d, seed);
    (Problem::new(&ds, &topo, g.f64_in(1.0, 20.0), 0.0, seed), topo)
}

#[test]
fn dual_variables_sum_to_zero_for_all_variants() {
    // Theorem 3's initialization condition: alpha^0 = 0 in col(M_-);
    // the per-edge antisymmetry keeps sum_n alpha_n = 0 forever, for
    // every schedule and every censoring/quantization combination.
    check("sum_n alpha_n == 0", 15, |g| {
        let (p, t) = random_problem(g);
        let spec = match g.usize_in(0, 3) {
            0 => AlgSpec::ggadmm(),
            1 => AlgSpec::c_ggadmm(0.3, 0.85),
            2 => AlgSpec::cq_ggadmm(0.3, 0.85, 0.99, 2),
            _ => AlgSpec::c_admm(0.1, 0.9),
        };
        let mut run = Run::new(p, t, spec, RunOptions { seed: g.u64(), ..Default::default() });
        for _ in 0..25 {
            run.step();
            assert!(run.dual_sum_norm() < 1e-7, "dual drift {}", run.dual_sum_norm());
        }
    });
}

#[test]
fn loss_gap_and_consensus_vanish_for_all_variants() {
    check("primal residual and optimality gap -> 0", 8, |g| {
        let (p, t) = random_problem(g);
        let spec = match g.usize_in(0, 2) {
            0 => AlgSpec::ggadmm(),
            1 => AlgSpec::c_ggadmm(0.2, 0.85),
            _ => AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 3),
        };
        let mut run = Run::new(p, t, spec, RunOptions { seed: g.u64(), ..Default::default() });
        let trace = run.run(250);
        let last = trace.points.last().unwrap();
        assert!(last.loss_gap < 1e-3, "gap={:.3e}", last.loss_gap);
        assert!(last.consensus_gap < 1e-2, "consensus={:.3e}", last.consensus_gap);
    });
}

#[test]
fn quantized_run_never_exceeds_full_precision_bits() {
    check("quantized payload < 32d per transmission", 10, |g| {
        let (p, t) = random_problem(g);
        let d = p.d;
        let spec = AlgSpec {
            name: "Q".into(),
            schedule: Schedule::Alternating,
            censor: None,
            quant: Some(QuantConfig { bits0: 2, omega: 0.995, max_bits: 24 }),
            update: cq_ggadmm::algs::UpdateRule::Admm,
            bits_split: None,
        };
        let mut run = Run::new(p, t, spec, RunOptions { seed: g.u64(), ..Default::default() });
        for _ in 0..40 {
            run.step();
        }
        for tx in &run.comm().transmissions {
            assert!(
                tx.payload_bits <= (24 * d + 64) as u64,
                "payload {} bits",
                tx.payload_bits
            );
            assert!(tx.payload_bits < (32 * d) as u64 || d < 9,
                "quantized payload should beat 32d for d >= 9");
        }
    });
}

#[test]
fn censoring_error_bounded_along_runs() {
    // eq. (31): whenever a worker is censored, the kept state is within
    // tau^k of the candidate; we instrument via the public snapshot API
    check("hat lags theta by at most tau after censoring", 8, |g| {
        let (p, t) = random_problem(g);
        let tau0 = g.f64_in(0.1, 1.0);
        let xi = g.f64_in(0.7, 0.95);
        let cfg = CensorConfig { tau0, xi };
        let spec = AlgSpec::c_ggadmm(tau0, xi);
        let mut run = Run::new(p, t.clone(), spec, RunOptions { seed: g.u64(), ..Default::default() });
        for k in 1..40u64 {
            run.step();
            for i in 0..t.n() {
                let snap = run.snapshot(i);
                let diff: f64 = snap
                    .theta
                    .iter()
                    .zip(&snap.hat)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt();
                // hat is either theta (transmitted) or within tau^k of it
                assert!(
                    diff < cfg.threshold(k) + 1e-9,
                    "worker {i} iter {k}: lag {diff} > tau {}",
                    cfg.threshold(k)
                );
            }
        }
    });
}

#[test]
fn message_counts_match_schedule_budgets() {
    check("transmissions per iteration <= N", 10, |g| {
        let (p, t) = random_problem(g);
        let n = t.n() as u64;
        let spec = AlgSpec::c_ggadmm(0.5, 0.8);
        let mut run = Run::new(p, t, spec, RunOptions { seed: g.u64(), ..Default::default() });
        for k in 0..30u64 {
            run.step();
            let count = run.comm().at_iteration(k).count() as u64;
            assert!(count <= n, "iteration {k}: {count} > {n}");
        }
    });
}
