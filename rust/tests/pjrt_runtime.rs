//! Integration tests of the PJRT runtime: AOT artifacts (Pallas/JAX
//! layers) vs the native Rust solvers — the cross-layer differential
//! signal of the whole reproduction.
//!
//! These tests need `artifacts/manifest.json` (run `make artifacts`);
//! they are skipped with a message otherwise so `cargo test` stays green
//! on a fresh checkout.  The whole file is additionally gated on the
//! `pjrt` cargo feature (the `xla` crate is not available in the default
//! offline build; see rust/Cargo.toml).
#![cfg(feature = "pjrt")]

use cq_ggadmm::algs::{AlgSpec, Problem, Run, RunOptions};
use cq_ggadmm::data::{partition_uniform, synthetic};
use cq_ggadmm::graph::Topology;
use cq_ggadmm::runtime::{context_for, Manifest};
use cq_ggadmm::solver::{Backend, LinearSolver, LogisticSolver, SubproblemSolver};
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_covers_experiment_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).expect("manifest");
    assert_eq!(m.row_block, 8);
    for name in [
        "linear_setup_56x50",
        "linear_setup_16x14",
        "linear_update_50",
        "linear_update_14",
        "logistic_newton_56x50",
        "logistic_newton_24x34",
        "quantize_50",
    ] {
        assert!(m.by_name(name).is_some(), "missing artifact {name}");
    }
}

#[test]
fn pjrt_linear_solver_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    // the (8, 4) test shape is built into the default artifact set
    let ds = synthetic::linear_dataset(32, 4, 5);
    let shards = partition_uniform(&ds, 4, 5);
    let rho = 2.0;
    let degree = 2;
    for sh in &shards {
        let mut native = LinearSolver::new(sh.x.clone(), sh.y.clone(), rho, degree);
        let mut pjrt = cq_ggadmm::runtime::pjrt_solver(
            &dir,
            cq_ggadmm::config::Task::Linear,
            sh,
            rho,
            0.0,
            degree,
        )
        .expect("pjrt solver");
        let alpha = vec![0.3, -0.1, 0.7, 0.0];
        let nbr = vec![1.0, 2.0, -1.0, 0.5];
        let warm = vec![0.0; 4];
        let a = native.update(&alpha, &nbr, &warm);
        let b = pjrt.update(&alpha, &nbr, &warm);
        for (x, y) in a.iter().zip(&b) {
            assert!(
                (x - y).abs() < 1e-4 * (1.0 + x.abs()),
                "native {x} vs pjrt {y}"
            );
        }
        // loss paths agree too
        assert!((native.loss(&a) - pjrt.loss(&a)).abs() < 1e-6 * (1.0 + native.loss(&a)));
    }
}

#[test]
fn pjrt_logistic_solver_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let ds = synthetic::logistic_dataset(32, 4, 6);
    let shards = partition_uniform(&ds, 4, 6);
    let (rho, mu0, degree) = (0.5, 0.05, 2);
    for sh in &shards {
        let mut native =
            LogisticSolver::new(sh.x.clone(), sh.y.clone(), mu0, rho, degree);
        let mut pjrt = cq_ggadmm::runtime::pjrt_solver(
            &dir,
            cq_ggadmm::config::Task::Logistic,
            sh,
            rho,
            mu0,
            degree,
        )
        .expect("pjrt solver");
        let alpha = vec![0.1, -0.2, 0.05, 0.3];
        let nbr = vec![0.5, 0.5, -0.5, 0.0];
        let warm = vec![0.0; 4];
        let a = native.update(&alpha, &nbr, &warm);
        let b = pjrt.update(&alpha, &nbr, &warm);
        for (x, y) in a.iter().zip(&b) {
            assert!(
                (x - y).abs() < 5e-3 * (1.0 + x.abs()),
                "native {x:?} vs pjrt {y:?} (fixed Newton budget, f32)"
            );
        }
    }
}

#[test]
fn pjrt_quantize_artifact_matches_rust_codec_semantics() {
    let Some(dir) = artifacts_dir() else { return };
    let ctx = context_for(&dir).expect("ctx");
    let d = 4usize;
    let v: Vec<f32> = vec![0.9, -0.4, 0.2, -0.05];
    let q_prev: Vec<f32> = vec![0.0; d];
    let radius = 1.0f32;
    let bits = 3u32;
    let levels = (1u32 << bits) as f32; // grid points
    let u: Vec<f32> = vec![0.99, 0.01, 0.5, 0.5]; // deterministic rounding
    let outs = ctx
        .execute(
            "quantize_4",
            &[
                xla::Literal::vec1(&v),
                xla::Literal::vec1(&q_prev),
                xla::Literal::vec1(&[radius]),
                xla::Literal::vec1(&[levels]),
                xla::Literal::vec1(&u),
            ],
        )
        .expect("quantize artifact");
    let codes = &outs[0];
    let recon = &outs[1];
    // replicate the arithmetic the Rust quantizer uses
    let delta = 2.0 * radius / (levels - 1.0);
    for i in 0..d {
        let c = (v[i] - q_prev[i] + radius) / delta;
        let low = c.floor();
        let frac = c - low;
        let expect = if u[i] < frac { low + 1.0 } else { low };
        let expect = expect.clamp(0.0, levels - 1.0);
        assert_eq!(codes[i], expect, "coord {i}");
        let er = q_prev[i] + delta * expect - radius;
        assert!((recon[i] - er).abs() < 1e-5, "recon {i}: {} vs {er}", recon[i]);
    }
}

#[test]
fn pjrt_full_run_tracks_native_run() {
    let Some(dir) = artifacts_dir() else { return };
    // paper-shaped shards: synth-linear across 24 workers -> (50, 50)
    let ds = synthetic::linear_dataset(1200, 50, 21);
    let topo = Topology::random_bipartite(24, 0.3, 21);
    let problem = Problem::new(&ds, &topo, 30.0, 0.0, 21);

    let mut native = Run::new(
        problem.clone(),
        topo.clone(),
        AlgSpec::ggadmm(),
        RunOptions::default(),
    );
    let tn = native.run(25);

    let mut pjrt = Run::new(
        problem,
        topo,
        AlgSpec::ggadmm(),
        RunOptions {
            backend: Backend::Pjrt,
            artifacts_dir: Some(dir),
            ..RunOptions::default()
        },
    );
    let tp = pjrt.run(25);

    // same trajectory up to f32 artifact precision
    for (a, b) in tn.points.iter().zip(&tp.points) {
        let denom = 1.0 + a.loss_gap.abs();
        assert!(
            (a.loss_gap - b.loss_gap).abs() / denom < 5e-3,
            "iter {}: native {:.6e} vs pjrt {:.6e}",
            a.iteration,
            a.loss_gap,
            b.loss_gap
        );
        assert_eq!(a.cum_rounds, b.cum_rounds);
        assert_eq!(a.cum_bits, b.cum_bits);
    }
}

#[test]
fn missing_artifact_is_reported() {
    let Some(dir) = artifacts_dir() else { return };
    let ds = synthetic::linear_dataset(640, 37, 9); // d=37 has no artifact
    let shards = partition_uniform(&ds, 4, 9);
    let err = cq_ggadmm::runtime::pjrt_solver(
        &dir,
        cq_ggadmm::config::Task::Linear,
        &shards[0],
        1.0,
        0.0,
        1,
    )
    .err()
    .expect("should fail");
    assert!(err.contains("no linear_setup artifact"), "{err}");
}

#[test]
fn manifest_missing_dir_errors() {
    let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
    assert!(err.contains("cannot read"));
}
