//! Paper-scale integration tests: the qualitative claims of every figure
//! must hold on the real workloads (shape fidelity, not absolute numbers).

use cq_ggadmm::experiments::{self, ExecOptions};
use cq_ggadmm::metrics::Trace;

fn get<'a>(traces: &'a [Trace], name: &str) -> &'a Trace {
    traces
        .iter()
        .find(|t| t.algorithm == name)
        .unwrap_or_else(|| panic!("missing trace {name}"))
}

/// Figure 2 (linear regression, synthetic, N=24): the paper's ordering.
#[test]
#[cfg_attr(debug_assertions, ignore = "paper-scale workload; run with `cargo test --release`")]
fn fig2_orderings_hold() {
    let spec = experiments::fig2();
    let res = experiments::run_figure(&spec, &ExecOptions::default());
    let t = spec.target_gap;
    let gg = get(&res.traces, "GGADMM").first_below(t).expect("GGADMM");
    let c = get(&res.traces, "C-GGADMM").first_below(t).expect("C-GGADMM");
    let cq = get(&res.traces, "CQ-GGADMM").first_below(t).expect("CQ-GGADMM");
    let ca = get(&res.traces, "C-ADMM").first_below(t).expect("C-ADMM");

    // (a) per-iteration: GGADMM-family ~equal, C-ADMM needs far more
    assert!(ca.iteration > 2 * gg.iteration, "{} vs {}", ca.iteration, gg.iteration);
    assert!(c.iteration < 3 * gg.iteration);
    // (b) comm rounds: censoring wins
    assert!(c.cum_rounds < gg.cum_rounds, "{} vs {}", c.cum_rounds, gg.cum_rounds);
    // (c) bits: quantization wins by a lot
    assert!(cq.cum_bits * 3 < gg.cum_bits, "{} vs {}", cq.cum_bits, gg.cum_bits);
    assert!(cq.cum_bits < c.cum_bits);
    // (d) energy: CQ-GGADMM orders of magnitude below C-ADMM
    assert!(cq.cum_energy_j * 100.0 < ca.cum_energy_j);
    assert!(cq.cum_energy_j * 5.0 < gg.cum_energy_j);
}

/// Figure 3 (linear regression, Body Fat, N=18).
#[test]
#[cfg_attr(debug_assertions, ignore = "paper-scale workload; run with `cargo test --release`")]
fn fig3_orderings_hold() {
    let spec = experiments::fig3();
    let res = experiments::run_figure(&spec, &ExecOptions::default());
    let t = spec.target_gap;
    let gg = get(&res.traces, "GGADMM").first_below(t).expect("GGADMM");
    let c = get(&res.traces, "C-GGADMM").first_below(t).expect("C-GGADMM");
    let cq = get(&res.traces, "CQ-GGADMM").first_below(t).expect("CQ-GGADMM");
    assert!(c.cum_rounds <= gg.cum_rounds);
    assert!(cq.cum_bits < gg.cum_bits / 2);
    assert!(cq.cum_energy_j < gg.cum_energy_j);
}

/// Figure 4 (logistic regression, synthetic, N=24).
#[test]
#[cfg_attr(debug_assertions, ignore = "paper-scale workload; run with `cargo test --release`")]
fn fig4_orderings_hold() {
    let spec = experiments::fig4();
    let res = experiments::run_figure(&spec, &ExecOptions::default());
    let t = spec.target_gap;
    let gg = get(&res.traces, "GGADMM").first_below(t).expect("GGADMM");
    let cq = get(&res.traces, "CQ-GGADMM").first_below(t).expect("CQ-GGADMM");
    let ca = get(&res.traces, "C-ADMM").first_below(t).expect("C-ADMM");
    assert!(ca.iteration > gg.iteration);
    assert!(cq.cum_bits * 2 < gg.cum_bits);
    assert!(cq.cum_energy_j * 10.0 < ca.cum_energy_j);
}

/// Figure 5 (logistic regression, Derm, N=18).
#[test]
#[cfg_attr(debug_assertions, ignore = "paper-scale workload; run with `cargo test --release`")]
fn fig5_orderings_hold() {
    let spec = experiments::fig5();
    let res = experiments::run_figure(&spec, &ExecOptions::default());
    let t = spec.target_gap;
    let gg = get(&res.traces, "GGADMM").first_below(t).expect("GGADMM");
    let cq = get(&res.traces, "CQ-GGADMM").first_below(t).expect("CQ-GGADMM");
    assert!(cq.cum_bits < gg.cum_bits);
    assert!(cq.cum_energy_j < gg.cum_energy_j);
}

/// Two traces must agree bit-for-bit (every f64 compared by bits).
fn assert_traces_identical(a: &Trace, b: &Trace, ctx: &str) {
    assert_eq!(a.algorithm, b.algorithm, "{ctx}: algorithm label");
    assert_eq!(a.points.len(), b.points.len(), "{ctx}: point count");
    for (i, (p, q)) in a.points.iter().zip(&b.points).enumerate() {
        assert_eq!(p.iteration, q.iteration, "{ctx} point {i}");
        assert_eq!(p.cum_rounds, q.cum_rounds, "{ctx} point {i}");
        assert_eq!(p.cum_bits, q.cum_bits, "{ctx} point {i}");
        assert_eq!(
            p.loss_gap.to_bits(),
            q.loss_gap.to_bits(),
            "{ctx} point {i}: loss gap {} vs {}",
            p.loss_gap,
            q.loss_gap
        );
        assert_eq!(
            p.consensus_gap.to_bits(),
            q.consensus_gap.to_bits(),
            "{ctx} point {i}: consensus gap"
        );
        assert_eq!(p.cum_energy_j.to_bits(), q.cum_energy_j.to_bits(), "{ctx} point {i}: energy");
    }
}

/// The sweep scheduler's determinism contract: a pool-scheduled figure
/// sweep reproduces the serial driver's traces bit-for-bit (every run
/// owns its seed; results are collected in job order).  Scaled-down fig2
/// plus the fig6 density flattening, so the whole contract is exercised
/// in a normal `cargo test` run.
#[test]
fn pool_scheduled_sweep_bit_identical_to_serial() {
    let mut spec = experiments::fig2();
    spec.workers = 6;
    spec.iters_alt = 80;
    spec.iters_jacobian = 240;
    spec.target_gap = 1e-2;
    let serial = ExecOptions { sweep_threads: 1, ..ExecOptions::default() };
    let pooled = ExecOptions { sweep_threads: 4, ..ExecOptions::default() };
    let a = experiments::run_figure(&spec, &serial);
    let b = experiments::run_figure(&spec, &pooled);
    assert_eq!(a.traces.len(), b.traces.len());
    for (x, y) in a.traces.iter().zip(&b.traces) {
        assert_traces_identical(x, y, "fig2-small");
    }
    assert_eq!(a.summary.render(), b.summary.render(), "summaries must match");

    // fig6 flattens (density x algorithm) into one job list
    let mut f6 = experiments::fig6();
    f6.base.workers = 6;
    f6.base.iters_alt = 60;
    f6.base.iters_jacobian = 180;
    f6.base.target_gap = 1e-2;
    let ra = experiments::run_fig6(&f6, &serial);
    let rb = experiments::run_fig6(&f6, &pooled);
    assert_eq!(ra.len(), 2);
    for (fa, fb) in ra.iter().zip(&rb) {
        assert_eq!(fa.id, fb.id);
        for (x, y) in fa.traces.iter().zip(&fb.traces) {
            assert_traces_identical(x, y, &fa.id);
        }
    }
}

/// Figure 6: denser graphs converge in fewer iterations for every scheme,
/// with the scheme ordering preserved.
#[test]
#[cfg_attr(debug_assertions, ignore = "paper-scale workload; run with `cargo test --release`")]
fn fig6_density_effect() {
    let spec = experiments::fig6();
    let results = experiments::run_fig6(&spec, &ExecOptions::default());
    assert_eq!(results.len(), 2);
    let sparse = &results[0].traces;
    let dense = &results[1].traces;
    let t = spec.base.target_gap;
    for (s_tr, d_tr) in sparse.iter().zip(dense.iter()) {
        // the density speedup of §7.3 is about the GGADMM family; the
        // Jacobian baseline's fixed rho interacts with the degree-scaled
        // DCADMM penalty, so its optimum shifts with density (see
        // EXPERIMENTS.md fig6 notes)
        if d_tr.algorithm.starts_with("C-ADMM") {
            continue;
        }
        let s_it = s_tr.first_below(t).map(|p| p.iteration);
        let d_it = d_tr.first_below(t).map(|p| p.iteration);
        if let (Some(s), Some(d)) = (s_it, d_it) {
            assert!(
                d <= s + s / 4,
                "{}: dense {} should not be slower than sparse {}",
                d_tr.algorithm,
                d,
                s
            );
        }
    }
}
