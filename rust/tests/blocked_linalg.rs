//! Differential tests: blocked dense kernels vs the retained scalar
//! reference implementations.
//!
//! The blocked layer (`linalg::block`) reassociates reductions, so the
//! contracts here are tolerance-based (scaled by the magnitude of the
//! result); the scalar references are the seed implementations kept on
//! `Mat`/`Cholesky` as `*_scalar`.  Dimensions sweep 1..=200 including
//! non-multiples of every block constant (PANEL = 64, TILE = 32,
//! CHOL_NB = 32), plus an ill-conditioned SPD stress case.

use cq_ggadmm::linalg::{Cholesky, Mat};
use cq_ggadmm::testing::prop::check;
use cq_ggadmm::util::rng::Pcg64;

fn random_mat(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    let mut m = Mat::zeros(r, c);
    for i in 0..r {
        for j in 0..c {
            m[(i, j)] = rng.normal();
        }
    }
    m
}

fn random_spd(n: usize, seed: u64) -> Mat {
    let b = random_mat(n, n, seed);
    b.t().matmul(&b).add_diag(n as f64 * 0.1)
}

/// Dimensions straddling every block boundary (PANEL = 64, TILE = 32,
/// CHOL_NB = 32, the 2x2 micro-kernel and the 4-wide lanes).
const DIMS: &[usize] = &[1, 2, 3, 5, 31, 32, 33, 63, 64, 65, 96, 127, 128, 130, 161, 200];

#[test]
fn gram_blocked_matches_scalar_across_dims() {
    for (k, &d) in DIMS.iter().enumerate() {
        // rows both shorter and longer than a panel
        for &s in &[d / 2 + 1, d, 2 * d + 3] {
            let x = random_mat(s, d, (1000 * k + s) as u64);
            let blocked = x.gram();
            let scalar = x.gram_scalar();
            let tol = 1e-11 * (1.0 + scalar.max_abs());
            assert!(
                blocked.sub(&scalar).max_abs() < tol,
                "gram mismatch at s={s} d={d}: {:.3e}",
                blocked.sub(&scalar).max_abs()
            );
            assert!(blocked.is_symmetric(0.0), "gram not exactly symmetric at d={d}");
        }
    }
}

#[test]
fn matmul_blocked_matches_scalar_across_dims() {
    for (k, &n) in DIMS.iter().enumerate() {
        let m = n / 2 + 1;
        let p = (n % 7) + 1;
        let a = random_mat(m, n, (2000 + k) as u64);
        let b = random_mat(n, p, (3000 + k) as u64);
        let blocked = a.matmul(&b);
        let scalar = a.matmul_scalar(&b);
        let tol = 1e-11 * (1.0 + scalar.max_abs());
        assert!(
            blocked.sub(&scalar).max_abs() < tol,
            "matmul mismatch at {m}x{n}x{p}"
        );
    }
}

#[test]
fn gram_rows_matches_scalar_gemm_across_dims() {
    for (k, &s) in DIMS.iter().enumerate() {
        let c = (s % 13) + 2;
        let x = random_mat(s, c, (4000 + k) as u64);
        let blocked = x.gram_rows();
        let scalar = x.matmul_scalar(&x.t());
        let tol = 1e-11 * (1.0 + scalar.max_abs());
        assert!(
            blocked.sub(&scalar).max_abs() < tol,
            "gram_rows mismatch at s={s} c={c}"
        );
        assert!(blocked.is_symmetric(0.0));
    }
}

#[test]
fn cholesky_blocked_matches_scalar_across_dims() {
    for (k, &n) in DIMS.iter().enumerate() {
        let a = random_spd(n, (5000 + k) as u64);
        let mut blocked = Cholesky::workspace(n);
        assert!(blocked.factor_into(&a), "blocked factor failed at n={n}");
        let mut scalar = Cholesky::workspace(n);
        assert!(scalar.factor_into_scalar(&a), "scalar factor failed at n={n}");
        let diff = blocked.l().sub(scalar.l()).max_abs();
        let tol = 1e-10 * (1.0 + scalar.l().max_abs());
        assert!(diff < tol, "factor mismatch at n={n}: {diff:.3e}");
        // and the factor actually reproduces A
        let rec = blocked.l().matmul(&blocked.l().t());
        assert!(a.sub(&rec).max_abs() < 1e-9 * (1.0 + a.max_abs()), "L L^T != A at n={n}");
    }
}

#[test]
fn solve_blocked_matches_scalar_across_dims() {
    for (k, &n) in DIMS.iter().enumerate() {
        let a = random_spd(n, (6000 + k) as u64);
        let ch = Cholesky::new(&a).unwrap();
        let mut rng = Pcg64::new((7000 + k) as u64);
        let b = rng.normal_vec(n);
        let mut blocked = vec![0.0; n];
        ch.solve_into(&b, &mut blocked);
        let mut scalar = vec![1.0; n]; // stale contents must not matter
        ch.solve_into_scalar(&b, &mut scalar);
        for i in 0..n {
            let tol = 1e-9 * (1.0 + scalar[i].abs());
            assert!(
                (blocked[i] - scalar[i]).abs() < tol,
                "solve mismatch at n={n} i={i}: {} vs {}",
                blocked[i],
                scalar[i]
            );
        }
    }
}

#[test]
fn multi_rhs_solve_and_inverse_match_per_column_solves() {
    check("solve_many / inverse vs per-column scalar solves", 25, |g| {
        let n = g.usize_in(1, 70);
        let m = g.usize_in(1, 12);
        let a = random_spd(n, g.u64());
        let ch = Cholesky::new(&a).unwrap();
        let rhs = random_mat(n, m, g.u64());
        let mut many = rhs.clone();
        ch.solve_many_into(&mut many);
        let mut col = vec![0.0; n];
        let mut x = vec![0.0; n];
        for j in 0..m {
            for i in 0..n {
                col[i] = rhs[(i, j)];
            }
            ch.solve_into_scalar(&col, &mut x);
            for i in 0..n {
                assert!(
                    (many[(i, j)] - x[i]).abs() < 1e-8 * (1.0 + x[i].abs()),
                    "solve_many col {j} row {i}"
                );
            }
        }
        // inverse: one blocked sweep vs A * A^{-1} = I, exactly symmetric
        let inv = ch.inverse();
        assert!(inv.is_symmetric(0.0), "inverse must be exactly symmetric");
        let id = a.matmul(&inv);
        assert!(
            id.sub(&Mat::eye(n)).max_abs() < 1e-7,
            "A * A^-1 != I at n={n}: {:.3e}",
            id.sub(&Mat::eye(n)).max_abs()
        );
    });
}

#[test]
fn matvec_blocked_bit_identical_to_per_row_dot() {
    check("blocked matvec == per-row dot (bitwise)", 60, |g| {
        let r = g.usize_in(1, 40);
        let c = g.usize_in(1, 40);
        let m = random_mat(r, c, g.u64());
        let v = g.normal_vec(c);
        let fast = m.matvec(&v);
        for i in 0..r {
            let want = cq_ggadmm::util::dot(m.row(i), &v);
            assert_eq!(fast[i].to_bits(), want.to_bits(), "row {i} of {r}x{c}");
        }
    });
}

#[test]
fn ill_conditioned_spd_stress() {
    // A = B^T B + eps*I with tiny eps: condition number ~1e9-1e12.  The
    // blocked factorization must still succeed, be backward stable
    // (L L^T ~ A relative to ||A||), solve to a small residual, and
    // agree with the scalar reference about positive-definiteness.
    for &n in &[33usize, 65, 100] {
        let b = random_mat(n, n, 0xBAD + n as u64);
        let a = b.t().matmul(&b).add_diag(1e-9);
        let mut blocked = Cholesky::workspace(n);
        let ok_blocked = blocked.factor_into(&a);
        let mut scalar = Cholesky::workspace(n);
        let ok_scalar = scalar.factor_into_scalar(&a);
        assert_eq!(ok_blocked, ok_scalar, "PD disagreement at n={n}");
        assert!(ok_blocked, "ill-conditioned SPD must still factor at n={n}");
        let rec = blocked.l().matmul(&blocked.l().t());
        let rel = a.sub(&rec).max_abs() / (1.0 + a.max_abs());
        assert!(rel < 1e-10, "backward error {rel:.3e} at n={n}");
        // residual check: ||A x - b|| small relative to ||b||
        let mut rng = Pcg64::new(n as u64);
        let rhs = rng.normal_vec(n);
        let mut x = vec![0.0; n];
        blocked.solve_into(&rhs, &mut x);
        let ax = a.matvec(&x);
        let resid: f64 = ax
            .iter()
            .zip(&rhs)
            .map(|(p, q)| (p - q) * (p - q))
            .sum::<f64>()
            .sqrt();
        // Cholesky is backward stable: the residual stays far below the
        // forward error a ~1e11 condition number would allow
        let bnorm = cq_ggadmm::util::norm2(&rhs);
        assert!(resid < 1e-3 * (1.0 + bnorm), "residual {resid:.3e} at n={n}");
    }
}

#[test]
fn blocked_factor_rejects_indefinite_like_scalar() {
    let a = Mat::from_rows(&[
        &[1.0, 2.0, 0.0],
        &[2.0, 1.0, 0.0],
        &[0.0, 0.0, 1.0],
    ]);
    let mut ws = Cholesky::workspace(3);
    assert!(!ws.factor_into(&a));
    assert!(!ws.factor_into_scalar(&a));
    // and the workspace stays reusable after the failure
    let good = random_spd(3, 99);
    assert!(ws.factor_into(&good));
}
