//! Differential suite for the SIMD kernel tier and the pool-parallel
//! trailing updates (ISSUE 7).
//!
//! Contract under test (see `linalg/block.rs` module docs):
//! * the scalar tier is the bit-exact reference; the AVX2 tier must agree
//!   to FMA-reassociation tolerance on every reduction-style kernel
//!   (dot / norm2 / gram family / matmul / matvec / Cholesky), over
//!   dimensions 1..=200 **including non-multiple-of-lane sizes** where
//!   the vector tail paths run;
//! * the axpy family carries no FMA by design, so it is **bit-identical**
//!   across tiers (this is what keeps triangular backward sweeps and
//!   checkpoint replay tier-stable);
//! * pool-parallel execution is **bit-identical** to serial execution
//!   within a tier (disjoint output ownership, unchanged per-entry
//!   reduction order) — parallelism may change wall-clock, never bits.
//!
//! Every test here uses the explicit-tier APIs (`*_with_tier`,
//! `KernelCtx`) so the process-global tier is never mutated — except the
//! single cross-tier checkpoint test at the bottom, which is exactly the
//! scenario those APIs exist to keep out of the rest of the suite.

use cq_ggadmm::linalg::block::{self, KernelCtx};
use cq_ggadmm::linalg::{Cholesky, KernelTier, Mat};
use cq_ggadmm::util::rng::Pcg64;
use cq_ggadmm::util::{axpy_with_tier, dot_with_tier, norm2_with_tier};

fn random_mat(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    let mut x = Mat::zeros(r, c);
    for i in 0..r {
        for j in 0..c {
            x[(i, j)] = rng.normal();
        }
    }
    x
}

/// The tier pair under test: scalar reference vs the vectorized tier.
/// On hosts without AVX2+FMA the "vectorized" side falls back to the
/// scalar body, so the comparisons hold trivially and the suite stays
/// green on every architecture.
fn tier_pair() -> (KernelTier, KernelTier) {
    (KernelTier::Scalar, KernelTier::vectorized().unwrap_or(KernelTier::Scalar))
}

fn assert_close(a: f64, b: f64, tol: f64, what: &str) {
    assert!(
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())),
        "{what}: {a} vs {b} (tol {tol})"
    );
}

fn assert_mats_close(a: &Mat, b: &Mat, tol: f64, what: &str) {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{what}: shape");
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            assert_close(a[(i, j)], b[(i, j)], tol, &format!("{what} [{i},{j}]"));
        }
    }
}

fn assert_mats_bit_identical(a: &Mat, b: &Mat, what: &str) {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{what}: shape");
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            assert_eq!(
                a[(i, j)].to_bits(),
                b[(i, j)].to_bits(),
                "{what} [{i},{j}]: {} vs {}",
                a[(i, j)],
                b[(i, j)]
            );
        }
    }
}

// ---------------------------------------------------------------------
// util reductions: every length 1..=200 (all lane-tail shapes)
// ---------------------------------------------------------------------

#[test]
fn util_reductions_differential_over_dims() {
    let (sca, vec) = tier_pair();
    let mut rng = Pcg64::new(11);
    for n in 1..=200usize {
        let a = rng.normal_vec(n);
        let b = rng.normal_vec(n);
        // FMA reassociation drift only: O(n eps) relative
        let tol = 1e-13 * (1.0 + n as f64);
        assert_close(
            dot_with_tier(sca, &a, &b),
            dot_with_tier(vec, &a, &b),
            tol,
            &format!("dot n={n}"),
        );
        assert_close(
            norm2_with_tier(sca, &a),
            norm2_with_tier(vec, &a),
            tol,
            &format!("norm2 n={n}"),
        );
        // axpy carries no FMA by design: bit-identical across tiers
        let mut out_s = b.clone();
        let mut out_v = b.clone();
        axpy_with_tier(sca, &mut out_s, 0.37, &a);
        axpy_with_tier(vec, &mut out_v, 0.37, &a);
        for (x, y) in out_s.iter().zip(&out_v) {
            assert_eq!(x.to_bits(), y.to_bits(), "axpy n={n}: {x} vs {y}");
        }
    }
}

// ---------------------------------------------------------------------
// gram family / matmul / matvec: vectorized vs scalar tier, serial,
// at non-lane-multiple shapes
// ---------------------------------------------------------------------

/// Dimensions straddling the 4-lane AVX2 width, the 2x2 micro-tile, the
/// TILE=32 output tile and the PANEL=64 packing width.
const DIMS: &[usize] = &[1, 2, 3, 5, 7, 8, 9, 15, 17, 31, 32, 33, 63, 64, 65, 97, 129, 200];

#[test]
fn gram_family_vectorized_matches_scalar() {
    let (sca, vec) = tier_pair();
    for (t, &d) in DIMS.iter().enumerate() {
        let s = d + 3; // rows != cols keeps the packing paths honest
        let x = random_mat(s, d, 100 + t as u64);
        let tol = 1e-12 * (1.0 + s as f64);

        let mut g_s = Mat::zeros(d, d);
        let mut g_v = Mat::zeros(d, d);
        block::gram_into_ctx(KernelCtx::serial(sca), &x, &mut g_s);
        block::gram_into_ctx(KernelCtx::serial(vec), &x, &mut g_v);
        assert_mats_close(&g_s, &g_v, tol, &format!("gram d={d}"));

        let mut rng = Pcg64::new(200 + t as u64);
        let w: Vec<f64> = (0..s).map(|_| rng.uniform()).collect();
        let mut pack_s = Vec::new();
        let mut pack_v = Vec::new();
        block::weighted_gram_into_ctx(KernelCtx::serial(sca), &x, &w, &mut g_s, &mut pack_s);
        block::weighted_gram_into_ctx(KernelCtx::serial(vec), &x, &w, &mut g_v, &mut pack_v);
        assert_mats_close(&g_s, &g_v, tol, &format!("weighted_gram d={d}"));

        let mut r_s = Mat::zeros(s, s);
        let mut r_v = Mat::zeros(s, s);
        block::gram_rows_into_ctx(KernelCtx::serial(sca), &x, &mut r_s);
        block::gram_rows_into_ctx(KernelCtx::serial(vec), &x, &mut r_v);
        assert_mats_close(&r_s, &r_v, tol, &format!("gram_rows d={d}"));

        let b = random_mat(d, d + 2, 300 + t as u64);
        let mut m_s = Mat::zeros(s, d + 2);
        let mut m_v = Mat::zeros(s, d + 2);
        block::matmul_into_ctx(KernelCtx::serial(sca), &x, &b, &mut m_s);
        block::matmul_into_ctx(KernelCtx::serial(vec), &x, &b, &mut m_v);
        assert_mats_close(&m_s, &m_v, tol, &format!("matmul d={d}"));

        let v = rng.normal_vec(d);
        let mut mv_s = vec![0.0; s];
        let mut mv_v = vec![0.0; s];
        block::matvec_into_ctx(KernelCtx::serial(sca), &x, &v, &mut mv_s);
        block::matvec_into_ctx(KernelCtx::serial(vec), &x, &v, &mut mv_v);
        for i in 0..s {
            assert_close(mv_s[i], mv_v[i], tol, &format!("matvec d={d} [{i}]"));
        }
    }
}

// ---------------------------------------------------------------------
// Cholesky: well- and ill-conditioned SPD inputs across tiers
// ---------------------------------------------------------------------

fn max_residual(l: &Mat, a: &Mat) -> f64 {
    let rec = l.matmul(&l.t());
    a.sub(&rec).max_abs()
}

#[test]
fn cholesky_vectorized_matches_scalar() {
    let (sca, vec) = tier_pair();
    for (t, &d) in DIMS.iter().enumerate() {
        let a = random_mat(d, d, 400 + t as u64).gram().add_diag(d as f64 * 0.1);
        let mut ws_s = Cholesky::workspace(d);
        let mut ws_v = Cholesky::workspace(d);
        assert!(ws_s.factor_into_ctx(KernelCtx::serial(sca), &a));
        assert!(ws_v.factor_into_ctx(KernelCtx::serial(vec), &a));
        let tol = 1e-11 * (1.0 + d as f64);
        assert_mats_close(ws_s.l(), ws_v.l(), tol, &format!("cholesky L d={d}"));

        // the solve's backward sweep is axpy-built (tier-invariant); the
        // forward sweep drifts only by FMA reassociation
        let mut rng = Pcg64::new(500 + t as u64);
        let b = rng.normal_vec(d);
        let mut x_s = vec![0.0; d];
        let mut x_v = vec![0.0; d];
        ws_s.solve_into_with_tier(sca, &b, &mut x_s);
        ws_s.solve_into_with_tier(vec, &b, &mut x_v);
        for i in 0..d {
            assert_close(x_s[i], x_v[i], tol, &format!("solve d={d} [{i}]"));
        }
    }
}

#[test]
fn cholesky_ill_conditioned_spd_both_tiers() {
    let (sca, vec) = tier_pair();

    // Hilbert matrix (condition number ~3e13 at n=10) plus a tiny ridge:
    // both tiers must factor it and reconstruct A to near-eps residual.
    let n = 10;
    let mut hil = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            hil[(i, j)] = 1.0 / ((i + j + 1) as f64);
        }
    }
    let hil = hil.add_diag(1e-10);
    for (tier, name) in [(sca, "scalar"), (vec, "vectorized")] {
        let mut ws = Cholesky::workspace(n);
        assert!(
            ws.factor_into_ctx(KernelCtx::serial(tier), &hil),
            "{name} tier must factor the ridged Hilbert matrix"
        );
        let res = max_residual(ws.l(), &hil);
        assert!(res < 1e-14, "{name} Hilbert residual {res}");
    }

    // graded SPD matrix: row/column scales spanning 12 orders of
    // magnitude — exercises the trailing-update subtraction paths where
    // cancellation is worst.  The tiers need only agree on the scaled
    // problem to reconstruction accuracy, not bitwise.
    let d = 96;
    let base = random_mat(d, d, 9).gram().add_diag(d as f64 * 0.1);
    let mut graded = Mat::zeros(d, d);
    for i in 0..d {
        let si = 10f64.powf(-12.0 * i as f64 / d as f64);
        for j in 0..d {
            let sj = 10f64.powf(-12.0 * j as f64 / d as f64);
            graded[(i, j)] = si * sj * base[(i, j)];
        }
    }
    for (tier, name) in [(sca, "scalar"), (vec, "vectorized")] {
        let mut ws = Cholesky::workspace(d);
        assert!(
            ws.factor_into_ctx(KernelCtx::serial(tier), &graded),
            "{name} tier must factor the graded SPD matrix"
        );
        // relative to the largest entry (1.0-scale corner), the residual
        // stays near machine precision because Cholesky is
        // row-equilibration invariant
        let res = max_residual(ws.l(), &graded);
        assert!(res < 1e-10 * graded.max_abs(), "{name} graded residual {res}");
    }
}

// ---------------------------------------------------------------------
// pool-parallel vs serial: bit-identical within each tier
// ---------------------------------------------------------------------

#[test]
fn pooled_kernels_bit_identical_to_serial_per_tier() {
    let mut tiers = vec![KernelTier::Scalar];
    tiers.extend(KernelTier::vectorized());
    for tier in tiers {
        let name = tier.name();
        let pooled = KernelCtx::with_tier(tier);
        let serial = KernelCtx::serial(tier);

        // gram above the PAR_MIN_DIM stripe threshold (non-tile-multiple)
        let d = block::PAR_MIN_DIM + 37;
        let x = random_mat(d, d, 600);
        let mut g_p = Mat::zeros(d, d);
        let mut g_s = Mat::zeros(d, d);
        block::gram_into_ctx(pooled, &x, &mut g_p);
        block::gram_into_ctx(serial, &x, &mut g_s);
        assert_mats_bit_identical(&g_p, &g_s, &format!("{name} pooled gram d={d}"));

        // blocked Cholesky: pooled panel solves + trailing SYRK stripes
        let spd = g_s.clone().add_diag(d as f64 * 0.1);
        let mut l_p = Mat::zeros(d, d);
        let mut l_s = Mat::zeros(d, d);
        assert!(block::cholesky_factor_blocked_ctx(pooled, &spd, &mut l_p));
        assert!(block::cholesky_factor_blocked_ctx(serial, &spd, &mut l_s));
        assert_mats_bit_identical(&l_p, &l_s, &format!("{name} pooled cholesky d={d}"));

        // GEMM above the PAR_MIN_FLOPS row-block threshold
        let a = random_mat(256, 256, 601);
        let b = random_mat(256, 256, 602);
        let mut m_p = Mat::zeros(256, 256);
        let mut m_s = Mat::zeros(256, 256);
        block::matmul_into_ctx(pooled, &a, &b, &mut m_p);
        block::matmul_into_ctx(serial, &a, &b, &mut m_s);
        assert_mats_bit_identical(&m_p, &m_s, &format!("{name} pooled matmul"));

        // matvec above the PAR_MIN_MV threshold (2 * 2048 * 1200 > 2^22)
        let big = random_mat(2048, 1200, 603);
        let mut rng = Pcg64::new(604);
        let v = rng.normal_vec(1200);
        let mut mv_p = vec![0.0; 2048];
        let mut mv_s = vec![0.0; 2048];
        block::matvec_into_ctx(pooled, &big, &v, &mut mv_p);
        block::matvec_into_ctx(serial, &big, &v, &mut mv_s);
        for i in 0..2048 {
            assert_eq!(
                mv_p[i].to_bits(),
                mv_s[i].to_bits(),
                "{name} pooled matvec [{i}]: {} vs {}",
                mv_p[i],
                mv_s[i]
            );
        }
    }
}

// ---------------------------------------------------------------------
// cross-tier checkpoint handoff — the ONE test that mutates the
// process-global tier
// ---------------------------------------------------------------------

/// Checkpoint **bit-identity is per-tier** (persistence.rs asserts it
/// under the pinned ambient tier); this test covers the complementary
/// contract: a checkpoint written under the vectorized tier must
/// *resume correctly* — same iteration count, finite state, convergent
/// trajectory — under the scalar tier, because the checkpoint format
/// stores plain f64 state with no tier-dependent layout.  The resumed
/// trajectory is NOT asserted bit-equal to a single-tier run (solver
/// internals legitimately differ by FMA reassociation); it must land on
/// the same minimizer to solver tolerance.
///
/// This is the only test in the binary that flips the global tier, and
/// every other test here uses explicit-tier APIs, so test-thread
/// interleaving cannot poison their dispatch.
#[test]
fn checkpoint_written_under_simd_resumes_under_scalar() {
    use cq_ggadmm::algs::{AlgSpec, Problem, Run};
    use cq_ggadmm::config::ExecutionConfig;
    use cq_ggadmm::data::synthetic;
    use cq_ggadmm::graph::Topology;
    use cq_ggadmm::io::{checkpoint, PersistableEngine};
    use cq_ggadmm::linalg::{kernel_tier, set_kernel_tier};

    let Some(simd) = KernelTier::vectorized() else {
        // no second tier on this host: the handoff is vacuous
        return;
    };
    let ambient = kernel_tier();

    let n = 12;
    let ds = synthetic::linear_dataset(n * 8, 5, 71);
    let topo = Topology::random_bipartite(n, 0.3, 71);
    let problem = Problem::new(&ds, &topo, 5.0, 0.0, 71);
    let spec = AlgSpec::ggadmm();
    let exec = ExecutionConfig::default().with_seed(71);
    let mk = || Run::new(problem.clone(), topo.clone(), spec.clone(), exec.clone());

    const K1: u64 = 9;
    const K2: u64 = 14;

    // reference: the whole trajectory under the scalar tier
    set_kernel_tier(KernelTier::Scalar);
    let mut full_scalar = mk();
    for _ in 0..(K1 + K2) {
        full_scalar.step();
    }
    let reference = full_scalar.snapshot_state();

    // first half under the SIMD tier, checkpointed at K1
    set_kernel_tier(simd);
    let mut first = mk();
    for _ in 0..K1 {
        first.step();
    }
    let bytes = checkpoint::encode(&first.snapshot_state());
    drop(first);

    // second half resumed under the scalar tier (fresh engine, as a
    // restarted process on a non-AVX2 host would build it)
    set_kernel_tier(KernelTier::Scalar);
    let mut second = mk();
    second.restore_state(&checkpoint::decode(&bytes).unwrap());
    assert_eq!(second.iteration(), K1, "cross-tier resume point");
    for _ in 0..K2 {
        second.step();
    }
    let resumed = second.snapshot_state();
    set_kernel_tier(ambient);

    assert_eq!(resumed.iteration, reference.iteration);
    for (c, r) in resumed.cores.iter().zip(&reference.cores) {
        for (a, b) in c.theta.iter().zip(&r.theta) {
            assert!(a.is_finite(), "cross-tier resume produced non-finite theta");
            // both trajectories contract to the same consensus point;
            // the tiers differ only by accumulated FMA reassociation
            assert!(
                (a - b).abs() < 1e-6,
                "cross-tier resume diverged from the scalar trajectory: {a} vs {b}"
            );
        }
    }
}
