//! Single-block transparency of the multi-block parameter refactor.
//!
//! `param::Blocks` threads the whole stack (solver, protocol core, wire
//! codec, medium accounting), but on flat (GLM) problems the refactor
//! must be **invisible**: a `Problem::with_model(.., ModelSpec::Glm)`
//! run — the degenerate one-block layout — must reproduce the classic
//! `Problem::new` run bit-for-bit on every axis the checkpoint codec
//! serializes (models, duals, RNG positions, bits/energy totals, the
//! full trace), across all six `AlgSpec` variants and both engines.
//! Byte equality of `checkpoint::encode` is the strongest such
//! statement: every f64 crosses it via `to_bits`.
//!
//! The per-block wire-framing round-trip property (bits 1..=32) lives
//! with the codec in `coordinator::message`; the multi-block engine
//! differential tests live in tests/coordinator_equivalence.rs and
//! tests/persistence.rs.

use cq_ggadmm::algs::{AlgSpec, Problem, Run};
use cq_ggadmm::config::{ExecutionConfig, ModelSpec};
use cq_ggadmm::coordinator::Coordinator;
use cq_ggadmm::data::synthetic;
use cq_ggadmm::graph::Topology;
use cq_ggadmm::io::checkpoint;
use cq_ggadmm::testing::prop::check;

/// Pin the kernel tier for the whole test binary (bit-identity is a
/// per-tier contract; see tests/coordinator_equivalence.rs).
fn pin_tier() {
    let t = cq_ggadmm::linalg::kernel_tier();
    cq_ggadmm::linalg::set_kernel_tier(t);
}

/// The paper's six ADMM-family variants.
fn variant(i: usize) -> AlgSpec {
    match i {
        0 => AlgSpec::ggadmm(),
        1 => AlgSpec::c_ggadmm(0.2, 0.85),
        2 => AlgSpec::q_ggadmm(0.995, 2),
        3 => AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2),
        4 => AlgSpec::c_admm(0.1, 0.9),
        _ => AlgSpec::gadmm_chain(),
    }
}

#[test]
fn glm_one_block_runs_are_bit_identical_to_flat_for_all_variants() {
    pin_tier();
    check("with_model(Glm) == Problem::new, Run engine", 12, |g| {
        let n = g.usize_in(4, 10);
        let seed = g.u64();
        let spec = variant(g.usize_in(0, 5));
        let topo = if spec.name == "GADMM" {
            Topology::chain(n)
        } else {
            Topology::random_bipartite(n, g.f64_in(0.3, 0.7), seed)
        };
        let ds = synthetic::linear_dataset(n * 10, 5, seed);
        let flat = Problem::new(&ds, &topo, 5.0, 0.0, seed);
        let modeled =
            Problem::with_model(&ds, &topo, 5.0, 0.0, seed, ModelSpec::Glm).unwrap();
        assert!(modeled.blocks.is_single(), "GLM is the one-block layout");
        assert_eq!(modeled.blocks.d(), flat.d);

        let e = ExecutionConfig::default().with_seed(seed).with_drop_prob(0.1);
        let mut a = Run::new(flat, topo.clone(), spec.clone(), e.clone());
        let mut b = Run::new(modeled, topo, spec, e);
        for _ in 0..8 {
            a.step();
            b.step();
        }
        let sa = a.snapshot_state();
        let bytes = checkpoint::encode(&sa);
        assert_eq!(
            bytes,
            checkpoint::encode(&b.snapshot_state()),
            "one-block run diverged from the flat run"
        );
        // no phantom per-block state: the ledgers stay empty and the
        // checkpoint stays the byte-stable version 2
        assert!(sa.block_bits.is_empty() && sa.block_stale.is_empty());
        assert_eq!(bytes[8], 2, "single-block checkpoints stay version 2");
    });
}

#[test]
fn glm_one_block_coordinator_matches_flat_run_bytes() {
    pin_tier();
    check("with_model(Glm), coordinator == flat Run", 8, |g| {
        let n = g.usize_in(4, 10);
        let seed = g.u64();
        let spec = variant(g.usize_in(0, 5));
        let topo = if spec.name == "GADMM" {
            Topology::chain(n)
        } else {
            Topology::random_bipartite(n, g.f64_in(0.3, 0.7), seed)
        };
        let ds = synthetic::linear_dataset(n * 10, 5, seed);
        let flat = Problem::new(&ds, &topo, 5.0, 0.0, seed);
        let modeled =
            Problem::with_model(&ds, &topo, 5.0, 0.0, seed, ModelSpec::Glm).unwrap();

        let e = ExecutionConfig::default().with_seed(seed).with_drop_prob(0.1);
        let mut a = Run::new(flat, topo.clone(), spec.clone(), e.clone());
        let mut coord = Coordinator::spawn(modeled, topo, spec, e.with_threads(2));
        for _ in 0..8 {
            a.step();
            coord.step();
        }
        assert_eq!(
            checkpoint::encode(&a.snapshot_state()),
            checkpoint::encode(&coord.snapshot_state()),
            "one-block coordinator diverged from the flat sequential run"
        );
    });
}

#[test]
fn uniform_one_entry_split_is_transparent_on_flat_problems() {
    pin_tier();
    // `--bits0 2` parses to a one-entry allocation; on a single-block
    // problem it must mean exactly what the plain uniform width means
    check("bits_split [b] == bits_split None on one block", 8, |g| {
        let n = g.usize_in(4, 8);
        let seed = g.u64();
        let topo = Topology::random_bipartite(n, 0.4, seed);
        let ds = synthetic::linear_dataset(n * 10, 5, seed);
        let p = Problem::new(&ds, &topo, 5.0, 0.0, seed);
        let plain = AlgSpec::q_ggadmm(0.995, 2);
        let split = AlgSpec::q_ggadmm(0.995, 2).with_bits_split(Some(vec![2]));
        split.validate().unwrap();
        let e = ExecutionConfig::default().with_seed(seed);
        let mut a = Run::new(p.clone(), topo.clone(), plain, e.clone());
        let mut b = Run::new(p, topo, split, e);
        for _ in 0..8 {
            a.step();
            b.step();
        }
        assert_eq!(
            checkpoint::encode(&a.snapshot_state()),
            checkpoint::encode(&b.snapshot_state()),
            "a one-entry split changed a flat run"
        );
    });
}
