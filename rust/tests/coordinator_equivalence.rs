//! The threaded coordinator and the sequential simulator implement the
//! same per-worker state machine; these tests lock their trajectories
//! together (same seeds => same quantizer streams => identical traces).

use cq_ggadmm::algs::{AlgSpec, Problem, Run, RunOptions};
use cq_ggadmm::coordinator::{Coordinator, CoordinatorOptions};
use cq_ggadmm::data::synthetic;
use cq_ggadmm::graph::Topology;

fn problem(n: usize, seed: u64) -> (Problem, Topology) {
    let topo = Topology::random_bipartite(n, 0.4, seed);
    let ds = synthetic::linear_dataset(n * 15, 6, seed);
    (Problem::new(&ds, &topo, 5.0, 0.0, seed), topo)
}

fn assert_traces_match(
    sim: &cq_ggadmm::metrics::Trace,
    coord: &cq_ggadmm::metrics::Trace,
    tol: f64,
) {
    assert_eq!(sim.points.len(), coord.points.len());
    for (a, b) in sim.points.iter().zip(&coord.points) {
        assert_eq!(a.cum_rounds, b.cum_rounds, "iter {}", a.iteration);
        assert_eq!(a.cum_bits, b.cum_bits, "iter {}", a.iteration);
        let denom = 1.0 + a.loss_gap.abs();
        assert!(
            (a.loss_gap - b.loss_gap).abs() / denom < tol,
            "iter {}: sim {:.9e} vs coord {:.9e}",
            a.iteration,
            a.loss_gap,
            b.loss_gap
        );
    }
}

#[test]
fn ggadmm_trajectories_identical() {
    let (p, t) = problem(8, 11);
    let mut sim = Run::new(p.clone(), t.clone(), AlgSpec::ggadmm(), RunOptions::default());
    let ts = sim.run(40);
    let coord = Coordinator::spawn(p, t, AlgSpec::ggadmm(), CoordinatorOptions::default());
    let tc = coord.run(40);
    // full-precision payloads cross the wire as f32, so tiny drift is
    // expected; counts must be exact
    assert_traces_match(&ts, &tc, 1e-5);
}

#[test]
fn c_ggadmm_trajectories_identical() {
    let (p, t) = problem(10, 12);
    let spec = AlgSpec::c_ggadmm(0.2, 0.85);
    let mut sim = Run::new(p.clone(), t.clone(), spec.clone(), RunOptions::default());
    let ts = sim.run(50);
    let coord = Coordinator::spawn(p, t, spec, CoordinatorOptions::default());
    let tc = coord.run(50);
    assert_traces_match(&ts, &tc, 1e-4);
}

#[test]
fn cq_ggadmm_trajectories_identical() {
    // same seed => same forked quantizer streams => identical stochastic
    // rounding decisions in both implementations
    let (p, t) = problem(8, 13);
    let spec = AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2);
    let opts = RunOptions { seed: 13, ..RunOptions::default() };
    let mut sim = Run::new(p.clone(), t.clone(), spec.clone(), opts);
    let ts = sim.run(50);
    let coord = Coordinator::spawn(
        p,
        t,
        spec,
        CoordinatorOptions { seed: 13, ..CoordinatorOptions::default() },
    );
    let tc = coord.run(50);
    assert_traces_match(&ts, &tc, 1e-4);
}

#[test]
fn c_admm_jacobian_also_matches() {
    let (p, t) = problem(8, 14);
    let spec = AlgSpec::c_admm(0.1, 0.9);
    let mut sim = Run::new(p.clone(), t.clone(), spec.clone(), RunOptions::default());
    let ts = sim.run(60);
    let coord = Coordinator::spawn(p, t, spec, CoordinatorOptions::default());
    let tc = coord.run(60);
    // NOTE: the coordinator's Jacobian phase must anchor on the worker's
    // own broadcast exactly like the simulator
    assert_traces_match(&ts, &tc, 1e-4);
}
