//! The sharded coordinator and the sequential simulator are thin drivers
//! over the same `protocol::WorkerCore` state machine, share solver
//! construction and quantizer RNG forking through `protocol::build_cores`,
//! and share the transmit path (energy accounting + erasure stream)
//! through `comm::Medium` — so their trajectories must match
//! **bit-for-bit**, not just within tolerance.
//!
//! These tests lock that across the paper's full algorithm family (all
//! six `AlgSpec` variants), both tasks (linear, logistic), and under
//! broadcast-erasure injection, at N = 64 workers sharded over a 4-thread
//! executor (N ≫ K: the scheduling itself must not perturb a single bit).
//!
//! The seed implementation could only match within 1e-4..1e-5 because its
//! full-precision payloads crossed the wire as f32; the rebuilt wire
//! carries f64 (the accounting still charges the paper's 32d bits), which
//! is what makes exact equality possible here.

use cq_ggadmm::algs::{AlgSpec, Problem, Run};
use cq_ggadmm::config::{ExecutionConfig, ModelSpec, TopologySpec};
use cq_ggadmm::coordinator::Coordinator;
use cq_ggadmm::data::synthetic;
use cq_ggadmm::graph::{gen, Topology};
use cq_ggadmm::metrics::Trace;

/// N = 64 simulated workers on 4 executor threads.
const N: usize = 64;
const THREADS: usize = 4;

/// Pin the kernel tier for the whole test binary.  Engine
/// bit-equivalence is a **per-tier** contract: both engines of every
/// pair must run under one explicitly resolved tier, because the SIMD
/// and scalar tiers legitimately differ by FMA reassociation.  The
/// first call freezes the ambient resolution (the `CQ_KERNEL_TIER`
/// override, or runtime detection); nothing in this binary flips it
/// afterwards — cross-tier handoff is covered by tests/simd_kernels.rs.
fn pin_tier() {
    let t = cq_ggadmm::linalg::kernel_tier();
    cq_ggadmm::linalg::set_kernel_tier(t);
}

fn problem(linear: bool, topo: &Topology, seed: u64) -> Problem {
    let n = topo.n();
    if linear {
        let ds = synthetic::linear_dataset(n * 10, 6, seed);
        Problem::new(&ds, topo, 5.0, 0.0, seed)
    } else {
        let ds = synthetic::logistic_dataset(n * 10, 6, seed);
        Problem::new(&ds, topo, 0.5, 0.05, seed)
    }
}

fn assert_traces_bit_identical(sim: &Trace, coord: &Trace, what: &str) {
    assert_eq!(sim.points.len(), coord.points.len(), "{what}: trace length");
    for (a, b) in sim.points.iter().zip(&coord.points) {
        let k = a.iteration;
        assert_eq!(a.iteration, b.iteration, "{what} iter {k}");
        assert_eq!(a.cum_rounds, b.cum_rounds, "{what} iter {k}: rounds");
        assert_eq!(a.cum_bits, b.cum_bits, "{what} iter {k}: bits");
        assert_eq!(
            a.loss_gap.to_bits(),
            b.loss_gap.to_bits(),
            "{what} iter {k}: loss gap {:.17e} vs {:.17e}",
            a.loss_gap,
            b.loss_gap
        );
        assert_eq!(
            a.consensus_gap.to_bits(),
            b.consensus_gap.to_bits(),
            "{what} iter {k}: consensus gap"
        );
        assert_eq!(
            a.cum_energy_j.to_bits(),
            b.cum_energy_j.to_bits(),
            "{what} iter {k}: energy"
        );
    }
}

/// Run both engines from ONE shared [`ExecutionConfig`] on the same
/// problem/spec/seed and compare bitwise.  Constructing both from the
/// same value is the point: the unified config must mean the same thing
/// to both engines (`Run` solves worker subproblems on `threads`
/// cores, the coordinator shards workers over `threads` executors —
/// either way the trajectory cannot move by a bit).
fn lock(spec: AlgSpec, topo: Topology, linear: bool, drop_prob: f64, seed: u64, iters: u64) {
    let p = problem(linear, &topo, seed);
    let what = format!(
        "{} / {} / drop={drop_prob}",
        spec.name,
        if linear { "linear" } else { "logistic" }
    );
    lock_on(p, spec, topo, what, drop_prob, seed, iters);
}

/// The engine-pair comparison itself, on an explicit problem (the MLP
/// legs build theirs via [`Problem::with_model`]).
fn lock_on(
    p: Problem,
    spec: AlgSpec,
    topo: Topology,
    what: String,
    drop_prob: f64,
    seed: u64,
    iters: u64,
) {
    pin_tier();
    let exec = ExecutionConfig::default()
        .with_seed(seed)
        .with_drop_prob(drop_prob)
        .with_threads(THREADS);
    let mut sim = Run::new(p.clone(), topo.clone(), spec.clone(), exec.clone());
    let ts = sim.run(iters);
    let coord = Coordinator::spawn(p, topo, spec, exec);
    let tc = coord.run(iters);
    assert_traces_bit_identical(&ts, &tc, &what);
}

fn bipartite(seed: u64) -> Topology {
    Topology::random_bipartite(N, 0.2, seed)
}

// ---- the six algorithm variants, linear task ------------------------

#[test]
fn ggadmm_linear_bit_identical() {
    lock(AlgSpec::ggadmm(), bipartite(11), true, 0.0, 11, 25);
}

#[test]
fn c_ggadmm_linear_bit_identical() {
    lock(AlgSpec::c_ggadmm(0.2, 0.85), bipartite(12), true, 0.0, 12, 30);
}

#[test]
fn q_ggadmm_linear_bit_identical() {
    // same seed => same forked quantizer streams => identical stochastic
    // rounding decisions in both engines
    lock(AlgSpec::q_ggadmm(0.995, 2), bipartite(13), true, 0.0, 13, 30);
}

#[test]
fn cq_ggadmm_linear_bit_identical() {
    lock(AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2), bipartite(14), true, 0.0, 14, 30);
}

#[test]
fn c_admm_jacobian_linear_bit_identical() {
    // the coordinator's Jacobian phase must anchor on the worker's own
    // broadcast exactly like the simulator
    lock(AlgSpec::c_admm(0.1, 0.9), bipartite(15), true, 0.0, 15, 30);
}

#[test]
fn gadmm_chain_linear_bit_identical() {
    // chain GADMM is GGADMM on Topology::chain, labelled as in the paper
    lock(AlgSpec::gadmm_chain(), Topology::chain(N), true, 0.0, 16, 30);
}

// ---- the six algorithm variants, logistic task ----------------------

#[test]
fn ggadmm_logistic_bit_identical() {
    lock(AlgSpec::ggadmm(), bipartite(21), false, 0.0, 21, 12);
}

#[test]
fn c_ggadmm_logistic_bit_identical() {
    lock(AlgSpec::c_ggadmm(0.2, 0.85), bipartite(22), false, 0.0, 22, 12);
}

#[test]
fn q_ggadmm_logistic_bit_identical() {
    lock(AlgSpec::q_ggadmm(0.995, 2), bipartite(23), false, 0.0, 23, 12);
}

#[test]
fn cq_ggadmm_logistic_bit_identical() {
    lock(AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2), bipartite(24), false, 0.0, 24, 12);
}

#[test]
fn c_admm_jacobian_logistic_bit_identical() {
    lock(AlgSpec::c_admm(0.1, 0.9), bipartite(25), false, 0.0, 25, 12);
}

#[test]
fn gadmm_chain_logistic_bit_identical() {
    lock(AlgSpec::gadmm_chain(), Topology::chain(N), false, 0.0, 26, 12);
}

// ---- erasure injection: the link-model RNG streams must align ------

#[test]
fn ggadmm_with_erasure_bit_identical() {
    lock(AlgSpec::ggadmm(), bipartite(31), true, 0.2, 31, 30);
}

#[test]
fn cq_ggadmm_with_erasure_bit_identical() {
    // quantizer forks advance the root stream before the erasure draws —
    // both engines must fork identically for the drops to line up
    lock(AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2), bipartite(32), true, 0.2, 32, 30);
}

#[test]
fn c_admm_with_erasure_bit_identical() {
    lock(AlgSpec::c_admm(0.1, 0.9), bipartite(33), true, 0.15, 33, 30);
}

#[test]
fn logistic_with_erasure_bit_identical() {
    lock(AlgSpec::c_ggadmm(0.2, 0.85), bipartite(34), false, 0.2, 34, 10);
}

// ---- generalized topology families (graph::gen) ---------------------
//
// The engines must stay bit-for-bit identical on every family the
// generator zoo produces, not just the seed's chain / random-bipartite
// shapes — including families that only become bipartite through the
// max-cut bipartition pass.

fn family(spec: TopologySpec, seed: u64) -> Topology {
    let b = gen::build(&spec, N, seed).expect("family builds at N=64");
    assert!(b.topology.is_connected() && b.topology.is_bipartite_consistent());
    b.topology
}

#[test]
fn ring_bit_identical() {
    // even ring: exact 2-coloring, the sparsest connected family
    lock(AlgSpec::ggadmm(), family(TopologySpec::Ring, 41), true, 0.0, 41, 30);
}

#[test]
fn torus_bit_identical() {
    // 64 = 8x8 torus: 4-regular, exact checkerboard coloring
    lock(
        AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2),
        family(TopologySpec::Grid { torus: true }, 42),
        true,
        0.0,
        42,
        30,
    );
}

#[test]
fn smallworld_bit_identical() {
    // Watts-Strogatz is not bipartite: this locks the engines on a
    // topology produced by the greedy max-cut bipartition
    let spec = TopologySpec::SmallWorld { k: 4, beta: 0.2 };
    let b = gen::build(&spec, N, 43).unwrap();
    assert!(b.dropped_edges > 0, "small world must exercise the max-cut path");
    lock(AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2), b.topology, true, 0.0, 43, 30);
}

#[test]
fn smallworld_logistic_bit_identical() {
    lock(
        AlgSpec::c_ggadmm(0.2, 0.85),
        family(TopologySpec::SmallWorld { k: 6, beta: 0.3 }, 44),
        false,
        0.0,
        44,
        12,
    );
}

#[test]
fn geometric_with_erasure_bit_identical() {
    // physical link distances + erasure: the energy/link accounting of
    // both engines must agree on radius-connected deployments too
    lock(
        AlgSpec::c_ggadmm(0.2, 0.85),
        family(TopologySpec::Geometric { radius_m: 120.0 }, 45),
        true,
        0.2,
        45,
        30,
    );
}

// ---- multi-block MLP model and the QDGD baseline --------------------
//
// The MLP threads the refactor end to end: per-block quantizer RNG
// forks, per-block censoring state, TAG_BLOCKS wire frames, and the
// per-block bits ledger all have to line up between the sequential
// simulator and the sharded coordinator for the traces to agree bitwise.

fn mlp_problem(topo: &Topology, hidden: usize, seed: u64) -> Problem {
    let ds = synthetic::linear_dataset(topo.n() * 10, 6, seed);
    Problem::with_model(&ds, topo, 5.0, 0.0, seed, ModelSpec::Mlp { hidden })
        .expect("linear dataset supports the MLP model")
}

fn lock_mlp(spec: AlgSpec, topo: Topology, drop_prob: f64, seed: u64, iters: u64) {
    let p = mlp_problem(&topo, 4, seed);
    assert_eq!(p.blocks.count(), 2, "MLP problems are two-block");
    let what = format!("{} / mlp / drop={drop_prob}", spec.name);
    lock_on(p, spec, topo, what, drop_prob, seed, iters);
}

#[test]
fn mlp_ggadmm_bit_identical() {
    lock_mlp(AlgSpec::ggadmm(), bipartite(51), 0.0, 51, 15);
}

#[test]
fn mlp_q_ggadmm_split_bit_identical() {
    // per-layer allocation: block 0 (W) at 6 bits, block 1 (v) at 2 —
    // the per-block quantizer forks must match across engines
    lock_mlp(
        AlgSpec::q_ggadmm(0.995, 6).with_bits_split(Some(vec![6, 2])),
        bipartite(52),
        0.0,
        52,
        15,
    );
}

#[test]
fn mlp_cq_ggadmm_with_erasure_bit_identical() {
    // censor + split quantization + drops: per-block tx_once flags and
    // the erasure stream alignment under TAG_BLOCKS frames
    lock_mlp(
        AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 4).with_bits_split(Some(vec![4, 2])),
        bipartite(53),
        0.15,
        53,
        15,
    );
}

#[test]
fn qdgd_mlp_bit_identical() {
    // the first-order Jacobian baseline on the two-block model
    lock_mlp(AlgSpec::qdgd(0.995, 8), bipartite(54), 0.0, 54, 15);
}

#[test]
fn qdgd_glm_bit_identical() {
    // QDGD on the flat single-block model: the degenerate path of the
    // new update rule must also agree across engines
    lock(AlgSpec::qdgd(0.995, 8), bipartite(55), true, 0.0, 55, 20);
}
