//! The TCP transport is the in-process coordinator, bit for bit.
//!
//! `serve` + `worker` run the same `protocol::WorkerCore` state machine
//! over localhost sockets that `coordinator::Coordinator` runs over
//! channels, against the same `comm::Medium` (bit/energy accounting,
//! erasure RNG) resolved in the same ascending worker order — so a
//! networked run must reproduce the in-process run **exactly**: trace,
//! rounds, bits, energy, and every f64 of durable worker state.
//!
//! "Exactly" is asserted through the checkpoint codec: the server's
//! final `checkpoint.bin` (written by `--checkpoint-every 0`, i.e.
//! final-iteration-only) is compared byte-for-byte against
//! `checkpoint::encode` of an in-process run built from the *same
//! manifest* — the `RunState` covers worker cores (quantizer RNGs,
//! censor history), medium totals, link RNG position, and the full
//! trace, so byte equality is bit equality over everything the paper's
//! figures are computed from.  Locked across all six `AlgSpec`
//! variants at N = 64 workers sharded over four worker processes.
//!
//! The disconnect test additionally locks the churn mapping: a worker
//! process that exits mid-run (`--exit-after-iter`) and rejoins must
//! leave the run in exactly the state a scheduled
//! [`ChurnSchedule`] leave/join pair would — the schedule is
//! reconstructed post-hoc from the server's event log and replayed
//! in-process.
//!
//! Like every bit-identity suite in this repo, the contract is
//! per-kernel-tier: the test binary pins the ambient tier and exports
//! it to the spawned processes via `CQ_KERNEL_TIER`.

use cq_ggadmm::config::{ExperimentManifest, ModelSpec};
use cq_ggadmm::coordinator::Coordinator;
use cq_ggadmm::graph::ChurnSchedule;
use cq_ggadmm::io::checkpoint;
use cq_ggadmm::io::PersistableEngine;
use cq_ggadmm::net;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// The compiled CLI under test.
const BIN: &str = env!("CARGO_BIN_EXE_cq-ggadmm");

/// N = 64 simulated workers, sharded over four worker processes.
const N: usize = 64;
const PROCS: usize = 4;

/// Generous per-process deadline: CI machines run these binaries in
/// debug profile under heavy parallelism.
const DEADLINE: Duration = Duration::from_secs(240);

/// Pin the kernel tier for the whole test binary and return its name.
/// Bit-equivalence is a per-tier contract; the spawned server/worker
/// processes inherit the same tier through `CQ_KERNEL_TIER`.
fn pin_tier() -> &'static str {
    let t = cq_ggadmm::linalg::kernel_tier();
    cq_ggadmm::linalg::set_kernel_tier(t);
    t.name()
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cq_net_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The manifest both sides run from.  Everything rides the defaults
/// (synth-linear, connectivity 0.3, the paper's censor/quantizer knobs)
/// except the identity of the run: algorithm, seed, iteration count,
/// and the erasure probability.
fn manifest(alg: &str, seed: u64, iters: usize, drop_prob: f64) -> ExperimentManifest {
    let mut m = ExperimentManifest::default();
    m.alg = alg.into();
    m.experiment.workers = N;
    m.experiment.iters = iters;
    m.experiment.seed = seed;
    m.exec.seed = seed;
    m.exec.drop_prob = drop_prob;
    m.validate().unwrap();
    m
}

/// Run the manifest in-process on the sharded coordinator and return
/// the final checkpoint bytes.
fn in_process_checkpoint(m: &ExperimentManifest) -> Vec<u8> {
    let (problem, topo, spec) = net::build_session(m).unwrap();
    let mut coord = Coordinator::spawn(problem, topo, spec, m.exec.clone());
    for _ in 0..m.experiment.iters {
        coord.step();
    }
    checkpoint::encode(&coord.snapshot_state())
}

fn spawn_serve(tier: &str, manifest_path: &Path, run_base: &Path, port_file: &Path) -> Child {
    Command::new(BIN)
        .arg("serve")
        .args(["--manifest".as_ref(), manifest_path.as_os_str()])
        .args(["--run-dir".as_ref(), run_base.as_os_str()])
        .args(["--checkpoint-every", "0"])
        .args(["--port-file".as_ref(), port_file.as_os_str()])
        .env("CQ_KERNEL_TIER", tier)
        .stdout(Stdio::null())
        .spawn()
        .expect("spawn serve")
}

fn spawn_worker(tier: &str, port: u16, ids: &str, exit_after: Option<u64>) -> Child {
    let mut cmd = Command::new(BIN);
    cmd.arg("worker")
        .args(["--connect", &format!("127.0.0.1:{port}")])
        .args(["--ids", ids])
        .env("CQ_KERNEL_TIER", tier)
        .stdout(Stdio::null());
    if let Some(k) = exit_after {
        cmd.args(["--exit-after-iter", &k.to_string()]);
    }
    cmd.spawn().expect("spawn worker")
}

/// Poll the server's `--port-file` until it appears (written atomically
/// via rename, so a present file is a complete file).
fn await_port(port_file: &Path, serve: &mut Child) -> u16 {
    let deadline = Instant::now() + DEADLINE;
    loop {
        if let Ok(text) = std::fs::read_to_string(port_file) {
            return text.trim().parse().expect("port file contents");
        }
        if let Some(status) = serve.try_wait().expect("poll serve") {
            panic!("serve exited before publishing its port: {status}");
        }
        assert!(Instant::now() < deadline, "timed out waiting for the port file");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Wait for a child with a deadline; panic (after killing it) on
/// timeout or nonzero exit.
fn await_exit(mut child: Child, what: &str) {
    let deadline = Instant::now() + DEADLINE;
    loop {
        if let Some(status) = child.try_wait().expect("poll child") {
            assert!(status.success(), "{what} failed: {status}");
            return;
        }
        if Instant::now() >= deadline {
            let _ = child.kill();
            let _ = child.wait();
            panic!("{what} timed out");
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// The run directory `serve --run-dir <base>` created (the sole child
/// of a base this test owns).
fn sole_run_dir(base: &Path) -> PathBuf {
    let mut dirs: Vec<PathBuf> = std::fs::read_dir(base)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.is_dir())
        .collect();
    assert_eq!(dirs.len(), 1, "expected exactly one run dir under {}", base.display());
    dirs.pop().unwrap()
}

fn networked_checkpoint(m: &ExperimentManifest, tier: &str, tag: &str) -> Vec<u8> {
    let dir = scratch(tag);
    let manifest_path = dir.join("manifest.toml");
    std::fs::write(&manifest_path, m.to_toml()).unwrap();
    let run_base = dir.join("runs");
    let port_file = dir.join("server.port");
    let mut serve = spawn_serve(tier, &manifest_path, &run_base, &port_file);
    let port = await_port(&port_file, &mut serve);
    let per = N / PROCS;
    let workers: Vec<Child> = (0..PROCS)
        .map(|p| spawn_worker(tier, port, &format!("{}..{}", p * per, (p + 1) * per), None))
        .collect();
    for (p, w) in workers.into_iter().enumerate() {
        await_exit(w, &format!("{tag}: worker process {p}"));
    }
    await_exit(serve, &format!("{tag}: serve"));
    let state = checkpoint::load(&sole_run_dir(&run_base).join("checkpoint.bin")).unwrap();
    let bytes = checkpoint::encode(&state);
    let _ = std::fs::remove_dir_all(&dir);
    bytes
}

/// All six algorithm variants, networked vs in-process, N = 64.
/// One test function: the variants share nothing but must each hold,
/// and running them sequentially keeps the process fan-out bounded.
#[test]
fn networked_run_matches_in_process_across_variants() {
    let tier = pin_tier();
    let variants: &[(&str, u64, f64)] = &[
        ("ggadmm", 11, 0.0),
        ("c-ggadmm", 12, 0.10),
        ("q-ggadmm", 13, 0.0),
        ("cq-ggadmm", 14, 0.15),
        ("c-admm", 15, 0.10),
        ("gadmm", 16, 0.0),
    ];
    for &(alg, seed, drop_prob) in variants {
        let m = manifest(alg, seed, 5, drop_prob);
        let net_bytes = networked_checkpoint(&m, tier, alg);
        let ref_bytes = in_process_checkpoint(&m);
        assert_eq!(
            net_bytes, ref_bytes,
            "{alg}: networked checkpoint diverges from the in-process run"
        );
    }
}

/// The multi-block MLP model and the QDGD baseline over TCP, N = 64.
/// TAG_BLOCKS wire frames, per-block quantizer forks and the per-block
/// bits ledger must survive the socket hop bit-for-bit — the server's
/// hat mirror is decoded from the same bytes the receiving workers
/// decode, so a single framing slip would show up as byte divergence.
#[test]
fn networked_mlp_and_qdgd_match_in_process() {
    let tier = pin_tier();
    let cases: &[(&str, Option<Vec<u32>>, u64, f64)] = &[
        // censored + per-layer split quantization + erasure
        ("cq-ggadmm", Some(vec![4, 2]), 17, 0.10),
        // the first-order Jacobian baseline, uniform width
        ("qdgd", None, 18, 0.0),
    ];
    for (alg, split, seed, drop_prob) in cases {
        let mut m = manifest(alg, *seed, 5, *drop_prob);
        m.experiment.model = Some(ModelSpec::Mlp { hidden: 4 });
        if let Some(split) = split {
            m.experiment.bits0 = split[0];
            m.experiment.bits_split = Some(split.clone());
        }
        m.validate().unwrap();
        let net_bytes = networked_checkpoint(&m, tier, &format!("mlp_{alg}"));
        let ref_bytes = in_process_checkpoint(&m);
        assert_eq!(
            net_bytes, ref_bytes,
            "{alg} (mlp): networked checkpoint diverges from the in-process run"
        );
    }
}

/// Iterations at which the server logged a membership event for one
/// worker.  Membership events serialize as
/// `{"event":"<ev>","iteration":<k>,"worker":<w>}` (schema v2).
fn membership_iters(events: &str, ev: &str, worker: usize) -> Vec<u64> {
    let ev_needle = format!("\"event\":\"{ev}\"");
    let worker_needle = format!("\"worker\":{worker}}}");
    let key = "\"iteration\":";
    events
        .lines()
        .filter(|l| l.contains(&ev_needle) && l.contains(&worker_needle))
        .map(|l| {
            let at = l.find(key).unwrap() + key.len();
            l[at..]
                .chars()
                .take_while(|c| c.is_ascii_digit())
                .collect::<String>()
                .parse()
                .unwrap()
        })
        .collect()
}

/// A worker process that exits mid-run and reconnects is
/// indistinguishable from a scheduled churn leave/join pair.
///
/// Worker 5 runs alone in its own process with `--exit-after-iter 4`;
/// once that process exits a fresh one re-registers the id.  The
/// *wall-clock* iteration the rejoin lands on is nondeterministic, so
/// the equivalent `ChurnSchedule` is reconstructed from the server's
/// own `worker_leave` / `worker_join` events and replayed in-process.
/// (Disconnect equivalence requires the default `record_every = 1` —
/// the record barrier is what pins the departure to a deterministic
/// boundary — and final-only checkpointing, both of which this test
/// uses.)
#[test]
fn worker_disconnect_reconnect_matches_scheduled_churn() {
    let tier = pin_tier();
    let m = manifest("cq-ggadmm", 21, 16, 0.10);
    let dir = scratch("churn");
    let manifest_path = dir.join("manifest.toml");
    std::fs::write(&manifest_path, m.to_toml()).unwrap();
    let run_base = dir.join("runs");
    let port_file = dir.join("server.port");
    let mut serve = spawn_serve(tier, &manifest_path, &run_base, &port_file);
    let port = await_port(&port_file, &mut serve);

    let fleet: Vec<Child> = ["0..5", "6..32", "32..64"]
        .iter()
        .map(|ids| spawn_worker(tier, port, ids, None))
        .collect();
    let transient = spawn_worker(tier, port, "5", Some(4));
    // the transient process lingers until the server has consumed its
    // goodbye, so once it exits the leave is committed server-side
    await_exit(transient, "transient worker 5");
    let rejoined = spawn_worker(tier, port, "5", None);

    for (p, w) in fleet.into_iter().enumerate() {
        await_exit(w, &format!("fleet process {p}"));
    }
    await_exit(rejoined, "rejoined worker 5");
    await_exit(serve, "serve");

    let run_dir = sole_run_dir(&run_base);
    let state = checkpoint::load(&run_dir.join("checkpoint.bin")).unwrap();
    let net_bytes = checkpoint::encode(&state);

    // reconstruct the schedule the run actually experienced
    let events = std::fs::read_to_string(run_dir.join("events.jsonl")).unwrap();
    let leaves = membership_iters(&events, "worker_leave", 5);
    let joins = membership_iters(&events, "worker_join", 5);
    assert_eq!(leaves, vec![4], "worker 5 must leave at the --exit-after-iter boundary");
    assert!(joins.len() <= 1, "worker 5 rejoined more than once: {joins:?}");
    let mut sched = format!("{}:leave:5", leaves[0]);
    for j in &joins {
        sched.push_str(&format!(" {j}:join:5"));
    }

    let (problem, topo, spec) = net::build_session(&m).unwrap();
    let churn = ChurnSchedule::parse(&sched).unwrap();
    let mut coord = Coordinator::spawn(
        problem,
        topo,
        spec,
        m.exec.clone().with_churn(Some(churn)),
    );
    for _ in 0..m.experiment.iters {
        coord.step();
    }
    let ref_bytes = checkpoint::encode(&coord.snapshot_state());
    assert_eq!(
        net_bytes, ref_bytes,
        "disconnect/reconnect (schedule '{sched}') diverges from scheduled churn"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
