//! Checkpoint / resume is **bit-identical**, not merely close.
//!
//! Every run's durable state (worker cores with quantizer RNGs and
//! censor history, link-model RNG, energy/bit accounting, trace
//! accumulator) round-trips through the on-disk checkpoint codec such
//! that a killed-and-resumed run reproduces the uninterrupted run's
//! trajectory exactly.  These tests lock that across the paper's six
//! `AlgSpec` variants, both engines (sequential simulator and sharded
//! coordinator), both tasks, and under broadcast erasure — plus the
//! cross-engine direction (a checkpoint written by one engine resumes
//! in the other) and the manifest front end (a manifest-driven run
//! reproduces the equivalent flag-driven run bit-for-bit).
//!
//! "Bit-identical" is asserted by comparing the serialized checkpoint
//! bytes of the final states: every f64 crosses `encode` via `to_bits`,
//! so byte equality *is* bit equality over the entire durable state
//! (models, duals, RNG positions, totals, and the full trace).
//!
//! Checkpoint bit-identity is a **per-kernel-tier** contract: the SIMD
//! and scalar linalg tiers legitimately differ by FMA reassociation, so
//! every test here pins the ambient tier first ([`pin_tier`]) and both
//! sides of each comparison run under it.  A checkpoint still *resumes
//! correctly* under a different tier (the format is plain f64 state with
//! no tier-dependent layout) — that handoff, and why it is not bit-
//! asserted, is covered by tests/simd_kernels.rs.

use cq_ggadmm::algs::{AlgSpec, Problem, Run};
use cq_ggadmm::comm::{LinkKind, LinkState};
use cq_ggadmm::config::{ExecutionConfig, ExperimentManifest, ModelSpec};
use cq_ggadmm::coordinator::Coordinator;
use cq_ggadmm::data::synthetic;
use cq_ggadmm::graph::{ChurnSchedule, Topology};
use cq_ggadmm::io::checkpoint::{self, RunState};
use cq_ggadmm::io::{run_with_persistence, JsonlSink, PersistableEngine, RunDir};
use std::path::PathBuf;

const N: usize = 12;
const K1: u64 = 9;
const K2: u64 = 14;

/// Pin the kernel tier for the whole test binary (see the module docs:
/// checkpoint bit-identity is per-tier).  The first call freezes the
/// ambient resolution — `CQ_KERNEL_TIER` override or runtime detection —
/// and nothing in this binary flips it afterwards.
fn pin_tier() {
    let t = cq_ggadmm::linalg::kernel_tier();
    cq_ggadmm::linalg::set_kernel_tier(t);
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cq_persist_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn problem(linear: bool, topo: &Topology, seed: u64) -> Problem {
    let n = topo.n();
    if linear {
        let ds = synthetic::linear_dataset(n * 8, 5, seed);
        Problem::new(&ds, topo, 5.0, 0.0, seed)
    } else {
        let ds = synthetic::logistic_dataset(n * 8, 5, seed);
        Problem::new(&ds, topo, 0.5, 0.05, seed)
    }
}

fn exec(seed: u64, drop_prob: f64) -> ExecutionConfig {
    ExecutionConfig::default()
        .with_seed(seed)
        .with_drop_prob(drop_prob)
}

fn assert_states_bit_identical(a: &RunState, b: &RunState, what: &str) {
    assert_eq!(a.iteration, b.iteration, "{what}: iteration");
    assert_eq!(
        checkpoint::encode(a),
        checkpoint::encode(b),
        "{what}: resumed state diverges from the uninterrupted run"
    );
}

/// Drive `full` for K1+K2 steps; drive `first` for K1, checkpoint it to
/// disk, drop it, load the checkpoint into `second` (simulating a fresh
/// process), drive K2 more — the final states must serialize to the
/// same bytes.
fn kill_and_resume<A, B, C>(mut full: A, mut first: B, mut second: C, what: &str)
where
    A: PersistableEngine,
    B: PersistableEngine,
    C: PersistableEngine,
{
    pin_tier();
    let dir = scratch(&what.replace([' ', '/'], "_"));
    let path = dir.join("state.ckpt");
    for _ in 0..(K1 + K2) {
        full.step();
    }
    for _ in 0..K1 {
        first.step();
    }
    checkpoint::save_atomic(&first.snapshot_state(), &path).unwrap();
    drop(first); // the "kill": nothing survives but the bytes on disk
    let state = checkpoint::load(&path).unwrap();
    second.restore_state(&state);
    assert_eq!(second.iteration(), K1, "{what}: resume point");
    for _ in 0..K2 {
        second.step();
    }
    assert_states_bit_identical(&full.snapshot_state(), &second.snapshot_state(), what);
    let _ = std::fs::remove_dir_all(&dir);
}

/// One (spec, task, link) cell of the matrix, for both engines — each
/// engine pair is built from ONE shared `ExecutionConfig`.
fn lock_resume(spec: AlgSpec, linear: bool, drop_prob: f64, seed: u64) {
    let topo = if spec.name == "GADMM" {
        Topology::chain(N)
    } else {
        Topology::random_bipartite(N, 0.3, seed)
    };
    let p = problem(linear, &topo, seed);
    let e = exec(seed, drop_prob);
    let what = format!(
        "{} {} drop={drop_prob}",
        spec.name,
        if linear { "linear" } else { "logistic" }
    );
    let run = |ex: &ExecutionConfig| Run::new(p.clone(), topo.clone(), spec.clone(), ex.clone());
    kill_and_resume(run(&e), run(&e), run(&e), &format!("run {what}"));
    let coord = |ex: &ExecutionConfig| {
        Coordinator::spawn(p.clone(), topo.clone(), spec.clone(), ex.clone().with_threads(3))
    };
    kill_and_resume(coord(&e), coord(&e), coord(&e), &format!("coord {what}"));
}

// ---- all six variants, in-process engine + coordinator --------------

#[test]
fn ggadmm_resumes_bit_identically() {
    lock_resume(AlgSpec::ggadmm(), true, 0.0, 51);
}

#[test]
fn c_ggadmm_resumes_bit_identically() {
    // the censor's last-transmitted slots and threshold decay cross the
    // checkpoint; a mismatch would flip a transmit decision immediately
    lock_resume(AlgSpec::c_ggadmm(0.2, 0.85), true, 0.0, 52);
}

#[test]
fn q_ggadmm_resumes_bit_identically() {
    // quantizer RNG positions cross the checkpoint: the first stochastic
    // rounding after resume must reuse the exact next draw
    lock_resume(AlgSpec::q_ggadmm(0.995, 2), true, 0.0, 53);
}

#[test]
fn cq_ggadmm_resumes_bit_identically() {
    lock_resume(AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2), true, 0.0, 54);
}

#[test]
fn c_admm_resumes_bit_identically() {
    lock_resume(AlgSpec::c_admm(0.1, 0.9), true, 0.0, 55);
}

#[test]
fn gadmm_chain_resumes_bit_identically() {
    lock_resume(AlgSpec::gadmm_chain(), true, 0.0, 56);
}

// ---- logistic task and erasure links --------------------------------

#[test]
fn logistic_variants_resume_bit_identically() {
    lock_resume(AlgSpec::ggadmm(), false, 0.0, 61);
    lock_resume(AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2), false, 0.0, 62);
}

#[test]
fn erasure_link_resumes_bit_identically() {
    // the link-model RNG position crosses the checkpoint: the drop
    // pattern after resume must continue the same Bernoulli stream
    lock_resume(AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2), true, 0.25, 63);
    lock_resume(AlgSpec::c_admm(0.1, 0.9), false, 0.2, 64);
}

// ---- the multi-block MLP model and the QDGD baseline -----------------

fn mlp_problem(topo: &Topology, seed: u64) -> Problem {
    let ds = synthetic::linear_dataset(topo.n() * 8, 5, seed);
    Problem::with_model(&ds, topo, 5.0, 0.0, seed, ModelSpec::Mlp { hidden: 3 })
        .expect("linear dataset supports the MLP model")
}

fn lock_resume_mlp(spec: AlgSpec, drop_prob: f64, seed: u64) {
    let topo = Topology::random_bipartite(N, 0.3, seed);
    let p = mlp_problem(&topo, seed);
    let e = exec(seed, drop_prob).with_staleness_bound(Some(3));
    let what = format!("{} mlp drop={drop_prob}", spec.name);
    let run = |ex: &ExecutionConfig| Run::new(p.clone(), topo.clone(), spec.clone(), ex.clone());
    kill_and_resume(run(&e), run(&e), run(&e), &format!("run {what}"));
    let coord = |ex: &ExecutionConfig| {
        Coordinator::spawn(p.clone(), topo.clone(), spec.clone(), ex.clone().with_threads(3))
    };
    kill_and_resume(coord(&e), coord(&e), coord(&e), &format!("coord {what}"));
}

#[test]
fn mlp_split_cq_resumes_bit_identically() {
    // the v3 checkpoint: per-block quantizer RNG positions, per-block
    // censor tx_once flags, block staleness ages and the per-block bits
    // ledger all cross the kill, in both engines
    lock_resume_mlp(
        AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 4).with_bits_split(Some(vec![4, 2])),
        0.15,
        57,
    );
}

#[test]
fn qdgd_mlp_resumes_bit_identically() {
    lock_resume_mlp(AlgSpec::qdgd(0.995, 8), 0.0, 58);
}

#[test]
fn mlp_checkpoint_uses_v3_and_flat_stays_v2() {
    pin_tier();
    // flat runs keep writing byte-stable version-2 checkpoints (the
    // back-compat contract); only live per-block state opts into v3
    let topo = Topology::random_bipartite(N, 0.3, 59);
    let spec2 = AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2);
    let mut flat = Run::new(problem(true, &topo, 59), topo.clone(), spec2, exec(59, 0.0));
    for _ in 0..K1 {
        flat.step();
    }
    let bytes = checkpoint::encode(&flat.snapshot_state());
    assert_eq!(bytes[8], 2, "flat checkpoint version");

    let p = mlp_problem(&topo, 59);
    let spec3 = AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 4).with_bits_split(Some(vec![4, 2]));
    let mut multi = Run::new(p, topo, spec3, exec(59, 0.0).with_staleness_bound(Some(3)));
    for _ in 0..K1 {
        multi.step();
    }
    let s = multi.snapshot_state();
    assert!(!s.block_bits.is_empty(), "multi-block run must ledger block bits");
    let bytes = checkpoint::encode(&s);
    assert_eq!(bytes[8], 3, "multi-block checkpoint version");
    let back = checkpoint::decode(&bytes).unwrap();
    assert_eq!(checkpoint::encode(&back), bytes, "v3 re-encode changed the bytes");
}

// ---- cross-engine resume --------------------------------------------

#[test]
fn checkpoint_resumes_across_engines() {
    // the checkpoint layout is engine-agnostic: a sharded-coordinator
    // checkpoint resumes in the sequential simulator and vice versa,
    // still matching the uninterrupted trajectory bit-for-bit
    let topo = Topology::random_bipartite(N, 0.3, 71);
    let p = problem(true, &topo, 71);
    let e = exec(71, 0.2);
    let spec = AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2);
    let run = || Run::new(p.clone(), topo.clone(), spec.clone(), e.clone());
    let coord = || {
        Coordinator::spawn(p.clone(), topo.clone(), spec.clone(), e.clone().with_threads(3))
    };
    kill_and_resume(run(), coord(), run(), "coord checkpoint -> run");
    kill_and_resume(run(), run(), coord(), "run checkpoint -> coord");
}

// ---- dynamic networks: churn, stragglers, staleness ------------------

/// The kill-and-resume fault schedule: workers 3 and 7 leave before the
/// checkpoint at K1 = 9 and rejoin after it, so the checkpoint captures
/// a *shrunk* graph and the resumed engine must replay the structural
/// transitions before importing values.
fn churned_exec(seed: u64, drop_prob: f64) -> ExecutionConfig {
    let churn = ChurnSchedule::parse("4:leave:3 14:join:3 6:leave:7 16:join:7").unwrap();
    exec(seed, drop_prob)
        .with_churn(Some(churn))
        .with_staleness_bound(Some(3))
}

#[test]
fn mid_churn_kill_and_resume_bit_identically() {
    // both engines, checkpointed while two workers are detached
    let topo = Topology::random_bipartite(N, 0.3, 72);
    let p = problem(true, &topo, 72);
    let e = churned_exec(72, 0.2);
    let spec = AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2);
    let run = || Run::new(p.clone(), topo.clone(), spec.clone(), e.clone());
    let coord = || {
        Coordinator::spawn(p.clone(), topo.clone(), spec.clone(), e.clone().with_threads(3))
    };
    kill_and_resume(run(), run(), run(), "run mid-churn");
    kill_and_resume(coord(), coord(), coord(), "coord mid-churn");
    // ... and across the engine boundary, over the same churn seam
    kill_and_resume(run(), coord(), run(), "coord mid-churn ckpt -> run");
    kill_and_resume(run(), run(), coord(), "run mid-churn ckpt -> coord");
}

#[test]
fn straggler_link_resumes_bit_identically() {
    // the straggler link holds durable RNG state (Pareto delay draws);
    // its position crosses the checkpoint like the erasure stream's
    let topo = Topology::random_bipartite(N, 0.3, 73);
    let p = problem(true, &topo, 73);
    let e = churned_exec(73, 0.0).with_link(Some(LinkKind::Straggler {
        frac: 0.25,
        rotate_every: 5,
        base_s: 8e-4,
        alpha: 1.3,
    }));
    let spec = AlgSpec::c_ggadmm(0.2, 0.85);
    let run = || Run::new(p.clone(), topo.clone(), spec.clone(), e.clone());
    kill_and_resume(run(), run(), run(), "run straggler");
}

#[test]
fn timevarying_link_resumes_bit_identically() {
    let topo = Topology::random_bipartite(N, 0.3, 74);
    let p = problem(true, &topo, 74);
    let e = churned_exec(74, 0.0).with_link(Some(LinkKind::TimeVarying {
        period_s: 0.02,
        bad_frac: 0.3,
        p_good: 0.05,
        p_bad: 0.6,
        bad_latency_s: 5e-4,
    }));
    let spec = AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2);
    let run = || Run::new(p.clone(), topo.clone(), spec.clone(), e.clone());
    kill_and_resume(run(), run(), run(), "run timevarying");
}

#[test]
fn checkpoint_bytes_round_trip_with_dynamic_link_states() {
    pin_tier();
    // encode ∘ decode is the identity on the bytes for every new link
    // model's durable state, mid-churn (shrunk graph, nonzero staleness)
    for (tag, link) in [
        ("straggler", LinkKind::Straggler { frac: 0.25, rotate_every: 5, base_s: 8e-4, alpha: 1.3 }),
        (
            "timevarying",
            LinkKind::TimeVarying {
                period_s: 0.02,
                bad_frac: 0.3,
                p_good: 0.05,
                p_bad: 0.6,
                bad_latency_s: 5e-4,
            },
        ),
    ] {
        let topo = Topology::random_bipartite(N, 0.3, 75);
        let p = problem(true, &topo, 75);
        let e = churned_exec(75, 0.0).with_link(Some(link));
        let mut run = Run::new(p, topo, AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2), e);
        for _ in 0..K1 {
            run.step();
        }
        let s = run.snapshot_state();
        assert!(
            matches!(s.medium.link, LinkState::Rng { .. }),
            "{tag}: link state must be durable RNG position"
        );
        assert!(!s.active.iter().all(|&a| a), "{tag}: checkpoint must capture absent workers");
        let bytes = checkpoint::encode(&s);
        let back = checkpoint::decode(&bytes).unwrap();
        assert_eq!(checkpoint::encode(&back), bytes, "{tag}: re-encode changed the bytes");
    }
}

// ---- the run-directory driver and the event stream ------------------

#[test]
fn run_dir_persistence_resumes_and_streams_events() {
    pin_tier();
    let base = scratch("rundir");
    let topo = Topology::random_bipartite(N, 0.3, 81);
    let p = problem(true, &topo, 81);
    let e = exec(81, 0.1);
    let spec = AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2);

    // uninterrupted reference
    let mut full = Run::new(p.clone(), topo.clone(), spec.clone(), e.clone());
    for _ in 0..(K1 + K2) {
        full.step();
    }

    // first life: stream events, checkpoint every 4 iterations, stop at K1
    let dir = RunDir::create(&base, "cq-test").unwrap();
    let mut first = Run::new(p.clone(), topo.clone(), spec.clone(), e.clone());
    first.start_event_log(Box::new(JsonlSink::create(&dir.events_path()).unwrap()));
    run_with_persistence(&mut first, K1, &dir, 4).unwrap();
    drop(first);

    // second life: reopen, restore, append to the same event stream
    let reopened = RunDir::open(dir.path()).unwrap();
    let state = checkpoint::load(&reopened.checkpoint_path()).unwrap();
    let mut second = Run::new(p.clone(), topo.clone(), spec.clone(), e.clone());
    second.restore_state(&state);
    second.resume_event_log(Box::new(JsonlSink::append(&reopened.events_path()).unwrap()));
    run_with_persistence(&mut second, K2, &reopened, 4).unwrap();

    assert_states_bit_identical(
        &full.snapshot_state(),
        &second.snapshot_state(),
        "run-dir driver",
    );

    // the event stream: exactly one run_start, a record per iteration,
    // checkpoint markers, and no rewound iterations at the resume seam
    let text = std::fs::read_to_string(reopened.events_path()).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines[0].contains("\"event\":\"run_start\""), "first line: {}", lines[0]);
    assert_eq!(
        lines.iter().filter(|l| l.contains("\"event\":\"run_start\"")).count(),
        1,
        "resume must append, not restart, the stream"
    );
    let records: Vec<&&str> = lines
        .iter()
        .filter(|l| l.contains("\"event\":\"record\""))
        .collect();
    assert_eq!(records.len() as u64, K1 + K2);
    let mut last_iter = 0u64;
    for r in &records {
        let iter: u64 = r
            .split("\"iteration\":")
            .nth(1)
            .and_then(|s| s.split([',', '}']).next())
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or_else(|| panic!("record without iteration: {r}"));
        assert!(iter > last_iter || last_iter == 0, "iteration rewound: {r}");
        last_iter = iter;
    }
    assert_eq!(last_iter, K1 + K2);
    assert!(
        lines.iter().any(|l| l.contains("\"event\":\"checkpoint\"")),
        "checkpoint events missing"
    );
    let _ = std::fs::remove_dir_all(&base);
}

// ---- the manifest front end -----------------------------------------

#[test]
fn manifest_driven_run_matches_flag_driven_run() {
    pin_tier();
    // the acceptance criterion of the manifest API: a run configured
    // through a TOML manifest is bit-for-bit the run configured through
    // direct (flag-style) construction of the same values
    let toml = r#"
[experiment]
dataset = "synth-linear"
alg = "cq-ggadmm"
workers = 12
connectivity = 0.3
rho = 5.0
iters = 20
seed = 91
tau0 = 0.2
xi = 0.85
omega = 0.995
bits0 = 2

[link]
drop_prob = 0.15
"#;
    let m = ExperimentManifest::from_toml(toml).unwrap();
    let e = &m.experiment;
    let ds = synthetic::linear_dataset(e.workers * 8, 5, e.seed);
    let topo = Topology::random_bipartite(e.workers, e.connectivity, e.seed);
    let p = Problem::new(&ds, &topo, e.rho, e.mu0, e.seed);

    let spec = AlgSpec::parse(&m.alg, e.tau0, e.xi, e.omega, e.bits0).unwrap();
    let mut via_manifest = Run::new(p.clone(), topo.clone(), spec, m.exec.clone());
    let tm = via_manifest.run(e.iters as u64);

    let flag_exec = ExecutionConfig::default().with_seed(91).with_drop_prob(0.15);
    let flag_spec = AlgSpec::cq_ggadmm(0.2, 0.85, 0.995, 2);
    let mut via_flags = Run::new(p, topo, flag_spec, flag_exec);
    let tf = via_flags.run(20);

    assert_eq!(tm.points.len(), tf.points.len());
    for (a, b) in tm.points.iter().zip(&tf.points) {
        assert_eq!(a.loss_gap.to_bits(), b.loss_gap.to_bits());
        assert_eq!(a.cum_bits, b.cum_bits);
        assert_eq!(a.cum_energy_j.to_bits(), b.cum_energy_j.to_bits());
    }

    // and the manifest itself round-trips through its serializer
    let reparsed = ExperimentManifest::from_toml(&m.to_toml()).unwrap();
    assert_eq!(reparsed, m);
}

// ---- the networked engine -------------------------------------------

/// The compiled CLI, for spawning `serve` / `worker` processes.
const BIN: &str = env!("CARGO_BIN_EXE_cq-ggadmm");
const NET_DEADLINE: std::time::Duration = std::time::Duration::from_secs(240);

/// Pin the ambient tier and return its name, so spawned processes can
/// inherit it through `CQ_KERNEL_TIER` (bit-identity is per-tier).
fn net_tier() -> &'static str {
    pin_tier();
    cq_ggadmm::linalg::kernel_tier().name()
}

fn spawn_net(args: &[&str], tier: &str) -> std::process::Child {
    std::process::Command::new(BIN)
        .args(args)
        .env("CQ_KERNEL_TIER", tier)
        .stdout(std::process::Stdio::null())
        .spawn()
        .expect("spawn subprocess")
}

fn await_port(port_file: &std::path::Path, serve: &mut std::process::Child) -> u16 {
    let deadline = std::time::Instant::now() + NET_DEADLINE;
    loop {
        if let Ok(text) = std::fs::read_to_string(port_file) {
            return text.trim().parse().expect("port file contents");
        }
        if let Some(status) = serve.try_wait().expect("poll serve") {
            panic!("serve exited before publishing its port: {status}");
        }
        assert!(
            std::time::Instant::now() < deadline,
            "timed out waiting for the port file"
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
}

fn await_exit(mut child: std::process::Child, what: &str) {
    let deadline = std::time::Instant::now() + NET_DEADLINE;
    loop {
        if let Some(status) = child.try_wait().expect("poll child") {
            assert!(status.success(), "{what} failed: {status}");
            return;
        }
        if std::time::Instant::now() >= deadline {
            let _ = child.kill();
            let _ = child.wait();
            panic!("{what} timed out");
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
}

/// Kill-and-resume for the networked engine.  A server runs to K1,
/// writes its final checkpoint, and shuts down; a *second* server
/// process resumes the run directory with a completely fresh worker
/// fleet (new processes, new sockets) and must land bit-identically
/// on the uninterrupted in-process run — the workers' durable state
/// lives in the checkpoint, not in the connections.
#[test]
fn networked_server_resume_is_bit_identical() {
    let tier = net_tier();
    let base = scratch("net_resume");
    let mut m = ExperimentManifest::default();
    m.alg = "cq-ggadmm".into();
    m.experiment.workers = N;
    m.experiment.iters = K1 as usize;
    m.experiment.seed = 77;
    m.exec.seed = 77;
    m.exec.drop_prob = 0.1;
    let manifest_path = base.join("manifest.toml").display().to_string();
    std::fs::write(&manifest_path, m.to_toml()).unwrap();
    let runs = base.join("runs");
    let runs_s = runs.display().to_string();

    // first life: run to K1, final-only checkpoint, clean shutdown
    let pf1 = base.join("first.port");
    let pf1_s = pf1.display().to_string();
    let mut serve = spawn_net(
        &[
            "serve", "--manifest", &manifest_path, "--run-dir", &runs_s,
            "--checkpoint-every", "0", "--port-file", &pf1_s,
        ],
        tier,
    );
    let port = await_port(&pf1, &mut serve);
    let addr = format!("127.0.0.1:{port}");
    let half = format!("{}..{}", 0, N / 2);
    let rest = format!("{}..{}", N / 2, N);
    let w0 = spawn_net(&["worker", "--connect", &addr, "--ids", &half], tier);
    let w1 = spawn_net(&["worker", "--connect", &addr, "--ids", &rest], tier);
    await_exit(w0, "first-life worker 0");
    await_exit(w1, "first-life worker 1");
    await_exit(serve, "first-life serve");
    let run_dir = {
        let mut dirs: Vec<PathBuf> = std::fs::read_dir(&runs)
            .unwrap()
            .map(|e| e.unwrap().path())
            .filter(|p| p.is_dir())
            .collect();
        assert_eq!(dirs.len(), 1, "expected one run dir");
        dirs.pop().unwrap()
    };

    // second life: resume to K1 + K2 with a fresh fleet
    let pf2 = base.join("second.port");
    let pf2_s = pf2.display().to_string();
    let run_dir_s = run_dir.display().to_string();
    let total = (K1 + K2).to_string();
    let mut serve = spawn_net(
        &["serve", "--resume", &run_dir_s, "--iters", &total, "--port-file", &pf2_s],
        tier,
    );
    let port = await_port(&pf2, &mut serve);
    let addr = format!("127.0.0.1:{port}");
    let w0 = spawn_net(&["worker", "--connect", &addr, "--ids", &half], tier);
    let w1 = spawn_net(&["worker", "--connect", &addr, "--ids", &rest], tier);
    await_exit(w0, "second-life worker 0");
    await_exit(w1, "second-life worker 1");
    await_exit(serve, "second-life serve");

    // uninterrupted in-process reference from the same manifest
    let mut full = m.clone();
    full.experiment.iters = (K1 + K2) as usize;
    let (problem, topo, spec) = cq_ggadmm::net::build_session(&full).unwrap();
    let mut coord = Coordinator::spawn(problem, topo, spec, full.exec.clone());
    for _ in 0..(K1 + K2) {
        coord.step();
    }

    let resumed = checkpoint::load(&run_dir.join("checkpoint.bin")).unwrap();
    assert_states_bit_identical(&coord.snapshot_state(), &resumed, "networked resume");

    // the event stream survived the handoff: one run_start, appended
    let text = std::fs::read_to_string(run_dir.join("events.jsonl")).unwrap();
    assert_eq!(
        text.lines().filter(|l| l.contains("\"event\":\"run_start\"")).count(),
        1,
        "resume must append to the event stream, not restart it"
    );
    let _ = std::fs::remove_dir_all(&base);
}
