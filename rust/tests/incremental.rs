//! Differential lock on the censoring-aware incremental engine.
//!
//! `RunOptions::incremental = true` (the default) skips the O(deg * d)
//! neighbor-sum / dual-increment rebuilds whenever no hat in a worker's
//! closed neighborhood committed; `incremental = false` rebuilds from
//! scratch every phase.  The design guarantees the two are **bit
//! identical** — a stale buffer is rebuilt by the exact from-scratch
//! loop, and a clean buffer's inputs are unchanged since its last
//! rebuild — so these tests compare *bits* (`f64::to_bits`), not
//! tolerances, across the whole algorithm family, both tasks, and under
//! broadcast-erasure failure injection.

use cq_ggadmm::algs::{AlgSpec, Problem, Run, RunOptions};
use cq_ggadmm::data::synthetic;
use cq_ggadmm::graph::Topology;
use cq_ggadmm::testing::prop::check;

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str, iter: u64, worker: usize) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (j, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "iter {iter}, worker {worker}, {what}[{j}]: {x:?} vs {y:?}"
        );
    }
}

/// Run the incremental and scratch engines in lockstep and compare every
/// piece of per-worker state bitwise at every iteration.
fn differential(spec: AlgSpec, linear: bool, drop_prob: f64, seed: u64, iters: u64) {
    let n = 10;
    let topo = Topology::random_bipartite(n, 0.5, seed);
    let ds = if linear {
        synthetic::linear_dataset(n * 12, 6, seed)
    } else {
        synthetic::logistic_dataset(n * 12, 6, seed)
    };
    let (rho, mu0) = if linear { (1.0, 0.0) } else { (0.5, 0.05) };
    let problem = Problem::new(&ds, &topo, rho, mu0, seed);
    let mk = |incremental: bool| {
        Run::new(
            problem.clone(),
            topo.clone(),
            spec.clone(),
            RunOptions { drop_prob, incremental, seed: 99, ..RunOptions::default() },
        )
    };
    let mut inc = mk(true);
    let mut scr = mk(false);
    for _ in 0..iters {
        inc.step();
        scr.step();
        let k = inc.iteration();
        for i in 0..n {
            let a = inc.snapshot(i);
            let b = scr.snapshot(i);
            assert_bits_eq(&a.theta, &b.theta, "theta", k, i);
            assert_bits_eq(&a.hat, &b.hat, "hat", k, i);
            assert_bits_eq(&a.alpha, &b.alpha, "alpha", k, i);
            assert_bits_eq(inc.neighbor_sum(i), scr.neighbor_sum(i), "nbr_sum", k, i);
            assert_bits_eq(inc.dual_delta(i), scr.dual_delta(i), "dual_delta", k, i);
        }
    }
    // identical trajectories must also spend identical communication
    assert_eq!(inc.comm().rounds(), scr.comm().rounds(), "round counts diverged");
    assert_eq!(inc.comm().total_bits, scr.comm().total_bits, "bit counts diverged");
}

#[test]
fn ggadmm_incremental_matches_scratch() {
    // no censoring: every round commits, so the caches are always stale —
    // the degenerate case where incremental == scratch by exhaustion
    differential(AlgSpec::ggadmm(), true, 0.0, 41, 30);
}

#[test]
fn c_ggadmm_incremental_matches_scratch() {
    differential(AlgSpec::c_ggadmm(0.3, 0.9), true, 0.0, 42, 40);
}

#[test]
fn cq_ggadmm_incremental_matches_scratch() {
    differential(AlgSpec::cq_ggadmm(0.3, 0.9, 0.995, 2), true, 0.0, 43, 40);
}

#[test]
fn c_admm_jacobian_incremental_matches_scratch() {
    // Jacobian schedule: the sums anchor on the worker's own hat too, so
    // the staleness tracking must cover self-commits
    differential(AlgSpec::c_admm(0.1, 0.9), true, 0.0, 44, 40);
}

#[test]
fn dropped_broadcasts_incremental_matches_scratch() {
    // erasures spend energy but roll back the hat commit: the incremental
    // engine must treat them exactly like censored rounds
    differential(AlgSpec::c_ggadmm(0.3, 0.9), true, 0.25, 45, 40);
    differential(AlgSpec::cq_ggadmm(0.3, 0.9, 0.995, 2), true, 0.25, 46, 40);
}

#[test]
fn logistic_task_incremental_matches_scratch() {
    // Newton-solver task: the solver consumes the cached sums bit-for-bit
    differential(AlgSpec::c_ggadmm(0.3, 0.9), false, 0.0, 47, 15);
}

#[test]
fn randomized_specs_incremental_matches_scratch() {
    // property sweep over the spec space (short horizons keep it cheap)
    check("incremental == scratch across random specs", 8, |g| {
        let spec = match g.usize_in(0, 3) {
            0 => AlgSpec::ggadmm(),
            1 => AlgSpec::c_ggadmm(g.f64_in(0.0, 1.0), g.f64_in(0.5, 0.99)),
            2 => AlgSpec::cq_ggadmm(g.f64_in(0.0, 1.0), g.f64_in(0.5, 0.99), 0.995, 2),
            _ => AlgSpec::c_admm(g.f64_in(0.0, 0.5), g.f64_in(0.5, 0.99)),
        };
        let drop_prob = if g.bool(0.5) { 0.2 } else { 0.0 };
        differential(spec, true, drop_prob, g.u64(), 12);
    });
}
