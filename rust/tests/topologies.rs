//! Property suite for the generalized topology layer (`graph::gen`).
//!
//! Every generator × parameter grid point must come out of the
//! bipartition pass as a **connected**, **bipartite-consistent**
//! head/tail instance with no isolated workers, deterministically per
//! seed, with the dropped-edge accounting consistent — at every worker
//! count, including the degenerate small ones.

use cq_ggadmm::config::TopologySpec;
use cq_ggadmm::graph::{gen, spectral, Group};
use cq_ggadmm::testing::prop::{check, Gen};

/// Draw one spec from the full generator × parameter grid.
fn arbitrary_spec(g: &mut Gen) -> TopologySpec {
    match g.usize_in(0, 7) {
        0 => TopologySpec::Chain,
        1 => TopologySpec::Ring,
        2 => TopologySpec::Star,
        3 => TopologySpec::Grid { torus: false },
        4 => TopologySpec::Grid { torus: true },
        5 => TopologySpec::ErdosRenyi { p: g.f64_in(0.0, 0.6) },
        6 => TopologySpec::SmallWorld { k: 2 * g.usize_in(1, 4), beta: g.f64_in(0.0, 1.0) },
        _ => TopologySpec::Geometric { radius_m: g.f64_in(30.0, 400.0) },
    }
}

#[test]
fn every_family_is_connected_and_bipartite_consistent() {
    check("generator zoo invariants", 150, |g| {
        let spec = arbitrary_spec(g);
        let n = g.usize_in(2, 48);
        let seed = g.u64();
        let b = gen::build(&spec, n, seed).unwrap_or_else(|e| panic!("{spec} n={n}: {e}"));
        let t = &b.topology;
        assert_eq!(t.n(), n, "{spec}");
        assert!(t.is_connected(), "{spec} n={n} seed={seed}: disconnected");
        assert!(t.is_bipartite_consistent(), "{spec} n={n} seed={seed}");
        // no isolated workers, every worker grouped
        let heads = t.heads().len();
        let tails = t.tails().len();
        assert_eq!(heads + tails, n);
        assert!(heads >= 1 && tails >= 1, "{spec} n={n}: empty group");
        for i in 0..n {
            assert!(t.degree(i) >= 1, "{spec} n={n}: worker {i} isolated");
            assert!(t.max_neighbor_distance(i).is_finite());
        }
        // every edge is head -> tail with real coordinates on both ends
        for &(h, tl) in t.edges() {
            assert_eq!(t.group(h), Group::Head);
            assert_eq!(t.group(tl), Group::Tail);
            let d = t.distance(h, tl);
            assert!(d.is_finite() && d >= 0.0);
        }
        // exact families keep everything; the max-cut path reports what
        // it dropped
        if b.exact {
            assert_eq!(b.dropped_edges, 0, "{spec}");
        }
    });
}

#[test]
fn builds_are_deterministic_per_seed() {
    check("same (spec, n, seed) => same topology", 60, |g| {
        let spec = arbitrary_spec(g);
        let n = g.usize_in(2, 32);
        let seed = g.u64();
        let a = gen::build(&spec, n, seed).unwrap();
        let b = gen::build(&spec, n, seed).unwrap();
        assert_eq!(a.topology.edges(), b.topology.edges(), "{spec}");
        assert_eq!(a.dropped_edges, b.dropped_edges, "{spec}");
        assert_eq!(a.exact, b.exact, "{spec}");
        for i in 0..n {
            assert_eq!(a.topology.group(i), b.topology.group(i), "{spec}");
            assert_eq!(a.topology.position(i), b.topology.position(i), "{spec}");
        }
    });
}

#[test]
fn exact_families_are_exact() {
    // families with a guaranteed 2-coloring must never drop an edge
    check("chain/star/grid/even-ring exact", 60, |g| {
        let specs = [
            (TopologySpec::Chain, g.usize_in(2, 40)),
            (TopologySpec::Star, g.usize_in(2, 40)),
            (TopologySpec::Grid { torus: false }, g.usize_in(2, 40)),
            (TopologySpec::Ring, 2 * g.usize_in(1, 20)),
        ];
        for (spec, n) in specs {
            let b = gen::build(&spec, n, g.u64()).unwrap();
            assert!(b.exact, "{spec} n={n}");
            assert_eq!(b.dropped_edges, 0, "{spec} n={n}");
        }
    });
}

#[test]
fn spectral_constants_finite_across_the_zoo() {
    // the Theorem-3 constants must be computable on every family (this
    // is where degenerate graphs used to surface NaN panics)
    check("spectral constants finite", 25, |g| {
        let spec = arbitrary_spec(g);
        let n = g.usize_in(4, 20);
        let b = gen::build(&spec, n, g.u64()).unwrap();
        let c = spectral::constants(&b.topology);
        assert!(c.sigma_max_c.is_finite() && c.sigma_max_c > 0.0, "{spec}");
        assert!(c.sigma_max_m_minus.is_finite() && c.sigma_max_m_minus > 0.0, "{spec}");
        assert!(c.sigma_min_nz_m_minus.is_finite() && c.sigma_min_nz_m_minus > 0.0, "{spec}");
    });
}

#[test]
fn energy_model_is_finite_on_generated_deployments() {
    // end-to-end: physical distances from every generator through the
    // (now saturating) Shannon energy model
    use cq_ggadmm::comm::{EnergyModel, EnergyParams};
    check("energy finite on zoo deployments", 40, |g| {
        let spec = arbitrary_spec(g);
        let n = g.usize_in(2, 32);
        let b = gen::build(&spec, n, g.u64()).unwrap();
        let m = EnergyModel::new(EnergyParams::default(), n, 0.5);
        let d_model = g.usize_in(1, 4096);
        for i in 0..n {
            let dist = b.topology.max_neighbor_distance(i);
            let e = m.energy_j(32 * d_model as u64, dist);
            assert!(e.is_finite() && e >= 0.0, "{spec} worker {i}: e={e}");
        }
    });
}
