//! Adaptive stochastic quantization (paper §5).
//!
//! Each worker quantizes the *difference* between its current model and
//! the reconstruction its neighbors already hold, with an unbiased
//! probabilistic rounding over `2^b - 1` levels spanning `[-R, R]`
//! (eqs. (14)-(17)), and reconstructs via eq. (20).  The bit width `b_n^k`
//! adapts per iteration under rule (18) so the step size shrinks
//! geometrically (`Delta^k <= omega * Delta^{k-1}`), which the convergence
//! proof requires.
//!
//! [`codec`] bit-packs the integer codes into the exact
//! `b*d + b_R + b_b`-bit wire payload the paper counts.

pub mod codec;

use crate::util::rng::Pcg64;

/// Static quantizer configuration.
#[derive(Clone, Copy, Debug)]
pub struct QuantConfig {
    /// Initial bit width `b^0` per coordinate.
    pub bits0: u32,
    /// Step-size decay `omega` in (0,1).
    pub omega: f64,
    /// Hard cap on per-coordinate bits (the paper assumes full precision
    /// is 32 bits).
    pub max_bits: u32,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig { bits0: 2, omega: 0.995, max_bits: 24 }
    }
}

impl QuantConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.bits0 < 1 || self.bits0 > self.max_bits {
            return Err(format!("bits0 {} out of range [1, {}]", self.bits0, self.max_bits));
        }
        if !(0.0 < self.omega && self.omega < 1.0) {
            return Err("omega must be in (0,1)".into());
        }
        if self.max_bits > 32 {
            // the wire codec packs u32 codes with a 1..=32-bit layout;
            // wider codes would silently truncate on the wire
            return Err(format!(
                "max_bits {} > 32: the codec packs 1..=32-bit codes",
                self.max_bits
            ));
        }
        Ok(())
    }
}

/// One quantized transmission: everything that goes over the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantMessage {
    /// Integer codes per coordinate, each in `[0, 2^bits - 1)`.
    pub codes: Vec<u32>,
    /// Quantization range `R^k`.
    pub radius: f64,
    /// Bits per coordinate `b^k`.
    pub bits: u32,
}

/// Wire payload size in bits of a quantized message: `b*d + b_R + b_b`
/// (paper §5 with `b_R = 32`, `b_b = 32`).  Single source of truth for
/// the size formula — [`QuantMessage::payload_bits`], the codec and the
/// run engine's communication accounting all go through here.
pub const fn payload_bits(d: usize, bits: u32) -> u64 {
    bits as u64 * d as u64 + 64
}

impl QuantMessage {
    /// Wire payload size in bits (see [`payload_bits`]).
    pub fn payload_bits(&self) -> u64 {
        payload_bits(self.codes.len(), self.bits)
    }

    /// Quantization step `Delta = 2R / (2^b - 1)` (paper §5: the range
    /// `2R` is divided into `2^b - 1` intervals; the `2^b` grid points are
    /// exactly the b-bit codes).
    pub fn step(&self) -> f64 {
        2.0 * self.radius / ((1u64 << self.bits) - 1) as f64
    }

    /// Reconstruct `\hat Q` from the message and the shared reference
    /// vector (eq. (20)).  Receiver-side decode.
    pub fn reconstruct(&self, reference: &[f64]) -> Vec<f64> {
        assert_eq!(reference.len(), self.codes.len());
        let delta = self.step();
        self.codes
            .iter()
            .zip(reference)
            .map(|(&q, &r)| r + delta * q as f64 - self.radius)
            .collect()
    }

    /// In-place receiver-side decode: `inout` holds the shared reference
    /// (the last value the receiver stores for the sender) and is
    /// overwritten with the reconstruction.  Bit-identical arithmetic to
    /// [`QuantMessage::reconstruct`] — the run engine's receive path uses
    /// this so quantized rounds stop allocating a vector per committed
    /// link.
    pub fn reconstruct_into(&self, inout: &mut [f64]) {
        assert_eq!(inout.len(), self.codes.len());
        let delta = self.step();
        for (r, &q) in inout.iter_mut().zip(&self.codes) {
            *r = *r + delta * q as f64 - self.radius;
        }
    }
}

/// Durable quantizer state for checkpointing: the adaptive-range history
/// and the exact position of the stochastic-rounding RNG stream.  The
/// static [`QuantConfig`] is *not* part of the state — it is rebuilt from
/// the `AlgSpec` on resume.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizerState {
    pub prev_radius: Option<f64>,
    pub prev_bits: u32,
    pub rng_state: u128,
    pub rng_inc: u128,
}

/// Per-worker quantizer state (the sender side).
#[derive(Clone, Debug)]
pub struct Quantizer {
    cfg: QuantConfig,
    /// Previous radius `R^{k-1}` (None before first transmission).
    prev_radius: Option<f64>,
    /// Previous bit width `b^{k-1}`.
    prev_bits: u32,
    rng: Pcg64,
}

impl Quantizer {
    pub fn new(cfg: QuantConfig, rng: Pcg64) -> Quantizer {
        cfg.validate().expect("invalid quant config");
        Quantizer { cfg, prev_radius: None, prev_bits: cfg.bits0, rng }
    }

    /// Export the durable state (see [`QuantizerState`]).
    pub fn state(&self) -> QuantizerState {
        let (rng_state, rng_inc) = self.rng.to_raw();
        QuantizerState {
            prev_radius: self.prev_radius,
            prev_bits: self.prev_bits,
            rng_state,
            rng_inc,
        }
    }

    /// Overwrite the durable state from a checkpoint.  The config stays
    /// as constructed; only the adaptive history and RNG position move.
    pub fn restore(&mut self, s: &QuantizerState) {
        self.prev_radius = s.prev_radius;
        self.prev_bits = s.prev_bits;
        self.rng = Pcg64::from_raw(s.rng_state, s.rng_inc);
    }

    /// Current bit width (next transmission will use at least this many).
    pub fn bits(&self) -> u32 {
        self.prev_bits
    }

    /// Bit-growth rule of eq. (18): the smallest `b^k` such that
    /// `Delta^k = 2 R^k / (2^{b^k} - 1) <= omega * Delta^{k-1}`.
    fn next_bits(&self, radius: f64) -> u32 {
        match self.prev_radius {
            None => self.cfg.bits0,
            Some(r_prev) => {
                let prev_levels = ((1u64 << self.prev_bits) - 1) as f64;
                let needed =
                    (1.0 + prev_levels * radius / (self.cfg.omega * r_prev)).log2().ceil();
                let b = needed.max(1.0) as u32;
                b.clamp(1, self.cfg.max_bits)
            }
        }
    }

    /// Shared quantization core: draws one stochastic-rounding uniform per
    /// coordinate, writes the reconstruction into `recon`, optionally
    /// collects the integer codes, and advances the (R, b) state.
    fn quantize_core(
        &mut self,
        value: &[f64],
        reference: &[f64],
        recon: &mut [f64],
        mut codes: Option<&mut Vec<u32>>,
    ) -> (f64, u32) {
        assert_eq!(value.len(), reference.len());
        assert_eq!(recon.len(), reference.len());
        let d = value.len();
        // radius covers the current difference (never zero)
        let mut radius = 0.0f64;
        for i in 0..d {
            radius = radius.max((value[i] - reference[i]).abs());
        }
        radius = radius.max(1e-12);
        // the wire carries R as f32 (b_R = 32); use the rounded value on
        // the sender side too so sender and receiver reconstructions are
        // bit-identical
        radius = radius as f32 as f64;
        let bits = self.next_bits(radius);
        // 2R split into (2^b - 1) intervals => 2^b grid points (the b-bit
        // codes); max code = 2^b - 1
        let max_code = ((1u64 << bits) - 1) as f64;
        let delta = 2.0 * radius / max_code;

        for i in 0..d {
            // eq. (14): center the difference at +R, measure in steps
            let c = (value[i] - reference[i] + radius) / delta;
            let low = c.floor();
            let frac = c - low;
            // eq. (15)/(17): round up with probability frac
            let q = if self.rng.uniform() < frac { low + 1.0 } else { low };
            let q = q.clamp(0.0, max_code);
            // eq. (20), identical arithmetic to `QuantMessage::reconstruct`
            recon[i] = reference[i] + delta * q - radius;
            if let Some(out) = codes.as_mut() {
                out.push(q as u32);
            }
        }
        self.prev_radius = Some(radius);
        self.prev_bits = bits;
        (radius, bits)
    }

    /// Quantize `value` against the shared `reference` (the reconstruction
    /// both sides hold).  Returns the wire message and the sender's own
    /// reconstruction (which equals the receiver's decode exactly).
    pub fn quantize(&mut self, value: &[f64], reference: &[f64]) -> (QuantMessage, Vec<f64>) {
        let d = value.len();
        let mut recon = vec![0.0; d];
        let mut codes = Vec::with_capacity(d);
        let (radius, bits) = self.quantize_core(value, reference, &mut recon, Some(&mut codes));
        (QuantMessage { codes, radius, bits }, recon)
    }

    /// Allocation-free variant for the simulator hot path: same RNG draws
    /// and reconstruction arithmetic as [`Quantizer::quantize`], but the
    /// reconstruction lands in a caller-provided buffer and no code vector
    /// is materialized (the run engine only needs the payload size,
    /// `bits * d + 64`).  Returns `(radius, bits)`.
    pub fn quantize_into(
        &mut self,
        value: &[f64],
        reference: &[f64],
        recon: &mut [f64],
    ) -> (f64, u32) {
        self.quantize_core(value, reference, recon, None)
    }

    /// Allocation-free variant that also collects the integer codes into a
    /// caller-provided scratch vector (cleared here, capacity retained) —
    /// the protocol core uses this so the wire encoder can serialize the
    /// candidate without materializing a [`QuantMessage`].  Bit-identical
    /// RNG draws, reconstruction and `(R, b)` state evolution to
    /// [`Quantizer::quantize`].  Returns `(radius, bits)`.
    pub fn quantize_with_codes(
        &mut self,
        value: &[f64],
        reference: &[f64],
        recon: &mut [f64],
        codes: &mut Vec<u32>,
    ) -> (f64, u32) {
        codes.clear();
        self.quantize_core(value, reference, recon, Some(codes))
    }

    /// Step size `Delta^k` that a transmission with this radius would use.
    pub fn step_size(&self, radius: f64, bits: u32) -> f64 {
        2.0 * radius / ((1u64 << bits) - 1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;
    use crate::util::norm2;

    fn mk(bits0: u32, omega: f64, seed: u64) -> Quantizer {
        Quantizer::new(
            QuantConfig { bits0, omega, max_bits: 24 },
            Pcg64::new(seed),
        )
    }

    #[test]
    fn reconstruction_error_bounded_by_step() {
        check("per-coordinate error <= Delta", 100, |g| {
            let d = g.usize_in(1, 100);
            let mut q = mk(g.usize_in(2, 8) as u32, g.f64_in(0.5, 0.99), g.u64());
            let v = g.normal_vec(d);
            let reference = g.normal_vec(d);
            let (msg, recon) = q.quantize(&v, &reference);
            let delta = q.step_size(msg.radius, msg.bits);
            for i in 0..d {
                assert!(
                    (recon[i] - v[i]).abs() <= delta * (1.0 + 1e-9),
                    "coord {i}: |{} - {}| > {delta}",
                    recon[i],
                    v[i]
                );
            }
        });
    }

    #[test]
    fn decode_matches_sender_recon() {
        check("sender/receiver reconstructions identical", 60, |g| {
            let d = g.usize_in(1, 64);
            let mut q = mk(3, 0.9, g.u64());
            let reference = g.normal_vec(d);
            let v = g.normal_vec(d);
            let (msg, recon) = q.quantize(&v, &reference);
            let decoded = msg.reconstruct(&reference);
            assert_eq!(recon, decoded);
        });
    }

    #[test]
    fn step_size_decays_geometrically() {
        // rule (18): Delta^k <= omega * Delta^{k-1} for every transmission,
        // as long as the bit cap (the paper's 32-bit full precision) is
        // not hit — once b^k saturates, the guarantee is vacuous.
        check("Delta monotone under bit rule", 40, |g| {
            let omega = g.f64_in(0.6, 0.99);
            let mut q = mk(2, omega, g.u64());
            let d = 16;
            let mut reference = vec![0.0; d];
            let mut prev_delta: Option<f64> = None;
            // shrinking differences, as in a converging run
            for k in 0..12 {
                let scale = 0.7f64.powi(k);
                let v: Vec<f64> =
                    reference.iter().map(|r| r + scale * g.normal()).collect();
                let (msg, recon) = q.quantize(&v, &reference);
                let delta = q.step_size(msg.radius, msg.bits);
                if msg.bits >= q.cfg.max_bits {
                    break; // cap reached: rule (18) no longer binds
                }
                if let Some(pd) = prev_delta {
                    assert!(
                        delta <= omega * pd * (1.0 + 1e-9),
                        "k={k}: {delta} > {omega} * {pd}"
                    );
                }
                prev_delta = Some(delta);
                reference = recon;
            }
        });
    }

    #[test]
    fn unbiasedness_monte_carlo() {
        let d = 8;
        let v: Vec<f64> = (0..d).map(|i| (i as f64 * 0.77).sin()).collect();
        let reference = vec![0.0; d];
        let trials = 4000;
        let mut acc = vec![0.0; d];
        for t in 0..trials {
            let mut q = mk(3, 0.9, t as u64);
            let (_, recon) = q.quantize(&v, &reference);
            for i in 0..d {
                acc[i] += recon[i];
            }
        }
        for i in 0..d {
            let mean = acc[i] / trials as f64;
            assert!(
                (mean - v[i]).abs() < 0.02,
                "coord {i}: mean {mean} vs {}",
                v[i]
            );
        }
    }

    #[test]
    fn payload_bits_formula() {
        let mut q = mk(4, 0.9, 1);
        let v = vec![1.0; 50];
        let reference = vec![0.0; 50];
        let (msg, _) = q.quantize(&v, &reference);
        assert_eq!(msg.payload_bits(), msg.bits as u64 * 50 + 64);
        assert!(msg.payload_bits() < 32 * 50); // beats full precision
    }

    #[test]
    fn error_norm_bounded_sqrt_d_delta() {
        // aggregate bound E||e||^2 <= d Delta^2 (we check the a.s. bound)
        check("||recon - v|| <= sqrt(d) Delta", 50, |g| {
            let d = g.usize_in(1, 80);
            let mut q = mk(3, 0.9, g.u64());
            let v = g.normal_vec(d);
            let reference = vec![0.0; d];
            let (msg, recon) = q.quantize(&v, &reference);
            let delta = q.step_size(msg.radius, msg.bits);
            let err: Vec<f64> = recon.iter().zip(&v).map(|(a, b)| a - b).collect();
            assert!(norm2(&err) <= (d as f64).sqrt() * delta * (1.0 + 1e-9));
        });
    }

    #[test]
    fn quantize_into_matches_quantize_bit_exactly() {
        // same seed => same RNG draws => identical reconstructions and
        // identical (R, b) state evolution; the run engine relies on this
        check("quantize_into == quantize", 60, |g| {
            let d = g.usize_in(1, 64);
            let seed = g.u64();
            let mut qa = mk(3, 0.9, seed);
            let mut qb = mk(3, 0.9, seed);
            let mut reference = g.normal_vec(d);
            let mut recon_b = vec![0.0; d];
            for _ in 0..4 {
                let v = g.normal_vec(d);
                let (msg, recon_a) = qa.quantize(&v, &reference);
                let (radius, bits) = qb.quantize_into(&v, &reference, &mut recon_b);
                assert_eq!(radius.to_bits(), msg.radius.to_bits());
                assert_eq!(bits, msg.bits);
                for (a, b) in recon_a.iter().zip(&recon_b) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                assert_eq!(msg.payload_bits(), bits as u64 * d as u64 + 64);
                reference = recon_a;
            }
        });
    }

    #[test]
    fn quantize_with_codes_matches_quantize_bit_exactly() {
        // the protocol core's wire-capable variant: same draws, same
        // reconstruction, same state, and exactly the codes the message
        // would carry — across multiple rounds on one reused scratch
        check("quantize_with_codes == quantize", 60, |g| {
            let d = g.usize_in(1, 64);
            let seed = g.u64();
            let mut qa = mk(3, 0.9, seed);
            let mut qb = mk(3, 0.9, seed);
            let mut reference = g.normal_vec(d);
            let mut recon_b = vec![0.0; d];
            let mut codes_b: Vec<u32> = Vec::new();
            for _ in 0..4 {
                let v = g.normal_vec(d);
                let (msg, recon_a) = qa.quantize(&v, &reference);
                let (radius, bits) =
                    qb.quantize_with_codes(&v, &reference, &mut recon_b, &mut codes_b);
                assert_eq!(radius.to_bits(), msg.radius.to_bits());
                assert_eq!(bits, msg.bits);
                assert_eq!(codes_b, msg.codes);
                for (a, b) in recon_a.iter().zip(&recon_b) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                reference = recon_a;
            }
        });
    }

    #[test]
    fn reconstruct_into_bit_identical_to_reconstruct() {
        check("reconstruct_into == reconstruct", 60, |g| {
            let d = g.usize_in(1, 64);
            let mut q = mk(3, 0.9, g.u64());
            let reference = g.normal_vec(d);
            let v = g.normal_vec(d);
            let (msg, _) = q.quantize(&v, &reference);
            let alloc = msg.reconstruct(&reference);
            let mut inplace = reference.clone();
            msg.reconstruct_into(&mut inplace);
            for (a, b) in alloc.iter().zip(&inplace) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
    }

    #[test]
    fn validate_locks_the_codec_bit_range() {
        // full precision (32) is exactly what the codec can pack ...
        let full = QuantConfig { bits0: 32, omega: 0.9, max_bits: 32 };
        full.validate().unwrap();
        // ... and one bit more must be rejected, not silently truncated
        let wide = QuantConfig { bits0: 2, omega: 0.9, max_bits: 33 };
        let err = wide.validate().unwrap_err();
        assert!(err.contains("32"), "{err}");
        // bits0 above the cap is rejected too
        let inverted = QuantConfig { bits0: 12, omega: 0.9, max_bits: 8 };
        let err = inverted.validate().unwrap_err();
        assert!(err.contains("out of range"), "{err}");
        assert!(QuantConfig { bits0: 0, omega: 0.9, max_bits: 8 }.validate().is_err());
        assert!(QuantConfig { bits0: 2, omega: 1.0, max_bits: 8 }.validate().is_err());
    }

    #[test]
    fn full_precision_32_bit_quantizer_roundtrips() {
        // level arithmetic at the 32-bit cap: max_code = 2^32 - 1 must
        // fit the u32 code type exactly, end to end through the decode
        let mut q = Quantizer::new(
            QuantConfig { bits0: 32, omega: 0.9, max_bits: 32 },
            Pcg64::new(11),
        );
        let v: Vec<f64> = (0..16).map(|i| (i as f64 * 0.37).sin() * 3.0).collect();
        let reference = vec![0.0; 16];
        let (msg, recon) = q.quantize(&v, &reference);
        assert_eq!(msg.bits, 32);
        assert!(msg.step() > 0.0 && msg.step().is_finite());
        let decoded = msg.reconstruct(&reference);
        assert_eq!(recon, decoded);
        // 32-bit steps over a few-unit radius are ~1e-9: reconstruction
        // is essentially exact
        for (r, t) in recon.iter().zip(&v) {
            assert!((r - t).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_difference_stays_stable() {
        let mut q = mk(2, 0.9, 7);
        let v = vec![1.0, -2.0, 3.0];
        let (_, recon1) = q.quantize(&v, &v.clone());
        // difference is zero: reconstruction must stay within the tiny
        // minimum radius of the true value
        for (r, t) in recon1.iter().zip(&v) {
            assert!((r - t).abs() < 1e-9);
        }
    }

    #[test]
    fn bits_capped_at_max() {
        let mut q = Quantizer::new(
            QuantConfig { bits0: 2, omega: 0.05, max_bits: 10 },
            Pcg64::new(3),
        );
        let mut reference = vec![0.0; 4];
        for _ in 0..20 {
            let v: Vec<f64> = reference.iter().map(|r| r + 1.0).collect();
            let (msg, recon) = q.quantize(&v, &reference);
            assert!(msg.bits <= 10);
            reference = recon;
        }
    }
}
