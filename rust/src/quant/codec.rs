//! Wire codec: bit-pack quantized messages into the exact payload the
//! paper counts (`b*d + b_R + b_b` bits), plus header encode/decode.
//!
//! Layout (little-endian bitstream):
//!   [ radius: f32 (32 bits) ][ bits: u32 (32 bits) ][ d codes of `bits` ]
//!
//! Perf: packing is word-level — codes accumulate in a `u64` and flush as
//! whole little-endian 32-bit words, so a d-coordinate message costs
//! O(d) shifts/ors instead of the O(d * b) per-bit loop of the original
//! implementation (see `bench_hotpath`'s codec shootout). The bit-level
//! layout is unchanged (golden test below).

use super::QuantMessage;

/// Word-level little-endian bit accumulator over a caller-provided
/// buffer (so hot paths can reuse one allocation across messages).
///
/// Invariant: fewer than 32 pending bits after every `push`, so a push of
/// up to 32 bits never overflows the 64-bit accumulator.
struct BitWriter<'a> {
    buf: &'a mut Vec<u8>,
    acc: u64,
    pending: u32,
}

impl<'a> BitWriter<'a> {
    fn over(buf: &'a mut Vec<u8>, reserve_bytes: usize) -> BitWriter<'a> {
        buf.reserve(reserve_bytes);
        BitWriter { buf, acc: 0, pending: 0 }
    }

    /// Append the `width` low bits of `value` (width in 1..=32).
    #[inline]
    fn push(&mut self, value: u64, width: u32) {
        debug_assert!((1..=32).contains(&width));
        debug_assert!(self.pending < 32);
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        self.acc |= (value & mask) << self.pending;
        self.pending += width;
        if self.pending >= 32 {
            self.buf.extend_from_slice(&(self.acc as u32).to_le_bytes());
            self.acc >>= 32;
            self.pending -= 32;
        }
    }

    /// Flush the trailing partial word; total bytes = ceil(bits / 8).
    fn finish(mut self) {
        while self.pending > 0 {
            self.buf.push(self.acc as u8);
            self.acc >>= 8;
            self.pending = self.pending.saturating_sub(8);
        }
    }
}

/// Word-level little-endian bit reader over a byte slice.
struct BitReader<'a> {
    buf: &'a [u8],
    /// Next unread byte.
    byte: usize,
    acc: u64,
    avail: u32,
}

impl<'a> BitReader<'a> {
    fn new(buf: &'a [u8]) -> BitReader<'a> {
        BitReader { buf, byte: 0, acc: 0, avail: 0 }
    }

    /// Read `width` bits (width in 1..=32); `None` when the stream is
    /// exhausted before `width` bits are available.
    #[inline]
    fn read(&mut self, width: u32) -> Option<u64> {
        debug_assert!((1..=32).contains(&width));
        if self.avail < width {
            // refill a whole 32-bit word when possible (avail < 32 here,
            // so the shifted word always fits the 64-bit accumulator)
            if self.byte + 4 <= self.buf.len() {
                let w = u32::from_le_bytes([
                    self.buf[self.byte],
                    self.buf[self.byte + 1],
                    self.buf[self.byte + 2],
                    self.buf[self.byte + 3],
                ]);
                self.acc |= (w as u64) << self.avail;
                self.byte += 4;
                self.avail += 32;
            } else {
                while self.avail < width {
                    if self.byte >= self.buf.len() {
                        return None;
                    }
                    self.acc |= (self.buf[self.byte] as u64) << self.avail;
                    self.byte += 1;
                    self.avail += 8;
                }
            }
        }
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        let out = self.acc & mask;
        self.acc >>= width;
        self.avail -= width;
        Some(out)
    }
}

/// Encode a message from its parts, **appending** to `out` (the
/// coordinator's alloc-free wire path: one persistent buffer per worker,
/// cleared by the caller, capacity retained across rounds).  Byte-for-byte
/// identical to [`encode`].
pub fn encode_parts_into(radius: f64, bits: u32, codes: &[u32], out: &mut Vec<u8>) {
    let payload = super::payload_bits(codes.len(), bits);
    let mut w = BitWriter::over(out, (payload as usize).div_ceil(8));
    w.push((radius as f32).to_bits() as u64, 32);
    w.push(bits as u64, 32);
    for &c in codes {
        debug_assert!(
            bits >= 32 || (c as u64) < (1u64 << bits),
            "code overflows bit width"
        );
        w.push(c as u64, bits);
    }
    w.finish();
}

/// Encode a message into its wire bytes. The *bit* length is exactly
/// `msg.payload_bits()`; the byte vector rounds up to whole bytes.
pub fn encode(msg: &QuantMessage) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_parts_into(msg.radius, msg.bits, &msg.codes, &mut buf);
    debug_assert_eq!(buf.len(), (msg.payload_bits() as usize).div_ceil(8));
    buf
}

/// Decode wire bytes back into a message; `d` is the (known) model
/// dimension.  Returns `None` on truncated/garbled input.
pub fn decode(buf: &[u8], d: usize) -> Option<QuantMessage> {
    let mut r = BitReader::new(buf);
    let radius = f32::from_bits(r.read(32)? as u32) as f64;
    let bits = r.read(32)? as u32;
    if bits == 0 || bits > 32 || !(radius.is_finite()) || radius < 0.0 {
        return None;
    }
    let mut codes = Vec::with_capacity(d);
    for _ in 0..d {
        codes.push(r.read(bits)? as u32);
    }
    Some(QuantMessage { codes, radius, bits })
}

/// Streaming decode + eq. (20) reconstruction in one pass: `stored` holds
/// the shared reference (the last value the receiver keeps for the
/// sender) and is overwritten coordinate-by-coordinate with the
/// reconstruction, without materializing a code vector.  Bit-identical to
/// [`decode`] followed by [`QuantMessage::reconstruct_into`] (property
/// test below) — the coordinator's receive path is allocation-free
/// through here.  Returns `(radius, bits)`.
///
/// On `None` (truncated/garbled input) a prefix of `stored` may already
/// be overwritten; callers on trusted in-process bytes treat `None` as
/// fatal.
pub fn decode_reconstruct_into(buf: &[u8], stored: &mut [f64]) -> Option<(f64, u32)> {
    let mut r = BitReader::new(buf);
    let radius = f32::from_bits(r.read(32)? as u32) as f64;
    let bits = r.read(32)? as u32;
    if bits == 0 || bits > 32 || !(radius.is_finite()) || radius < 0.0 {
        return None;
    }
    // same expression as `QuantMessage::step` so the arithmetic is
    // bit-identical to the two-step decode
    let delta = 2.0 * radius / ((1u64 << bits) - 1) as f64;
    for slot in stored.iter_mut() {
        let q = r.read(bits)? as u32;
        *slot = *slot + delta * q as f64 - radius;
    }
    Some((radius, bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    #[test]
    fn roundtrip_random_messages() {
        check("codec encode/decode identity", 150, |g| {
            let d = g.usize_in(0, 128);
            let bits = g.usize_in(2, 24) as u32;
            let n_codes = 1u64 << bits;
            let codes: Vec<u32> = (0..d)
                .map(|_| (g.u64() % n_codes) as u32)
                .collect();
            let radius = (g.f64_in(1e-9, 1e3) as f32) as f64; // f32-representable
            let msg = QuantMessage { codes, radius, bits };
            let bytes = encode(&msg);
            assert_eq!(bytes.len(), (msg.payload_bits() as usize).div_ceil(8));
            let back = decode(&bytes, d).expect("decode failed");
            assert_eq!(back, msg);
        });
    }

    #[test]
    fn roundtrip_all_widths_1_to_32() {
        // the full width range the wire format admits, including the
        // 32-bit edge case the word-level accumulator must not overflow on
        check("codec identity for bits in 1..=32", 200, |g| {
            let bits = g.usize_in(1, 32) as u32;
            let d = g.usize_in(0, 96);
            let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let codes: Vec<u32> = (0..d).map(|_| g.u64() as u32 & mask).collect();
            let radius = (g.f64_in(0.0, 1e6) as f32) as f64;
            let msg = QuantMessage { codes, radius, bits };
            let bytes = encode(&msg);
            assert_eq!(bytes.len(), (msg.payload_bits() as usize).div_ceil(8));
            assert_eq!(decode(&bytes, d).expect("decode"), msg);
        });
    }

    #[test]
    fn encode_parts_into_matches_encode_and_reuses_capacity() {
        check("encode_parts_into == encode", 80, |g| {
            let bits = g.usize_in(1, 32) as u32;
            let d = g.usize_in(0, 96);
            let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let codes: Vec<u32> = (0..d).map(|_| g.u64() as u32 & mask).collect();
            let radius = (g.f64_in(0.0, 1e4) as f32) as f64;
            let msg = QuantMessage { codes, radius, bits };
            let mut buf = Vec::new();
            buf.clear();
            encode_parts_into(msg.radius, msg.bits, &msg.codes, &mut buf);
            assert_eq!(buf, encode(&msg));
            // second round over the same buffer: clear + append again
            buf.clear();
            let cap = buf.capacity();
            encode_parts_into(msg.radius, msg.bits, &msg.codes, &mut buf);
            assert_eq!(buf, encode(&msg));
            assert!(buf.capacity() >= cap, "capacity must be retained");
        });
    }

    #[test]
    fn decode_reconstruct_into_matches_two_step_decode() {
        check("decode_reconstruct_into == decode + reconstruct_into", 100, |g| {
            let bits = g.usize_in(1, 24) as u32;
            let d = g.usize_in(1, 96);
            let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let codes: Vec<u32> = (0..d).map(|_| g.u64() as u32 & mask).collect();
            let radius = (g.f64_in(1e-9, 1e3) as f32) as f64;
            let msg = QuantMessage { codes, radius, bits };
            let bytes = encode(&msg);
            let reference = g.normal_vec(d);

            let mut two_step = reference.clone();
            decode(&bytes, d).expect("decode").reconstruct_into(&mut two_step);

            let mut fused = reference.clone();
            let (r, b) = decode_reconstruct_into(&bytes, &mut fused).expect("fused decode");
            assert_eq!(r.to_bits(), msg.radius.to_bits());
            assert_eq!(b, msg.bits);
            for (a, z) in two_step.iter().zip(&fused) {
                assert_eq!(a.to_bits(), z.to_bits());
            }
        });
    }

    #[test]
    fn decode_reconstruct_into_rejects_truncation() {
        let msg = QuantMessage { codes: vec![1, 2, 3, 4], radius: 0.5, bits: 5 };
        let bytes = encode(&msg);
        let mut stored = vec![0.0; 4];
        assert!(decode_reconstruct_into(&bytes[..bytes.len() - 1], &mut stored).is_none());
        assert!(decode_reconstruct_into(&[], &mut stored).is_none());
    }

    #[test]
    fn truncated_input_rejected() {
        let msg = QuantMessage { codes: vec![1, 2, 3], radius: 0.5, bits: 4 };
        let bytes = encode(&msg);
        assert!(decode(&bytes[..bytes.len() - 1], 3).is_none());
        assert!(decode(&[], 3).is_none());
    }

    #[test]
    fn every_truncation_length_rejected() {
        // word-level refill must never report more bits than the slice holds
        let msg = QuantMessage { codes: (0..40).collect(), radius: 2.0, bits: 7 };
        let bytes = encode(&msg);
        for cut in 0..bytes.len() {
            assert!(decode(&bytes[..cut], 40).is_none(), "cut={cut}");
        }
        assert!(decode(&bytes, 40).is_some());
    }

    #[test]
    fn wrong_dimension_detected_or_harmless() {
        let msg = QuantMessage { codes: vec![7; 10], radius: 1.0, bits: 3 };
        let bytes = encode(&msg);
        // asking for more coordinates than encoded must fail
        assert!(decode(&bytes, 40).is_none());
    }

    #[test]
    fn payload_is_dramatically_smaller_than_f32() {
        let d = 1000;
        let msg = QuantMessage { codes: vec![1; d], radius: 1.0, bits: 2 };
        assert!(msg.payload_bits() < (32 * d) as u64 / 10);
    }

    #[test]
    fn bit_level_layout_stable() {
        // golden test: layout must not silently change across refactors
        let msg = QuantMessage { codes: vec![0b101, 0b011], radius: 1.0, bits: 3 };
        let bytes = encode(&msg);
        // radius f32 1.0 = 0x3f800000 little-endian bits first
        assert_eq!(&bytes[..4], &0x3f800000u32.to_le_bytes());
        assert_eq!(&bytes[4..8], &3u32.to_le_bytes());
        assert_eq!(bytes[8], 0b011_101); // first code in low bits
    }

    #[test]
    fn word_level_matches_bit_loop_reference() {
        // differential test against the original bit-at-a-time packer: the
        // wire bytes must be identical for arbitrary messages
        fn ref_encode(msg: &QuantMessage) -> Vec<u8> {
            fn push_bits(buf: &mut Vec<u8>, bitlen: &mut usize, value: u64, width: u32) {
                for i in 0..width {
                    let bit = (value >> i) & 1;
                    let byte_idx = *bitlen / 8;
                    if byte_idx == buf.len() {
                        buf.push(0);
                    }
                    if bit == 1 {
                        buf[byte_idx] |= 1 << (*bitlen % 8);
                    }
                    *bitlen += 1;
                }
            }
            let mut buf = Vec::new();
            let mut bitlen = 0usize;
            push_bits(&mut buf, &mut bitlen, (msg.radius as f32).to_bits() as u64, 32);
            push_bits(&mut buf, &mut bitlen, msg.bits as u64, 32);
            for &c in &msg.codes {
                push_bits(&mut buf, &mut bitlen, c as u64, msg.bits);
            }
            buf
        }
        check("word-level == bit-loop wire bytes", 120, |g| {
            let bits = g.usize_in(1, 32) as u32;
            let d = g.usize_in(0, 64);
            let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let codes: Vec<u32> = (0..d).map(|_| g.u64() as u32 & mask).collect();
            let msg = QuantMessage {
                codes,
                radius: (g.f64_in(0.0, 10.0) as f32) as f64,
                bits,
            };
            assert_eq!(encode(&msg), ref_encode(&msg));
        });
    }
}
