//! Wire codec: bit-pack quantized messages into the exact payload the
//! paper counts (`b*d + b_R + b_b` bits), plus header encode/decode.
//!
//! Layout (little-endian bitstream):
//!   [ radius: f32 (32 bits) ][ bits: u32 (32 bits) ][ d codes of `bits` ]

use super::QuantMessage;

/// Append `width` low bits of `value` to the bitstream.
fn push_bits(buf: &mut Vec<u8>, bitlen: &mut usize, value: u64, width: u32) {
    for i in 0..width {
        let bit = (value >> i) & 1;
        let byte_idx = *bitlen / 8;
        if byte_idx == buf.len() {
            buf.push(0);
        }
        if bit == 1 {
            buf[byte_idx] |= 1 << (*bitlen % 8);
        }
        *bitlen += 1;
    }
}

/// Read `width` bits starting at `*pos` (advances `*pos`).
fn read_bits(buf: &[u8], pos: &mut usize, width: u32) -> Option<u64> {
    let mut out = 0u64;
    for i in 0..width {
        let byte_idx = *pos / 8;
        if byte_idx >= buf.len() {
            return None;
        }
        let bit = (buf[byte_idx] >> (*pos % 8)) & 1;
        out |= (bit as u64) << i;
        *pos += 1;
    }
    Some(out)
}

/// Encode a message into its wire bytes. The *bit* length is exactly
/// `msg.payload_bits()`; the byte vector rounds up to whole bytes.
pub fn encode(msg: &QuantMessage) -> Vec<u8> {
    let mut buf = Vec::with_capacity((msg.payload_bits() as usize).div_ceil(8));
    let mut bitlen = 0usize;
    push_bits(&mut buf, &mut bitlen, (msg.radius as f32).to_bits() as u64, 32);
    push_bits(&mut buf, &mut bitlen, msg.bits as u64, 32);
    for &c in &msg.codes {
        debug_assert!(msg.bits >= 32 || (c as u64) < (1u64 << msg.bits), "code overflows bit width");
        push_bits(&mut buf, &mut bitlen, c as u64, msg.bits);
    }
    debug_assert_eq!(bitlen as u64, msg.payload_bits());
    buf
}

/// Decode wire bytes back into a message; `d` is the (known) model
/// dimension.  Returns `None` on truncated/garbled input.
pub fn decode(buf: &[u8], d: usize) -> Option<QuantMessage> {
    let mut pos = 0usize;
    let radius = f32::from_bits(read_bits(buf, &mut pos, 32)? as u32) as f64;
    let bits = read_bits(buf, &mut pos, 32)? as u32;
    if bits == 0 || bits > 32 || !(radius.is_finite()) || radius < 0.0 {
        return None;
    }
    let mut codes = Vec::with_capacity(d);
    for _ in 0..d {
        codes.push(read_bits(buf, &mut pos, bits)? as u32);
    }
    Some(QuantMessage { codes, radius, bits })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    #[test]
    fn roundtrip_random_messages() {
        check("codec encode/decode identity", 150, |g| {
            let d = g.usize_in(0, 128);
            let bits = g.usize_in(2, 24) as u32;
            let n_codes = 1u64 << bits;
            let codes: Vec<u32> = (0..d)
                .map(|_| (g.u64() % n_codes) as u32)
                .collect();
            let radius = (g.f64_in(1e-9, 1e3) as f32) as f64; // f32-representable
            let msg = QuantMessage { codes, radius, bits };
            let bytes = encode(&msg);
            assert_eq!(bytes.len(), (msg.payload_bits() as usize).div_ceil(8));
            let back = decode(&bytes, d).expect("decode failed");
            assert_eq!(back, msg);
        });
    }

    #[test]
    fn truncated_input_rejected() {
        let msg = QuantMessage { codes: vec![1, 2, 3], radius: 0.5, bits: 4 };
        let bytes = encode(&msg);
        assert!(decode(&bytes[..bytes.len() - 1], 3).is_none());
        assert!(decode(&[], 3).is_none());
    }

    #[test]
    fn wrong_dimension_detected_or_harmless() {
        let msg = QuantMessage { codes: vec![7; 10], radius: 1.0, bits: 3 };
        let bytes = encode(&msg);
        // asking for more coordinates than encoded must fail
        assert!(decode(&bytes, 40).is_none());
    }

    #[test]
    fn payload_is_dramatically_smaller_than_f32() {
        let d = 1000;
        let msg = QuantMessage { codes: vec![1; d], radius: 1.0, bits: 2 };
        assert!(msg.payload_bits() < (32 * d) as u64 / 10);
    }

    #[test]
    fn bit_level_layout_stable() {
        // golden test: layout must not silently change across refactors
        let msg = QuantMessage { codes: vec![0b101, 0b011], radius: 1.0, bits: 3 };
        let bytes = encode(&msg);
        // radius f32 1.0 = 0x3f800000 little-endian bits first
        assert_eq!(&bytes[..4], &0x3f800000u32.to_le_bytes());
        assert_eq!(&bytes[4..8], &3u32.to_le_bytes());
        assert_eq!(bytes[8], 0b011_101); // first code in low bits
    }
}
