//! Communication accounting: transmissions, payloads and the wireless
//! energy model of paper §7.
//!
//! Each *transmission* is one worker broadcasting its (possibly quantized)
//! model to all neighbors in one upload slot.  The paper's metrics:
//! * **communication rounds** — cumulative number of transmissions,
//! * **transmitted bits** — cumulative payload bits (32d full precision,
//!   `b d + 64` quantized),
//! * **energy** — Shannon-capacity transmit power over the worst
//!   (bottleneck) link, `P = tau * D^2 * N0 * B (2^{R/B} - 1)`, `E = P tau`.

pub mod energy;
pub mod link;

pub use energy::{EnergyModel, EnergyParams};
pub use link::{
    ErasureLink, Fate, IdealLink, LatencyLink, LinkKind, LinkModel, LinkState, Medium,
    SlotOutcome, StragglerLink, TimeVaryingLink, LINK_GRAMMAR,
};

/// What one worker put on the air in one slot.
#[derive(Clone, Copy, Debug)]
pub struct Transmission {
    pub worker: usize,
    pub iteration: u64,
    pub payload_bits: u64,
    /// Bottleneck (max) distance to the intended receivers, meters.
    pub distance_m: f64,
    pub energy_j: f64,
}

/// Running totals + log of every transmission of a run.
///
/// A checkpoint restores only the totals (`prior_rounds`, `total_bits`,
/// `total_energy_j`), not the per-transmission history, so checkpoints
/// stay O(state) rather than O(history); `rounds()` folds the restored
/// prior count into the live tally.
#[derive(Clone, Debug, Default)]
pub struct CommLog {
    pub transmissions: Vec<Transmission>,
    pub total_bits: u64,
    pub total_energy_j: f64,
    /// Rounds from before the last restore (zero for a fresh run).
    pub prior_rounds: u64,
    /// Cumulative transmitted bits **per parameter block** (multi-block
    /// models only; empty for flat models — a multi-block transmission's
    /// `payload_bits` is the sum of its transmitting blocks' bits, so
    /// `block_bits` always sums to `total_bits` when present).
    pub block_bits: Vec<u64>,
}

impl CommLog {
    pub fn record(&mut self, t: Transmission) {
        self.total_bits += t.payload_bits;
        self.total_energy_j += t.energy_j;
        self.transmissions.push(t);
    }

    /// Account one multi-block transmission's per-block bits (the caller
    /// has already masked censored blocks to zero).  Grows the ledger on
    /// first use so flat models never allocate it.
    pub fn record_block_bits(&mut self, per_block: &[u64]) {
        if self.block_bits.len() < per_block.len() {
            self.block_bits.resize(per_block.len(), 0);
        }
        for (acc, b) in self.block_bits.iter_mut().zip(per_block) {
            *acc += b;
        }
    }

    /// Cumulative communication rounds (= number of transmissions,
    /// including rounds restored from a checkpoint).
    pub fn rounds(&self) -> u64 {
        self.prior_rounds + self.transmissions.len() as u64
    }

    /// Reset to checkpointed totals, dropping the per-transmission log.
    pub fn restore_totals(&mut self, rounds: u64, total_bits: u64, total_energy_j: f64) {
        self.transmissions.clear();
        self.prior_rounds = rounds;
        self.total_bits = total_bits;
        self.total_energy_j = total_energy_j;
    }

    /// Restore the per-block ledger alongside [`CommLog::restore_totals`]
    /// (v3 checkpoints; v2 leaves it empty).
    pub fn restore_block_bits(&mut self, block_bits: Vec<u64>) {
        self.block_bits = block_bits;
    }

    /// Transmissions belonging to iteration `k`.
    pub fn at_iteration(&self, k: u64) -> impl Iterator<Item = &Transmission> {
        self.transmissions.iter().filter(move |t| t.iteration == k)
    }
}

/// Full-precision payload size (the paper's 32d bits).
pub fn full_precision_bits(d: usize) -> u64 {
    32 * d as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_accumulates() {
        let mut log = CommLog::default();
        log.record(Transmission {
            worker: 0,
            iteration: 0,
            payload_bits: 1600,
            distance_m: 100.0,
            energy_j: 1e-3,
        });
        log.record(Transmission {
            worker: 1,
            iteration: 0,
            payload_bits: 164,
            distance_m: 50.0,
            energy_j: 1e-5,
        });
        assert_eq!(log.rounds(), 2);
        assert_eq!(log.total_bits, 1764);
        assert!((log.total_energy_j - 1.01e-3).abs() < 1e-12);
        assert_eq!(log.at_iteration(0).count(), 2);
        assert_eq!(log.at_iteration(1).count(), 0);
    }

    #[test]
    fn full_precision_is_32d() {
        assert_eq!(full_precision_bits(50), 1600);
    }

    #[test]
    fn block_ledger_accumulates_and_restores() {
        let mut log = CommLog::default();
        assert!(log.block_bits.is_empty());
        log.record_block_bits(&[100, 0]);
        log.record_block_bits(&[50, 64]);
        assert_eq!(log.block_bits, vec![150, 64]);
        log.restore_block_bits(vec![7, 8]);
        assert_eq!(log.block_bits, vec![7, 8]);
    }
}
