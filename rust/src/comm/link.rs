//! Pluggable link models + the shared transmit path.
//!
//! Every committed broadcast of either engine — the sequential simulator
//! ([`crate::algs::Run`]) and the sharded coordinator
//! ([`crate::coordinator`]) — goes through [`Medium::transmit`]: the
//! paper's §7 energy model is charged, the transmission is logged, and a
//! [`LinkModel`] decides the broadcast's fate.  Centralizing the path
//! keeps the accounting (and the erasure RNG stream) bit-identical across
//! engines, which `tests/coordinator_equivalence.rs` locks.
//!
//! Shipped models:
//! * [`IdealLink`] — every broadcast is delivered within its slot;
//! * [`ErasureLink`] — a broadcast is lost with probability `p` (erasure
//!   with perfect feedback: energy and bits are still spent, receivers
//!   keep the stale value, sender state rolls back);
//! * [`LatencyLink`] — deterministic per-link delay (propagation +
//!   serialization): a synchronous phase ends when its slowest broadcast
//!   lands, so stragglers stretch the simulated wall clock that
//!   [`Medium::sim_time_s`] accumulates;
//! * [`TimeVaryingLink`] — a periodic Gilbert–Elliott good/bad channel:
//!   drop probability (and an optional extra delay) are piecewise
//!   functions of [`Medium::sim_time_s`], so link quality drifts over
//!   the run instead of being drawn i.i.d.;
//! * [`StragglerLink`] — a seeded, rotating subset of workers is tagged
//!   as stragglers whose broadcasts suffer heavy-tailed (Pareto) delays;
//!   everyone else lands within the slot.
//!
//! Every stochastic model draws **once per committed broadcast, in
//! commit order** (ascending worker id within a phase) and exports its
//! RNG position as durable [`LinkState`], which is what keeps
//! checkpoint/resume bit-identical across both engines.

use super::{CommLog, EnergyModel, Transmission};
use crate::util::rng::Pcg64;

/// Fate of one broadcast, as decided by a [`LinkModel`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fate {
    /// Delivered to every neighbor after `latency_s` seconds (0 = within
    /// the upload slot).
    Delivered { latency_s: f64 },
    /// Lost on the air; the slot's airtime is still consumed.
    Dropped,
}

/// Durable link-model state for checkpointing.  Stateless models
/// (ideal, latency) carry nothing; the stochastic links (erasure,
/// time-varying, straggler) carry their RNG stream position so resumed
/// draws line up bit-for-bit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LinkState {
    Stateless,
    Rng { state: u128, inc: u128 },
}

/// A channel impairment model consulted once per committed broadcast.
/// `now_s` is the medium's simulated clock at the start of the slot, so
/// models can vary over simulated time.
pub trait LinkModel: Send {
    fn fate(
        &mut self,
        from: usize,
        iteration: u64,
        payload_bits: u64,
        distance_m: f64,
        now_s: f64,
    ) -> Fate;

    /// Export durable state (default: none).
    fn state(&self) -> LinkState {
        LinkState::Stateless
    }

    /// Restore durable state (default: nothing to restore).
    fn restore(&mut self, _s: &LinkState) {}
}

/// Perfect channel.
pub struct IdealLink;

impl LinkModel for IdealLink {
    fn fate(&mut self, _: usize, _: u64, _: u64, _: f64, _: f64) -> Fate {
        Fate::Delivered { latency_s: 0.0 }
    }
}

/// Broadcast erasure with probability `p` (one Bernoulli draw per
/// committed broadcast, in commit order — the determinism contract both
/// engines share).
pub struct ErasureLink {
    p: f64,
    rng: Pcg64,
}

impl ErasureLink {
    pub fn new(p: f64, rng: Pcg64) -> ErasureLink {
        assert!((0.0..=1.0).contains(&p), "erasure probability out of range");
        ErasureLink { p, rng }
    }
}

impl LinkModel for ErasureLink {
    fn fate(&mut self, _: usize, _: u64, _: u64, _: f64, _: f64) -> Fate {
        if self.rng.bernoulli(self.p) {
            Fate::Dropped
        } else {
            Fate::Delivered { latency_s: 0.0 }
        }
    }

    fn state(&self) -> LinkState {
        let (state, inc) = self.rng.to_raw();
        LinkState::Rng { state, inc }
    }

    fn restore(&mut self, s: &LinkState) {
        if let LinkState::Rng { state, inc } = *s {
            self.rng = Pcg64::from_raw(state, inc);
        }
    }
}

/// Deterministic per-link latency: fixed processing overhead plus
/// serialization (`payload_bits * per_bit_s`) plus free-space propagation
/// at c.  Never drops.
pub struct LatencyLink {
    pub base_s: f64,
    pub per_bit_s: f64,
}

const SPEED_OF_LIGHT_M_S: f64 = 299_792_458.0;

impl LinkModel for LatencyLink {
    fn fate(&mut self, _: usize, _: u64, payload_bits: u64, distance_m: f64, _: f64) -> Fate {
        Fate::Delivered {
            latency_s: self.base_s
                + payload_bits as f64 * self.per_bit_s
                + distance_m / SPEED_OF_LIGHT_M_S,
        }
    }
}

/// Periodic Gilbert–Elliott channel: each period of `period_s` simulated
/// seconds opens with a *bad* burst covering the first `bad_frac` of the
/// period (drop probability `p_bad`, extra delivery delay `bad_latency_s`)
/// and spends the rest in the *good* state (`p_good`, no extra delay).
/// The good/bad phase is a pure function of the medium's clock — only the
/// Bernoulli stream is durable state, so checkpoint/resume needs nothing
/// beyond the RNG position.
pub struct TimeVaryingLink {
    period_s: f64,
    bad_frac: f64,
    p_good: f64,
    p_bad: f64,
    bad_latency_s: f64,
    rng: Pcg64,
}

impl TimeVaryingLink {
    pub fn new(
        period_s: f64,
        bad_frac: f64,
        p_good: f64,
        p_bad: f64,
        bad_latency_s: f64,
        rng: Pcg64,
    ) -> TimeVaryingLink {
        assert!(period_s > 0.0, "period_s must be positive");
        assert!((0.0..=1.0).contains(&bad_frac), "bad_frac out of [0,1]");
        assert!((0.0..=1.0).contains(&p_good), "p_good out of [0,1]");
        assert!((0.0..=1.0).contains(&p_bad), "p_bad out of [0,1]");
        assert!(bad_latency_s >= 0.0, "bad_latency_s must be non-negative");
        TimeVaryingLink { period_s, bad_frac, p_good, p_bad, bad_latency_s, rng }
    }

    /// True when the clock sits inside a bad burst (pure in `now_s`).
    pub fn in_bad_state(&self, now_s: f64) -> bool {
        let phase = (now_s / self.period_s).fract();
        phase < self.bad_frac
    }
}

impl LinkModel for TimeVaryingLink {
    fn fate(&mut self, _: usize, _: u64, _: u64, _: f64, now_s: f64) -> Fate {
        let bad = self.in_bad_state(now_s);
        let p = if bad { self.p_bad } else { self.p_good };
        if self.rng.bernoulli(p) {
            Fate::Dropped
        } else {
            Fate::Delivered {
                latency_s: if bad { self.bad_latency_s } else { 0.0 },
            }
        }
    }

    fn state(&self) -> LinkState {
        let (state, inc) = self.rng.to_raw();
        LinkState::Rng { state, inc }
    }

    fn restore(&mut self, s: &LinkState) {
        if let LinkState::Rng { state, inc } = *s {
            self.rng = Pcg64::from_raw(state, inc);
        }
    }
}

/// Heavy-tailed straggler injection: `ceil(frac * n)` workers are tagged
/// as stragglers; the subset is re-sampled every `rotate_every`
/// iterations from a seed fixed at construction, so membership is a pure
/// function of the epoch (`iteration / rotate_every`) — no stream-order
/// dependence and nothing extra to checkpoint.  A straggler's broadcast
/// is delivered after a Pareto(`alpha`) delay scaled by `base_s`
/// (drawn from the durable RNG stream); everyone else lands within the
/// slot.  Nothing is ever dropped.
pub struct StragglerLink {
    n: usize,
    k: usize,
    rotate_every: u64,
    base_s: f64,
    alpha: f64,
    subset_seed: u64,
    rng: Pcg64,
    /// Cached membership for `cached_epoch` (recomputed on demand; pure
    /// in the epoch, so it is scratch, not durable state).
    cached_epoch: u64,
    straggler: Vec<bool>,
}

impl StragglerLink {
    pub fn new(
        n: usize,
        frac: f64,
        rotate_every: u64,
        base_s: f64,
        alpha: f64,
        subset_seed: u64,
        rng: Pcg64,
    ) -> StragglerLink {
        assert!(n > 0, "straggler link needs at least one worker");
        assert!((0.0..=1.0).contains(&frac), "straggler fraction out of [0,1]");
        assert!(rotate_every >= 1, "rotate_every must be >= 1");
        assert!(base_s >= 0.0, "base_s must be non-negative");
        assert!(alpha > 0.0, "Pareto alpha must be positive");
        let k = ((frac * n as f64).ceil() as usize).min(n);
        StragglerLink {
            n,
            k,
            rotate_every,
            base_s,
            alpha,
            subset_seed,
            rng,
            cached_epoch: u64::MAX,
            straggler: vec![false; n],
        }
    }

    /// Straggler membership at `iteration` (pure: a throwaway generator
    /// keyed by the epoch, independent of the fate stream).
    pub fn is_straggler(&mut self, from: usize, iteration: u64) -> bool {
        let epoch = iteration / self.rotate_every;
        if epoch != self.cached_epoch {
            self.straggler.iter_mut().for_each(|s| *s = false);
            let mut pick = Pcg64::with_stream(
                self.subset_seed ^ epoch.wrapping_mul(0x9e37_79b9_7f4a_7c15),
                0x5747_a6_1e_55,
            );
            for i in pick.sample_indices(self.n, self.k) {
                self.straggler[i] = true;
            }
            self.cached_epoch = epoch;
        }
        self.straggler[from]
    }
}

impl LinkModel for StragglerLink {
    fn fate(&mut self, from: usize, iteration: u64, _: u64, _: f64, _: f64) -> Fate {
        if self.is_straggler(from, iteration) {
            // Pareto tail: base_s * (1-u)^(-1/alpha), u in [0,1) => the
            // scale factor is >= 1 and finite
            let u = self.rng.uniform();
            let delay = self.base_s * (1.0 - u).powf(-1.0 / self.alpha);
            Fate::Delivered { latency_s: delay }
        } else {
            Fate::Delivered { latency_s: 0.0 }
        }
    }

    fn state(&self) -> LinkState {
        let (state, inc) = self.rng.to_raw();
        LinkState::Rng { state, inc }
    }

    fn restore(&mut self, s: &LinkState) {
        if let LinkState::Rng { state, inc } = *s {
            self.rng = Pcg64::from_raw(state, inc);
        }
    }
}

/// Serializable link-model selection (run/coordinator options).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LinkKind {
    Ideal,
    Erasure { p: f64 },
    Latency { base_s: f64, per_bit_s: f64 },
    TimeVarying {
        period_s: f64,
        bad_frac: f64,
        p_good: f64,
        p_bad: f64,
        bad_latency_s: f64,
    },
    Straggler {
        frac: f64,
        rotate_every: u64,
        base_s: f64,
        alpha: f64,
    },
}

/// The one place the link-spec grammar lives; every parse error reports
/// it verbatim.
pub const LINK_GRAMMAR: &str = "ideal | erasure:<p> | latency:<base_s>,<per_bit_s> | \
     timevarying:<period_s>,<bad_frac>,<p_good>,<p_bad>[,<bad_latency_s>] | \
     straggler:<frac>,<rotate_every>,<base_s>,<alpha>";

impl LinkKind {
    /// Resolve an optional explicit kind against the legacy `drop_prob`
    /// knob: an explicit kind wins; otherwise `drop_prob > 0` selects an
    /// erasure link and `0` the ideal one (no RNG draws — bit-compatible
    /// with the pre-refactor engines).
    pub fn resolve(explicit: Option<LinkKind>, drop_prob: f64) -> LinkKind {
        explicit.unwrap_or(if drop_prob > 0.0 {
            LinkKind::Erasure { p: drop_prob }
        } else {
            LinkKind::Ideal
        })
    }

    /// Instantiate the model.  `rng` must be the post-fork root stream of
    /// [`crate::protocol::build_cores`] so stochastic draws line up
    /// across engines; `n_workers` sizes worker-indexed models (the
    /// straggler subset).
    pub fn build(self, rng: Pcg64, n_workers: usize) -> Box<dyn LinkModel> {
        match self {
            LinkKind::Ideal => Box::new(IdealLink),
            LinkKind::Erasure { p } => Box::new(ErasureLink::new(p, rng)),
            LinkKind::Latency { base_s, per_bit_s } => {
                Box::new(LatencyLink { base_s, per_bit_s })
            }
            LinkKind::TimeVarying { period_s, bad_frac, p_good, p_bad, bad_latency_s } => {
                Box::new(TimeVaryingLink::new(
                    period_s,
                    bad_frac,
                    p_good,
                    p_bad,
                    bad_latency_s,
                    rng,
                ))
            }
            LinkKind::Straggler { frac, rotate_every, base_s, alpha } => {
                let mut rng = rng;
                // the subset seed comes off the same root stream, so both
                // engines derive the identical rotating membership
                let subset_seed = rng.next_u64();
                Box::new(StragglerLink::new(
                    n_workers,
                    frac,
                    rotate_every,
                    base_s,
                    alpha,
                    subset_seed,
                    rng,
                ))
            }
        }
    }

    /// Parse the compact spec syntax used by manifests and CLI flags
    /// ([`LINK_GRAMMAR`]).  Trailing garbage — extra fields, stray
    /// suffixes, arguments on `ideal` — is rejected, not ignored.
    pub fn parse(s: &str) -> Result<LinkKind, String> {
        let s = s.trim();
        let (head, rest) = match s.split_once(':') {
            Some((h, r)) => (h.trim(), Some(r.trim())),
            None => (s, None),
        };
        let bad = |why: &str| -> String {
            format!("link spec '{s}': {why} (grammar: {LINK_GRAMMAR})")
        };
        // split the argument list and parse each field as f64, enforcing
        // the exact arity [min, max] — extra fields are trailing garbage
        let args = |min: usize, max: usize| -> Result<Vec<f64>, String> {
            let raw = rest.ok_or_else(|| bad("missing arguments"))?;
            let fields: Vec<&str> = raw.split(',').map(str::trim).collect();
            if fields.len() < min {
                return Err(bad(&format!("expected at least {min} fields")));
            }
            if fields.len() > max {
                return Err(bad("too many fields"));
            }
            fields
                .iter()
                .map(|f| {
                    f.parse::<f64>()
                        .map_err(|_| bad(&format!("bad number '{f}'")))
                })
                .collect()
        };
        let prob = |p: f64, what: &str| -> Result<f64, String> {
            if (0.0..=1.0).contains(&p) {
                Ok(p)
            } else {
                Err(bad(&format!("{what} out of [0,1]")))
            }
        };
        match head {
            "ideal" => {
                if rest.is_some() {
                    return Err(bad("takes no arguments"));
                }
                Ok(LinkKind::Ideal)
            }
            "erasure" => {
                let a = args(1, 1)?;
                Ok(LinkKind::Erasure { p: prob(a[0], "probability")? })
            }
            "latency" => {
                let a = args(2, 2)?;
                Ok(LinkKind::Latency { base_s: a[0], per_bit_s: a[1] })
            }
            "timevarying" => {
                let a = args(4, 5)?;
                if a[0] <= 0.0 {
                    return Err(bad("period_s must be positive"));
                }
                Ok(LinkKind::TimeVarying {
                    period_s: a[0],
                    bad_frac: prob(a[1], "bad_frac")?,
                    p_good: prob(a[2], "p_good")?,
                    p_bad: prob(a[3], "p_bad")?,
                    bad_latency_s: *a.get(4).unwrap_or(&0.0),
                })
            }
            "straggler" => {
                let a = args(4, 4)?;
                let rotate = a[1];
                if rotate < 1.0 || rotate.fract() != 0.0 {
                    return Err(bad("rotate_every must be a positive integer"));
                }
                if a[3] <= 0.0 {
                    return Err(bad("alpha must be positive"));
                }
                Ok(LinkKind::Straggler {
                    frac: prob(a[0], "frac")?,
                    rotate_every: rotate as u64,
                    base_s: a[2],
                    alpha: a[3],
                })
            }
            _ => Err(bad("unknown link spec")),
        }
    }

    /// Canonical label; `LinkKind::parse(kind.label())` round-trips.
    pub fn label(&self) -> String {
        match self {
            LinkKind::Ideal => "ideal".into(),
            LinkKind::Erasure { p } => format!("erasure:{p}"),
            LinkKind::Latency { base_s, per_bit_s } => format!("latency:{base_s},{per_bit_s}"),
            LinkKind::TimeVarying { period_s, bad_frac, p_good, p_bad, bad_latency_s } => {
                format!("timevarying:{period_s},{bad_frac},{p_good},{p_bad},{bad_latency_s}")
            }
            LinkKind::Straggler { frac, rotate_every, base_s, alpha } => {
                format!("straggler:{frac},{rotate_every},{base_s},{alpha}")
            }
        }
    }
}

/// Outcome of one slot under the bounded-staleness round policy (see
/// [`Medium::transmit_bounded`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotOutcome {
    /// Delivered within the slot; receivers update.
    Landed,
    /// Lost on the air (erasure); sender rolls back.
    Dropped,
    /// Delivered, but after the slot closed — the round proceeded
    /// without it, so receivers keep the stale value and the sender
    /// rolls back (identical to a drop, but counted as straggling).
    Late,
}

/// The shared transmit path: §7 energy accounting + transmission log +
/// link-model fate + simulated wall clock, one instance per run.
pub struct Medium {
    log: CommLog,
    energy: EnergyModel,
    link: Box<dyn LinkModel>,
    /// Upload slot duration (each phase occupies at least one slot).
    slot_s: f64,
    /// Slowest broadcast of the slot in flight.
    slot_latency_s: f64,
    sim_time_s: f64,
}

impl Medium {
    pub fn new(energy: EnergyModel, slot_s: f64, link: Box<dyn LinkModel>) -> Medium {
        Medium {
            log: CommLog::default(),
            energy,
            link,
            slot_s,
            slot_latency_s: 0.0,
            sim_time_s: 0.0,
        }
    }

    /// One committed broadcast: charge energy, log it, and return whether
    /// the neighbors actually receive it (false = erasure; the caller
    /// rolls the sender's state back — perfect feedback).
    pub fn transmit(
        &mut self,
        worker: usize,
        iteration: u64,
        payload_bits: u64,
        distance_m: f64,
    ) -> bool {
        self.log.record(Transmission {
            worker,
            iteration,
            payload_bits,
            distance_m,
            energy_j: self.energy.energy_j(payload_bits, distance_m),
        });
        match self
            .link
            .fate(worker, iteration, payload_bits, distance_m, self.sim_time_s)
        {
            Fate::Delivered { latency_s } => {
                self.slot_latency_s = self.slot_latency_s.max(latency_s);
                true
            }
            Fate::Dropped => {
                // the airtime is consumed even though nothing lands
                self.slot_latency_s = self.slot_latency_s.max(self.slot_s);
                false
            }
        }
    }

    /// One committed broadcast under the bounded-staleness round policy:
    /// same accounting as [`Medium::transmit`], but a delivery that
    /// would straggle past the slot counts as [`SlotOutcome::Late`] —
    /// the round closes on time without it instead of stretching the
    /// clock.  `reliable = true` models the forced staleness refresh
    /// (retransmit-until-success): the broadcast always lands, consumes
    /// the full slot, and — crucially for engine equivalence — skips
    /// the link-model fate draw entirely.
    pub fn transmit_bounded(
        &mut self,
        worker: usize,
        iteration: u64,
        payload_bits: u64,
        distance_m: f64,
        reliable: bool,
    ) -> SlotOutcome {
        self.log.record(Transmission {
            worker,
            iteration,
            payload_bits,
            distance_m,
            energy_j: self.energy.energy_j(payload_bits, distance_m),
        });
        if reliable {
            self.slot_latency_s = self.slot_latency_s.max(self.slot_s);
            return SlotOutcome::Landed;
        }
        match self
            .link
            .fate(worker, iteration, payload_bits, distance_m, self.sim_time_s)
        {
            Fate::Delivered { latency_s } if latency_s <= self.slot_s => {
                self.slot_latency_s = self.slot_latency_s.max(latency_s);
                SlotOutcome::Landed
            }
            Fate::Delivered { .. } => {
                self.slot_latency_s = self.slot_latency_s.max(self.slot_s);
                SlotOutcome::Late
            }
            Fate::Dropped => {
                self.slot_latency_s = self.slot_latency_s.max(self.slot_s);
                SlotOutcome::Dropped
            }
        }
    }

    /// Close one synchronous phase: the slot lasts at least `slot_s`, and
    /// longer when a latency model made a broadcast straggle.
    pub fn end_slot(&mut self) {
        self.sim_time_s += self.slot_latency_s.max(self.slot_s);
        self.slot_latency_s = 0.0;
    }

    /// Transmission log so far.
    pub fn log(&self) -> &CommLog {
        &self.log
    }

    /// Account one multi-block broadcast's per-block bits next to the
    /// transmission just recorded (see [`CommLog::record_block_bits`];
    /// the caller has already zeroed censored blocks).
    pub fn record_block_bits(&mut self, per_block: &[u64]) {
        self.log.record_block_bits(per_block);
    }

    /// Restore the per-block bits ledger alongside [`Medium::restore`]
    /// (v3 checkpoints; empty resets it for flat models).
    pub fn restore_block_bits(&mut self, block_bits: Vec<u64>) {
        self.log.restore_block_bits(block_bits);
    }

    /// Simulated wall-clock seconds spent on the air so far (slots ×
    /// phase count, stretched by link latency).
    pub fn sim_time_s(&self) -> f64 {
        self.sim_time_s
    }

    /// Durable link-model state (checkpointing).
    pub fn link_state(&self) -> LinkState {
        self.link.state()
    }

    /// Restore the medium at an iteration boundary: checkpointed totals,
    /// simulated clock, and the link model's RNG position.  The in-slot
    /// scratch (`slot_latency_s`) is always zero between phases.
    pub fn restore(
        &mut self,
        rounds: u64,
        total_bits: u64,
        total_energy_j: f64,
        sim_time_s: f64,
        link: &LinkState,
    ) {
        self.log.restore_totals(rounds, total_bits, total_energy_j);
        self.sim_time_s = sim_time_s;
        self.slot_latency_s = 0.0;
        self.link.restore(link);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::EnergyParams;

    fn medium(kind: LinkKind) -> Medium {
        let params = EnergyParams::default();
        Medium::new(
            EnergyModel::new(params, 8, 0.5),
            params.slot_s,
            kind.build(Pcg64::new(3), 8),
        )
    }

    #[test]
    fn ideal_always_delivers_and_charges() {
        let mut m = medium(LinkKind::Ideal);
        for k in 0..5 {
            assert!(m.transmit(0, k, 160, 100.0));
        }
        m.end_slot();
        assert_eq!(m.log().rounds(), 5);
        assert_eq!(m.log().total_bits, 800);
        assert!(m.log().total_energy_j > 0.0);
        assert!((m.sim_time_s() - EnergyParams::default().slot_s).abs() < 1e-15);
    }

    #[test]
    fn erasure_rate_roughly_p_and_always_charges() {
        let mut m = medium(LinkKind::Erasure { p: 0.3 });
        let trials: u64 = 2000;
        let delivered = (0..trials).filter(|&k| m.transmit(0, k, 160, 100.0)).count();
        // every attempt is logged regardless of fate
        assert_eq!(m.log().rounds(), trials);
        let rate = 1.0 - delivered as f64 / trials as f64;
        assert!((rate - 0.3).abs() < 0.05, "erasure rate {rate}");
    }

    #[test]
    fn latency_stretches_the_slot() {
        let mut m = medium(LinkKind::Latency { base_s: 0.5, per_bit_s: 0.0 });
        assert!(m.transmit(0, 0, 160, 100.0));
        m.end_slot();
        assert!(m.sim_time_s() >= 0.5, "straggler must stretch the slot");
        // an empty (fully censored) phase still occupies one slot
        m.end_slot();
        assert!((m.sim_time_s() - (0.5 + 1e-3)).abs() < 1e-9);
    }

    #[test]
    fn latency_grows_with_bits_and_distance() {
        let mut l = LatencyLink { base_s: 0.0, per_bit_s: 1e-6 };
        let short = match l.fate(0, 0, 100, 10.0, 0.0) {
            Fate::Delivered { latency_s } => latency_s,
            Fate::Dropped => unreachable!(),
        };
        let long = match l.fate(0, 0, 10_000, 10.0, 0.0) {
            Fate::Delivered { latency_s } => latency_s,
            Fate::Dropped => unreachable!(),
        };
        assert!(long > short);
    }

    #[test]
    fn resolve_prefers_explicit_kind() {
        assert_eq!(LinkKind::resolve(None, 0.0), LinkKind::Ideal);
        assert_eq!(LinkKind::resolve(None, 0.2), LinkKind::Erasure { p: 0.2 });
        assert_eq!(
            LinkKind::resolve(Some(LinkKind::Ideal), 0.2),
            LinkKind::Ideal
        );
    }

    // ---- time-varying (Gilbert-Elliott) link -------------------------

    #[test]
    fn timevarying_phase_is_pure_in_sim_time() {
        let mut l = TimeVaryingLink::new(1.0, 0.25, 0.0, 1.0, 0.1, Pcg64::new(5));
        assert!(l.in_bad_state(0.0));
        assert!(l.in_bad_state(0.2));
        assert!(!l.in_bad_state(0.3));
        assert!(!l.in_bad_state(0.9));
        assert!(l.in_bad_state(1.1)); // periodic
        // p_bad = 1: everything inside the burst drops
        assert_eq!(l.fate(0, 0, 32, 1.0, 0.1), Fate::Dropped);
        // p_good = 0: everything outside the burst lands within the slot
        assert_eq!(l.fate(0, 0, 32, 1.0, 0.5), Fate::Delivered { latency_s: 0.0 });
    }

    #[test]
    fn timevarying_drop_rate_tracks_the_burst() {
        let mut m = medium(LinkKind::TimeVarying {
            period_s: 1.0,
            bad_frac: 0.5,
            p_good: 0.0,
            p_bad: 0.8,
            bad_latency_s: 0.0,
        });
        // sim_time starts at 0 => inside the bad burst until end_slot
        // pushes the clock past bad_frac * period
        let trials: u64 = 500;
        let dropped = (0..trials).filter(|&k| !m.transmit(0, k, 160, 10.0)).count();
        let rate = dropped as f64 / trials as f64;
        assert!((rate - 0.8).abs() < 0.08, "bad-state drop rate {rate}");
    }

    #[test]
    fn timevarying_state_round_trips_through_rng() {
        let mut a = TimeVaryingLink::new(2.0, 0.3, 0.2, 0.9, 0.0, Pcg64::new(11));
        for k in 0..17 {
            a.fate(0, k, 64, 5.0, k as f64 * 0.1);
        }
        let s = a.state();
        assert!(matches!(s, LinkState::Rng { .. }));
        let mut b = TimeVaryingLink::new(2.0, 0.3, 0.2, 0.9, 0.0, Pcg64::new(999));
        b.restore(&s);
        for k in 0..64 {
            let now = k as f64 * 0.07;
            assert_eq!(a.fate(0, k, 64, 5.0, now), b.fate(0, k, 64, 5.0, now));
        }
        // byte-level contract: state after identical draws is identical
        assert_eq!(a.state(), b.state());
    }

    // ---- straggler link ----------------------------------------------

    #[test]
    fn straggler_subset_rotates_and_is_deterministic() {
        let mk = || StragglerLink::new(16, 0.25, 10, 0.01, 1.5, 77, Pcg64::new(4));
        let (mut a, mut b) = (mk(), mk());
        for iter in [0u64, 5, 9, 10, 25, 100] {
            let sa: Vec<bool> = (0..16).map(|w| a.is_straggler(w, iter)).collect();
            let sb: Vec<bool> = (0..16).map(|w| b.is_straggler(w, iter)).collect();
            assert_eq!(sa, sb, "membership must be a pure function of the epoch");
            assert_eq!(sa.iter().filter(|&&s| s).count(), 4, "ceil(0.25 * 16)");
        }
        // epochs 0 and 1 should (for this seed) pick different subsets
        let e0: Vec<bool> = (0..16).map(|w| a.is_straggler(w, 0)).collect();
        let e1: Vec<bool> = (0..16).map(|w| a.is_straggler(w, 10)).collect();
        assert_ne!(e0, e1, "rotation must re-sample the subset");
    }

    #[test]
    fn straggler_delays_are_heavy_tailed_and_positive() {
        let mut l = StragglerLink::new(4, 1.0, 1, 0.02, 1.2, 3, Pcg64::new(8));
        for k in 0..200 {
            match l.fate(k as usize % 4, k, 64, 5.0, 0.0) {
                Fate::Delivered { latency_s } => {
                    assert!(latency_s >= 0.02 && latency_s.is_finite());
                }
                Fate::Dropped => panic!("straggler link never drops"),
            }
        }
    }

    #[test]
    fn straggler_state_round_trips_through_rng() {
        let mut a = StragglerLink::new(8, 0.5, 4, 0.01, 2.0, 55, Pcg64::new(21));
        for k in 0..13 {
            a.fate(k as usize % 8, k, 64, 5.0, 0.0);
        }
        let s = a.state();
        let mut b = StragglerLink::new(8, 0.5, 4, 0.01, 2.0, 55, Pcg64::new(1234));
        b.restore(&s);
        for k in 0..64 {
            assert_eq!(
                a.fate(k as usize % 8, k, 64, 5.0, 0.0),
                b.fate(k as usize % 8, k, 64, 5.0, 0.0)
            );
        }
        assert_eq!(a.state(), b.state());
    }

    // ---- bounded-staleness transmit path -----------------------------

    #[test]
    fn transmit_bounded_classifies_late_and_caps_the_slot() {
        let slot = EnergyParams::default().slot_s;
        let mut m = medium(LinkKind::Latency { base_s: 10.0 * slot, per_bit_s: 0.0 });
        assert_eq!(m.transmit_bounded(0, 0, 160, 10.0, false), SlotOutcome::Late);
        m.end_slot();
        // the round closed on time: the straggler did NOT stretch the clock
        assert!((m.sim_time_s() - slot).abs() < 1e-15);
        // the attempt is still charged
        assert_eq!(m.log().rounds(), 1);
        assert!(m.log().total_energy_j > 0.0);
    }

    #[test]
    fn transmit_bounded_reliable_always_lands_without_a_fate_draw() {
        // p = 1 erasure would drop everything; reliable delivery bypasses
        // the draw entirely (and must not advance the RNG stream)
        let mut m = medium(LinkKind::Erasure { p: 1.0 });
        let before = m.link_state();
        assert_eq!(m.transmit_bounded(0, 0, 160, 10.0, true), SlotOutcome::Landed);
        assert_eq!(m.link_state(), before, "reliable send must not draw");
        assert_eq!(m.transmit_bounded(1, 0, 160, 10.0, false), SlotOutcome::Dropped);
        assert_ne!(m.link_state(), before, "unreliable send draws");
    }

    // ---- parse round trips: every family (satellite bugfix) ----------

    #[test]
    fn parse_round_trips_every_family() {
        let kinds = [
            LinkKind::Ideal,
            LinkKind::Erasure { p: 0.17 },
            LinkKind::Latency { base_s: 1.5e-3, per_bit_s: 1e-9 },
            LinkKind::TimeVarying {
                period_s: 0.5,
                bad_frac: 0.2,
                p_good: 0.01,
                p_bad: 0.6,
                bad_latency_s: 0.002,
            },
            LinkKind::Straggler { frac: 0.125, rotate_every: 20, base_s: 0.0015, alpha: 1.5 },
        ];
        for k in kinds {
            let label = k.label();
            assert_eq!(LinkKind::parse(&label).unwrap(), k, "round trip of '{label}'");
        }
        // the 4-field timevarying form defaults bad_latency_s to 0
        assert_eq!(
            LinkKind::parse("timevarying:1,0.25,0.05,0.5").unwrap(),
            LinkKind::TimeVarying {
                period_s: 1.0,
                bad_frac: 0.25,
                p_good: 0.05,
                p_bad: 0.5,
                bad_latency_s: 0.0,
            }
        );
    }

    #[test]
    fn parse_rejects_trailing_garbage_and_reports_the_grammar() {
        for bad in [
            "ideal:1",            // ideal takes no arguments
            "erasure:0.2,junk",   // trailing field
            "erasure:0.2extra",   // trailing garbage inside the number
            "latency:1e-3,1e-9,0",
            "timevarying:1,0.2,0.1,0.5,0.001,9",
            "straggler:0.1,10,0.001,1.5,0",
            "straggler:0.1,10.5,0.001,1.5", // non-integer rotate_every
            "carrier-pigeon",
        ] {
            let err = LinkKind::parse(bad).unwrap_err();
            assert!(err.contains("grammar:"), "'{bad}' error must cite the grammar: {err}");
        }
        // out-of-range probabilities stay rejected
        assert!(LinkKind::parse("erasure:1.5").is_err());
        assert!(LinkKind::parse("timevarying:1,2,0.1,0.5").is_err());
        assert!(LinkKind::parse("straggler:-0.1,10,0.001,1.5").is_err());
    }
}
