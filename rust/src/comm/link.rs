//! Pluggable link models + the shared transmit path.
//!
//! Every committed broadcast of either engine — the sequential simulator
//! ([`crate::algs::Run`]) and the sharded coordinator
//! ([`crate::coordinator`]) — goes through [`Medium::transmit`]: the
//! paper's §7 energy model is charged, the transmission is logged, and a
//! [`LinkModel`] decides the broadcast's fate.  Centralizing the path
//! keeps the accounting (and the erasure RNG stream) bit-identical across
//! engines, which `tests/coordinator_equivalence.rs` locks.
//!
//! Shipped models:
//! * [`IdealLink`] — every broadcast is delivered within its slot;
//! * [`ErasureLink`] — a broadcast is lost with probability `p` (erasure
//!   with perfect feedback: energy and bits are still spent, receivers
//!   keep the stale value, sender state rolls back);
//! * [`LatencyLink`] — deterministic per-link delay (propagation +
//!   serialization): a synchronous phase ends when its slowest broadcast
//!   lands, so stragglers stretch the simulated wall clock that
//!   [`Medium::sim_time_s`] accumulates.

use super::{CommLog, EnergyModel, Transmission};
use crate::util::rng::Pcg64;

/// Fate of one broadcast, as decided by a [`LinkModel`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fate {
    /// Delivered to every neighbor after `latency_s` seconds (0 = within
    /// the upload slot).
    Delivered { latency_s: f64 },
    /// Lost on the air; the slot's airtime is still consumed.
    Dropped,
}

/// Durable link-model state for checkpointing.  Stateless models
/// (ideal, latency) carry nothing; the erasure link carries its RNG
/// stream position so resumed drops line up bit-for-bit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LinkState {
    Stateless,
    Rng { state: u128, inc: u128 },
}

/// A channel impairment model consulted once per committed broadcast.
pub trait LinkModel: Send {
    fn fate(&mut self, from: usize, iteration: u64, payload_bits: u64, distance_m: f64) -> Fate;

    /// Export durable state (default: none).
    fn state(&self) -> LinkState {
        LinkState::Stateless
    }

    /// Restore durable state (default: nothing to restore).
    fn restore(&mut self, _s: &LinkState) {}
}

/// Perfect channel.
pub struct IdealLink;

impl LinkModel for IdealLink {
    fn fate(&mut self, _: usize, _: u64, _: u64, _: f64) -> Fate {
        Fate::Delivered { latency_s: 0.0 }
    }
}

/// Broadcast erasure with probability `p` (one Bernoulli draw per
/// committed broadcast, in commit order — the determinism contract both
/// engines share).
pub struct ErasureLink {
    p: f64,
    rng: Pcg64,
}

impl ErasureLink {
    pub fn new(p: f64, rng: Pcg64) -> ErasureLink {
        assert!((0.0..=1.0).contains(&p), "erasure probability out of range");
        ErasureLink { p, rng }
    }
}

impl LinkModel for ErasureLink {
    fn fate(&mut self, _: usize, _: u64, _: u64, _: f64) -> Fate {
        if self.rng.bernoulli(self.p) {
            Fate::Dropped
        } else {
            Fate::Delivered { latency_s: 0.0 }
        }
    }

    fn state(&self) -> LinkState {
        let (state, inc) = self.rng.to_raw();
        LinkState::Rng { state, inc }
    }

    fn restore(&mut self, s: &LinkState) {
        if let LinkState::Rng { state, inc } = *s {
            self.rng = Pcg64::from_raw(state, inc);
        }
    }
}

/// Deterministic per-link latency: fixed processing overhead plus
/// serialization (`payload_bits * per_bit_s`) plus free-space propagation
/// at c.  Never drops.
pub struct LatencyLink {
    pub base_s: f64,
    pub per_bit_s: f64,
}

const SPEED_OF_LIGHT_M_S: f64 = 299_792_458.0;

impl LinkModel for LatencyLink {
    fn fate(&mut self, _: usize, _: u64, payload_bits: u64, distance_m: f64) -> Fate {
        Fate::Delivered {
            latency_s: self.base_s
                + payload_bits as f64 * self.per_bit_s
                + distance_m / SPEED_OF_LIGHT_M_S,
        }
    }
}

/// Serializable link-model selection (run/coordinator options).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LinkKind {
    Ideal,
    Erasure { p: f64 },
    Latency { base_s: f64, per_bit_s: f64 },
}

impl LinkKind {
    /// Resolve an optional explicit kind against the legacy `drop_prob`
    /// knob: an explicit kind wins; otherwise `drop_prob > 0` selects an
    /// erasure link and `0` the ideal one (no RNG draws — bit-compatible
    /// with the pre-refactor engines).
    pub fn resolve(explicit: Option<LinkKind>, drop_prob: f64) -> LinkKind {
        explicit.unwrap_or(if drop_prob > 0.0 {
            LinkKind::Erasure { p: drop_prob }
        } else {
            LinkKind::Ideal
        })
    }

    /// Instantiate the model.  `rng` must be the post-fork root stream of
    /// [`crate::protocol::build_cores`] so erasure draws line up across
    /// engines.
    pub fn build(self, rng: Pcg64) -> Box<dyn LinkModel> {
        match self {
            LinkKind::Ideal => Box::new(IdealLink),
            LinkKind::Erasure { p } => Box::new(ErasureLink::new(p, rng)),
            LinkKind::Latency { base_s, per_bit_s } => {
                Box::new(LatencyLink { base_s, per_bit_s })
            }
        }
    }

    /// Parse the compact spec syntax used by manifests and CLI flags:
    /// `ideal`, `erasure:<p>`, `latency:<base_s>,<per_bit_s>`.
    pub fn parse(s: &str) -> Result<LinkKind, String> {
        let s = s.trim();
        let (head, rest) = match s.split_once(':') {
            Some((h, r)) => (h.trim(), Some(r.trim())),
            None => (s, None),
        };
        let num = |v: &str, what: &str| -> Result<f64, String> {
            v.trim()
                .parse::<f64>()
                .map_err(|_| format!("link spec '{s}': bad {what} '{v}'"))
        };
        match (head, rest) {
            ("ideal", None) => Ok(LinkKind::Ideal),
            ("erasure", Some(p)) => {
                let p = num(p, "probability")?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("link spec '{s}': probability out of [0,1]"));
                }
                Ok(LinkKind::Erasure { p })
            }
            ("latency", Some(args)) => {
                let mut it = args.split(',');
                let base = num(it.next().unwrap_or(""), "base_s")?;
                let per_bit = num(it.next().ok_or_else(|| {
                    format!("link spec '{s}': expected latency:<base_s>,<per_bit_s>")
                })?, "per_bit_s")?;
                if it.next().is_some() {
                    return Err(format!("link spec '{s}': too many fields"));
                }
                Ok(LinkKind::Latency { base_s: base, per_bit_s: per_bit })
            }
            _ => Err(format!(
                "unknown link spec '{s}' (expected ideal | erasure:<p> | latency:<base_s>,<per_bit_s>)"
            )),
        }
    }

    /// Canonical label; `LinkKind::parse(kind.label())` round-trips.
    pub fn label(&self) -> String {
        match self {
            LinkKind::Ideal => "ideal".into(),
            LinkKind::Erasure { p } => format!("erasure:{p}"),
            LinkKind::Latency { base_s, per_bit_s } => format!("latency:{base_s},{per_bit_s}"),
        }
    }
}

/// The shared transmit path: §7 energy accounting + transmission log +
/// link-model fate + simulated wall clock, one instance per run.
pub struct Medium {
    log: CommLog,
    energy: EnergyModel,
    link: Box<dyn LinkModel>,
    /// Upload slot duration (each phase occupies at least one slot).
    slot_s: f64,
    /// Slowest broadcast of the slot in flight.
    slot_latency_s: f64,
    sim_time_s: f64,
}

impl Medium {
    pub fn new(energy: EnergyModel, slot_s: f64, link: Box<dyn LinkModel>) -> Medium {
        Medium {
            log: CommLog::default(),
            energy,
            link,
            slot_s,
            slot_latency_s: 0.0,
            sim_time_s: 0.0,
        }
    }

    /// One committed broadcast: charge energy, log it, and return whether
    /// the neighbors actually receive it (false = erasure; the caller
    /// rolls the sender's state back — perfect feedback).
    pub fn transmit(
        &mut self,
        worker: usize,
        iteration: u64,
        payload_bits: u64,
        distance_m: f64,
    ) -> bool {
        self.log.record(Transmission {
            worker,
            iteration,
            payload_bits,
            distance_m,
            energy_j: self.energy.energy_j(payload_bits, distance_m),
        });
        match self.link.fate(worker, iteration, payload_bits, distance_m) {
            Fate::Delivered { latency_s } => {
                self.slot_latency_s = self.slot_latency_s.max(latency_s);
                true
            }
            Fate::Dropped => {
                // the airtime is consumed even though nothing lands
                self.slot_latency_s = self.slot_latency_s.max(self.slot_s);
                false
            }
        }
    }

    /// Close one synchronous phase: the slot lasts at least `slot_s`, and
    /// longer when a latency model made a broadcast straggle.
    pub fn end_slot(&mut self) {
        self.sim_time_s += self.slot_latency_s.max(self.slot_s);
        self.slot_latency_s = 0.0;
    }

    /// Transmission log so far.
    pub fn log(&self) -> &CommLog {
        &self.log
    }

    /// Simulated wall-clock seconds spent on the air so far (slots ×
    /// phase count, stretched by link latency).
    pub fn sim_time_s(&self) -> f64 {
        self.sim_time_s
    }

    /// Durable link-model state (checkpointing).
    pub fn link_state(&self) -> LinkState {
        self.link.state()
    }

    /// Restore the medium at an iteration boundary: checkpointed totals,
    /// simulated clock, and the link model's RNG position.  The in-slot
    /// scratch (`slot_latency_s`) is always zero between phases.
    pub fn restore(
        &mut self,
        rounds: u64,
        total_bits: u64,
        total_energy_j: f64,
        sim_time_s: f64,
        link: &LinkState,
    ) {
        self.log.restore_totals(rounds, total_bits, total_energy_j);
        self.sim_time_s = sim_time_s;
        self.slot_latency_s = 0.0;
        self.link.restore(link);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::EnergyParams;

    fn medium(kind: LinkKind) -> Medium {
        let params = EnergyParams::default();
        Medium::new(
            EnergyModel::new(params, 8, 0.5),
            params.slot_s,
            kind.build(Pcg64::new(3)),
        )
    }

    #[test]
    fn ideal_always_delivers_and_charges() {
        let mut m = medium(LinkKind::Ideal);
        for k in 0..5 {
            assert!(m.transmit(0, k, 160, 100.0));
        }
        m.end_slot();
        assert_eq!(m.log().rounds(), 5);
        assert_eq!(m.log().total_bits, 800);
        assert!(m.log().total_energy_j > 0.0);
        assert!((m.sim_time_s() - EnergyParams::default().slot_s).abs() < 1e-15);
    }

    #[test]
    fn erasure_rate_roughly_p_and_always_charges() {
        let mut m = medium(LinkKind::Erasure { p: 0.3 });
        let trials: u64 = 2000;
        let delivered = (0..trials).filter(|&k| m.transmit(0, k, 160, 100.0)).count();
        // every attempt is logged regardless of fate
        assert_eq!(m.log().rounds(), trials);
        let rate = 1.0 - delivered as f64 / trials as f64;
        assert!((rate - 0.3).abs() < 0.05, "erasure rate {rate}");
    }

    #[test]
    fn latency_stretches_the_slot() {
        let mut m = medium(LinkKind::Latency { base_s: 0.5, per_bit_s: 0.0 });
        assert!(m.transmit(0, 0, 160, 100.0));
        m.end_slot();
        assert!(m.sim_time_s() >= 0.5, "straggler must stretch the slot");
        // an empty (fully censored) phase still occupies one slot
        m.end_slot();
        assert!((m.sim_time_s() - (0.5 + 1e-3)).abs() < 1e-9);
    }

    #[test]
    fn latency_grows_with_bits_and_distance() {
        let mut l = LatencyLink { base_s: 0.0, per_bit_s: 1e-6 };
        let short = match l.fate(0, 0, 100, 10.0) {
            Fate::Delivered { latency_s } => latency_s,
            Fate::Dropped => unreachable!(),
        };
        let long = match l.fate(0, 0, 10_000, 10.0) {
            Fate::Delivered { latency_s } => latency_s,
            Fate::Dropped => unreachable!(),
        };
        assert!(long > short);
    }

    #[test]
    fn resolve_prefers_explicit_kind() {
        assert_eq!(LinkKind::resolve(None, 0.0), LinkKind::Ideal);
        assert_eq!(LinkKind::resolve(None, 0.2), LinkKind::Erasure { p: 0.2 });
        assert_eq!(
            LinkKind::resolve(Some(LinkKind::Ideal), 0.2),
            LinkKind::Ideal
        );
    }
}
