//! Wireless transmit-energy model (paper §7 "Communication Energy").
//!
//! * Total system bandwidth 2 MHz, equally divided across the workers that
//!   transmit in a slot: GGADMM-family schedules transmit only half the
//!   workers per slot, so each gets `4/N` MHz; Jacobian C-ADMM transmits
//!   all workers, so each gets `2/N` MHz.
//! * Power spectral density `N0 = 1e-6` W/Hz, upload slot `tau = 1 ms`.
//! * A worker must deliver its payload within one slot over its worst
//!   (bottleneck) link of distance `D`, i.e. at rate `R = bits / tau`.
//!   Free-space Shannon capacity then prices the transmit power as
//!   `P = tau * D^2 * N0 * B * (2^{R/B} - 1)` and the energy as `E = P tau`
//!   (the paper's exact formula).
//!
//! The distances come from the topology's worker placement (uniform in a
//! 500 m square by default; the paper does not specify its deployment —
//! see DESIGN.md §Substitutions).

/// Scenario parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyParams {
    /// Total system bandwidth in Hz (paper: 2 MHz).
    pub total_bandwidth_hz: f64,
    /// Noise power spectral density in W/Hz (paper: 1e-6).
    pub n0_w_per_hz: f64,
    /// Upload slot duration in seconds (paper: 1 ms).
    pub slot_s: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        EnergyParams {
            total_bandwidth_hz: 2e6,
            n0_w_per_hz: 1e-6,
            slot_s: 1e-3,
        }
    }
}

/// Energy model bound to a worker count + schedule concurrency.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    params: EnergyParams,
    /// Per-worker bandwidth share in Hz for this schedule.
    pub bandwidth_hz: f64,
}

impl EnergyModel {
    /// `concurrent_fraction` is the fraction of workers transmitting in a
    /// slot: 0.5 for alternating GGADMM schedules (=> 4/N MHz each),
    /// 1.0 for Jacobian C-ADMM (=> 2/N MHz each).
    ///
    /// The transmitter count is clamped to >= 1: a fraction small enough
    /// to round the count to zero would otherwise hand one worker an
    /// infinite bandwidth share and poison every downstream energy total.
    pub fn new(params: EnergyParams, n_workers: usize, concurrent_fraction: f64) -> EnergyModel {
        assert!(n_workers >= 1);
        assert!(concurrent_fraction > 0.0 && concurrent_fraction <= 1.0);
        let transmitters = (n_workers as f64 * concurrent_fraction).max(1.0);
        EnergyModel {
            params,
            bandwidth_hz: params.total_bandwidth_hz / transmitters,
        }
    }

    /// Required data rate to push `bits` through one slot.
    pub fn rate_bps(&self, bits: u64) -> f64 {
        bits as f64 / self.params.slot_s
    }

    /// Saturation ceiling for one transmission's power in watts.  Far
    /// beyond any physical scenario, yet small enough that cumulative
    /// sums over arbitrarily many saturated transmissions stay finite
    /// (`f64` overflows only past ~1.8e308).
    pub const SATURATION_W: f64 = 1e30;

    /// Transmit power for `bits` over a bottleneck link of `distance_m`.
    ///
    /// Total: the Shannon term `2^{R/B} - 1` overflows `f64` once
    /// `R/B > 1024` (large payloads over a thin bandwidth share), which
    /// used to return `inf` — and `NaN` at `distance_m == 0` (the
    /// `0 * inf` limit).  Both degenerate corners now resolve to their
    /// physical limits: zero-length links and empty payloads cost
    /// nothing, and an overflowing power saturates at
    /// [`EnergyModel::SATURATION_W`] so per-run cumulative energy
    /// accounting stays finite (ordering is non-strict once saturated).
    pub fn power_w(&self, bits: u64, distance_m: f64) -> f64 {
        if bits == 0 || distance_m <= 0.0 {
            return 0.0;
        }
        let b = self.bandwidth_hz;
        let r = self.rate_bps(bits);
        let gain = self.params.slot_s * distance_m * distance_m * self.params.n0_w_per_hz * b;
        // gain is finite > 0 and snr >= 0, so the product is never NaN;
        // min() turns an overflowed inf into the finite ceiling
        let snr = (2f64).powf(r / b) - 1.0;
        (gain * snr).min(Self::SATURATION_W)
    }

    /// Energy of one transmission: `E = P * tau` (finite for every
    /// `bits`/`distance_m`, see [`EnergyModel::power_w`]).
    pub fn energy_j(&self, bits: u64, distance_m: f64) -> f64 {
        self.power_w(bits, distance_m) * self.params.slot_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    #[test]
    fn bandwidth_shares_match_paper() {
        let p = EnergyParams::default();
        // GGADMM with N=24: 4/N MHz each
        let g = EnergyModel::new(p, 24, 0.5);
        assert!((g.bandwidth_hz - 4e6 / 24.0).abs() < 1e-6);
        // C-ADMM: 2/N MHz each
        let c = EnergyModel::new(p, 24, 1.0);
        assert!((c.bandwidth_hz - 2e6 / 24.0).abs() < 1e-6);
    }

    #[test]
    fn energy_monotone_in_bits_and_distance() {
        check("energy monotonicity", 60, |g| {
            let n = g.usize_in(2, 32);
            let m = EnergyModel::new(EnergyParams::default(), n, 0.5);
            let bits = g.usize_in(10, 100_000) as u64;
            let dist = g.f64_in(1.0, 700.0);
            let e = m.energy_j(bits, dist);
            assert!(e > 0.0 && e.is_finite());
            assert!(m.energy_j(bits + 1000, dist) > e);
            assert!(m.energy_j(bits, dist + 50.0) > e);
        });
    }

    #[test]
    fn energy_finite_for_every_payload_and_distance() {
        // regression: bits up to the full-precision payload 32*d of a
        // large model over a thin bandwidth share used to overflow
        // `2^{R/B}` to inf (and to NaN at distance 0)
        check("energy_j finite for bits in 0..=32d, distance >= 0", 80, |g| {
            let n = g.usize_in(1, 64);
            let frac = g.f64_in(0.01, 1.0);
            let m = EnergyModel::new(EnergyParams::default(), n, frac);
            assert!(m.bandwidth_hz.is_finite() && m.bandwidth_hz > 0.0);
            let d = g.usize_in(1, 20_000);
            let bits = g.usize_in(0, 32 * d) as u64;
            let dist = if g.bool(0.25) { 0.0 } else { g.f64_in(0.0, 700.0) };
            let p = m.power_w(bits, dist);
            let e = m.energy_j(bits, dist);
            assert!(p.is_finite() && p >= 0.0, "power {p} bits={bits} dist={dist}");
            assert!(e.is_finite() && e >= 0.0, "energy {e} bits={bits} dist={dist}");
        });
    }

    #[test]
    fn degenerate_corners_have_physical_limits() {
        let m = EnergyModel::new(EnergyParams::default(), 24, 0.5);
        // empty payloads and zero-length links cost nothing
        assert_eq!(m.energy_j(0, 300.0), 0.0);
        assert_eq!(m.energy_j(32 * 100_000, 0.0), 0.0);
        // an overflowing SNR saturates finite instead of going inf,
        // and stays ordered above any representable payload
        let huge = m.energy_j(32 * 100_000, 300.0);
        assert!(huge.is_finite());
        assert!(huge > m.energy_j(32 * 50, 300.0));
        // a tiny concurrent fraction clamps to one transmitter
        let tiny = EnergyModel::new(EnergyParams::default(), 1, 0.01);
        assert!((tiny.bandwidth_hz - EnergyParams::default().total_bandwidth_hz).abs() < 1e-9);
    }

    #[test]
    fn degree_zero_worker_accounting_stays_finite() {
        // regression (dynamic networks): after a churn departure a worker
        // can be left with an empty neighbor set.  Its bottleneck
        // distance folds over nothing (0.0) and the engines skip its
        // broadcast entirely — but if anything ever does price such a
        // transmission, every quantity must stay finite and the
        // zero-length link must cost nothing.
        for n in [1usize, 2, 64] {
            for frac in [0.5, 1.0] {
                let m = EnergyModel::new(EnergyParams::default(), n, frac);
                let empty_bottleneck: f64 =
                    [].iter().copied().fold(0.0f64, f64::max);
                assert_eq!(empty_bottleneck, 0.0);
                for bits in [0u64, 64, 32 * 10_000] {
                    let e = m.energy_j(bits, empty_bottleneck);
                    assert!(e.is_finite());
                    assert_eq!(e, 0.0, "zero-length link must cost nothing");
                }
            }
        }
    }

    #[test]
    fn quantization_saves_orders_of_magnitude() {
        // the paper's headline: exponential rate-power tradeoff makes
        // 2-bit payloads orders of magnitude cheaper than 32-bit
        let m = EnergyModel::new(EnergyParams::default(), 24, 0.5);
        let d = 50;
        let full = m.energy_j(32 * d, 300.0);
        let quant = m.energy_j(2 * d + 64, 300.0);
        assert!(
            full / quant > 100.0,
            "expected >= 2 orders of magnitude, got {:.1}x",
            full / quant
        );
    }

    #[test]
    fn shannon_formula_hand_check() {
        let m = EnergyModel::new(
            EnergyParams { total_bandwidth_hz: 1e6, n0_w_per_hz: 1e-6, slot_s: 1e-3 },
            2,
            0.5,
        );
        // B = 1 MHz, bits = 1000 -> R = 1e6 bps -> R/B = 1 -> 2^1 - 1 = 1
        // P = tau D^2 N0 B * 1 = 1e-3 * 1e4 * 1e-6 * 1e6 = 10
        let p = m.power_w(1000, 100.0);
        assert!((p - 10.0).abs() < 1e-9, "p={p}");
        assert!((m.energy_j(1000, 100.0) - 0.01).abs() < 1e-12);
    }
}
