//! Network topology: generation, bipartite grouping, incidence matrices
//! and the spectral quantities of the paper's rate analysis.
//!
//! The paper (Assumption 1) works over **bipartite and connected**
//! communication graphs; workers are split into a head group `H` and a
//! tail group `T`, and every edge crosses the groups.  [`Topology`] owns
//! the edge set, the grouping and worker positions (for the free-space
//! energy model of §7), and exposes the matrices `A`, `D`, `C`, `M_-`,
//! `M_+` used in Appendix D.  [`gen`] grows the family zoo beyond the
//! seed's chain / random-bipartite shapes: ring, star, grid/torus,
//! Erdős–Rényi, small-world and random-geometric generators, all routed
//! through a bipartition pass that makes any connected graph a valid
//! head/tail instance.

pub mod churn;
pub mod gen;
pub mod spectral;

pub use churn::{ChurnEvent, ChurnKind, ChurnSchedule};

use crate::util::rng::Pcg64;

/// Worker group (paper's H / T).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Group {
    Head,
    Tail,
}

/// A bipartite, connected communication topology over `n` workers.
#[derive(Clone, Debug)]
pub struct Topology {
    n: usize,
    /// Edges as (head, tail) pairs, deduplicated, head in H, tail in T.
    edges: Vec<(usize, usize)>,
    /// Group of each worker.
    groups: Vec<Group>,
    /// Sorted neighbor lists.
    neighbors: Vec<Vec<usize>>,
    /// Worker coordinates in meters (for the energy model).
    positions: Vec<(f64, f64)>,
}

impl Topology {
    /// Build from an explicit bipartite edge list + grouping.
    /// Panics if an edge does not cross the groups or the graph is
    /// disconnected (use [`Topology::try_new`] for fallible construction).
    pub fn new(n: usize, edges: Vec<(usize, usize)>, groups: Vec<Group>) -> Topology {
        Self::try_new(n, edges, groups).expect("invalid topology")
    }

    /// Fallible constructor with validation.
    pub fn try_new(
        n: usize,
        raw_edges: Vec<(usize, usize)>,
        groups: Vec<Group>,
    ) -> Result<Topology, String> {
        if groups.len() != n {
            return Err(format!("groups length {} != n {}", groups.len(), n));
        }
        let mut edges = Vec::with_capacity(raw_edges.len());
        let mut seen = std::collections::BTreeSet::new();
        for (a, b) in raw_edges {
            if a >= n || b >= n || a == b {
                return Err(format!("bad edge ({a}, {b})"));
            }
            let (h, t) = match (groups[a], groups[b]) {
                (Group::Head, Group::Tail) => (a, b),
                (Group::Tail, Group::Head) => (b, a),
                _ => {
                    return Err(format!(
                        "edge ({a}, {b}) does not cross head/tail groups"
                    ))
                }
            };
            if seen.insert((h, t)) {
                edges.push((h, t));
            }
        }
        let mut neighbors = vec![Vec::new(); n];
        for &(h, t) in &edges {
            neighbors[h].push(t);
            neighbors[t].push(h);
        }
        for nbrs in &mut neighbors {
            nbrs.sort_unstable();
        }
        let topo = Topology {
            n,
            edges,
            groups,
            neighbors,
            positions: default_positions(n),
        };
        if !topo.is_connected() {
            return Err("graph is not connected".into());
        }
        Ok(topo)
    }

    /// Chain topology of the original GADMM: 0-1-2-...-(n-1), workers at
    /// even positions are heads (paper Fig. 1(a)).
    pub fn chain(n: usize) -> Topology {
        assert!(n >= 2, "chain needs >= 2 workers");
        let groups: Vec<Group> = (0..n)
            .map(|i| if i % 2 == 0 { Group::Head } else { Group::Tail })
            .collect();
        let edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
        Topology::new(n, edges, groups)
    }

    /// Random connected bipartite graph with connectivity ratio `p`
    /// (paper §7 "Graph Generation"): targets `p * n(n-1)/2` edges chosen
    /// uniformly among head-tail pairs after a random balanced grouping,
    /// seeded with a spanning tree so the graph is always connected.
    pub fn random_bipartite(n: usize, p: f64, seed: u64) -> Topology {
        assert!(n >= 2);
        assert!((0.0..=1.0).contains(&p));
        let mut rng = Pcg64::new(seed ^ 0x9E37_79B9_7F4A_7C15);
        // balanced random grouping
        let mut ids: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut ids);
        let mut groups = vec![Group::Tail; n];
        for &w in ids.iter().take(n / 2) {
            groups[w] = Group::Head;
        }
        let heads: Vec<usize> = (0..n).filter(|&i| groups[i] == Group::Head).collect();
        let tails: Vec<usize> = (0..n).filter(|&i| groups[i] == Group::Tail).collect();

        // spanning tree over the bipartition: connect every node to a random
        // already-connected node of the opposite group (alternating growth).
        let mut edges: Vec<(usize, usize)> = Vec::new();
        let mut connected_h: Vec<usize> = vec![heads[0]];
        let mut connected_t: Vec<usize> = Vec::new();
        let mut pending_h: Vec<usize> = heads[1..].to_vec();
        let mut pending_t: Vec<usize> = tails.clone();
        rng.shuffle(&mut pending_h);
        rng.shuffle(&mut pending_t);
        while !pending_h.is_empty() || !pending_t.is_empty() {
            // prefer attaching a tail if any head is connected, else a head
            let attach_tail = !pending_t.is_empty()
                && (pending_h.is_empty() || rng.bernoulli(0.5) || connected_t.is_empty());
            if attach_tail {
                let t = pending_t.pop().unwrap();
                let h = connected_h[rng.below(connected_h.len() as u64) as usize];
                edges.push((h, t));
                connected_t.push(t);
            } else {
                let h = pending_h.pop().unwrap();
                let t = connected_t[rng.below(connected_t.len() as u64) as usize];
                edges.push((h, t));
                connected_h.push(h);
            }
        }

        // fill with random extra head-tail edges up to the target count
        let target = ((p * (n * (n - 1)) as f64 / 2.0).round() as usize)
            .max(edges.len())
            .min(heads.len() * tails.len());
        let mut all_pairs: Vec<(usize, usize)> = Vec::new();
        let existing: std::collections::BTreeSet<(usize, usize)> =
            edges.iter().cloned().collect();
        for &h in &heads {
            for &t in &tails {
                if !existing.contains(&(h, t)) {
                    all_pairs.push((h, t));
                }
            }
        }
        rng.shuffle(&mut all_pairs);
        for pair in all_pairs {
            if edges.len() >= target {
                break;
            }
            edges.push(pair);
        }

        let mut topo = Topology::new(n, edges, groups);
        topo.positions = random_positions(n, 500.0, &mut rng);
        topo
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Edge list as (head, tail) pairs.
    pub fn edges(&self) -> &[(usize, usize)] {
        &self.edges
    }

    /// Group of worker `i`.
    pub fn group(&self, i: usize) -> Group {
        self.groups[i]
    }

    /// Worker ids in the head group.
    pub fn heads(&self) -> Vec<usize> {
        (0..self.n).filter(|&i| self.groups[i] == Group::Head).collect()
    }

    /// Worker ids in the tail group.
    pub fn tails(&self) -> Vec<usize> {
        (0..self.n).filter(|&i| self.groups[i] == Group::Tail).collect()
    }

    /// Neighbors of worker `i` (sorted).
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.neighbors[i]
    }

    /// Degree of worker `i` (the paper's `d_n`).
    pub fn degree(&self, i: usize) -> usize {
        self.neighbors[i].len()
    }

    /// Worker position in meters.
    pub fn position(&self, i: usize) -> (f64, f64) {
        self.positions[i]
    }

    /// Override worker positions (tests / custom deployments).
    pub fn set_positions(&mut self, pos: Vec<(f64, f64)>) {
        assert_eq!(pos.len(), self.n);
        self.positions = pos;
    }

    /// Euclidean distance between two workers in meters.
    pub fn distance(&self, a: usize, b: usize) -> f64 {
        let (xa, ya) = self.positions[a];
        let (xb, yb) = self.positions[b];
        ((xa - xb).powi(2) + (ya - yb).powi(2)).sqrt()
    }

    /// Max distance from `i` to any of its neighbors — the broadcast
    /// bottleneck link of the energy model.
    pub fn max_neighbor_distance(&self, i: usize) -> f64 {
        self.neighbors[i]
            .iter()
            .map(|&m| self.distance(i, m))
            .fold(0.0, f64::max)
    }

    /// Actual connectivity ratio |E| / (n(n-1)/2).
    pub fn connectivity_ratio(&self) -> f64 {
        self.edges.len() as f64 / (self.n * (self.n - 1)) as f64 * 2.0
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut queue = std::collections::VecDeque::from([0usize]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for &v in &self.neighbors[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == self.n
    }

    /// Verify every edge crosses groups (used by property tests).
    pub fn is_bipartite_consistent(&self) -> bool {
        self.edges
            .iter()
            .all(|&(h, t)| self.groups[h] == Group::Head && self.groups[t] == Group::Tail)
    }
}

fn default_positions(n: usize) -> Vec<(f64, f64)> {
    // deterministic ring layout, 250 m radius — overridden by generators
    (0..n)
        .map(|i| {
            let a = 2.0 * std::f64::consts::PI * i as f64 / n as f64;
            (250.0 + 250.0 * a.cos(), 250.0 + 250.0 * a.sin())
        })
        .collect()
}

fn random_positions(n: usize, side: f64, rng: &mut Pcg64) -> Vec<(f64, f64)> {
    (0..n)
        .map(|_| (rng.uniform_in(0.0, side), rng.uniform_in(0.0, side)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    #[test]
    fn chain_structure() {
        let t = Topology::chain(5);
        assert_eq!(t.n(), 5);
        assert_eq!(t.edges().len(), 4);
        assert!(t.is_connected());
        assert!(t.is_bipartite_consistent());
        assert_eq!(t.group(0), Group::Head);
        assert_eq!(t.group(1), Group::Tail);
        assert_eq!(t.neighbors(2), &[1, 3]);
        assert_eq!(t.degree(0), 1);
    }

    #[test]
    fn random_graphs_connected_and_bipartite() {
        check("random bipartite topology invariants", 60, |g| {
            let n = g.usize_in(2, 32);
            let p = g.f64_in(0.05, 1.0);
            let seed = g.u64();
            let t = Topology::random_bipartite(n, p, seed);
            assert!(t.is_connected(), "disconnected n={n} p={p}");
            assert!(t.is_bipartite_consistent());
            assert_eq!(t.heads().len() + t.tails().len(), n);
            assert!(!t.heads().is_empty());
            assert!(!t.tails().is_empty());
            // every worker participates (connected => degree >= 1)
            for i in 0..n {
                assert!(t.degree(i) >= 1);
            }
        });
    }

    #[test]
    fn density_tracks_p() {
        let sparse = Topology::random_bipartite(18, 0.2, 3);
        let dense = Topology::random_bipartite(18, 0.4, 3);
        assert!(dense.edges().len() > sparse.edges().len());
    }

    #[test]
    fn determinism_per_seed() {
        let a = Topology::random_bipartite(12, 0.3, 9);
        let b = Topology::random_bipartite(12, 0.3, 9);
        assert_eq!(a.edges(), b.edges());
        let c = Topology::random_bipartite(12, 0.3, 10);
        assert_ne!(a.edges(), c.edges());
    }

    #[test]
    fn rejects_non_crossing_edge() {
        let groups = vec![Group::Head, Group::Head, Group::Tail];
        let err = Topology::try_new(3, vec![(0, 1)], groups).unwrap_err();
        assert!(err.contains("does not cross"));
    }

    #[test]
    fn rejects_disconnected() {
        let groups = vec![Group::Head, Group::Tail, Group::Head, Group::Tail];
        let err = Topology::try_new(4, vec![(0, 1), (2, 3)], groups).unwrap_err();
        assert!(err.contains("not connected"));
    }

    #[test]
    fn distances_symmetric_positive() {
        let t = Topology::random_bipartite(10, 0.5, 1);
        for &(h, tl) in t.edges() {
            assert!((t.distance(h, tl) - t.distance(tl, h)).abs() < 1e-12);
            assert!(t.distance(h, tl) > 0.0);
        }
        for i in 0..10 {
            assert!(t.max_neighbor_distance(i) > 0.0);
        }
    }

    #[test]
    fn dedup_edges() {
        let groups = vec![Group::Head, Group::Tail];
        let t = Topology::try_new(2, vec![(0, 1), (1, 0), (0, 1)], groups).unwrap();
        assert_eq!(t.edges().len(), 1);
    }
}
