//! Deterministic worker-churn schedules (dynamic networks).
//!
//! A [`ChurnSchedule`] is a sorted list of join/leave events applied by
//! both engines at the **start** of the iteration they name: a leaving
//! worker is detached from every surviving neighbor (and frozen in
//! place), a rejoining worker warm-starts from the current
//! group-consensus iterate and re-attaches its edges.  Schedules are
//! plain data — explicitly constructed, parsed from the compact
//! `<at>:<kind>:<worker>` syntax, or generated from a seed — so an
//! identical schedule drives bit-identical runs on both engines and
//! replays exactly across checkpoint/resume.

use crate::util::rng::Pcg64;

/// What happens to a worker at a scheduled iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnKind {
    /// The worker departs: neighbors drop it, its state freezes.
    Leave,
    /// The worker returns: warm start + edge re-attachment.
    Join,
}

impl ChurnKind {
    pub fn label(self) -> &'static str {
        match self {
            ChurnKind::Leave => "leave",
            ChurnKind::Join => "join",
        }
    }
}

/// One scheduled membership change.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChurnEvent {
    /// Iteration (0-based) at whose start the event applies.
    pub at: u64,
    pub worker: usize,
    pub kind: ChurnKind,
}

/// A validated, sorted churn schedule.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct ChurnSchedule {
    /// Sorted by `(at, worker)`; per worker the kinds alternate starting
    /// with [`ChurnKind::Leave`] (everyone starts present).
    events: Vec<ChurnEvent>,
}

impl ChurnSchedule {
    /// Build from explicit events (any order; sorted internally).
    ///
    /// Validation: at most one event per worker per iteration, and per
    /// worker the kinds must alternate starting with a leave — every
    /// worker is present at iteration 0, may only leave while present
    /// and only join while absent.
    pub fn try_new(mut events: Vec<ChurnEvent>) -> Result<ChurnSchedule, String> {
        events.sort_by_key(|e| (e.at, e.worker));
        for w in events.windows(2) {
            if w[0].at == w[1].at && w[0].worker == w[1].worker {
                return Err(format!(
                    "worker {} has two churn events at iteration {}",
                    w[0].worker, w[0].at
                ));
            }
        }
        let max_worker = events.iter().map(|e| e.worker).max().unwrap_or(0);
        let mut present = vec![true; max_worker + 1];
        for e in &events {
            match e.kind {
                ChurnKind::Leave if !present[e.worker] => {
                    return Err(format!(
                        "worker {} leaves at iteration {} while absent",
                        e.worker, e.at
                    ));
                }
                ChurnKind::Join if present[e.worker] => {
                    return Err(format!(
                        "worker {} joins at iteration {} while present",
                        e.worker, e.at
                    ));
                }
                _ => present[e.worker] = e.kind == ChurnKind::Join,
            }
        }
        Ok(ChurnSchedule { events })
    }

    /// All events, sorted by `(at, worker)`.
    pub fn events(&self) -> &[ChurnEvent] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events applying at the start of iteration `at`, in worker order.
    pub fn events_at(&self, at: u64) -> &[ChurnEvent] {
        let lo = self.events.partition_point(|e| e.at < at);
        let hi = self.events.partition_point(|e| e.at <= at);
        &self.events[lo..hi]
    }

    /// Largest worker id named by the schedule (`None` when empty); the
    /// engines check it against the topology size.
    pub fn max_worker(&self) -> Option<usize> {
        self.events.iter().map(|e| e.worker).max()
    }

    /// Seeded generator: `ceil(rate * n)` distinct workers each get one
    /// leave + rejoin cycle inside `(0, iters)`.  Leaves land in the
    /// first half of the run and every absence spans at least one
    /// iteration, so the schedule exercises detach, absent rounds and
    /// warm-started rejoin.  Pure in `(n, iters, rate, seed)`.
    pub fn generate(n: usize, iters: u64, rate: f64, seed: u64) -> ChurnSchedule {
        assert!(n >= 1);
        assert!((0.0..=1.0).contains(&rate), "churn rate out of [0,1]");
        if rate == 0.0 || iters < 3 {
            return ChurnSchedule::default();
        }
        let k = ((rate * n as f64).ceil() as usize).min(n);
        let mut rng = Pcg64::new(seed ^ 0xC4A2_0005);
        let mut chosen = rng.sample_indices(n, k);
        chosen.sort_unstable();
        let mut events = Vec::with_capacity(2 * k);
        for w in chosen {
            // leave in [1, iters/2], rejoin in (leave, iters)
            let leave = 1 + rng.below((iters / 2).max(1)) as u64;
            let span = iters - leave - 1;
            let join = leave + 1 + rng.below(span.max(1)) as u64;
            debug_assert!(join < iters);
            events.push(ChurnEvent { at: leave, worker: w, kind: ChurnKind::Leave });
            events.push(ChurnEvent { at: join, worker: w, kind: ChurnKind::Join });
        }
        ChurnSchedule::try_new(events).expect("generated schedule must validate")
    }

    /// Parse the compact syntax: space-separated `<at>:<kind>:<worker>`
    /// tokens, e.g. `"10:leave:5 20:join:5"`.  The empty string is the
    /// empty schedule.
    pub fn parse(s: &str) -> Result<ChurnSchedule, String> {
        let mut events = Vec::new();
        for tok in s.split_whitespace() {
            let mut it = tok.split(':');
            let (at, kind, worker) = match (it.next(), it.next(), it.next(), it.next()) {
                (Some(a), Some(k), Some(w), None) => (a, k, w),
                _ => {
                    return Err(format!(
                        "churn token '{tok}': expected <at>:<kind>:<worker>"
                    ))
                }
            };
            let at: u64 = at
                .parse()
                .map_err(|_| format!("churn token '{tok}': bad iteration '{at}'"))?;
            let kind = match kind {
                "leave" => ChurnKind::Leave,
                "join" => ChurnKind::Join,
                _ => {
                    return Err(format!(
                        "churn token '{tok}': kind must be leave|join"
                    ))
                }
            };
            let worker: usize = worker
                .parse()
                .map_err(|_| format!("churn token '{tok}': bad worker '{worker}'"))?;
            events.push(ChurnEvent { at, worker, kind });
        }
        ChurnSchedule::try_new(events)
    }

    /// Canonical label; `ChurnSchedule::parse(s.label())` round-trips.
    pub fn label(&self) -> String {
        self.events
            .iter()
            .map(|e| format!("{}:{}:{}", e.at, e.kind.label(), e.worker))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_label_round_trip() {
        let s = ChurnSchedule::parse("10:leave:5 20:join:5 3:leave:1").unwrap();
        assert_eq!(s.events().len(), 3);
        // sorted by (at, worker)
        assert_eq!(s.events()[0], ChurnEvent { at: 3, worker: 1, kind: ChurnKind::Leave });
        assert_eq!(ChurnSchedule::parse(&s.label()).unwrap(), s);
        assert_eq!(ChurnSchedule::parse("").unwrap(), ChurnSchedule::default());
    }

    #[test]
    fn rejects_invalid_sequences() {
        // join while present
        assert!(ChurnSchedule::parse("5:join:0").is_err());
        // double leave
        assert!(ChurnSchedule::parse("5:leave:0 9:leave:0").is_err());
        // two events for one worker at one iteration
        assert!(ChurnSchedule::parse("5:leave:0 5:join:0").is_err());
        // malformed tokens
        assert!(ChurnSchedule::parse("5:leave").is_err());
        assert!(ChurnSchedule::parse("5:vanish:0").is_err());
        assert!(ChurnSchedule::parse("x:leave:0").is_err());
        assert!(ChurnSchedule::parse("5:leave:0:9").is_err());
    }

    #[test]
    fn events_at_slices_by_iteration() {
        let s = ChurnSchedule::parse("2:leave:3 2:leave:7 4:join:3").unwrap();
        assert_eq!(s.events_at(2).len(), 2);
        assert_eq!(s.events_at(2)[0].worker, 3, "worker order within an iteration");
        assert_eq!(s.events_at(3).len(), 0);
        assert_eq!(s.events_at(4).len(), 1);
        assert_eq!(s.max_worker(), Some(7));
    }

    #[test]
    fn generate_is_deterministic_and_valid() {
        let a = ChurnSchedule::generate(32, 40, 0.25, 9);
        let b = ChurnSchedule::generate(32, 40, 0.25, 9);
        assert_eq!(a, b);
        assert_ne!(a, ChurnSchedule::generate(32, 40, 0.25, 10));
        // ceil(0.25 * 32) = 8 workers, each with a leave + rejoin cycle
        let leaves = a.events().iter().filter(|e| e.kind == ChurnKind::Leave).count();
        let joins = a.events().iter().filter(|e| e.kind == ChurnKind::Join).count();
        assert_eq!(leaves, 8);
        assert_eq!(joins, 8);
        for e in a.events() {
            assert!(e.at >= 1 && e.at < 40);
            assert!(e.worker < 32);
        }
        assert!(ChurnSchedule::generate(32, 40, 0.0, 9).is_empty());
    }
}
