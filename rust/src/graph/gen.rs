//! Seeded topology generators + the bipartition pass.
//!
//! The "G" of CQ-GGADMM is *generalized* topologies: the algorithm runs
//! on any **bipartite and connected** graph (Assumption 1).  This module
//! grows the repo beyond the seed's two shapes (chain,
//! [`Topology::random_bipartite`]) with the deterministic families the
//! GADMM literature compares against — ring, star, 2D grid/torus,
//! Erdős–Rényi, Watts–Strogatz small-world and random-geometric graphs —
//! and a [`bipartition`] pass that turns **any connected graph** into a
//! valid head/tail instance:
//!
//! * when the graph is 2-colorable, an exact BFS coloring keeps every
//!   edge (`dropped_edges == 0`, `exact == true`);
//! * otherwise a greedy max-cut grouping (local-search flips seeded from
//!   the BFS parity coloring) keeps only cross-group edges, repairs
//!   connectivity by flipping endpoints of dropped bridge edges, and
//!   reports how many same-group edges were dropped.  If the bounded
//!   repair cannot reconnect the cut, the pass falls back to the plain
//!   BFS parity coloring, whose kept edges contain the BFS spanning tree
//!   — so the result is *always* connected.
//!
//! Every family places workers in the 500 m deployment square of §7
//! (lines, circles, lattices, or uniform droppings), so the
//! [`crate::comm::EnergyModel`] link distances are physically meaningful
//! — for random-geometric graphs the link lengths *are* the connection
//! radius.  Construction is deterministic per `(spec, n, seed)`.

use super::{Group, Topology};
use crate::config::TopologySpec;
use crate::util::rng::Pcg64;
use std::collections::BTreeSet;

/// Side of the deployment square in meters (matches the paper-§7 random
/// placement used by [`Topology::random_bipartite`]).
pub const DEPLOY_SIDE_M: f64 = 500.0;

/// An undirected graph before head/tail grouping: what the family
/// generators emit and [`bipartition`] consumes.
#[derive(Clone, Debug)]
pub struct RawGraph {
    pub n: usize,
    /// Undirected edges in arbitrary order (deduplicated canonically by
    /// the bipartition pass).
    pub edges: Vec<(usize, usize)>,
    /// Worker coordinates in meters.
    pub positions: Vec<(f64, f64)>,
}

/// A bipartitioned, connected topology plus the pass's report.
#[derive(Clone, Debug)]
pub struct BuiltTopology {
    pub topology: Topology,
    /// Same-group edges removed by the max-cut grouping (0 when exact).
    pub dropped_edges: usize,
    /// `true` when the input was 2-colorable and every edge was kept.
    pub exact: bool,
}

/// Build a topology family from its spec, deterministically per seed.
pub fn build(spec: &TopologySpec, n: usize, seed: u64) -> Result<BuiltTopology, String> {
    if n < 2 {
        return Err(format!("topology needs >= 2 workers, got {n}"));
    }
    spec.validate()?;
    match *spec {
        TopologySpec::RandomBipartite { p } => Ok(BuiltTopology {
            topology: Topology::random_bipartite(n, p, seed),
            dropped_edges: 0,
            exact: true,
        }),
        TopologySpec::Chain => bipartition(chain(n)),
        TopologySpec::Ring => bipartition(ring(n)),
        TopologySpec::Star => bipartition(star(n)),
        TopologySpec::Grid { torus } => bipartition(grid(n, torus)),
        TopologySpec::ErdosRenyi { p } => {
            let mut rng = Pcg64::new(seed ^ 0x5EED_E2D0_5EED_E2D0);
            bipartition(erdos_renyi(n, p, &mut rng))
        }
        TopologySpec::SmallWorld { k, beta } => {
            let mut rng = Pcg64::new(seed ^ 0x5EED_5311_1D0A_11D0);
            bipartition(small_world(n, k, beta, &mut rng))
        }
        TopologySpec::Geometric { radius_m } => {
            let mut rng = Pcg64::new(seed ^ 0x5EED_6E0E_0612_1C21);
            bipartition(geometric(n, radius_m, &mut rng))
        }
    }
}

// ---------------------------------------------------------------------------
// Family generators (raw graphs)
// ---------------------------------------------------------------------------

/// Path 0-1-...-(n-1) laid out on a line across the deployment square.
pub fn chain(n: usize) -> RawGraph {
    let edges = (0..n - 1).map(|i| (i, i + 1)).collect();
    let positions = (0..n)
        .map(|i| (DEPLOY_SIDE_M * (i as f64 + 0.5) / n as f64, DEPLOY_SIDE_M / 2.0))
        .collect();
    RawGraph { n, edges, positions }
}

/// Cycle 0-1-...-(n-1)-0 on a circle (bipartite iff `n` is even; odd
/// rings drop exactly one edge in the bipartition pass).
pub fn ring(n: usize) -> RawGraph {
    let edges = (0..n).map(|i| (i, (i + 1) % n)).collect();
    RawGraph { n, edges, positions: circle_positions(n) }
}

/// Hub-and-spoke around worker 0 (always bipartite: hub vs leaves).
pub fn star(n: usize) -> RawGraph {
    let edges = (1..n).map(|i| (0, i)).collect();
    let mut positions = circle_positions(n);
    positions[0] = (DEPLOY_SIDE_M / 2.0, DEPLOY_SIDE_M / 2.0);
    RawGraph { n, edges, positions }
}

/// Near-square `rows x cols` lattice with `rows * cols == n` (rows is
/// the largest divisor of `n` at most `sqrt(n)`; primes degenerate to a
/// 1 x n line).  `torus` adds wraparound links on every dimension of
/// extent > 2 (extent-2 wraps would duplicate existing links).  Plain
/// grids are bipartite (checkerboard); torus wraps over odd extents are
/// dropped by the max-cut pass.
pub fn grid(n: usize, torus: bool) -> RawGraph {
    let mut rows = (n as f64).sqrt().floor() as usize;
    while rows > 1 && n % rows != 0 {
        rows -= 1;
    }
    let rows = rows.max(1);
    let cols = n / rows;
    let idx = |r: usize, c: usize| r * cols + c;
    let mut edges = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                edges.push((idx(r, c), idx(r, c + 1)));
            }
            if r + 1 < rows {
                edges.push((idx(r, c), idx(r + 1, c)));
            }
        }
    }
    if torus {
        if cols > 2 {
            for r in 0..rows {
                edges.push((idx(r, cols - 1), idx(r, 0)));
            }
        }
        if rows > 2 {
            for c in 0..cols {
                edges.push((idx(rows - 1, c), idx(0, c)));
            }
        }
    }
    let positions = (0..n)
        .map(|i| {
            let (r, c) = (i / cols, i % cols);
            (
                DEPLOY_SIDE_M * (c as f64 + 0.5) / cols as f64,
                DEPLOY_SIDE_M * (r as f64 + 0.5) / rows as f64,
            )
        })
        .collect();
    RawGraph { n, edges, positions }
}

/// Erdős–Rényi G(n, p) over a random attachment tree (each node in a
/// shuffled order links to a uniform earlier node — *not* the uniform
/// spanning-tree distribution, just a connectivity guarantee at any
/// `p`), workers dropped uniformly in the deployment square.
pub fn erdos_renyi(n: usize, p: f64, rng: &mut Pcg64) -> RawGraph {
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let mut edges = Vec::new();
    // random attachment tree over the shuffled order
    for i in 1..n {
        let j = rng.below(i as u64) as usize;
        edges.push((perm[i], perm[j]));
    }
    for a in 0..n {
        for b in a + 1..n {
            if rng.bernoulli(p) {
                edges.push((a, b));
            }
        }
    }
    let positions = square_positions(n, rng);
    RawGraph { n, edges, positions }
}

/// Watts–Strogatz small world: ring lattice where every worker links to
/// its `k` nearest ring neighbors (`k/2` each side, clamped to the ring
/// size), then each lattice link is rewired to a uniform random endpoint
/// with probability `beta`.  Disconnected rewires are repaired by
/// re-linking components.
pub fn small_world(n: usize, k: usize, beta: f64, rng: &mut Pcg64) -> RawGraph {
    let half = (k / 2).min((n - 1) / 2).max(1);
    let mut kept: BTreeSet<(usize, usize)> = BTreeSet::new();
    for i in 0..n {
        for j in 1..=half {
            kept.insert(canonical(i, (i + j) % n));
        }
    }
    // rewire pass over the deterministic lattice order
    let lattice: Vec<(usize, usize)> = kept.iter().cloned().collect();
    for (a, b) in lattice {
        if !rng.bernoulli(beta) {
            continue;
        }
        // keep endpoint `a`, rewire `b` to a fresh uniform target
        let mut target = None;
        for _ in 0..2 * n {
            let t = rng.below(n as u64) as usize;
            if t != a && !kept.contains(&canonical(a, t)) {
                target = Some(t);
                break;
            }
        }
        if let Some(t) = target {
            kept.remove(&canonical(a, b));
            kept.insert(canonical(a, t));
        }
    }
    let mut edges: Vec<(usize, usize)> = kept.into_iter().collect();
    // rewiring can disconnect: re-link components deterministically
    loop {
        let comp = components(n, &edges);
        let ncomp = 1 + *comp.iter().max().unwrap();
        if ncomp == 1 {
            break;
        }
        let a = (0..n).find(|&v| comp[v] == 0).unwrap();
        let b = (0..n).find(|&v| comp[v] == 1).unwrap();
        edges.push(canonical(a, b));
    }
    RawGraph { n, edges, positions: circle_positions(n) }
}

/// Random geometric graph: workers uniform in the deployment square,
/// linked iff within `radius_m`.  While disconnected, the globally
/// closest cross-component pair is linked, so every repair edge is the
/// shortest physically possible one.
pub fn geometric(n: usize, radius_m: f64, rng: &mut Pcg64) -> RawGraph {
    let positions = square_positions(n, rng);
    let dist = |a: usize, b: usize| -> f64 {
        let (xa, ya) = positions[a];
        let (xb, yb) = positions[b];
        ((xa - xb).powi(2) + (ya - yb).powi(2)).sqrt()
    };
    let mut edges = Vec::new();
    for a in 0..n {
        for b in a + 1..n {
            if dist(a, b) <= radius_m {
                edges.push((a, b));
            }
        }
    }
    loop {
        let comp = components(n, &edges);
        let ncomp = 1 + *comp.iter().max().unwrap();
        if ncomp == 1 {
            break;
        }
        let mut best: Option<(f64, usize, usize)> = None;
        for a in 0..n {
            for b in a + 1..n {
                if comp[a] != comp[b] {
                    let d = dist(a, b);
                    if best.map_or(true, |(bd, _, _)| d < bd) {
                        best = Some((d, a, b));
                    }
                }
            }
        }
        let (_, a, b) = best.expect("disconnected graph has a cross-component pair");
        edges.push((a, b));
    }
    RawGraph { n, edges, positions }
}

// ---------------------------------------------------------------------------
// The bipartition pass
// ---------------------------------------------------------------------------

/// Turn any connected graph into a valid GGADMM head/tail instance (see
/// the module docs for the exact/greedy/fallback contract).
pub fn bipartition(raw: RawGraph) -> Result<BuiltTopology, String> {
    let n = raw.n;
    if n < 2 {
        return Err(format!("bipartition needs >= 2 workers, got {n}"));
    }
    if raw.positions.len() != n {
        return Err(format!("positions length {} != n {n}", raw.positions.len()));
    }
    let mut seen = BTreeSet::new();
    for &(a, b) in &raw.edges {
        if a >= n || b >= n || a == b {
            return Err(format!("bad edge ({a}, {b})"));
        }
        seen.insert(canonical(a, b));
    }
    let edges: Vec<(usize, usize)> = seen.into_iter().collect();
    let comp = components(n, &edges);
    if 1 + *comp.iter().max().unwrap() != 1 {
        return Err("bipartition input graph is not connected".into());
    }
    let mut adj = vec![Vec::new(); n];
    for &(a, b) in &edges {
        adj[a].push(b);
        adj[b].push(a);
    }

    // BFS parity coloring from worker 0.  Its kept (cross-parity) edges
    // always contain the BFS spanning tree, so this coloring is both the
    // exact answer for 2-colorable graphs and the connected fallback.
    let parity = bfs_parity(n, &adj);
    let odd = edges.iter().any(|&(a, b)| parity[a] == parity[b]);
    if !odd {
        let topology = assemble(n, &edges, &parity, raw.positions)?;
        return Ok(BuiltTopology { topology, dropped_edges: 0, exact: true });
    }

    // Greedy max-cut local search seeded from the parity coloring: flip
    // any worker with more same-group than cross-group neighbors.  Each
    // flip strictly grows the cut, so the sweep terminates.
    let mut color = parity.clone();
    for _pass in 0..n + 8 {
        let mut flipped = false;
        for v in 0..n {
            let same = adj[v].iter().filter(|&&u| color[u] == color[v]).count();
            if 2 * same > adj[v].len() {
                color[v] ^= 1;
                flipped = true;
            }
        }
        if !flipped {
            break;
        }
    }

    // Bounded connectivity repair: while the kept (cross-group) subgraph
    // is disconnected, the input's connectivity guarantees some dropped
    // edge bridges two kept-components — flipping one endpoint turns it
    // into a kept edge and merges them.
    for _ in 0..n {
        let kept = cross_edges(&edges, &color);
        let comp = components(n, &kept);
        if 1 + *comp.iter().max().unwrap() == 1 {
            break;
        }
        let bridge = edges
            .iter()
            .find(|&&(a, b)| color[a] == color[b] && comp[a] != comp[b]);
        match bridge {
            Some(&(_, b)) => color[b] ^= 1,
            None => break,
        }
    }
    let mut kept = cross_edges(&edges, &color);
    let comp = components(n, &kept);
    if 1 + *comp.iter().max().unwrap() != 1 {
        // repair budget exhausted: the parity coloring is always valid
        color = parity;
        kept = cross_edges(&edges, &color);
    }
    let dropped_edges = edges.len() - kept.len();
    let topology = assemble(n, &kept, &color, raw.positions)?;
    Ok(BuiltTopology { topology, dropped_edges, exact: false })
}

fn assemble(
    n: usize,
    edges: &[(usize, usize)],
    color: &[u8],
    positions: Vec<(f64, f64)>,
) -> Result<Topology, String> {
    let groups: Vec<Group> = color
        .iter()
        .map(|&c| if c == 0 { Group::Head } else { Group::Tail })
        .collect();
    let mut topo = Topology::try_new(n, edges.to_vec(), groups)?;
    topo.set_positions(positions);
    Ok(topo)
}

fn canonical(a: usize, b: usize) -> (usize, usize) {
    if a < b {
        (a, b)
    } else {
        (b, a)
    }
}

fn cross_edges(edges: &[(usize, usize)], color: &[u8]) -> Vec<(usize, usize)> {
    edges
        .iter()
        .filter(|&&(a, b)| color[a] != color[b])
        .cloned()
        .collect()
}

/// BFS 2-coloring by depth parity (input must be connected).
fn bfs_parity(n: usize, adj: &[Vec<usize>]) -> Vec<u8> {
    let mut color = vec![u8::MAX; n];
    let mut queue = std::collections::VecDeque::from([0usize]);
    color[0] = 0;
    while let Some(u) = queue.pop_front() {
        for &v in &adj[u] {
            if color[v] == u8::MAX {
                color[v] = color[u] ^ 1;
                queue.push_back(v);
            }
        }
    }
    color
}

/// Connected-component id per node (0-based, component of node 0 first).
fn components(n: usize, edges: &[(usize, usize)]) -> Vec<usize> {
    let mut adj = vec![Vec::new(); n];
    for &(a, b) in edges {
        adj[a].push(b);
        adj[b].push(a);
    }
    let mut comp = vec![usize::MAX; n];
    let mut next = 0;
    for start in 0..n {
        if comp[start] != usize::MAX {
            continue;
        }
        comp[start] = next;
        let mut queue = std::collections::VecDeque::from([start]);
        while let Some(u) = queue.pop_front() {
            for &v in &adj[u] {
                if comp[v] == usize::MAX {
                    comp[v] = next;
                    queue.push_back(v);
                }
            }
        }
        next += 1;
    }
    comp
}

fn circle_positions(n: usize) -> Vec<(f64, f64)> {
    let r = DEPLOY_SIDE_M / 2.0;
    (0..n)
        .map(|i| {
            let a = 2.0 * std::f64::consts::PI * i as f64 / n as f64;
            (r + r * a.cos(), r + r * a.sin())
        })
        .collect()
}

fn square_positions(n: usize, rng: &mut Pcg64) -> Vec<(f64, f64)> {
    (0..n)
        .map(|_| (rng.uniform_in(0.0, DEPLOY_SIDE_M), rng.uniform_in(0.0, DEPLOY_SIDE_M)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn degrees(t: &Topology) -> Vec<usize> {
        (0..t.n()).map(|i| t.degree(i)).collect()
    }

    #[test]
    fn ring_even_is_exact_odd_drops_one() {
        let even = build(&TopologySpec::Ring, 8, 1).unwrap();
        assert!(even.exact);
        assert_eq!(even.dropped_edges, 0);
        assert_eq!(even.topology.edges().len(), 8);
        assert!(degrees(&even.topology).iter().all(|&d| d == 2));

        let odd = build(&TopologySpec::Ring, 9, 1).unwrap();
        assert!(!odd.exact);
        assert_eq!(odd.dropped_edges, 1, "odd ring drops exactly one edge");
        assert_eq!(odd.topology.edges().len(), 8);
        assert!(odd.topology.is_connected());
        assert!(odd.topology.is_bipartite_consistent());
    }

    #[test]
    fn star_center_is_a_group_of_one_side() {
        let b = build(&TopologySpec::Star, 12, 3).unwrap();
        assert!(b.exact);
        assert_eq!(b.topology.degree(0), 11);
        for i in 1..12 {
            assert_eq!(b.topology.degree(i), 1);
            assert_ne!(b.topology.group(i), b.topology.group(0));
        }
    }

    #[test]
    fn grid_is_checkerboard_bipartite() {
        // 12 = 3 x 4 lattice: interior degree 4, corners 2
        let b = build(&TopologySpec::Grid { torus: false }, 12, 1).unwrap();
        assert!(b.exact);
        assert_eq!(b.topology.edges().len(), 3 * 3 + 2 * 4); // rows*(cols-1) + (rows-1)*cols
        let d = degrees(&b.topology);
        assert_eq!(d.iter().filter(|&&x| x == 2).count(), 4); // corners
    }

    #[test]
    fn torus_even_dims_exact_odd_dims_drop() {
        // 16 = 4 x 4 torus: 4-regular, bipartite
        let b = build(&TopologySpec::Grid { torus: true }, 16, 1).unwrap();
        assert!(b.exact);
        assert!(degrees(&b.topology).iter().all(|&d| d == 4));
        assert_eq!(b.topology.edges().len(), 32);
        // 9 = 3 x 3 torus has odd wrap cycles: some edges must drop
        let b = build(&TopologySpec::Grid { torus: true }, 9, 1).unwrap();
        assert!(!b.exact);
        assert!(b.dropped_edges > 0);
        assert!(b.topology.is_connected());
    }

    #[test]
    fn prime_grid_degenerates_to_line() {
        let b = build(&TopologySpec::Grid { torus: false }, 7, 1).unwrap();
        assert_eq!(b.topology.edges().len(), 6);
        assert!(b.exact);
    }

    #[test]
    fn bipartition_accounts_every_edge() {
        // kept + dropped == raw edge count, on a family that drops
        let mut rng = Pcg64::new(9);
        let raw = small_world(20, 6, 0.2, &mut rng);
        let raw_edges = raw.edges.len();
        let b = bipartition(raw).unwrap();
        assert_eq!(b.topology.edges().len() + b.dropped_edges, raw_edges);
        assert!(b.topology.is_connected());
        assert!(b.topology.is_bipartite_consistent());
    }

    #[test]
    fn bipartition_rejects_disconnected_input() {
        let raw = RawGraph {
            n: 4,
            edges: vec![(0, 1), (2, 3)],
            positions: vec![(0.0, 0.0); 4],
        };
        let err = bipartition(raw).unwrap_err();
        assert!(err.contains("not connected"), "{err}");
    }

    #[test]
    fn geometric_edges_respect_radius() {
        let b = build(&TopologySpec::Geometric { radius_m: 220.0 }, 24, 5).unwrap();
        let t = &b.topology;
        // non-repair edges are within the radius; repair edges are the
        // shortest available, so every link is a real physical distance
        for &(h, tl) in t.edges() {
            assert!(t.distance(h, tl) > 0.0);
            assert!(t.distance(h, tl) <= DEPLOY_SIDE_M * 2f64.sqrt());
        }
        assert!(t.is_connected());
    }

    #[test]
    fn deterministic_per_seed_and_distinct_across_seeds() {
        let specs = [
            TopologySpec::ErdosRenyi { p: 0.2 },
            TopologySpec::SmallWorld { k: 4, beta: 0.3 },
            TopologySpec::Geometric { radius_m: 180.0 },
        ];
        for spec in specs {
            let a = build(&spec, 16, 7).unwrap();
            let b = build(&spec, 16, 7).unwrap();
            assert_eq!(a.topology.edges(), b.topology.edges(), "{spec}");
            assert_eq!(a.dropped_edges, b.dropped_edges);
            let c = build(&spec, 16, 8).unwrap();
            assert_ne!(a.topology.edges(), c.topology.edges(), "{spec}");
        }
    }

    #[test]
    fn smallworld_beta_zero_is_the_lattice() {
        let b = build(&TopologySpec::SmallWorld { k: 4, beta: 0.0 }, 10, 1).unwrap();
        // k=4 ring lattice has n*k/2 edges; bipartition may drop some
        // (triangle-free it is not), but the raw lattice is 4-regular
        let mut rng = Pcg64::new(0);
        let raw = small_world(10, 4, 0.0, &mut rng);
        assert_eq!(raw.edges.len(), 20);
        assert!(b.topology.is_connected());
    }

    #[test]
    fn tiny_n_all_families() {
        for spec in [
            TopologySpec::Chain,
            TopologySpec::Ring,
            TopologySpec::Star,
            TopologySpec::Grid { torus: false },
            TopologySpec::Grid { torus: true },
            TopologySpec::ErdosRenyi { p: 0.5 },
            TopologySpec::SmallWorld { k: 4, beta: 0.5 },
            TopologySpec::Geometric { radius_m: 100.0 },
        ] {
            for n in 2..=5 {
                let b = build(&spec, n, 3).unwrap_or_else(|e| panic!("{spec} n={n}: {e}"));
                assert!(b.topology.is_connected(), "{spec} n={n}");
                assert!(b.topology.is_bipartite_consistent(), "{spec} n={n}");
                for i in 0..n {
                    assert!(b.topology.degree(i) >= 1, "{spec} n={n} worker {i} isolated");
                }
            }
            assert!(build(&spec, 1, 3).is_err());
        }
    }
}
