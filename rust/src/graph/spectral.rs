//! Topology matrices and spectral constants of the paper's Appendix D.
//!
//! For a bipartite graph with |H| = r heads listed before |T| = s tails,
//! the adjacency matrix is `A = [[0, B], [B^T, 0]]`; the rate analysis
//! uses the *upper-triangular half* `C = [[0, B], [0, 0]]`, the signed /
//! unsigned incidence matrices `M_-`, `M_+` (columns indexed by edges,
//! head end +1, tail end -1 resp. +1/+1) and the identities
//! `D - A = 1/2 M_- M_-^T`, `D + A = 1/2 M_+ M_+^T`.
//!
//! The spectral constants run on the blocked dense kernels: power
//! iteration drives the blocked matvec, and the normal matrices behind
//! `sigma~_min(M_-)` are formed by the symmetric row-Gram kernel
//! ([`Mat::gram_rows`]) rather than a general GEMM against an explicit
//! transpose.

use super::Topology;
use crate::linalg::{min_nonzero_singular, power_iteration_sigma_max, Mat};

/// Dense topology matrices (N x N resp. N x 2|E|).
///
/// The paper's incidence convention counts every edge in both directions
/// (hence the 1/2 in `D - A = 1/2 M_- M_-^T`): `M_-` has one ±1 column per
/// *directed* edge.
pub struct TopoMatrices {
    pub adjacency: Mat,
    pub degree: Mat,
    pub c: Mat,
    pub m_minus: Mat,
    pub m_plus: Mat,
}

/// Spectral constants feeding the Theorem-3 rate bound.
#[derive(Clone, Copy, Debug)]
pub struct SpectralConstants {
    pub sigma_max_c: f64,
    pub sigma_max_m_minus: f64,
    /// smallest *non-zero* singular value of `M_-`
    pub sigma_min_nz_m_minus: f64,
}

/// Assemble the dense matrices of Appendix D for a topology.
pub fn matrices(t: &Topology) -> TopoMatrices {
    let n = t.n();
    let e = t.edges().len();
    let mut adjacency = Mat::zeros(n, n);
    let mut degree = Mat::zeros(n, n);
    let mut c = Mat::zeros(n, n);
    let mut m_minus = Mat::zeros(n, 2 * e);
    let mut m_plus = Mat::zeros(n, 2 * e);
    for (k, &(h, tl)) in t.edges().iter().enumerate() {
        adjacency[(h, tl)] = 1.0;
        adjacency[(tl, h)] = 1.0;
        // C keeps only the head->tail (upper bipartite) block
        c[(h, tl)] = 1.0;
        // directed edge h -> tl
        m_minus[(h, 2 * k)] = 1.0;
        m_minus[(tl, 2 * k)] = -1.0;
        m_plus[(h, 2 * k)] = 1.0;
        m_plus[(tl, 2 * k)] = 1.0;
        // directed edge tl -> h
        m_minus[(tl, 2 * k + 1)] = 1.0;
        m_minus[(h, 2 * k + 1)] = -1.0;
        m_plus[(tl, 2 * k + 1)] = 1.0;
        m_plus[(h, 2 * k + 1)] = 1.0;
    }
    for i in 0..n {
        degree[(i, i)] = t.degree(i) as f64;
    }
    TopoMatrices { adjacency, degree, c, m_minus, m_plus }
}

/// Spectral constants of the topology.
pub fn constants(t: &Topology) -> SpectralConstants {
    let m = matrices(t);
    SpectralConstants {
        sigma_max_c: power_iteration_sigma_max(&m.c, 500),
        sigma_max_m_minus: power_iteration_sigma_max(&m.m_minus, 500),
        sigma_min_nz_m_minus: min_nonzero_singular(&m.m_minus, 1e-8),
    }
}

/// Theoretical contraction factor estimate `(1 + delta_2)/2` of Theorem 3
/// for given strong-convexity/Lipschitz moduli and parameters.  This
/// mirrors the chain of definitions (147)-(154); it is a *bound*, the
/// experiments compare the empirically fitted rate against it.
pub fn theorem3_rate_bound(
    t: &Topology,
    mu: f64,
    l: f64,
    rho: f64,
    psi: f64,
    kappa: f64,
    eta: f64,
) -> Theorem3Bound {
    let sc = constants(t);
    let smc2 = sc.sigma_max_c * sc.sigma_max_c;
    let smin2 = sc.sigma_min_nz_m_minus * sc.sigma_min_nz_m_minus;
    // eta_i choices follow the proof's free parameters; we use the
    // symmetric choice eta_0..eta_5 = 1 which keeps b_1, b_2 simple.
    let b1 = smc2 / 2.0;
    let b2 = 0.5 * smc2 + 0.5 + 0.5 + 0.5 + 0.5 + 0.25;
    let c_const = 4.0 * eta * l * l / smin2;
    let a_const = 8.0 * eta * smc2 / ((eta - 1.0) * smin2);
    let quad = (b2 + a_const * kappa) + (1.0 + kappa) * (b1 + a_const * kappa);
    let disc = mu * mu - 4.0 * c_const * kappa * quad;
    let rho_bar = if disc > 0.0 {
        (mu + disc.sqrt()) / quad
    } else {
        0.0
    };
    let delta2 = ((1.0 + kappa).recip()).max(psi * psi);
    Theorem3Bound {
        constants: sc,
        rho_bar,
        discriminant: disc,
        rate: (1.0 + delta2) / 2.0,
        rho_ok: rho > 0.0 && rho < rho_bar,
    }
}

/// Output of [`theorem3_rate_bound`].
#[derive(Clone, Copy, Debug)]
pub struct Theorem3Bound {
    pub constants: SpectralConstants,
    pub rho_bar: f64,
    pub discriminant: f64,
    /// `(1 + delta_2)/2` — the guaranteed per-iteration contraction.
    pub rate: f64,
    pub rho_ok: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    #[test]
    fn incidence_identities() {
        check("D - A = 1/2 M- M-^T and D + A = 1/2 M+ M+^T", 30, |g| {
            let n = g.usize_in(2, 16);
            let p = g.f64_in(0.1, 0.9);
            let t = Topology::random_bipartite(n, p, g.u64());
            let m = matrices(&t);
            let lhs_minus = m.degree.sub(&m.adjacency);
            let rhs_minus = m.m_minus.gram_rows().scale(0.5);
            assert!(lhs_minus.sub(&rhs_minus).max_abs() < 1e-10);
            // blocked row-Gram agrees with the general GEMM formulation
            let gemm_minus = m.m_minus.matmul(&m.m_minus.t()).scale(0.5);
            assert!(rhs_minus.sub(&gemm_minus).max_abs() < 1e-10);
            let lhs_plus = m.degree.add(&m.adjacency);
            let rhs_plus = m.m_plus.gram_rows().scale(0.5);
            assert!(lhs_plus.sub(&rhs_plus).max_abs() < 1e-10);
            // A = C + C^T
            let rebuilt = m.c.add(&m.c.t());
            assert!(m.adjacency.sub(&rebuilt).max_abs() < 1e-12);
        });
    }

    #[test]
    fn chain_spectrum_known() {
        // chain of 2: one edge counted both ways, M- M-^T = 2*(D - A) with
        // eigenvalues {0, 4} => sigma values 2
        let t = Topology::chain(2);
        let c = constants(&t);
        assert!((c.sigma_max_m_minus - 2.0).abs() < 1e-6, "{}", c.sigma_max_m_minus);
        assert!((c.sigma_min_nz_m_minus - 2.0).abs() < 1e-6);
        assert!((c.sigma_max_c - 1.0).abs() < 1e-6);
    }

    #[test]
    fn laplacian_null_space_dim_one_iff_connected() {
        let t = Topology::random_bipartite(10, 0.3, 5);
        let m = matrices(&t);
        let lap = m.degree.sub(&m.adjacency);
        let eig = crate::linalg::symmetric_eigen(&lap);
        // connected graph: exactly one ~zero eigenvalue
        assert!(eig[0].abs() < 1e-8);
        assert!(eig[1] > 1e-8, "{eig:?}");
    }

    #[test]
    fn rate_bound_in_unit_interval() {
        let t = Topology::random_bipartite(12, 0.4, 2);
        let b = theorem3_rate_bound(&t, 0.5, 5.0, 0.05, 0.9, 0.05, 2.0);
        assert!(b.rate > 0.5 && b.rate < 1.0, "rate={}", b.rate);
        assert!(b.constants.sigma_max_c > 0.0);
    }
}
