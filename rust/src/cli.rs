//! Zero-dependency declarative CLI parser (clap substitute).
//!
//! Supports subcommands, `--flag value` / `--flag=value` options, boolean
//! switches, typed getters with defaults, and generated `--help` text.

use std::collections::BTreeMap;

/// Option/flag declaration.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
}

/// A declarative command: name, help, options.
#[derive(Clone, Debug)]
pub struct Command {
    pub name: &'static str,
    pub help: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, help: &'static str) -> Self {
        Command { name, help, opts: Vec::new() }
    }

    /// Declare a valued option.
    pub fn opt(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default, is_switch: false });
        self
    }

    /// Declare a boolean switch.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_switch: true });
        self
    }
}

/// Parsed argument bag for a matched command.
#[derive(Clone, Debug)]
pub struct Args {
    pub command: String,
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    /// Option names the user passed explicitly (declared defaults are
    /// seeded into `values` but not recorded here).
    explicit: Vec<String>,
    /// Free (positional) arguments after options.
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// True when the option was passed on the command line (a declared
    /// default alone does not count).  This is what lets `--manifest`
    /// layering work: manifest values win over flag *defaults*, explicit
    /// flags win over the manifest.
    pub fn given(&self, name: &str) -> bool {
        self.explicit.iter().any(|s| s == name)
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| format!("option --{name}: expected a number, got '{v}'")),
        }
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| format!("option --{name}: expected an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<u64>()
                .map(Some)
                .map_err(|_| format!("option --{name}: expected an integer, got '{v}'")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

/// Top-level parser over a set of commands.
pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl Cli {
    pub fn new(bin: &'static str, about: &'static str) -> Self {
        Cli { bin, about, commands: Vec::new() }
    }

    pub fn command(mut self, c: Command) -> Self {
        self.commands.push(c);
        self
    }

    /// Render the global help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", self.bin, self.about, self.bin);
        for c in &self.commands {
            s.push_str(&format!("  {:<12} {}\n", c.name, c.help));
        }
        s.push_str("\nRun '<command> --help' for command options.\n");
        s
    }

    /// Render per-command help.
    pub fn command_help(&self, cmd: &Command) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.bin, cmd.name, cmd.help);
        for o in &cmd.opts {
            let d = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let kind = if o.is_switch { "" } else { " <value>" };
            s.push_str(&format!("  --{}{kind:<10} {}{d}\n", o.name, o.help));
        }
        s
    }

    /// Parse argv (without the binary name).  `Err` carries a user-facing
    /// message (help requests are `Err` with the help text so callers can
    /// print-and-exit-0 on `is_help`).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            return Err(CliError::help(self.help()));
        }
        let cmd_name = &argv[0];
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name.as_str())
            .ok_or_else(|| {
                CliError::error(format!(
                    "unknown command '{cmd_name}'\n\n{}",
                    self.help()
                ))
            })?;

        let mut values = BTreeMap::new();
        let mut switches = Vec::new();
        let mut explicit = Vec::new();
        let mut positional = Vec::new();
        // seed defaults
        for o in &cmd.opts {
            if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(CliError::help(self.command_help(cmd)));
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = cmd.opts.iter().find(|o| o.name == name).ok_or_else(|| {
                    CliError::error(format!(
                        "unknown option '--{name}' for '{}'\n\n{}",
                        cmd.name,
                        self.command_help(cmd)
                    ))
                })?;
                if spec.is_switch {
                    if inline.is_some() {
                        return Err(CliError::error(format!(
                            "switch '--{name}' does not take a value"
                        )));
                    }
                    switches.push(name.to_string());
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| {
                                    CliError::error(format!("option '--{name}' needs a value"))
                                })?
                        }
                    };
                    values.insert(name.to_string(), value);
                    explicit.push(name.to_string());
                }
            } else {
                positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(Args {
            command: cmd.name.to_string(),
            values,
            switches,
            explicit,
            positional,
        })
    }
}

/// Parse failure or help request.
#[derive(Debug)]
pub struct CliError {
    pub message: String,
    pub is_help: bool,
}

impl CliError {
    fn help(message: String) -> Self {
        CliError { message, is_help: true }
    }
    fn error(message: String) -> Self {
        CliError { message, is_help: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("cq-ggadmm", "test cli").command(
            Command::new("exp", "run experiment")
                .opt("figure", Some("fig2"), "figure id")
                .opt("iters", Some("100"), "iterations")
                .switch("quiet", "no output"),
        )
    }

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli().parse(&sv(&["exp", "--iters", "50"])).unwrap();
        assert_eq!(a.get("figure"), Some("fig2"));
        assert_eq!(a.get_usize("iters").unwrap(), Some(50));
        assert!(!a.has("quiet"));
        // defaults are readable but not "given"; explicit flags are both
        assert!(a.given("iters"));
        assert!(!a.given("figure"));
    }

    #[test]
    fn equals_form_and_switch() {
        let a = cli().parse(&sv(&["exp", "--figure=fig6", "--quiet"])).unwrap();
        assert_eq!(a.get("figure"), Some("fig6"));
        assert!(a.has("quiet"));
    }

    #[test]
    fn unknown_command_errors() {
        let e = cli().parse(&sv(&["nope"])).unwrap_err();
        assert!(!e.is_help);
        assert!(e.message.contains("unknown command"));
    }

    #[test]
    fn unknown_option_errors() {
        let e = cli().parse(&sv(&["exp", "--bogus", "1"])).unwrap_err();
        assert!(e.message.contains("unknown option"));
    }

    #[test]
    fn help_flag_is_help() {
        let e = cli().parse(&sv(&["--help"])).unwrap_err();
        assert!(e.is_help);
        let e = cli().parse(&sv(&["exp", "--help"])).unwrap_err();
        assert!(e.is_help);
        assert!(e.message.contains("--figure"));
    }

    #[test]
    fn missing_value_errors() {
        let e = cli().parse(&sv(&["exp", "--iters"])).unwrap_err();
        assert!(e.message.contains("needs a value"));
    }

    #[test]
    fn bad_number_reported() {
        let a = cli().parse(&sv(&["exp", "--iters", "abc"])).unwrap();
        assert!(a.get_usize("iters").is_err());
    }

    #[test]
    fn positional_args_collected() {
        let a = cli().parse(&sv(&["exp", "out.csv"])).unwrap();
        assert_eq!(a.positional, vec!["out.csv".to_string()]);
    }
}
