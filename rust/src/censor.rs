//! Communication censoring (paper §4).
//!
//! A worker transmits at iteration `k+1` only when its candidate update
//! differs from its last transmitted state by at least the decaying
//! threshold `tau^{k+1} = tau0 * xi^{k+1}`; otherwise the link is censored
//! and neighbors keep the stale value.  The censoring error is therefore
//! bounded by `tau^k` at every iteration (eq. (31)), which the convergence
//! proof leans on.

use crate::util::max_abs_diff;

/// Censoring schedule parameters.
#[derive(Clone, Copy, Debug)]
pub struct CensorConfig {
    /// Initial threshold `tau0` (0 disables censoring: every iteration
    /// transmits, recovering GGADMM exactly).
    pub tau0: f64,
    /// Geometric decay `xi` in (0,1).
    pub xi: f64,
}

impl CensorConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.tau0 < 0.0 {
            return Err("tau0 must be >= 0".into());
        }
        if !(0.0 < self.xi && self.xi < 1.0) {
            return Err("xi must be in (0,1)".into());
        }
        Ok(())
    }

    /// Threshold at iteration `k` (`tau^k = tau0 * xi^k`).
    pub fn threshold(&self, k: u64) -> f64 {
        if self.tau0 == 0.0 {
            return 0.0;
        }
        self.tau0 * self.xi.powi(k.min(i32::MAX as u64) as i32)
    }
}

/// Decision of the censoring gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gate {
    Transmit,
    Censor,
}

/// Apply the censoring condition of Algorithms 1/2:
/// transmit iff `|| last_sent - candidate || >= tau^{k}` (Euclidean).
pub fn gate(cfg: &CensorConfig, k: u64, last_sent: &[f64], candidate: &[f64]) -> Gate {
    if cfg.tau0 == 0.0 {
        return Gate::Transmit;
    }
    let diff: f64 = last_sent
        .iter()
        .zip(candidate)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    if diff >= cfg.threshold(k) {
        Gate::Transmit
    } else {
        Gate::Censor
    }
}

/// Invariant check used by property tests: whatever the gate decided, the
/// censoring error `|| kept - candidate ||_inf` never exceeds `tau^k` when
/// censored (eq. (31)).
pub fn censor_error_ok(cfg: &CensorConfig, k: u64, kept: &[f64], candidate: &[f64], decision: Gate) -> bool {
    match decision {
        Gate::Transmit => max_abs_diff(kept, candidate) == 0.0,
        Gate::Censor => {
            let l2: f64 = kept
                .iter()
                .zip(candidate)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            l2 < cfg.threshold(k)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    #[test]
    fn threshold_decays_geometrically() {
        let cfg = CensorConfig { tau0: 2.0, xi: 0.5 };
        assert_eq!(cfg.threshold(0), 2.0);
        assert_eq!(cfg.threshold(1), 1.0);
        assert_eq!(cfg.threshold(3), 0.25);
        for k in 0..50 {
            assert!(cfg.threshold(k + 1) < cfg.threshold(k));
        }
    }

    #[test]
    fn tau0_zero_always_transmits() {
        let cfg = CensorConfig { tau0: 0.0, xi: 0.9 };
        let g = gate(&cfg, 5, &[0.0, 0.0], &[0.0, 0.0]);
        assert_eq!(g, Gate::Transmit);
    }

    #[test]
    fn small_updates_censored_large_pass() {
        let cfg = CensorConfig { tau0: 1.0, xi: 0.5 };
        // threshold at k=1 is 0.5
        assert_eq!(gate(&cfg, 1, &[0.0], &[0.4]), Gate::Censor);
        assert_eq!(gate(&cfg, 1, &[0.0], &[0.6]), Gate::Transmit);
        // same diff later in training passes as the threshold decays
        assert_eq!(gate(&cfg, 6, &[0.0], &[0.4]), Gate::Transmit);
    }

    #[test]
    fn censor_error_invariant() {
        check("censoring error bounded by tau^k (eq. 31)", 100, |g| {
            let cfg = CensorConfig {
                tau0: g.f64_in(0.01, 5.0),
                xi: g.f64_in(0.3, 0.99),
            };
            let k = g.usize_in(0, 40) as u64;
            let d = g.usize_in(1, 32);
            let last = g.normal_vec(d);
            let cand = g.normal_vec(d);
            let decision = gate(&cfg, k, &last, &cand);
            let kept = match decision {
                Gate::Transmit => cand.clone(),
                Gate::Censor => last.clone(),
            };
            assert!(censor_error_ok(&cfg, k, &kept, &cand, decision));
        });
    }

    #[test]
    fn validation() {
        assert!(CensorConfig { tau0: -1.0, xi: 0.5 }.validate().is_err());
        assert!(CensorConfig { tau0: 1.0, xi: 1.0 }.validate().is_err());
        assert!(CensorConfig { tau0: 1.0, xi: 0.5 }.validate().is_ok());
    }
}
