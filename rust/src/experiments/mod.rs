//! The paper's evaluation suite: one module entry per figure/table.
//!
//! Every figure of §7 maps to a [`FigureSpec`] (workload, topology,
//! parameters, algorithm set) and regenerates the paper's series as
//! [`Trace`]s plus a comparison summary at the paper's reference accuracy.
//! `DESIGN.md §4` holds the index; the `cq-ggadmm exp <figure>` CLI and
//! the cargo benches drive these.
//!
//! ## Sweep scheduling
//!
//! Reproducing a figure means several *independent* runs (one per
//! algorithm; for fig6 per algorithm x density; for the full paper per
//! figure as well).  [`run_figure`], [`run_figures`] and [`run_fig6`]
//! flatten those runs into one job list and dispatch it over a
//! persistent [`crate::parallel::WorkerPool`]
//! ([`ExecOptions::sweep_threads`] concurrent runs, collected in job
//! order), so a sweep saturates the machine instead of one core.
//! Scheduling is **deterministic**: every job owns its spec-pinned seed
//! and builds its own engine state, so pool-scheduled sweeps reproduce
//! the serial driver's traces bit-for-bit regardless of thread count or
//! claim order (`tests/figures.rs` locks this).  When a sweep is down
//! to a single job — or run-level parallelism is off — the jobs fall
//! back to intra-run threading ([`ExecOptions::threads`]), so a single
//! expensive run can still use the whole pool.

pub mod matrix;
pub mod rates;
pub mod sensitivity;

use crate::algs::{dgd, AlgSpec, Problem, Run};
use crate::comm::EnergyParams;
use crate::config::DatasetId;
use crate::data;
use crate::graph::Topology;
use crate::io::Table;
use crate::metrics::Trace;
use crate::solver::Backend;

/// A figure's full experimental setup.
#[derive(Clone, Debug)]
pub struct FigureSpec {
    pub id: &'static str,
    pub title: &'static str,
    pub dataset: DatasetId,
    pub workers: usize,
    pub connectivity: f64,
    pub rho: f64,
    pub mu0: f64,
    /// iteration budget for alternating (GGADMM-family) schemes
    pub iters_alt: u64,
    /// iteration budget for the Jacobian C-ADMM baseline (the paper's
    /// plots run it ~an order of magnitude longer)
    pub iters_jacobian: u64,
    pub seed: u64,
    /// reference accuracy the summary compares schemes at
    pub target_gap: f64,
    pub algs: Vec<AlgSpec>,
    /// include the DGD first-order baseline
    pub with_dgd: bool,
}

/// Paper-tuned parameter sets ("we choose the values leading to the best
/// performance of all algorithms" — §7; these were tuned empirically on
/// this reproduction, see EXPERIMENTS.md).
fn default_algs(linear: bool) -> Vec<AlgSpec> {
    if linear {
        vec![
            AlgSpec::c_admm(0.1, 0.8),
            AlgSpec::ggadmm(),
            AlgSpec::c_ggadmm(0.1, 0.8),
            AlgSpec::cq_ggadmm(0.1, 0.8, 0.995, 2),
        ]
    } else {
        vec![
            AlgSpec::c_admm(0.3, 0.9),
            AlgSpec::ggadmm(),
            AlgSpec::c_ggadmm(0.3, 0.9),
            AlgSpec::cq_ggadmm(0.3, 0.9, 0.995, 2),
        ]
    }
}

/// Figure 2: linear regression, synthetic dataset, N = 24.
pub fn fig2() -> FigureSpec {
    FigureSpec {
        id: "fig2",
        title: "Linear regression, synthetic dataset (N=24)",
        dataset: DatasetId::SynthLinear,
        workers: 24,
        connectivity: 0.3,
        rho: 30.0,
        mu0: 0.0,
        iters_alt: 300,
        iters_jacobian: 1200,
        seed: 21,
        target_gap: 1e-4,
        algs: default_algs(true),
        with_dgd: false,
    }
}

/// Figure 3: linear regression, Body Fat, N = 18.
pub fn fig3() -> FigureSpec {
    FigureSpec {
        id: "fig3",
        title: "Linear regression, real dataset Body Fat (N=18)",
        dataset: DatasetId::BodyFat,
        workers: 18,
        connectivity: 0.3,
        rho: 5.0,
        mu0: 0.0,
        iters_alt: 400,
        iters_jacobian: 1500,
        seed: 22,
        target_gap: 1e-4,
        algs: default_algs(true),
        with_dgd: false,
    }
}

/// Figure 4: logistic regression, synthetic dataset, N = 24.
pub fn fig4() -> FigureSpec {
    FigureSpec {
        id: "fig4",
        title: "Logistic regression, synthetic dataset (N=24)",
        dataset: DatasetId::SynthLogistic,
        workers: 24,
        connectivity: 0.3,
        rho: 0.1,
        mu0: 1e-2,
        iters_alt: 300,
        iters_jacobian: 1000,
        seed: 23,
        target_gap: 1e-4,
        algs: default_algs(false),
        with_dgd: false,
    }
}

/// Figure 5: logistic regression, Derm, N = 18.
pub fn fig5() -> FigureSpec {
    FigureSpec {
        id: "fig5",
        title: "Logistic regression, real dataset Derm (N=18)",
        dataset: DatasetId::Derm,
        workers: 18,
        connectivity: 0.3,
        rho: 0.1,
        mu0: 1e-2,
        iters_alt: 300,
        iters_jacobian: 1000,
        seed: 24,
        target_gap: 1e-4,
        algs: default_algs(false),
        with_dgd: false,
    }
}

/// Figure 6 is the density ablation; see [`fig6`].
#[derive(Clone, Debug)]
pub struct Fig6Spec {
    pub base: FigureSpec,
    pub sparse_p: f64,
    pub dense_p: f64,
}

/// Figure 6: graph-density effect, Body Fat linear regression, N = 18,
/// sparse p = 0.2 vs dense p = 0.4.
pub fn fig6() -> Fig6Spec {
    let mut base = fig3();
    base.id = "fig6";
    base.title = "Graph density effect, Body Fat (N=18, p=0.2 vs p=0.4)";
    Fig6Spec { base, sparse_p: 0.2, dense_p: 0.4 }
}

/// Result bundle of a figure run.
pub struct FigureResult {
    pub id: String,
    pub title: String,
    pub traces: Vec<Trace>,
    pub summary: Table,
}

/// Execution knobs shared by all figure runs — the unified
/// [`crate::config::ExecutionConfig`] (this alias is the legacy name;
/// the sweep-relevant knobs are [`ExecutionConfig::threads`] and
/// [`ExecutionConfig::sweep_threads`]).
pub type ExecOptions = crate::config::ExecutionConfig;

/// Build the topology + problem of a figure (shared with the rate study).
pub fn build_problem(spec: &FigureSpec, p_override: Option<f64>) -> (Problem, Topology) {
    let topo = Topology::random_bipartite(
        spec.workers,
        p_override.unwrap_or(spec.connectivity),
        spec.seed,
    );
    let ds = data::load(spec.dataset, spec.seed);
    let problem = Problem::new(&ds, &topo, spec.rho, spec.mu0, spec.seed);
    (problem, topo)
}

/// One independent run of a sweep: an (algorithm, problem instance)
/// pair, optionally relabelled (fig6 density variants, topology-matrix
/// families).  Jobs borrow the prebuilt problem and clone it inside the
/// worker — `Problem` clones share shards behind `Arc`, so the clone is
/// cheap and every job gets its own engine state.
struct SweepJob<'a> {
    problem: &'a Problem,
    topo: &'a Topology,
    /// `None` runs the DGD first-order baseline instead of an ADMM spec.
    alg: Option<&'a AlgSpec>,
    iters: u64,
    seed: u64,
    /// Trace-label suffix, rendered as `"NAME (suffix)"`.
    rename: Option<String>,
}

/// Dispatch a flattened job list over a persistent pool and collect the
/// traces in job order (run-level parallelism; see the module docs for
/// the determinism and fallback-to-intra-run-threading contract).
fn run_jobs(jobs: &[SweepJob], exec: &ExecOptions) -> Vec<Trace> {
    let sweep = match (exec.backend, exec.sweep_threads) {
        // the PJRT backend shares one client per process; keep runs serial
        (Backend::Pjrt, _) => 1,
        // auto mode: saturate with run-level parallelism, but an explicit
        // intra-run thread request wins — the caller asked for that layout,
        // and sweep scheduling would silently force runs single-threaded
        (_, 0) if exec.threads > 1 => 1,
        (_, 0) => crate::parallel::default_threads(),
        (_, t) => t,
    };
    let sweep = sweep.min(jobs.len()).max(1);
    // concurrently scheduled runs go single-threaded (no nested pools);
    // a lone job — or a serial sweep — keeps the intra-run fan-out
    let run_threads = if sweep > 1 { 1 } else { exec.threads };
    let mut pool = (sweep > 1).then(|| crate::parallel::WorkerPool::new(sweep));
    crate::parallel::map_maybe_pool(pool.as_mut(), jobs.len(), |j| {
        let job = &jobs[j];
        let mut trace = match job.alg {
            Some(alg) => {
                // the job inherits every execution knob (link model,
                // energy, incremental, ...) and pins its own seed and
                // thread layout
                let opts = exec
                    .clone()
                    .with_threads(run_threads)
                    .with_seed(job.seed)
                    .with_sweep_threads(1);
                let mut run = Run::new(job.problem.clone(), job.topo.clone(), alg.clone(), opts);
                run.run(job.iters)
            }
            None => dgd::run_dgd(
                job.problem,
                job.topo,
                0.01,
                job.iters,
                EnergyParams::default(),
            ),
        };
        if let Some(suffix) = &job.rename {
            trace.algorithm = format!("{} ({suffix})", trace.algorithm);
        }
        trace
    })
}

/// Append one job per algorithm (plus DGD if requested) for `spec`.
fn push_spec_jobs<'a>(
    jobs: &mut Vec<SweepJob<'a>>,
    spec: &'a FigureSpec,
    problem: &'a Problem,
    topo: &'a Topology,
    rename: Option<String>,
) {
    for alg in &spec.algs {
        let iters = match alg.schedule {
            crate::algs::Schedule::Alternating => spec.iters_alt,
            crate::algs::Schedule::Jacobian => spec.iters_jacobian,
        };
        jobs.push(SweepJob {
            problem,
            topo,
            alg: Some(alg),
            iters,
            seed: spec.seed,
            rename: rename.clone(),
        });
    }
    if spec.with_dgd {
        jobs.push(SweepJob {
            problem,
            topo,
            alg: None,
            iters: spec.iters_jacobian,
            seed: spec.seed,
            rename,
        });
    }
}

/// Run one figure: all algorithm series + the summary table.  The runs
/// are scheduled as pool jobs (see [`ExecOptions::sweep_threads`]).
pub fn run_figure(spec: &FigureSpec, exec: &ExecOptions) -> FigureResult {
    run_figures(std::slice::from_ref(spec), exec)
        .pop()
        .expect("one spec in, one result out")
}

/// Run several figures as **one** flattened job list on **one** pool —
/// the full-paper sweep saturates all cores across figure boundaries
/// instead of draining one figure at a time.  Results come back in spec
/// order with the per-figure trace order of the serial driver.
pub fn run_figures(specs: &[FigureSpec], exec: &ExecOptions) -> Vec<FigureResult> {
    // problem construction is deterministic (spec-pinned seeds) and kept
    // serial: it computes each figure's reference optimum f* once
    let built: Vec<(Problem, Topology)> = specs.iter().map(|s| build_problem(s, None)).collect();
    let mut jobs = Vec::new();
    for (spec, (problem, topo)) in specs.iter().zip(&built) {
        push_spec_jobs(&mut jobs, spec, problem, topo, None);
    }
    let mut traces = run_jobs(&jobs, exec).into_iter();
    specs
        .iter()
        .map(|spec| {
            let n = spec.algs.len() + usize::from(spec.with_dgd);
            let traces: Vec<Trace> = traces.by_ref().take(n).collect();
            let summary = summarize(&traces, spec.target_gap);
            FigureResult {
                id: spec.id.to_string(),
                title: spec.title.to_string(),
                traces,
                summary,
            }
        })
        .collect()
}

/// Run figure 6: the same algorithms over the sparse and dense graphs,
/// flattened into one (density x algorithm) job list on one pool.
pub fn run_fig6(spec: &Fig6Spec, exec: &ExecOptions) -> Vec<FigureResult> {
    let variants = [("sparse", spec.sparse_p), ("dense", spec.dense_p)];
    let built: Vec<(Problem, Topology)> = variants
        .iter()
        .map(|(_, p)| build_problem(&spec.base, Some(*p)))
        .collect();
    let mut jobs = Vec::new();
    for (&(label, p), (problem, topo)) in variants.iter().zip(&built) {
        push_spec_jobs(&mut jobs, &spec.base, problem, topo, Some(format!("{label} p={p}")));
    }
    let mut traces = run_jobs(&jobs, exec).into_iter();
    let per_variant = spec.base.algs.len() + usize::from(spec.base.with_dgd);
    variants
        .iter()
        .map(|(label, p)| {
            let traces: Vec<Trace> = traces.by_ref().take(per_variant).collect();
            let summary = summarize(&traces, spec.base.target_gap);
            FigureResult {
                id: format!("{}-{label}", spec.base.id),
                title: format!("{} [{label}, p={p}]", spec.base.title),
                traces,
                summary,
            }
        })
        .collect()
}

/// The paper's comparison: per scheme, the cost to reach the reference
/// accuracy on every axis (iterations / rounds / bits / energy).
pub fn summarize(traces: &[Trace], target_gap: f64) -> Table {
    let mut t = Table::new(&[
        "algorithm",
        "final gap",
        &format!("iters to {target_gap:.0e}"),
        "comm rounds",
        "Mbits",
        "energy (J)",
    ]);
    for tr in traces {
        match tr.first_below(target_gap) {
            Some(p) => t.row(&[
                tr.algorithm.clone(),
                format!("{:.2e}", tr.last_gap()),
                p.iteration.to_string(),
                p.cum_rounds.to_string(),
                format!("{:.3}", p.cum_bits as f64 / 1e6),
                format!("{:.3e}", p.cum_energy_j),
            ]),
            None => t.row(&[
                tr.algorithm.clone(),
                format!("{:.2e}", tr.last_gap()),
                "—".into(),
                "—".into(),
                "—".into(),
                "—".into(),
            ]),
        }
    }
    t
}

/// Table 1 of the paper: the dataset inventory.  The four dataset loads
/// (synthesis + normalization) are independent, so they run as pool jobs
/// too; rows are collected in inventory order, so the rendered table is
/// identical to the serial build.
pub fn table1() -> Table {
    let entries = [
        (DatasetId::SynthLinear, "synthetic"),
        (DatasetId::BodyFat, "real (surrogate)"),
        (DatasetId::SynthLogistic, "synthetic"),
        (DatasetId::Derm, "real (surrogate)"),
    ];
    let threads = entries.len().min(crate::parallel::default_threads());
    let rows = crate::parallel::map_indexed(entries.len(), threads, |i| {
        let (id, kind) = entries[i];
        let ds = data::load(id, 1);
        [
            id.name().into(),
            format!("{:?}", ds.task).to_lowercase(),
            kind.into(),
            ds.d().to_string(),
            ds.n().to_string(),
        ]
    });
    let mut t = Table::new(&["dataset", "task", "type", "model size d", "instances"]);
    for row in &rows {
        t.row(row);
    }
    t
}

/// All standard figures by id.
pub fn figure_by_id(id: &str) -> Option<FigureSpec> {
    match id {
        "fig2" => Some(fig2()),
        "fig3" => Some(fig3()),
        "fig4" => Some(fig4()),
        "fig5" => Some(fig5()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_specs_match_paper_workloads() {
        assert_eq!(fig2().workers, 24);
        assert_eq!(fig3().workers, 18);
        assert_eq!(fig4().dataset, DatasetId::SynthLogistic);
        assert_eq!(fig5().dataset, DatasetId::Derm);
        let f6 = fig6();
        assert_eq!((f6.sparse_p, f6.dense_p), (0.2, 0.4));
        for spec in [fig2(), fig3(), fig4(), fig5()] {
            assert_eq!(spec.algs.len(), 4);
            for a in &spec.algs {
                a.validate().unwrap();
            }
        }
    }

    #[test]
    fn table1_rows() {
        let t = table1();
        let s = t.render();
        assert!(s.contains("synth-linear"));
        assert!(s.contains("34")); // derm d
        assert!(s.contains("252")); // bodyfat instances
    }

    #[test]
    fn tiny_figure_run_end_to_end() {
        // a scaled-down fig2 exercising the whole path quickly
        let mut spec = fig2();
        spec.workers = 6;
        spec.iters_alt = 150;
        spec.iters_jacobian = 400;
        spec.target_gap = 1e-2;
        let res = run_figure(&spec, &ExecOptions::default());
        assert_eq!(res.traces.len(), 4);
        let rendered = res.summary.render();
        assert!(rendered.contains("GGADMM"), "{rendered}");
        // GGADMM-family final gaps must beat the target
        for tr in &res.traces {
            if tr.algorithm != "C-ADMM" {
                assert!(tr.last_gap() < 1e-2, "{}: {:.2e}", tr.algorithm, tr.last_gap());
            }
        }
    }
}
