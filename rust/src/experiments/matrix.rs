//! The (topology × algorithm) scenario matrix.
//!
//! The paper evaluates its algorithm family on one generated topology
//! shape; this module crosses every [`TopologySpec`] family from
//! [`crate::graph::gen`] with the full algorithm set and reports, per
//! family, the paper's comparison axes (iterations / rounds / bits /
//! energy to the reference accuracy) plus the bipartition report
//! (kept/dropped edges) and the spectral constants driving the
//! Theorem-3 rate.  Runs are flattened into one job list on the shared
//! sweep scheduler ([`super::ExecOptions::sweep_threads`]), so the
//! whole matrix saturates the machine and stays bit-deterministic.

use super::{run_jobs, summarize, ExecOptions, SweepJob};
use crate::algs::{AlgSpec, Problem, Run, Schedule};
use crate::comm::LinkKind;
use crate::config::{DatasetId, Task, TopologySpec};
use crate::data;
use crate::graph::{gen, spectral, ChurnSchedule};
use crate::io::Table;
use crate::metrics::Trace;
use std::fmt::Write as _;

/// Full setup of a matrix sweep.
#[derive(Clone, Debug)]
pub struct MatrixSpec {
    pub dataset: DatasetId,
    pub workers: usize,
    pub families: Vec<TopologySpec>,
    pub algs: Vec<AlgSpec>,
    pub rho: f64,
    pub mu0: f64,
    /// Iteration budget for alternating (GGADMM-family) schedules.
    pub iters_alt: u64,
    /// Iteration budget for the Jacobian C-ADMM baseline.
    pub iters_jacobian: u64,
    pub seed: u64,
    pub target_gap: f64,
}

/// The standard family zoo: one representative per generator, the
/// random parameters chosen so every family is connected and
/// interestingly sparse at the default N.
pub fn default_families() -> Vec<TopologySpec> {
    vec![
        TopologySpec::Chain,
        TopologySpec::Ring,
        TopologySpec::Star,
        TopologySpec::Grid { torus: false },
        TopologySpec::Grid { torus: true },
        TopologySpec::ErdosRenyi { p: 0.15 },
        TopologySpec::SmallWorld { k: 4, beta: 0.1 },
        TopologySpec::Geometric { radius_m: 200.0 },
        TopologySpec::RandomBipartite { p: 0.3 },
    ]
}

/// Matrix over the standard families and the figure algorithm set, with
/// the figure-tuned per-dataset (rho, mu0).
pub fn default_matrix(dataset: DatasetId, workers: usize, iters: u64, seed: u64) -> MatrixSpec {
    let linear = dataset.task() == Task::Linear;
    let (rho, mu0) = match dataset {
        DatasetId::SynthLinear => (30.0, 0.0),
        DatasetId::BodyFat => (5.0, 0.0),
        DatasetId::SynthLogistic | DatasetId::Derm => (0.1, 1e-2),
    };
    MatrixSpec {
        dataset,
        workers,
        families: default_families(),
        algs: super::default_algs(linear),
        rho,
        mu0,
        iters_alt: iters,
        iters_jacobian: iters.saturating_mul(4),
        seed,
        target_gap: 1e-4,
    }
}

/// One family's slice of the matrix.
pub struct FamilyResult {
    pub family: TopologySpec,
    pub label: String,
    pub edges: usize,
    /// Same-group edges removed by the bipartition pass (0 for exact
    /// 2-colorings).
    pub dropped_edges: usize,
    pub traces: Vec<Trace>,
    pub summary: Table,
}

/// Run the whole matrix as one flattened (family × algorithm) job list
/// on the shared sweep pool.  Results come back in family order with
/// traces labelled `"ALG (family)"`.
pub fn run_matrix(spec: &MatrixSpec, exec: &ExecOptions) -> Result<Vec<FamilyResult>, String> {
    let ds = data::load(spec.dataset, spec.seed);
    let built: Vec<gen::BuiltTopology> = spec
        .families
        .iter()
        .map(|f| gen::build(f, spec.workers, spec.seed))
        .collect::<Result<_, _>>()?;
    let problems: Vec<Problem> = built
        .iter()
        .map(|b| Problem::new(&ds, &b.topology, spec.rho, spec.mu0, spec.seed))
        .collect();
    let mut jobs = Vec::new();
    for ((fam, b), problem) in spec.families.iter().zip(&built).zip(&problems) {
        for alg in &spec.algs {
            let iters = match alg.schedule {
                Schedule::Alternating => spec.iters_alt,
                Schedule::Jacobian => spec.iters_jacobian,
            };
            jobs.push(SweepJob {
                problem,
                topo: &b.topology,
                alg: Some(alg),
                iters,
                seed: spec.seed,
                rename: Some(fam.label()),
            });
        }
    }
    let mut traces = run_jobs(&jobs, exec).into_iter();
    Ok(spec
        .families
        .iter()
        .zip(&built)
        .map(|(fam, b)| {
            let t: Vec<Trace> = traces.by_ref().take(spec.algs.len()).collect();
            FamilyResult {
                family: *fam,
                label: fam.label(),
                edges: b.topology.edges().len(),
                dropped_edges: b.dropped_edges,
                summary: summarize(&t, spec.target_gap),
                traces: t,
            }
        })
        .collect())
}

/// Structural + spectral properties of every family at this `(n, seed)`:
/// what the bipartition kept/dropped and the Theorem-3 constants.
pub fn properties_table(
    workers: usize,
    families: &[TopologySpec],
    seed: u64,
) -> Result<Table, String> {
    let mut t = Table::new(&[
        "topology",
        "edges",
        "dropped",
        "heads/tails",
        "ratio",
        "sigma_max(C)",
        "sigma~_min(M-)",
    ]);
    for fam in families {
        let b = gen::build(fam, workers, seed)?;
        let c = spectral::constants(&b.topology);
        t.row(&[
            fam.label(),
            b.topology.edges().len().to_string(),
            b.dropped_edges.to_string(),
            format!("{}/{}", b.topology.heads().len(), b.topology.tails().len()),
            format!("{:.3}", b.topology.connectivity_ratio()),
            format!("{:.3}", c.sigma_max_c),
            format!("{:.3}", c.sigma_min_nz_m_minus),
        ]);
    }
    Ok(t)
}

/// The (churn × straggler × topology × algorithm) robustness matrix.
///
/// Every cell re-runs the same problem under a generated worker-churn
/// schedule ([`ChurnSchedule::generate`]) and, optionally, a rotating
/// straggler subset ([`LinkKind::Straggler`]), with the bounded-staleness
/// round policy keeping censored workers from starving.  The output is a
/// degradation surface: final gap and cost-to-target per perturbation
/// level, serialized by [`churn_matrix_csv`].
#[derive(Clone, Debug)]
pub struct ChurnMatrixSpec {
    pub dataset: DatasetId,
    pub workers: usize,
    pub families: Vec<TopologySpec>,
    pub algs: Vec<AlgSpec>,
    /// Fraction of workers that get one leave→rejoin cycle (0 = static;
    /// see [`ChurnSchedule::generate`]).
    pub churn_rates: Vec<f64>,
    /// Fraction of workers in the rotating straggler subset (0 = none).
    pub straggler_fracs: Vec<f64>,
    /// Bounded-staleness refresh threshold applied to every cell.
    pub staleness_bound: Option<u64>,
    pub rho: f64,
    pub mu0: f64,
    pub iters: u64,
    pub seed: u64,
    pub target_gap: f64,
}

/// The acceptance grid: {chain, torus, smallworld} × {GADMM, CQ-GGADMM}
/// under increasing churn, with and without stragglers.
pub fn default_churn_matrix(
    dataset: DatasetId,
    workers: usize,
    iters: u64,
    seed: u64,
) -> ChurnMatrixSpec {
    let (rho, mu0) = match dataset {
        DatasetId::SynthLinear => (30.0, 0.0),
        DatasetId::BodyFat => (5.0, 0.0),
        DatasetId::SynthLogistic | DatasetId::Derm => (0.1, 1e-2),
    };
    let linear = dataset.task() == Task::Linear;
    let (tau0, xi) = if linear { (0.1, 0.8) } else { (0.3, 0.9) };
    ChurnMatrixSpec {
        dataset,
        workers,
        families: vec![
            TopologySpec::Chain,
            TopologySpec::Grid { torus: true },
            TopologySpec::SmallWorld { k: 4, beta: 0.1 },
        ],
        algs: vec![AlgSpec::gadmm_chain(), AlgSpec::cq_ggadmm(tau0, xi, 0.995, 2)],
        churn_rates: vec![0.0, 0.5, 1.0],
        straggler_fracs: vec![0.0, 0.25],
        staleness_bound: Some(4),
        rho,
        mu0,
        iters,
        seed,
        target_gap: 1e-4,
    }
}

/// One cell of the churn matrix: a full trace plus its coordinates.
pub struct ChurnCell {
    pub family: String,
    pub alg: String,
    pub churn_rate: f64,
    pub straggler_frac: f64,
    pub trace: Trace,
    /// Simulated on-air wall clock ([`Run::sim_time_s`]) at the first
    /// recorded point with `loss_gap <= target_gap`; `None` when the
    /// cell never reached the target.  Captured while stepping because
    /// [`crate::metrics::TracePoint`] does not carry the clock.
    pub sim_time_to_target: Option<f64>,
}

/// Run the robustness matrix as one flattened job list on the sweep
/// pool.  Unlike [`run_matrix`], every cell carries its *own*
/// [`ExecOptions`] (churn schedule, straggler link, staleness bound), so
/// the jobs are built eagerly and only the engine runs are pooled.
/// Deterministic for a fixed spec regardless of thread count.
pub fn run_churn_matrix(
    spec: &ChurnMatrixSpec,
    exec: &ExecOptions,
) -> Result<Vec<ChurnCell>, String> {
    let ds = data::load(spec.dataset, spec.seed);
    let built: Vec<gen::BuiltTopology> = spec
        .families
        .iter()
        .map(|f| gen::build(f, spec.workers, spec.seed))
        .collect::<Result<_, _>>()?;
    let problems: Vec<Problem> = built
        .iter()
        .map(|b| Problem::new(&ds, &b.topology, spec.rho, spec.mu0, spec.seed))
        .collect();
    let sweep = match (exec.backend, exec.sweep_threads) {
        (crate::solver::Backend::Pjrt, _) => {
            return Err("the churn matrix re-derives solver degrees; use the native backend".into())
        }
        (_, 0) if exec.threads > 1 => 1,
        (_, 0) => crate::parallel::default_threads(),
        (_, t) => t,
    };
    struct Cell<'a> {
        problem: &'a Problem,
        topo: &'a crate::graph::Topology,
        family: String,
        alg: &'a AlgSpec,
        rate: f64,
        frac: f64,
        opts: ExecOptions,
    }
    let mut cells = Vec::new();
    for ((fam, b), problem) in spec.families.iter().zip(&built).zip(&problems) {
        for alg in &spec.algs {
            for &rate in &spec.churn_rates {
                for &frac in &spec.straggler_fracs {
                    let churn = (rate > 0.0)
                        .then(|| ChurnSchedule::generate(spec.workers, spec.iters, rate, spec.seed));
                    let link = (frac > 0.0).then(|| LinkKind::Straggler {
                        frac,
                        rotate_every: 25,
                        base_s: 2e-3,
                        alpha: 1.5,
                    });
                    let opts = exec
                        .clone()
                        .with_seed(spec.seed)
                        .with_sweep_threads(1)
                        .with_churn(churn)
                        .with_link(link.or(exec.link))
                        .with_staleness_bound(spec.staleness_bound);
                    cells.push(Cell {
                        problem,
                        topo: &b.topology,
                        family: fam.label(),
                        alg,
                        rate,
                        frac,
                        opts,
                    });
                }
            }
        }
    }
    let sweep = sweep.min(cells.len()).max(1);
    let run_threads = if sweep > 1 { 1 } else { exec.threads };
    let mut pool = (sweep > 1).then(|| crate::parallel::WorkerPool::new(sweep));
    let target = spec.target_gap;
    let traces = crate::parallel::map_maybe_pool(pool.as_mut(), cells.len(), |j| {
        let c = &cells[j];
        let opts = c.opts.clone().with_threads(run_threads);
        let mut run = Run::new(c.problem.clone(), c.topo.clone(), c.alg.clone(), opts);
        // step (rather than batch-run) so the simulated clock can be read
        // the moment the gap first crosses the target — trace points do
        // not carry sim time, and stepping is bit-identical to `run()`
        let mut sim_to_target = None;
        for _ in 0..spec.iters {
            run.step();
            if sim_to_target.is_none()
                && run.trace().points.last().is_some_and(|p| p.loss_gap <= target)
            {
                sim_to_target = Some(run.sim_time_s());
            }
        }
        (run.trace().clone(), sim_to_target)
    });
    Ok(cells
        .into_iter()
        .zip(traces)
        .map(|(c, (trace, sim_time_to_target))| ChurnCell {
            family: c.family,
            alg: c.alg.name.clone(),
            churn_rate: c.rate,
            straggler_frac: c.frac,
            trace,
            sim_time_to_target,
        })
        .collect())
}

/// Serialize the degradation surface: one CSV row per cell, empty
/// to-target fields when the cell never reached `target_gap`.  The
/// to-target columns are the fig. 5 comparison families — iterations,
/// rounds, bits, energy, and simulated wall clock — so the robustness
/// sweep and the per-layer bit-allocation ablation share one schema.
/// `sim_s_to_target` comes from [`ChurnCell::sim_time_to_target`],
/// which was captured at the spec's own target; pass the same
/// `target_gap` here for a coherent row.
pub fn churn_matrix_csv(cells: &[ChurnCell], target_gap: f64) -> String {
    let mut s = String::from(
        "family,algorithm,churn_rate,straggler_frac,final_gap,\
         iters_to_target,rounds_to_target,mbits_to_target,energy_j_to_target,\
         sim_s_to_target\n",
    );
    for c in cells {
        // family labels can carry commas (e.g. `smallworld:4,0.1`)
        let family = if c.family.contains(',') {
            format!("\"{}\"", c.family)
        } else {
            c.family.clone()
        };
        let _ = write!(
            s,
            "{},{},{},{},{:e}",
            family, c.alg, c.churn_rate, c.straggler_frac,
            c.trace.last_gap()
        );
        match c.trace.first_below(target_gap) {
            Some(p) => {
                let _ = write!(
                    s,
                    ",{},{},{},{:e},",
                    p.iteration,
                    p.cum_rounds,
                    p.cum_bits as f64 / 1e6,
                    p.cum_energy_j
                );
                match c.sim_time_to_target {
                    Some(t) => {
                        let _ = writeln!(s, "{t:e}");
                    }
                    None => s.push('\n'),
                }
            }
            None => s.push_str(",,,,,\n"),
        }
    }
    s
}

/// Per (family, algorithm) degradation summary of a churn-matrix run.
pub fn churn_summary(cells: &[ChurnCell], target_gap: f64) -> Table {
    let mut t = Table::new(&[
        "family",
        "algorithm",
        "churn",
        "stragglers",
        "final gap",
        &format!("iters to {target_gap:.0e}"),
    ]);
    for c in cells {
        t.row(&[
            c.family.clone(),
            c.alg.clone(),
            format!("{}", c.churn_rate),
            format!("{}", c.straggler_frac),
            format!("{:.2e}", c.trace.last_gap()),
            match c.trace.first_below(target_gap) {
                Some(p) => p.iteration.to_string(),
                None => "—".into(),
            },
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_matrix_end_to_end() {
        let mut spec = default_matrix(DatasetId::SynthLinear, 6, 300, 31);
        // two contrasting families and the two cheapest algorithms keep
        // the test fast while exercising rename + per-family summaries
        spec.families = vec![TopologySpec::Ring, TopologySpec::SmallWorld { k: 4, beta: 0.3 }];
        spec.algs = vec![AlgSpec::ggadmm(), AlgSpec::cq_ggadmm(0.1, 0.8, 0.995, 2)];
        spec.target_gap = 1e-2;
        let results = run_matrix(&spec, &ExecOptions::default()).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].label, "ring");
        assert_eq!(results[0].dropped_edges, 0, "even ring is exact");
        assert!(results[1].dropped_edges > 0, "small world is not bipartite");
        for fr in &results {
            assert_eq!(fr.traces.len(), 2);
            for tr in &fr.traces {
                assert!(tr.algorithm.ends_with(&format!("({})", fr.label)), "{}", tr.algorithm);
                assert!(tr.last_gap().is_finite());
                let p = tr.points.last().unwrap();
                assert!(p.cum_bits > 0 && p.cum_energy_j.is_finite());
            }
            // GGADMM reaches the relaxed target on these tiny problems
            assert!(
                fr.traces[0].first_below(1e-2).is_some(),
                "{}: {:.3e}",
                fr.traces[0].algorithm,
                fr.traces[0].last_gap()
            );
        }
    }

    #[test]
    fn matrix_is_deterministic() {
        let mut spec = default_matrix(DatasetId::SynthLinear, 6, 60, 7);
        spec.families = vec![TopologySpec::Grid { torus: true }];
        spec.algs = vec![AlgSpec::cq_ggadmm(0.1, 0.8, 0.995, 2)];
        let a = run_matrix(&spec, &ExecOptions::default()).unwrap();
        let b = run_matrix(&spec, &ExecOptions::default()).unwrap();
        let (ta, tb) = (&a[0].traces[0], &b[0].traces[0]);
        assert_eq!(ta.points.len(), tb.points.len());
        for (x, y) in ta.points.iter().zip(&tb.points) {
            assert_eq!(x.loss_gap.to_bits(), y.loss_gap.to_bits());
            assert_eq!(x.cum_bits, y.cum_bits);
        }
    }

    #[test]
    fn tiny_churn_matrix_degrades_gracefully() {
        let mut spec = default_churn_matrix(DatasetId::SynthLinear, 6, 120, 17);
        spec.families = vec![TopologySpec::Chain, TopologySpec::SmallWorld { k: 4, beta: 0.1 }];
        spec.churn_rates = vec![0.0, 0.5];
        spec.straggler_fracs = vec![0.0];
        spec.target_gap = 1e-2;
        let cells = run_churn_matrix(&spec, &ExecOptions::default()).unwrap();
        // families × algs × rates × fracs
        assert_eq!(cells.len(), 2 * 2 * 2);
        for c in &cells {
            assert!(
                c.trace.last_gap().is_finite(),
                "{} ({}) churn={} diverged",
                c.alg,
                c.family,
                c.churn_rate
            );
        }
        // the static chain GADMM baseline still converges
        let base = cells
            .iter()
            .find(|c| c.family == "chain" && c.alg == "GADMM" && c.churn_rate == 0.0)
            .unwrap();
        assert!(base.trace.last_gap() < 1e-2, "{:.2e}", base.trace.last_gap());
        // a converged cell carries the wall-clock-to-target reading, and
        // it is consistent with the trace's first-below point
        assert!(base.trace.first_below(spec.target_gap).is_some());
        let sim = base.sim_time_to_target.expect("converged cell has a sim time");
        assert!(sim > 0.0 && sim.is_finite(), "{sim}");
        let csv = churn_matrix_csv(&cells, spec.target_gap);
        assert!(csv.starts_with("family,algorithm,churn_rate,straggler_frac"));
        let header = csv.lines().next().unwrap();
        assert!(header.ends_with("energy_j_to_target,sim_s_to_target"), "{header}");
        assert_eq!(csv.lines().count(), 1 + cells.len());
        assert!(csv.contains("chain,GADMM,0,0,"), "{csv}");
        // every data row has the full column count, reached target or not
        let cols = header.split(',').count();
        for line in csv.lines().skip(1) {
            let fields = if line.starts_with('"') {
                // quoted family label carries one comma
                line.split(',').count() - 1
            } else {
                line.split(',').count()
            };
            assert_eq!(fields, cols, "{line}");
        }
        // comma-bearing family labels are quoted so columns stay aligned
        assert!(csv.contains("\"smallworld:4,0.1\",GADMM,"), "{csv}");
        let table = churn_summary(&cells, spec.target_gap).render();
        assert!(table.contains("CQ-GGADMM"), "{table}");
    }

    #[test]
    fn churn_matrix_is_deterministic_across_sweep_layouts() {
        let mut spec = default_churn_matrix(DatasetId::SynthLinear, 6, 60, 9);
        spec.families = vec![TopologySpec::Grid { torus: true }];
        spec.churn_rates = vec![0.5];
        spec.straggler_fracs = vec![0.25];
        let serial = ExecOptions::default().with_sweep_threads(1);
        let pooled = ExecOptions::default().with_sweep_threads(2);
        let a = run_churn_matrix(&spec, &serial).unwrap();
        let b = run_churn_matrix(&spec, &pooled).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                x.trace.last_gap().to_bits(),
                y.trace.last_gap().to_bits(),
                "{} ({})",
                x.alg,
                x.family
            );
            let (px, py) = (x.trace.points.last().unwrap(), y.trace.points.last().unwrap());
            assert_eq!(px.cum_bits, py.cum_bits);
            assert_eq!(px.cum_rounds, py.cum_rounds);
            assert_eq!(
                x.sim_time_to_target.map(f64::to_bits),
                y.sim_time_to_target.map(f64::to_bits)
            );
        }
    }

    #[test]
    fn properties_table_covers_all_families() {
        let t = properties_table(12, &default_families(), 3).unwrap();
        let s = t.render();
        for label in ["chain", "ring", "star", "grid", "torus"] {
            assert!(s.contains(label), "missing {label} in\n{s}");
        }
        for label in ["er:", "smallworld:", "geometric:", "random:"] {
            assert!(s.contains(label), "missing {label} in\n{s}");
        }
    }
}
