//! The (topology × algorithm) scenario matrix.
//!
//! The paper evaluates its algorithm family on one generated topology
//! shape; this module crosses every [`TopologySpec`] family from
//! [`crate::graph::gen`] with the full algorithm set and reports, per
//! family, the paper's comparison axes (iterations / rounds / bits /
//! energy to the reference accuracy) plus the bipartition report
//! (kept/dropped edges) and the spectral constants driving the
//! Theorem-3 rate.  Runs are flattened into one job list on the shared
//! sweep scheduler ([`super::ExecOptions::sweep_threads`]), so the
//! whole matrix saturates the machine and stays bit-deterministic.

use super::{run_jobs, summarize, ExecOptions, SweepJob};
use crate::algs::{AlgSpec, Problem, Schedule};
use crate::config::{DatasetId, Task, TopologySpec};
use crate::data;
use crate::graph::{gen, spectral};
use crate::io::Table;
use crate::metrics::Trace;

/// Full setup of a matrix sweep.
#[derive(Clone, Debug)]
pub struct MatrixSpec {
    pub dataset: DatasetId,
    pub workers: usize,
    pub families: Vec<TopologySpec>,
    pub algs: Vec<AlgSpec>,
    pub rho: f64,
    pub mu0: f64,
    /// Iteration budget for alternating (GGADMM-family) schedules.
    pub iters_alt: u64,
    /// Iteration budget for the Jacobian C-ADMM baseline.
    pub iters_jacobian: u64,
    pub seed: u64,
    pub target_gap: f64,
}

/// The standard family zoo: one representative per generator, the
/// random parameters chosen so every family is connected and
/// interestingly sparse at the default N.
pub fn default_families() -> Vec<TopologySpec> {
    vec![
        TopologySpec::Chain,
        TopologySpec::Ring,
        TopologySpec::Star,
        TopologySpec::Grid { torus: false },
        TopologySpec::Grid { torus: true },
        TopologySpec::ErdosRenyi { p: 0.15 },
        TopologySpec::SmallWorld { k: 4, beta: 0.1 },
        TopologySpec::Geometric { radius_m: 200.0 },
        TopologySpec::RandomBipartite { p: 0.3 },
    ]
}

/// Matrix over the standard families and the figure algorithm set, with
/// the figure-tuned per-dataset (rho, mu0).
pub fn default_matrix(dataset: DatasetId, workers: usize, iters: u64, seed: u64) -> MatrixSpec {
    let linear = dataset.task() == Task::Linear;
    let (rho, mu0) = match dataset {
        DatasetId::SynthLinear => (30.0, 0.0),
        DatasetId::BodyFat => (5.0, 0.0),
        DatasetId::SynthLogistic | DatasetId::Derm => (0.1, 1e-2),
    };
    MatrixSpec {
        dataset,
        workers,
        families: default_families(),
        algs: super::default_algs(linear),
        rho,
        mu0,
        iters_alt: iters,
        iters_jacobian: iters.saturating_mul(4),
        seed,
        target_gap: 1e-4,
    }
}

/// One family's slice of the matrix.
pub struct FamilyResult {
    pub family: TopologySpec,
    pub label: String,
    pub edges: usize,
    /// Same-group edges removed by the bipartition pass (0 for exact
    /// 2-colorings).
    pub dropped_edges: usize,
    pub traces: Vec<Trace>,
    pub summary: Table,
}

/// Run the whole matrix as one flattened (family × algorithm) job list
/// on the shared sweep pool.  Results come back in family order with
/// traces labelled `"ALG (family)"`.
pub fn run_matrix(spec: &MatrixSpec, exec: &ExecOptions) -> Result<Vec<FamilyResult>, String> {
    let ds = data::load(spec.dataset, spec.seed);
    let built: Vec<gen::BuiltTopology> = spec
        .families
        .iter()
        .map(|f| gen::build(f, spec.workers, spec.seed))
        .collect::<Result<_, _>>()?;
    let problems: Vec<Problem> = built
        .iter()
        .map(|b| Problem::new(&ds, &b.topology, spec.rho, spec.mu0, spec.seed))
        .collect();
    let mut jobs = Vec::new();
    for ((fam, b), problem) in spec.families.iter().zip(&built).zip(&problems) {
        for alg in &spec.algs {
            let iters = match alg.schedule {
                Schedule::Alternating => spec.iters_alt,
                Schedule::Jacobian => spec.iters_jacobian,
            };
            jobs.push(SweepJob {
                problem,
                topo: &b.topology,
                alg: Some(alg),
                iters,
                seed: spec.seed,
                rename: Some(fam.label()),
            });
        }
    }
    let mut traces = run_jobs(&jobs, exec).into_iter();
    Ok(spec
        .families
        .iter()
        .zip(&built)
        .map(|(fam, b)| {
            let t: Vec<Trace> = traces.by_ref().take(spec.algs.len()).collect();
            FamilyResult {
                family: *fam,
                label: fam.label(),
                edges: b.topology.edges().len(),
                dropped_edges: b.dropped_edges,
                summary: summarize(&t, spec.target_gap),
                traces: t,
            }
        })
        .collect())
}

/// Structural + spectral properties of every family at this `(n, seed)`:
/// what the bipartition kept/dropped and the Theorem-3 constants.
pub fn properties_table(
    workers: usize,
    families: &[TopologySpec],
    seed: u64,
) -> Result<Table, String> {
    let mut t = Table::new(&[
        "topology",
        "edges",
        "dropped",
        "heads/tails",
        "ratio",
        "sigma_max(C)",
        "sigma~_min(M-)",
    ]);
    for fam in families {
        let b = gen::build(fam, workers, seed)?;
        let c = spectral::constants(&b.topology);
        t.row(&[
            fam.label(),
            b.topology.edges().len().to_string(),
            b.dropped_edges.to_string(),
            format!("{}/{}", b.topology.heads().len(), b.topology.tails().len()),
            format!("{:.3}", b.topology.connectivity_ratio()),
            format!("{:.3}", c.sigma_max_c),
            format!("{:.3}", c.sigma_min_nz_m_minus),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_matrix_end_to_end() {
        let mut spec = default_matrix(DatasetId::SynthLinear, 6, 300, 31);
        // two contrasting families and the two cheapest algorithms keep
        // the test fast while exercising rename + per-family summaries
        spec.families = vec![TopologySpec::Ring, TopologySpec::SmallWorld { k: 4, beta: 0.3 }];
        spec.algs = vec![AlgSpec::ggadmm(), AlgSpec::cq_ggadmm(0.1, 0.8, 0.995, 2)];
        spec.target_gap = 1e-2;
        let results = run_matrix(&spec, &ExecOptions::default()).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].label, "ring");
        assert_eq!(results[0].dropped_edges, 0, "even ring is exact");
        assert!(results[1].dropped_edges > 0, "small world is not bipartite");
        for fr in &results {
            assert_eq!(fr.traces.len(), 2);
            for tr in &fr.traces {
                assert!(tr.algorithm.ends_with(&format!("({})", fr.label)), "{}", tr.algorithm);
                assert!(tr.last_gap().is_finite());
                let p = tr.points.last().unwrap();
                assert!(p.cum_bits > 0 && p.cum_energy_j.is_finite());
            }
            // GGADMM reaches the relaxed target on these tiny problems
            assert!(
                fr.traces[0].first_below(1e-2).is_some(),
                "{}: {:.3e}",
                fr.traces[0].algorithm,
                fr.traces[0].last_gap()
            );
        }
    }

    #[test]
    fn matrix_is_deterministic() {
        let mut spec = default_matrix(DatasetId::SynthLinear, 6, 60, 7);
        spec.families = vec![TopologySpec::Grid { torus: true }];
        spec.algs = vec![AlgSpec::cq_ggadmm(0.1, 0.8, 0.995, 2)];
        let a = run_matrix(&spec, &ExecOptions::default()).unwrap();
        let b = run_matrix(&spec, &ExecOptions::default()).unwrap();
        let (ta, tb) = (&a[0].traces[0], &b[0].traces[0]);
        assert_eq!(ta.points.len(), tb.points.len());
        for (x, y) in ta.points.iter().zip(&tb.points) {
            assert_eq!(x.loss_gap.to_bits(), y.loss_gap.to_bits());
            assert_eq!(x.cum_bits, y.cum_bits);
        }
    }

    #[test]
    fn properties_table_covers_all_families() {
        let t = properties_table(12, &default_families(), 3).unwrap();
        let s = t.render();
        for label in ["chain", "ring", "star", "grid", "torus"] {
            assert!(s.contains(label), "missing {label} in\n{s}");
        }
        for label in ["er:", "smallworld:", "geometric:", "random:"] {
            assert!(s.contains(label), "missing {label} in\n{s}");
        }
    }
}
