//! Sensitivity / ablation studies for the design choices DESIGN.md calls
//! out: the penalty `rho`, the censoring threshold `tau0` (§4 discusses
//! both extremes), the decay `xi`, the initial bit width `bits0`, and —
//! on the multi-block MLP model — the per-layer bit allocation.

use crate::algs::{AlgSpec, Problem, Run, RunOptions};
use crate::config::ModelSpec;
use crate::data;
use crate::graph::Topology;
use crate::io::Table;

/// One sweep point.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub label: String,
    pub iters_to_target: Option<u64>,
    pub rounds_to_target: Option<u64>,
    pub bits_to_target: Option<u64>,
    pub final_gap: f64,
}

fn run_point(problem: &Problem, topo: &Topology, spec: AlgSpec, iters: u64, target: f64, label: String) -> SweepPoint {
    let mut run = Run::new(problem.clone(), topo.clone(), spec, RunOptions::default());
    let trace = run.run(iters);
    let at = trace.first_below(target);
    SweepPoint {
        label,
        iters_to_target: at.map(|p| p.iteration),
        rounds_to_target: at.map(|p| p.cum_rounds),
        bits_to_target: at.map(|p| p.cum_bits),
        final_gap: trace.last_gap(),
    }
}

/// Standard workload for the sweeps: synth-linear, N = 16, p = 0.3.
fn workload(rho: f64, seed: u64) -> (Problem, Topology) {
    let topo = Topology::random_bipartite(16, 0.3, seed);
    let ds = data::load(crate::config::DatasetId::SynthLinear, seed);
    let problem = Problem::new(&ds, &topo, rho, 0.0, seed);
    (problem, topo)
}

/// rho sensitivity of GGADMM (too small => slow consensus; very large =>
/// over-damped but still convergent for this closed-form workload).
pub fn rho_sweep(rhos: &[f64], iters: u64, seed: u64) -> Vec<SweepPoint> {
    rhos.iter()
        .map(|&rho| {
            let (p, t) = workload(rho, seed);
            run_point(&p, &t, AlgSpec::ggadmm(), iters, 1e-4, format!("rho={rho}"))
        })
        .collect()
}

/// tau0 sensitivity of C-GGADMM (paper §4: tau0 = 0 recovers GGADMM; very
/// large tau0 censors almost everything and slows convergence).
pub fn tau0_sweep(tau0s: &[f64], xi: f64, iters: u64, seed: u64) -> Vec<SweepPoint> {
    let (p, t) = workload(30.0, seed);
    tau0s
        .iter()
        .map(|&tau0| {
            let spec = if tau0 == 0.0 {
                AlgSpec::ggadmm()
            } else {
                AlgSpec::c_ggadmm(tau0, xi)
            };
            run_point(&p, &t, spec, iters, 1e-4, format!("tau0={tau0}"))
        })
        .collect()
}

/// bits0 sensitivity of CQ-GGADMM.
pub fn bits_sweep(bits: &[u32], iters: u64, seed: u64) -> Vec<SweepPoint> {
    let (p, t) = workload(30.0, seed);
    bits.iter()
        .map(|&b| {
            let spec = AlgSpec::cq_ggadmm(0.1, 0.8, 0.995, b);
            run_point(&p, &t, spec, iters, 1e-4, format!("bits0={b}"))
        })
        .collect()
}

/// MLP variant of the standard workload: same graph and dataset, the
/// two-block (hidden layer W, output head v) model.
fn mlp_workload(hidden: usize, seed: u64) -> (Problem, Topology) {
    let topo = Topology::random_bipartite(16, 0.3, seed);
    let ds = data::load(crate::config::DatasetId::SynthLinear, seed);
    let problem = Problem::with_model(&ds, &topo, 30.0, 0.0, seed, ModelSpec::Mlp { hidden })
        .expect("synth-linear supports the MLP model");
    (problem, topo)
}

/// Per-layer bit-allocation ablation on the two-block MLP: each
/// allocation `[w_bits, v_bits]` runs Q-GGADMM with that split, and the
/// first allocation also runs the QDGD first-order baseline at the same
/// split.  This is the `--bits0 N,M` axis of the experiment matrix.
pub fn bits_alloc_sweep(
    allocs: &[Vec<u32>],
    hidden: usize,
    iters: u64,
    target: f64,
    seed: u64,
) -> Vec<SweepPoint> {
    let (p, t) = mlp_workload(hidden, seed);
    let label_of = |alloc: &[u32]| {
        alloc.iter().map(u32::to_string).collect::<Vec<_>>().join(",")
    };
    let mut pts: Vec<SweepPoint> = allocs
        .iter()
        .map(|alloc| {
            let spec =
                AlgSpec::q_ggadmm(0.995, alloc[0]).with_bits_split(Some(alloc.clone()));
            run_point(&p, &t, spec, iters, target, format!("bits={}", label_of(alloc)))
        })
        .collect();
    if let Some(alloc) = allocs.first() {
        let spec = AlgSpec::qdgd(0.995, alloc[0]).with_bits_split(Some(alloc.clone()));
        pts.push(run_point(
            &p,
            &t,
            spec,
            iters,
            target,
            format!("QDGD bits={}", label_of(alloc)),
        ));
    }
    pts
}

/// Component ablation at fixed parameters: none / censor / quant / both.
pub fn component_ablation(iters: u64, seed: u64) -> Vec<SweepPoint> {
    let (p, t) = workload(30.0, seed);
    vec![
        run_point(&p, &t, AlgSpec::ggadmm(), iters, 1e-4, "baseline (GGADMM)".into()),
        run_point(&p, &t, AlgSpec::c_ggadmm(0.1, 0.8), iters, 1e-4, "+censoring".into()),
        run_point(&p, &t, AlgSpec::q_ggadmm(0.995, 2), iters, 1e-4, "+quantization".into()),
        run_point(&p, &t, AlgSpec::cq_ggadmm(0.1, 0.8, 0.995, 2), iters, 1e-4, "+both (CQ)".into()),
    ]
}

/// Render any sweep as a table.
pub fn render(title: &str, points: &[SweepPoint]) -> Table {
    let mut t = Table::new(&[title, "iters@1e-4", "rounds@1e-4", "bits@1e-4", "final gap"]);
    for p in points {
        let f = |v: Option<u64>| v.map(|x| x.to_string()).unwrap_or_else(|| "—".into());
        t.row(&[
            p.label.clone(),
            f(p.iters_to_target),
            f(p.rounds_to_target),
            f(p.bits_to_target),
            format!("{:.2e}", p.final_gap),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(debug_assertions, ignore = "paper-scale sweep; run with --release")]
    fn huge_tau0_slows_convergence() {
        // §4: "if tau0 is very large, most workers will be censored ...
        // which will slow down the convergence"
        let pts = tau0_sweep(&[0.0, 0.1, 50.0], 0.95, 250, 41);
        let base = pts[0].iters_to_target.expect("GGADMM");
        let mild = pts[1].iters_to_target.expect("mild censoring");
        let huge = pts[2].iters_to_target.unwrap_or(u64::MAX);
        assert!(mild <= base * 2, "mild {mild} vs base {base}");
        assert!(huge > mild, "huge {huge} vs mild {mild}");
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "paper-scale sweep; run with --release")]
    fn component_ablation_shape() {
        let pts = component_ablation(250, 42);
        let bits = |i: usize| pts[i].bits_to_target.expect(&pts[i].label);
        // quantization (alone or with censoring) must slash the bits
        assert!(bits(2) * 3 < bits(0));
        assert!(bits(3) * 3 < bits(0));
        // censoring must cut rounds
        let rounds = |i: usize| pts[i].rounds_to_target.expect(&pts[i].label);
        assert!(rounds(1) < rounds(0));
    }

    #[test]
    fn bits_alloc_sweep_covers_allocations_and_qdgd_baseline() {
        let pts = bits_alloc_sweep(&[vec![4, 4], vec![6, 2]], 4, 25, 1e-1, 33);
        assert_eq!(pts.len(), 3, "two allocations + the QDGD baseline");
        assert_eq!(pts[0].label, "bits=4,4");
        assert_eq!(pts[1].label, "bits=6,2");
        assert_eq!(pts[2].label, "QDGD bits=4,4");
        for p in &pts {
            assert!(p.final_gap.is_finite(), "{}: {}", p.label, p.final_gap);
        }
    }

    #[test]
    fn render_handles_missing_targets() {
        let pts = vec![SweepPoint {
            label: "x".into(),
            iters_to_target: None,
            rounds_to_target: None,
            bits_to_target: None,
            final_gap: 1.0,
        }];
        let s = render("sweep", &pts).render();
        assert!(s.contains("—"));
    }
}
