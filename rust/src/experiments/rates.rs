//! Empirical check of Theorems 2 and 3: the linear convergence rate and
//! its dependence on the topology spectrum.
//!
//! For a strongly convex linear-regression workload we (a) fit the
//! empirical contraction factor of `||theta^k - theta*||_F^2` per
//! iteration, (b) evaluate the Theorem-3 bound `(1+delta_2)/2` from the
//! topology's spectral constants, and (c) verify the empirical rate beats
//! the bound (the bound is conservative) and reacts to graph density the
//! way the theory predicts.

use crate::algs::{AlgSpec, Problem, Run, RunOptions};
use crate::data::synthetic;
use crate::graph::{spectral, Topology};
use crate::io::Table;
use crate::linalg::symmetric_eigen;

/// One topology's rate study.
#[derive(Clone, Debug)]
pub struct RateStudy {
    pub p: f64,
    pub sigma_max_c: f64,
    pub sigma_min_nz_m_minus: f64,
    pub empirical_rate: f64,
    pub bound_rate: f64,
}

/// Strong-convexity / Lipschitz moduli of the decentralized least-squares
/// objective: extremal eigenvalues of the per-worker Gram matrices.
fn moduli(problem: &Problem) -> (f64, f64) {
    let mut mu = f64::INFINITY;
    let mut l: f64 = 0.0;
    for sh in &problem.shards {
        let eig = symmetric_eigen(&sh.x.gram());
        mu = mu.min(eig[0].max(1e-9));
        l = l.max(*eig.last().unwrap());
    }
    (mu, l)
}

/// Run the study over a set of connectivity ratios.
pub fn study(ps: &[f64], workers: usize, seed: u64, iters: u64) -> Vec<RateStudy> {
    let ds = synthetic::linear_dataset(workers * 20, 8, seed);
    ps.iter()
        .map(|&p| {
            let topo = Topology::random_bipartite(workers, p, seed);
            let problem = Problem::new(&ds, &topo, 1.0, 0.0, seed);
            let (mu, l) = moduli(&problem);
            let consts = spectral::constants(&topo);
            let bound = spectral::theorem3_rate_bound(&topo, mu, l, 0.05, 0.9, 0.02, 2.0);
            let mut run = Run::new(
                problem,
                topo,
                AlgSpec::ggadmm(),
                RunOptions { seed, ..RunOptions::default() },
            );
            let trace = run.run(iters);
            RateStudy {
                p,
                sigma_max_c: consts.sigma_max_c,
                sigma_min_nz_m_minus: consts.sigma_min_nz_m_minus,
                empirical_rate: trace.fitted_rate().unwrap_or(f64::NAN),
                bound_rate: bound.rate,
            }
        })
        .collect()
}

/// Render the study as a table.
pub fn render(studies: &[RateStudy]) -> Table {
    let mut t = Table::new(&[
        "connectivity p",
        "sigma_max(C)",
        "sigma~_min(M-)",
        "empirical rate",
        "Thm-3 bound",
    ]);
    for s in studies {
        t.row(&[
            format!("{:.2}", s.p),
            format!("{:.3}", s.sigma_max_c),
            format!("{:.3}", s.sigma_min_nz_m_minus),
            format!("{:.4}", s.empirical_rate),
            format!("{:.4}", s.bound_rate),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empirical_rate_is_linear_and_beats_bound() {
        let studies = study(&[0.3], 10, 5, 120);
        let s = &studies[0];
        assert!(
            s.empirical_rate > 0.0 && s.empirical_rate < 1.0,
            "rate={}",
            s.empirical_rate
        );
        // the Theorem-3 bound is conservative: empirical <= bound
        assert!(
            s.empirical_rate <= s.bound_rate + 1e-6,
            "empirical {} vs bound {}",
            s.empirical_rate,
            s.bound_rate
        );
    }

    #[test]
    fn denser_graphs_converge_faster() {
        let studies = study(&[0.15, 0.6], 12, 6, 150);
        assert!(
            studies[1].empirical_rate <= studies[0].empirical_rate + 0.02,
            "dense {} vs sparse {}",
            studies[1].empirical_rate,
            studies[0].empirical_rate
        );
    }
}
