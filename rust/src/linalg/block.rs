//! Cache-blocked dense kernels under [`Mat`] and [`super::Cholesky`].
//!
//! Every O(d^2)/O(d^3) dense operation of the crate routes through here:
//! SYRK-style Gram products (plain, row-wise and weighted — the logistic
//! Newton Hessian), GEMM, matvec, and a right-looking blocked Cholesky
//! with blocked triangular solves (single- and multi-RHS).  The former
//! scalar triple-loops are retained on [`Mat`]/[`super::Cholesky`] as
//! `*_scalar` reference implementations; differential tests
//! (`tests/blocked_linalg.rs`, `tests/simd_kernels.rs`) lock
//! blocked-vs-scalar and vectorized-vs-scalar agreement and
//! `bench_hotpath` asserts the blocked kernels win at d in
//! {50, 200, 500, 1000, 10000}.
//!
//! Design (CPU, f64, no external BLAS):
//! * **Panel packing** — Gram products pack [`PANEL`] rows of `X`
//!   transposed into a contiguous scratch, so the reduction dimension of
//!   every inner product is a unit-stride slice.
//! * **Register tiling** — symmetric-product and trailing-update kernels
//!   process 2x2 output tiles with four accumulator lanes each (see
//!   `dot2x2`): input rows are reused across two outputs and the 16
//!   independent accumulator chains keep the FMA pipeline full.
//! * **Cache tiling** — output blocks of [`TILE`] x [`TILE`] keep both
//!   packed operand panels resident while a tile is produced.
//! * **No data-dependent branches** — unlike the seed kernels, the inner
//!   loops never test operand values (`if a == 0.0 { continue; }` is a
//!   mispredict on dense data); work is bounded by shapes alone.
//!
//! # Kernel tiers
//!
//! Each micro-kernel exists in two tiers dispatched through
//! [`KernelTier`] (resolved once at startup; `CQ_KERNEL_TIER` /
//! `--kernel-tier` override): the 4-wide unrolled **scalar** reference
//! (bit-exact baseline, fallback on non-AVX2 machines) and explicit
//! **AVX2+FMA** intrinsics.  AVX2 lane layout:
//!
//! * `dot2x2` keeps its four accumulators as one `__m256d` each (the
//!   scalar tier's four `[f64; 4]` lane arrays map 1:1 onto the four
//!   vector registers); 4-element steps, scalar tail.
//! * plain reductions (`util::dot`, per-row matvec) run two independent
//!   4-lane FMA chains over 8-element steps, combined as one vector add
//!   + the `(l0+l1)+(l2+l3)` horizontal sum — the matvec micro-kernel
//!   replicates `util`'s layout exactly so `matvec == per-row dot`
//!   stays **bit-identical within each tier**.
//! * `axpy`-family updates are multiply-then-add (no FMA), so all
//!   triangular solves/backsubstitutions and `cholesky_inverse_into`
//!   are bit-identical **across** tiers; `axpy2` (GEMM) does use FMA.
//!
//! Cross-tier agreement of the FMA reductions is rounding-level only
//! (tolerance property tests); per-tier results are deterministic.
//!
//! # Pool-parallel trailing updates
//!
//! Large SYRK/GEMM trailing updates and the blocked-Cholesky trailing
//! block dispatch over the shared [`crate::parallel::WorkerPool`]
//! (`CQ_LINALG_THREADS`, [`crate::parallel::kernel_threads`]) once the
//! parallel dimension reaches [`PAR_MIN_DIM`] (resp. [`PAR_MIN_FLOPS`] /
//! [`PAR_MIN_MV`] flop floors for GEMM/matvec).  Jobs own disjoint
//! output row stripes and the per-entry reduction order is unchanged, so
//! pooled results are **bit-identical to the serial path** on every
//! tier.
//!
//! Tuning: the block constants below were chosen for ~32 KiB L1 / 512 KiB
//! L2 caches (packed panel rows of `PANEL * 8` = 512 B; a 2x[`TILE`] tile
//! pair is 32 KiB).  AVX2 re-tune notes: the micro-kernels are bound by
//! two loads per FMA, so widening [`TILE`] helps only once the packed
//! panels outgrow L1; re-run `cargo bench --bench bench_hotpath` after
//! any change — the `blocked vs scalar` and `simd vs scalar` shootouts
//! print the speedup per dimension (see README §Performance).

use super::Mat;
use crate::parallel::{with_kernel_pool, SyncPtr, WorkerPool};
use crate::util::tier::{kernel_tier, KernelTier};
use crate::util::{axpy, axpy_with_tier, dot_with_tier};

/// Rows of `X` packed per Gram panel (reduction-dimension blocking).
pub const PANEL: usize = 64;

/// Output tile edge for symmetric products and trailing updates.
pub const TILE: usize = 32;

/// Columns processed per GEMM reduction block.
pub const GEMM_KC: usize = 64;

/// Diagonal-block edge of the right-looking blocked Cholesky.
pub const CHOL_NB: usize = 32;

/// Minimum extent of the parallel dimension before a SYRK/Cholesky
/// trailing update pays the pool dispatch barrier.
pub const PAR_MIN_DIM: usize = 256;

/// Minimum GEMM flop volume (`2 m n k`) before output rows are pooled.
pub const PAR_MIN_FLOPS: usize = 1 << 24;

/// Minimum matvec flop volume (`2 rows cols`) before row quads are
/// pooled.
pub const PAR_MIN_MV: usize = 1 << 22;

/// Output rows per pooled GEMM job (preserves reduction-panel reuse
/// while keeping claim overhead negligible).
const PAR_ROWBLOCK: usize = 16;

/// Rows per pooled Cholesky panel-solve job.
const PAR_CHOLBLOCK: usize = 32;

/// Execution context for the blocked kernels: instruction tier plus
/// whether large trailing updates may dispatch over the shared kernel
/// pool.  Pooled and serial runs produce identical bits on every tier;
/// explicit-tier contexts exist so differential tests and bench
/// shootouts never mutate process-global state.
#[derive(Clone, Copy, Debug)]
pub struct KernelCtx {
    /// Instruction tier for every reduction in the call.
    pub tier: KernelTier,
    /// Allow pool-parallel trailing updates (subject to the size
    /// thresholds above and pool availability).
    pub pooled: bool,
}

impl KernelCtx {
    /// The process-wide default: resolved tier, pooling allowed.
    pub fn auto() -> KernelCtx {
        KernelCtx { tier: kernel_tier(), pooled: true }
    }

    /// Explicit tier, pooling allowed.
    pub fn with_tier(tier: KernelTier) -> KernelCtx {
        KernelCtx { tier, pooled: true }
    }

    /// Explicit tier, strictly single-threaded.
    pub fn serial(tier: KernelTier) -> KernelCtx {
        KernelCtx { tier, pooled: false }
    }
}

/// Whether `tier` may take the AVX2 paths on this machine.
#[cfg(target_arch = "x86_64")]
#[inline]
fn use_avx2(tier: KernelTier) -> bool {
    tier == KernelTier::Avx2 && crate::util::tier::avx2_available()
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn use_avx2(_tier: KernelTier) -> bool {
    false
}

/// Packed row `i` of a panel: `p` contiguous reduction elements.
#[inline]
fn prow(pack: &[f64], i: usize, p: usize) -> &[f64] {
    &pack[i * p..(i + 1) * p]
}

/// Shared slice over columns `c0..c1` of row `i`, through the raw base
/// pointer of a row-major matrix (used inside pooled jobs where the
/// borrow checker cannot see row disjointness).
///
/// # Safety
/// The indexed range must lie inside the allocation and no concurrent
/// write may overlap columns `c0..c1` of row `i` for the slice's
/// lifetime.
#[inline]
unsafe fn raw_row<'a>(base: *const f64, cols: usize, i: usize, c0: usize, c1: usize) -> &'a [f64] {
    std::slice::from_raw_parts(base.add(i * cols + c0), c1 - c0)
}

/// 2x2 register-tiled micro-kernel (tier-dispatched): the four inner
/// products between rows `{a0, a1}` and `{b0, b1}`.
#[inline]
fn dot2x2(
    tier: KernelTier,
    a0: &[f64],
    a1: &[f64],
    b0: &[f64],
    b1: &[f64],
) -> (f64, f64, f64, f64) {
    if use_avx2(tier) {
        // SAFETY: `use_avx2` confirmed AVX2+FMA at runtime.
        return unsafe { avx2::dot2x2(a0, a1, b0, b1) };
    }
    dot2x2_scalar(a0, a1, b0, b1)
}

/// Scalar reference 2x2 micro-kernel: each product accumulates over
/// four independent lanes (16 chains total) so the pipeline never
/// stalls on a single additive dependency.
#[inline]
fn dot2x2_scalar(a0: &[f64], a1: &[f64], b0: &[f64], b1: &[f64]) -> (f64, f64, f64, f64) {
    let mut c00 = [0.0f64; 4];
    let mut c01 = [0.0f64; 4];
    let mut c10 = [0.0f64; 4];
    let mut c11 = [0.0f64; 4];
    let mut ka0 = a0.chunks_exact(4);
    let mut ka1 = a1.chunks_exact(4);
    let mut kb0 = b0.chunks_exact(4);
    let mut kb1 = b1.chunks_exact(4);
    for (((x0, x1), y0), y1) in (&mut ka0).zip(&mut ka1).zip(&mut kb0).zip(&mut kb1) {
        for t in 0..4 {
            c00[t] += x0[t] * y0[t];
            c01[t] += x0[t] * y1[t];
            c10[t] += x1[t] * y0[t];
            c11[t] += x1[t] * y1[t];
        }
    }
    let mut s00 = (c00[0] + c00[1]) + (c00[2] + c00[3]);
    let mut s01 = (c01[0] + c01[1]) + (c01[2] + c01[3]);
    let mut s10 = (c10[0] + c10[1]) + (c10[2] + c10[3]);
    let mut s11 = (c11[0] + c11[1]) + (c11[2] + c11[3]);
    for (((x0, x1), y0), y1) in ka0
        .remainder()
        .iter()
        .zip(ka1.remainder())
        .zip(kb0.remainder())
        .zip(kb1.remainder())
    {
        s00 += x0 * y0;
        s01 += x0 * y1;
        s10 += x1 * y0;
        s11 += x1 * y1;
    }
    (s00, s01, s10, s11)
}

/// `out[j] += a0 * b0[j] + a1 * b1[j]` (tier-dispatched) — the two-row
/// GEMM update that halves output-row traffic relative to two separate
/// axpys.
#[inline]
fn axpy2(tier: KernelTier, out: &mut [f64], a0: f64, b0: &[f64], a1: f64, b1: &[f64]) {
    if use_avx2(tier) {
        // SAFETY: `use_avx2` confirmed AVX2+FMA at runtime.
        unsafe { avx2::axpy2(out, a0, b0, a1, b1) };
        return;
    }
    axpy2_scalar(out, a0, b0, a1, b1)
}

/// Scalar reference two-row GEMM update.
#[inline]
fn axpy2_scalar(out: &mut [f64], a0: f64, b0: &[f64], a1: f64, b1: &[f64]) {
    let mut co = out.chunks_exact_mut(4);
    let mut c0 = b0.chunks_exact(4);
    let mut c1 = b1.chunks_exact(4);
    for ((o, x0), x1) in (&mut co).zip(&mut c0).zip(&mut c1) {
        for t in 0..4 {
            o[t] += a0 * x0[t] + a1 * x1[t];
        }
    }
    for ((o, x0), x1) in co
        .into_remainder()
        .iter_mut()
        .zip(c0.remainder())
        .zip(c1.remainder())
    {
        *o += a0 * x0 + a1 * x1;
    }
}

/// Pack `p` rows of `x` starting at `p0`, transposed (column-major over
/// the panel): `pack[j*p + r] = w_r * x[p0+r, j]` with `w_r = 1` when no
/// weights are given, `sqrt(w[p0+r])` otherwise (so the SYRK kernel
/// computes `sum w_r x_r x_r^T` without a per-element weight multiply).
fn pack_panel(x: &Mat, p0: usize, p: usize, w: Option<&[f64]>, pack: &mut [f64]) {
    for r in 0..p {
        let row = x.row(p0 + r);
        let scale = match w {
            Some(w) => w[p0 + r].sqrt(),
            None => 1.0,
        };
        for (j, &v) in row.iter().enumerate() {
            pack[j * p + r] = scale * v;
        }
    }
}

/// One [`TILE`]-stripe of the upper-triangle SYRK: the diagonal tile at
/// `i0` plus every full rectangle to its right.  Writes only rows
/// `i0..min(i0+TILE, n)` of the `n x cols` output at `out`, so
/// concurrent stripes are disjoint; the arithmetic is identical whether
/// stripes run serially or pooled.
///
/// # Safety
/// `out` must point at an `n x cols` row-major buffer; no concurrent
/// access may touch rows `i0..min(i0+TILE, n)`; `row(i)` must not read
/// from `out`.
unsafe fn syrk_upper_stripe<'a, F: Fn(usize) -> &'a [f64]>(
    tier: KernelTier,
    row: &F,
    n: usize,
    cols: usize,
    out: *mut f64,
    i0: usize,
) {
    let i1 = (i0 + TILE).min(n);
    // diagonal tile: plain dots over the triangle
    for i in i0..i1 {
        for j in i..i1 {
            let v = dot_with_tier(tier, row(i), row(j));
            *out.add(i * cols + j) += v;
        }
    }
    // off-diagonal tiles: full rectangles, 2x2 register tiling
    let mut j0 = i1;
    while j0 < n {
        let j1 = (j0 + TILE).min(n);
        rect_tile_acc(tier, row, i0, i1, j0, j1, cols, out);
        j0 = j1;
    }
}

/// `out[i0..i1, j0..j1] += row_i . row_j` over a full rectangular tile.
///
/// # Safety
/// Same contract as [`syrk_upper_stripe`] (which is the only caller).
unsafe fn rect_tile_acc<'a, F: Fn(usize) -> &'a [f64]>(
    tier: KernelTier,
    row: &F,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    cols: usize,
    out: *mut f64,
) {
    let mut i = i0;
    while i + 2 <= i1 {
        let pi0 = row(i);
        let pi1 = row(i + 1);
        let mut j = j0;
        while j + 2 <= j1 {
            let (s00, s01, s10, s11) = dot2x2(tier, pi0, pi1, row(j), row(j + 1));
            *out.add(i * cols + j) += s00;
            *out.add(i * cols + j + 1) += s01;
            *out.add((i + 1) * cols + j) += s10;
            *out.add((i + 1) * cols + j + 1) += s11;
            j += 2;
        }
        if j < j1 {
            let pj = row(j);
            *out.add(i * cols + j) += dot_with_tier(tier, pi0, pj);
            *out.add((i + 1) * cols + j) += dot_with_tier(tier, pi1, pj);
        }
        i += 2;
    }
    if i < i1 {
        let pi = row(i);
        for j in j0..j1 {
            *out.add(i * cols + j) += dot_with_tier(tier, pi, row(j));
        }
    }
}

/// Accumulate the upper triangle of the self-product of rows
/// `row(0..n)` into `out`, one [`TILE`]-stripe at a time — pooled over
/// stripes when a pool is supplied and `n >= PAR_MIN_DIM` (stripes own
/// disjoint output rows, so pooled == serial bitwise).  Shared by the
/// packed-panel Gram kernel ([`gram_into`] via `prow`) and the row-Gram
/// kernel ([`gram_rows_into`] via `Mat::row`).
fn syrk_upper_tiled<'a, F: Fn(usize) -> &'a [f64] + Sync>(
    tier: KernelTier,
    row: &F,
    n: usize,
    out: &mut Mat,
    pool: Option<&mut WorkerPool>,
) {
    let cols = out.cols();
    let base = out.data_mut().as_mut_ptr();
    let stripes = n.div_ceil(TILE);
    match pool {
        Some(pool) if n >= PAR_MIN_DIM => {
            let ptr = SyncPtr(base);
            pool.for_each(stripes, |s| {
                // SAFETY: stripe `s` writes only rows s*TILE..(s+1)*TILE
                // and each stripe is claimed by exactly one job; `row`
                // reads a different buffer than `out`.
                unsafe { syrk_upper_stripe(tier, row, n, cols, ptr.0, s * TILE) };
            });
        }
        _ => {
            for s in 0..stripes {
                // SAFETY: exclusive access through `&mut Mat`.
                unsafe { syrk_upper_stripe(tier, row, n, cols, base, s * TILE) };
            }
        }
    }
}

/// Mirror the upper triangle of a square matrix onto the lower.
fn mirror_upper(out: &mut Mat) {
    let n = out.rows();
    for i in 0..n {
        for j in 0..i {
            out[(i, j)] = out[(j, i)];
        }
    }
}

/// Blocked Gram product `out = x^T x` (SYRK; upper triangle computed
/// through packed panels + the 2x2 micro-kernel, then mirrored).
pub fn gram_into(x: &Mat, out: &mut Mat) {
    gram_into_ctx(KernelCtx::auto(), x, out);
}

/// [`gram_into`] under an explicit [`KernelCtx`].
pub fn gram_into_ctx(ctx: KernelCtx, x: &Mat, out: &mut Mat) {
    let d = x.cols();
    let mut pack = vec![0.0; d * PANEL];
    if ctx.pooled && d >= PAR_MIN_DIM {
        with_kernel_pool(|pool| weighted_gram_with_pack(ctx.tier, x, None, out, &mut pack, pool));
    } else {
        weighted_gram_with_pack(ctx.tier, x, None, out, &mut pack, None);
    }
}

/// Blocked weighted Gram product `out = sum_r w[r] * x_r x_r^T`
/// (`w[r] >= 0`; the weights enter the packed panel as `sqrt(w)` so the
/// micro-kernel is identical to the unweighted case).  `pack` is a
/// caller-held scratch buffer (resized here), so per-Newton-step Hessian
/// assemblies allocate nothing.
pub fn weighted_gram_into(x: &Mat, w: &[f64], out: &mut Mat, pack: &mut Vec<f64>) {
    weighted_gram_into_ctx(KernelCtx::auto(), x, w, out, pack);
}

/// [`weighted_gram_into`] under an explicit [`KernelCtx`].
pub fn weighted_gram_into_ctx(
    ctx: KernelCtx,
    x: &Mat,
    w: &[f64],
    out: &mut Mat,
    pack: &mut Vec<f64>,
) {
    assert_eq!(w.len(), x.rows(), "weighted_gram weight length mismatch");
    if ctx.pooled && x.cols() >= PAR_MIN_DIM {
        with_kernel_pool(|pool| weighted_gram_with_pack(ctx.tier, x, Some(w), out, pack, pool));
    } else {
        weighted_gram_with_pack(ctx.tier, x, Some(w), out, pack, None);
    }
}

fn weighted_gram_with_pack(
    tier: KernelTier,
    x: &Mat,
    w: Option<&[f64]>,
    out: &mut Mat,
    pack: &mut Vec<f64>,
    mut pool: Option<&mut WorkerPool>,
) {
    let (s, d) = (x.rows(), x.cols());
    assert_eq!(out.rows(), d, "gram output dimension mismatch");
    assert_eq!(out.cols(), d, "gram output dimension mismatch");
    out.data_mut().iter_mut().for_each(|v| *v = 0.0);
    pack.resize(d * PANEL, 0.0);
    let mut p0 = 0;
    while p0 < s {
        let p = PANEL.min(s - p0);
        pack_panel(x, p0, p, w, pack);
        let panel: &[f64] = pack;
        syrk_upper_tiled(tier, &|i| prow(panel, i, p), d, out, pool.as_deref_mut());
        p0 += p;
    }
    mirror_upper(out);
}

/// Blocked row-Gram product `out = x x^T` (rows are already contiguous,
/// so no packing is needed; tiled 2x2 micro-kernel over row pairs).
/// Used by the spectral tools on wide matrices (e.g. the paper's signed
/// incidence matrix `M_-`).
pub fn gram_rows_into(x: &Mat, out: &mut Mat) {
    gram_rows_into_ctx(KernelCtx::auto(), x, out);
}

/// [`gram_rows_into`] under an explicit [`KernelCtx`].
pub fn gram_rows_into_ctx(ctx: KernelCtx, x: &Mat, out: &mut Mat) {
    let s = x.rows();
    assert_eq!(out.rows(), s, "gram_rows output dimension mismatch");
    assert_eq!(out.cols(), s, "gram_rows output dimension mismatch");
    out.data_mut().iter_mut().for_each(|v| *v = 0.0);
    if ctx.pooled && s >= PAR_MIN_DIM {
        with_kernel_pool(|pool| syrk_upper_tiled(ctx.tier, &|i| x.row(i), s, out, pool));
    } else {
        syrk_upper_tiled(ctx.tier, &|i| x.row(i), s, out, None);
    }
    mirror_upper(out);
}

/// GEMM over output rows `r0..r1`: per row, reduction blocks of
/// [`GEMM_KC`] in ascending order, two reduction rows per pass — the
/// per-row operation order is independent of how rows are grouped, so
/// serial and pooled runs are bit-identical.
///
/// # Safety
/// `base` must point at the `a.rows() x b.cols()` row-major output; no
/// concurrent access may touch rows `r0..r1`.
unsafe fn matmul_rows(
    tier: KernelTier,
    a: &Mat,
    b: &Mat,
    base: *mut f64,
    r0: usize,
    r1: usize,
) {
    let k = a.cols();
    let m = b.cols();
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + GEMM_KC).min(k);
        for i in r0..r1 {
            let arow = &a.row(i)[k0..k1];
            let orow = std::slice::from_raw_parts_mut(base.add(i * m), m);
            let mut kk = 0;
            while kk + 2 <= arow.len() {
                axpy2(
                    tier,
                    orow,
                    arow[kk],
                    b.row(k0 + kk),
                    arow[kk + 1],
                    b.row(k0 + kk + 1),
                );
                kk += 2;
            }
            if kk < arow.len() {
                axpy_with_tier(tier, orow, arow[kk], b.row(k0 + kk));
            }
        }
        k0 = k1;
    }
}

/// Blocked GEMM `out = a * b` (k-blocked, two reduction rows per pass
/// through the output row; branch-free inner loops).  `out` must not
/// alias `a` or `b`.
pub fn matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    matmul_into_ctx(KernelCtx::auto(), a, b, out);
}

/// [`matmul_into`] under an explicit [`KernelCtx`].
pub fn matmul_into_ctx(ctx: KernelCtx, a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols(), b.rows(), "matmul dimension mismatch");
    assert_eq!(out.rows(), a.rows(), "matmul output dimension mismatch");
    assert_eq!(out.cols(), b.cols(), "matmul output dimension mismatch");
    out.data_mut().iter_mut().for_each(|v| *v = 0.0);
    let rows = a.rows();
    let flops = 2 * rows * a.cols() * b.cols();
    let base = out.data_mut().as_mut_ptr();
    if ctx.pooled && rows >= 2 * PAR_ROWBLOCK && flops >= PAR_MIN_FLOPS {
        with_kernel_pool(|pool| match pool {
            Some(pool) => {
                let ptr = SyncPtr(base);
                let blocks = rows.div_ceil(PAR_ROWBLOCK);
                pool.for_each(blocks, |blk| {
                    let r0 = blk * PAR_ROWBLOCK;
                    let r1 = (r0 + PAR_ROWBLOCK).min(rows);
                    // SAFETY: each row block is claimed by exactly one
                    // job; blocks partition 0..rows disjointly.
                    unsafe { matmul_rows(ctx.tier, a, b, ptr.0, r0, r1) };
                });
            }
            // SAFETY: exclusive access through `&mut Mat`.
            None => unsafe { matmul_rows(ctx.tier, a, b, base, 0, rows) },
        });
    } else {
        // SAFETY: exclusive access through `&mut Mat`.
        unsafe { matmul_rows(ctx.tier, a, b, base, 0, rows) };
    }
}

/// Matvec over row quads `q0..q1` (quad `q` covers rows
/// `4q..min(4q+4, rows)`): full quads through the four-rows-share-`v`
/// micro-kernel, the trailing partial quad row-by-row.  Per-row
/// accumulation matches `util::dot`'s layout on each tier, so results
/// are bit-identical to the row-by-row dot formulation (and pooled ==
/// serial bitwise).
///
/// # Safety
/// `base` must point at the length-`rows` output; no concurrent access
/// may touch rows `4*q0..min(4*q1, rows)`.
unsafe fn matvec_quads(
    tier: KernelTier,
    a: &Mat,
    v: &[f64],
    base: *mut f64,
    q0: usize,
    q1: usize,
) {
    let rows = a.rows();
    let n = a.cols();
    for q in q0..q1 {
        let i = 4 * q;
        if i + 4 <= rows {
            let vals = if use_avx2(tier) {
                // SAFETY: `use_avx2` confirmed AVX2+FMA at runtime.
                avx2::matvec4(a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3), v)
            } else {
                matvec4_scalar(a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3), v)
            };
            for (r, val) in vals.iter().enumerate() {
                *base.add(i + r) = *val;
            }
        } else {
            for r in i..rows {
                *base.add(r) = dot_with_tier(tier, a.row(r), v);
            }
        }
    }
}

/// Scalar reference four-row matvec micro-kernel: four rows share each
/// load of `v`; per-row accumulation order is exactly
/// [`crate::util::dot_scalar`]'s (four independent lanes, left-fold
/// tail, pairwise combine).
#[inline]
fn matvec4_scalar(r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64], v: &[f64]) -> [f64; 4] {
    let n = v.len();
    let (r0, r1, r2, r3) = (&r0[..n], &r1[..n], &r2[..n], &r3[..n]);
    let ch = n - n % 4;
    let mut acc = [[0.0f64; 4]; 4];
    let mut c = 0;
    while c < ch {
        for t in 0..4 {
            let vt = v[c + t];
            acc[0][t] += r0[c + t] * vt;
            acc[1][t] += r1[c + t] * vt;
            acc[2][t] += r2[c + t] * vt;
            acc[3][t] += r3[c + t] * vt;
        }
        c += 4;
    }
    let mut tail = [0.0f64; 4];
    while c < n {
        tail[0] += r0[c] * v[c];
        tail[1] += r1[c] * v[c];
        tail[2] += r2[c] * v[c];
        tail[3] += r3[c] * v[c];
        c += 1;
    }
    let mut out = [0.0f64; 4];
    for (r, t) in tail.iter().enumerate() {
        out[r] = (acc[r][0] + acc[r][1]) + (acc[r][2] + acc[r][3]) + t;
    }
    out
}

/// Blocked matvec `out = a * v`: four rows share each load of `v`.  The
/// per-row accumulation order is exactly [`crate::util::dot`]'s on the
/// same tier, so the result is bit-identical to the row-by-row dot
/// formulation.
pub fn matvec_into(a: &Mat, v: &[f64], out: &mut [f64]) {
    matvec_into_ctx(KernelCtx::auto(), a, v, out);
}

/// [`matvec_into`] under an explicit [`KernelCtx`].
pub fn matvec_into_ctx(ctx: KernelCtx, a: &Mat, v: &[f64], out: &mut [f64]) {
    let rows = a.rows();
    let n = a.cols();
    assert_eq!(v.len(), n, "matvec dimension mismatch");
    assert_eq!(out.len(), rows, "matvec output dimension mismatch");
    let quads = rows.div_ceil(4);
    let base = out.as_mut_ptr();
    if ctx.pooled && 2 * rows * n >= PAR_MIN_MV && quads >= 2 {
        with_kernel_pool(|pool| match pool {
            Some(pool) => {
                let ptr = SyncPtr(base);
                pool.for_each(quads, |q| {
                    // SAFETY: each quad is claimed by exactly one job;
                    // quads partition the output disjointly.
                    unsafe { matvec_quads(ctx.tier, a, v, ptr.0, q, q + 1) };
                });
            }
            // SAFETY: exclusive access through `&mut [f64]`.
            None => unsafe { matvec_quads(ctx.tier, a, v, base, 0, quads) },
        });
    } else {
        // SAFETY: exclusive access through `&mut [f64]`.
        unsafe { matvec_quads(ctx.tier, a, v, base, 0, quads) };
    }
}

/// Right-looking blocked Cholesky: factor `a` (SPD) into the lower
/// triangle of `l` (`l`'s upper triangle is never written).  Returns
/// `false` when a diagonal pivot is non-positive; `l` is then
/// unspecified until the next successful factorization.
///
/// Per [`CHOL_NB`]-wide panel: (1) factor the diagonal block in place
/// (left-looking, contiguous-prefix dots), (2) solve the sub-diagonal
/// panel against it, (3) subtract the panel's self-product from the
/// trailing lower triangle with the tiled 2x2 SYRK micro-kernel — so the
/// O(n^3) bulk runs on unit-stride slices of length [`CHOL_NB`].  Steps
/// (2)/(3) pool over row blocks / tile stripes while the trailing
/// dimension stays above [`PAR_MIN_DIM`] (disjoint row ownership; reads
/// are confined to panel columns finalized before the dispatch, so
/// pooled == serial bitwise).
pub fn cholesky_factor_blocked(a: &Mat, l: &mut Mat) -> bool {
    cholesky_factor_blocked_ctx(KernelCtx::auto(), a, l)
}

/// [`cholesky_factor_blocked`] under an explicit [`KernelCtx`].
pub fn cholesky_factor_blocked_ctx(ctx: KernelCtx, a: &Mat, l: &mut Mat) -> bool {
    if ctx.pooled && a.rows() >= PAR_MIN_DIM {
        with_kernel_pool(|pool| cholesky_factor_core(ctx.tier, a, l, pool))
    } else {
        cholesky_factor_core(ctx.tier, a, l, None)
    }
}

fn cholesky_factor_core(
    tier: KernelTier,
    a: &Mat,
    l: &mut Mat,
    mut pool: Option<&mut WorkerPool>,
) -> bool {
    let n = a.rows();
    debug_assert_eq!(a.cols(), n);
    debug_assert_eq!(l.rows(), n);
    debug_assert_eq!(l.cols(), n);
    for i in 0..n {
        let src = &a.row(i)[..=i];
        l.row_mut(i)[..=i].copy_from_slice(src);
    }
    let cols = l.cols();
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + CHOL_NB).min(n);
        // (1) diagonal block, left-looking within the panel (columns
        // < k0 were already subtracted by earlier trailing updates)
        for i in k0..k1 {
            for j in k0..=i {
                let s = dot_with_tier(tier, &l.row(i)[k0..j], &l.row(j)[k0..j]);
                let sum = l[(i, j)] - s;
                if i == j {
                    if sum <= 0.0 {
                        return false;
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        // (2) panel solve: L21 = A21 * L11^{-T} — row-parallel (each
        // row only reads its own prefix and the finalized panel rows)
        let base = l.data_mut().as_mut_ptr();
        match pool.as_deref_mut() {
            Some(pool) if n - k1 >= PAR_MIN_DIM => {
                let ptr = SyncPtr(base);
                let blocks = (n - k1).div_ceil(PAR_CHOLBLOCK);
                pool.for_each(blocks, |blk| {
                    let r0 = k1 + blk * PAR_CHOLBLOCK;
                    let r1 = (r0 + PAR_CHOLBLOCK).min(n);
                    // SAFETY: row blocks partition k1..n disjointly;
                    // reads touch only finalized panel rows < k1 and
                    // the writing row itself.
                    unsafe { chol_panel_solve_rows(tier, ptr.0, cols, k0, k1, r0, r1) };
                });
            }
            _ => {
                // SAFETY: exclusive access through `&mut Mat`.
                unsafe { chol_panel_solve_rows(tier, base, cols, k0, k1, k1, n) };
            }
        }
        // (3) trailing update: A22 (lower triangle) -= L21 L21^T —
        // stripe-parallel (stripes own disjoint rows; reads are
        // confined to panel columns k0..k1, never written here)
        match pool.as_deref_mut() {
            Some(pool) if n - k1 >= PAR_MIN_DIM => {
                let ptr = SyncPtr(base);
                let stripes = (n - k1).div_ceil(TILE);
                pool.for_each(stripes, |s| {
                    // heaviest stripes (largest i0) first for balance
                    let i0 = k1 + (stripes - 1 - s) * TILE;
                    // SAFETY: stripes partition rows k1..n disjointly.
                    unsafe { syrk_sub_stripe(tier, ptr.0, cols, n, k1, k0, k1, i0) };
                });
            }
            _ => {
                let mut i0 = k1;
                while i0 < n {
                    // SAFETY: exclusive access through `&mut Mat`.
                    unsafe { syrk_sub_stripe(tier, base, cols, n, k1, k0, k1, i0) };
                    i0 += TILE;
                }
            }
        }
        k0 = k1;
    }
    true
}

/// Panel-solve rows `r0..r1` of the blocked Cholesky: for each row,
/// `L[i, j] = (A[i, j] - L[i, k0..j] . L[j, k0..j]) / L[j, j]` over the
/// panel columns `j in k0..k1`.
///
/// # Safety
/// `base` must point at the `n x cols` row-major factor; rows `k0..k1`
/// must be finalized; no concurrent access may touch rows `r0..r1`.
unsafe fn chol_panel_solve_rows(
    tier: KernelTier,
    base: *mut f64,
    cols: usize,
    k0: usize,
    k1: usize,
    r0: usize,
    r1: usize,
) {
    for i in r0..r1 {
        for j in k0..k1 {
            let s = dot_with_tier(
                tier,
                raw_row(base, cols, i, k0, j),
                raw_row(base, cols, j, k0, j),
            );
            let ljj = *base.add(j * cols + j);
            let idx = i * cols + j;
            *base.add(idx) = (*base.add(idx) - s) / ljj;
        }
    }
}

/// One [`TILE`]-stripe of the Cholesky trailing update: subtract
/// `L[:, k0..k1] L[:, k0..k1]^T` from the lower triangle rows
/// `i0..min(i0+TILE, n)` of the trailing block `l[start.., start..]`
/// (2x2 micro-kernel on full rectangles, plain dots on
/// diagonal-crossing tiles).
///
/// # Safety
/// `base` must point at the `n x cols` row-major factor; writes stay in
/// rows `i0..min(i0+TILE, n)` at columns `>= start`; reads stay in
/// columns `k0..k1 <= start`, which no concurrent stripe writes.
unsafe fn syrk_sub_stripe(
    tier: KernelTier,
    base: *mut f64,
    cols: usize,
    n: usize,
    start: usize,
    k0: usize,
    k1: usize,
    i0: usize,
) {
    let i1 = (i0 + TILE).min(n);
    let mut j0 = start;
    while j0 < i1 {
        let j1 = (j0 + TILE).min(i1);
        if j1 <= i0 {
            // full rectangle below the diagonal
            let mut i = i0;
            while i + 2 <= i1 {
                let mut j = j0;
                while j + 2 <= j1 {
                    let (s00, s01, s10, s11) = dot2x2(
                        tier,
                        raw_row(base, cols, i, k0, k1),
                        raw_row(base, cols, i + 1, k0, k1),
                        raw_row(base, cols, j, k0, k1),
                        raw_row(base, cols, j + 1, k0, k1),
                    );
                    *base.add(i * cols + j) -= s00;
                    *base.add(i * cols + j + 1) -= s01;
                    *base.add((i + 1) * cols + j) -= s10;
                    *base.add((i + 1) * cols + j + 1) -= s11;
                    j += 2;
                }
                if j < j1 {
                    let s0 = dot_with_tier(
                        tier,
                        raw_row(base, cols, i, k0, k1),
                        raw_row(base, cols, j, k0, k1),
                    );
                    let s1 = dot_with_tier(
                        tier,
                        raw_row(base, cols, i + 1, k0, k1),
                        raw_row(base, cols, j, k0, k1),
                    );
                    *base.add(i * cols + j) -= s0;
                    *base.add((i + 1) * cols + j) -= s1;
                }
                i += 2;
            }
            if i < i1 {
                for j in j0..j1 {
                    let s = dot_with_tier(
                        tier,
                        raw_row(base, cols, i, k0, k1),
                        raw_row(base, cols, j, k0, k1),
                    );
                    *base.add(i * cols + j) -= s;
                }
            }
        } else {
            // diagonal-crossing tile: plain dots over the triangle
            for i in i0..i1 {
                let jmax = j1.min(i + 1);
                for j in j0..jmax {
                    let s = dot_with_tier(
                        tier,
                        raw_row(base, cols, i, k0, k1),
                        raw_row(base, cols, j, k0, k1),
                    );
                    *base.add(i * cols + j) -= s;
                }
            }
        }
        j0 = j1;
    }
}

/// Forward substitution `L y = b` (`y` into `out`; `b` and `out` must
/// not alias).  Each step is one unit-stride prefix dot (sequential
/// dependency — never pooled).
pub fn solve_lower(l: &Mat, b: &[f64], out: &mut [f64]) {
    solve_lower_with_tier(kernel_tier(), l, b, out);
}

/// [`solve_lower`] under an explicit tier.
pub fn solve_lower_with_tier(tier: KernelTier, l: &Mat, b: &[f64], out: &mut [f64]) {
    let n = l.rows();
    assert_eq!(b.len(), n, "solve dimension mismatch");
    assert_eq!(out.len(), n, "solve output dimension mismatch");
    for i in 0..n {
        let s = dot_with_tier(tier, &l.row(i)[..i], &out[..i]);
        out[i] = (b[i] - s) / l[(i, i)];
    }
}

/// Backward substitution `L^T x = y` in place over `out`, right-looking:
/// once `x[k]` is final, its contribution is pushed into all earlier
/// entries through one unit-stride axpy over row `k` of `L` — no strided
/// column walks (the seed implementation's backward pass read `L`
/// column-wise).  Built entirely on `axpy`, so the result is
/// bit-identical across kernel tiers.
pub fn solve_lower_transpose_in_place(l: &Mat, out: &mut [f64]) {
    let n = l.rows();
    assert_eq!(out.len(), n, "solve output dimension mismatch");
    for k in (0..n).rev() {
        let xk = out[k] / l[(k, k)];
        out[k] = xk;
        axpy(&mut out[..k], -xk, &l.row(k)[..k]);
    }
}

/// Multi-RHS solve `A X = B` with `A = L L^T`, in place over the columns
/// of `b` (`n x m`): one blocked forward + one blocked backward sweep,
/// all updates as unit-stride row axpys of width `m` — every element of
/// `L` is loaded once per sweep instead of once per right-hand side.
/// Built entirely on `axpy`, so the result is bit-identical across
/// kernel tiers.
pub fn solve_many_in_place(l: &Mat, b: &mut Mat) {
    let n = l.rows();
    assert_eq!(b.rows(), n, "solve_many dimension mismatch");
    let m = b.cols();
    // forward, left-looking: row i accumulates -L[i,j] * y_j for j < i
    for i in 0..n {
        let (done, rest) = b.data_mut().split_at_mut(i * m);
        let bi = &mut rest[..m];
        let li = l.row(i);
        for j in 0..i {
            axpy(bi, -li[j], &done[j * m..(j + 1) * m]);
        }
        let inv = 1.0 / li[i];
        for v in bi.iter_mut() {
            *v *= inv;
        }
    }
    // backward, right-looking: finalize x_k, push into earlier rows
    for k in (0..n).rev() {
        let (head, rest) = b.data_mut().split_at_mut(k * m);
        let bk = &mut rest[..m];
        let lk = l.row(k);
        let inv = 1.0 / lk[k];
        for v in bk.iter_mut() {
            *v *= inv;
        }
        for i in 0..k {
            axpy(&mut head[i * m..(i + 1) * m], -lk[i], bk);
        }
    }
}

/// Dense inverse `A^{-1} = (L L^T)^{-1}` into `out`, as one blocked
/// multi-RHS sweep over the identity.  The forward half exploits the
/// triangular structure of the intermediate `Y = L^{-1}` (row `j` of `Y`
/// is zero beyond column `j`), cutting its cost to n^3/6; the result is
/// mirrored at the end so the returned inverse is exactly symmetric.
/// Built entirely on `axpy`, so the result is bit-identical across
/// kernel tiers.
pub fn cholesky_inverse_into(l: &Mat, out: &mut Mat) {
    let n = l.rows();
    assert_eq!(out.rows(), n, "inverse output dimension mismatch");
    assert_eq!(out.cols(), n, "inverse output dimension mismatch");
    out.data_mut().iter_mut().for_each(|v| *v = 0.0);
    for i in 0..n {
        out[(i, i)] = 1.0;
    }
    // forward: Y = L^{-1} (lower triangular — restrict every axpy to the
    // structurally non-zero prefix)
    for i in 0..n {
        let (done, rest) = out.data_mut().split_at_mut(i * n);
        let yi = &mut rest[..n];
        let li = l.row(i);
        for j in 0..i {
            axpy(&mut yi[..=j], -li[j], &done[j * n..j * n + j + 1]);
        }
        let inv = 1.0 / li[i];
        for v in yi[..=i].iter_mut() {
            *v *= inv;
        }
    }
    // backward: X = L^{-T} Y (dense from the first finalized row on)
    for k in (0..n).rev() {
        let (head, rest) = out.data_mut().split_at_mut(k * n);
        let xk = &mut rest[..n];
        let lk = l.row(k);
        let inv = 1.0 / lk[k];
        for v in xk.iter_mut() {
            *v *= inv;
        }
        for i in 0..k {
            axpy(&mut head[i * n..(i + 1) * n], -lk[i], xk);
        }
    }
    // exact symmetry (the two halves agree to rounding; keep the lower)
    for i in 0..n {
        for j in 0..i {
            out[(j, i)] = out[(i, j)];
        }
    }
}

/// AVX2+FMA micro-kernels (see the module docs for the lane layout; the
/// `matvec4` accumulation must mirror `util::avx2::dot` exactly for the
/// per-tier `matvec == dot` bit-identity contract).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    /// Horizontal sum in the shared `(l0 + l1) + (l2 + l3)` order.
    ///
    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256d) -> f64 {
        let mut l = [0.0f64; 4];
        _mm256_storeu_pd(l.as_mut_ptr(), v);
        (l[0] + l[1]) + (l[2] + l[3])
    }

    /// 2x2 micro-kernel: four `__m256d` FMA accumulators (one per
    /// output), 4-element steps, scalar tail.
    ///
    /// # Safety
    /// Requires AVX2+FMA; all four slices must share one length.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot2x2(
        a0: &[f64],
        a1: &[f64],
        b0: &[f64],
        b1: &[f64],
    ) -> (f64, f64, f64, f64) {
        let n = a0.len();
        let (p0, p1) = (a0.as_ptr(), a1.as_ptr());
        let (q0, q1) = (b0.as_ptr(), b1.as_ptr());
        let mut c00 = _mm256_setzero_pd();
        let mut c01 = _mm256_setzero_pd();
        let mut c10 = _mm256_setzero_pd();
        let mut c11 = _mm256_setzero_pd();
        let mut i = 0usize;
        while i + 4 <= n {
            let x0 = _mm256_loadu_pd(p0.add(i));
            let x1 = _mm256_loadu_pd(p1.add(i));
            let y0 = _mm256_loadu_pd(q0.add(i));
            let y1 = _mm256_loadu_pd(q1.add(i));
            c00 = _mm256_fmadd_pd(x0, y0, c00);
            c01 = _mm256_fmadd_pd(x0, y1, c01);
            c10 = _mm256_fmadd_pd(x1, y0, c10);
            c11 = _mm256_fmadd_pd(x1, y1, c11);
            i += 4;
        }
        let mut s00 = hsum(c00);
        let mut s01 = hsum(c01);
        let mut s10 = hsum(c10);
        let mut s11 = hsum(c11);
        while i < n {
            let (x0, x1) = (*p0.add(i), *p1.add(i));
            let (y0, y1) = (*q0.add(i), *q1.add(i));
            s00 += x0 * y0;
            s01 += x0 * y1;
            s10 += x1 * y0;
            s11 += x1 * y1;
            i += 1;
        }
        (s00, s01, s10, s11)
    }

    /// Two-row GEMM update `out += a0*b0 + a1*b1` (FMA on the second
    /// product).
    ///
    /// # Safety
    /// Requires AVX2+FMA; `b0`/`b1` must be at least `out.len()` long.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy2(out: &mut [f64], a0: f64, b0: &[f64], a1: f64, b1: &[f64]) {
        let n = out.len();
        let po = out.as_mut_ptr();
        let (p0, p1) = (b0.as_ptr(), b1.as_ptr());
        let va0 = _mm256_set1_pd(a0);
        let va1 = _mm256_set1_pd(a1);
        let mut i = 0usize;
        while i + 4 <= n {
            let t = _mm256_fmadd_pd(
                va1,
                _mm256_loadu_pd(p1.add(i)),
                _mm256_mul_pd(va0, _mm256_loadu_pd(p0.add(i))),
            );
            _mm256_storeu_pd(po.add(i), _mm256_add_pd(_mm256_loadu_pd(po.add(i)), t));
            i += 4;
        }
        while i < n {
            *po.add(i) += a0 * *p0.add(i) + a1 * *p1.add(i);
            i += 1;
        }
    }

    /// Four-row matvec: two FMA chains per row over 8-element steps —
    /// per row this is exactly `util::avx2::dot`'s accumulation, so the
    /// results match the row-by-row dot bit-for-bit.
    ///
    /// # Safety
    /// Requires AVX2+FMA; every row must be at least `v.len()` long.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn matvec4(r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64], v: &[f64]) -> [f64; 4] {
        let n = v.len();
        let p = [r0.as_ptr(), r1.as_ptr(), r2.as_ptr(), r3.as_ptr()];
        let pv = v.as_ptr();
        let mut a0 = [_mm256_setzero_pd(); 4];
        let mut a1 = [_mm256_setzero_pd(); 4];
        let mut i = 0usize;
        while i + 8 <= n {
            let v0 = _mm256_loadu_pd(pv.add(i));
            let v1 = _mm256_loadu_pd(pv.add(i + 4));
            for r in 0..4 {
                a0[r] = _mm256_fmadd_pd(_mm256_loadu_pd(p[r].add(i)), v0, a0[r]);
                a1[r] = _mm256_fmadd_pd(_mm256_loadu_pd(p[r].add(i + 4)), v1, a1[r]);
            }
            i += 8;
        }
        let mut out = [0.0f64; 4];
        for r in 0..4 {
            let mut l = [0.0f64; 4];
            _mm256_storeu_pd(l.as_mut_ptr(), _mm256_add_pd(a0[r], a1[r]));
            let mut tail = 0.0;
            let mut c = i;
            while c < n {
                tail += *p[r].add(c) * *pv.add(c);
                c += 1;
            }
            out[r] = (l[0] + l[1]) + (l[2] + l[3]) + tail;
        }
        out
    }
}

/// Scalar delegates so non-x86 builds monomorphize the same call sites
/// (`use_avx2` is statically false there, so these never run).
#[cfg(not(target_arch = "x86_64"))]
mod avx2 {
    /// # Safety
    /// Trivially safe (scalar delegate); unreachable behind `use_avx2`.
    pub unsafe fn dot2x2(
        a0: &[f64],
        a1: &[f64],
        b0: &[f64],
        b1: &[f64],
    ) -> (f64, f64, f64, f64) {
        super::dot2x2_scalar(a0, a1, b0, b1)
    }

    /// # Safety
    /// Trivially safe (scalar delegate); unreachable behind `use_avx2`.
    pub unsafe fn axpy2(out: &mut [f64], a0: f64, b0: &[f64], a1: f64, b1: &[f64]) {
        super::axpy2_scalar(out, a0, b0, a1, b1)
    }

    /// # Safety
    /// Trivially safe (scalar delegate); unreachable behind `use_avx2`.
    pub unsafe fn matvec4(r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64], v: &[f64]) -> [f64; 4] {
        super::matvec4_scalar(r0, r1, r2, r3, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut m = Mat::zeros(r, c);
        for i in 0..r {
            for j in 0..c {
                m[(i, j)] = rng.normal();
            }
        }
        m
    }

    #[test]
    fn gram_matches_scalar_across_block_boundaries() {
        // hit every remainder path: below/at/above PANEL and TILE edges
        for &(s, d) in &[(1, 1), (3, 2), (65, 31), (64, 32), (130, 33), (7, 97), (200, 65)] {
            let x = random_mat(s, d, (s * 1000 + d) as u64);
            let blocked = x.gram();
            let scalar = x.gram_scalar();
            let tol = 1e-12 * (1.0 + scalar.max_abs());
            assert!(blocked.sub(&scalar).max_abs() < tol, "gram mismatch at s={s} d={d}");
            assert!(blocked.is_symmetric(0.0));
        }
    }

    #[test]
    fn weighted_gram_matches_direct_sum() {
        let mut rng = Pcg64::new(9);
        for &(s, d) in &[(5, 3), (70, 33), (129, 17)] {
            let x = random_mat(s, d, (s + d) as u64);
            let w: Vec<f64> = (0..s).map(|_| rng.uniform()).collect();
            let mut out = Mat::zeros(d, d);
            let mut pack = Vec::new();
            weighted_gram_into(&x, &w, &mut out, &mut pack);
            let mut direct = Mat::zeros(d, d);
            for r in 0..s {
                for i in 0..d {
                    for j in 0..d {
                        direct[(i, j)] += w[r] * x[(r, i)] * x[(r, j)];
                    }
                }
            }
            let tol = 1e-11 * (1.0 + direct.max_abs());
            assert!(out.sub(&direct).max_abs() < tol, "s={s} d={d}");
        }
    }

    #[test]
    fn gram_rows_matches_matmul_transpose() {
        for &(s, c) in &[(2, 5), (33, 64), (66, 7)] {
            let x = random_mat(s, c, (s * 7 + c) as u64);
            let fast = x.gram_rows();
            let slow = x.matmul_scalar(&x.t());
            let tol = 1e-12 * (1.0 + slow.max_abs());
            assert!(fast.sub(&slow).max_abs() < tol, "s={s} c={c}");
        }
    }

    #[test]
    fn matvec_into_bit_identical_to_dot_rows() {
        for &(r, c) in &[(1, 1), (4, 4), (5, 9), (9, 5), (130, 67)] {
            let a = random_mat(r, c, (r * 31 + c) as u64);
            let v: Vec<f64> = random_mat(1, c, c as u64).data().to_vec();
            let mut out = vec![0.0; r];
            matvec_into(&a, &v, &mut out);
            for i in 0..r {
                let want = crate::util::dot(a.row(i), &v);
                assert_eq!(out[i].to_bits(), want.to_bits(), "r={r} c={c} row {i}");
            }
        }
    }

    #[test]
    fn blocked_cholesky_round_trips() {
        for &n in &[1usize, 2, 31, 32, 33, 70] {
            let b = random_mat(n, n, n as u64);
            let a = b.t().matmul(&b).add_diag(n as f64 * 0.1);
            let mut l = Mat::zeros(n, n);
            assert!(cholesky_factor_blocked(&a, &mut l), "n={n}");
            let rec = l.matmul(&l.t());
            let tol = 1e-9 * (1.0 + a.max_abs());
            assert!(a.sub(&rec).max_abs() < tol, "n={n}");
        }
    }

    #[test]
    fn solve_many_matches_column_solves() {
        let n = 37;
        let m = 9;
        let b0 = random_mat(n, n, 5);
        let a = b0.t().matmul(&b0).add_diag(2.0);
        let ch = super::super::Cholesky::new(&a).unwrap();
        let rhs = random_mat(n, m, 6);
        let mut many = rhs.clone();
        solve_many_in_place(ch.l(), &mut many);
        for j in 0..m {
            let col: Vec<f64> = (0..n).map(|i| rhs[(i, j)]).collect();
            let x = ch.solve(&col);
            for i in 0..n {
                assert!(
                    (many[(i, j)] - x[i]).abs() < 1e-9 * (1.0 + x[i].abs()),
                    "col {j} row {i}"
                );
            }
        }
    }

    #[test]
    fn pooled_kernels_bit_identical_to_serial() {
        // above PAR_MIN_DIM so the pooled branch genuinely dispatches
        // (when kernel_threads() > 1); explicit-tier ctx keeps this
        // independent of process-global state
        let tier = kernel_tier();
        let d = PAR_MIN_DIM + 37;
        let x = random_mat(48, d, 11);
        let mut pooled = Mat::zeros(d, d);
        let mut serial = Mat::zeros(d, d);
        gram_into_ctx(KernelCtx::with_tier(tier), &x, &mut pooled);
        gram_into_ctx(KernelCtx::serial(tier), &x, &mut serial);
        assert_bits_eq(pooled.data(), serial.data(), "gram");

        let a = pooled.add_diag(d as f64);
        let mut lp = Mat::zeros(d, d);
        let mut ls = Mat::zeros(d, d);
        assert!(cholesky_factor_blocked_ctx(KernelCtx::with_tier(tier), &a, &mut lp));
        assert!(cholesky_factor_blocked_ctx(KernelCtx::serial(tier), &a, &mut ls));
        for i in 0..d {
            assert_bits_eq(&lp.row(i)[..=i], &ls.row(i)[..=i], "cholesky row");
        }

        // wide enough that 2*rows*cols crosses PAR_MIN_MV
        let (mr, mc) = (2048, 1200);
        let wide = random_mat(mr, mc, 13);
        let v: Vec<f64> = random_mat(1, mc, 17).data().to_vec();
        let mut mp = vec![0.0; mr];
        let mut ms = vec![0.0; mr];
        matvec_into_ctx(KernelCtx::with_tier(tier), &wide, &v, &mut mp);
        matvec_into_ctx(KernelCtx::serial(tier), &wide, &v, &mut ms);
        assert_bits_eq(&mp, &ms, "matvec");
    }

    fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
        assert_eq!(a.len(), b.len(), "{what} length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(x.to_bits() == y.to_bits(), "{what} [{i}]: {x:?} vs {y:?}");
        }
    }
}
