//! Cache-blocked dense kernels under [`Mat`] and [`super::Cholesky`].
//!
//! Every O(d^2)/O(d^3) dense operation of the crate routes through here:
//! SYRK-style Gram products (plain, row-wise and weighted — the logistic
//! Newton Hessian), GEMM, matvec, and a right-looking blocked Cholesky
//! with blocked triangular solves (single- and multi-RHS).  The former
//! scalar triple-loops are retained on [`Mat`]/[`super::Cholesky`] as
//! `*_scalar` reference implementations; differential tests
//! (`tests/blocked_linalg.rs`) lock blocked-vs-scalar agreement and
//! `bench_hotpath` asserts the blocked kernels win at d in {50, 200, 500}.
//!
//! Design (CPU, f64, no external BLAS):
//! * **Panel packing** — Gram products pack [`PANEL`] rows of `X`
//!   transposed into a contiguous scratch, so the reduction dimension of
//!   every inner product is a unit-stride slice.
//! * **Register tiling** — symmetric-product and trailing-update kernels
//!   process 2x2 output tiles with four 4-wide accumulator lanes each
//!   (see [`dot2x2`]): input rows are reused across two outputs and the
//!   16 independent accumulator chains keep the FMA pipeline full.
//! * **Cache tiling** — output blocks of [`TILE`] x [`TILE`] keep both
//!   packed operand panels resident while a tile is produced.
//! * **No data-dependent branches** — unlike the seed kernels, the inner
//!   loops never test operand values (`if a == 0.0 { continue; }` is a
//!   mispredict on dense data); work is bounded by shapes alone.
//!
//! Tuning: the block constants below were chosen for ~32 KiB L1 / 512 KiB
//! L2 caches (packed panel rows of `PANEL * 8` = 512 B; a 2x[`TILE`] tile
//! pair is 32 KiB).  To re-tune for a different cache hierarchy, adjust
//! the constants and re-run `cargo bench --bench bench_hotpath` — the
//! `blocked vs scalar` shootouts print the speedup per dimension (see
//! README §Performance).

use super::Mat;
use crate::util::{axpy, dot};

/// Rows of `X` packed per Gram panel (reduction-dimension blocking).
pub const PANEL: usize = 64;

/// Output tile edge for symmetric products and trailing updates.
pub const TILE: usize = 32;

/// Columns processed per GEMM reduction block.
pub const GEMM_KC: usize = 64;

/// Diagonal-block edge of the right-looking blocked Cholesky.
pub const CHOL_NB: usize = 32;

/// Packed row `i` of a panel: `p` contiguous reduction elements.
#[inline]
fn prow(pack: &[f64], i: usize, p: usize) -> &[f64] {
    &pack[i * p..(i + 1) * p]
}

/// 2x2 register-tiled micro-kernel: the four inner products between rows
/// `{a0, a1}` and `{b0, b1}`, each accumulated over four independent
/// lanes (16 chains total) so the FMA pipeline never stalls on a single
/// additive dependency.
#[inline]
fn dot2x2(a0: &[f64], a1: &[f64], b0: &[f64], b1: &[f64]) -> (f64, f64, f64, f64) {
    let mut c00 = [0.0f64; 4];
    let mut c01 = [0.0f64; 4];
    let mut c10 = [0.0f64; 4];
    let mut c11 = [0.0f64; 4];
    let mut ka0 = a0.chunks_exact(4);
    let mut ka1 = a1.chunks_exact(4);
    let mut kb0 = b0.chunks_exact(4);
    let mut kb1 = b1.chunks_exact(4);
    for (((x0, x1), y0), y1) in (&mut ka0).zip(&mut ka1).zip(&mut kb0).zip(&mut kb1) {
        for t in 0..4 {
            c00[t] += x0[t] * y0[t];
            c01[t] += x0[t] * y1[t];
            c10[t] += x1[t] * y0[t];
            c11[t] += x1[t] * y1[t];
        }
    }
    let mut s00 = (c00[0] + c00[1]) + (c00[2] + c00[3]);
    let mut s01 = (c01[0] + c01[1]) + (c01[2] + c01[3]);
    let mut s10 = (c10[0] + c10[1]) + (c10[2] + c10[3]);
    let mut s11 = (c11[0] + c11[1]) + (c11[2] + c11[3]);
    for (((x0, x1), y0), y1) in ka0
        .remainder()
        .iter()
        .zip(ka1.remainder())
        .zip(kb0.remainder())
        .zip(kb1.remainder())
    {
        s00 += x0 * y0;
        s01 += x0 * y1;
        s10 += x1 * y0;
        s11 += x1 * y1;
    }
    (s00, s01, s10, s11)
}

/// `out[j] += a0 * b0[j] + a1 * b1[j]` — the two-row GEMM update that
/// halves output-row traffic relative to two separate axpys.
#[inline]
fn axpy2(out: &mut [f64], a0: f64, b0: &[f64], a1: f64, b1: &[f64]) {
    let mut co = out.chunks_exact_mut(4);
    let mut c0 = b0.chunks_exact(4);
    let mut c1 = b1.chunks_exact(4);
    for ((o, x0), x1) in (&mut co).zip(&mut c0).zip(&mut c1) {
        for t in 0..4 {
            o[t] += a0 * x0[t] + a1 * x1[t];
        }
    }
    for ((o, x0), x1) in co
        .into_remainder()
        .iter_mut()
        .zip(c0.remainder())
        .zip(c1.remainder())
    {
        *o += a0 * x0 + a1 * x1;
    }
}

/// Pack `p` rows of `x` starting at `p0`, transposed (column-major over
/// the panel): `pack[j*p + r] = w_r * x[p0+r, j]` with `w_r = 1` when no
/// weights are given, `sqrt(w[p0+r])` otherwise (so the SYRK kernel
/// computes `sum w_r x_r x_r^T` without a per-element weight multiply).
fn pack_panel(x: &Mat, p0: usize, p: usize, w: Option<&[f64]>, pack: &mut [f64]) {
    for r in 0..p {
        let row = x.row(p0 + r);
        let scale = match w {
            Some(w) => w[p0 + r].sqrt(),
            None => 1.0,
        };
        for (j, &v) in row.iter().enumerate() {
            pack[j * p + r] = scale * v;
        }
    }
}

/// Accumulate the upper triangle of the self-product of rows
/// `row(0..n)` into `out` (tiled; 2x2 micro-kernel on full off-diagonal
/// tiles, plain dots on the diagonal tiles and odd remainders).  Shared
/// by the packed-panel Gram kernel ([`gram_into`] via `prow`) and the
/// row-Gram kernel ([`gram_rows_into`] via `Mat::row`).
fn syrk_upper_tiled<'a, F: Fn(usize) -> &'a [f64]>(row: &F, n: usize, out: &mut Mat) {
    let mut i0 = 0;
    while i0 < n {
        let i1 = (i0 + TILE).min(n);
        // diagonal tile: plain dots over the triangle
        for i in i0..i1 {
            for j in i..i1 {
                let v = dot(row(i), row(j));
                out[(i, j)] += v;
            }
        }
        // off-diagonal tiles: full rectangles, 2x2 register tiling
        let mut j0 = i1;
        while j0 < n {
            let j1 = (j0 + TILE).min(n);
            rect_tile_acc(row, i0, i1, j0, j1, out);
            j0 = j1;
        }
        i0 = i1;
    }
}

/// `out[i0..i1, j0..j1] += row_i . row_j` over a full rectangular tile.
fn rect_tile_acc<'a, F: Fn(usize) -> &'a [f64]>(
    row: &F,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    out: &mut Mat,
) {
    let mut i = i0;
    while i + 2 <= i1 {
        let pi0 = row(i);
        let pi1 = row(i + 1);
        let mut j = j0;
        while j + 2 <= j1 {
            let (s00, s01, s10, s11) = dot2x2(pi0, pi1, row(j), row(j + 1));
            out[(i, j)] += s00;
            out[(i, j + 1)] += s01;
            out[(i + 1, j)] += s10;
            out[(i + 1, j + 1)] += s11;
            j += 2;
        }
        if j < j1 {
            let pj = row(j);
            out[(i, j)] += dot(pi0, pj);
            out[(i + 1, j)] += dot(pi1, pj);
        }
        i += 2;
    }
    if i < i1 {
        let pi = row(i);
        for j in j0..j1 {
            out[(i, j)] += dot(pi, row(j));
        }
    }
}

/// Mirror the upper triangle of a square matrix onto the lower.
fn mirror_upper(out: &mut Mat) {
    let n = out.rows();
    for i in 0..n {
        for j in 0..i {
            out[(i, j)] = out[(j, i)];
        }
    }
}

/// Blocked Gram product `out = x^T x` (SYRK; upper triangle computed
/// through packed panels + the 2x2 micro-kernel, then mirrored).
pub fn gram_into(x: &Mat, out: &mut Mat) {
    let d = x.cols();
    let mut pack = vec![0.0; d * PANEL];
    weighted_gram_with_pack(x, None, out, &mut pack);
}

/// Blocked weighted Gram product `out = sum_r w[r] * x_r x_r^T`
/// (`w[r] >= 0`; the weights enter the packed panel as `sqrt(w)` so the
/// micro-kernel is identical to the unweighted case).  `pack` is a
/// caller-held scratch buffer (resized here), so per-Newton-step Hessian
/// assemblies allocate nothing.
pub fn weighted_gram_into(x: &Mat, w: &[f64], out: &mut Mat, pack: &mut Vec<f64>) {
    assert_eq!(w.len(), x.rows(), "weighted_gram weight length mismatch");
    weighted_gram_with_pack(x, Some(w), out, pack);
}

fn weighted_gram_with_pack(x: &Mat, w: Option<&[f64]>, out: &mut Mat, pack: &mut Vec<f64>) {
    let (s, d) = (x.rows(), x.cols());
    assert_eq!(out.rows(), d, "gram output dimension mismatch");
    assert_eq!(out.cols(), d, "gram output dimension mismatch");
    out.data_mut().iter_mut().for_each(|v| *v = 0.0);
    pack.resize(d * PANEL, 0.0);
    let mut p0 = 0;
    while p0 < s {
        let p = PANEL.min(s - p0);
        pack_panel(x, p0, p, w, pack);
        let panel: &[f64] = pack;
        syrk_upper_tiled(&|i| prow(panel, i, p), d, out);
        p0 += p;
    }
    mirror_upper(out);
}

/// Blocked row-Gram product `out = x x^T` (rows are already contiguous,
/// so no packing is needed; tiled 2x2 micro-kernel over row pairs).
/// Used by the spectral tools on wide matrices (e.g. the paper's signed
/// incidence matrix `M_-`).
pub fn gram_rows_into(x: &Mat, out: &mut Mat) {
    let s = x.rows();
    assert_eq!(out.rows(), s, "gram_rows output dimension mismatch");
    assert_eq!(out.cols(), s, "gram_rows output dimension mismatch");
    out.data_mut().iter_mut().for_each(|v| *v = 0.0);
    syrk_upper_tiled(&|i| x.row(i), s, out);
    mirror_upper(out);
}

/// Blocked GEMM `out = a * b` (k-blocked, two reduction rows per pass
/// through the output row; branch-free inner loops).  `out` must not
/// alias `a` or `b`.
pub fn matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols(), b.rows(), "matmul dimension mismatch");
    assert_eq!(out.rows(), a.rows(), "matmul output dimension mismatch");
    assert_eq!(out.cols(), b.cols(), "matmul output dimension mismatch");
    out.data_mut().iter_mut().for_each(|v| *v = 0.0);
    let k = a.cols();
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + GEMM_KC).min(k);
        for i in 0..a.rows() {
            let arow = &a.row(i)[k0..k1];
            let orow = out.row_mut(i);
            let mut kk = 0;
            while kk + 2 <= arow.len() {
                axpy2(orow, arow[kk], b.row(k0 + kk), arow[kk + 1], b.row(k0 + kk + 1));
                kk += 2;
            }
            if kk < arow.len() {
                axpy(orow, arow[kk], b.row(k0 + kk));
            }
        }
        k0 = k1;
    }
}

/// Blocked matvec `out = a * v`: four rows share each load of `v`.  The
/// per-row accumulation order is exactly [`crate::util::dot`]'s (four
/// independent lanes, left-fold tail, pairwise combine), so the result
/// is bit-identical to the row-by-row dot formulation.
pub fn matvec_into(a: &Mat, v: &[f64], out: &mut [f64]) {
    let rows = a.rows();
    let n = a.cols();
    assert_eq!(v.len(), n, "matvec dimension mismatch");
    assert_eq!(out.len(), rows, "matvec output dimension mismatch");
    let v = &v[..n];
    let ch = n - n % 4;
    let mut i = 0;
    while i + 4 <= rows {
        let r0 = &a.row(i)[..n];
        let r1 = &a.row(i + 1)[..n];
        let r2 = &a.row(i + 2)[..n];
        let r3 = &a.row(i + 3)[..n];
        let mut acc = [[0.0f64; 4]; 4];
        let mut c = 0;
        while c < ch {
            for t in 0..4 {
                let vt = v[c + t];
                acc[0][t] += r0[c + t] * vt;
                acc[1][t] += r1[c + t] * vt;
                acc[2][t] += r2[c + t] * vt;
                acc[3][t] += r3[c + t] * vt;
            }
            c += 4;
        }
        let mut tail = [0.0f64; 4];
        while c < n {
            tail[0] += r0[c] * v[c];
            tail[1] += r1[c] * v[c];
            tail[2] += r2[c] * v[c];
            tail[3] += r3[c] * v[c];
            c += 1;
        }
        for (r, t) in tail.iter().enumerate() {
            out[i + r] = (acc[r][0] + acc[r][1]) + (acc[r][2] + acc[r][3]) + t;
        }
        i += 4;
    }
    while i < rows {
        out[i] = dot(a.row(i), v);
        i += 1;
    }
}

/// Right-looking blocked Cholesky: factor `a` (SPD) into the lower
/// triangle of `l` (`l`'s upper triangle is never written).  Returns
/// `false` when a diagonal pivot is non-positive; `l` is then
/// unspecified until the next successful factorization.
///
/// Per [`CHOL_NB`]-wide panel: (1) factor the diagonal block in place
/// (left-looking, contiguous-prefix dots), (2) solve the sub-diagonal
/// panel against it, (3) subtract the panel's self-product from the
/// trailing lower triangle with the tiled 2x2 SYRK micro-kernel — so the
/// O(n^3) bulk runs on unit-stride slices of length [`CHOL_NB`].
pub fn cholesky_factor_blocked(a: &Mat, l: &mut Mat) -> bool {
    let n = a.rows();
    debug_assert_eq!(a.cols(), n);
    debug_assert_eq!(l.rows(), n);
    debug_assert_eq!(l.cols(), n);
    for i in 0..n {
        let src = &a.row(i)[..=i];
        l.row_mut(i)[..=i].copy_from_slice(src);
    }
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + CHOL_NB).min(n);
        // (1) diagonal block, left-looking within the panel (columns
        // < k0 were already subtracted by earlier trailing updates)
        for i in k0..k1 {
            for j in k0..=i {
                let s = dot(&l.row(i)[k0..j], &l.row(j)[k0..j]);
                let sum = l[(i, j)] - s;
                if i == j {
                    if sum <= 0.0 {
                        return false;
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        // (2) panel solve: L21 = A21 * L11^{-T}
        for i in k1..n {
            for j in k0..k1 {
                let s = dot(&l.row(i)[k0..j], &l.row(j)[k0..j]);
                l[(i, j)] = (l[(i, j)] - s) / l[(j, j)];
            }
        }
        // (3) trailing update: A22 (lower triangle) -= L21 L21^T
        syrk_sub_lower(l, k1, k0, k1);
        k0 = k1;
    }
    true
}

/// Subtract `L[:, k0..k1] L[:, k0..k1]^T` from the lower triangle of the
/// trailing block `l[start.., start..]` (tiled; 2x2 micro-kernel on full
/// rectangles, scalar dots on diagonal-crossing tiles).
fn syrk_sub_lower(l: &mut Mat, start: usize, k0: usize, k1: usize) {
    let n = l.rows();
    let mut i0 = start;
    while i0 < n {
        let i1 = (i0 + TILE).min(n);
        let mut j0 = start;
        while j0 < i1 {
            let j1 = (j0 + TILE).min(i1);
            if j1 <= i0 {
                // full rectangle below the diagonal
                let mut i = i0;
                while i + 2 <= i1 {
                    let mut j = j0;
                    while j + 2 <= j1 {
                        let (s00, s01, s10, s11) = dot2x2(
                            &l.row(i)[k0..k1],
                            &l.row(i + 1)[k0..k1],
                            &l.row(j)[k0..k1],
                            &l.row(j + 1)[k0..k1],
                        );
                        l[(i, j)] -= s00;
                        l[(i, j + 1)] -= s01;
                        l[(i + 1, j)] -= s10;
                        l[(i + 1, j + 1)] -= s11;
                        j += 2;
                    }
                    if j < j1 {
                        let s0 = dot(&l.row(i)[k0..k1], &l.row(j)[k0..k1]);
                        let s1 = dot(&l.row(i + 1)[k0..k1], &l.row(j)[k0..k1]);
                        l[(i, j)] -= s0;
                        l[(i + 1, j)] -= s1;
                    }
                    i += 2;
                }
                if i < i1 {
                    for j in j0..j1 {
                        let s = dot(&l.row(i)[k0..k1], &l.row(j)[k0..k1]);
                        l[(i, j)] -= s;
                    }
                }
            } else {
                // diagonal-crossing tile: scalar over the triangle
                for i in i0..i1 {
                    let jmax = j1.min(i + 1);
                    for j in j0..jmax {
                        let s = dot(&l.row(i)[k0..k1], &l.row(j)[k0..k1]);
                        l[(i, j)] -= s;
                    }
                }
            }
            j0 = j1;
        }
        i0 = i1;
    }
}

/// Forward substitution `L y = b` (`y` into `out`; `b` and `out` must
/// not alias).  Each step is one unit-stride prefix dot.
pub fn solve_lower(l: &Mat, b: &[f64], out: &mut [f64]) {
    let n = l.rows();
    assert_eq!(b.len(), n, "solve dimension mismatch");
    assert_eq!(out.len(), n, "solve output dimension mismatch");
    for i in 0..n {
        let s = dot(&l.row(i)[..i], &out[..i]);
        out[i] = (b[i] - s) / l[(i, i)];
    }
}

/// Backward substitution `L^T x = y` in place over `out`, right-looking:
/// once `x[k]` is final, its contribution is pushed into all earlier
/// entries through one unit-stride axpy over row `k` of `L` — no strided
/// column walks (the seed implementation's backward pass read `L`
/// column-wise).
pub fn solve_lower_transpose_in_place(l: &Mat, out: &mut [f64]) {
    let n = l.rows();
    assert_eq!(out.len(), n, "solve output dimension mismatch");
    for k in (0..n).rev() {
        let xk = out[k] / l[(k, k)];
        out[k] = xk;
        axpy(&mut out[..k], -xk, &l.row(k)[..k]);
    }
}

/// Multi-RHS solve `A X = B` with `A = L L^T`, in place over the columns
/// of `b` (`n x m`): one blocked forward + one blocked backward sweep,
/// all updates as unit-stride row axpys of width `m` — every element of
/// `L` is loaded once per sweep instead of once per right-hand side.
pub fn solve_many_in_place(l: &Mat, b: &mut Mat) {
    let n = l.rows();
    assert_eq!(b.rows(), n, "solve_many dimension mismatch");
    let m = b.cols();
    // forward, left-looking: row i accumulates -L[i,j] * y_j for j < i
    for i in 0..n {
        let (done, rest) = b.data_mut().split_at_mut(i * m);
        let bi = &mut rest[..m];
        let li = l.row(i);
        for j in 0..i {
            axpy(bi, -li[j], &done[j * m..(j + 1) * m]);
        }
        let inv = 1.0 / li[i];
        for v in bi.iter_mut() {
            *v *= inv;
        }
    }
    // backward, right-looking: finalize x_k, push into earlier rows
    for k in (0..n).rev() {
        let (head, rest) = b.data_mut().split_at_mut(k * m);
        let bk = &mut rest[..m];
        let lk = l.row(k);
        let inv = 1.0 / lk[k];
        for v in bk.iter_mut() {
            *v *= inv;
        }
        for i in 0..k {
            axpy(&mut head[i * m..(i + 1) * m], -lk[i], bk);
        }
    }
}

/// Dense inverse `A^{-1} = (L L^T)^{-1}` into `out`, as one blocked
/// multi-RHS sweep over the identity.  The forward half exploits the
/// triangular structure of the intermediate `Y = L^{-1}` (row `j` of `Y`
/// is zero beyond column `j`), cutting its cost to n^3/6; the result is
/// mirrored at the end so the returned inverse is exactly symmetric.
pub fn cholesky_inverse_into(l: &Mat, out: &mut Mat) {
    let n = l.rows();
    assert_eq!(out.rows(), n, "inverse output dimension mismatch");
    assert_eq!(out.cols(), n, "inverse output dimension mismatch");
    out.data_mut().iter_mut().for_each(|v| *v = 0.0);
    for i in 0..n {
        out[(i, i)] = 1.0;
    }
    // forward: Y = L^{-1} (lower triangular — restrict every axpy to the
    // structurally non-zero prefix)
    for i in 0..n {
        let (done, rest) = out.data_mut().split_at_mut(i * n);
        let yi = &mut rest[..n];
        let li = l.row(i);
        for j in 0..i {
            axpy(&mut yi[..=j], -li[j], &done[j * n..j * n + j + 1]);
        }
        let inv = 1.0 / li[i];
        for v in yi[..=i].iter_mut() {
            *v *= inv;
        }
    }
    // backward: X = L^{-T} Y (dense from the first finalized row on)
    for k in (0..n).rev() {
        let (head, rest) = out.data_mut().split_at_mut(k * n);
        let xk = &mut rest[..n];
        let lk = l.row(k);
        let inv = 1.0 / lk[k];
        for v in xk.iter_mut() {
            *v *= inv;
        }
        for i in 0..k {
            axpy(&mut head[i * n..(i + 1) * n], -lk[i], xk);
        }
    }
    // exact symmetry (the two halves agree to rounding; keep the lower)
    for i in 0..n {
        for j in 0..i {
            out[(j, i)] = out[(i, j)];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut m = Mat::zeros(r, c);
        for i in 0..r {
            for j in 0..c {
                m[(i, j)] = rng.normal();
            }
        }
        m
    }

    #[test]
    fn gram_matches_scalar_across_block_boundaries() {
        // hit every remainder path: below/at/above PANEL and TILE edges
        for &(s, d) in &[(1, 1), (3, 2), (65, 31), (64, 32), (130, 33), (7, 97), (200, 65)] {
            let x = random_mat(s, d, (s * 1000 + d) as u64);
            let blocked = x.gram();
            let scalar = x.gram_scalar();
            let tol = 1e-12 * (1.0 + scalar.max_abs());
            assert!(blocked.sub(&scalar).max_abs() < tol, "gram mismatch at s={s} d={d}");
            assert!(blocked.is_symmetric(0.0));
        }
    }

    #[test]
    fn weighted_gram_matches_direct_sum() {
        let mut rng = Pcg64::new(9);
        for &(s, d) in &[(5, 3), (70, 33), (129, 17)] {
            let x = random_mat(s, d, (s + d) as u64);
            let w: Vec<f64> = (0..s).map(|_| rng.uniform()).collect();
            let mut out = Mat::zeros(d, d);
            let mut pack = Vec::new();
            weighted_gram_into(&x, &w, &mut out, &mut pack);
            let mut direct = Mat::zeros(d, d);
            for r in 0..s {
                for i in 0..d {
                    for j in 0..d {
                        direct[(i, j)] += w[r] * x[(r, i)] * x[(r, j)];
                    }
                }
            }
            let tol = 1e-11 * (1.0 + direct.max_abs());
            assert!(out.sub(&direct).max_abs() < tol, "s={s} d={d}");
        }
    }

    #[test]
    fn gram_rows_matches_matmul_transpose() {
        for &(s, c) in &[(2, 5), (33, 64), (66, 7)] {
            let x = random_mat(s, c, (s * 7 + c) as u64);
            let fast = x.gram_rows();
            let slow = x.matmul_scalar(&x.t());
            let tol = 1e-12 * (1.0 + slow.max_abs());
            assert!(fast.sub(&slow).max_abs() < tol, "s={s} c={c}");
        }
    }

    #[test]
    fn matvec_into_bit_identical_to_dot_rows() {
        for &(r, c) in &[(1, 1), (4, 4), (5, 9), (9, 5), (130, 67)] {
            let a = random_mat(r, c, (r * 31 + c) as u64);
            let v: Vec<f64> = random_mat(1, c, c as u64).data().to_vec();
            let mut out = vec![0.0; r];
            matvec_into(&a, &v, &mut out);
            for i in 0..r {
                let want = crate::util::dot(a.row(i), &v);
                assert_eq!(out[i].to_bits(), want.to_bits(), "r={r} c={c} row {i}");
            }
        }
    }

    #[test]
    fn blocked_cholesky_round_trips() {
        for &n in &[1usize, 2, 31, 32, 33, 70] {
            let b = random_mat(n, n, n as u64);
            let a = b.t().matmul(&b).add_diag(n as f64 * 0.1);
            let mut l = Mat::zeros(n, n);
            assert!(cholesky_factor_blocked(&a, &mut l), "n={n}");
            let rec = l.matmul(&l.t());
            let tol = 1e-9 * (1.0 + a.max_abs());
            assert!(a.sub(&rec).max_abs() < tol, "n={n}");
        }
    }

    #[test]
    fn solve_many_matches_column_solves() {
        let n = 37;
        let m = 9;
        let b0 = random_mat(n, n, 5);
        let a = b0.t().matmul(&b0).add_diag(2.0);
        let ch = super::super::Cholesky::new(&a).unwrap();
        let rhs = random_mat(n, m, 6);
        let mut many = rhs.clone();
        solve_many_in_place(ch.l(), &mut many);
        for j in 0..m {
            let col: Vec<f64> = (0..n).map(|i| rhs[(i, j)]).collect();
            let x = ch.solve(&col);
            for i in 0..n {
                assert!(
                    (many[(i, j)] - x[i]).abs() < 1e-9 * (1.0 + x[i].abs()),
                    "col {j} row {i}"
                );
            }
        }
    }
}
