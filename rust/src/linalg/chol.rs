//! Cholesky factorization of symmetric positive-definite matrices.
//!
//! Two usage patterns:
//! * one-time setup (linear regression): `A = X^T X + rho d_n I` is
//!   factored once per worker via [`Cholesky::new`]; every iteration is
//!   then a cheap [`Cholesky::solve_into`], and
//! * per-Newton-step refactorization (logistic regression): the solver
//!   holds a persistent [`Cholesky::workspace`] and calls
//!   [`Cholesky::factor_into`] each step, so the factor storage never
//!   reallocates on the hot path.
//!
//! The factorization and solves run on the cache-blocked kernels of
//! [`super::block`] (right-looking blocked factor, unit-stride
//! substitution sweeps, one-sweep multi-RHS solve behind
//! [`Cholesky::inverse`]).  The seed scalar loops are retained as
//! [`Cholesky::factor_into_scalar`] / [`Cholesky::solve_into_scalar`]
//! for differential tests and the `bench_hotpath` shootouts.
//!
//! Kernel tiers: the factorization inherits the process-wide
//! [`crate::util::tier::KernelTier`] (its trailing updates also pool
//! across threads at large `n`; pooled == serial bitwise per tier).
//! The solve's forward sweep is tier-dependent (prefix dots), while the
//! backward sweep is axpy-built and bit-identical across tiers.
//! [`Cholesky::factor_into_ctx`] / [`Cholesky::solve_into_with_tier`]
//! take the tier explicitly for differential tests and bench shootouts.

use super::{block, Mat};
use crate::util::tier::KernelTier;

/// Lower-triangular Cholesky factor `L` with `L L^T = A`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factor an SPD matrix. Returns `None` if the matrix is not positive
    /// definite (within floating-point tolerance).
    pub fn new(a: &Mat) -> Option<Cholesky> {
        let mut c = Cholesky::workspace(a.rows());
        if c.factor_into(a) {
            Some(c)
        } else {
            None
        }
    }

    /// An unfactored `n x n` workspace: factor with [`Self::factor_into`]
    /// before solving.
    pub fn workspace(n: usize) -> Cholesky {
        Cholesky { l: Mat::zeros(n, n) }
    }

    /// Refactor `a` into this workspace, reusing the factor storage (no
    /// allocation when the dimension matches the workspace).  Returns
    /// `false` if `a` is not positive definite within floating-point
    /// tolerance; the workspace contents are then unspecified until the
    /// next successful factorization (every lower-triangle entry is
    /// rewritten by it).  Runs the right-looking blocked factorization
    /// of [`block::cholesky_factor_blocked`].
    pub fn factor_into(&mut self, a: &Mat) -> bool {
        assert_eq!(a.rows(), a.cols(), "cholesky needs square");
        let n = a.rows();
        if self.l.rows() != n || self.l.cols() != n {
            self.l = Mat::zeros(n, n);
        }
        block::cholesky_factor_blocked(a, &mut self.l)
    }

    /// [`Cholesky::factor_into`] under an explicit [`block::KernelCtx`]
    /// (tier + pooling), for differential tests and bench shootouts.
    pub fn factor_into_ctx(&mut self, ctx: block::KernelCtx, a: &Mat) -> bool {
        assert_eq!(a.rows(), a.cols(), "cholesky needs square");
        let n = a.rows();
        if self.l.rows() != n || self.l.cols() != n {
            self.l = Mat::zeros(n, n);
        }
        block::cholesky_factor_blocked_ctx(ctx, a, &mut self.l)
    }

    /// Seed-faithful scalar factorization (left-looking triple loop) —
    /// retained as the reference implementation for differential tests
    /// and the `bench_hotpath` blocked-vs-scalar shootout.  Same
    /// contract as [`Cholesky::factor_into`].
    pub fn factor_into_scalar(&mut self, a: &Mat) -> bool {
        assert_eq!(a.rows(), a.cols(), "cholesky needs square");
        let n = a.rows();
        if self.l.rows() != n || self.l.cols() != n {
            self.l = Mat::zeros(n, n);
        }
        let l = &mut self.l;
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        return false;
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        true
    }

    /// The lower factor.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = vec![0.0; self.l.rows()];
        self.solve_into(b, &mut x);
        x
    }

    /// Allocation-free solve into a caller-provided buffer (`b` and `out`
    /// must not alias).  `out` doubles as the forward-substitution
    /// workspace; both sweeps run on unit-stride slices of `L`
    /// ([`block::solve_lower`] + the right-looking in-place backward
    /// substitution — no strided column walks).
    pub fn solve_into(&self, b: &[f64], out: &mut [f64]) {
        block::solve_lower(&self.l, b, out);
        block::solve_lower_transpose_in_place(&self.l, out);
    }

    /// [`Cholesky::solve_into`] under an explicit kernel tier (the
    /// backward sweep is tier-invariant; only the forward prefix dots
    /// change), for differential tests and bench shootouts.
    pub fn solve_into_with_tier(&self, tier: KernelTier, b: &[f64], out: &mut [f64]) {
        block::solve_lower_with_tier(tier, &self.l, b, out);
        block::solve_lower_transpose_in_place(&self.l, out);
    }

    /// Seed-faithful scalar solve (column-striding backward pass) —
    /// retained as the reference implementation for differential tests
    /// and the `bench_hotpath` blocked-vs-scalar shootout.  Same
    /// contract as [`Cholesky::solve_into`].
    pub fn solve_into_scalar(&self, b: &[f64], out: &mut [f64]) {
        let n = self.l.rows();
        assert_eq!(b.len(), n, "solve dimension mismatch");
        assert_eq!(out.len(), n, "solve output dimension mismatch");
        // forward: L y = b (y written into out)
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l[(i, k)] * out[k];
            }
            out[i] = sum / self.l[(i, i)];
        }
        // backward: L^T x = y (in place over out)
        for i in (0..n).rev() {
            let mut sum = out[i];
            for k in i + 1..n {
                sum -= self.l[(k, i)] * out[k];
            }
            out[i] = sum / self.l[(i, i)];
        }
    }

    /// Multi-RHS solve `A X = B` in place over the columns of `b`
    /// (`n x m`): one blocked forward + one blocked backward sweep —
    /// every element of `L` is loaded once per sweep instead of once per
    /// right-hand side.
    pub fn solve_many_into(&self, b: &mut Mat) {
        block::solve_many_in_place(&self.l, b);
    }

    /// Dense inverse `A^{-1}` (used to feed the `linear_update` artifact,
    /// whose fused kernel wants an explicit matrix).  One blocked
    /// multi-RHS sweep over the identity; the forward half exploits the
    /// triangular structure of `L^{-1}` and the result is exactly
    /// symmetric (see [`block::cholesky_inverse_into`]).  The seed
    /// implementation solved — and allocated — one column at a time.
    pub fn inverse(&self) -> Mat {
        let n = self.l.rows();
        let mut inv = Mat::zeros(n, n);
        block::cholesky_inverse_into(&self.l, &mut inv);
        inv
    }

    /// Allocation-free [`Cholesky::inverse`] into a caller-provided
    /// matrix.
    pub fn inverse_into(&self, out: &mut Mat) {
        block::cholesky_inverse_into(&self.l, out);
    }

    /// log-determinant of `A` (handy for conditioning diagnostics).
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut b = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = rng.normal();
            }
        }
        b.t().matmul(&b).add_diag(n as f64 * 0.1)
    }

    #[test]
    fn factor_and_solve() {
        let a = random_spd(12, 0);
        let ch = Cholesky::new(&a).unwrap();
        let x_true: Vec<f64> = (0..12).map(|i| (i as f64) - 5.0).collect();
        let b = a.matvec(&x_true);
        let x = ch.solve(&b);
        for (xs, xt) in x.iter().zip(&x_true) {
            assert!((xs - xt).abs() < 1e-8, "{xs} vs {xt}");
        }
    }

    #[test]
    fn solve_into_matches_solve() {
        let a = random_spd(9, 5);
        let ch = Cholesky::new(&a).unwrap();
        let b: Vec<f64> = (0..9).map(|i| (i as f64 * 0.7).cos()).collect();
        let x = ch.solve(&b);
        let mut out = vec![1.0; 9]; // stale contents must not matter
        ch.solve_into(&b, &mut out);
        assert_eq!(x, out);
    }

    #[test]
    fn l_times_lt_is_a() {
        let a = random_spd(8, 1);
        let ch = Cholesky::new(&a).unwrap();
        let rec = ch.l().matmul(&ch.l().t());
        assert!(a.sub(&rec).max_abs() < 1e-9);
    }

    #[test]
    fn inverse_is_inverse() {
        let a = random_spd(10, 2);
        let inv = Cholesky::new(&a).unwrap().inverse();
        let id = a.matmul(&inv);
        assert!(id.sub(&Mat::eye(10)).max_abs() < 1e-8);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(Cholesky::new(&a).is_none());
    }

    #[test]
    fn factor_into_reuses_workspace_and_matches_new() {
        let mut ws = Cholesky::workspace(7);
        for seed in 0..5 {
            let a = random_spd(7, 100 + seed);
            assert!(ws.factor_into(&a));
            let fresh = Cholesky::new(&a).unwrap();
            // refactorization in a reused workspace is bit-identical to
            // a fresh factorization (every lower entry is rewritten)
            assert_eq!(ws.l(), fresh.l());
        }
        // a failed factor leaves the workspace reusable
        let bad = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]);
        assert!(!ws.factor_into(&bad)); // also exercises the resize path
        let good = random_spd(2, 9);
        assert!(ws.factor_into(&good));
        assert_eq!(ws.l(), Cholesky::new(&good).unwrap().l());
    }

    #[test]
    fn logdet_matches_direct() {
        let a = Mat::from_rows(&[&[4.0, 0.0], &[0.0, 9.0]]);
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.logdet() - (36.0f64).ln()).abs() < 1e-12);
    }
}
