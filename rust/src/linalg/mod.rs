//! Dense linear algebra substrate (first-party, no external deps).
//!
//! Everything the reproduction needs: a row-major [`Mat`] type, GEMM /
//! matvec, Cholesky and LU factorizations with solves and inverses, power
//! iteration for the largest singular value and Jacobi rotation for
//! symmetric eigendecompositions (used by the topology-spectrum analysis
//! of Theorem 2).
//!
//! Every O(d^2)/O(d^3) kernel (`gram`, `matmul`, `matvec`, the Cholesky
//! factor/solves) routes through the cache-blocked, register-tiled layer
//! in [`block`]; the seed scalar triple-loops remain available as
//! `*_scalar` reference implementations for differential tests and the
//! `bench_hotpath` blocked-vs-scalar shootouts.
//!
//! The blocked layer is additionally **tiered** ([`KernelTier`]: scalar
//! reference vs runtime-detected AVX2+FMA, override via
//! `CQ_KERNEL_TIER` / `--kernel-tier`) and its large trailing updates
//! pool across `parallel::WorkerPool` threads (`CQ_LINALG_THREADS`).
//! Results are deterministic and bit-stable per tier (pooled == serial
//! bitwise); cross-tier agreement is rounding-level for FMA reductions
//! and exact for the axpy-built solves — see [`block`]'s module docs.

pub mod block;
mod chol;
mod lu;
mod spectral;

pub use block::KernelCtx;
pub use chol::Cholesky;
pub use lu::Lu;
pub use spectral::{power_iteration_sigma_max, symmetric_eigen, min_nonzero_singular};

pub use crate::util::tier::{kernel_tier, set_kernel_tier, KernelTier};

/// Dense row-major matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Mat { rows, cols, data }
    }

    /// Build from nested rows (test convenience).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transpose.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Matrix-vector product `self * v` (blocked kernel: four rows share
    /// every load of `v`; per-row arithmetic is exactly the 4-wide
    /// unrolled [`crate::util::dot`], so the result is bit-identical to
    /// the row-by-row dot formulation and reassociated relative to a
    /// naive inner loop only at the last-ulp level).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        block::matvec_into(self, v, &mut out);
        out
    }

    /// Allocation-free [`Mat::matvec`] into a caller-provided buffer.
    pub fn matvec_into(&self, v: &[f64], out: &mut [f64]) {
        block::matvec_into(self, v, out);
    }

    /// Transposed matvec `self^T * v` (row-major friendly: one unrolled
    /// [`crate::util::axpy`] per row, bit-identical to the naive loop).
    pub fn t_matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.rows, "t_matvec dimension mismatch");
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            crate::util::axpy(&mut out, v[i], self.row(i));
        }
        out
    }

    /// Matrix product `self * other` (blocked kernel: k-blocked with two
    /// reduction rows per pass and branch-free inner loops — the seed's
    /// data-dependent `a == 0.0` skip was a mispredict on dense data).
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        block::matmul_into(self, other, &mut out);
        out
    }

    /// Seed-faithful scalar GEMM (ikj triple loop with the zero-skip
    /// branch) — retained as the reference implementation for the
    /// differential tests and the `bench_hotpath` blocked-vs-scalar
    /// shootout.
    pub fn matmul_scalar(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dimension mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for j in 0..other.cols {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// Gram matrix `self^T * self` (symmetric; blocked SYRK kernel —
    /// packed panels + 2x2 register tiling, upper triangle mirrored).
    pub fn gram(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.cols);
        block::gram_into(self, &mut out);
        out
    }

    /// Row Gram matrix `self * self^T` (symmetric; blocked kernel over
    /// the already-contiguous rows).  Used by the spectral tools on wide
    /// matrices such as the paper's incidence matrix `M_-`.
    pub fn gram_rows(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.rows);
        block::gram_rows_into(self, &mut out);
        out
    }

    /// Seed-faithful scalar Gram product (triple loop with the zero-skip
    /// branch) — retained as the reference implementation for the
    /// differential tests and the `bench_hotpath` blocked-vs-scalar
    /// shootout.
    pub fn gram_scalar(&self) -> Mat {
        let d = self.cols;
        let mut out = Mat::zeros(d, d);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..d {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                for j in i..d {
                    out[(i, j)] += xi * row[j];
                }
            }
        }
        for i in 0..d {
            for j in 0..i {
                out[(i, j)] = out[(j, i)];
            }
        }
        out
    }

    /// `self + scale * I` (in place, returns self for chaining).
    pub fn add_diag(mut self, scale: f64) -> Mat {
        assert_eq!(self.rows, self.cols, "add_diag needs square");
        for i in 0..self.rows {
            self[(i, i)] += scale;
        }
        self
    }

    /// Element-wise `self + other`.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Element-wise `self - other`.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Scale all entries.
    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |entry| (used in tolerances).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }

    /// Symmetry check within tolerance.
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in 0..i {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_matmul() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        let b = Mat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(&[&[2.0, 1.0], &[4.0, 3.0]]));
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a.t().t(), a);
        assert_eq!(a.t().rows(), 3);
    }

    #[test]
    fn gram_matches_matmul() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let g = a.gram();
        let g2 = a.t().matmul(&a);
        assert!(g.sub(&g2).max_abs() < 1e-12);
        assert!(g.is_symmetric(0.0));
    }

    #[test]
    fn t_matvec_matches_transpose() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let v = vec![1.0, -1.0, 2.0];
        assert_eq!(a.t_matvec(&v), a.t().matvec(&v));
    }

    #[test]
    fn unrolled_matvecs_match_naive_loops() {
        use crate::testing::prop::check;
        // matvec reassociates (unrolled dot): tolerance; t_matvec keeps
        // the naive per-element arithmetic (unrolled axpy): bitwise
        check("matvec/t_matvec vs naive loops", 100, |g| {
            let r = g.usize_in(1, 23);
            let c = g.usize_in(1, 23);
            let mut m = Mat::zeros(r, c);
            for i in 0..r {
                for j in 0..c {
                    m[(i, j)] = g.normal();
                }
            }
            let v = g.normal_vec(c);
            let fast = m.matvec(&v);
            for i in 0..r {
                let mut acc = 0.0;
                for j in 0..c {
                    acc += m[(i, j)] * v[j];
                }
                assert!(
                    (fast[i] - acc).abs() <= 1e-12 * (1.0 + acc.abs()),
                    "matvec row {i}: {} vs {acc}",
                    fast[i]
                );
            }
            let w = g.normal_vec(r);
            let fast_t = m.t_matvec(&w);
            let mut slow_t = vec![0.0; c];
            for i in 0..r {
                for j in 0..c {
                    slow_t[j] += w[i] * m[(i, j)];
                }
            }
            for j in 0..c {
                assert!(
                    fast_t[j].to_bits() == slow_t[j].to_bits(),
                    "t_matvec col {j}: {:?} vs {:?}",
                    fast_t[j],
                    slow_t[j]
                );
            }
        });
    }

    #[test]
    fn add_diag_and_ops() {
        let a = Mat::eye(3).scale(2.0).add_diag(1.0);
        assert_eq!(a[(0, 0)], 3.0);
        assert_eq!(a[(0, 1)], 0.0);
        let b = a.sub(&Mat::eye(3).scale(3.0));
        assert!(b.fro_norm() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "matvec dimension mismatch")]
    fn matvec_dim_panics() {
        Mat::zeros(2, 3).matvec(&[1.0, 2.0]);
    }
}
