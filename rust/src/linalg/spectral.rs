//! Spectral tools for the topology analysis of Theorems 2 and 3.
//!
//! The linear-rate constant of the paper depends on `sigma_max(C)`,
//! `sigma_max(M_-)` and the smallest **non-zero** singular value
//! `sigma~_min(M_-)` of the signed incidence matrix.  We compute the
//! largest singular value by power iteration on `A^T A` and full symmetric
//! spectra with cyclic Jacobi (matrices here are at most N+|E| ~ 100 wide).
//!
//! The power iteration runs on [`Mat::matvec`] / [`Mat::t_matvec`] and so
//! inherits the process-wide kernel tier ([`crate::util::tier`]); at
//! these tiny dimensions the tiers agree to rounding and the iteration
//! count dominates, so no tier-pinning is done here.

use super::Mat;

/// Largest singular value of `a` via power iteration on `a^T a`.
pub fn power_iteration_sigma_max(a: &Mat, iters: usize) -> f64 {
    let n = a.cols();
    if n == 0 || a.rows() == 0 {
        return 0.0;
    }
    // deterministic start vector with all-nonzero entries
    let mut v: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.7).sin() * 0.3).collect();
    let mut lambda = 0.0;
    for _ in 0..iters {
        let av = a.matvec(&v);
        let atav = a.t_matvec(&av);
        let norm = atav.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm < 1e-300 {
            return 0.0;
        }
        lambda = norm;
        for (vi, yi) in v.iter_mut().zip(&atav) {
            *vi = yi / norm;
        }
    }
    lambda.sqrt()
}

/// Full eigendecomposition of a symmetric matrix by cyclic Jacobi.
/// Returns eigenvalues in ascending order.
pub fn symmetric_eigen(a: &Mat) -> Vec<f64> {
    assert!(a.is_symmetric(1e-9), "symmetric_eigen needs symmetric input");
    let n = a.rows();
    let mut m = a.clone();
    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-12 * (1.0 + m.max_abs()) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p and q
                for k in 0..n {
                    let akp = m[(k, p)];
                    let akq = m[(k, q)];
                    m[(k, p)] = c * akp - s * akq;
                    m[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[(p, k)];
                    let aqk = m[(q, k)];
                    m[(p, k)] = c * apk - s * aqk;
                    m[(q, k)] = s * apk + c * aqk;
                }
            }
        }
    }
    let mut eig: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    // total_cmp: a degenerate input (overflow to inf during rotation,
    // NaN on the diagonal) must not panic the sort — NaNs order last
    // and the callers' `> tol` filters skip them.
    eig.sort_by(f64::total_cmp);
    eig
}

/// Smallest non-zero singular value of `a` (zero modes below `tol` are
/// skipped) — the paper's `sigma~_min(M_-)`.  The normal matrix is
/// formed by the blocked symmetric kernels ([`Mat::gram`] for tall
/// inputs, [`Mat::gram_rows`] for wide ones such as the incidence
/// matrices) instead of a general GEMM against an explicit transpose.
pub fn min_nonzero_singular(a: &Mat, tol: f64) -> f64 {
    let g = if a.rows() >= a.cols() {
        a.gram()
    } else {
        a.gram_rows()
    };
    let eig = symmetric_eigen(&g);
    for e in eig {
        if e > tol {
            return e.sqrt();
        }
    }
    0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigma_max_of_diagonal() {
        let a = Mat::from_rows(&[&[3.0, 0.0], &[0.0, 2.0]]);
        let s = power_iteration_sigma_max(&a, 200);
        assert!((s - 3.0).abs() < 1e-6, "s={s}");
    }

    #[test]
    fn sigma_max_of_rectangular() {
        // singular values of [[1,0],[0,1],[1,1]] are sqrt(3), 1
        let a = Mat::from_rows(&[&[1.0, 0.0], &[0.0, 1.0], &[1.0, 1.0]]);
        let s = power_iteration_sigma_max(&a, 300);
        assert!((s - 3f64.sqrt()).abs() < 1e-6, "s={s}");
    }

    #[test]
    fn jacobi_eigenvalues_known() {
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let e = symmetric_eigen(&a);
        assert!((e[0] - 1.0).abs() < 1e-9 && (e[1] - 3.0).abs() < 1e-9, "{e:?}");
    }

    #[test]
    fn jacobi_trace_preserved() {
        let a = Mat::from_rows(&[
            &[4.0, 1.0, 0.5],
            &[1.0, 3.0, -0.2],
            &[0.5, -0.2, 1.0],
        ]);
        let e = symmetric_eigen(&a);
        let trace: f64 = e.iter().sum();
        assert!((trace - 8.0).abs() < 1e-8);
    }

    #[test]
    fn jacobi_survives_nan_input() {
        // regression: a NaN on the diagonal (off-diagonals still compare
        // symmetric) poisons the rotations; the eigenvalue sort used to
        // panic on `partial_cmp(..).unwrap()`
        let a = Mat::from_rows(&[&[f64::NAN, 1.0], &[1.0, 0.0]]);
        let e = symmetric_eigen(&a);
        assert_eq!(e.len(), 2);
        // finite values (if any) order before the NaNs
        assert!(e.windows(2).all(|w| w[0].total_cmp(&w[1]).is_le()));
    }

    #[test]
    fn jacobi_survives_inf_diagonal() {
        // an infinite diagonal entry (overflowed upstream arithmetic)
        // must come back as an ordinary sorted spectrum, not a panic
        let a = Mat::from_rows(&[
            &[f64::INFINITY, 1.0, 0.0],
            &[1.0, 2.0, 0.5],
            &[0.0, 0.5, 1.0],
        ]);
        let e = symmetric_eigen(&a);
        assert_eq!(e.len(), 3);
    }

    #[test]
    fn min_nonzero_singular_nan_poisoned_returns_zero() {
        // NaN eigenvalues sort last and fail every `> tol` test, so the
        // degenerate answer is the conservative 0.0 — not a panic
        let a = Mat::from_rows(&[&[f64::NAN, 0.0], &[0.0, f64::NAN]]);
        let s = min_nonzero_singular(&a, 1e-9);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn min_nonzero_skips_null_space() {
        // rank-1 matrix: singular values {sqrt(2), 0}
        let a = Mat::from_rows(&[&[1.0, 1.0], &[0.0, 0.0]]);
        let s = min_nonzero_singular(&a, 1e-9);
        assert!((s - 2f64.sqrt()).abs() < 1e-6, "s={s}");
    }
}
