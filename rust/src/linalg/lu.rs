//! LU factorization with partial pivoting (general square systems).
//!
//! The centralized reference solver and the spectral analysis need solves
//! of matrices that are not necessarily SPD; this complements `chol.rs`.

use super::Mat;

/// Packed LU factors with a row-permutation vector.
#[derive(Clone, Debug)]
pub struct Lu {
    lu: Mat,
    perm: Vec<usize>,
    sign: f64,
}

impl Lu {
    /// Factor a square matrix. Returns `None` if (numerically) singular.
    pub fn new(a: &Mat) -> Option<Lu> {
        assert_eq!(a.rows(), a.cols(), "lu needs square");
        let n = a.rows();
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // pivot
            let mut p = k;
            let mut best = lu[(k, k)].abs();
            for i in k + 1..n {
                let v = lu[(i, k)].abs();
                if v > best {
                    best = v;
                    p = i;
                }
            }
            if best < 1e-14 {
                return None;
            }
            if p != k {
                for j in 0..n {
                    let t = lu[(k, j)];
                    lu[(k, j)] = lu[(p, j)];
                    lu[(p, j)] = t;
                }
                perm.swap(k, p);
                sign = -sign;
            }
            let pivot = lu[(k, k)];
            for i in k + 1..n {
                let f = lu[(i, k)] / pivot;
                lu[(i, k)] = f;
                for j in k + 1..n {
                    let v = lu[(k, j)];
                    lu[(i, j)] -= f * v;
                }
            }
        }
        Some(Lu { lu, perm, sign })
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows();
        assert_eq!(b.len(), n, "solve dimension mismatch");
        // apply permutation, forward substitute (unit lower)
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[self.perm[i]];
            for k in 0..i {
                sum -= self.lu[(i, k)] * y[k];
            }
            y[i] = sum;
        }
        // back substitute (upper)
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in i + 1..n {
                sum -= self.lu[(i, k)] * x[k];
            }
            x[i] = sum / self.lu[(i, i)];
        }
        x
    }

    /// Determinant.
    pub fn det(&self) -> f64 {
        let mut d = self.sign;
        for i in 0..self.lu.rows() {
            d *= self.lu[(i, i)];
        }
        d
    }

    /// Dense inverse.
    pub fn inverse(&self) -> Mat {
        let n = self.lu.rows();
        let mut inv = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = self.solve(&e);
            e[j] = 0.0;
            for i in 0..n {
                inv[(i, j)] = col[i];
            }
        }
        inv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_mat(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = rng.normal();
            }
        }
        a
    }

    #[test]
    fn solve_random_system() {
        let a = random_mat(15, 3);
        let lu = Lu::new(&a).unwrap();
        let x_true: Vec<f64> = (0..15).map(|i| (i as f64 * 0.3).sin()).collect();
        let b = a.matvec(&x_true);
        let x = lu.solve(&b);
        for (xs, xt) in x.iter().zip(&x_true) {
            assert!((xs - xt).abs() < 1e-8);
        }
    }

    #[test]
    fn det_of_known() {
        let a = Mat::from_rows(&[&[2.0, 0.0], &[0.0, 3.0]]);
        assert!((Lu::new(&a).unwrap().det() - 6.0).abs() < 1e-12);
        let b = Mat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]); // det -1, needs pivot
        assert!((Lu::new(&b).unwrap().det() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn inverse_roundtrip() {
        let a = random_mat(9, 4);
        let inv = Lu::new(&a).unwrap().inverse();
        assert!(a.matmul(&inv).sub(&Mat::eye(9)).max_abs() < 1e-8);
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(Lu::new(&a).is_none());
    }
}
