//! The per-worker protocol core — **one** implementation of the paper's
//! per-link state machine, shared by both execution engines.
//!
//! [`WorkerCore`] owns everything a worker carries through a CQ-GGADMM
//! run (Algorithm 2; same structure as Q-GADMM and GADMM):
//!
//! * the primal model `theta`, the dual `alpha`, the worker's own last
//!   broadcast `hat_self` (what its neighbors hold for it), and one
//!   `hat_nbrs` slot per neighbor (what it holds for each of them);
//! * the primal solve (eqs. (21)/(22)) over the cached neighbor sum,
//!   including the Jacobian (DCADMM) self-anchor `d_i * hat_self`;
//! * the quantize → censor → broadcast pipeline with persistent
//!   candidate/code scratch (no per-round allocation);
//! * the dual update (eq. (23)) over the cached increment;
//! * the censoring-aware **incremental** bookkeeping: the neighbor sum
//!   and the dual increment are rebuilt only when some hat in the
//!   worker's closed neighborhood committed since the last rebuild, by
//!   the exact from-scratch loops — so the caches are bit-identical to
//!   an always-recompute engine (`incremental = false`, locked by
//!   `tests/incremental.rs`) and censored/dropped rounds cost nothing.
//!
//! The drivers are deliberately thin:
//! * [`crate::algs::Run`] — the sequential simulator — delivers committed
//!   hats in-process as `f64` slices;
//! * [`crate::coordinator`] — the sharded system engine — encodes the
//!   committed payload to wire bytes ([`crate::coordinator::message`]),
//!   and receivers decode straight into their [`WorkerCore`] slot;
//! * [`crate::net`] — the TCP transport — runs the same state machine in
//!   a separate worker process ([`build_core_at`] replays the fleet's
//!   construction for one id) and ships the wire bytes over a socket.
//!
//! Both paths reconstruct bit-identical hats (the quantizer's sender-side
//! reconstruction equals the receiver-side decode by construction, and
//! full-precision payloads travel as `f64`), so the engines are locked
//! trajectory-for-trajectory by `tests/coordinator_equivalence.rs` —
//! including erasure injection through the shared [`crate::comm::Medium`]
//! transmit path.

use crate::algs::{AlgSpec, Problem, Schedule, UpdateRule};
use crate::censor::{gate, CensorConfig, Gate};
use crate::comm::full_precision_bits;
use crate::config::ModelSpec;
use crate::graph::{ChurnEvent, ChurnKind, ChurnSchedule, Topology};
use crate::param::Blocks;
use crate::quant::{payload_bits, QuantConfig, Quantizer, QuantizerState};
use crate::solver::{Backend, LinearSolver, LogisticSolver, MlpSolver, SubproblemSolver};
use crate::util::axpy;
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// Everything a [`WorkerCore`] needs at construction.
pub struct WorkerSetup {
    pub id: usize,
    pub d: usize,
    pub rho: f64,
    /// Neighbor ids in **ascending** order (the summation order both
    /// engines share; [`Topology::neighbors`] is already sorted).
    pub neighbors: Vec<usize>,
    pub solver: Box<dyn SubproblemSolver>,
    pub censor: Option<CensorConfig>,
    /// Single-block quantizer (`blocks.is_single()` models); multi-block
    /// models use `block_quantizers` instead.
    pub quantizer: Option<Quantizer>,
    /// Jacobian (DCADMM) schedules anchor the update on the worker's own
    /// last broadcast: `nbr_sum += d_i * hat_self` (the solver then
    /// carries the doubled penalty; see [`build_cores`]).
    pub jacobian_anchor: bool,
    /// Censoring-aware incremental cache maintenance (`false` forces the
    /// from-scratch rebuild every phase — the differential baseline).
    pub incremental: bool,
    /// Primal/dual update rule (ADMM family or the QDGD baseline).
    pub update: UpdateRule,
    /// Parameter-block layout.  [`Blocks::single`] engages none of the
    /// per-block machinery — that path is bit-identical to the
    /// pre-multi-block core.
    pub blocks: Blocks,
    /// Per-block quantizers, parallel to the layout's blocks.  Must be
    /// empty for single-block layouts (use `quantizer`) and for
    /// unquantized specs.
    pub block_quantizers: Vec<Quantizer>,
}

/// Borrowed view of the payload a committed broadcast carries; what the
/// coordinator's wire encoder consumes (the simulator ships the hat
/// itself).
pub enum PayloadRef<'a> {
    /// Full-precision model (unquantized schemes).
    Full(&'a [f64]),
    /// Quantized difference message (codes + adaptive `(R, b)` header).
    Quantized { radius: f64, bits: u32, codes: &'a [u32] },
}

/// Per-block transmission state of a multi-block core.  `None` for
/// single-block layouts: that path runs the exact pre-multi-block code
/// and stays bit-identical to it.
struct MultiBlock {
    layout: Blocks,
    /// Per-block quantizers (empty when the spec is unquantized).
    quantizers: Vec<Quantizer>,
    /// Per-block first-transmission flags (a block's first broadcast is
    /// never censored — state initialization, per block).
    tx_once: Vec<bool>,
    /// Gate decision per block of the current candidate; after a commit
    /// this is the committed-block mask receivers must apply.
    mask: Vec<bool>,
    /// Per-block payload bits of the current candidate.
    bits: Vec<u64>,
    /// Per-block `(radius, bits)` when quantized.
    last_quant: Vec<Option<(f64, u32)>>,
    /// Per-block code scratch (only filled when `collect_codes`).
    codes: Vec<Vec<u32>>,
}

/// The shared per-worker protocol state machine.
pub struct WorkerCore {
    id: usize,
    d: usize,
    rho: f64,
    neighbors: Vec<usize>,
    solver: Box<dyn SubproblemSolver>,
    censor: Option<CensorConfig>,
    quantizer: Option<Quantizer>,
    jacobian_anchor: bool,
    incremental: bool,
    update: UpdateRule,
    /// `Some` only for multi-block layouts (see [`MultiBlock`]).
    multi: Option<MultiBlock>,
    /// Gradient scratch of the QDGD rule (empty under ADMM rules).
    grad: Vec<f64>,
    theta: Vec<f64>,
    alpha: Vec<f64>,
    /// The worker's own last committed broadcast (theta-tilde / theta-hat
    /// — exactly what every neighbor holds for this worker).
    hat_self: Vec<f64>,
    /// One slot per neighbor (parallel to `neighbors`): the last
    /// reconstruction received from that neighbor (init 0, Alg. 2 l. 2).
    hat_nbrs: Vec<Vec<f64>>,
    /// First transmission is never censored (state initialization).
    transmitted_once: bool,
    /// Cached `sum_m hat_m` (+ Jacobian anchor), rebuilt while stale.
    nbr_sum: Vec<f64>,
    nbr_stale: bool,
    /// Cached dual increment `sum_m (hat_self - hat_m)`, rebuilt when the
    /// closed neighborhood changed since the last dual update.
    dual_delta: Vec<f64>,
    dual_stale: bool,
    /// Persistent quantize/censor candidate buffer.
    cand: Vec<f64>,
    /// Persistent code scratch of the current candidate (cleared, never
    /// reallocated after warm-up; only filled when `collect_codes`).
    codes: Vec<u32>,
    /// Whether `prepare_broadcast` materializes the candidate's integer
    /// codes.  The coordinator's wire encoder needs them; the in-process
    /// simulator does not and skips the per-coordinate collection
    /// (bit-identical RNG/arithmetic either way — property-locked).
    collect_codes: bool,
    /// `(radius, bits)` of the current candidate when quantized.
    last_quant: Option<(f64, u32)>,
    /// Payload bits of a prepared-but-unresolved broadcast.
    pending_bits: Option<u64>,
}

impl WorkerCore {
    pub fn new(setup: WorkerSetup) -> WorkerCore {
        let WorkerSetup {
            id,
            d,
            rho,
            neighbors,
            solver,
            censor,
            quantizer,
            jacobian_anchor,
            incremental,
            update,
            blocks,
            block_quantizers,
        } = setup;
        debug_assert!(
            neighbors.windows(2).all(|w| w[0] < w[1]),
            "neighbor ids must be strictly increasing"
        );
        assert_eq!(blocks.d(), d, "block layout does not cover the model");
        let multi = if blocks.is_single() {
            assert!(
                block_quantizers.is_empty(),
                "single-block cores take the flat quantizer"
            );
            None
        } else {
            assert!(quantizer.is_none(), "multi-block cores quantize per block");
            assert!(
                block_quantizers.is_empty() || block_quantizers.len() == blocks.count(),
                "one quantizer per block"
            );
            let b = blocks.count();
            Some(MultiBlock {
                quantizers: block_quantizers,
                tx_once: vec![false; b],
                mask: vec![false; b],
                bits: vec![0; b],
                last_quant: vec![None; b],
                codes: vec![Vec::new(); b],
                layout: blocks,
            })
        };
        let deg = neighbors.len();
        WorkerCore {
            id,
            d,
            rho,
            hat_nbrs: vec![vec![0.0; d]; deg],
            neighbors,
            solver,
            censor,
            quantizer,
            jacobian_anchor,
            incremental,
            update,
            multi,
            grad: match update {
                UpdateRule::Qdgd { .. } => vec![0.0; d],
                UpdateRule::Admm => Vec::new(),
            },
            theta: vec![0.0; d],
            alpha: vec![0.0; d],
            hat_self: vec![0.0; d],
            transmitted_once: false,
            nbr_sum: vec![0.0; d],
            // mirror the run engine's seed state: the first fill always
            // rebuilds (from all-zero hats, so the value is zero anyway)
            nbr_stale: true,
            dual_delta: vec![0.0; d],
            // all hats are zero, so the zero increment is already correct
            dual_stale: false,
            cand: vec![0.0; d],
            codes: Vec::new(),
            collect_codes: false,
            last_quant: None,
            pending_bits: None,
        }
    }

    /// Opt in to code collection (see the `collect_codes` field); the
    /// coordinator calls this once per core at spawn.
    pub fn enable_code_collection(&mut self) {
        self.collect_codes = true;
    }

    /// Primal update (eqs. (21)/(22)): refresh the cached neighbor sum if
    /// stale, then solve the penalized subproblem in place over `theta`
    /// (which doubles as the warm start).  Allocation-free.
    ///
    /// Incremental engine: a clean cache's inputs are unchanged since its
    /// last rebuild, and a stale cache is rebuilt by this exact loop — so
    /// the value is bit-identical to a from-scratch recompute either way.
    pub fn primal_update(&mut self) {
        if !self.incremental || self.nbr_stale {
            self.nbr_sum.iter_mut().for_each(|v| *v = 0.0);
            for hat in &self.hat_nbrs {
                axpy(&mut self.nbr_sum, 1.0, hat);
            }
            if self.jacobian_anchor {
                axpy(&mut self.nbr_sum, self.neighbors.len() as f64, &self.hat_self);
            }
            self.nbr_stale = false;
        }
        match self.update {
            UpdateRule::Admm => {
                self.solver.update_into(&self.alpha, &self.nbr_sum, &mut self.theta)
            }
            // QDGD: consensus-average with the latest neighbor
            // reconstructions, then a first-order step
            //   theta <- (theta + sum_m hat_m) / (d_n + 1) - lr grad f_n
            UpdateRule::Qdgd { lr } => {
                self.solver.grad_into(&self.theta, &mut self.grad);
                let scale = 1.0 / (self.neighbors.len() as f64 + 1.0);
                for j in 0..self.d {
                    self.theta[j] =
                        (self.theta[j] + self.nbr_sum[j]) * scale - lr * self.grad[j];
                }
            }
        }
    }

    /// Seed the initial model (iteration 0 only; [`build_cores`] copies
    /// the problem's `theta0`).  A no-op value-wise for the all-zeros GLM
    /// start, so the pre-refactor trajectories are unchanged.
    pub fn seed_theta(&mut self, theta0: &[f64]) {
        assert_eq!(theta0.len(), self.d);
        self.theta.copy_from_slice(theta0);
    }

    /// Transmission pipeline (quantize → censor) at censoring iteration
    /// `k_plus_1`.  Builds the candidate hat in the persistent scratch
    /// (quantizers also advance their `(R, b)` state and RNG stream —
    /// exactly once per phase, committed or not) and gates it.  Returns
    /// the payload bits when the worker decided to broadcast; the driver
    /// must then resolve the attempt with [`WorkerCore::commit_pending`]
    /// (delivered) or [`WorkerCore::abort_pending`] (erasure).
    pub fn prepare_broadcast(&mut self, k_plus_1: u64) -> Option<u64> {
        self.prepare_broadcast_gated(k_plus_1, false)
    }

    /// [`WorkerCore::prepare_broadcast`] with an optional staleness
    /// override: `force = true` bypasses the censor gate (the bounded-
    /// staleness policy's force-refresh once a neighbor's copy is τ
    /// rounds stale).  The candidate pipeline — including the
    /// quantizer's `(R, b)` advance and RNG draw — is identical either
    /// way, so forcing changes only the gate decision, never the stream.
    pub fn prepare_broadcast_gated(&mut self, k_plus_1: u64, force: bool) -> Option<u64> {
        debug_assert!(self.pending_bits.is_none(), "unresolved broadcast");
        if self.multi.is_some() {
            return self.prepare_broadcast_blocks(k_plus_1, force);
        }
        let payload_bits = match &mut self.quantizer {
            Some(q) => {
                // quantize the difference against the last state the
                // neighbors hold (hat_self) so sender/receiver stay in sync
                let (radius, bits) = if self.collect_codes {
                    q.quantize_with_codes(
                        &self.theta,
                        &self.hat_self,
                        &mut self.cand,
                        &mut self.codes,
                    )
                } else {
                    q.quantize_into(&self.theta, &self.hat_self, &mut self.cand)
                };
                self.last_quant = Some((radius, bits));
                payload_bits(self.d, bits)
            }
            None => {
                self.cand.copy_from_slice(&self.theta);
                self.last_quant = None;
                full_precision_bits(self.d)
            }
        };
        let decision = match (&self.censor, self.transmitted_once) {
            _ if force => Gate::Transmit,
            // first broadcast always goes out (state init)
            (_, false) => Gate::Transmit,
            (None, _) => Gate::Transmit,
            (Some(c), true) => gate(c, k_plus_1, &self.hat_self, &self.cand),
        };
        if decision == Gate::Transmit {
            self.pending_bits = Some(payload_bits);
            Some(payload_bits)
        } else {
            None
        }
    }

    /// The multi-block candidate pipeline: every block quantizes (its
    /// quantizer advancing exactly once per phase, committed or not) and
    /// gates **independently** — a censored layer ships nothing while
    /// another layer commits.  The broadcast goes out iff at least one
    /// block transmits; its payload bits are the sum over transmitting
    /// blocks.  A block's first transmission is never censored.
    fn prepare_broadcast_blocks(&mut self, k_plus_1: u64, force: bool) -> Option<u64> {
        let multi = self.multi.as_mut().expect("multi-block path");
        let mut total = 0u64;
        let mut any = false;
        for b in 0..multi.layout.count() {
            let r = multi.layout.range(b);
            let len = r.end - r.start;
            let bits_b = if multi.quantizers.is_empty() {
                self.cand[r.clone()].copy_from_slice(&self.theta[r.clone()]);
                multi.last_quant[b] = None;
                full_precision_bits(len)
            } else {
                let q = &mut multi.quantizers[b];
                let (radius, bits) = if self.collect_codes {
                    q.quantize_with_codes(
                        &self.theta[r.clone()],
                        &self.hat_self[r.clone()],
                        &mut self.cand[r.clone()],
                        &mut multi.codes[b],
                    )
                } else {
                    q.quantize_into(
                        &self.theta[r.clone()],
                        &self.hat_self[r.clone()],
                        &mut self.cand[r.clone()],
                    )
                };
                multi.last_quant[b] = Some((radius, bits));
                payload_bits(len, bits)
            };
            let decision = match (&self.censor, multi.tx_once[b]) {
                _ if force => Gate::Transmit,
                (_, false) => Gate::Transmit,
                (None, _) => Gate::Transmit,
                (Some(c), true) => {
                    gate(c, k_plus_1, &self.hat_self[r.clone()], &self.cand[r])
                }
            };
            multi.mask[b] = decision == Gate::Transmit;
            multi.bits[b] = bits_b;
            if multi.mask[b] {
                total += bits_b;
                any = true;
            }
        }
        if any {
            self.pending_bits = Some(total);
            Some(total)
        } else {
            None
        }
    }

    /// Payload bits of the prepared-but-unresolved broadcast, if any.
    pub fn pending_bits(&self) -> Option<u64> {
        self.pending_bits
    }

    /// The broadcast was delivered: commit the candidate as the new
    /// `hat_self` and stale the caches its commit invalidates (the dual
    /// increment always; the neighbor sum only under the Jacobian anchor
    /// — neighbors stale their own caches in [`WorkerCore::deliver_with`]).
    pub fn commit_pending(&mut self) {
        debug_assert!(self.pending_bits.is_some(), "commit without a pending broadcast");
        self.pending_bits = None;
        if let Some(multi) = &mut self.multi {
            for b in 0..multi.layout.count() {
                if multi.mask[b] {
                    let r = multi.layout.range(b);
                    self.hat_self[r.clone()].copy_from_slice(&self.cand[r]);
                    multi.tx_once[b] = true;
                }
            }
        } else {
            self.hat_self.copy_from_slice(&self.cand);
        }
        self.transmitted_once = true;
        self.dual_stale = true;
        if self.jacobian_anchor {
            self.nbr_stale = true;
        }
    }

    /// The broadcast was lost (erasure with perfect feedback): the cost
    /// was paid by the medium, but state rolls back — neighbors keep the
    /// stale value and `hat_self` is unchanged, so every cache stays
    /// valid.  (The quantizer state has already advanced; both engines
    /// share that behavior by construction.)
    pub fn abort_pending(&mut self) {
        debug_assert!(self.pending_bits.is_some(), "abort without a pending broadcast");
        self.pending_bits = None;
        if let Some(multi) = &mut self.multi {
            // nothing reached the neighbors: clear the mask so a stale
            // read cannot mistake the aborted candidate for a commit
            multi.mask.iter_mut().for_each(|m| *m = false);
        }
    }

    /// Payload of the most recently prepared candidate (valid after
    /// [`WorkerCore::commit_pending`]; what the wire encoder serializes).
    pub fn committed_payload(&self) -> PayloadRef<'_> {
        debug_assert!(self.multi.is_none(), "multi-block cores encode per block");
        match self.last_quant {
            Some((radius, bits)) => {
                debug_assert!(
                    self.codes.len() == self.d,
                    "codes not collected: call enable_code_collection at setup"
                );
                PayloadRef::Quantized { radius, bits, codes: &self.codes }
            }
            None => PayloadRef::Full(&self.hat_self),
        }
    }

    /// Payload of the prepared-but-**unresolved** candidate (valid
    /// between [`WorkerCore::prepare_broadcast_gated`] and the
    /// commit/abort resolution).  The networked worker encodes this
    /// optimistically and ships it alongside its transmit decision —
    /// the leader then resolves the erasure draw without a second round
    /// trip.  Full-precision reads the candidate scratch (identical to
    /// `hat_self` only *after* a commit); quantized parts are the same
    /// either side of the commit.
    pub fn pending_payload(&self) -> PayloadRef<'_> {
        debug_assert!(self.pending_bits.is_some(), "pending payload without a pending broadcast");
        debug_assert!(self.multi.is_none(), "multi-block cores encode per block");
        match self.last_quant {
            Some((radius, bits)) => {
                debug_assert!(
                    self.codes.len() == self.d,
                    "codes not collected: call enable_code_collection at setup"
                );
                PayloadRef::Quantized { radius, bits, codes: &self.codes }
            }
            None => PayloadRef::Full(&self.cand),
        }
    }

    /// Number of parameter blocks (1 for flat models).
    pub fn block_count(&self) -> usize {
        self.multi.as_ref().map_or(1, |m| m.layout.count())
    }

    /// The parameter-block layout (an owned copy; setup-time use only).
    pub fn block_layout(&self) -> Blocks {
        self.multi
            .as_ref()
            .map_or_else(|| Blocks::single(self.d), |m| m.layout.clone())
    }

    /// Per-block transmit mask of the current candidate — after
    /// [`WorkerCore::commit_pending`], the committed-block mask receivers
    /// must apply ([`WorkerCore::deliver_spans`]).  `None` for
    /// single-block cores (the whole vector commits or nothing does).
    pub fn broadcast_mask(&self) -> Option<&[bool]> {
        self.multi.as_ref().map(|m| &m.mask[..])
    }

    /// Per-block payload bits of the current candidate (censored blocks
    /// included — mask with [`WorkerCore::broadcast_mask`] to account
    /// transmitted bits).  `None` for single-block cores.
    pub fn candidate_block_bits(&self) -> Option<&[u64]> {
        self.multi.as_ref().map(|m| &m.bits[..])
    }

    /// Block `b`'s payload after a commit (what the wire encoder
    /// serializes for transmitting blocks of a multi-block core).
    pub fn committed_block_payload(&self, b: usize) -> PayloadRef<'_> {
        let multi = self.multi.as_ref().expect("single-block cores use committed_payload");
        match multi.last_quant[b] {
            Some((radius, bits)) => {
                debug_assert!(
                    multi.codes[b].len() == multi.layout.len_of(b),
                    "codes not collected: call enable_code_collection at setup"
                );
                PayloadRef::Quantized { radius, bits, codes: &multi.codes[b] }
            }
            None => PayloadRef::Full(&self.hat_self[multi.layout.range(b)]),
        }
    }

    /// Block `b`'s payload between prepare and resolution (the networked
    /// worker's optimistic encode; see [`WorkerCore::pending_payload`]).
    pub fn pending_block_payload(&self, b: usize) -> PayloadRef<'_> {
        debug_assert!(self.pending_bits.is_some(), "pending payload without a pending broadcast");
        let multi = self.multi.as_ref().expect("single-block cores use pending_payload");
        match multi.last_quant[b] {
            Some((radius, bits)) => {
                debug_assert!(
                    multi.codes[b].len() == multi.layout.len_of(b),
                    "codes not collected: call enable_code_collection at setup"
                );
                PayloadRef::Quantized { radius, bits, codes: &multi.codes[b] }
            }
            None => PayloadRef::Full(&self.cand[multi.layout.range(b)]),
        }
    }

    /// Receive a neighbor's committed hat in-process (the simulator's
    /// delivery path): overwrite the slot with the sender's exact `f64`
    /// reconstruction.
    pub fn deliver(&mut self, from: usize, hat: &[f64]) {
        self.deliver_with(from, |slot| slot.copy_from_slice(hat));
    }

    /// Receive a neighbor's broadcast through an arbitrary decoder: `f`
    /// gets mutable access to the stored slot for `from` (which holds the
    /// shared reference the quantized decode reconstructs against) and
    /// the caches invalidated by the delivery are staled.  The
    /// coordinator's wire path decodes straight into the slot here —
    /// no intermediate allocation.
    pub fn deliver_with<F: FnOnce(&mut [f64])>(&mut self, from: usize, f: F) {
        let idx = match self.neighbors.binary_search(&from) {
            Ok(idx) => idx,
            Err(_) => panic!("worker {}: delivery from non-neighbor {from}", self.id),
        };
        f(&mut self.hat_nbrs[idx]);
        self.nbr_stale = true;
        self.dual_stale = true;
    }

    /// Receive a multi-block broadcast in-process: copy only the sender's
    /// **committed** block spans into the slot (`mask` is the sender's
    /// [`WorkerCore::broadcast_mask`] after its commit).  Censored spans
    /// keep the receiver's stale copy — overwriting the whole slot would
    /// resync spans the wire path never shipped, diverging under erasure.
    pub fn deliver_spans(&mut self, from: usize, hat: &[f64], mask: &[bool]) {
        assert_eq!(hat.len(), self.d);
        let idx = match self.neighbors.binary_search(&from) {
            Ok(idx) => idx,
            Err(_) => panic!("worker {}: delivery from non-neighbor {from}", self.id),
        };
        let multi = self.multi.as_ref().expect("deliver_spans on a single-block core");
        assert_eq!(mask.len(), multi.layout.count());
        let slot = &mut self.hat_nbrs[idx];
        for b in 0..multi.layout.count() {
            if mask[b] {
                let r = multi.layout.range(b);
                slot[r.clone()].copy_from_slice(&hat[r]);
            }
        }
        self.nbr_stale = true;
        self.dual_stale = true;
    }

    /// Dual update (eq. (23)): rebuild the cached increment if a hat in
    /// the closed neighborhood committed since the last dual update, then
    /// integrate `alpha += rho * sum_m (hat_self - hat_m)`.  The O(d)
    /// integration runs every iteration (duals accumulate even across
    /// censored rounds); the O(deg * d) rebuild only when needed.
    pub fn dual_update(&mut self) {
        // QDGD is primal-only: no dual variable accumulates
        if let UpdateRule::Qdgd { .. } = self.update {
            return;
        }
        if !self.incremental || self.dual_stale {
            self.dual_delta.iter_mut().for_each(|v| *v = 0.0);
            for hat in &self.hat_nbrs {
                for j in 0..self.d {
                    self.dual_delta[j] += self.hat_self[j] - hat[j];
                }
            }
            self.dual_stale = false;
        }
        axpy(&mut self.alpha, self.rho, &self.dual_delta);
    }

    /// Local objective `f_n(theta_n)` (no penalty terms).
    pub fn loss(&self) -> f64 {
        self.solver.loss(&self.theta)
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn neighbors(&self) -> &[usize] {
        &self.neighbors
    }

    pub fn theta(&self) -> &[f64] {
        &self.theta
    }

    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }

    pub fn hat_self(&self) -> &[f64] {
        &self.hat_self
    }

    /// Cached neighbor sum (tests/diagnostics); under the incremental
    /// engine, bit-identical to a from-scratch recompute at the point of
    /// this worker's latest primal update.
    pub fn neighbor_sum(&self) -> &[f64] {
        &self.nbr_sum
    }

    /// Cached dual increment (tests/diagnostics); same bit-identity
    /// guarantee as [`WorkerCore::neighbor_sum`].
    pub fn dual_delta(&self) -> &[f64] {
        &self.dual_delta
    }

    /// Solver degree corresponding to a graph degree (Jacobian-anchored
    /// schedules carry the doubled DCADMM penalty; see [`build_cores`]).
    fn solver_degree(&self, graph_degree: usize) -> usize {
        if self.jacobian_anchor {
            2 * graph_degree
        } else {
            graph_degree
        }
    }

    /// Drop a departed neighbor (churn): remove its id and hat slot,
    /// stale the incremental caches (the next primal/dual update rebuilds
    /// them from scratch over the surviving neighbors — bit-identical to
    /// a core constructed on the shrunken graph), and re-derive the
    /// solver's degree-dependent terms.  A worker left at degree 0 keeps
    /// its old solver untouched: the engines skip it entirely until a
    /// neighbor (re)attaches.
    pub fn detach_neighbor(&mut self, id: usize) {
        let idx = match self.neighbors.binary_search(&id) {
            Ok(idx) => idx,
            Err(_) => panic!("worker {}: detach of non-neighbor {id}", self.id),
        };
        self.neighbors.remove(idx);
        self.hat_nbrs.remove(idx);
        self.nbr_stale = true;
        self.dual_stale = true;
        let deg = self.neighbors.len();
        if deg >= 1 {
            self.solver.set_degree(self.solver_degree(deg));
        }
    }

    /// Attach a (re)joining neighbor (churn): insert its id in sorted
    /// position with `hat` as the reconstruction slot (the joiner's
    /// current `hat_self` — both sides agree on it by construction),
    /// stale the caches, and re-derive the solver degree.
    pub fn attach_neighbor(&mut self, id: usize, hat: &[f64]) {
        assert_eq!(hat.len(), self.d);
        let idx = match self.neighbors.binary_search(&id) {
            Ok(_) => panic!("worker {}: attach of existing neighbor {id}", self.id),
            Err(idx) => idx,
        };
        self.neighbors.insert(idx, id);
        self.hat_nbrs.insert(idx, hat.to_vec());
        self.nbr_stale = true;
        self.dual_stale = true;
        self.solver.set_degree(self.solver_degree(self.neighbors.len()));
    }

    /// Warm-start a rejoining worker from the group-consensus iterate
    /// `warm`: the model, its own broadcast state and the dual all reset
    /// (`alpha = 0` — the departed dual trajectory is meaningless on the
    /// new graph), and the handoff counts as the state-initializing first
    /// transmission, so the censor gate applies from the next round.  The
    /// caller attaches neighbors separately (both directions).
    pub fn rejoin_with(&mut self, warm: &[f64]) {
        assert_eq!(warm.len(), self.d);
        debug_assert!(self.pending_bits.is_none(), "rejoin with unresolved broadcast");
        debug_assert!(self.neighbors.is_empty(), "rejoin before neighbors re-attach");
        self.theta.copy_from_slice(warm);
        self.hat_self.copy_from_slice(warm);
        self.alpha.iter_mut().for_each(|v| *v = 0.0);
        self.transmitted_once = true;
        if let Some(multi) = &mut self.multi {
            // the handoff initializes every block's broadcast state
            multi.tx_once.iter_mut().for_each(|t| *t = true);
        }
        self.nbr_stale = true;
        self.dual_stale = true;
    }

    /// Export the full durable state at an iteration boundary (after
    /// `dual_update`, before the next `primal_update`).  The candidate /
    /// code / `last_quant` scratch and `pending_bits` are deliberately
    /// excluded: between iterations every broadcast is resolved
    /// (`pending_bits` is `None`) and the scratch is overwritten before
    /// its next read, so it carries no information.
    pub fn export_state(&self) -> CoreState {
        debug_assert!(self.pending_bits.is_none(), "export with unresolved broadcast");
        CoreState {
            theta: self.theta.clone(),
            alpha: self.alpha.clone(),
            hat_self: self.hat_self.clone(),
            hat_nbrs: self.hat_nbrs.clone(),
            transmitted_once: self.transmitted_once,
            nbr_sum: self.nbr_sum.clone(),
            nbr_stale: self.nbr_stale,
            dual_delta: self.dual_delta.clone(),
            dual_stale: self.dual_stale,
            quantizer: self.quantizer.as_ref().map(|q| q.state()),
            block_quantizers: self
                .multi
                .as_ref()
                .map_or_else(Vec::new, |m| m.quantizers.iter().map(|q| q.state()).collect()),
            block_tx_once: self.multi.as_ref().map_or_else(Vec::new, |m| m.tx_once.clone()),
        }
    }

    /// Overwrite the durable state from a checkpoint.  The core must have
    /// been constructed for the same problem/topology/spec (dimension,
    /// degree, and quantizer presence are asserted).
    pub fn import_state(&mut self, s: &CoreState) {
        assert_eq!(s.theta.len(), self.d, "checkpoint dimension mismatch");
        assert_eq!(
            s.hat_nbrs.len(),
            self.neighbors.len(),
            "checkpoint degree mismatch for worker {}",
            self.id
        );
        assert_eq!(
            s.quantizer.is_some(),
            self.quantizer.is_some(),
            "checkpoint quantizer presence mismatch for worker {}",
            self.id
        );
        self.theta.copy_from_slice(&s.theta);
        self.alpha.copy_from_slice(&s.alpha);
        self.hat_self.copy_from_slice(&s.hat_self);
        for (slot, hat) in self.hat_nbrs.iter_mut().zip(&s.hat_nbrs) {
            slot.copy_from_slice(hat);
        }
        self.transmitted_once = s.transmitted_once;
        self.nbr_sum.copy_from_slice(&s.nbr_sum);
        self.nbr_stale = s.nbr_stale;
        self.dual_delta.copy_from_slice(&s.dual_delta);
        self.dual_stale = s.dual_stale;
        if let (Some(q), Some(qs)) = (&mut self.quantizer, &s.quantizer) {
            q.restore(qs);
        }
        if let Some(multi) = &mut self.multi {
            assert_eq!(
                s.block_quantizers.len(),
                multi.quantizers.len(),
                "checkpoint block-quantizer arity mismatch for worker {}",
                self.id
            );
            assert_eq!(
                s.block_tx_once.len(),
                multi.tx_once.len(),
                "checkpoint block arity mismatch for worker {}",
                self.id
            );
            for (q, qs) in multi.quantizers.iter_mut().zip(&s.block_quantizers) {
                q.restore(qs);
            }
            multi.tx_once.copy_from_slice(&s.block_tx_once);
            multi.mask.iter_mut().for_each(|m| *m = false);
        } else {
            assert!(
                s.block_quantizers.is_empty() && s.block_tx_once.is_empty(),
                "multi-block checkpoint restored onto a single-block core (worker {})",
                self.id
            );
        }
        self.pending_bits = None;
    }
}

/// The durable per-worker state a checkpoint carries — everything the
/// trajectory depends on between iteration boundaries (model, dual, hat
/// slots, censor init flag, incremental caches with their staleness, and
/// the quantizer's adaptive `(R, b)` history + RNG stream position).
#[derive(Clone, Debug, PartialEq)]
pub struct CoreState {
    pub theta: Vec<f64>,
    pub alpha: Vec<f64>,
    pub hat_self: Vec<f64>,
    /// Parallel to the core's (sorted) neighbor list.
    pub hat_nbrs: Vec<Vec<f64>>,
    pub transmitted_once: bool,
    pub nbr_sum: Vec<f64>,
    pub nbr_stale: bool,
    pub dual_delta: Vec<f64>,
    pub dual_stale: bool,
    pub quantizer: Option<QuantizerState>,
    /// Per-block quantizer states (multi-block quantized specs only;
    /// empty otherwise — single-block checkpoints stay byte-identical).
    pub block_quantizers: Vec<QuantizerState>,
    /// Per-block first-transmission flags (multi-block only).
    pub block_tx_once: Vec<bool>,
}

/// Construction options shared by both drivers.
#[derive(Clone, Debug)]
pub struct ProtocolConfig {
    pub backend: Backend,
    /// Artifact directory for the PJRT backend.
    pub artifacts_dir: Option<std::path::PathBuf>,
    pub incremental: bool,
    /// Seed for the quantizer streams (and, downstream, the link model).
    pub seed: u64,
}

/// Build the per-worker solver fleet (optionally fanned out over an
/// existing pool: the Gram + Cholesky setup is the expensive part of
/// construction and embarrassingly parallel).
fn build_solvers(
    problem: &Problem,
    topo: &Topology,
    cfg: &ProtocolConfig,
    spec: &AlgSpec,
    pool: Option<&mut crate::parallel::WorkerPool>,
) -> Vec<Box<dyn SubproblemSolver>> {
    crate::parallel::map_maybe_pool(pool, topo.n(), |i| {
        build_solver_at(problem, topo, cfg, spec, i)
    })
}

/// Build worker `i`'s solver alone (what [`build_solvers`] fans out, and
/// what a networked worker process builds for just its own ids).
fn build_solver_at(
    problem: &Problem,
    topo: &Topology,
    cfg: &ProtocolConfig,
    spec: &AlgSpec,
    i: usize,
) -> Box<dyn SubproblemSolver> {
    use crate::config::Task;
    let sh = &problem.shards[i];
    // Jacobian updates carry the doubled penalty rho*d_i||theta||^2
    // of DCADMM (see `WorkerCore::primal_update`'s anchor); the
    // solver's quadratic coefficient is rho*degree/2, so feed it 2*d_i.
    // QDGD never anchors (its schedule is Jacobian only in the sense
    // that every worker updates every round), so no doubling either.
    let degree = match (spec.update, spec.schedule) {
        (UpdateRule::Qdgd { .. }, _) => topo.degree(i),
        (UpdateRule::Admm, Schedule::Alternating) => topo.degree(i),
        (UpdateRule::Admm, Schedule::Jacobian) => 2 * topo.degree(i),
    };
    if let ModelSpec::Mlp { hidden } = problem.model {
        assert_eq!(
            cfg.backend,
            Backend::Native,
            "the MLP model runs on the native backend only"
        );
        return Box::new(MlpSolver::from_shard(
            Arc::clone(sh),
            problem.mu0,
            problem.rho,
            degree,
            hidden,
        ));
    }
    match (cfg.backend, problem.task) {
        (Backend::Native, Task::Linear) => {
            Box::new(LinearSolver::from_shard(Arc::clone(sh), problem.rho, degree))
        }
        (Backend::Native, Task::Logistic) => Box::new(LogisticSolver::from_shard(
            Arc::clone(sh),
            problem.mu0,
            problem.rho,
            degree,
        )),
        (Backend::Pjrt, task) => crate::runtime::pjrt_solver(
            cfg.artifacts_dir
                .as_deref()
                .expect("PJRT backend needs artifacts_dir"),
            task,
            sh,
            problem.rho,
            problem.mu0,
            degree,
        )
        .expect("failed to build PJRT solver"),
    }
}

/// Per-block quantizer configs: the spec's config with `bits0` replaced
/// by the per-block allocation (`bits_split`, or the uniform `bits0`
/// broadcast).  `None` when the spec is unquantized.
fn per_block_quant_cfgs(spec: &AlgSpec, blocks: &Blocks) -> Option<Vec<QuantConfig>> {
    let q = spec.quant?;
    let widths: Vec<u32> = match &spec.bits_split {
        Some(s) => {
            assert_eq!(
                s.len(),
                blocks.count(),
                "bits split names {} blocks but the model has {}",
                s.len(),
                blocks.count()
            );
            s.clone()
        }
        None => vec![q.bits0; blocks.count()],
    };
    Some(widths.into_iter().map(|b| QuantConfig { bits0: b, ..q }).collect())
}

/// Build the worker fleet for one run.  This is the **single** place both
/// engines construct their state from, so they cannot drift: quantizer
/// RNG streams are forked from `Pcg64::new(seed ^ 0xA16_0001)` in worker
/// order (only for quantized specs — unquantized specs leave the root
/// stream untouched), and the leftover root generator is returned for the
/// link model's erasure draws (same stream position in both engines).
pub fn build_cores(
    problem: &Problem,
    topo: &Topology,
    spec: &AlgSpec,
    cfg: &ProtocolConfig,
    pool: Option<&mut crate::parallel::WorkerPool>,
) -> (Vec<WorkerCore>, Pcg64) {
    assert_eq!(problem.shards.len(), topo.n());
    let d = problem.d;
    let single = problem.blocks.is_single();
    let mut rng = Pcg64::new(cfg.seed ^ 0xA16_0001);
    let solvers = build_solvers(problem, topo, cfg, spec, pool);
    let block_cfgs = per_block_quant_cfgs(spec, &problem.blocks);
    let cores = solvers
        .into_iter()
        .enumerate()
        .map(|(i, solver)| {
            // one root fork per worker in both layouts, so the leftover
            // link stream is at the same position either way; multi-block
            // quantizers fork the per-worker stream once per block
            let (quantizer, block_quantizers) = match (&spec.quant, single) {
                (None, _) => (None, Vec::new()),
                (Some(q), true) => (Some(Quantizer::new(*q, rng.fork(i as u64))), Vec::new()),
                (Some(_), false) => {
                    let mut wrng = rng.fork(i as u64);
                    let cfgs = block_cfgs.as_ref().expect("quantized spec");
                    let qs = cfgs
                        .iter()
                        .enumerate()
                        .map(|(b, c)| Quantizer::new(*c, wrng.fork(b as u64)))
                        .collect();
                    (None, qs)
                }
            };
            let mut core = WorkerCore::new(WorkerSetup {
                id: i,
                d,
                rho: problem.rho,
                neighbors: topo.neighbors(i).to_vec(),
                solver,
                censor: spec.censor,
                quantizer,
                jacobian_anchor: spec.schedule == Schedule::Jacobian
                    && matches!(spec.update, UpdateRule::Admm),
                incremental: cfg.incremental,
                update: spec.update,
                blocks: problem.blocks.clone(),
                block_quantizers,
            });
            core.seed_theta(&problem.theta0);
            core
        })
        .collect();
    (cores, rng)
}

/// Build **one** worker's core in isolation — the networked worker
/// process's construction path.  Replays the exact quantizer-stream fork
/// sequence of [`build_cores`] up to worker `i` (each fork consumes one
/// root draw, so replay is `i + 1` cheap RNG steps, no solver work for
/// the other workers), so the resulting core is bit-identical to the
/// in-process fleet's `cores[i]`.
pub fn build_core_at(
    problem: &Problem,
    topo: &Topology,
    spec: &AlgSpec,
    cfg: &ProtocolConfig,
    i: usize,
) -> WorkerCore {
    assert_eq!(problem.shards.len(), topo.n());
    assert!(i < topo.n(), "worker id {i} out of range for n = {}", topo.n());
    let single = problem.blocks.is_single();
    let mut rng = Pcg64::new(cfg.seed ^ 0xA16_0001);
    let (quantizer, block_quantizers) = match (&spec.quant, single) {
        (None, _) => (None, Vec::new()),
        (Some(q), true) => {
            for j in 0..i {
                let _ = rng.fork(j as u64);
            }
            (Some(Quantizer::new(*q, rng.fork(i as u64))), Vec::new())
        }
        (Some(_), false) => {
            for j in 0..i {
                let _ = rng.fork(j as u64);
            }
            let mut wrng = rng.fork(i as u64);
            let cfgs =
                per_block_quant_cfgs(spec, &problem.blocks).expect("quantized spec");
            let qs = cfgs
                .iter()
                .enumerate()
                .map(|(b, c)| Quantizer::new(*c, wrng.fork(b as u64)))
                .collect();
            (None, qs)
        }
    };
    let mut core = WorkerCore::new(WorkerSetup {
        id: i,
        d: problem.d,
        rho: problem.rho,
        neighbors: topo.neighbors(i).to_vec(),
        solver: build_solver_at(problem, topo, cfg, spec, i),
        censor: spec.censor,
        quantizer,
        jacobian_anchor: spec.schedule == Schedule::Jacobian
            && matches!(spec.update, UpdateRule::Admm),
        incremental: cfg.incremental,
        update: spec.update,
        blocks: problem.blocks.clone(),
        block_quantizers,
    });
    core.seed_theta(&problem.theta0);
    core
}

/// The link-model RNG both engines hand to `LinkKind::build`: the
/// construction root stream after [`build_cores`]'s quantizer forks
/// (`n` draws for quantized specs, none otherwise).  Lets the networked
/// server — which builds no cores of its own — derive the identical
/// stream position.
pub fn link_rng(spec: &AlgSpec, cfg: &ProtocolConfig, n: usize) -> Pcg64 {
    let mut rng = Pcg64::new(cfg.seed ^ 0xA16_0001);
    if spec.quant.is_some() {
        for j in 0..n {
            let _ = rng.fork(j as u64);
        }
    }
    rng
}

impl Default for ProtocolConfig {
    fn default() -> Self {
        ProtocolConfig {
            backend: Backend::Native,
            artifacts_dir: None,
            incremental: true,
            seed: 7,
        }
    }
}

/// Apply one churn event to the fleet.  Both engines call this with
/// identical arguments at the start of the event's iteration, so the
/// membership transitions — detach order, warm-start arithmetic,
/// re-attachment order — cannot drift between them.  `C` is whatever
/// the engine wraps its cores in (`WorkerCore` itself in the simulator,
/// `ShardWorker` in the coordinator).
///
/// * Leave: the worker is detached from every current neighbor (both
///   directions, ascending neighbor order) and its state freezes in
///   place; `active[w]` flips off.
/// * Join: the worker warm-starts from the mean `hat_self` of the
///   active workers sharing its bipartite group (ascending worker
///   order; its own frozen hat when the group is empty), then
///   re-attaches every edge to an active topology neighbor.
pub fn apply_churn_event<C>(cores: &mut [C], active: &mut [bool], topo: &Topology, e: &ChurnEvent)
where
    C: AsRef<WorkerCore> + AsMut<WorkerCore>,
{
    let w = e.worker;
    assert!(w < cores.len(), "churn event names worker {w} of {}", cores.len());
    match e.kind {
        ChurnKind::Leave => {
            assert!(active[w], "validated schedule: leave while present");
            let nbrs: Vec<usize> = cores[w].as_ref().neighbors().to_vec();
            for m in nbrs {
                cores[m].as_mut().detach_neighbor(w);
                cores[w].as_mut().detach_neighbor(m);
            }
            active[w] = false;
        }
        ChurnKind::Join => {
            assert!(!active[w], "validated schedule: join while absent");
            let d = cores[w].as_ref().hat_self().len();
            let mut warm = vec![0.0; d];
            let mut count = 0usize;
            for (j, core) in cores.iter().enumerate() {
                if j != w && active[j] && topo.group(j) == topo.group(w) {
                    axpy(&mut warm, 1.0, core.as_ref().hat_self());
                    count += 1;
                }
            }
            if count > 0 {
                let inv = 1.0 / count as f64;
                warm.iter_mut().for_each(|v| *v *= inv);
            } else {
                warm.copy_from_slice(cores[w].as_ref().hat_self());
            }
            cores[w].as_mut().rejoin_with(&warm);
            for &m in topo.neighbors(w) {
                if active[m] {
                    let hat_m = cores[m].as_ref().hat_self().to_vec();
                    cores[w].as_mut().attach_neighbor(m, &hat_m);
                    cores[m].as_mut().attach_neighbor(w, &warm);
                }
            }
            active[w] = true;
        }
    }
}

/// Replay only the **structural** effect of every churn event strictly
/// before `upto` — neighbor lists, solver degrees, membership flags —
/// on a freshly built fleet, so a checkpoint taken mid-churn restores
/// onto cores whose shapes match its [`CoreState`]s.  Values (hats,
/// warm starts) are left as placeholders: the caller's `import_state`
/// pass overwrites them, and `set_degree` is a pure function of the
/// final degree, so the result is bit-identical to the live engine.
pub fn replay_churn_structure<C>(
    cores: &mut [C],
    active: &mut [bool],
    topo: &Topology,
    schedule: &ChurnSchedule,
    upto: u64,
) where
    C: AsRef<WorkerCore> + AsMut<WorkerCore>,
{
    let zeros = vec![0.0; cores.first().map_or(0, |c| c.as_ref().hat_self().len())];
    for e in schedule.events() {
        if e.at >= upto {
            break;
        }
        let w = e.worker;
        match e.kind {
            ChurnKind::Leave => {
                let nbrs: Vec<usize> = cores[w].as_ref().neighbors().to_vec();
                for m in nbrs {
                    cores[m].as_mut().detach_neighbor(w);
                    cores[w].as_mut().detach_neighbor(m);
                }
                active[w] = false;
            }
            ChurnKind::Join => {
                for &m in topo.neighbors(w) {
                    if active[m] {
                        cores[w].as_mut().attach_neighbor(m, &zeros);
                        cores[m].as_mut().attach_neighbor(w, &zeros);
                    }
                }
                active[w] = true;
            }
        }
    }
}

// Reflexive impls so the simulator's bare `Vec<WorkerCore>` satisfies
// the churn helpers' bounds (std has no blanket reflexive `AsRef`).
impl AsRef<WorkerCore> for WorkerCore {
    fn as_ref(&self) -> &WorkerCore {
        self
    }
}

impl AsMut<WorkerCore> for WorkerCore {
    fn as_mut(&mut self) -> &mut WorkerCore {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn two_cores() -> Vec<WorkerCore> {
        let topo = Topology::chain(2);
        let ds = synthetic::linear_dataset(24, 3, 5);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 5);
        let (cores, _) =
            build_cores(&p, &topo, &AlgSpec::ggadmm(), &ProtocolConfig::default(), None);
        cores
    }

    #[test]
    fn first_broadcast_never_censored() {
        let topo = Topology::chain(2);
        let ds = synthetic::linear_dataset(24, 3, 5);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 5);
        // huge tau0 would censor everything after state init
        let spec = AlgSpec::c_ggadmm(1e9, 0.9);
        let (mut cores, _) =
            build_cores(&p, &topo, &spec, &ProtocolConfig::default(), None);
        cores[0].primal_update();
        assert!(cores[0].prepare_broadcast(1).is_some(), "state init must transmit");
        cores[0].commit_pending();
        cores[0].primal_update();
        assert!(
            cores[0].prepare_broadcast(2).is_none(),
            "tau0 = 1e9 must censor every later round"
        );
    }

    #[test]
    fn commit_delivers_exact_hat_and_stales_receiver() {
        let mut cores = two_cores();
        cores[0].primal_update();
        let bits = cores[0].prepare_broadcast(1).expect("must transmit");
        assert_eq!(bits, full_precision_bits(3));
        assert_eq!(cores[0].pending_bits(), Some(bits));
        cores[0].commit_pending();
        let hat: Vec<f64> = cores[0].hat_self().to_vec();
        assert_eq!(hat, cores[0].theta(), "full-precision hat is theta exactly");
        cores[1].deliver(0, &hat);
        // the receiver's neighbor sum must now reflect the delivery
        cores[1].primal_update();
        assert_eq!(cores[1].neighbor_sum(), &hat[..]);
    }

    #[test]
    fn abort_rolls_back_nothing() {
        let mut cores = two_cores();
        cores[0].primal_update();
        cores[0].prepare_broadcast(1).expect("must transmit");
        let hat_before: Vec<f64> = cores[0].hat_self().to_vec();
        cores[0].abort_pending();
        assert_eq!(cores[0].hat_self(), &hat_before[..], "dropped broadcast keeps hat");
        // erasure does not count as the first transmission: the next
        // round must again transmit unconditionally
        cores[0].primal_update();
        assert!(cores[0].prepare_broadcast(2).is_some());
    }

    #[test]
    #[should_panic(expected = "non-neighbor")]
    fn delivery_from_non_neighbor_panics() {
        let mut cores = two_cores();
        let hat = vec![0.0; 3];
        cores[0].deliver(5, &hat);
    }

    #[test]
    fn churn_leave_then_join_restores_edges_and_warm_starts() {
        let topo = Topology::chain(4);
        let ds = synthetic::linear_dataset(32, 3, 5);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 5);
        let (mut cores, _) =
            build_cores(&p, &topo, &AlgSpec::ggadmm(), &ProtocolConfig::default(), None);
        // give the fleet distinct hats so the warm start is observable
        for c in cores.iter_mut() {
            c.primal_update();
            c.prepare_broadcast(1).expect("first broadcast");
            c.commit_pending();
        }
        let mut active = vec![true; 4];
        apply_churn_event(
            &mut cores,
            &mut active,
            &topo,
            &ChurnEvent { at: 1, worker: 1, kind: ChurnKind::Leave },
        );
        assert!(!active[1]);
        assert!(cores[1].neighbors().is_empty());
        assert_eq!(cores[0].neighbors(), &[] as &[usize]);
        assert_eq!(cores[2].neighbors(), &[3]);
        apply_churn_event(
            &mut cores,
            &mut active,
            &topo,
            &ChurnEvent { at: 5, worker: 1, kind: ChurnKind::Join },
        );
        assert!(active[1]);
        assert_eq!(cores[1].neighbors(), &[0, 2]);
        assert_eq!(cores[0].neighbors(), &[1]);
        assert_eq!(cores[2].neighbors(), &[1, 3]);
        // warm start = mean hat over the same bipartite group's active
        // workers (chain groups alternate, so worker 1's peer is 3)
        let expect: Vec<f64> = cores[3].hat_self().to_vec();
        assert_eq!(cores[1].hat_self(), &expect[..]);
        assert_eq!(cores[1].theta(), &expect[..]);
        assert!(cores[1].alpha().iter().all(|&a| a == 0.0));
    }

    #[test]
    fn build_core_at_matches_fleet_construction() {
        let topo = Topology::random_bipartite(6, 0.5, 9);
        let ds = synthetic::linear_dataset(48, 4, 9);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 9);
        for spec in [AlgSpec::ggadmm(), AlgSpec::cq_ggadmm(2.0, 0.9, 0.995, 3)] {
            let cfg = ProtocolConfig::default();
            let (mut fleet, mut fleet_rng) = build_cores(&p, &topo, &spec, &cfg, None);
            // state equality via export (covers quantizer RNG position)
            for i in 0..topo.n() {
                let mut solo = build_core_at(&p, &topo, &spec, &cfg, i);
                assert_eq!(solo.export_state(), fleet[i].export_state(), "worker {i}");
                // run a phase on both so the quantizer streams draw
                solo.primal_update();
                fleet[i].primal_update();
                let a = solo.prepare_broadcast(1);
                let b = fleet[i].prepare_broadcast(1);
                assert_eq!(a, b, "worker {i} transmit decision");
                solo.abort_pending();
                fleet[i].abort_pending();
                assert_eq!(solo.export_state(), fleet[i].export_state(), "worker {i} post-phase");
            }
            // the derived link stream equals build_cores' leftover root
            let mut derived = link_rng(&spec, &cfg, topo.n());
            assert_eq!(derived.next_u64(), fleet_rng.next_u64(), "{}", spec.name);
        }
    }

    fn mlp_problem(n: usize) -> (Topology, Problem) {
        let topo = Topology::chain(n);
        let ds = synthetic::linear_dataset(24, 3, 5);
        let p = Problem::with_model(
            &ds,
            &topo,
            1.0,
            0.05,
            5,
            ModelSpec::Mlp { hidden: 2 },
        )
        .expect("mlp problem");
        (topo, p)
    }

    #[test]
    fn multi_block_first_broadcast_then_full_censor() {
        let (topo, p) = mlp_problem(2);
        // huge tau0: every block censors after its state-initializing
        // first transmission
        let spec = AlgSpec::c_ggadmm(1e9, 0.9);
        let (mut cores, _) = build_cores(&p, &topo, &spec, &ProtocolConfig::default(), None);
        assert_eq!(cores[0].block_count(), 2);
        cores[0].primal_update();
        let bits = cores[0].prepare_broadcast(1).expect("state init must transmit");
        // both blocks ship full precision: bits must cover the whole model
        assert_eq!(bits, full_precision_bits(6) + full_precision_bits(2));
        assert_eq!(cores[0].broadcast_mask(), Some(&[true, true][..]));
        cores[0].commit_pending();
        cores[0].primal_update();
        assert!(
            cores[0].prepare_broadcast(2).is_none(),
            "tau0 = 1e9 must censor every block after init"
        );
    }

    #[test]
    fn mlp_seeds_nonzero_theta() {
        let (topo, p) = mlp_problem(2);
        let (cores, _) = build_cores(&p, &topo, &AlgSpec::ggadmm(), &ProtocolConfig::default(), None);
        assert_eq!(cores[0].theta(), &p.theta0[..]);
        assert!(cores[0].theta().iter().any(|t| *t != 0.0));
        // hats still start at zero (Alg. 2 line 2)
        assert!(cores[0].hat_self().iter().all(|t| *t == 0.0));
    }

    #[test]
    fn deliver_spans_copies_only_masked_spans() {
        let (topo, p) = mlp_problem(2);
        let (mut cores, _) = build_cores(&p, &topo, &AlgSpec::ggadmm(), &ProtocolConfig::default(), None);
        cores[0].primal_update();
        cores[0].prepare_broadcast(1).expect("transmit");
        cores[0].commit_pending();
        let hat: Vec<f64> = cores[0].hat_self().to_vec();
        // deliver only block 0 (the W block, 6 coords): block 1 keeps 0
        cores[1].deliver_spans(0, &hat, &[true, false]);
        cores[1].primal_update();
        let sum = cores[1].neighbor_sum();
        assert_eq!(&sum[..6], &hat[..6]);
        assert!(sum[6..].iter().all(|v| *v == 0.0));
    }

    #[test]
    fn multi_block_build_core_at_matches_fleet() {
        let (topo, p) = mlp_problem(4);
        let specs = [
            AlgSpec::ggadmm(),
            AlgSpec::q_ggadmm(0.995, 2).with_bits_split(Some(vec![4, 2])),
            AlgSpec::cq_ggadmm(2.0, 0.9, 0.995, 3),
        ];
        for spec in specs {
            let cfg = ProtocolConfig::default();
            let (mut fleet, mut fleet_rng) = build_cores(&p, &topo, &spec, &cfg, None);
            for i in 0..topo.n() {
                let mut solo = build_core_at(&p, &topo, &spec, &cfg, i);
                assert_eq!(solo.export_state(), fleet[i].export_state(), "worker {i}");
                solo.primal_update();
                fleet[i].primal_update();
                let a = solo.prepare_broadcast(1);
                let b = fleet[i].prepare_broadcast(1);
                assert_eq!(a, b, "worker {i} transmit decision");
                if a.is_some() {
                    solo.abort_pending();
                    fleet[i].abort_pending();
                }
                assert_eq!(solo.export_state(), fleet[i].export_state(), "worker {i} post");
            }
            let mut derived = link_rng(&spec, &cfg, topo.n());
            assert_eq!(derived.next_u64(), fleet_rng.next_u64(), "{}", spec.name);
        }
    }

    #[test]
    fn bits_split_changes_per_block_widths() {
        let (topo, p) = mlp_problem(2);
        let spec = AlgSpec::q_ggadmm(0.995, 8).with_bits_split(Some(vec![8, 2]));
        let (mut cores, _) = build_cores(&p, &topo, &spec, &ProtocolConfig::default(), None);
        cores[0].enable_code_collection();
        cores[0].primal_update();
        let bits = cores[0].prepare_broadcast(1).expect("transmit");
        // block 0: 6 coords at 8 bits; block 1: 2 coords at 2 bits
        assert_eq!(bits, crate::quant::payload_bits(6, 8) + crate::quant::payload_bits(2, 2));
        cores[0].commit_pending();
        match cores[0].committed_block_payload(0) {
            PayloadRef::Quantized { bits, codes, .. } => {
                assert_eq!(bits, 8);
                assert_eq!(codes.len(), 6);
            }
            PayloadRef::Full(_) => panic!("expected quantized block"),
        }
        match cores[0].committed_block_payload(1) {
            PayloadRef::Quantized { bits, codes, .. } => {
                assert_eq!(bits, 2);
                assert_eq!(codes.len(), 2);
            }
            PayloadRef::Full(_) => panic!("expected quantized block"),
        }
    }

    #[test]
    fn qdgd_is_primal_only_and_descends() {
        let topo = Topology::chain(2);
        let ds = synthetic::linear_dataset(24, 3, 5);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 5);
        let spec = AlgSpec::qdgd(0.995, 8);
        let (mut cores, _) = build_cores(&p, &topo, &spec, &ProtocolConfig::default(), None);
        let f0: f64 = cores.iter().map(|c| c.loss()).sum();
        for _ in 0..30 {
            for c in cores.iter_mut() {
                c.primal_update();
            }
            let hats: Vec<Vec<f64>> = cores
                .iter_mut()
                .map(|c| {
                    c.prepare_broadcast(1).expect("qdgd never censors");
                    c.commit_pending();
                    c.hat_self().to_vec()
                })
                .collect();
            for (i, hat) in hats.iter().enumerate() {
                for &m in topo.neighbors(i) {
                    cores[m].deliver(i, hat);
                }
            }
            for c in cores.iter_mut() {
                c.dual_update();
            }
        }
        let f1: f64 = cores.iter().map(|c| c.loss()).sum();
        assert!(f1 < f0, "QDGD must descend: {f1} vs {f0}");
        for c in &cores {
            assert!(c.alpha().iter().all(|a| *a == 0.0), "QDGD carries no dual");
        }
    }

    #[test]
    fn quantized_payload_exposes_codes() {
        let topo = Topology::chain(2);
        let ds = synthetic::linear_dataset(24, 3, 5);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 5);
        let spec = AlgSpec::q_ggadmm(0.995, 2);
        let (mut cores, _) =
            build_cores(&p, &topo, &spec, &ProtocolConfig::default(), None);
        cores[0].enable_code_collection();
        cores[0].primal_update();
        let bits = cores[0].prepare_broadcast(1).expect("must transmit");
        assert_eq!(bits, crate::quant::payload_bits(3, 2));
        cores[0].commit_pending();
        match cores[0].committed_payload() {
            PayloadRef::Quantized { bits, codes, .. } => {
                assert_eq!(bits, 2);
                assert_eq!(codes.len(), 3);
            }
            PayloadRef::Full(_) => panic!("expected a quantized payload"),
        }
    }
}
