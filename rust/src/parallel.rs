//! Deterministic parallel primitives: a persistent, barrier-synchronized
//! [`WorkerPool`] plus the fork-join [`map_indexed`] helper built on it.
//!
//! The head (resp. tail) group of GGADMM updates its primal variables in
//! parallel; the original implementation spawned fresh OS threads through
//! `std::thread::scope` every phase, which costs more than a paper-scale
//! linear solve.  [`WorkerPool`] amortizes that: helper threads are
//! spawned **once** (e.g. in `Run::new`) and every phase dispatches
//! through a generation counter + condvar barrier.  Work items are
//! claimed dynamically off an atomic counter, so uneven subproblem costs
//! (logistic Newton steps) balance across threads, and the caller thread
//! participates in the claim loop, so a pool of `t` threads uses exactly
//! `t` cores.  (No tokio in the sandbox, and the workloads are CPU-bound
//! anyway.)

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Raw base pointer handed to pool jobs for disjoint per-index `&mut`
/// access into a slice (the borrow checker cannot see index-disjointness
/// across threads).  The creator promises that concurrent jobs touch
/// distinct indices; the pool barrier orders every access before the
/// dispatching call returns.
pub(crate) struct SyncPtr<T>(pub(crate) *mut T);

// SAFETY: the pointer is only dereferenced at indices the caller
// guarantees are claimed by exactly one job (see users); `T: Send`
// because the pointee values are produced/consumed across threads.
unsafe impl<T: Send> Sync for SyncPtr<T> {}

/// One dispatched generation of work: run `f(i)` for every `i in 0..n`.
#[derive(Clone, Copy)]
struct Job {
    /// Lifetime-erased job closure.  Soundness: `for_each` does not
    /// return before every helper has finished the generation, so the
    /// referent outlives every call through this reference.
    f: &'static (dyn Fn(usize) + Sync),
    n: usize,
}

struct State {
    /// Bumped once per dispatched generation.
    generation: u64,
    job: Option<Job>,
    /// Helpers still working on the current generation.
    active: usize,
    /// A helper's job closure panicked (re-raised by the caller).
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Helpers wait here for a new generation (or shutdown).
    work_cv: Condvar,
    /// The dispatching caller waits here for `active == 0`.
    done_cv: Condvar,
    /// Next unclaimed work index of the current generation.
    next: AtomicUsize,
}

/// A persistent fork-join pool with barrier-synchronized dispatch.
///
/// `for_each` takes `&mut self`: one generation runs at a time, and the
/// call does not return until every index has been processed — so jobs
/// may soundly borrow caller-local data.
pub struct WorkerPool {
    shared: Arc<Shared>,
    helpers: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Build a pool that runs jobs on `threads` OS threads in total: the
    /// caller participates, so `threads - 1` helpers are spawned
    /// (`threads <= 1` spawns none and `for_each` degrades to a plain
    /// sequential loop).
    pub fn new(threads: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                generation: 0,
                job: None,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            next: AtomicUsize::new(0),
        });
        let helpers = (1..threads.max(1))
            .map(|k| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("pool-{k}"))
                    .spawn(move || helper_main(&shared))
                    .expect("spawn pool helper")
            })
            .collect();
        WorkerPool { shared, helpers }
    }

    /// Total threads the pool dispatches over (helpers + the caller).
    pub fn threads(&self) -> usize {
        self.helpers.len() + 1
    }

    /// Run `f(i)` for every `i in 0..n`, claiming indices dynamically
    /// across the helpers and the calling thread, and return once all of
    /// them completed (the barrier that makes borrowing `f`'s captures
    /// sound).  Panics in `f` are re-raised here after the barrier.
    pub fn for_each<F: Fn(usize) + Sync>(&mut self, n: usize, f: F) {
        if n <= 1 || self.helpers.is_empty() {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: lifetime erasure only; the barrier below keeps every
        // use of the reference within this call frame.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f_ref)
        };
        {
            let mut st = self.shared.state.lock().expect("pool state poisoned");
            debug_assert!(st.job.is_none() && st.active == 0, "generation overlap");
            self.shared.next.store(0, Ordering::Relaxed);
            st.job = Some(Job { f: f_static, n });
            st.generation = st.generation.wrapping_add(1);
            st.active = self.helpers.len();
            self.shared.work_cv.notify_all();
        }
        // the caller claims work too; catch panics so the barrier always
        // happens before unwinding can invalidate `f`
        let caller = catch_unwind(AssertUnwindSafe(|| loop {
            let i = self.shared.next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            f(i);
        }));
        let panicked = {
            let mut st = self.shared.state.lock().expect("pool state poisoned");
            while st.active != 0 {
                st = self.shared.done_cv.wait(st).expect("pool state poisoned");
            }
            st.job = None;
            std::mem::take(&mut st.panicked)
        };
        match caller {
            Err(payload) => resume_unwind(payload),
            Ok(()) => assert!(!panicked, "worker pool job panicked"),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().expect("pool state poisoned");
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.helpers.drain(..) {
            let _ = h.join();
        }
    }
}

fn helper_main(shared: &Shared) {
    let mut seen_gen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().expect("pool state poisoned");
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != seen_gen {
                    seen_gen = st.generation;
                    break st.job.expect("generation bumped without a job");
                }
                st = shared.work_cv.wait(st).expect("pool state poisoned");
            }
        };
        // claim loop; panics are contained so `active` always reaches 0
        // and the dispatching caller never deadlocks
        let result = catch_unwind(AssertUnwindSafe(|| loop {
            let i = shared.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.n {
                break;
            }
            (job.f)(i);
        }));
        let mut st = shared.state.lock().expect("pool state poisoned");
        if result.is_err() {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_one();
        }
    }
}

/// Collect `f(i)` for every `i in 0..n` through an **existing** pool, in
/// index order (the reuse path for call sites that already hold a
/// [`WorkerPool`], e.g. solver construction in `Run::new`).
pub fn map_with_pool<T, F>(pool: &mut WorkerPool, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = SyncPtr(out.as_mut_ptr());
    pool.for_each(n, |i| {
        // SAFETY: each index in 0..n is claimed by exactly one job, so
        // the writes are disjoint; the pool barrier orders them before
        // the reads below.
        unsafe { *slots.0.add(i) = Some(f(i)) };
    });
    out.into_iter().map(|x| x.expect("slot unfilled")).collect()
}

/// Collect `f(i)` for every `i in 0..n` in index order, through `pool`
/// when one is provided and sequentially otherwise.  The shared
/// dispatch-or-degrade shim for call sites whose pool is optional
/// (solver construction in `Run::new`, the sweep scheduler in
/// `experiments`).
pub fn map_maybe_pool<T, F>(pool: Option<&mut WorkerPool>, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    match pool {
        Some(pool) => map_with_pool(pool, n, f),
        None => (0..n).map(f).collect(),
    }
}

/// Run `f(i)` for every `i in 0..n` over a transient [`WorkerPool`] of at
/// most `max_threads` threads and collect results in index order.
///
/// Falls back to a plain sequential loop when `n <= 1` or
/// `max_threads <= 1` (keeps tests deterministic and avoids thread spawn
/// overhead for tiny inputs).  Call sites with per-iteration dispatch
/// should hold a [`WorkerPool`] and use [`map_with_pool`] instead of
/// paying the spawns here.
pub fn map_indexed<T, F>(n: usize, max_threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = max_threads.min(n).max(1);
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let mut pool = WorkerPool::new(threads);
    map_with_pool(&mut pool, n, f)
}

/// 0 = unresolved; otherwise the resolved kernel-pool width + 1 (so a
/// resolved width of 0 is representable — it is not, widths are >= 1).
static KERNEL_THREADS: AtomicUsize = AtomicUsize::new(0);

/// The shared dense-kernel pool (`linalg::block` trailing updates).
/// Guarded by a mutex so one generation dispatches at a time;
/// [`with_kernel_pool`] falls back to serial on contention instead of
/// queueing, so nested dense ops (e.g. per-worker solves already running
/// inside a [`WorkerPool`] job) never deadlock or oversubscribe.
static KERNEL_POOL: Mutex<Option<(usize, WorkerPool)>> = Mutex::new(None);

/// Thread budget for the shared dense-kernel pool: `CQ_LINALG_THREADS`
/// when set (`0` = all cores, `1` disables pooling), otherwise
/// [`default_threads`].  Resolved once and cached.
pub fn kernel_threads() -> usize {
    match KERNEL_THREADS.load(Ordering::Relaxed) {
        0 => {
            let resolved = match std::env::var("CQ_LINALG_THREADS") {
                Ok(v) => match v.trim().parse::<usize>() {
                    Ok(n) => resolve_threads(n),
                    Err(_) => {
                        eprintln!(
                            "warning: unparseable CQ_LINALG_THREADS={v:?}; using default"
                        );
                        default_threads()
                    }
                },
                Err(_) => default_threads(),
            };
            // benign race: concurrent first calls resolve identically
            KERNEL_THREADS.store(resolved + 1, Ordering::Relaxed);
            resolved
        }
        n => n - 1,
    }
}

/// Override the dense-kernel pool width (`0` = all cores, `1` disables
/// pooling).  Drops any cached pool so the next dispatch rebuilds at the
/// new width; bench shootouts use this to time serial vs pooled kernels
/// in one process.
pub fn set_kernel_threads(threads: usize) {
    let resolved = resolve_threads(threads).max(1);
    KERNEL_THREADS.store(resolved + 1, Ordering::Relaxed);
    if let Ok(mut guard) = KERNEL_POOL.lock() {
        *guard = None;
    }
}

/// Run `f` with the shared dense-kernel pool when it is available:
/// `f(Some(pool))` after lazily (re)building the pool at the current
/// [`kernel_threads`] width, or `f(None)` when pooling is disabled
/// (width <= 1) or another thread currently holds the pool (nested or
/// concurrent dense ops degrade to serial rather than blocking).
pub fn with_kernel_pool<R>(f: impl FnOnce(Option<&mut WorkerPool>) -> R) -> R {
    let threads = kernel_threads();
    if threads <= 1 {
        return f(None);
    }
    match KERNEL_POOL.try_lock() {
        Ok(mut guard) => {
            if guard.as_ref().map(|(t, _)| *t) != Some(threads) {
                *guard = Some((threads, WorkerPool::new(threads)));
            }
            f(guard.as_mut().map(|(_, pool)| pool))
        }
        Err(_) => f(None),
    }
}

/// Number of worker threads to use by default (leave one core for the
/// coordinator/metrics thread).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

/// Resolve an executor-size knob: `0` means "all cores" (the sharded
/// coordinator's convention — its leader participates in every dispatch,
/// so using every core is the saturating default), any other value is
/// taken literally.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_in_index_order() {
        let out = map_indexed(100, 8, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn sequential_fallback_matches() {
        let a = map_indexed(10, 1, |i| i + 1);
        let b = map_indexed(10, 4, |i| i + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn all_indices_visited_once() {
        let count = AtomicUsize::new(0);
        let out = map_indexed(37, 5, |i| {
            count.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(count.load(Ordering::SeqCst), 37);
        assert_eq!(out, (0..37).collect::<Vec<_>>());
    }

    #[test]
    fn empty_is_empty() {
        let out: Vec<usize> = map_indexed(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_reuse_across_generations() {
        // the persistent-pool contract: one spawn, many dispatches
        let mut pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        for round in 0..50usize {
            let hits: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
            pool.for_each(23, |i| {
                hits[i].fetch_add(round + 1, Ordering::SeqCst);
            });
            for h in &hits {
                assert_eq!(h.load(Ordering::SeqCst), round + 1);
            }
        }
    }

    #[test]
    fn pool_single_thread_is_sequential() {
        let mut pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let count = AtomicUsize::new(0);
        pool.for_each(16, |_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn pool_disjoint_writes_match_sequential() {
        let mut pool = WorkerPool::new(3);
        let mut out = vec![0usize; 101];
        let slots = SyncPtr(out.as_mut_ptr());
        pool.for_each(101, |i| {
            // SAFETY: indices are claimed exactly once
            unsafe { *slots.0.add(i) = i * 3 + 1 };
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 3 + 1);
        }
    }

    #[test]
    fn map_maybe_pool_matches_sequential() {
        let mut pool = WorkerPool::new(3);
        let seq = map_maybe_pool(None, 12, |i| i * 2);
        let pooled = map_maybe_pool(Some(&mut pool), 12, |i| i * 2);
        assert_eq!(seq, pooled);
    }

    #[test]
    fn resolve_threads_zero_means_all_cores() {
        assert_eq!(resolve_threads(3), 3);
        let all = resolve_threads(0);
        assert!(all >= 1);
        assert_eq!(
            all,
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        );
    }

    #[test]
    fn kernel_pool_dispatch_and_nested_fallback() {
        // outer call may get the shared pool (or serial if another test
        // holds it / pooling is disabled); a nested call must degrade to
        // serial instead of deadlocking on the pool mutex
        let sum = with_kernel_pool(|outer| {
            assert!(outer.is_none() || kernel_threads() > 1);
            with_kernel_pool(|nested| {
                // the outer closure holds the lock, so if the outer got
                // the pool, the nested call cannot also get it
                if outer.is_some() {
                    assert!(nested.is_none(), "nested dispatch must run serial");
                }
                7usize
            })
        });
        assert_eq!(sum, 7);
    }

    #[test]
    fn pool_survives_a_panicking_job() {
        let mut pool = WorkerPool::new(3);
        let hit = catch_unwind(AssertUnwindSafe(|| {
            pool.for_each(8, |i| {
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(hit.is_err(), "panic must propagate to the dispatcher");
        // the pool stays usable after a failed generation
        let count = AtomicUsize::new(0);
        pool.for_each(9, |_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 9);
    }
}
