//! Scoped fork-join helper for group-parallel worker updates.
//!
//! The head (resp. tail) group of GGADMM updates its primal variables in
//! parallel; this module gives the coordinator a tiny deterministic
//! fork-join primitive on `std::thread::scope` (no tokio in the sandbox,
//! and the workloads are CPU-bound anyway).

/// Run `f(i)` for every `i in 0..n`, distributing across at most
/// `max_threads` OS threads, and collect results in index order.
///
/// Falls back to a plain sequential loop when `n <= 1` or
/// `max_threads <= 1` (keeps tests deterministic and avoids thread spawn
/// overhead for tiny groups).
pub fn map_indexed<T, F>(n: usize, max_threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = max_threads.min(n).max(1);
    if threads == 1 {
        return (0..n).map(&f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest: &mut [Option<T>] = &mut out;
        let mut start = 0usize;
        let mut handles = Vec::new();
        while start < n {
            let len = chunk.min(n - start);
            let (head, tail) = rest.split_at_mut(len);
            rest = tail;
            let fref = &f;
            let base = start;
            handles.push(scope.spawn(move || {
                for (off, slot) in head.iter_mut().enumerate() {
                    *slot = Some(fref(base + off));
                }
            }));
            start += len;
        }
        for h in handles {
            h.join().expect("worker thread panicked");
        }
    });
    out.into_iter().map(|x| x.expect("slot unfilled")).collect()
}

/// Number of worker threads to use by default (leave one core for the
/// coordinator/metrics thread).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_in_index_order() {
        let out = map_indexed(100, 8, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn sequential_fallback_matches() {
        let a = map_indexed(10, 1, |i| i + 1);
        let b = map_indexed(10, 4, |i| i + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn all_indices_visited_once() {
        let count = AtomicUsize::new(0);
        let out = map_indexed(37, 5, |i| {
            count.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(count.load(Ordering::SeqCst), 37);
        assert_eq!(out, (0..37).collect::<Vec<_>>());
    }

    #[test]
    fn empty_is_empty() {
        let out: Vec<usize> = map_indexed(0, 4, |i| i);
        assert!(out.is_empty());
    }
}
