//! Multi-block parameter layout (L-FGADMM-style layer-wise models).
//!
//! A [`Blocks`] describes how one flat parameter buffer `Vec<f64>` is
//! partitioned into contiguous blocks (layers).  The buffer stays flat —
//! a single-block layout is allocation-identical to the pre-refactor
//! `Vec<f64>` path, which is what lets the degenerate case remain
//! bit-for-bit identical across every engine.  Multi-block models (the
//! one-hidden-layer MLP: `[vec(W), v]`) thread per-block quantizer /
//! censor / staleness state through [`crate::protocol::WorkerCore`] and
//! frame per-block payloads on the wire
//! ([`crate::coordinator::message`]).
//!
//! [`BitsSpec`] is the per-layer bit-allocation grammar (`--bits0 24,8`):
//! one initial bit width per block, or a single width broadcast to every
//! block.

use std::ops::Range;

/// A partition of a flat `d`-dimensional buffer into contiguous blocks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Blocks {
    /// Block start offsets, ascending; `offsets[0] == 0`.
    offsets: Vec<usize>,
    /// Block lengths, each `>= 1`.
    lens: Vec<usize>,
    /// Total dimension (`== lens.iter().sum()`).
    d: usize,
}

impl Blocks {
    /// The degenerate single-block layout over `d` coordinates — the
    /// pre-refactor flat model.
    pub fn single(d: usize) -> Blocks {
        assert!(d >= 1, "empty model");
        Blocks { offsets: vec![0], lens: vec![d], d }
    }

    /// A layout of `lens.len()` contiguous blocks.
    pub fn from_lens(lens: &[usize]) -> Blocks {
        assert!(!lens.is_empty(), "layout needs at least one block");
        assert!(lens.iter().all(|&l| l >= 1), "empty blocks are not allowed");
        let mut offsets = Vec::with_capacity(lens.len());
        let mut off = 0usize;
        for &l in lens {
            offsets.push(off);
            off += l;
        }
        Blocks { offsets, lens: lens.to_vec(), d: off }
    }

    /// Number of blocks.
    pub fn count(&self) -> usize {
        self.lens.len()
    }

    /// Total dimension.
    pub fn d(&self) -> usize {
        self.d
    }

    /// `true` for the degenerate flat layout.
    pub fn is_single(&self) -> bool {
        self.lens.len() == 1
    }

    /// Length of block `b`.
    pub fn len_of(&self, b: usize) -> usize {
        self.lens[b]
    }

    /// Coordinate range of block `b`.
    pub fn range(&self, b: usize) -> Range<usize> {
        let o = self.offsets[b];
        o..o + self.lens[b]
    }

    /// Borrow block `b` of a flat buffer.
    pub fn slice<'a>(&self, buf: &'a [f64], b: usize) -> &'a [f64] {
        &buf[self.range(b)]
    }

    /// Mutably borrow block `b` of a flat buffer.
    pub fn slice_mut<'a>(&self, buf: &'a mut [f64], b: usize) -> &'a mut [f64] {
        let r = self.range(b);
        &mut buf[r]
    }
}

/// Grammar of the per-layer bit-allocation spec (`--bits0`, manifest
/// `bits0`): mirrors the `LinkKind` grammar style — every rejection
/// cites this string.
pub const BITS_GRAMMAR: &str = "<b> | <b>,<b>[,<b>...] with each <b> an integer in [1, 32]";

/// Initial quantization bit widths, one per block (`N` or `N,M,...`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitsSpec {
    pub per_block: Vec<u32>,
}

impl BitsSpec {
    /// A uniform allocation (every block at `bits`).
    pub fn uniform(bits: u32) -> BitsSpec {
        BitsSpec { per_block: vec![bits] }
    }

    /// Parse `N` or `N,M,...`; rejects empty items, out-of-range widths
    /// and trailing garbage with errors citing [`BITS_GRAMMAR`].
    pub fn parse(s: &str) -> Result<BitsSpec, String> {
        let bad = |msg: String| -> Result<BitsSpec, String> {
            Err(format!("bad bits spec '{s}': {msg}; grammar: {BITS_GRAMMAR}"))
        };
        let body = s.trim();
        if body.is_empty() {
            return bad("empty spec".into());
        }
        let mut per_block = Vec::new();
        for item in body.split(',') {
            let item = item.trim();
            if item.is_empty() {
                // covers "24,", ",8" and "24,,8": a dangling comma is
                // trailing garbage, not an implicit block
                return bad("empty item (dangling comma?)".into());
            }
            let b: u32 = match item.parse() {
                Ok(b) => b,
                Err(_) => return bad(format!("'{item}' is not an integer")),
            };
            if !(1..=32).contains(&b) {
                return bad(format!("width {b} out of range [1, 32]"));
            }
            per_block.push(b);
        }
        Ok(BitsSpec { per_block })
    }

    /// `true` when the spec names one width for every block.
    pub fn is_uniform(&self) -> bool {
        self.per_block.len() == 1
    }

    /// Resolve against a layout: a uniform spec broadcasts to every
    /// block; a per-block spec must match the block count exactly.
    pub fn resolve(&self, blocks: usize) -> Result<Vec<u32>, String> {
        if self.per_block.len() == 1 {
            return Ok(vec![self.per_block[0]; blocks]);
        }
        if self.per_block.len() != blocks {
            return Err(format!(
                "bits spec names {} widths but the model has {} blocks",
                self.per_block.len(),
                blocks
            ));
        }
        Ok(self.per_block.clone())
    }

    /// Canonical label (round-trips through [`BitsSpec::parse`]).
    pub fn label(&self) -> String {
        self.per_block
            .iter()
            .map(|b| b.to_string())
            .collect::<Vec<_>>()
            .join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_layout_is_flat() {
        let b = Blocks::single(7);
        assert!(b.is_single());
        assert_eq!(b.count(), 1);
        assert_eq!(b.d(), 7);
        assert_eq!(b.range(0), 0..7);
        let buf = vec![1.0; 7];
        assert_eq!(b.slice(&buf, 0).len(), 7);
    }

    #[test]
    fn multi_layout_spans_are_contiguous_and_cover() {
        let b = Blocks::from_lens(&[6, 2, 3]);
        assert_eq!(b.count(), 3);
        assert_eq!(b.d(), 11);
        assert_eq!(b.range(0), 0..6);
        assert_eq!(b.range(1), 6..8);
        assert_eq!(b.range(2), 8..11);
        assert!(!b.is_single());
        let mut covered = vec![false; b.d()];
        for blk in 0..b.count() {
            for j in b.range(blk) {
                assert!(!covered[j], "overlap at {j}");
                covered[j] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn slice_mut_writes_land_in_the_right_span() {
        let b = Blocks::from_lens(&[2, 3]);
        let mut buf = vec![0.0; 5];
        for v in b.slice_mut(&mut buf, 1) {
            *v = 9.0;
        }
        assert_eq!(buf, vec![0.0, 0.0, 9.0, 9.0, 9.0]);
    }

    #[test]
    #[should_panic]
    fn empty_block_rejected() {
        Blocks::from_lens(&[3, 0, 2]);
    }

    #[test]
    fn bits_spec_parses_single_and_list() {
        assert_eq!(BitsSpec::parse("2").unwrap().per_block, vec![2]);
        assert_eq!(BitsSpec::parse("24,8").unwrap().per_block, vec![24, 8]);
        assert_eq!(BitsSpec::parse(" 4 , 8 , 16 ").unwrap().per_block, vec![4, 8, 16]);
        assert_eq!(BitsSpec::parse("32").unwrap().per_block, vec![32]);
    }

    #[test]
    fn bits_spec_rejects_garbage_citing_grammar() {
        for bad in ["", "  ", "0", "33", "24,", ",8", "24,,8", "24,8x", "a", "2.5", "-3"] {
            let err = BitsSpec::parse(bad).unwrap_err();
            assert!(err.contains("grammar"), "{bad}: {err}");
            assert!(err.contains(BITS_GRAMMAR), "{bad}: {err}");
        }
    }

    #[test]
    fn bits_spec_resolves_uniform_and_exact() {
        let u = BitsSpec::parse("3").unwrap();
        assert_eq!(u.resolve(4).unwrap(), vec![3, 3, 3, 3]);
        let p = BitsSpec::parse("24,8").unwrap();
        assert_eq!(p.resolve(2).unwrap(), vec![24, 8]);
        let err = p.resolve(3).unwrap_err();
        assert!(err.contains("2 widths"), "{err}");
        assert!(err.contains("3 blocks"), "{err}");
    }

    #[test]
    fn bits_spec_label_round_trips() {
        for s in ["2", "24,8", "1,32,16"] {
            let spec = BitsSpec::parse(s).unwrap();
            assert_eq!(BitsSpec::parse(&spec.label()).unwrap(), spec);
        }
    }
}
