//! Per-edge dual formulation + D-GGADMM (dynamic topology).
//!
//! The main engine ([`super::Run`]) carries the *aggregated* dual
//! `alpha_n = sum_m lambda_{n,m}` of paper eq. (7).  This module keeps the
//! individual edge duals `lambda_{n,m}` instead, which
//!
//! 1. differentially validates the aggregation (for a fixed topology the
//!    two engines must produce identical GGADMM trajectories), and
//! 2. enables **D-GGADMM**: the dynamic-topology extension (Elgabli et
//!    al. 2020c study D-GADMM for time-varying chains) where the graph is
//!    resampled every `epoch` iterations — duals of surviving edges are
//!    kept, duals of new edges start at zero, duals of dropped edges are
//!    discarded.

use super::Problem;
use crate::graph::Topology;
use crate::metrics::{Trace, TracePoint};
use crate::solver::{LinearSolver, LogisticSolver, SubproblemSolver};
use std::collections::BTreeMap;

/// GGADMM with explicit per-edge duals, optional topology resampling.
pub struct EdgeDualRun {
    problem: Problem,
    topo: Topology,
    /// lambda keyed by (head, tail) edge; the worker-side values are
    /// lambda_{n,m} = +lambda_e at the head and -lambda_e at the tail.
    lambda: BTreeMap<(usize, usize), Vec<f64>>,
    thetas: Vec<Vec<f64>>,
    solvers: Vec<Box<dyn SubproblemSolver>>,
    iter: u64,
    trace: Trace,
    /// resample the topology every `epoch` iterations (None = static)
    epoch: Option<u64>,
    topo_seed: u64,
    connectivity: f64,
    /// persistent per-phase scratch: aggregated dual of the active worker
    alpha_buf: Vec<f64>,
    /// persistent per-phase scratch: neighbor sum of the active worker
    nbr_buf: Vec<f64>,
    /// cached `[heads, tails]` (rebuilt on retopologize only)
    groups: [Vec<usize>; 2],
}

impl EdgeDualRun {
    pub fn new(problem: Problem, topo: Topology) -> EdgeDualRun {
        let d = problem.d;
        let lambda = topo
            .edges()
            .iter()
            .map(|&e| (e, vec![0.0; d]))
            .collect();
        let solvers = build(&problem, &topo);
        let trace = Trace::new("GGADMM(edge-dual)", &problem.dataset_name);
        let thetas = vec![vec![0.0; d]; topo.n()];
        let topo_groups = (topo.heads(), topo.tails());
        EdgeDualRun {
            problem,
            topo,
            lambda,
            thetas,
            solvers,
            iter: 0,
            trace,
            epoch: None,
            topo_seed: 0,
            connectivity: 0.3,
            alpha_buf: vec![0.0; d],
            nbr_buf: vec![0.0; d],
            groups: [topo_groups.0, topo_groups.1],
        }
    }

    /// Enable D-GGADMM: resample a fresh connected bipartite topology with
    /// ratio `connectivity` every `epoch` iterations.
    pub fn dynamic(mut self, epoch: u64, connectivity: f64, seed: u64) -> EdgeDualRun {
        assert!(epoch > 0);
        self.epoch = Some(epoch);
        self.connectivity = connectivity;
        self.topo_seed = seed;
        self.trace = Trace::new("D-GGADMM", &self.problem.dataset_name);
        self
    }

    /// Fill `buf` with the aggregated dual `alpha_n = sum_m lambda_{n,m}`
    /// (eq. 7) — free function over the fields so the persistent scratch
    /// can be borrowed alongside the map.
    fn fill_alpha(lambda: &BTreeMap<(usize, usize), Vec<f64>>, n: usize, buf: &mut [f64]) {
        buf.iter_mut().for_each(|v| *v = 0.0);
        for (&(h, t), lam) in lambda {
            if h == n {
                crate::util::axpy(buf, 1.0, lam);
            } else if t == n {
                crate::util::axpy(buf, -1.0, lam);
            }
        }
    }

    fn fill_neighbor_sum(topo: &Topology, thetas: &[Vec<f64>], n: usize, buf: &mut [f64]) {
        buf.iter_mut().for_each(|v| *v = 0.0);
        for &m in topo.neighbors(n) {
            crate::util::axpy(buf, 1.0, &thetas[m]);
        }
    }

    /// Worker-side aggregated dual `alpha_n = sum_m lambda_{n,m}` (eq. 7).
    pub fn alpha(&self, n: usize) -> Vec<f64> {
        let mut a = vec![0.0; self.problem.d];
        Self::fill_alpha(&self.lambda, n, &mut a);
        a
    }

    /// One GGADMM iteration with per-edge dual updates (eqs. (4)-(6)).
    /// Allocation-free after construction: alpha / neighbor-sum scratch
    /// is persistent and the solvers update `thetas[n]` in place (the
    /// current value doubles as the warm start, exactly as before).
    pub fn step(&mut self) {
        // resample topology at epoch boundaries (D-GGADMM)
        if let Some(epoch) = self.epoch {
            if self.iter > 0 && self.iter % epoch == 0 {
                let new_topo = Topology::random_bipartite(
                    self.topo.n(),
                    self.connectivity,
                    self.topo_seed ^ self.iter,
                );
                self.retopologize(new_topo);
            }
        }
        // head phase, then tail phase (which sees fresh head values)
        for group in &self.groups {
            for &n in group {
                Self::fill_alpha(&self.lambda, n, &mut self.alpha_buf);
                Self::fill_neighbor_sum(&self.topo, &self.thetas, n, &mut self.nbr_buf);
                self.solvers[n].update_into(&self.alpha_buf, &self.nbr_buf, &mut self.thetas[n]);
            }
        }
        // dual update per edge: lambda += rho (theta_h - theta_t)  (eq. 6)
        let rho = self.problem.rho;
        for (&(h, t), lam) in self.lambda.iter_mut() {
            for j in 0..lam.len() {
                lam[j] += rho * (self.thetas[h][j] - self.thetas[t][j]);
            }
        }
        self.iter += 1;
        self.record();
    }

    /// Keep duals of surviving edges, zero the new ones, drop the rest;
    /// rebuild solvers for the new degrees.
    fn retopologize(&mut self, new_topo: Topology) {
        let d = self.problem.d;
        let mut new_lambda = BTreeMap::new();
        for &e in new_topo.edges() {
            // surviving edges keep lambda even if head/tail flipped
            let lam = self
                .lambda
                .remove(&e)
                .or_else(|| {
                    self.lambda
                        .remove(&(e.1, e.0))
                        .map(|v| v.iter().map(|x| -x).collect())
                })
                .unwrap_or_else(|| vec![0.0; d]);
            new_lambda.insert(e, lam);
        }
        self.lambda = new_lambda;
        self.solvers = build(&self.problem, &new_topo);
        self.groups = [new_topo.heads(), new_topo.tails()];
        self.topo = new_topo;
    }

    fn record(&mut self) {
        let obj = self.problem.objective_at(&self.thetas);
        let mut consensus: f64 = 0.0;
        for &(h, t) in self.topo.edges() {
            let diff: f64 = self.thetas[h]
                .iter()
                .zip(&self.thetas[t])
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            consensus = consensus.max(diff);
        }
        // every worker broadcasts full precision once per iteration
        let n = self.topo.n() as u64;
        let d = self.problem.d as u64;
        self.trace.push(TracePoint {
            iteration: self.iter,
            loss_gap: (obj - self.problem.f_star).abs(),
            consensus_gap: consensus,
            cum_rounds: self.iter * n,
            cum_bits: self.iter * n * 32 * d,
            cum_energy_j: 0.0,
        });
    }

    pub fn run(&mut self, iters: u64) -> Trace {
        for _ in 0..iters {
            self.step();
        }
        self.trace.clone()
    }

    pub fn theta(&self, n: usize) -> &[f64] {
        &self.thetas[n]
    }
}

fn build(problem: &Problem, topo: &Topology) -> Vec<Box<dyn SubproblemSolver>> {
    use crate::config::Task;
    use std::sync::Arc;
    (0..topo.n())
        .map(|i| -> Box<dyn SubproblemSolver> {
            let sh = &problem.shards[i];
            match problem.task {
                Task::Linear => Box::new(LinearSolver::from_shard(
                    Arc::clone(sh),
                    problem.rho,
                    topo.degree(i),
                )),
                Task::Logistic => Box::new(LogisticSolver::from_shard(
                    Arc::clone(sh),
                    problem.mu0,
                    problem.rho,
                    topo.degree(i),
                )),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algs::{AlgSpec, Run, RunOptions};
    use crate::data::synthetic;

    fn problem(n: usize, seed: u64) -> (Problem, Topology) {
        let topo = Topology::random_bipartite(n, 0.5, seed);
        let ds = synthetic::linear_dataset(n * 12, 5, seed);
        (Problem::new(&ds, &topo, 5.0, 0.0, seed), topo)
    }

    #[test]
    fn edge_dual_matches_aggregated_engine_exactly() {
        // paper eq. (7): the aggregated-alpha and per-edge-lambda
        // formulations are the same algorithm
        let (p, t) = problem(8, 31);
        let mut agg = Run::new(p.clone(), t.clone(), AlgSpec::ggadmm(), RunOptions::default());
        let mut edge = EdgeDualRun::new(p, t.clone());
        for _ in 0..30 {
            agg.step();
            edge.step();
        }
        for n in 0..8 {
            let a = agg.snapshot(n);
            for (x, y) in a.theta.iter().zip(edge.theta(n)) {
                assert!((x - y).abs() < 1e-9, "worker {n}: {x} vs {y}");
            }
            // the aggregated dual equals the edge-dual sum
            let alpha_edge = edge.alpha(n);
            for (x, y) in a.alpha.iter().zip(&alpha_edge) {
                assert!((x - y).abs() < 1e-9, "dual {n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn static_edge_dual_converges() {
        let (p, t) = problem(6, 32);
        let mut run = EdgeDualRun::new(p, t);
        let trace = run.run(150);
        assert!(trace.last_gap() < 1e-8, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn dynamic_topology_still_converges() {
        // D-GGADMM: resample the graph every 40 iterations; each switch
        // perturbs the duals of changed links, so convergence is slower
        // than the static run but must still reach high accuracy
        let (p, t) = problem(10, 33);
        let mut run = EdgeDualRun::new(p, t).dynamic(40, 0.4, 77);
        let trace = run.run(400);
        assert!(trace.last_gap() < 1e-4, "gap={:.3e}", trace.last_gap());
        assert_eq!(trace.algorithm, "D-GGADMM");
    }

    #[test]
    fn dynamic_epoch_boundary_preserves_progress() {
        let (p, t) = problem(8, 34);
        let mut run = EdgeDualRun::new(p, t).dynamic(15, 0.5, 5);
        let mut gaps = Vec::new();
        for _ in 0..120 {
            run.step();
            gaps.push(run.trace.points.last().unwrap().loss_gap);
        }
        // the switch may bump the gap transiently but must not reset it to
        // the initial magnitude
        let initial = gaps[0];
        for (k, g) in gaps.iter().enumerate().skip(60) {
            assert!(*g < initial * 0.5, "iter {k}: gap {g} vs initial {initial}");
        }
    }
}
