//! The sequential run engine: a thin in-process driver over the shared
//! [`crate::protocol::WorkerCore`] state machine.
//!
//! One [`Run`] owns the worker cores (each carrying its solver, censoring
//! gate, quantizer and incremental caches — see [`crate::protocol`]), the
//! shared [`Medium`] transmit path (energy/bits accounting + pluggable
//! [`crate::comm::LinkModel`]), and drives iterations of the configured
//! [`AlgSpec`] while recording the paper's metrics.  The exact same core
//! runs inside the sharded [`crate::coordinator`]; the two engines are
//! locked together bit-for-bit by `tests/coordinator_equivalence.rs`.
//!
//! Perf: the per-iteration path is allocation-free after construction
//! (persistent scratch inside each core, in-place
//! [`crate::solver::SubproblemSolver::update_into`] solves, `Arc`-shared
//! shards), and the core is **censoring-aware**: neighbor sums and dual
//! increments are maintained incrementally, so the O(deg * d) rebuilds
//! only run for workers whose closed neighborhood committed a
//! transmission — censored and dropped rounds touch nothing, making the
//! bookkeeping cost proportional to committed transmissions rather than
//! to N.  A stale buffer is rebuilt by the exact from-scratch loop, so
//! the engine is bit-identical to the always-recompute path
//! (`RunOptions::incremental = false`, locked by `tests/incremental.rs`).
//! The opt-in `threads > 1` fan-out dispatches through a persistent
//! barrier-synchronized [`crate::parallel::WorkerPool`] built once in
//! [`Run::new`] — no per-phase thread spawns or job lists.

use super::{AlgSpec, Problem, Schedule};
use crate::comm::{CommLog, EnergyModel, EnergyParams, LinkKind, Medium, SlotOutcome};
use crate::config::ExecutionConfig;
use crate::graph::{ChurnEvent, ChurnKind, Topology};
use crate::io::checkpoint::{MediumState, RunState};
use crate::io::{EventRecorder, EventSink, PersistableEngine};
use crate::metrics::{Trace, TracePoint};
use crate::protocol::{
    apply_churn_event, build_cores, replay_churn_structure, ProtocolConfig, WorkerCore,
};
use crate::solver::Backend;

/// Legacy execution options for a run — a thin shim over
/// [`ExecutionConfig`], kept so existing call sites compile; new code
/// should construct an [`ExecutionConfig`] directly (both engines accept
/// `impl Into<ExecutionConfig>`).
#[derive(Clone, Debug)]
pub struct RunOptions {
    pub backend: Backend,
    /// Threads for group-parallel updates (native backend only).
    pub threads: usize,
    /// Seed for quantizer randomness and failure injection.
    pub seed: u64,
    /// Sample the trace every this many iterations (1 = every iteration).
    pub record_every: u64,
    /// Artifact directory for the PJRT backend.
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// Broadcast-erasure probability (failure injection): a transmission
    /// is lost with this probability — energy and bits are still spent,
    /// but receivers keep the stale value (erasure with perfect feedback,
    /// so sender state stays consistent).  Shorthand for
    /// `link = Some(LinkKind::Erasure { p })`.
    pub drop_prob: f64,
    pub energy: EnergyParams,
    /// Censoring-aware incremental bookkeeping (default): neighbor sums
    /// and dual increments are rebuilt only when a hat in the worker's
    /// closed neighborhood committed, so censored/dropped rounds skip the
    /// O(deg * d) walks.  `false` forces the from-scratch recompute every
    /// phase — bit-identical by construction (differential tests, and the
    /// scratch baseline of `bench_hotpath`).
    pub incremental: bool,
    /// Explicit link model; when `None`, `drop_prob` selects between
    /// [`LinkKind::Ideal`] and [`LinkKind::Erasure`].
    pub link: Option<LinkKind>,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            backend: Backend::Native,
            threads: 1,
            seed: 7,
            record_every: 1,
            artifacts_dir: None,
            drop_prob: 0.0,
            energy: EnergyParams::default(),
            incremental: true,
            link: None,
        }
    }
}

impl From<RunOptions> for ExecutionConfig {
    fn from(o: RunOptions) -> ExecutionConfig {
        ExecutionConfig {
            backend: o.backend,
            artifacts_dir: o.artifacts_dir,
            threads: o.threads,
            sweep_threads: 1,
            seed: o.seed,
            record_every: o.record_every,
            drop_prob: o.drop_prob,
            link: o.link,
            energy: o.energy,
            incremental: o.incremental,
            churn: None,
            staleness_bound: None,
        }
    }
}

/// Read-only view of a worker's state (tests/diagnostics).
#[derive(Clone, Debug)]
pub struct WorkerSnapshot {
    pub theta: Vec<f64>,
    pub hat: Vec<f64>,
    pub alpha: Vec<f64>,
}

/// A configured, running instance of one algorithm on one problem.
pub struct Run {
    problem: Problem,
    topo: Topology,
    opts: ExecutionConfig,
    cores: Vec<WorkerCore>,
    medium: Medium,
    trace: Trace,
    iter: u64,
    /// optional streaming event log (io::events); emits at the same
    /// cadence as the trace
    recorder: Option<EventRecorder>,
    /// cached phase groups: `[heads, tails]` for alternating schedules,
    /// `[all]` for Jacobian — constant over a run, so `step` never
    /// rebuilds them (taken/restored around the phase loop to satisfy the
    /// borrow checker without cloning)
    phase_groups: Vec<Vec<usize>>,
    /// `phase_groups` filtered to active, degree >= 1 workers; equal to
    /// `phase_groups` on a static graph and rebuilt only when a churn
    /// event fires
    live_groups: Vec<Vec<usize>>,
    /// per-worker membership under churn (all `true` on a static graph)
    active: Vec<bool>,
    /// consecutive rounds each worker's broadcast stayed off the air
    /// (censored, dropped or late); only maintained under the
    /// bounded-staleness policy, all zero otherwise
    stale: Vec<u64>,
    /// per-(worker, block) ages under bounded staleness, flattened
    /// row-major by worker; a multi-block worker's partial commit resets
    /// only the blocks that went on the air, so a perpetually-censored
    /// layer still forces a refresh.  Empty for flat (single-block)
    /// models, where `stale` alone carries the policy.
    block_stale: Vec<u64>,
    /// scratch: committed-block mask of the sender being relayed (copied
    /// out of the sender's core so neighbors can be borrowed mutably)
    mask_scratch: Vec<bool>,
    /// scratch: per-block candidate bits masked to transmitting blocks
    block_bits_scratch: Vec<u64>,
    /// churn events applied so far (restore-time sanity: replaying a
    /// checkpoint's structure needs a freshly constructed engine)
    churn_applied: usize,
    /// persistent relay buffer: a committed hat is copied here once and
    /// delivered to every neighbor's core (the in-process "wire")
    relay: Vec<f64>,
    /// persistent worker pool for the `threads > 1` fan-out, built once
    /// (taken/restored around dispatch to satisfy the borrow checker)
    pool: Option<crate::parallel::WorkerPool>,
}

impl Run {
    pub fn new(
        problem: Problem,
        topo: Topology,
        spec: AlgSpec,
        opts: impl Into<ExecutionConfig>,
    ) -> Run {
        let opts: ExecutionConfig = opts.into();
        spec.validate().expect("invalid AlgSpec");
        opts.validate().expect("invalid ExecutionConfig");
        assert_eq!(problem.shards.len(), topo.n());
        let threads = crate::parallel::resolve_threads(opts.threads);
        assert!(
            !(opts.backend == Backend::Pjrt && threads > 1),
            "the PJRT backend shares one client across workers; use threads = 1"
        );
        // the persistent pool is built first so the one-time solver
        // construction (Gram matrices + Cholesky factors) fans out over
        // it too — one spawn serves both setup and every phase dispatch
        let mut pool = (threads > 1).then(|| crate::parallel::WorkerPool::new(threads));
        let cfg = ProtocolConfig {
            backend: opts.backend,
            artifacts_dir: opts.artifacts_dir.clone(),
            incremental: opts.incremental,
            seed: opts.seed,
        };
        let (cores, rng) = build_cores(&problem, &topo, &spec, &cfg, pool.as_mut());
        let energy = EnergyModel::new(opts.energy, topo.n(), spec.concurrent_fraction());
        let medium = Medium::new(
            energy,
            opts.energy.slot_s,
            LinkKind::resolve(opts.link, opts.drop_prob).build(rng, topo.n()),
        );
        let trace = Trace::new(&spec.name, &problem.dataset_name);
        let n = topo.n();
        if let Some(w) = opts.churn.as_ref().and_then(|c| c.max_worker()) {
            assert!(w < n, "churn schedule names worker {w}, but the topology has {n} workers");
        }
        let phase_groups = match spec.schedule {
            Schedule::Alternating => vec![topo.heads(), topo.tails()],
            Schedule::Jacobian => vec![(0..n).collect()],
        };
        let nblocks = problem.blocks.count();
        Run {
            relay: vec![0.0; problem.d],
            live_groups: phase_groups.clone(),
            phase_groups,
            active: vec![true; n],
            stale: vec![0; n],
            block_stale: vec![0; if nblocks > 1 { n * nblocks } else { 0 }],
            mask_scratch: Vec::with_capacity(nblocks),
            block_bits_scratch: Vec::with_capacity(nblocks),
            churn_applied: 0,
            pool,
            cores,
            medium,
            problem,
            topo,
            opts,
            trace,
            iter: 0,
            recorder: None,
        }
    }

    /// Attach a fresh streaming event log: emits `run_start` now and a
    /// `record` event at every trace sample from here on.
    pub fn start_event_log(&mut self, sink: Box<dyn EventSink>) {
        let mut rec = EventRecorder::new(sink, self.topo.n());
        rec.rebase(self.iter);
        rec.run_start(
            &self.trace.algorithm,
            &self.problem.dataset_name,
            self.topo.n(),
            self.problem.d,
            self.opts.seed,
        );
        self.recorder = Some(rec);
    }

    /// Attach an event log continuing an earlier one (resume): no
    /// `run_start` line; interval accounting restarts at the current
    /// iteration.
    pub fn resume_event_log(&mut self, sink: Box<dyn EventSink>) {
        let mut rec = EventRecorder::new(sink, self.topo.n());
        rec.rebase(self.iter);
        self.recorder = Some(rec);
    }

    /// Primal update for one group of workers (in parallel across the
    /// group, as the paper's schedule allows): each core refreshes its
    /// cached neighbor sum if stale and solves in place.
    ///
    /// Perf: allocation-free; the threaded path dispatches through the
    /// persistent pool built in `Run::new` (no per-phase thread spawns or
    /// job lists); fan-out only pays for expensive subproblems (logistic
    /// Newton), so tiny closed-form updates should run with `threads = 1`.
    fn update_group(&mut self, ids: &[usize]) {
        debug_assert!(ids.windows(2).all(|w| w[0] < w[1]), "ids must be increasing");
        if self.pool.is_none() || ids.len() <= 1 {
            for &i in ids {
                self.cores[i].primal_update();
            }
            return;
        }
        // pool path: the same in-place solves, claimed dynamically across
        // the pool's threads.  Access to the per-worker cores goes through
        // a raw base pointer because the borrow checker cannot see
        // index-disjointness across threads; `ids` are strictly increasing
        // (checked above), so no two jobs alias, and the pool barrier ends
        // every access before `for_each` returns.
        let mut pool = self.pool.take().expect("pool presence checked above");
        {
            let cores = crate::parallel::SyncPtr(self.cores.as_mut_ptr());
            pool.for_each(ids.len(), |j| {
                // SAFETY: distinct ids => disjoint elements; see above
                let core = unsafe { &mut *cores.0.add(ids[j]) };
                core.primal_update();
            });
        }
        self.pool = Some(pool);
    }

    /// Bottleneck broadcast distance of worker `i` over its **active**
    /// neighbors; equal to [`Topology::max_neighbor_distance`] on a
    /// static graph (same fold over the same set).
    fn active_neighbor_distance(&self, i: usize) -> f64 {
        self.topo
            .neighbors(i)
            .iter()
            .filter(|&&m| self.active[m])
            .map(|&m| self.topo.distance(i, m))
            .fold(0.0, f64::max)
    }

    /// Transmission pipeline for one group at censoring iteration index
    /// `k_plus_1`: each core builds and gates its candidate, committed
    /// broadcasts go through the shared [`Medium`] (energy + link fate),
    /// and deliveries land in the neighbors' cores via the persistent
    /// relay buffer — no per-round allocation anywhere.
    ///
    /// Under the bounded-staleness policy (`staleness_bound = Some(tau)`)
    /// the fate call is [`Medium::transmit_bounded`]: broadcasts that
    /// straggle past the slot are aborted (the round closes on time),
    /// per-worker staleness counts censored/lost rounds, and a worker at
    /// `stale >= tau` bypasses its censor gate and transmits reliably.
    fn transmit_group(&mut self, ids: &[usize], k_plus_1: u64) {
        let tau = self.opts.staleness_bound;
        for &i in ids {
            if let Some(rec) = &mut self.recorder {
                rec.note_attempt();
            }
            let nb = self.cores[i].block_count();
            let multi = nb > 1;
            // multi-block: any single block past the bound forces a full
            // reliable refresh (a censored layer cannot lag forever while
            // its siblings keep committing)
            let force = match tau {
                None => false,
                Some(t) if multi => {
                    self.block_stale[i * nb..(i + 1) * nb].iter().any(|&a| a >= t)
                }
                Some(t) => self.stale[i] >= t,
            };
            let Some(bits) = self.cores[i].prepare_broadcast_gated(k_plus_1, force) else {
                if tau.is_some() {
                    self.stale[i] += 1;
                    if multi {
                        for a in &mut self.block_stale[i * nb..(i + 1) * nb] {
                            *a += 1;
                        }
                    }
                }
                continue;
            };
            if multi {
                // per-block ledger: like the medium's totals, bits are
                // spent whether or not the broadcast lands
                let mask = self.cores[i].broadcast_mask().expect("multi-block candidate");
                let per = self.cores[i].candidate_block_bits().expect("multi-block candidate");
                self.block_bits_scratch.clear();
                self.block_bits_scratch
                    .extend(per.iter().zip(mask).map(|(&b, &on)| if on { b } else { 0 }));
                self.medium.record_block_bits(&self.block_bits_scratch);
            }
            let dist = self.active_neighbor_distance(i);
            let landed = match tau {
                None => self.medium.transmit(i, self.iter, bits, dist),
                Some(_) => matches!(
                    self.medium.transmit_bounded(i, self.iter, bits, dist, force),
                    SlotOutcome::Landed
                ),
            };
            if landed {
                self.cores[i].commit_pending();
                self.relay.copy_from_slice(self.cores[i].hat_self());
                if multi {
                    // partial commit: only the transmitting blocks'
                    // spans land at the neighbors — censored spans were
                    // never on the air, so receivers must keep their
                    // stale copies (the TCP transport can't resync them
                    // either; tests lock the engines together)
                    let mask = self.cores[i].broadcast_mask().expect("multi-block commit");
                    self.mask_scratch.clear();
                    self.mask_scratch.extend_from_slice(mask);
                    for &m in self.topo.neighbors(i) {
                        if self.active[m] {
                            self.cores[m].deliver_spans(i, &self.relay, &self.mask_scratch);
                        }
                    }
                } else {
                    for &m in self.topo.neighbors(i) {
                        if self.active[m] {
                            self.cores[m].deliver(i, &self.relay);
                        }
                    }
                }
                if force {
                    let staleness = self.stale[i];
                    if let Some(rec) = &mut self.recorder {
                        rec.stale_refresh(self.iter, i, staleness);
                    }
                }
                if multi && tau.is_some() {
                    // committed blocks reset; still-censored blocks keep
                    // aging.  `stale[i]` mirrors the worst block so the
                    // worker-level counter stays meaningful in events.
                    let ages = &mut self.block_stale[i * nb..(i + 1) * nb];
                    for (a, &on) in ages.iter_mut().zip(&self.mask_scratch) {
                        if on {
                            *a = 0;
                        } else {
                            *a += 1;
                        }
                    }
                    self.stale[i] = ages.iter().copied().max().unwrap_or(0);
                } else {
                    self.stale[i] = 0;
                }
            } else {
                // erasure/straggler with perfect feedback: cost was paid
                // by the medium, state update is rolled back
                self.cores[i].abort_pending();
                if tau.is_some() {
                    self.stale[i] += 1;
                    if multi {
                        for a in &mut self.block_stale[i * nb..(i + 1) * nb] {
                            *a += 1;
                        }
                    }
                }
            }
        }
    }

    /// Apply the churn events scheduled for the start of this iteration
    /// (shared transition logic: [`crate::protocol::apply_churn_event`])
    /// and rebuild the live phase groups.
    fn apply_churn_events(&mut self) {
        let events: Vec<ChurnEvent> = match &self.opts.churn {
            Some(c) => c.events_at(self.iter).to_vec(),
            None => return,
        };
        if events.is_empty() {
            return;
        }
        for e in &events {
            apply_churn_event(&mut self.cores, &mut self.active, &self.topo, e);
            self.stale[e.worker] = 0;
            let nb = self.cores[e.worker].block_count();
            if nb > 1 {
                for a in &mut self.block_stale[e.worker * nb..(e.worker + 1) * nb] {
                    *a = 0;
                }
            }
            self.churn_applied += 1;
            if let Some(rec) = &mut self.recorder {
                match e.kind {
                    ChurnKind::Leave => rec.worker_leave(self.iter, e.worker),
                    ChurnKind::Join => rec.worker_join(self.iter, e.worker),
                }
            }
        }
        self.refresh_live_groups();
    }

    /// Rebuild `live_groups` from the membership flags: a worker updates
    /// and transmits only while active with at least one active neighbor
    /// (a stranded degree-0 worker freezes in place until an edge
    /// returns).
    fn refresh_live_groups(&mut self) {
        self.live_groups = self
            .phase_groups
            .iter()
            .map(|g| {
                g.iter()
                    .copied()
                    .filter(|&i| self.active[i] && !self.cores[i].neighbors().is_empty())
                    .collect()
            })
            .collect();
    }

    /// Execute one iteration of the configured schedule: apply scheduled
    /// churn, then for each phase group (heads then tails, or everyone
    /// under Jacobian), primal update then transmission, followed by the
    /// dual update over the active workers.
    pub fn step(&mut self) {
        self.apply_churn_events();
        let k_plus_1 = self.iter + 1;
        let groups = std::mem::take(&mut self.live_groups);
        for group in &groups {
            self.update_group(group);
            self.transmit_group(group, k_plus_1);
            self.medium.end_slot();
        }
        self.live_groups = groups;
        for i in 0..self.cores.len() {
            if self.active[i] && !self.cores[i].neighbors().is_empty() {
                self.cores[i].dual_update();
            }
        }
        self.iter += 1;
        if self.iter % self.opts.record_every == 0 {
            self.record();
        }
    }

    fn record(&mut self) {
        // the cores hold the shard data: evaluate sum_n f_n(theta_n)
        // without cloning the worker models
        let obj: f64 = self.cores.iter().map(|c| c.loss()).sum();
        let gap = (obj - self.problem.f_star).abs();
        let mut consensus: f64 = 0.0;
        // consensus over live edges only: a detached worker's frozen
        // model is not part of the current constraint set
        for &(h, t) in self.topo.edges() {
            if !(self.active[h] && self.active[t]) {
                continue;
            }
            let diff: f64 = self.cores[h]
                .theta()
                .iter()
                .zip(self.cores[t].theta())
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            consensus = consensus.max(diff);
        }
        let log = self.medium.log();
        let point = TracePoint {
            iteration: self.iter,
            loss_gap: gap,
            consensus_gap: consensus,
            cum_rounds: log.rounds(),
            cum_bits: log.total_bits,
            cum_energy_j: log.total_energy_j,
        };
        self.trace.push(point);
        if let Some(rec) = &mut self.recorder {
            rec.record(&point, log, self.medium.sim_time_s());
        }
    }

    /// Run `iters` iterations and return the trace.
    pub fn run(&mut self, iters: u64) -> Trace {
        for _ in 0..iters {
            self.step();
        }
        self.trace.clone()
    }

    /// Current iteration count.
    pub fn iteration(&self) -> u64 {
        self.iter
    }

    /// Trace so far.
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Communication log so far.
    pub fn comm(&self) -> &CommLog {
        self.medium.log()
    }

    /// Simulated on-air wall clock so far (one upload slot per phase,
    /// stretched by the link model's latency when one is configured).
    pub fn sim_time_s(&self) -> f64 {
        self.medium.sim_time_s()
    }

    /// The underlying problem.
    pub fn problem(&self) -> &Problem {
        &self.problem
    }

    /// The topology this run communicates over.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Persistent neighbor-sum buffer of worker `i` (tests/diagnostics).
    /// Reflects the inputs of `i`'s most recent primal update; under the
    /// incremental engine it is bit-identical to what a from-scratch
    /// recompute at that point would have produced (`tests/incremental.rs`
    /// locks this against `RunOptions { incremental: false }`).
    pub fn neighbor_sum(&self, i: usize) -> &[f64] {
        self.cores[i].neighbor_sum()
    }

    /// Persistent dual-increment buffer of worker `i` (tests/diagnostics);
    /// same bit-identity guarantee as [`Run::neighbor_sum`].
    pub fn dual_delta(&self, i: usize) -> &[f64] {
        self.cores[i].dual_delta()
    }

    /// Snapshot worker `i` (tests / invariant checks).
    pub fn snapshot(&self, i: usize) -> WorkerSnapshot {
        WorkerSnapshot {
            theta: self.cores[i].theta().to_vec(),
            hat: self.cores[i].hat_self().to_vec(),
            alpha: self.cores[i].alpha().to_vec(),
        }
    }

    /// Invariant of the dual initialization (Theorem 3): with
    /// `alpha^0 = 0`, the duals satisfy `sum_n alpha_n = 0` at every
    /// iteration (alpha stays in the column space of `M_-`).
    pub fn dual_sum_norm(&self) -> f64 {
        let d = self.problem.d;
        let mut sum = vec![0.0; d];
        for c in &self.cores {
            crate::util::axpy(&mut sum, 1.0, c.alpha());
        }
        crate::util::norm2(&sum)
    }

    /// Export the full durable state at the current iteration boundary:
    /// every core's protocol state (including quantizer RNGs), the
    /// medium's cumulative totals + link-model RNG, and the trace so far.
    /// Restoring this into a freshly constructed engine reproduces the
    /// uninterrupted trajectory bit-for-bit (`tests/persistence.rs`).
    pub fn snapshot_state(&self) -> RunState {
        let log = self.medium.log();
        RunState {
            iteration: self.iter,
            cores: self.cores.iter().map(|c| c.export_state()).collect(),
            medium: MediumState {
                rounds: log.rounds(),
                total_bits: log.total_bits,
                total_energy_j: log.total_energy_j,
                sim_time_s: self.medium.sim_time_s(),
                link: self.medium.link_state(),
            },
            trace: self.trace.clone(),
            active: self.active.clone(),
            stale: self.stale.clone(),
            block_stale: self.block_stale.clone(),
            block_bits: log.block_bits.clone(),
        }
    }

    /// Overwrite this engine's state from a checkpoint.  The engine must
    /// have been constructed **fresh** for the same problem / topology /
    /// spec / options the checkpoint came from; under churn, the
    /// structural effect of every event before the checkpoint is replayed
    /// first so the cores' shapes match before values are imported.
    pub fn restore_state(&mut self, s: &RunState) {
        assert_eq!(
            s.cores.len(),
            self.cores.len(),
            "checkpoint is for a different worker count"
        );
        assert_eq!(s.active.len(), self.cores.len(), "checkpoint dynamic section size");
        assert_eq!(s.stale.len(), self.cores.len(), "checkpoint dynamic section size");
        if let Some(churn) = self.opts.churn.clone() {
            if !churn.is_empty() {
                assert_eq!(
                    self.churn_applied, 0,
                    "restore with churn requires a freshly constructed engine"
                );
                replay_churn_structure(
                    &mut self.cores,
                    &mut self.active,
                    &self.topo,
                    &churn,
                    s.iteration,
                );
                self.churn_applied =
                    churn.events().iter().filter(|e| e.at < s.iteration).count();
                self.refresh_live_groups();
            }
        }
        assert_eq!(
            self.active, s.active,
            "checkpoint membership does not match the configured churn schedule"
        );
        self.stale.copy_from_slice(&s.stale);
        if s.block_stale.is_empty() {
            // v2 checkpoints carry no per-block section (flat-model era)
            self.block_stale.iter_mut().for_each(|a| *a = 0);
        } else {
            assert_eq!(
                s.block_stale.len(),
                self.block_stale.len(),
                "checkpoint per-block staleness section size"
            );
            self.block_stale.copy_from_slice(&s.block_stale);
        }
        for (core, cs) in self.cores.iter_mut().zip(&s.cores) {
            core.import_state(cs);
        }
        self.medium.restore(
            s.medium.rounds,
            s.medium.total_bits,
            s.medium.total_energy_j,
            s.medium.sim_time_s,
            &s.medium.link,
        );
        self.medium.restore_block_bits(s.block_bits.clone());
        self.trace = s.trace.clone();
        self.iter = s.iteration;
        if let Some(rec) = &mut self.recorder {
            rec.rebase(s.iteration);
        }
    }
}

impl PersistableEngine for Run {
    fn step(&mut self) {
        Run::step(self);
    }
    fn iteration(&self) -> u64 {
        Run::iteration(self)
    }
    fn snapshot_state(&self) -> RunState {
        Run::snapshot_state(self)
    }
    fn restore_state(&mut self, state: &RunState) {
        Run::restore_state(self, state);
    }
    fn recorder_mut(&mut self) -> Option<&mut EventRecorder> {
        self.recorder.as_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn small_problem(task_linear: bool, n: usize, seed: u64) -> (Problem, Topology) {
        let topo = Topology::random_bipartite(n, 0.5, seed);
        if task_linear {
            let ds = synthetic::linear_dataset(n * 12, 5, seed);
            (Problem::new(&ds, &topo, 1.0, 0.0, seed), topo)
        } else {
            let ds = synthetic::logistic_dataset(n * 12, 5, seed);
            (Problem::new(&ds, &topo, 0.5, 0.05, seed), topo)
        }
    }

    #[test]
    fn ggadmm_converges_linear() {
        let (p, t) = small_problem(true, 8, 1);
        let mut run = Run::new(p, t, AlgSpec::ggadmm(), RunOptions::default());
        let trace = run.run(150);
        assert!(
            trace.last_gap() < 1e-6,
            "gap={:.3e}",
            trace.last_gap()
        );
        // consensus reached
        assert!(trace.points.last().unwrap().consensus_gap < 1e-4);
    }

    #[test]
    fn ggadmm_converges_logistic() {
        let (p, t) = small_problem(false, 6, 2);
        let mut run = Run::new(p, t, AlgSpec::ggadmm(), RunOptions::default());
        let trace = run.run(200);
        assert!(trace.last_gap() < 1e-5, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn cq_ggadmm_converges_and_spends_fewer_bits() {
        let (p, t) = small_problem(true, 8, 3);
        let mut plain = Run::new(p.clone(), t.clone(), AlgSpec::ggadmm(), RunOptions::default());
        let plain_trace = plain.run(250);
        let mut cq = Run::new(p, t, AlgSpec::cq_ggadmm(0.1, 0.9, 0.99, 2), RunOptions::default());
        let cq_trace = cq.run(250);
        assert!(cq_trace.last_gap() < 1e-4, "gap={:.3e}", cq_trace.last_gap());
        let pb = plain_trace.points.last().unwrap().cum_bits;
        let qb = cq_trace.points.last().unwrap().cum_bits;
        // at d=5 the 64-bit (R, b) header dominates, so the saving here is
        // modest; the paper-scale d=50 runs in the figure suite show the
        // full effect
        assert!(qb * 2 < pb, "quantized bits {qb} vs full {pb}");
    }

    #[test]
    fn censoring_reduces_rounds() {
        let (p, t) = small_problem(true, 10, 4);
        let mut plain = Run::new(p.clone(), t.clone(), AlgSpec::ggadmm(), RunOptions::default());
        let tr_plain = plain.run(200);
        let mut cens = Run::new(p, t, AlgSpec::c_ggadmm(0.5, 0.85), RunOptions::default());
        let tr_cens = cens.run(200);
        assert!(tr_cens.last_gap() < 1e-4, "gap={:.3e}", tr_cens.last_gap());
        assert!(
            tr_cens.points.last().unwrap().cum_rounds
                < tr_plain.points.last().unwrap().cum_rounds
        );
    }

    #[test]
    fn c_ggadmm_with_tau0_zero_equals_ggadmm() {
        // tau0 = 0 disables censoring: identical trajectories
        let (p, t) = small_problem(true, 6, 5);
        let mut a = Run::new(p.clone(), t.clone(), AlgSpec::ggadmm(), RunOptions::default());
        let spec_zero = AlgSpec {
            name: "C-GGADMM".into(),
            schedule: Schedule::Alternating,
            censor: Some(crate::censor::CensorConfig { tau0: 0.0, xi: 0.5 }),
            quant: None,
            update: crate::algs::UpdateRule::Admm,
            bits_split: None,
        };
        let mut b = Run::new(p, t, spec_zero, RunOptions::default());
        for _ in 0..30 {
            a.step();
            b.step();
        }
        for i in 0..6 {
            let sa = a.snapshot(i);
            let sb = b.snapshot(i);
            assert_eq!(sa.theta, sb.theta);
            assert_eq!(sa.alpha, sb.alpha);
        }
    }

    #[test]
    fn c_admm_converges() {
        // correctness of the Jacobian baseline; the per-iteration speed
        // comparison against GGADMM lives in the paper-scale figure suite
        // (tiny problems do not separate the schemes reliably)
        let (p, t) = small_problem(true, 8, 6);
        let mut cadmm =
            Run::new(p.clone(), t.clone(), AlgSpec::c_admm(0.05, 0.9), RunOptions::default());
        let tr_c = cadmm.run(400);
        assert!(tr_c.last_gap() < 1e-4, "gap={:.3e}", tr_c.last_gap());
        // the per-iteration GGADMM-vs-C-ADMM ordering is checked at paper
        // scale in tests/figures.rs (tiny problems do not separate them)
    }

    #[test]
    fn dual_sum_stays_zero() {
        // alpha^0 = 0 is in col(M_-); the sum over workers is conserved at 0
        let (p, t) = small_problem(true, 8, 7);
        let mut run = Run::new(p, t, AlgSpec::cq_ggadmm(0.3, 0.85, 0.99, 2), RunOptions::default());
        for _ in 0..50 {
            run.step();
            assert!(run.dual_sum_norm() < 1e-8, "sum alpha drifted");
        }
    }

    #[test]
    fn parallel_threads_match_sequential() {
        let (p, t) = small_problem(true, 10, 8);
        let mut seq = Run::new(
            p.clone(),
            t.clone(),
            AlgSpec::ggadmm(),
            RunOptions { threads: 1, ..RunOptions::default() },
        );
        let mut par = Run::new(
            p,
            t,
            AlgSpec::ggadmm(),
            RunOptions { threads: 4, ..RunOptions::default() },
        );
        for _ in 0..20 {
            seq.step();
            par.step();
        }
        for i in 0..10 {
            let a = seq.snapshot(i);
            let b = par.snapshot(i);
            for (x, y) in a.theta.iter().zip(&b.theta) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn threaded_logistic_matches_sequential() {
        // the thread fan-out is meant for Newton-dominated subproblems;
        // lock the in-place threaded path to the sequential one there too
        let (p, t) = small_problem(false, 8, 15);
        let mut seq = Run::new(
            p.clone(),
            t.clone(),
            AlgSpec::ggadmm(),
            RunOptions { threads: 1, ..RunOptions::default() },
        );
        let mut par = Run::new(
            p,
            t,
            AlgSpec::ggadmm(),
            RunOptions { threads: 3, ..RunOptions::default() },
        );
        for _ in 0..10 {
            seq.step();
            par.step();
        }
        for i in 0..8 {
            let a = seq.snapshot(i);
            let b = par.snapshot(i);
            for (x, y) in a.theta.iter().zip(&b.theta) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn scratch_engine_still_converges() {
        // incremental = false keeps the always-recompute path alive (the
        // differential baseline of tests/incremental.rs and the bench)
        let (p, t) = small_problem(true, 8, 21);
        let mut run = Run::new(
            p,
            t,
            AlgSpec::c_ggadmm(0.3, 0.85),
            RunOptions { incremental: false, ..RunOptions::default() },
        );
        let trace = run.run(200);
        assert!(trace.last_gap() < 1e-4, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn censored_round_leaves_caches_untouched() {
        // under heavy censoring the incremental engine must stop
        // rebuilding sums: freeze the run, snapshot the caches, step, and
        // check pointers-worth of state only moved where a commit happened
        let (p, t) = small_problem(true, 8, 22);
        let mut run = Run::new(
            p,
            t,
            AlgSpec::c_ggadmm(50.0, 0.999),
            RunOptions::default(),
        );
        // iteration 1 always transmits (state init), and iteration 2
        // still drains its staleness (heads built their phase-1 sums
        // before the tails' first commit); from iteration 3 on the huge
        // tau0 censors everything and the caches must freeze
        run.step();
        run.step();
        assert_eq!(run.comm().rounds(), 8, "tau0=50 must censor iteration 2");
        let before: Vec<Vec<f64>> = (0..8).map(|i| run.neighbor_sum(i).to_vec()).collect();
        let hats: Vec<Vec<f64>> = (0..8).map(|i| run.snapshot(i).hat).collect();
        run.step();
        assert_eq!(run.comm().rounds(), 8, "tau0=50 must censor iteration 3");
        for i in 0..8 {
            assert_eq!(run.snapshot(i).hat, hats[i], "hat {i} moved while censored");
            assert_eq!(
                run.neighbor_sum(i),
                &before[i][..],
                "cached sum {i} changed although no neighbor committed"
            );
        }
    }

    #[test]
    fn failure_injection_still_converges() {
        let (p, t) = small_problem(true, 8, 9);
        let mut run = Run::new(
            p,
            t,
            AlgSpec::ggadmm(),
            RunOptions { drop_prob: 0.1, ..RunOptions::default() },
        );
        let trace = run.run(300);
        assert!(trace.last_gap() < 1e-4, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn explicit_erasure_link_matches_drop_prob() {
        // the LinkKind plumbing must reproduce the legacy drop_prob knob
        // exactly (same RNG stream, same draw order)
        let (p, t) = small_problem(true, 8, 16);
        let mut a = Run::new(
            p.clone(),
            t.clone(),
            AlgSpec::ggadmm(),
            RunOptions { drop_prob: 0.25, ..RunOptions::default() },
        );
        let mut b = Run::new(
            p,
            t,
            AlgSpec::ggadmm(),
            RunOptions {
                link: Some(LinkKind::Erasure { p: 0.25 }),
                ..RunOptions::default()
            },
        );
        for _ in 0..25 {
            a.step();
            b.step();
        }
        assert_eq!(a.comm().rounds(), b.comm().rounds());
        for i in 0..8 {
            assert_eq!(a.snapshot(i).theta, b.snapshot(i).theta);
        }
    }

    #[test]
    fn latency_link_stretches_sim_time() {
        let (p, t) = small_problem(true, 6, 17);
        let mut ideal = Run::new(
            p.clone(),
            t.clone(),
            AlgSpec::ggadmm(),
            RunOptions::default(),
        );
        let mut slow = Run::new(
            p,
            t,
            AlgSpec::ggadmm(),
            RunOptions {
                link: Some(LinkKind::Latency { base_s: 0.05, per_bit_s: 0.0 }),
                ..RunOptions::default()
            },
        );
        ideal.run(10);
        slow.run(10);
        // 10 iterations x 2 phases x >= one slot each
        assert!((ideal.sim_time_s() - 20.0 * EnergyParams::default().slot_s).abs() < 1e-12);
        assert!(slow.sim_time_s() > ideal.sim_time_s());
        // latency must not perturb the trajectory, only the clock
        for i in 0..6 {
            assert_eq!(ideal.snapshot(i).theta, slow.snapshot(i).theta);
        }
    }

    #[test]
    fn snapshot_restore_resumes_bit_identically() {
        // quantized + censored + erasure: every piece of RNG state is live
        let (p, t) = small_problem(true, 8, 30);
        let spec = AlgSpec::cq_ggadmm(0.3, 0.85, 0.99, 2);
        let opts = ExecutionConfig::default().with_seed(11).with_drop_prob(0.2);
        let mut oracle = Run::new(p.clone(), t.clone(), spec.clone(), opts.clone());
        let mut a = Run::new(p.clone(), t.clone(), spec.clone(), opts.clone());
        for _ in 0..12 {
            oracle.step();
            a.step();
        }
        let state = a.snapshot_state();
        drop(a); // the engine is gone; resume into a fresh one
        let mut b = Run::new(p, t, spec, opts);
        b.restore_state(&state);
        assert_eq!(b.iteration(), 12);
        for _ in 0..18 {
            oracle.step();
            b.step();
        }
        assert_eq!(oracle.trace(), b.trace(), "resumed trace diverged");
        assert_eq!(oracle.comm().total_bits, b.comm().total_bits);
        assert_eq!(
            oracle.sim_time_s().to_bits(),
            b.sim_time_s().to_bits(),
            "sim clock diverged"
        );
    }

    #[test]
    fn event_log_streams_run_start_and_records() {
        let (p, t) = small_problem(true, 6, 31);
        let mut run = Run::new(
            p,
            t,
            AlgSpec::c_ggadmm(0.5, 0.85),
            ExecutionConfig::default().with_record_every(2),
        );
        let sink = crate::io::MemorySink::new();
        run.start_event_log(Box::new(sink.clone()));
        run.run(6);
        let lines = sink.lines();
        // run_start + records at iterations 2, 4, 6
        assert_eq!(lines.len(), 4, "{lines:?}");
        assert!(lines[0].contains(r#""event":"run_start""#), "{}", lines[0]);
        assert!(lines[0].contains(r#""workers":6"#), "{}", lines[0]);
        assert!(lines[1].contains(r#""iteration":2"#), "{}", lines[1]);
        assert!(lines[3].contains(r#""iteration":6"#), "{}", lines[3]);
    }

    #[test]
    fn churn_leave_and_rejoin_converges_and_streams_events() {
        let (p, t) = small_problem(true, 8, 40);
        let churn = crate::graph::ChurnSchedule::parse("5:leave:2 15:join:2").unwrap();
        let mut run = Run::new(
            p,
            t,
            AlgSpec::ggadmm(),
            ExecutionConfig::default().with_churn(Some(churn)),
        );
        let sink = crate::io::MemorySink::new();
        run.start_event_log(Box::new(sink.clone()));
        let trace = run.run(250);
        assert!(trace.last_gap() < 1e-4, "gap={:.3e}", trace.last_gap());
        let lines = sink.lines().join("\n");
        assert!(lines.contains(r#""event":"worker_leave""#), "{lines}");
        assert!(lines.contains(r#""event":"worker_join""#), "{lines}");
    }

    #[test]
    fn bounded_staleness_refreshes_heavily_censored_workers() {
        let (p, t) = small_problem(true, 8, 41);
        // tau0 = 50 censors every broadcast after state init; the
        // staleness bound must force workers back on the air anyway
        let mut run = Run::new(
            p,
            t,
            AlgSpec::c_ggadmm(50.0, 0.999),
            ExecutionConfig::default().with_staleness_bound(Some(3)),
        );
        let sink = crate::io::MemorySink::new();
        run.start_event_log(Box::new(sink.clone()));
        run.run(12);
        assert!(run.comm().rounds() > 8, "rounds={}", run.comm().rounds());
        let lines = sink.lines().join("\n");
        assert!(lines.contains(r#""event":"stale_refresh""#), "{lines}");
        assert!(lines.contains(r#""staleness":3"#), "{lines}");
    }

    #[test]
    fn degree_zero_mid_run_freezes_then_recovers() {
        // chain(2): worker 1 leaving strands worker 0 at degree 0 — the
        // run must idle through the gap without NaNs and recover after
        // the rejoin
        let topo = Topology::chain(2);
        let ds = synthetic::linear_dataset(24, 3, 43);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 43);
        let churn = crate::graph::ChurnSchedule::parse("3:leave:1 8:join:1").unwrap();
        let mut run = Run::new(
            p,
            topo,
            AlgSpec::ggadmm(),
            ExecutionConfig::default().with_churn(Some(churn)),
        );
        let trace = run.run(80);
        for pnt in &trace.points {
            assert!(pnt.loss_gap.is_finite() && pnt.consensus_gap.is_finite());
            assert!(pnt.cum_energy_j.is_finite());
        }
        for i in 0..2 {
            let s = run.snapshot(i);
            assert!(s.theta.iter().all(|v| v.is_finite()));
            assert!(s.alpha.iter().all(|v| v.is_finite()));
        }
        assert!(trace.last_gap() < 1e-5, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn snapshot_restore_mid_churn_resumes_bit_identically() {
        // checkpoint while a worker is detached, restore into a fresh
        // engine, and cross the rejoin: trajectory, clock and structure
        // must all match the uninterrupted oracle
        let (p, t) = small_problem(true, 8, 42);
        let churn = crate::graph::ChurnSchedule::parse("4:leave:3 14:join:3").unwrap();
        let spec = AlgSpec::cq_ggadmm(0.3, 0.85, 0.99, 2);
        let opts = ExecutionConfig::default()
            .with_churn(Some(churn))
            .with_staleness_bound(Some(2))
            .with_drop_prob(0.2);
        let mut oracle = Run::new(p.clone(), t.clone(), spec.clone(), opts.clone());
        let mut a = Run::new(p.clone(), t.clone(), spec.clone(), opts.clone());
        for _ in 0..9 {
            oracle.step();
            a.step();
        }
        let state = a.snapshot_state();
        assert!(!state.active[3], "worker 3 must be out at the checkpoint");
        drop(a);
        let mut b = Run::new(p, t, spec, opts);
        b.restore_state(&state);
        for _ in 0..12 {
            oracle.step();
            b.step();
        }
        assert_eq!(oracle.trace(), b.trace(), "resumed trace diverged");
        assert_eq!(
            oracle.sim_time_s().to_bits(),
            b.sim_time_s().to_bits(),
            "sim clock diverged"
        );
        for i in 0..8 {
            assert_eq!(oracle.snapshot(i).theta, b.snapshot(i).theta);
        }
    }

    fn mlp_problem(n: usize, seed: u64) -> (Problem, Topology) {
        let topo = Topology::chain(n);
        let ds = synthetic::linear_dataset(n * 12, 3, seed);
        let p = Problem::with_model(
            &ds,
            &topo,
            1.0,
            0.05,
            seed,
            crate::config::ModelSpec::Mlp { hidden: 2 },
        )
        .expect("mlp problem");
        (p, topo)
    }

    #[test]
    fn mlp_multi_block_run_ledgers_per_block_bits() {
        let (p, t) = mlp_problem(4, 44);
        let spec = AlgSpec::q_ggadmm(0.995, 4).with_bits_split(Some(vec![4, 2]));
        let mut run = Run::new(p, t, spec, RunOptions::default());
        let trace = run.run(40);
        let log = run.comm();
        assert_eq!(log.block_bits.len(), 2, "two parameter blocks must be ledgered");
        assert_eq!(
            log.block_bits.iter().sum::<u64>(),
            log.total_bits,
            "per-block bits must sum to the medium's total"
        );
        assert!(log.block_bits.iter().all(|&b| b > 0));
        assert!(trace.last_gap().is_finite());
        assert!(trace.points.last().unwrap().consensus_gap.is_finite());
    }

    #[test]
    fn mlp_snapshot_restore_resumes_bit_identically() {
        // multi-block + censored + quantized + erasure + staleness bound:
        // per-block quantizer RNGs, tx_once flags and block ages are live
        let (p, t) = mlp_problem(4, 45);
        let spec = AlgSpec::cq_ggadmm(0.3, 0.85, 0.995, 4).with_bits_split(Some(vec![4, 2]));
        let opts = ExecutionConfig::default()
            .with_seed(11)
            .with_drop_prob(0.2)
            .with_staleness_bound(Some(2));
        let mut oracle = Run::new(p.clone(), t.clone(), spec.clone(), opts.clone());
        let mut a = Run::new(p.clone(), t.clone(), spec.clone(), opts.clone());
        for _ in 0..10 {
            oracle.step();
            a.step();
        }
        let state = a.snapshot_state();
        drop(a);
        let mut b = Run::new(p, t, spec, opts);
        b.restore_state(&state);
        for _ in 0..14 {
            oracle.step();
            b.step();
        }
        assert_eq!(oracle.trace(), b.trace(), "resumed trace diverged");
        assert_eq!(oracle.comm().total_bits, b.comm().total_bits);
        assert_eq!(oracle.comm().block_bits, b.comm().block_bits, "block ledger diverged");
        assert_eq!(
            oracle.sim_time_s().to_bits(),
            b.sim_time_s().to_bits(),
            "sim clock diverged"
        );
    }

    #[test]
    fn qdgd_run_descends() {
        let (p, t) = small_problem(true, 6, 46);
        let mut run = Run::new(p, t, AlgSpec::qdgd(0.995, 8), RunOptions::default());
        let trace = run.run(120);
        let first = trace.points.first().unwrap().loss_gap;
        let last = trace.last_gap();
        assert!(last.is_finite());
        assert!(last < first, "qdgd failed to descend: {first} -> {last}");
        // primal-only baseline: duals never move off the zero init
        assert!(run.dual_sum_norm() == 0.0);
    }

    #[test]
    fn gadmm_on_chain_converges() {
        let topo = Topology::chain(8);
        let ds = synthetic::linear_dataset(96, 5, 10);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 10);
        // chains propagate information one hop per phase, so the diameter
        // slows convergence relative to denser bipartite graphs
        let mut run = Run::new(p, topo, AlgSpec::gadmm_chain(), RunOptions::default());
        let trace = run.run(800);
        assert!(trace.last_gap() < 1e-5, "gap={:.3e}", trace.last_gap());
    }
}
