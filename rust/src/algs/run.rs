//! The run engine: per-worker state machine + sequential simulator.
//!
//! One [`Run`] owns the worker states, the solver backends, the censoring
//! gates and quantizers, and drives iterations of the configured
//! [`AlgSpec`] while recording the paper's metrics.  The same state
//! transitions are reused by the threaded [`crate::coordinator`].

use super::{AlgSpec, Problem, Schedule};
use crate::censor::{gate, Gate};
use crate::comm::{full_precision_bits, CommLog, EnergyModel, EnergyParams, Transmission};
use crate::graph::{Group, Topology};
use crate::metrics::{Trace, TracePoint};
use crate::quant::Quantizer;
use crate::solver::{Backend, LinearSolver, LogisticSolver, SubproblemSolver};
use crate::util::rng::Pcg64;

/// Execution options for a run.
#[derive(Clone, Debug)]
pub struct RunOptions {
    pub backend: Backend,
    /// Threads for group-parallel updates (native backend only).
    pub threads: usize,
    /// Seed for quantizer randomness and failure injection.
    pub seed: u64,
    /// Sample the trace every this many iterations (1 = every iteration).
    pub record_every: u64,
    /// Artifact directory for the PJRT backend.
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// Broadcast-erasure probability (failure injection): a transmission
    /// is lost with this probability — energy and bits are still spent,
    /// but receivers keep the stale value (erasure with perfect feedback,
    /// so sender state stays consistent).
    pub drop_prob: f64,
    pub energy: EnergyParams,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            backend: Backend::Native,
            threads: 1,
            seed: 7,
            record_every: 1,
            artifacts_dir: None,
            drop_prob: 0.0,
            energy: EnergyParams::default(),
        }
    }
}

/// Read-only view of a worker's state (tests/diagnostics).
#[derive(Clone, Debug)]
pub struct WorkerSnapshot {
    pub theta: Vec<f64>,
    pub hat: Vec<f64>,
    pub alpha: Vec<f64>,
}

struct WorkerState {
    theta: Vec<f64>,
    /// Last value this worker's neighbors hold (theta-tilde / theta-hat).
    hat: Vec<f64>,
    alpha: Vec<f64>,
    quantizer: Option<Quantizer>,
    /// Whether this worker has ever transmitted (first transmission is
    /// never censored: neighbors start from zero, as in Algorithm 2 line 2).
    transmitted_once: bool,
}

/// A configured, running instance of one algorithm on one problem.
pub struct Run {
    problem: Problem,
    topo: Topology,
    spec: AlgSpec,
    opts: RunOptions,
    solvers: Vec<Box<dyn SubproblemSolver>>,
    workers: Vec<WorkerState>,
    energy: EnergyModel,
    comm: CommLog,
    trace: Trace,
    iter: u64,
    rng: Pcg64,
    /// reusable neighbor-sum buffer for the sequential update path
    nbr_scratch: Vec<f64>,
    /// preallocated per-worker dual-update increments
    dual_deltas: Vec<Vec<f64>>,
}

impl Run {
    pub fn new(problem: Problem, topo: Topology, spec: AlgSpec, opts: RunOptions) -> Run {
        spec.validate().expect("invalid AlgSpec");
        assert_eq!(problem.shards.len(), topo.n());
        assert!(
            !(opts.backend == Backend::Pjrt && opts.threads > 1),
            "the PJRT backend shares one client across workers; use threads = 1"
        );
        let d = problem.d;
        let mut rng = Pcg64::new(opts.seed ^ 0xA16_0001);
        let solvers = build_solvers(&problem, &topo, &opts, spec.schedule);
        let workers = (0..topo.n())
            .map(|i| WorkerState {
                theta: vec![0.0; d],
                hat: vec![0.0; d],
                alpha: vec![0.0; d],
                quantizer: spec
                    .quant
                    .as_ref()
                    .map(|q| Quantizer::new(*q, rng.fork(i as u64))),
                transmitted_once: false,
            })
            .collect();
        let energy = EnergyModel::new(opts.energy, topo.n(), spec.concurrent_fraction());
        let trace = Trace::new(&spec.name, &problem.dataset_name);
        let n = topo.n();
        Run {
            nbr_scratch: vec![0.0; d],
            dual_deltas: vec![vec![0.0; d]; n],
            problem,
            topo,
            spec,
            opts,
            solvers,
            workers,
            energy,
            comm: CommLog::default(),
            trace,
            iter: 0,
            rng,
        }
    }

    /// Penalty linear term for worker `i`'s subproblem.
    ///
    /// * Alternating (GGADMM, eqs. (21)/(22)): `sum_{m in N(i)} theta_hat_m`.
    /// * Jacobian (C-ADMM / DCADMM of Shi et al. 2014, Liu et al. 2019):
    ///   the update anchors on the worker's *own* last broadcast as well,
    ///   `d_i * theta_hat_i + sum_m theta_hat_m`, with the doubled
    ///   quadratic penalty `rho d_i ||theta||^2` (see `build_solvers`) —
    ///   the naive Jacobi variant without the anchor diverges.
    fn neighbor_sum(&self, i: usize) -> Vec<f64> {
        let d = self.problem.d;
        let mut sum = vec![0.0; d];
        for &m in self.topo.neighbors(i) {
            crate::util::axpy(&mut sum, 1.0, &self.workers[m].hat);
        }
        if self.spec.schedule == Schedule::Jacobian {
            crate::util::axpy(&mut sum, self.topo.degree(i) as f64, &self.workers[i].hat);
        }
        sum
    }

    /// Primal update for one group of workers (in parallel across the
    /// group, as the paper's schedule allows).
    ///
    /// Perf: the sequential path is allocation-free after warmup (scratch
    /// neighbor-sum buffer, split field borrows instead of input clones);
    /// see EXPERIMENTS.md §Perf.  Thread fan-out only pays for expensive
    /// subproblems (logistic Newton), so tiny closed-form updates should
    /// run with `threads = 1`.
    fn update_group(&mut self, ids: &[usize]) {
        if self.opts.threads <= 1 || ids.len() <= 1 {
            for &i in ids {
                // fill the scratch neighbor sum (immutable borrow ends
                // before the solver call below)
                let d = self.problem.d;
                self.nbr_scratch.iter_mut().for_each(|v| *v = 0.0);
                for &m in self.topo.neighbors(i) {
                    for j in 0..d {
                        self.nbr_scratch[j] += self.workers[m].hat[j];
                    }
                }
                if self.spec.schedule == Schedule::Jacobian {
                    let deg = self.topo.degree(i) as f64;
                    for j in 0..d {
                        self.nbr_scratch[j] += deg * self.workers[i].hat[j];
                    }
                }
                // disjoint field borrows: solvers (mut) + workers/scratch
                let theta = self.solvers[i].update(
                    &self.workers[i].alpha,
                    &self.nbr_scratch,
                    &self.workers[i].theta,
                );
                self.workers[i].theta = theta;
            }
            return;
        }
        // threaded path: gather inputs first (immutable pass), then solve
        let inputs: Vec<(usize, Vec<f64>, Vec<f64>, Vec<f64>)> = ids
            .iter()
            .map(|&i| {
                (
                    i,
                    self.workers[i].alpha.clone(),
                    self.neighbor_sum(i),
                    self.workers[i].theta.clone(),
                )
            })
            .collect();
        {
            // split the solver vector so each thread owns its workers
            let mut solver_refs: Vec<(usize, &mut Box<dyn SubproblemSolver>, &(usize, Vec<f64>, Vec<f64>, Vec<f64>))> = Vec::new();
            let mut remaining: &mut [Box<dyn SubproblemSolver>] = &mut self.solvers;
            let mut offset = 0usize;
            let mut inputs_iter = inputs.iter().peekable();
            while let Some(input) = inputs_iter.next() {
                let i = input.0;
                let (_, rest) = remaining.split_at_mut(i - offset);
                let (item, rest2) = rest.split_at_mut(1);
                solver_refs.push((i, &mut item[0], input));
                remaining = rest2;
                offset = i + 1;
                let _ = inputs_iter.peek();
            }
            let threads = self.opts.threads;
            let results: Vec<(usize, Vec<f64>)> = {
                let jobs: Vec<_> = solver_refs
                    .into_iter()
                    .map(|(i, solver, input)| (i, solver, input))
                    .collect();
                // scoped threads over chunks of jobs
                let mut out: Vec<Option<(usize, Vec<f64>)>> =
                    (0..jobs.len()).map(|_| None).collect();
                std::thread::scope(|scope| {
                    let chunk = jobs.len().div_ceil(threads.max(1));
                    let mut job_slices: Vec<_> = Vec::new();
                    let mut jobs = jobs;
                    let mut outs: &mut [Option<(usize, Vec<f64>)>] = &mut out;
                    while !jobs.is_empty() {
                        let take = chunk.min(jobs.len());
                        let rest = jobs.split_off(take);
                        let (head_out, rest_out) = outs.split_at_mut(take);
                        job_slices.push((std::mem::replace(&mut jobs, rest), head_out));
                        outs = rest_out;
                    }
                    let mut handles = Vec::new();
                    for (batch, out_slice) in job_slices {
                        handles.push(scope.spawn(move || {
                            for ((i, solver, input), slot) in
                                batch.into_iter().zip(out_slice.iter_mut())
                            {
                                let (_, alpha, nbr, warm) = input;
                                *slot = Some((i, solver.update(alpha, nbr, warm)));
                            }
                        }));
                    }
                    for h in handles {
                        h.join().expect("solver thread panicked");
                    }
                });
                out.into_iter().map(|x| x.unwrap()).collect()
            };
            for (i, theta) in results {
                self.workers[i].theta = theta;
            }
        }
    }

    /// Transmission pipeline (quantize -> censor -> broadcast) for one
    /// group at censoring iteration index `k_plus_1`.
    fn transmit_group(&mut self, ids: &[usize], k_plus_1: u64) {
        for &i in ids {
            let d = self.problem.d;
            let w = &mut self.workers[i];
            let (candidate_hat, payload_bits) = match &mut w.quantizer {
                Some(q) => {
                    // quantize the difference against the last state the
                    // neighbors hold (hat) so sender/receiver stay in sync
                    let (msg, recon) = q.quantize(&w.theta, &w.hat);
                    (recon, msg.payload_bits())
                }
                None => (w.theta.clone(), full_precision_bits(d)),
            };
            let decision = match (&self.spec.censor, self.workers[i].transmitted_once) {
                // first broadcast always goes out (state init)
                (_, false) => Gate::Transmit,
                (None, _) => Gate::Transmit,
                (Some(c), true) => gate(c, k_plus_1, &self.workers[i].hat, &candidate_hat),
            };
            if decision == Gate::Transmit {
                // failure injection: erasure with perfect feedback — cost
                // is paid, state update is rolled back
                let dropped =
                    self.opts.drop_prob > 0.0 && self.rng.bernoulli(self.opts.drop_prob);
                let dist = self.topo.max_neighbor_distance(i);
                self.comm.record(Transmission {
                    worker: i,
                    iteration: self.iter,
                    payload_bits: payload_bits,
                    distance_m: dist,
                    energy_j: self.energy.energy_j(payload_bits, dist),
                });
                if !dropped {
                    self.workers[i].hat = candidate_hat;
                    self.workers[i].transmitted_once = true;
                }
            }
        }
    }

    /// Dual update (eq. (23)): every worker, from the hat values.
    /// Allocation-free: increments accumulate into preallocated buffers.
    fn dual_update(&mut self) {
        let rho = self.problem.rho;
        let d = self.problem.d;
        for i in 0..self.topo.n() {
            let acc = &mut self.dual_deltas[i];
            acc.iter_mut().for_each(|v| *v = 0.0);
            for &m in self.topo.neighbors(i) {
                for j in 0..d {
                    acc[j] += self.workers[i].hat[j] - self.workers[m].hat[j];
                }
            }
        }
        for i in 0..self.topo.n() {
            crate::util::axpy(&mut self.workers[i].alpha, rho, &self.dual_deltas[i]);
        }
    }

    /// Execute one iteration of the configured schedule.
    pub fn step(&mut self) {
        let k_plus_1 = self.iter + 1;
        match self.spec.schedule {
            Schedule::Alternating => {
                let heads = self.topo.heads();
                let tails = self.topo.tails();
                self.update_group(&heads);
                self.transmit_group(&heads, k_plus_1);
                self.update_group(&tails);
                self.transmit_group(&tails, k_plus_1);
            }
            Schedule::Jacobian => {
                let all: Vec<usize> = (0..self.topo.n()).collect();
                self.update_group(&all);
                self.transmit_group(&all, k_plus_1);
            }
        }
        self.dual_update();
        self.iter += 1;
        if self.iter % self.opts.record_every == 0 {
            self.record();
        }
    }

    fn record(&mut self) {
        // the solvers hold the shard data: evaluate sum_n f_n(theta_n)
        // without cloning the worker models
        let obj: f64 = self
            .solvers
            .iter()
            .zip(&self.workers)
            .map(|(s, w)| s.loss(&w.theta))
            .sum();
        let gap = (obj - self.problem.f_star).abs();
        let mut consensus: f64 = 0.0;
        for &(h, t) in self.topo.edges() {
            let diff: f64 = self.workers[h]
                .theta
                .iter()
                .zip(&self.workers[t].theta)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            consensus = consensus.max(diff);
        }
        self.trace.push(TracePoint {
            iteration: self.iter,
            loss_gap: gap,
            consensus_gap: consensus,
            cum_rounds: self.comm.rounds(),
            cum_bits: self.comm.total_bits,
            cum_energy_j: self.comm.total_energy_j,
        });
    }

    /// Run `iters` iterations and return the trace.
    pub fn run(&mut self, iters: u64) -> Trace {
        for _ in 0..iters {
            self.step();
        }
        self.trace.clone()
    }

    /// Current iteration count.
    pub fn iteration(&self) -> u64 {
        self.iter
    }

    /// Trace so far.
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Communication log so far.
    pub fn comm(&self) -> &CommLog {
        &self.comm
    }

    /// The underlying problem.
    pub fn problem(&self) -> &Problem {
        &self.problem
    }

    /// The topology this run communicates over.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Snapshot worker `i` (tests / invariant checks).
    pub fn snapshot(&self, i: usize) -> WorkerSnapshot {
        WorkerSnapshot {
            theta: self.workers[i].theta.clone(),
            hat: self.workers[i].hat.clone(),
            alpha: self.workers[i].alpha.clone(),
        }
    }

    /// Invariant of the dual initialization (Theorem 3): with
    /// `alpha^0 = 0`, the duals satisfy `sum_n alpha_n = 0` at every
    /// iteration (alpha stays in the column space of `M_-`).
    pub fn dual_sum_norm(&self) -> f64 {
        let d = self.problem.d;
        let mut sum = vec![0.0; d];
        for w in &self.workers {
            crate::util::axpy(&mut sum, 1.0, &w.alpha);
        }
        crate::util::norm2(&sum)
    }
}

fn build_solvers(
    problem: &Problem,
    topo: &Topology,
    opts: &RunOptions,
    schedule: Schedule,
) -> Vec<Box<dyn SubproblemSolver>> {
    use crate::config::Task;
    (0..topo.n())
        .map(|i| -> Box<dyn SubproblemSolver> {
            let sh = &problem.shards[i];
            // Jacobian updates carry the doubled penalty rho*d_i||theta||^2
            // of DCADMM (see `neighbor_sum`); the solver's quadratic
            // coefficient is rho*degree/2, so feed it 2*d_i.
            let degree = match schedule {
                Schedule::Alternating => topo.degree(i),
                Schedule::Jacobian => 2 * topo.degree(i),
            };
            match (opts.backend, problem.task) {
                (Backend::Native, Task::Linear) => Box::new(LinearSolver::new(
                    sh.x.clone(),
                    sh.y.clone(),
                    problem.rho,
                    degree,
                )),
                (Backend::Native, Task::Logistic) => Box::new(LogisticSolver::new(
                    sh.x.clone(),
                    sh.y.clone(),
                    problem.mu0,
                    problem.rho,
                    degree,
                )),
                (Backend::Pjrt, task) => crate::runtime::pjrt_solver(
                    opts.artifacts_dir
                        .as_deref()
                        .expect("PJRT backend needs artifacts_dir"),
                    task,
                    sh,
                    problem.rho,
                    problem.mu0,
                    degree,
                )
                .expect("failed to build PJRT solver"),
            }
        })
        .collect()
}

// group is unused directly but kept for symmetry of the public API
#[allow(unused_imports)]
use Group as _Group;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn small_problem(task_linear: bool, n: usize, seed: u64) -> (Problem, Topology) {
        let topo = Topology::random_bipartite(n, 0.5, seed);
        if task_linear {
            let ds = synthetic::linear_dataset(n * 12, 5, seed);
            (Problem::new(&ds, &topo, 1.0, 0.0, seed), topo)
        } else {
            let ds = synthetic::logistic_dataset(n * 12, 5, seed);
            (Problem::new(&ds, &topo, 0.5, 0.05, seed), topo)
        }
    }

    #[test]
    fn ggadmm_converges_linear() {
        let (p, t) = small_problem(true, 8, 1);
        let mut run = Run::new(p, t, AlgSpec::ggadmm(), RunOptions::default());
        let trace = run.run(150);
        assert!(
            trace.last_gap() < 1e-6,
            "gap={:.3e}",
            trace.last_gap()
        );
        // consensus reached
        assert!(trace.points.last().unwrap().consensus_gap < 1e-4);
    }

    #[test]
    fn ggadmm_converges_logistic() {
        let (p, t) = small_problem(false, 6, 2);
        let mut run = Run::new(p, t, AlgSpec::ggadmm(), RunOptions::default());
        let trace = run.run(200);
        assert!(trace.last_gap() < 1e-5, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn cq_ggadmm_converges_and_spends_fewer_bits() {
        let (p, t) = small_problem(true, 8, 3);
        let mut plain = Run::new(p.clone(), t.clone(), AlgSpec::ggadmm(), RunOptions::default());
        let plain_trace = plain.run(250);
        let mut cq = Run::new(p, t, AlgSpec::cq_ggadmm(0.1, 0.9, 0.99, 2), RunOptions::default());
        let cq_trace = cq.run(250);
        assert!(cq_trace.last_gap() < 1e-4, "gap={:.3e}", cq_trace.last_gap());
        let pb = plain_trace.points.last().unwrap().cum_bits;
        let qb = cq_trace.points.last().unwrap().cum_bits;
        // at d=5 the 64-bit (R, b) header dominates, so the saving here is
        // modest; the paper-scale d=50 runs in the figure suite show the
        // full effect
        assert!(qb * 2 < pb, "quantized bits {qb} vs full {pb}");
    }

    #[test]
    fn censoring_reduces_rounds() {
        let (p, t) = small_problem(true, 10, 4);
        let mut plain = Run::new(p.clone(), t.clone(), AlgSpec::ggadmm(), RunOptions::default());
        let tr_plain = plain.run(200);
        let mut cens = Run::new(p, t, AlgSpec::c_ggadmm(0.5, 0.85), RunOptions::default());
        let tr_cens = cens.run(200);
        assert!(tr_cens.last_gap() < 1e-4, "gap={:.3e}", tr_cens.last_gap());
        assert!(
            tr_cens.points.last().unwrap().cum_rounds
                < tr_plain.points.last().unwrap().cum_rounds
        );
    }

    #[test]
    fn c_ggadmm_with_tau0_zero_equals_ggadmm() {
        // tau0 = 0 disables censoring: identical trajectories
        let (p, t) = small_problem(true, 6, 5);
        let mut a = Run::new(p.clone(), t.clone(), AlgSpec::ggadmm(), RunOptions::default());
        let spec_zero = AlgSpec {
            name: "C-GGADMM".into(),
            schedule: Schedule::Alternating,
            censor: Some(crate::censor::CensorConfig { tau0: 0.0, xi: 0.5 }),
            quant: None,
        };
        let mut b = Run::new(p, t, spec_zero, RunOptions::default());
        for _ in 0..30 {
            a.step();
            b.step();
        }
        for i in 0..6 {
            let sa = a.snapshot(i);
            let sb = b.snapshot(i);
            assert_eq!(sa.theta, sb.theta);
            assert_eq!(sa.alpha, sb.alpha);
        }
    }

    #[test]
    fn c_admm_converges() {
        // correctness of the Jacobian baseline; the per-iteration speed
        // comparison against GGADMM lives in the paper-scale figure suite
        // (tiny problems do not separate the schemes reliably)
        let (p, t) = small_problem(true, 8, 6);
        let mut cadmm =
            Run::new(p.clone(), t.clone(), AlgSpec::c_admm(0.05, 0.9), RunOptions::default());
        let tr_c = cadmm.run(400);
        assert!(tr_c.last_gap() < 1e-4, "gap={:.3e}", tr_c.last_gap());
        // the per-iteration GGADMM-vs-C-ADMM ordering is checked at paper
        // scale in tests/figures.rs (tiny problems do not separate them)
    }

    #[test]
    fn dual_sum_stays_zero() {
        // alpha^0 = 0 is in col(M_-); the sum over workers is conserved at 0
        let (p, t) = small_problem(true, 8, 7);
        let mut run = Run::new(p, t, AlgSpec::cq_ggadmm(0.3, 0.85, 0.99, 2), RunOptions::default());
        for _ in 0..50 {
            run.step();
            assert!(run.dual_sum_norm() < 1e-8, "sum alpha drifted");
        }
    }

    #[test]
    fn parallel_threads_match_sequential() {
        let (p, t) = small_problem(true, 10, 8);
        let mut seq = Run::new(
            p.clone(),
            t.clone(),
            AlgSpec::ggadmm(),
            RunOptions { threads: 1, ..RunOptions::default() },
        );
        let mut par = Run::new(
            p,
            t,
            AlgSpec::ggadmm(),
            RunOptions { threads: 4, ..RunOptions::default() },
        );
        for _ in 0..20 {
            seq.step();
            par.step();
        }
        for i in 0..10 {
            let a = seq.snapshot(i);
            let b = par.snapshot(i);
            for (x, y) in a.theta.iter().zip(&b.theta) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn failure_injection_still_converges() {
        let (p, t) = small_problem(true, 8, 9);
        let mut run = Run::new(
            p,
            t,
            AlgSpec::ggadmm(),
            RunOptions { drop_prob: 0.1, ..RunOptions::default() },
        );
        let trace = run.run(300);
        assert!(trace.last_gap() < 1e-4, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn gadmm_on_chain_converges() {
        let topo = Topology::chain(8);
        let ds = synthetic::linear_dataset(96, 5, 10);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 10);
        // chains propagate information one hop per phase, so the diameter
        // slows convergence relative to denser bipartite graphs
        let mut run = Run::new(p, topo, AlgSpec::gadmm_chain(), RunOptions::default());
        let trace = run.run(800);
        assert!(trace.last_gap() < 1e-5, "gap={:.3e}", trace.last_gap());
    }
}
