//! The run engine: per-worker state machine + sequential simulator.
//!
//! One [`Run`] owns the worker states, the solver backends, the censoring
//! gates and quantizers, and drives iterations of the configured
//! [`AlgSpec`] while recording the paper's metrics.  The same state
//! transitions are reused by the threaded [`crate::coordinator`].
//!
//! Perf: the per-iteration path is allocation-free after construction
//! (persistent scratch buffers, in-place [`SubproblemSolver::update_into`]
//! solves, `Arc`-shared shards), and the engine is **censoring-aware**:
//! neighbor sums and dual increments are maintained incrementally, so the
//! O(deg * d) rebuilds only run for workers whose closed neighborhood
//! committed a transmission — censored and dropped rounds touch nothing,
//! making the bookkeeping cost proportional to committed transmissions
//! rather than to N.  Staleness tracking works at link granularity and a
//! stale buffer is rebuilt by the exact from-scratch loop, so the engine
//! is bit-identical to the always-recompute path
//! (`RunOptions::incremental = false`, locked by `tests/incremental.rs`);
//! a delta-push scheme (`sum += new - old`) would be cheaper still but is
//! not IEEE-stable against recomputation, which the differential
//! guarantees here rely on.  The opt-in `threads > 1` fan-out dispatches
//! through a persistent barrier-synchronized [`crate::parallel::WorkerPool`]
//! built once in [`Run::new`] — no per-phase thread spawns or job lists.

use super::{AlgSpec, Problem, Schedule};
use crate::censor::{gate, Gate};
use crate::comm::{full_precision_bits, CommLog, EnergyModel, EnergyParams, Transmission};
use crate::graph::Topology;
use crate::metrics::{Trace, TracePoint};
use crate::quant::Quantizer;
use crate::solver::{Backend, LinearSolver, LogisticSolver, SubproblemSolver};
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// Execution options for a run.
#[derive(Clone, Debug)]
pub struct RunOptions {
    pub backend: Backend,
    /// Threads for group-parallel updates (native backend only).
    pub threads: usize,
    /// Seed for quantizer randomness and failure injection.
    pub seed: u64,
    /// Sample the trace every this many iterations (1 = every iteration).
    pub record_every: u64,
    /// Artifact directory for the PJRT backend.
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// Broadcast-erasure probability (failure injection): a transmission
    /// is lost with this probability — energy and bits are still spent,
    /// but receivers keep the stale value (erasure with perfect feedback,
    /// so sender state stays consistent).
    pub drop_prob: f64,
    pub energy: EnergyParams,
    /// Censoring-aware incremental bookkeeping (default): neighbor sums
    /// and dual increments are rebuilt only when a hat in the worker's
    /// closed neighborhood committed, so censored/dropped rounds skip the
    /// O(deg * d) walks.  `false` forces the from-scratch recompute every
    /// phase — bit-identical by construction (differential tests, and the
    /// scratch baseline of `bench_hotpath`).
    pub incremental: bool,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            backend: Backend::Native,
            threads: 1,
            seed: 7,
            record_every: 1,
            artifacts_dir: None,
            drop_prob: 0.0,
            energy: EnergyParams::default(),
            incremental: true,
        }
    }
}

/// Read-only view of a worker's state (tests/diagnostics).
#[derive(Clone, Debug)]
pub struct WorkerSnapshot {
    pub theta: Vec<f64>,
    pub hat: Vec<f64>,
    pub alpha: Vec<f64>,
}

struct WorkerState {
    theta: Vec<f64>,
    /// Last value this worker's neighbors hold (theta-tilde / theta-hat).
    hat: Vec<f64>,
    alpha: Vec<f64>,
    quantizer: Option<Quantizer>,
    /// Whether this worker has ever transmitted (first transmission is
    /// never censored: neighbors start from zero, as in Algorithm 2 line 2).
    transmitted_once: bool,
}

/// A configured, running instance of one algorithm on one problem.
pub struct Run {
    problem: Problem,
    topo: Topology,
    spec: AlgSpec,
    opts: RunOptions,
    solvers: Vec<Box<dyn SubproblemSolver>>,
    workers: Vec<WorkerState>,
    energy: EnergyModel,
    comm: CommLog,
    trace: Trace,
    iter: u64,
    rng: Pcg64,
    /// persistent per-worker neighbor-sum buffers, maintained
    /// incrementally (rebuilt only while `nbr_stale`)
    nbr_sums: Vec<Vec<f64>>,
    /// persistent quantize/censor candidate buffer (transmit is sequential)
    cand: Vec<f64>,
    /// persistent per-worker dual-update increments, maintained
    /// incrementally (rebuilt only when the closed neighborhood changed)
    dual_deltas: Vec<Vec<f64>>,
    /// cached phase groups: `[heads, tails]` for alternating schedules,
    /// `[all]` for Jacobian — constant over a run, so `step` never
    /// rebuilds them (taken/restored around the phase loop to satisfy the
    /// borrow checker without cloning)
    phase_groups: Vec<Vec<usize>>,
    /// `nbr_sums[i]` no longer reflects the hats it sums (a neighbor —
    /// or, under the Jacobian anchor, the worker itself — committed)
    nbr_stale: Vec<bool>,
    /// worker committed a hat update this iteration (cleared in `step`;
    /// drives the dual-increment rebuild decision)
    hat_changed: Vec<bool>,
    /// persistent worker pool for the `threads > 1` fan-out, built once
    /// (taken/restored around dispatch to satisfy the borrow checker)
    pool: Option<crate::parallel::WorkerPool>,
}

impl Run {
    pub fn new(problem: Problem, topo: Topology, spec: AlgSpec, opts: RunOptions) -> Run {
        spec.validate().expect("invalid AlgSpec");
        assert_eq!(problem.shards.len(), topo.n());
        assert!(
            !(opts.backend == Backend::Pjrt && opts.threads > 1),
            "the PJRT backend shares one client across workers; use threads = 1"
        );
        let d = problem.d;
        let mut rng = Pcg64::new(opts.seed ^ 0xA16_0001);
        // the persistent pool is built first so the one-time solver
        // construction (Gram matrices + Cholesky factors) fans out over
        // it too — one spawn serves both setup and every phase dispatch
        let mut pool =
            (opts.threads > 1).then(|| crate::parallel::WorkerPool::new(opts.threads));
        let solvers = build_solvers(&problem, &topo, &opts, spec.schedule, pool.as_mut());
        let workers = (0..topo.n())
            .map(|i| WorkerState {
                theta: vec![0.0; d],
                hat: vec![0.0; d],
                alpha: vec![0.0; d],
                quantizer: spec
                    .quant
                    .as_ref()
                    .map(|q| Quantizer::new(*q, rng.fork(i as u64))),
                transmitted_once: false,
            })
            .collect();
        let energy = EnergyModel::new(opts.energy, topo.n(), spec.concurrent_fraction());
        let trace = Trace::new(&spec.name, &problem.dataset_name);
        let n = topo.n();
        let phase_groups = match spec.schedule {
            Schedule::Alternating => vec![topo.heads(), topo.tails()],
            Schedule::Jacobian => vec![(0..n).collect()],
        };
        Run {
            nbr_sums: vec![vec![0.0; d]; n],
            cand: vec![0.0; d],
            dual_deltas: vec![vec![0.0; d]; n],
            phase_groups,
            nbr_stale: vec![true; n],
            hat_changed: vec![false; n],
            pool,
            problem,
            topo,
            spec,
            opts,
            solvers,
            workers,
            energy,
            comm: CommLog::default(),
            trace,
            iter: 0,
            rng,
        }
    }

    /// Refresh the persistent neighbor-sum buffers for `ids` from the
    /// current hat state (paper eqs. (21)/(22)).
    ///
    /// * Alternating (GGADMM): `sum_{m in N(i)} theta_hat_m`.
    /// * Jacobian (C-ADMM / DCADMM of Shi et al. 2014, Liu et al. 2019):
    ///   the update anchors on the worker's *own* last broadcast as well,
    ///   `d_i * theta_hat_i + sum_m theta_hat_m`, with the doubled
    ///   quadratic penalty `rho d_i ||theta||^2` (see `build_solvers`) —
    ///   the naive Jacobi variant without the anchor diverges.
    ///
    /// Incremental engine: a buffer is rebuilt only while `nbr_stale[i]`
    /// (some input hat committed since it was last built).  A clean
    /// buffer's inputs are unchanged, so the cached value is bit-identical
    /// to what this exact loop would produce — censored rounds skip the
    /// O(deg * d) walk entirely.
    fn fill_neighbor_sums(&mut self, ids: &[usize]) {
        let d = self.problem.d;
        let jacobian = self.spec.schedule == Schedule::Jacobian;
        for &i in ids {
            if self.opts.incremental && !self.nbr_stale[i] {
                continue;
            }
            let sum = &mut self.nbr_sums[i];
            sum.iter_mut().for_each(|v| *v = 0.0);
            for &m in self.topo.neighbors(i) {
                let hat = &self.workers[m].hat;
                for j in 0..d {
                    sum[j] += hat[j];
                }
            }
            if jacobian {
                let deg = self.topo.degree(i) as f64;
                let hat = &self.workers[i].hat;
                for j in 0..d {
                    sum[j] += deg * hat[j];
                }
            }
            self.nbr_stale[i] = false;
        }
    }

    /// Primal update for one group of workers (in parallel across the
    /// group, as the paper's schedule allows).
    ///
    /// Perf: both paths are allocation-free — neighbor sums land in
    /// persistent buffers, and `update_into` solves in place over each
    /// worker's `theta` (which doubles as the warm start).  The threaded
    /// path dispatches through the persistent pool built in `Run::new`
    /// (no per-phase thread spawns or job lists); fan-out only pays for
    /// expensive subproblems (logistic Newton), so tiny closed-form
    /// updates should run with `threads = 1`.
    fn update_group(&mut self, ids: &[usize]) {
        debug_assert!(ids.windows(2).all(|w| w[0] < w[1]), "ids must be increasing");
        self.fill_neighbor_sums(ids);
        if self.pool.is_none() || ids.len() <= 1 {
            for &i in ids {
                let w = &mut self.workers[i];
                self.solvers[i].update_into(&w.alpha, &self.nbr_sums[i], &mut w.theta);
            }
            return;
        }
        // pool path: the same in-place solves, claimed dynamically across
        // the pool's threads.  Access to (&mut solver, &mut worker) pairs
        // goes through raw base pointers because the borrow checker cannot
        // see index-disjointness across threads; `ids` are strictly
        // increasing (checked above), so no two jobs alias, and the pool
        // barrier ends every access before `for_each` returns.
        let mut pool = self.pool.take().expect("pool presence checked above");
        {
            let solvers = crate::parallel::SyncPtr(self.solvers.as_mut_ptr());
            let workers = crate::parallel::SyncPtr(self.workers.as_mut_ptr());
            let sums = &self.nbr_sums;
            pool.for_each(ids.len(), |j| {
                let i = ids[j];
                // SAFETY: distinct ids => disjoint elements; see above
                let solver = unsafe { &mut *solvers.0.add(i) };
                let w = unsafe { &mut *workers.0.add(i) };
                solver.update_into(&w.alpha, &sums[i], &mut w.theta);
            });
        }
        self.pool = Some(pool);
    }

    /// Transmission pipeline (quantize -> censor -> broadcast) for one
    /// group at censoring iteration index `k_plus_1`.
    ///
    /// Perf: the candidate state lands in the persistent `cand` buffer
    /// (quantizers reconstruct into it; full-precision senders memcpy
    /// their theta) and a transmit commits with `copy_from_slice` — no
    /// per-round vector allocation.
    fn transmit_group(&mut self, ids: &[usize], k_plus_1: u64) {
        let d = self.problem.d;
        let jacobian = self.spec.schedule == Schedule::Jacobian;
        for &i in ids {
            let w = &mut self.workers[i];
            let payload_bits = match &mut w.quantizer {
                Some(q) => {
                    // quantize the difference against the last state the
                    // neighbors hold (hat) so sender/receiver stay in sync
                    let (_radius, bits) = q.quantize_into(&w.theta, &w.hat, &mut self.cand);
                    crate::quant::payload_bits(d, bits)
                }
                None => {
                    self.cand.copy_from_slice(&w.theta);
                    full_precision_bits(d)
                }
            };
            let decision = match (&self.spec.censor, w.transmitted_once) {
                // first broadcast always goes out (state init)
                (_, false) => Gate::Transmit,
                (None, _) => Gate::Transmit,
                (Some(c), true) => gate(c, k_plus_1, &w.hat, &self.cand),
            };
            if decision == Gate::Transmit {
                // failure injection: erasure with perfect feedback — cost
                // is paid, state update is rolled back
                let dropped =
                    self.opts.drop_prob > 0.0 && self.rng.bernoulli(self.opts.drop_prob);
                let dist = self.topo.max_neighbor_distance(i);
                self.comm.record(Transmission {
                    worker: i,
                    iteration: self.iter,
                    payload_bits,
                    distance_m: dist,
                    energy_j: self.energy.energy_j(payload_bits, dist),
                });
                if !dropped {
                    w.hat.copy_from_slice(&self.cand);
                    w.transmitted_once = true;
                    // incremental bookkeeping: this commit staled every
                    // neighbor's cached sum (and, under the Jacobian
                    // anchor, the worker's own) plus the dual increments
                    // of the closed neighborhood this iteration.
                    // Censored and dropped rounds reach neither branch,
                    // so they leave all caches untouched.
                    self.hat_changed[i] = true;
                    for &m in self.topo.neighbors(i) {
                        self.nbr_stale[m] = true;
                    }
                    if jacobian {
                        self.nbr_stale[i] = true;
                    }
                }
            }
        }
    }

    /// Dual update (eq. (23)): every worker integrates
    /// `rho * sum_m (hat_n - hat_m)` into its dual.
    ///
    /// Allocation-free, and incremental: an increment buffer is rebuilt
    /// only when a hat in the worker's closed neighborhood committed this
    /// iteration — otherwise its inputs are unchanged and the cached
    /// value is bit-identical to what the rebuild would produce.  The
    /// O(d) `alpha += rho * delta` integration itself runs every
    /// iteration (duals accumulate even across censored rounds).
    fn dual_update(&mut self) {
        let rho = self.problem.rho;
        let d = self.problem.d;
        for i in 0..self.topo.n() {
            if self.opts.incremental
                && !self.hat_changed[i]
                && !self.topo.neighbors(i).iter().any(|&m| self.hat_changed[m])
            {
                continue;
            }
            let acc = &mut self.dual_deltas[i];
            acc.iter_mut().for_each(|v| *v = 0.0);
            for &m in self.topo.neighbors(i) {
                for j in 0..d {
                    acc[j] += self.workers[i].hat[j] - self.workers[m].hat[j];
                }
            }
        }
        for i in 0..self.topo.n() {
            crate::util::axpy(&mut self.workers[i].alpha, rho, &self.dual_deltas[i]);
        }
    }

    /// Execute one iteration of the configured schedule: for each phase
    /// group (heads then tails, or everyone under Jacobian), primal update
    /// then transmission, followed by the dual update.
    pub fn step(&mut self) {
        let k_plus_1 = self.iter + 1;
        self.hat_changed.iter_mut().for_each(|v| *v = false);
        let groups = std::mem::take(&mut self.phase_groups);
        for group in &groups {
            self.update_group(group);
            self.transmit_group(group, k_plus_1);
        }
        self.phase_groups = groups;
        self.dual_update();
        self.iter += 1;
        if self.iter % self.opts.record_every == 0 {
            self.record();
        }
    }

    fn record(&mut self) {
        // the solvers hold the shard data: evaluate sum_n f_n(theta_n)
        // without cloning the worker models
        let obj: f64 = self
            .solvers
            .iter()
            .zip(&self.workers)
            .map(|(s, w)| s.loss(&w.theta))
            .sum();
        let gap = (obj - self.problem.f_star).abs();
        let mut consensus: f64 = 0.0;
        for &(h, t) in self.topo.edges() {
            let diff: f64 = self.workers[h]
                .theta
                .iter()
                .zip(&self.workers[t].theta)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            consensus = consensus.max(diff);
        }
        self.trace.push(TracePoint {
            iteration: self.iter,
            loss_gap: gap,
            consensus_gap: consensus,
            cum_rounds: self.comm.rounds(),
            cum_bits: self.comm.total_bits,
            cum_energy_j: self.comm.total_energy_j,
        });
    }

    /// Run `iters` iterations and return the trace.
    pub fn run(&mut self, iters: u64) -> Trace {
        for _ in 0..iters {
            self.step();
        }
        self.trace.clone()
    }

    /// Current iteration count.
    pub fn iteration(&self) -> u64 {
        self.iter
    }

    /// Trace so far.
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Communication log so far.
    pub fn comm(&self) -> &CommLog {
        &self.comm
    }

    /// The underlying problem.
    pub fn problem(&self) -> &Problem {
        &self.problem
    }

    /// The topology this run communicates over.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Persistent neighbor-sum buffer of worker `i` (tests/diagnostics).
    /// Reflects the inputs of `i`'s most recent primal update; under the
    /// incremental engine it is bit-identical to what a from-scratch
    /// recompute at that point would have produced (`tests/incremental.rs`
    /// locks this against `RunOptions { incremental: false }`).
    pub fn neighbor_sum(&self, i: usize) -> &[f64] {
        &self.nbr_sums[i]
    }

    /// Persistent dual-increment buffer of worker `i` (tests/diagnostics);
    /// same bit-identity guarantee as [`Run::neighbor_sum`].
    pub fn dual_delta(&self, i: usize) -> &[f64] {
        &self.dual_deltas[i]
    }

    /// Snapshot worker `i` (tests / invariant checks).
    pub fn snapshot(&self, i: usize) -> WorkerSnapshot {
        WorkerSnapshot {
            theta: self.workers[i].theta.clone(),
            hat: self.workers[i].hat.clone(),
            alpha: self.workers[i].alpha.clone(),
        }
    }

    /// Invariant of the dual initialization (Theorem 3): with
    /// `alpha^0 = 0`, the duals satisfy `sum_n alpha_n = 0` at every
    /// iteration (alpha stays in the column space of `M_-`).
    pub fn dual_sum_norm(&self) -> f64 {
        let d = self.problem.d;
        let mut sum = vec![0.0; d];
        for w in &self.workers {
            crate::util::axpy(&mut sum, 1.0, &w.alpha);
        }
        crate::util::norm2(&sum)
    }
}

fn build_solvers(
    problem: &Problem,
    topo: &Topology,
    opts: &RunOptions,
    schedule: Schedule,
    pool: Option<&mut crate::parallel::WorkerPool>,
) -> Vec<Box<dyn SubproblemSolver>> {
    use crate::config::Task;
    let build_one = |i: usize| -> Box<dyn SubproblemSolver> {
        let sh = &problem.shards[i];
        // Jacobian updates carry the doubled penalty rho*d_i||theta||^2
        // of DCADMM (see `fill_neighbor_sums`); the solver's quadratic
        // coefficient is rho*degree/2, so feed it 2*d_i.
        let degree = match schedule {
            Schedule::Alternating => topo.degree(i),
            Schedule::Jacobian => 2 * topo.degree(i),
        };
        match (opts.backend, problem.task) {
            (Backend::Native, Task::Linear) => Box::new(LinearSolver::from_shard(
                Arc::clone(sh),
                problem.rho,
                degree,
            )),
            (Backend::Native, Task::Logistic) => Box::new(LogisticSolver::from_shard(
                Arc::clone(sh),
                problem.mu0,
                problem.rho,
                degree,
            )),
            (Backend::Pjrt, task) => crate::runtime::pjrt_solver(
                opts.artifacts_dir
                    .as_deref()
                    .expect("PJRT backend needs artifacts_dir"),
                task,
                sh,
                problem.rho,
                problem.mu0,
                degree,
            )
            .expect("failed to build PJRT solver"),
        }
    };
    // setup-time fan-out over the run's persistent pool: the per-worker
    // Gram + Cholesky construction is O(s d^2 + d^3) each and
    // embarrassingly parallel (PJRT is pinned to threads = 1 by the
    // assertion in `Run::new`, so it always takes the sequential arm)
    crate::parallel::map_maybe_pool(pool, topo.n(), build_one)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn small_problem(task_linear: bool, n: usize, seed: u64) -> (Problem, Topology) {
        let topo = Topology::random_bipartite(n, 0.5, seed);
        if task_linear {
            let ds = synthetic::linear_dataset(n * 12, 5, seed);
            (Problem::new(&ds, &topo, 1.0, 0.0, seed), topo)
        } else {
            let ds = synthetic::logistic_dataset(n * 12, 5, seed);
            (Problem::new(&ds, &topo, 0.5, 0.05, seed), topo)
        }
    }

    #[test]
    fn ggadmm_converges_linear() {
        let (p, t) = small_problem(true, 8, 1);
        let mut run = Run::new(p, t, AlgSpec::ggadmm(), RunOptions::default());
        let trace = run.run(150);
        assert!(
            trace.last_gap() < 1e-6,
            "gap={:.3e}",
            trace.last_gap()
        );
        // consensus reached
        assert!(trace.points.last().unwrap().consensus_gap < 1e-4);
    }

    #[test]
    fn ggadmm_converges_logistic() {
        let (p, t) = small_problem(false, 6, 2);
        let mut run = Run::new(p, t, AlgSpec::ggadmm(), RunOptions::default());
        let trace = run.run(200);
        assert!(trace.last_gap() < 1e-5, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn cq_ggadmm_converges_and_spends_fewer_bits() {
        let (p, t) = small_problem(true, 8, 3);
        let mut plain = Run::new(p.clone(), t.clone(), AlgSpec::ggadmm(), RunOptions::default());
        let plain_trace = plain.run(250);
        let mut cq = Run::new(p, t, AlgSpec::cq_ggadmm(0.1, 0.9, 0.99, 2), RunOptions::default());
        let cq_trace = cq.run(250);
        assert!(cq_trace.last_gap() < 1e-4, "gap={:.3e}", cq_trace.last_gap());
        let pb = plain_trace.points.last().unwrap().cum_bits;
        let qb = cq_trace.points.last().unwrap().cum_bits;
        // at d=5 the 64-bit (R, b) header dominates, so the saving here is
        // modest; the paper-scale d=50 runs in the figure suite show the
        // full effect
        assert!(qb * 2 < pb, "quantized bits {qb} vs full {pb}");
    }

    #[test]
    fn censoring_reduces_rounds() {
        let (p, t) = small_problem(true, 10, 4);
        let mut plain = Run::new(p.clone(), t.clone(), AlgSpec::ggadmm(), RunOptions::default());
        let tr_plain = plain.run(200);
        let mut cens = Run::new(p, t, AlgSpec::c_ggadmm(0.5, 0.85), RunOptions::default());
        let tr_cens = cens.run(200);
        assert!(tr_cens.last_gap() < 1e-4, "gap={:.3e}", tr_cens.last_gap());
        assert!(
            tr_cens.points.last().unwrap().cum_rounds
                < tr_plain.points.last().unwrap().cum_rounds
        );
    }

    #[test]
    fn c_ggadmm_with_tau0_zero_equals_ggadmm() {
        // tau0 = 0 disables censoring: identical trajectories
        let (p, t) = small_problem(true, 6, 5);
        let mut a = Run::new(p.clone(), t.clone(), AlgSpec::ggadmm(), RunOptions::default());
        let spec_zero = AlgSpec {
            name: "C-GGADMM".into(),
            schedule: Schedule::Alternating,
            censor: Some(crate::censor::CensorConfig { tau0: 0.0, xi: 0.5 }),
            quant: None,
        };
        let mut b = Run::new(p, t, spec_zero, RunOptions::default());
        for _ in 0..30 {
            a.step();
            b.step();
        }
        for i in 0..6 {
            let sa = a.snapshot(i);
            let sb = b.snapshot(i);
            assert_eq!(sa.theta, sb.theta);
            assert_eq!(sa.alpha, sb.alpha);
        }
    }

    #[test]
    fn c_admm_converges() {
        // correctness of the Jacobian baseline; the per-iteration speed
        // comparison against GGADMM lives in the paper-scale figure suite
        // (tiny problems do not separate the schemes reliably)
        let (p, t) = small_problem(true, 8, 6);
        let mut cadmm =
            Run::new(p.clone(), t.clone(), AlgSpec::c_admm(0.05, 0.9), RunOptions::default());
        let tr_c = cadmm.run(400);
        assert!(tr_c.last_gap() < 1e-4, "gap={:.3e}", tr_c.last_gap());
        // the per-iteration GGADMM-vs-C-ADMM ordering is checked at paper
        // scale in tests/figures.rs (tiny problems do not separate them)
    }

    #[test]
    fn dual_sum_stays_zero() {
        // alpha^0 = 0 is in col(M_-); the sum over workers is conserved at 0
        let (p, t) = small_problem(true, 8, 7);
        let mut run = Run::new(p, t, AlgSpec::cq_ggadmm(0.3, 0.85, 0.99, 2), RunOptions::default());
        for _ in 0..50 {
            run.step();
            assert!(run.dual_sum_norm() < 1e-8, "sum alpha drifted");
        }
    }

    #[test]
    fn parallel_threads_match_sequential() {
        let (p, t) = small_problem(true, 10, 8);
        let mut seq = Run::new(
            p.clone(),
            t.clone(),
            AlgSpec::ggadmm(),
            RunOptions { threads: 1, ..RunOptions::default() },
        );
        let mut par = Run::new(
            p,
            t,
            AlgSpec::ggadmm(),
            RunOptions { threads: 4, ..RunOptions::default() },
        );
        for _ in 0..20 {
            seq.step();
            par.step();
        }
        for i in 0..10 {
            let a = seq.snapshot(i);
            let b = par.snapshot(i);
            for (x, y) in a.theta.iter().zip(&b.theta) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn threaded_logistic_matches_sequential() {
        // the thread fan-out is meant for Newton-dominated subproblems;
        // lock the in-place threaded path to the sequential one there too
        let (p, t) = small_problem(false, 8, 15);
        let mut seq = Run::new(
            p.clone(),
            t.clone(),
            AlgSpec::ggadmm(),
            RunOptions { threads: 1, ..RunOptions::default() },
        );
        let mut par = Run::new(
            p,
            t,
            AlgSpec::ggadmm(),
            RunOptions { threads: 3, ..RunOptions::default() },
        );
        for _ in 0..10 {
            seq.step();
            par.step();
        }
        for i in 0..8 {
            let a = seq.snapshot(i);
            let b = par.snapshot(i);
            for (x, y) in a.theta.iter().zip(&b.theta) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn scratch_engine_still_converges() {
        // incremental = false keeps the always-recompute path alive (the
        // differential baseline of tests/incremental.rs and the bench)
        let (p, t) = small_problem(true, 8, 21);
        let mut run = Run::new(
            p,
            t,
            AlgSpec::c_ggadmm(0.3, 0.85),
            RunOptions { incremental: false, ..RunOptions::default() },
        );
        let trace = run.run(200);
        assert!(trace.last_gap() < 1e-4, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn censored_round_leaves_caches_untouched() {
        // under heavy censoring the incremental engine must stop
        // rebuilding sums: freeze the run, snapshot the caches, step, and
        // check pointers-worth of state only moved where a commit happened
        let (p, t) = small_problem(true, 8, 22);
        let mut run = Run::new(
            p,
            t,
            AlgSpec::c_ggadmm(50.0, 0.999),
            RunOptions::default(),
        );
        // iteration 1 always transmits (state init), and iteration 2
        // still drains its staleness (heads built their phase-1 sums
        // before the tails' first commit); from iteration 3 on the huge
        // tau0 censors everything and the caches must freeze
        run.step();
        run.step();
        assert_eq!(run.comm().rounds(), 8, "tau0=50 must censor iteration 2");
        let before: Vec<Vec<f64>> = (0..8).map(|i| run.neighbor_sum(i).to_vec()).collect();
        let hats: Vec<Vec<f64>> = (0..8).map(|i| run.snapshot(i).hat).collect();
        run.step();
        assert_eq!(run.comm().rounds(), 8, "tau0=50 must censor iteration 3");
        for i in 0..8 {
            assert_eq!(run.snapshot(i).hat, hats[i], "hat {i} moved while censored");
            assert_eq!(
                run.neighbor_sum(i),
                &before[i][..],
                "cached sum {i} changed although no neighbor committed"
            );
        }
    }

    #[test]
    fn failure_injection_still_converges() {
        let (p, t) = small_problem(true, 8, 9);
        let mut run = Run::new(
            p,
            t,
            AlgSpec::ggadmm(),
            RunOptions { drop_prob: 0.1, ..RunOptions::default() },
        );
        let trace = run.run(300);
        assert!(trace.last_gap() < 1e-4, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn gadmm_on_chain_converges() {
        let topo = Topology::chain(8);
        let ds = synthetic::linear_dataset(96, 5, 10);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 10);
        // chains propagate information one hop per phase, so the diameter
        // slows convergence relative to denser bipartite graphs
        let mut run = Run::new(p, topo, AlgSpec::gadmm_chain(), RunOptions::default());
        let trace = run.run(800);
        assert!(trace.last_gap() < 1e-5, "gap={:.3e}", trace.last_gap());
    }
}
