//! Decentralized gradient descent (DGD) — extra first-order baseline.
//!
//! The paper motivates GADMM's second-order updates against first-order
//! decentralized methods; this module provides that comparison point:
//! Metropolis-weighted consensus + local gradient step,
//! `theta_n^{k+1} = sum_m W_nm theta_m^k - eta_k grad f_n(theta_n^k)`.
//! Every worker transmits full precision every iteration (concurrent
//! fraction 1.0 for the energy model).

use super::Problem;
use crate::comm::{full_precision_bits, CommLog, EnergyModel, EnergyParams, Transmission};
use crate::config::Task;
use crate::graph::Topology;
use crate::metrics::{Trace, TracePoint};

/// Metropolis–Hastings mixing weights: `W_nm = 1/(1+max(d_n,d_m))` for
/// edges, diagonal absorbs the rest (doubly stochastic, symmetric).
pub fn metropolis_weights(topo: &Topology) -> Vec<Vec<(usize, f64)>> {
    let n = topo.n();
    let mut weights = vec![Vec::new(); n];
    for i in 0..n {
        let mut self_w = 1.0;
        for &m in topo.neighbors(i) {
            let w = 1.0 / (1.0 + topo.degree(i).max(topo.degree(m)) as f64);
            weights[i].push((m, w));
            self_w -= w;
        }
        weights[i].push((i, self_w));
    }
    weights
}

/// Local gradient of `f_n` at `theta`.
fn local_grad(problem: &Problem, n: usize, theta: &[f64]) -> Vec<f64> {
    let sh = &problem.shards[n];
    let d = problem.d;
    match problem.task {
        Task::Linear => {
            let resid = sh.x.matvec(theta);
            let resid: Vec<f64> = resid.iter().zip(&sh.y).map(|(p, y)| p - y).collect();
            sh.x.t_matvec(&resid)
        }
        Task::Logistic => {
            let inv_s = 1.0 / sh.s() as f64;
            let mut g = vec![0.0; d];
            for i in 0..sh.s() {
                let row = sh.x.row(i);
                let z = sh.y[i] * crate::util::dot(row, theta);
                let p = 1.0 / (1.0 + z.exp());
                let gs = -sh.y[i] * p * inv_s;
                for a in 0..d {
                    g[a] += gs * row[a];
                }
            }
            for a in 0..d {
                g[a] += problem.mu0 * theta[a];
            }
            g
        }
    }
}

/// Run DGD for `iters` iterations with step size `eta0 / sqrt(k+1)`.
pub fn run_dgd(
    problem: &Problem,
    topo: &Topology,
    eta0: f64,
    iters: u64,
    energy_params: EnergyParams,
) -> Trace {
    let n = topo.n();
    let d = problem.d;
    let weights = metropolis_weights(topo);
    let energy = EnergyModel::new(energy_params, n, 1.0);
    let mut comm = CommLog::default();
    let mut thetas = vec![vec![0.0; d]; n];
    let mut trace = Trace::new("DGD", &problem.dataset_name);
    for k in 0..iters {
        // everyone broadcasts full precision
        for i in 0..n {
            let bits = full_precision_bits(d);
            let dist = topo.max_neighbor_distance(i);
            comm.record(Transmission {
                worker: i,
                iteration: k,
                payload_bits: bits,
                distance_m: dist,
                energy_j: energy.energy_j(bits, dist),
            });
        }
        let eta = eta0 / ((k + 1) as f64).sqrt();
        let mut next = vec![vec![0.0; d]; n];
        for i in 0..n {
            for &(m, w) in &weights[i] {
                crate::util::axpy(&mut next[i], w, &thetas[m]);
            }
            let g = local_grad(problem, i, &thetas[i]);
            crate::util::axpy(&mut next[i], -eta, &g);
        }
        thetas = next;
        let obj = problem.objective_at(&thetas);
        let mut consensus: f64 = 0.0;
        for &(h, t) in topo.edges() {
            let diff: f64 = thetas[h]
                .iter()
                .zip(&thetas[t])
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            consensus = consensus.max(diff);
        }
        trace.push(TracePoint {
            iteration: k + 1,
            loss_gap: (obj - problem.f_star).abs(),
            consensus_gap: consensus,
            cum_rounds: comm.rounds(),
            cum_bits: comm.total_bits,
            cum_energy_j: comm.total_energy_j,
        });
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn metropolis_rows_sum_to_one() {
        let topo = Topology::random_bipartite(10, 0.4, 1);
        let w = metropolis_weights(&topo);
        for row in &w {
            let sum: f64 = row.iter().map(|(_, v)| v).sum();
            assert!((sum - 1.0).abs() < 1e-12);
            for &(_, v) in row {
                assert!(v > 0.0);
            }
        }
    }

    #[test]
    fn metropolis_symmetric() {
        let topo = Topology::random_bipartite(8, 0.5, 2);
        let w = metropolis_weights(&topo);
        for i in 0..8 {
            for &(m, v) in &w[i] {
                if m != i {
                    let back = w[m].iter().find(|(j, _)| *j == i).unwrap().1;
                    assert!((v - back).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn dgd_decreases_objective() {
        let topo = Topology::random_bipartite(6, 0.5, 3);
        let ds = synthetic::linear_dataset(72, 4, 3);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 3);
        let trace = run_dgd(&p, &topo, 0.01, 300, EnergyParams::default());
        let first = trace.points.first().unwrap().loss_gap;
        let last = trace.last_gap();
        assert!(last < first * 0.2, "first={first:.3e} last={last:.3e}");
    }
}
