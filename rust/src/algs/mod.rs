//! The algorithm family of the paper behind one engine.
//!
//! All schemes share the ADMM primal/dual structure and differ along three
//! orthogonal axes, which [`AlgSpec`] composes:
//!
//! | scheme      | schedule     | censoring | quantization |
//! |-------------|--------------|-----------|--------------|
//! | GGADMM      | alternating  | —         | —            |
//! | C-GGADMM    | alternating  | yes       | —            |
//! | Q-GGADMM    | alternating  | —         | yes          |
//! | CQ-GGADMM   | alternating  | yes       | yes          |
//! | C-ADMM      | Jacobian     | yes       | —            |
//! | GADMM       | alternating (chain topology) | — | —    |
//!
//! plus [`dgd`], the decentralized-gradient-descent extra baseline.
//!
//! The engine here is the *sequential simulator* used by the experiment
//! harness (deterministic, allocation-light); both it and the sharded
//! [`crate::coordinator`] are thin drivers over the single per-worker
//! state machine in [`crate::protocol`], and the two are locked together
//! bit-for-bit by `tests/coordinator_equivalence.rs`.

pub mod dgd;
pub mod edge_dual;
mod run;

pub use run::{Run, RunOptions, WorkerSnapshot};

use crate::censor::CensorConfig;
use crate::config::{ModelSpec, Task};
use crate::data::{partition_uniform, Dataset, Shard};
use crate::graph::Topology;
use crate::param::Blocks;
use crate::quant::QuantConfig;
use crate::solver::{
    central_linear_optimum, central_logistic_optimum, global_objective,
};
use std::sync::Arc;

/// Update schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// GGADMM: heads update + transmit, then tails (paper Algorithm 2).
    Alternating,
    /// Jacobian decentralized ADMM (C-ADMM of Liu et al. 2019b): all
    /// workers update in parallel from the previous broadcast state.
    Jacobian,
}

/// Per-iteration primal/dual update rule: the ADMM family of the paper,
/// or the first-order QDGD baseline (Reisizadeh et al. 2018) that rides
/// the same schedule/quantizer/transport machinery.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UpdateRule {
    /// Subproblem solve + dual ascent (every GGADMM-family variant).
    Admm,
    /// Quantized decentralized gradient descent: average the latest
    /// neighbor reconstructions with the local model, then take one
    /// gradient step of size `lr`.  No dual variables, no censoring.
    Qdgd { lr: f64 },
}

/// A fully specified algorithm variant.
#[derive(Clone, Debug)]
pub struct AlgSpec {
    pub name: String,
    pub schedule: Schedule,
    pub censor: Option<CensorConfig>,
    pub quant: Option<QuantConfig>,
    /// Primal/dual update rule ([`UpdateRule::Admm`] for the paper's
    /// schemes).
    pub update: UpdateRule,
    /// Per-layer initial bit allocation for multi-block models (`None` =
    /// uniform `quant.bits0` on every block; ignored without a
    /// quantizer).
    pub bits_split: Option<Vec<u32>>,
}

impl AlgSpec {
    pub fn ggadmm() -> AlgSpec {
        AlgSpec {
            name: "GGADMM".into(),
            schedule: Schedule::Alternating,
            censor: None,
            quant: None,
            update: UpdateRule::Admm,
            bits_split: None,
        }
    }

    pub fn c_ggadmm(tau0: f64, xi: f64) -> AlgSpec {
        AlgSpec {
            name: "C-GGADMM".into(),
            schedule: Schedule::Alternating,
            censor: Some(CensorConfig { tau0, xi }),
            quant: None,
            update: UpdateRule::Admm,
            bits_split: None,
        }
    }

    pub fn q_ggadmm(omega: f64, bits0: u32) -> AlgSpec {
        AlgSpec {
            name: "Q-GGADMM".into(),
            schedule: Schedule::Alternating,
            censor: None,
            quant: Some(Self::quant_cfg(omega, bits0)),
            update: UpdateRule::Admm,
            bits_split: None,
        }
    }

    pub fn cq_ggadmm(tau0: f64, xi: f64, omega: f64, bits0: u32) -> AlgSpec {
        AlgSpec {
            name: "CQ-GGADMM".into(),
            schedule: Schedule::Alternating,
            censor: Some(CensorConfig { tau0, xi }),
            quant: Some(Self::quant_cfg(omega, bits0)),
            update: UpdateRule::Admm,
            bits_split: None,
        }
    }

    /// Quantizer config with the bit cap raised to cover `bits0` (a
    /// `bits0` above the default cap but within the codec's 32-bit wire
    /// limit is a valid request, not a construction panic).
    fn quant_cfg(omega: f64, bits0: u32) -> QuantConfig {
        let default_cap = QuantConfig::default().max_bits;
        QuantConfig { bits0, omega, max_bits: default_cap.max(bits0) }
    }

    pub fn c_admm(tau0: f64, xi: f64) -> AlgSpec {
        AlgSpec {
            name: "C-ADMM".into(),
            schedule: Schedule::Jacobian,
            censor: Some(CensorConfig { tau0, xi }),
            quant: None,
            update: UpdateRule::Admm,
            bits_split: None,
        }
    }

    /// QDGD (Reisizadeh et al. 2018): quantized decentralized gradient
    /// descent — the first-order baseline the paper compares against
    /// conceptually.  All workers update in parallel (Jacobian
    /// schedule, no anchor/degree-doubling), broadcast quantized model
    /// differences, and never censor.
    pub fn qdgd(omega: f64, bits0: u32) -> AlgSpec {
        AlgSpec {
            name: "QDGD".into(),
            schedule: Schedule::Jacobian,
            censor: None,
            quant: Some(Self::quant_cfg(omega, bits0)),
            update: UpdateRule::Qdgd { lr: 0.05 },
            bits_split: None,
        }
    }

    /// Attach a per-layer bit allocation (kept only when the variant
    /// quantizes — the knob-ignoring policy of [`AlgSpec::parse`]).
    pub fn with_bits_split(mut self, split: Option<Vec<u32>>) -> AlgSpec {
        self.bits_split = if self.quant.is_some() { split } else { None };
        self
    }

    /// Chain GADMM is GGADMM run on [`Topology::chain`]; this alias exists
    /// so traces are labelled as the paper labels them.
    pub fn gadmm_chain() -> AlgSpec {
        AlgSpec { name: "GADMM".into(), ..AlgSpec::ggadmm() }
    }

    /// Fraction of workers transmitting concurrently in one slot (feeds
    /// the bandwidth split of the energy model).
    pub fn concurrent_fraction(&self) -> f64 {
        match self.schedule {
            Schedule::Alternating => 0.5,
            Schedule::Jacobian => 1.0,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if let Some(c) = &self.censor {
            c.validate()?;
        }
        if let Some(q) = &self.quant {
            q.validate()?;
        }
        if let UpdateRule::Qdgd { lr } = self.update {
            if !(lr > 0.0 && lr.is_finite()) {
                return Err(format!("qdgd learning rate {lr} must be finite and > 0"));
            }
        }
        if let Some(split) = &self.bits_split {
            if split.is_empty() {
                return Err("bits_split must name at least one width".into());
            }
            let cap = self.quant.map(|q| q.max_bits).unwrap_or(32).min(32);
            if let Some(b) = split.iter().find(|b| !(1..=cap).contains(*b)) {
                return Err(format!("bits_split width {b} out of range [1, {cap}]"));
            }
        }
        Ok(())
    }

    /// Build a variant from its CLI / manifest name plus the censoring
    /// and quantization knobs (knobs a variant does not use are
    /// ignored).  `dgd` is *not* an `AlgSpec` — the first-order baseline
    /// has its own driver; callers route it before this.  Keep the name
    /// list in sync with `config::manifest::ALG_NAMES`.
    pub fn parse(
        name: &str,
        tau0: f64,
        xi: f64,
        omega: f64,
        bits0: u32,
    ) -> Result<AlgSpec, String> {
        let spec = match name {
            "ggadmm" => AlgSpec::ggadmm(),
            "c-ggadmm" => AlgSpec::c_ggadmm(tau0, xi),
            "q-ggadmm" => AlgSpec::q_ggadmm(omega, bits0),
            "cq-ggadmm" => AlgSpec::cq_ggadmm(tau0, xi, omega, bits0),
            "c-admm" => AlgSpec::c_admm(tau0, xi),
            "gadmm" => AlgSpec::gadmm_chain(),
            "qdgd" => AlgSpec::qdgd(omega, bits0),
            other => {
                return Err(format!(
                    "unknown algorithm '{other}' \
                     (expected ggadmm|c-ggadmm|q-ggadmm|cq-ggadmm|c-admm|gadmm|qdgd)"
                ))
            }
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// A decentralized consensus problem instance: the partitioned data, the
/// penalty/regularization constants and the centralized reference optimum.
#[derive(Clone, Debug)]
pub struct Problem {
    pub task: Task,
    pub dataset_name: String,
    /// Shards are shared (`Arc`) so solver construction and `Problem`
    /// clones never copy the underlying `X`/`y` data.
    pub shards: Vec<Arc<Shard>>,
    pub rho: f64,
    pub mu0: f64,
    pub d: usize,
    pub theta_star: Vec<f64>,
    pub f_star: f64,
    /// Model parameterization ([`ModelSpec::Glm`] for the paper's
    /// single-block problems).
    pub model: ModelSpec,
    /// Parameter-block layout; [`Blocks::single`] for GLM models.
    pub blocks: Blocks,
    /// Initial model every worker starts from.  All-zeros for GLM
    /// (bit-identical to the pre-refactor engines); a deterministic
    /// seeded nonzero point for the MLP, whose zero point is a saddle.
    pub theta0: Vec<f64>,
}

impl Problem {
    /// Partition `ds` across the topology's workers and precompute `f*`.
    pub fn new(ds: &Dataset, topo: &Topology, rho: f64, mu0: f64, seed: u64) -> Problem {
        let shards: Vec<Arc<Shard>> = partition_uniform(ds, topo.n(), seed)
            .into_iter()
            .map(Arc::new)
            .collect();
        let theta_star = match ds.task {
            Task::Linear => central_linear_optimum(&shards),
            Task::Logistic => central_logistic_optimum(&shards, mu0),
        };
        let f_star = global_objective(&shards, ds.task, mu0, &theta_star);
        let d = ds.d();
        Problem {
            task: ds.task,
            dataset_name: ds.name.clone(),
            shards,
            rho,
            mu0,
            d,
            theta_star,
            f_star,
            model: ModelSpec::Glm,
            blocks: Blocks::single(d),
            theta0: vec![0.0; d],
        }
    }

    /// Like [`Problem::new`], but parameterized by [`ModelSpec`]: `glm`
    /// delegates to `new` (bit-identical), `mlp:h` builds the two-block
    /// one-hidden-layer model with a seeded nonzero start and a
    /// Gauss–Newton centralized reference optimum.
    pub fn with_model(
        ds: &Dataset,
        topo: &Topology,
        rho: f64,
        mu0: f64,
        seed: u64,
        model: ModelSpec,
    ) -> Result<Problem, String> {
        let hidden = match model {
            ModelSpec::Glm => return Ok(Problem::new(ds, topo, rho, mu0, seed)),
            ModelSpec::Mlp { hidden } => hidden,
        };
        if ds.task != Task::Linear {
            return Err(format!(
                "model 'mlp' fits regression targets; dataset '{}' is a {:?} task",
                ds.name, ds.task
            ));
        }
        let shards: Vec<Arc<Shard>> = partition_uniform(ds, topo.n(), seed)
            .into_iter()
            .map(Arc::new)
            .collect();
        let d_in = ds.d();
        let blocks = crate::solver::mlp::mlp_blocks(d_in, hidden);
        let theta0 = crate::solver::mlp::mlp_theta0(d_in, hidden, seed);
        let theta_star = crate::solver::mlp::central_mlp_optimum(&shards, mu0, hidden, &theta0);
        let f_star = crate::solver::mlp::mlp_global_objective(&shards, mu0, hidden, &theta_star);
        Ok(Problem {
            task: ds.task,
            dataset_name: ds.name.clone(),
            shards,
            rho,
            mu0,
            d: blocks.d(),
            theta_star,
            f_star,
            model,
            blocks,
            theta0,
        })
    }

    /// Convenience: linear problem with default seed/regularization.
    pub fn linear(ds: Dataset, topo: &Topology, rho: f64) -> Problem {
        assert_eq!(ds.task, Task::Linear);
        Problem::new(&ds, topo, rho, 0.0, 17)
    }

    /// Convenience: logistic problem.
    pub fn logistic(ds: Dataset, topo: &Topology, rho: f64, mu0: f64) -> Problem {
        assert_eq!(ds.task, Task::Logistic);
        Problem::new(&ds, topo, rho, mu0, 17)
    }

    /// Global objective at per-worker models: `sum_n f_n(theta_n)`.
    pub fn objective_at(&self, thetas: &[Vec<f64>]) -> f64 {
        assert_eq!(thetas.len(), self.shards.len());
        let mut total = 0.0;
        for (sh, th) in self.shards.iter().zip(thetas) {
            total += match self.model {
                ModelSpec::Glm => {
                    global_objective(std::slice::from_ref(sh), self.task, self.mu0, th)
                }
                ModelSpec::Mlp { hidden } => crate::solver::mlp::mlp_global_objective(
                    std::slice::from_ref(sh),
                    self.mu0,
                    hidden,
                    th,
                ),
            };
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn spec_constructors_label_correctly() {
        assert_eq!(AlgSpec::ggadmm().name, "GGADMM");
        assert_eq!(AlgSpec::cq_ggadmm(0.5, 0.8, 0.99, 2).name, "CQ-GGADMM");
        assert_eq!(AlgSpec::c_admm(0.5, 0.8).schedule, Schedule::Jacobian);
        assert!(AlgSpec::cq_ggadmm(0.5, 0.8, 0.99, 2).validate().is_ok());
        assert!(AlgSpec::c_ggadmm(-1.0, 0.8).validate().is_err());
    }

    #[test]
    fn concurrent_fractions() {
        assert_eq!(AlgSpec::ggadmm().concurrent_fraction(), 0.5);
        assert_eq!(AlgSpec::c_admm(0.1, 0.9).concurrent_fraction(), 1.0);
    }

    #[test]
    fn problem_reference_optimum_sane() {
        let ds = synthetic::linear_dataset(120, 6, 3);
        let topo = Topology::random_bipartite(6, 0.5, 1);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 5);
        assert_eq!(p.shards.len(), 6);
        assert_eq!(p.d, 6);
        // objective at the optimum equals f_star when all workers agree
        let thetas = vec![p.theta_star.clone(); 6];
        let f = p.objective_at(&thetas);
        assert!((f - p.f_star).abs() < 1e-9);
        // and is higher elsewhere
        let zeros = vec![vec![0.0; 6]; 6];
        assert!(p.objective_at(&zeros) > p.f_star);
    }
}
