//! The sharded execution unit: one [`ShardWorker`] per simulated worker,
//! **not** one OS thread per worker.
//!
//! A `ShardWorker` is the wire adapter over the shared
//! [`crate::protocol::WorkerCore`] state machine: it runs the core's
//! phase on whichever executor thread claims it, encodes committed
//! payloads into a persistent per-worker buffer, and decodes incoming
//! broadcasts straight into the core's neighbor slot.  The leader
//! ([`super::Coordinator`]) schedules M of these over a fixed-size
//! [`crate::parallel::WorkerPool`] of K threads (K ≪ M), which is what
//! lifts the scale ceiling from ~hundreds of OS threads to thousands of
//! simulated workers.

use super::message;
use crate::param::Blocks;
use crate::protocol::{PayloadRef, WorkerCore};

/// One simulated worker, scheduled onto the executor pool by the leader.
pub struct ShardWorker {
    pub core: WorkerCore,
    /// Persistent wire buffer for this worker's outbound payloads
    /// (cleared per commit, capacity retained — the broadcast path
    /// allocates nothing after warm-up).
    wire: Vec<u8>,
    /// The core's block layout, cloned once so decode can address spans
    /// while the core's slot is mutably borrowed.
    layout: Blocks,
}

impl ShardWorker {
    pub fn new(mut core: WorkerCore) -> ShardWorker {
        // the wire encoder needs the candidate's integer codes; the
        // shared core skips collecting them unless a driver opts in
        core.enable_code_collection();
        let layout = core.block_layout();
        ShardWorker { core, wire: Vec::new(), layout }
    }

    /// One phase turn, run on an executor thread: primal update, then
    /// build + gate the broadcast candidate for censoring iteration
    /// `k_plus_1` (`force` bypasses the censor gate — the leader sets it
    /// from its staleness bookkeeping before dispatch).  The transmit
    /// decision is left pending in the core for the leader to resolve
    /// (the erasure draw must happen in deterministic worker order on
    /// the leader).
    pub fn phase(&mut self, k_plus_1: u64, force: bool) {
        self.core.primal_update();
        self.core.prepare_broadcast_gated(k_plus_1, force);
    }

    /// Leader-side: the medium delivered this worker's broadcast — commit
    /// it and encode the wire bytes into the persistent buffer.  Flat
    /// cores keep the original single-tag frame byte-for-byte;
    /// multi-block cores frame each transmitting block separately
    /// ([`message::TAG_BLOCKS`]) so a censored block ships nothing.
    pub fn commit_and_encode(&mut self) {
        self.core.commit_pending();
        self.wire.clear();
        let nb = self.core.block_count();
        if nb > 1 {
            let mask = self.core.broadcast_mask().expect("multi-block commit has a mask");
            message::begin_blocks_into(nb, &mut self.wire);
            for b in 0..nb {
                if !mask[b] {
                    message::encode_absent_block_into(&mut self.wire);
                    continue;
                }
                let at = message::begin_block_into(&mut self.wire);
                match self.core.committed_block_payload(b) {
                    PayloadRef::Full(span) => message::encode_full_into(span, &mut self.wire),
                    PayloadRef::Quantized { radius, bits, codes } => {
                        message::encode_quantized_into(radius, bits, codes, &mut self.wire)
                    }
                }
                message::finish_block_into(&mut self.wire, at);
            }
            return;
        }
        match self.core.committed_payload() {
            PayloadRef::Full(theta) => message::encode_full_into(theta, &mut self.wire),
            PayloadRef::Quantized { radius, bits, codes } => {
                message::encode_quantized_into(radius, bits, codes, &mut self.wire)
            }
        }
    }

    /// Take the wire buffer out (the leader fans the bytes out to the
    /// neighbors' `deliver` while this worker stays borrow-free); return
    /// it via [`ShardWorker::put_wire`].
    pub fn take_wire(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.wire)
    }

    pub fn put_wire(&mut self, wire: Vec<u8>) {
        self.wire = wire;
    }

    /// Receive a neighbor's broadcast: decode straight into the core's
    /// stored slot for `from` (full precision overwrites; quantized
    /// reconstructs in place against the shared reference; multi-block
    /// frames land span-by-span, absent blocks keeping the stale span —
    /// the wire twin of the in-process engine's masked delivery).
    pub fn deliver(&mut self, from: usize, bytes: &[u8]) {
        let layout = &self.layout;
        self.core.deliver_with(from, |slot| {
            let ok = if layout.count() > 1 {
                message::decode_blocks_into_slot(bytes, layout, slot)
            } else {
                message::decode_into_slot(bytes, slot)
            };
            assert!(ok, "malformed broadcast from worker {from}");
        });
    }
}

// The shared churn helpers (`crate::protocol::apply_churn_event`,
// `replay_churn_structure`) operate on any fleet that can expose its
// `WorkerCore`s — the simulator's `Vec<WorkerCore>` or this engine's
// `Vec<ShardWorker>`.
impl AsRef<WorkerCore> for ShardWorker {
    fn as_ref(&self) -> &WorkerCore {
        &self.core
    }
}

impl AsMut<WorkerCore> for ShardWorker {
    fn as_mut(&mut self) -> &mut WorkerCore {
        &mut self.core
    }
}
