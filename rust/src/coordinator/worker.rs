//! Worker actor: one OS thread per worker, owning its shard, solver and
//! per-link state, driven by leader [`Command`]s.

use super::message::{
    decode_full, decode_quantized, encode_full, encode_quantized, Command, Event, Payload,
};
use crate::censor::{gate, CensorConfig, Gate};
use crate::quant::Quantizer;
use crate::solver::SubproblemSolver;
use std::collections::BTreeMap;
use std::sync::mpsc::{Receiver, Sender};

/// Everything a worker thread needs at spawn time.
pub struct WorkerSetup {
    pub id: usize,
    pub d: usize,
    pub rho: f64,
    pub neighbors: Vec<usize>,
    pub solver: Box<dyn SubproblemSolver>,
    pub censor: Option<CensorConfig>,
    pub quantizer: Option<Quantizer>,
    /// Jacobian (DCADMM) schedules anchor the update on the worker's own
    /// last broadcast: `nbr_sum += d_i * hat_self` (the solver then carries
    /// the doubled penalty; see `algs::run::build_solvers`).
    pub jacobian_anchor: bool,
}

/// The worker event loop.  Runs until [`Command::Stop`] or the leader
/// channel closes.
pub fn worker_main(setup: WorkerSetup, rx: Receiver<Command>, tx: Sender<Event>) {
    let WorkerSetup {
        id,
        d,
        rho,
        neighbors,
        mut solver,
        censor,
        mut quantizer,
        jacobian_anchor,
    } = setup;
    let mut theta = vec![0.0; d];
    let mut alpha = vec![0.0; d];
    // what my neighbors believe about me (theta-hat_n)
    let mut hat_self = vec![0.0; d];
    // what I believe about my neighbors (init 0, Algorithm 2 line 2)
    let mut hat_nbrs: BTreeMap<usize, Vec<f64>> =
        neighbors.iter().map(|&m| (m, vec![0.0; d])).collect();
    let mut transmitted_once = false;
    // persistent per-phase scratch (zeroed each phase — same arithmetic
    // as a freshly allocated buffer, without the per-phase allocation)
    let mut nbr_sum = vec![0.0; d];

    while let Ok(cmd) = rx.recv() {
        match cmd {
            Command::Phase { k } => {
                // primal update (eq. 21/22)
                nbr_sum.iter_mut().for_each(|v| *v = 0.0);
                for v in hat_nbrs.values() {
                    crate::util::axpy(&mut nbr_sum, 1.0, v);
                }
                if jacobian_anchor {
                    crate::util::axpy(&mut nbr_sum, neighbors.len() as f64, &hat_self);
                }
                solver.update_into(&alpha, &nbr_sum, &mut theta);

                // transmission pipeline: quantize -> censor -> broadcast
                let (candidate_hat, payload) = match &mut quantizer {
                    Some(q) => {
                        let (msg, recon) = q.quantize(&theta, &hat_self);
                        (recon, encode_quantized(&msg))
                    }
                    None => (theta.clone(), encode_full(&theta)),
                };
                let decision = match (&censor, transmitted_once) {
                    (_, false) => Gate::Transmit,
                    (None, _) => Gate::Transmit,
                    (Some(c), true) => gate(c, k, &hat_self, &candidate_hat),
                };
                if decision == Gate::Transmit {
                    hat_self = candidate_hat;
                    transmitted_once = true;
                    let _ = tx.send(Event::Broadcast { from: id, payload });
                }
                let _ = tx.send(Event::PhaseDone { worker: id });
            }
            Command::Deliver { from, payload } => {
                let stored = hat_nbrs
                    .get_mut(&from)
                    .unwrap_or_else(|| panic!("worker {id}: message from non-neighbor {from}"));
                match payload {
                    Payload::Full(bytes) => {
                        *stored = decode_full(&bytes, d).expect("bad full payload");
                    }
                    Payload::Quantized(bytes) => {
                        let msg = decode_quantized(&bytes, d).expect("bad quantized payload");
                        // reconstruct in place against the last value I
                        // hold for the sender — exactly the sender's own
                        // reference — without allocating per link
                        msg.reconstruct_into(stored);
                    }
                }
            }
            Command::DualUpdate => {
                // eq. (23): alpha += rho * sum_m (hat_self - hat_m)
                for v in hat_nbrs.values() {
                    for j in 0..d {
                        alpha[j] += rho * (hat_self[j] - v[j]);
                    }
                }
                let _ = tx.send(Event::DualDone { worker: id });
            }
            Command::Report => {
                let loss = solver.loss(&theta);
                let _ = tx.send(Event::Loss { worker: id, loss, theta: theta.clone() });
            }
            Command::Stop => break,
        }
    }
}
