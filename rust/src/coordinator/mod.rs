//! The distributed coordinator: the paper's decentralized protocol run as
//! a real multi-threaded system with explicit message passing.
//!
//! One OS thread per worker ([`worker`]); the leader thread plays the
//! wireless medium and the experiment driver: it triggers head/tail
//! phases, forwards each broadcast to the sender's neighbors (paying the
//! §7 energy model for the *encoded byte* payload that actually crossed
//! the channel), synchronizes the dual update, and collects loss reports.
//!
//! The per-worker state machine is identical to the sequential simulator
//! in [`crate::algs`]; `tests/coordinator_equivalence.rs` locks the two
//! together trajectory-for-trajectory.

pub mod message;
pub mod worker;

use crate::algs::{AlgSpec, Problem, Schedule};
use crate::comm::{CommLog, EnergyModel, Transmission};
use crate::graph::Topology;
use crate::metrics::{Trace, TracePoint};
use crate::solver::{LinearSolver, LogisticSolver, SubproblemSolver};
use crate::util::rng::Pcg64;
use message::{Command, Event};
use std::sync::mpsc::{channel, Receiver, Sender};

/// Options for a coordinated run.
#[derive(Clone, Debug)]
pub struct CoordinatorOptions {
    pub seed: u64,
    pub record_every: u64,
    pub energy: crate::comm::EnergyParams,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            seed: 7,
            record_every: 1,
            energy: crate::comm::EnergyParams::default(),
        }
    }
}

/// Leader handle over the worker fleet.
pub struct Coordinator {
    topo: Topology,
    spec: AlgSpec,
    problem: Problem,
    opts: CoordinatorOptions,
    cmd_tx: Vec<Sender<Command>>,
    event_rx: Receiver<Event>,
    handles: Vec<std::thread::JoinHandle<()>>,
    comm: CommLog,
    energy: EnergyModel,
    trace: Trace,
    iter: u64,
}

impl Coordinator {
    /// Spawn the worker fleet (native solvers).
    pub fn spawn(
        problem: Problem,
        topo: Topology,
        spec: AlgSpec,
        opts: CoordinatorOptions,
    ) -> Coordinator {
        spec.validate().expect("invalid AlgSpec");
        let n = topo.n();
        let d = problem.d;
        // fork quantizer RNG streams exactly like the simulator so the two
        // implementations stay trajectory-equivalent
        let mut rng = Pcg64::new(opts.seed ^ 0xA16_0001);
        let (event_tx, event_rx) = channel::<Event>();
        let mut cmd_tx = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        // build all solvers before spawning the actors: the per-worker
        // Gram + Cholesky setup is the expensive part of spawn, and it
        // fans out over the same pool primitive the simulator uses
        // (solvers share shards through the Arc — no X/y copies)
        let solvers = crate::parallel::map_indexed(
            n,
            crate::parallel::default_threads().min(n),
            |i| -> Box<dyn SubproblemSolver> {
                // Jacobian schedules carry the DCADMM doubled penalty (see
                // algs::run::build_solvers)
                let degree = match spec.schedule {
                    Schedule::Alternating => topo.degree(i),
                    Schedule::Jacobian => 2 * topo.degree(i),
                };
                match problem.task {
                    crate::config::Task::Linear => Box::new(LinearSolver::from_shard(
                        std::sync::Arc::clone(&problem.shards[i]),
                        problem.rho,
                        degree,
                    )),
                    crate::config::Task::Logistic => Box::new(LogisticSolver::from_shard(
                        std::sync::Arc::clone(&problem.shards[i]),
                        problem.mu0,
                        problem.rho,
                        degree,
                    )),
                }
            },
        );
        for (i, solver) in solvers.into_iter().enumerate() {
            let setup = worker::WorkerSetup {
                id: i,
                d,
                rho: problem.rho,
                neighbors: topo.neighbors(i).to_vec(),
                solver,
                censor: spec.censor,
                quantizer: spec
                    .quant
                    .as_ref()
                    .map(|q| crate::quant::Quantizer::new(*q, rng.fork(i as u64))),
                jacobian_anchor: spec.schedule == Schedule::Jacobian,
            };
            let (tx, rx) = channel::<Command>();
            let etx = event_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("worker-{i}"))
                    .spawn(move || worker::worker_main(setup, rx, etx))
                    .expect("spawn worker"),
            );
            cmd_tx.push(tx);
        }
        let energy = EnergyModel::new(opts.energy, n, spec.concurrent_fraction());
        let trace = Trace::new(&spec.name, &problem.dataset_name);
        Coordinator {
            topo,
            spec,
            problem,
            opts,
            cmd_tx,
            event_rx,
            handles,
            comm: CommLog::default(),
            energy,
            trace,
            iter: 0,
        }
    }

    /// Run one phase over `group`: trigger updates, collect broadcasts,
    /// forward them, wait for completion.
    fn run_phase(&mut self, group: &[usize], k: u64) {
        for &i in group {
            self.cmd_tx[i].send(Command::Phase { k }).expect("send phase");
        }
        let mut done = 0usize;
        let mut broadcasts: Vec<(usize, message::Payload)> = Vec::new();
        while done < group.len() {
            match self.event_rx.recv().expect("event channel closed") {
                Event::Broadcast { from, payload } => broadcasts.push((from, payload)),
                Event::PhaseDone { .. } => done += 1,
                other => panic!("unexpected event during phase: {other:?}"),
            }
        }
        // the medium: deliver + charge
        let d = self.problem.d;
        for (from, payload) in broadcasts {
            let bits = payload.bits(d);
            let dist = self.topo.max_neighbor_distance(from);
            self.comm.record(Transmission {
                worker: from,
                iteration: self.iter,
                payload_bits: bits,
                distance_m: dist,
                energy_j: self.energy.energy_j(bits, dist),
            });
            for &m in self.topo.neighbors(from) {
                self.cmd_tx[m]
                    .send(Command::Deliver { from, payload: payload.clone() })
                    .expect("deliver");
            }
        }
    }

    /// Execute one full iteration.
    pub fn step(&mut self) {
        let k = self.iter + 1;
        match self.spec.schedule {
            Schedule::Alternating => {
                let heads = self.topo.heads();
                let tails = self.topo.tails();
                self.run_phase(&heads, k);
                self.run_phase(&tails, k);
            }
            Schedule::Jacobian => {
                let all: Vec<usize> = (0..self.topo.n()).collect();
                self.run_phase(&all, k);
            }
        }
        for tx in &self.cmd_tx {
            tx.send(Command::DualUpdate).expect("dual");
        }
        let mut done = 0;
        while done < self.topo.n() {
            if let Event::DualDone { .. } = self.event_rx.recv().expect("event") {
                done += 1;
            }
        }
        self.iter += 1;
        if self.iter % self.opts.record_every == 0 {
            self.record();
        }
    }

    fn record(&mut self) {
        for tx in &self.cmd_tx {
            tx.send(Command::Report).expect("report");
        }
        let n = self.topo.n();
        let mut losses = vec![0.0; n];
        let mut thetas: Vec<Vec<f64>> = vec![Vec::new(); n];
        let mut got = 0;
        while got < n {
            if let Event::Loss { worker, loss, theta } = self.event_rx.recv().expect("event") {
                losses[worker] = loss;
                thetas[worker] = theta;
                got += 1;
            }
        }
        let obj: f64 = losses.iter().sum();
        let mut consensus: f64 = 0.0;
        for &(h, t) in self.topo.edges() {
            let diff: f64 = thetas[h]
                .iter()
                .zip(&thetas[t])
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            consensus = consensus.max(diff);
        }
        self.trace.push(TracePoint {
            iteration: self.iter,
            loss_gap: (obj - self.problem.f_star).abs(),
            consensus_gap: consensus,
            cum_rounds: self.comm.rounds(),
            cum_bits: self.comm.total_bits,
            cum_energy_j: self.comm.total_energy_j,
        });
    }

    /// Run `iters` iterations, shut the fleet down, return the trace.
    pub fn run(mut self, iters: u64) -> Trace {
        for _ in 0..iters {
            self.step();
        }
        for tx in &self.cmd_tx {
            let _ = tx.send(Command::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        std::mem::replace(&mut self.trace, Trace::new("", ""))
    }

    /// Trace so far (for incremental inspection).
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Communication log so far.
    pub fn comm(&self) -> &CommLog {
        &self.comm
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Command::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn coordinated_ggadmm_converges() {
        let topo = Topology::random_bipartite(6, 0.5, 1);
        let ds = synthetic::linear_dataset(72, 4, 1);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 1);
        let coord = Coordinator::spawn(p, topo, AlgSpec::ggadmm(), CoordinatorOptions::default());
        let trace = coord.run(200);
        assert!(trace.last_gap() < 1e-6, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn coordinated_cq_ggadmm_converges() {
        let topo = Topology::random_bipartite(6, 0.5, 2);
        let ds = synthetic::linear_dataset(72, 4, 2);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 2);
        let coord = Coordinator::spawn(
            p,
            topo,
            AlgSpec::cq_ggadmm(0.2, 0.9, 0.99, 2),
            CoordinatorOptions::default(),
        );
        let trace = coord.run(200);
        assert!(trace.last_gap() < 1e-4, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn message_accounting_matches_schedule() {
        // GGADMM without censoring: every worker broadcasts once per
        // iteration => rounds == n * iters
        let topo = Topology::random_bipartite(8, 0.4, 3);
        let ds = synthetic::linear_dataset(80, 4, 3);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 3);
        let mut coord =
            Coordinator::spawn(p, topo, AlgSpec::ggadmm(), CoordinatorOptions::default());
        for _ in 0..10 {
            coord.step();
        }
        assert_eq!(coord.comm().rounds(), 80);
    }
}
