//! The distributed coordinator: the paper's decentralized protocol run as
//! a real system engine — M simulated workers **sharded over a
//! fixed-size executor pool** of K threads (K ≪ M), with an event-driven
//! leader loop and explicit wire-encoded broadcasts.
//!
//! Architecture (one iteration):
//!
//! 1. **Phase dispatch** — the leader fans the phase group (heads, tails,
//!    or everyone under Jacobian) out over the
//!    [`crate::parallel::WorkerPool`]: executor threads claim
//!    [`worker::ShardWorker`]s dynamically and run each one's primal
//!    solve + quantize→censor candidate build (all per-worker RNG lives
//!    in per-worker streams, so scheduling cannot perturb results).
//! 2. **Broadcast resolution** — back on the leader, pending broadcasts
//!    are resolved in ascending worker order (the determinism contract
//!    for the erasure stream) through the shared [`crate::comm::Medium`]:
//!    energy/bits are charged, the [`crate::comm::LinkModel`] decides the
//!    fate, delivered payloads are wire-encoded once and decoded straight
//!    into each neighbor's core slot.
//! 3. **Dual update** — fanned out over the pool again.
//!
//! The per-worker state machine is the shared
//! [`crate::protocol::WorkerCore`] — the *same* code the sequential
//! simulator drives — so the two engines are locked together
//! **bit-for-bit** by `tests/coordinator_equivalence.rs`, across the full
//! algorithm family and under erasure injection.
//!
//! Scale: the seed implementation spawned one OS thread per worker and
//! topped out around the OS thread ceiling; the sharded executor runs
//! N = 1024+ simulated workers on a laptop-sized pool (see the
//! `coordinator_scale` example, exercised in CI).  Shutdown is
//! deterministic: dropping a [`Coordinator`] mid-run drops the pool,
//! which joins its helper threads (no detached threads or leaked
//! channels), and a panic inside a shard solve is re-raised on the
//! leader after the pool barrier — the pool (and the coordinator)
//! survive, exactly like [`crate::parallel::WorkerPool`].
//!
//! This engine has a networked twin: [`crate::net`] runs the same
//! leader loop over real TCP connections (worker cores hosted in
//! remote processes, `serve`/`worker` subcommands), locked
//! bit-for-bit against this one by `tests/net_equivalence.rs`.

pub mod message;
pub mod worker;

use crate::algs::{AlgSpec, Problem, Schedule};
use crate::comm::{CommLog, EnergyModel, EnergyParams, LinkKind, Medium, SlotOutcome};
use crate::config::ExecutionConfig;
use crate::graph::{ChurnEvent, ChurnKind, Topology};
use crate::io::checkpoint::{MediumState, RunState};
use crate::io::{EventRecorder, EventSink, PersistableEngine};
use crate::metrics::{Trace, TracePoint};
use crate::parallel::{resolve_threads, SyncPtr, WorkerPool};
use crate::protocol::{apply_churn_event, build_cores, replay_churn_structure, ProtocolConfig};
use crate::solver::Backend;
use worker::ShardWorker;

/// Legacy options for a coordinated run — a thin shim over
/// [`ExecutionConfig`]; new code should construct an
/// [`ExecutionConfig`] directly ([`Coordinator::spawn`] accepts
/// `impl Into<ExecutionConfig>`).
#[derive(Clone, Debug)]
pub struct CoordinatorOptions {
    pub seed: u64,
    pub record_every: u64,
    pub energy: EnergyParams,
    /// Executor threads the workers are sharded over (0 = all cores).
    /// The leader participates in every dispatch, so `threads` is the
    /// total parallelism — independent of the worker count.
    pub threads: usize,
    /// Broadcast-erasure probability; shorthand for
    /// `link = Some(LinkKind::Erasure { p })` (same stream discipline as
    /// [`crate::algs::RunOptions::drop_prob`], so trajectories match the
    /// simulator bit-for-bit).
    pub drop_prob: f64,
    /// Explicit link model; `None` resolves from `drop_prob`.
    pub link: Option<LinkKind>,
    /// Censoring-aware incremental cache maintenance (diagnostics knob;
    /// `false` forces from-scratch rebuilds like the simulator's).
    pub incremental: bool,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            seed: 7,
            record_every: 1,
            energy: EnergyParams::default(),
            threads: 0,
            drop_prob: 0.0,
            link: None,
            incremental: true,
        }
    }
}

impl From<CoordinatorOptions> for ExecutionConfig {
    fn from(o: CoordinatorOptions) -> ExecutionConfig {
        ExecutionConfig {
            backend: Backend::Native,
            artifacts_dir: None,
            threads: o.threads,
            sweep_threads: 1,
            seed: o.seed,
            record_every: o.record_every,
            drop_prob: o.drop_prob,
            link: o.link,
            energy: o.energy,
            incremental: o.incremental,
            churn: None,
            staleness_bound: None,
        }
    }
}

/// Leader handle over the sharded worker fleet.
pub struct Coordinator {
    topo: Topology,
    problem: Problem,
    opts: ExecutionConfig,
    shards: Vec<ShardWorker>,
    pool: WorkerPool,
    medium: Medium,
    trace: Trace,
    iter: u64,
    /// cached phase groups (constant over a run; see `algs::Run`)
    phase_groups: Vec<Vec<usize>>,
    /// `phase_groups` filtered to active, degree >= 1 workers (equal to
    /// `phase_groups` on a static graph)
    live_groups: Vec<Vec<usize>>,
    /// per-worker membership under churn (leader-owned; all `true` on a
    /// static graph)
    active: Vec<bool>,
    /// consecutive off-the-air rounds per worker (bounded-staleness
    /// policy; all zero without one)
    stale: Vec<u64>,
    /// per-(worker, block) ages under bounded staleness, flattened
    /// row-major (multi-block models only; see [`crate::algs::Run`]'s
    /// twin — identical policy, so the engines stay locked)
    block_stale: Vec<u64>,
    /// scratch: per-block candidate bits masked to transmitting blocks
    block_bits_scratch: Vec<u64>,
    /// scratch: committed-block mask of the sender being fanned out
    mask_scratch: Vec<bool>,
    /// per-worker force-refresh flags, computed leader-side before each
    /// phase dispatch (the executors must not read the mutable staleness
    /// bookkeeping)
    force_scratch: Vec<bool>,
    /// churn events applied so far (restore-time sanity)
    churn_applied: usize,
    /// persistent per-worker loss scratch for `record`
    losses: Vec<f64>,
    /// optional streaming event log (io::events); emits at the same
    /// cadence as the trace
    recorder: Option<EventRecorder>,
}

impl Coordinator {
    /// Build the worker fleet (native solvers) and the executor pool.
    /// The expensive per-worker Gram + Cholesky setup fans out over the
    /// same pool that later runs the phases — built once, reused for
    /// every dispatch.
    pub fn spawn(
        problem: Problem,
        topo: Topology,
        spec: AlgSpec,
        opts: impl Into<ExecutionConfig>,
    ) -> Coordinator {
        let opts: ExecutionConfig = opts.into();
        spec.validate().expect("invalid AlgSpec");
        opts.validate().expect("invalid ExecutionConfig");
        assert_eq!(
            opts.backend,
            Backend::Native,
            "the coordinator shards native solvers only"
        );
        let n = topo.n();
        let mut pool = WorkerPool::new(resolve_threads(opts.threads));
        let cfg = ProtocolConfig {
            backend: Backend::Native,
            artifacts_dir: None,
            incremental: opts.incremental,
            seed: opts.seed,
        };
        // the shared constructor forks quantizer RNG streams exactly like
        // the simulator and hands back the root stream for the link model
        // — the two engines cannot drift
        let (cores, rng) = build_cores(&problem, &topo, &spec, &cfg, Some(&mut pool));
        let shards: Vec<ShardWorker> = cores.into_iter().map(ShardWorker::new).collect();
        let energy = EnergyModel::new(opts.energy, n, spec.concurrent_fraction());
        let medium = Medium::new(
            energy,
            opts.energy.slot_s,
            LinkKind::resolve(opts.link, opts.drop_prob).build(rng, n),
        );
        let trace = Trace::new(&spec.name, &problem.dataset_name);
        if let Some(w) = opts.churn.as_ref().and_then(|c| c.max_worker()) {
            assert!(w < n, "churn schedule names worker {w}, but the topology has {n} workers");
        }
        let phase_groups = match spec.schedule {
            Schedule::Alternating => vec![topo.heads(), topo.tails()],
            Schedule::Jacobian => vec![(0..n).collect()],
        };
        let nblocks = problem.blocks.count();
        Coordinator {
            losses: vec![0.0; n],
            live_groups: phase_groups.clone(),
            phase_groups,
            active: vec![true; n],
            stale: vec![0; n],
            block_stale: vec![0; if nblocks > 1 { n * nblocks } else { 0 }],
            block_bits_scratch: Vec::with_capacity(nblocks),
            mask_scratch: Vec::with_capacity(nblocks),
            force_scratch: vec![false; n],
            churn_applied: 0,
            shards,
            pool,
            medium,
            topo,
            problem,
            opts,
            trace,
            iter: 0,
            recorder: None,
        }
    }

    /// Attach a fresh streaming event log (see [`crate::algs::Run::start_event_log`]).
    pub fn start_event_log(&mut self, sink: Box<dyn EventSink>) {
        let mut rec = EventRecorder::new(sink, self.topo.n());
        rec.rebase(self.iter);
        rec.run_start(
            &self.trace.algorithm,
            &self.problem.dataset_name,
            self.topo.n(),
            self.problem.d,
            self.opts.seed,
        );
        self.recorder = Some(rec);
    }

    /// Attach an event log continuing an earlier one (resume): no
    /// `run_start` line; interval accounting restarts here.
    pub fn resume_event_log(&mut self, sink: Box<dyn EventSink>) {
        let mut rec = EventRecorder::new(sink, self.topo.n());
        rec.rebase(self.iter);
        self.recorder = Some(rec);
    }

    /// Total executor threads (pool helpers + the leader).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Bottleneck broadcast distance of worker `i` over its **active**
    /// neighbors (see [`crate::algs::Run`]'s twin — same fold, so the
    /// engines agree bit-for-bit).
    fn active_neighbor_distance(&self, i: usize) -> f64 {
        self.topo
            .neighbors(i)
            .iter()
            .filter(|&&m| self.active[m])
            .map(|&m| self.topo.distance(i, m))
            .fold(0.0, f64::max)
    }

    /// Run one phase over `group`: shard the primal + candidate work over
    /// the executor, then resolve the broadcasts event-by-event in
    /// deterministic worker order.
    fn run_phase(&mut self, group: &[usize], k_plus_1: u64) {
        let tau = self.opts.staleness_bound;
        // leader-side: derive force-refresh flags from the staleness
        // bookkeeping before dispatch (the executors read them immutably).
        // Multi-block: any one block past the bound forces a full refresh.
        for &i in group {
            let nb = self.shards[i].core.block_count();
            self.force_scratch[i] = match tau {
                None => false,
                Some(t) if nb > 1 => {
                    self.block_stale[i * nb..(i + 1) * nb].iter().any(|&a| a >= t)
                }
                Some(t) => self.stale[i] >= t,
            };
        }
        // 1. parallel: primal solve + quantize/censor candidate.  Raw
        // base pointer for disjoint per-index &mut access (group ids are
        // strictly increasing, so no two jobs alias; the pool barrier
        // ends every access before for_each returns).
        debug_assert!(group.windows(2).all(|w| w[0] < w[1]), "group must be increasing");
        {
            let shards = SyncPtr(self.shards.as_mut_ptr());
            let force = &self.force_scratch;
            self.pool.for_each(group.len(), |j| {
                // SAFETY: distinct indices => disjoint elements; see above
                let s = unsafe { &mut *shards.0.add(group[j]) };
                s.phase(k_plus_1, force[group[j]]);
            });
        }
        // 2. sequential resolution on the leader: charge the medium, let
        // the link decide, deliver wire bytes to the neighbors' cores
        for &i in group {
            if let Some(rec) = &mut self.recorder {
                rec.note_attempt();
            }
            let force = self.force_scratch[i];
            let nb = self.shards[i].core.block_count();
            let multi = nb > 1;
            let Some(bits) = self.shards[i].core.pending_bits() else {
                if tau.is_some() {
                    self.stale[i] += 1;
                    if multi {
                        for a in &mut self.block_stale[i * nb..(i + 1) * nb] {
                            *a += 1;
                        }
                    }
                }
                continue;
            };
            if multi {
                // per-block ledger: bits are spent whether or not the
                // broadcast lands (identical to the in-process engine)
                let mask = self.shards[i].core.broadcast_mask().expect("multi-block candidate");
                let per =
                    self.shards[i].core.candidate_block_bits().expect("multi-block candidate");
                self.block_bits_scratch.clear();
                self.block_bits_scratch
                    .extend(per.iter().zip(mask).map(|(&b, &on)| if on { b } else { 0 }));
                self.medium.record_block_bits(&self.block_bits_scratch);
            }
            let dist = self.active_neighbor_distance(i);
            let landed = match tau {
                None => self.medium.transmit(i, self.iter, bits, dist),
                Some(_) => matches!(
                    self.medium.transmit_bounded(i, self.iter, bits, dist, force),
                    SlotOutcome::Landed
                ),
            };
            if landed {
                self.shards[i].commit_and_encode();
                if multi {
                    let mask = self.shards[i].core.broadcast_mask().expect("multi-block commit");
                    self.mask_scratch.clear();
                    self.mask_scratch.extend_from_slice(mask);
                }
                let wire = self.shards[i].take_wire();
                for &m in self.topo.neighbors(i) {
                    if self.active[m] {
                        self.shards[m].deliver(i, &wire);
                    }
                }
                self.shards[i].put_wire(wire);
                if force {
                    let staleness = self.stale[i];
                    if let Some(rec) = &mut self.recorder {
                        rec.stale_refresh(self.iter, i, staleness);
                    }
                }
                if multi && tau.is_some() {
                    // committed blocks reset; still-censored blocks keep
                    // aging; `stale[i]` mirrors the worst block
                    let ages = &mut self.block_stale[i * nb..(i + 1) * nb];
                    for (a, &on) in ages.iter_mut().zip(&self.mask_scratch) {
                        if on {
                            *a = 0;
                        } else {
                            *a += 1;
                        }
                    }
                    self.stale[i] = ages.iter().copied().max().unwrap_or(0);
                } else {
                    self.stale[i] = 0;
                }
            } else {
                self.shards[i].core.abort_pending();
                if tau.is_some() {
                    self.stale[i] += 1;
                    if multi {
                        for a in &mut self.block_stale[i * nb..(i + 1) * nb] {
                            *a += 1;
                        }
                    }
                }
            }
        }
        self.medium.end_slot();
    }

    /// Apply the churn events scheduled for the start of this iteration
    /// (leader-side; shared transition logic with the simulator) and
    /// rebuild the live phase groups.
    fn apply_churn_events(&mut self) {
        let events: Vec<ChurnEvent> = match &self.opts.churn {
            Some(c) => c.events_at(self.iter).to_vec(),
            None => return,
        };
        if events.is_empty() {
            return;
        }
        for e in &events {
            apply_churn_event(&mut self.shards, &mut self.active, &self.topo, e);
            self.stale[e.worker] = 0;
            let nb = self.shards[e.worker].core.block_count();
            if nb > 1 {
                for a in &mut self.block_stale[e.worker * nb..(e.worker + 1) * nb] {
                    *a = 0;
                }
            }
            self.churn_applied += 1;
            if let Some(rec) = &mut self.recorder {
                match e.kind {
                    ChurnKind::Leave => rec.worker_leave(self.iter, e.worker),
                    ChurnKind::Join => rec.worker_join(self.iter, e.worker),
                }
            }
        }
        self.refresh_live_groups();
    }

    /// Rebuild `live_groups` from the membership flags (see
    /// [`crate::algs::Run`]'s twin).
    fn refresh_live_groups(&mut self) {
        self.live_groups = self
            .phase_groups
            .iter()
            .map(|g| {
                g.iter()
                    .copied()
                    .filter(|&i| self.active[i] && !self.shards[i].core.neighbors().is_empty())
                    .collect()
            })
            .collect();
    }

    /// Execute one full iteration.
    pub fn step(&mut self) {
        self.apply_churn_events();
        let k_plus_1 = self.iter + 1;
        let groups = std::mem::take(&mut self.live_groups);
        for group in &groups {
            self.run_phase(group, k_plus_1);
        }
        self.live_groups = groups;
        // dual update, sharded over the executor (disjoint per-worker;
        // detached and stranded degree-0 workers stay frozen)
        {
            let shards = SyncPtr(self.shards.as_mut_ptr());
            let active = &self.active;
            self.pool.for_each(self.shards.len(), |i| {
                // SAFETY: each index claimed by exactly one job
                let s = unsafe { &mut *shards.0.add(i) };
                if active[i] && !s.core.neighbors().is_empty() {
                    s.core.dual_update();
                }
            });
        }
        self.iter += 1;
        if self.iter % self.opts.record_every == 0 {
            self.record();
        }
    }

    fn record(&mut self) {
        // per-worker losses, sharded (loss is O(s d) per worker); summed
        // in worker order on the leader — identical arithmetic to the
        // simulator's record
        {
            let shards = SyncPtr(self.shards.as_mut_ptr());
            let losses = SyncPtr(self.losses.as_mut_ptr());
            self.pool.for_each(self.shards.len(), |i| {
                // SAFETY: disjoint reads of shard i, disjoint write of
                // slot i; the barrier orders them before the sum below
                let s = unsafe { &*shards.0.add(i) };
                unsafe { *losses.0.add(i) = s.core.loss() };
            });
        }
        let obj: f64 = self.losses.iter().sum();
        let mut consensus: f64 = 0.0;
        // consensus over live edges only (matches the simulator)
        for &(h, t) in self.topo.edges() {
            if !(self.active[h] && self.active[t]) {
                continue;
            }
            let diff: f64 = self.shards[h]
                .core
                .theta()
                .iter()
                .zip(self.shards[t].core.theta())
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            consensus = consensus.max(diff);
        }
        let log = self.medium.log();
        let point = TracePoint {
            iteration: self.iter,
            loss_gap: (obj - self.problem.f_star).abs(),
            consensus_gap: consensus,
            cum_rounds: log.rounds(),
            cum_bits: log.total_bits,
            cum_energy_j: log.total_energy_j,
        };
        self.trace.push(point);
        if let Some(rec) = &mut self.recorder {
            rec.record(&point, log, self.medium.sim_time_s());
        }
    }

    /// Run `iters` iterations and return the trace.  The executor pool
    /// (and its threads) are joined when `self` drops here — shutdown is
    /// deterministic even if the caller abandons the coordinator earlier.
    pub fn run(mut self, iters: u64) -> Trace {
        for _ in 0..iters {
            self.step();
        }
        std::mem::replace(&mut self.trace, Trace::new("", ""))
    }

    /// Trace so far (for incremental inspection).
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Communication log so far.
    pub fn comm(&self) -> &CommLog {
        self.medium.log()
    }

    /// Simulated on-air wall clock so far (see [`Medium::sim_time_s`]).
    pub fn sim_time_s(&self) -> f64 {
        self.medium.sim_time_s()
    }

    /// Current iteration count.
    pub fn iteration(&self) -> u64 {
        self.iter
    }

    /// Export the full durable state at the current iteration boundary
    /// (same layout as [`crate::algs::Run::snapshot_state`] — a
    /// checkpoint taken by one engine resumes in the other).
    pub fn snapshot_state(&self) -> RunState {
        let log = self.medium.log();
        RunState {
            iteration: self.iter,
            cores: self.shards.iter().map(|s| s.core.export_state()).collect(),
            medium: MediumState {
                rounds: log.rounds(),
                total_bits: log.total_bits,
                total_energy_j: log.total_energy_j,
                sim_time_s: self.medium.sim_time_s(),
                link: self.medium.link_state(),
            },
            trace: self.trace.clone(),
            active: self.active.clone(),
            stale: self.stale.clone(),
            block_stale: self.block_stale.clone(),
            block_bits: log.block_bits.clone(),
        }
    }

    /// Overwrite this engine's state from a checkpoint (same problem /
    /// topology / spec the checkpoint came from; under churn the engine
    /// must be freshly spawned — see [`crate::algs::Run::restore_state`]).
    pub fn restore_state(&mut self, s: &RunState) {
        assert_eq!(
            s.cores.len(),
            self.shards.len(),
            "checkpoint is for a different worker count"
        );
        assert_eq!(s.active.len(), self.shards.len(), "checkpoint dynamic section size");
        assert_eq!(s.stale.len(), self.shards.len(), "checkpoint dynamic section size");
        if let Some(churn) = self.opts.churn.clone() {
            if !churn.is_empty() {
                assert_eq!(
                    self.churn_applied, 0,
                    "restore with churn requires a freshly spawned coordinator"
                );
                replay_churn_structure(
                    &mut self.shards,
                    &mut self.active,
                    &self.topo,
                    &churn,
                    s.iteration,
                );
                self.churn_applied =
                    churn.events().iter().filter(|e| e.at < s.iteration).count();
                self.refresh_live_groups();
            }
        }
        assert_eq!(
            self.active, s.active,
            "checkpoint membership does not match the configured churn schedule"
        );
        self.stale.copy_from_slice(&s.stale);
        if s.block_stale.is_empty() {
            // v2 checkpoints carry no per-block section (flat-model era)
            self.block_stale.iter_mut().for_each(|a| *a = 0);
        } else {
            assert_eq!(
                s.block_stale.len(),
                self.block_stale.len(),
                "checkpoint per-block staleness section size"
            );
            self.block_stale.copy_from_slice(&s.block_stale);
        }
        for (shard, cs) in self.shards.iter_mut().zip(&s.cores) {
            shard.core.import_state(cs);
        }
        self.medium.restore(
            s.medium.rounds,
            s.medium.total_bits,
            s.medium.total_energy_j,
            s.medium.sim_time_s,
            &s.medium.link,
        );
        self.medium.restore_block_bits(s.block_bits.clone());
        self.trace = s.trace.clone();
        self.iter = s.iteration;
        if let Some(rec) = &mut self.recorder {
            rec.rebase(s.iteration);
        }
    }
}

impl PersistableEngine for Coordinator {
    fn step(&mut self) {
        Coordinator::step(self);
    }
    fn iteration(&self) -> u64 {
        Coordinator::iteration(self)
    }
    fn snapshot_state(&self) -> RunState {
        Coordinator::snapshot_state(self)
    }
    fn restore_state(&mut self, state: &RunState) {
        Coordinator::restore_state(self, state);
    }
    fn recorder_mut(&mut self) -> Option<&mut EventRecorder> {
        self.recorder.as_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn coordinated_ggadmm_converges() {
        let topo = Topology::random_bipartite(6, 0.5, 1);
        let ds = synthetic::linear_dataset(72, 4, 1);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 1);
        let coord = Coordinator::spawn(p, topo, AlgSpec::ggadmm(), CoordinatorOptions::default());
        let trace = coord.run(200);
        assert!(trace.last_gap() < 1e-6, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn coordinated_cq_ggadmm_converges() {
        let topo = Topology::random_bipartite(6, 0.5, 2);
        let ds = synthetic::linear_dataset(72, 4, 2);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 2);
        let coord = Coordinator::spawn(
            p,
            topo,
            AlgSpec::cq_ggadmm(0.2, 0.9, 0.99, 2),
            CoordinatorOptions::default(),
        );
        let trace = coord.run(200);
        assert!(trace.last_gap() < 1e-4, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn message_accounting_matches_schedule() {
        // GGADMM without censoring: every worker broadcasts once per
        // iteration => rounds == n * iters
        let topo = Topology::random_bipartite(8, 0.4, 3);
        let ds = synthetic::linear_dataset(80, 4, 3);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 3);
        let mut coord =
            Coordinator::spawn(p, topo, AlgSpec::ggadmm(), CoordinatorOptions::default());
        for _ in 0..10 {
            coord.step();
        }
        assert_eq!(coord.comm().rounds(), 80);
    }

    #[test]
    fn worker_count_exceeds_executor_threads() {
        // the scale contract: N workers on a K-thread pool, K << N
        let topo = Topology::random_bipartite(32, 0.2, 4);
        let ds = synthetic::linear_dataset(320, 4, 4);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 4);
        let coord = Coordinator::spawn(
            p,
            topo,
            AlgSpec::cq_ggadmm(0.2, 0.9, 0.99, 2),
            CoordinatorOptions { threads: 2, ..CoordinatorOptions::default() },
        );
        assert_eq!(coord.threads(), 2);
        let trace = coord.run(120);
        assert!(trace.last_gap() < 1e-4, "gap={:.3e}", trace.last_gap());
    }

    #[test]
    fn dropping_midrun_joins_cleanly() {
        // satellite contract: abandoning a coordinator before run()
        // completes must not detach threads or leak channels — dropping
        // the pool joins its helpers deterministically.  This test hangs
        // (and times out) if shutdown regresses.
        let topo = Topology::random_bipartite(12, 0.4, 5);
        let ds = synthetic::linear_dataset(120, 4, 5);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 5);
        let mut coord = Coordinator::spawn(
            p.clone(),
            topo.clone(),
            AlgSpec::ggadmm(),
            CoordinatorOptions { threads: 3, ..CoordinatorOptions::default() },
        );
        coord.step();
        coord.step();
        drop(coord);
        // never stepped at all
        let coord2 =
            Coordinator::spawn(p, topo, AlgSpec::ggadmm(), CoordinatorOptions::default());
        drop(coord2);
    }

    #[test]
    fn churned_coordinator_converges_and_streams_events() {
        let topo = Topology::random_bipartite(8, 0.5, 7);
        let ds = synthetic::linear_dataset(96, 4, 7);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 7);
        let churn = crate::graph::ChurnSchedule::parse("5:leave:2 15:join:2").unwrap();
        let mut coord = Coordinator::spawn(
            p,
            topo,
            AlgSpec::c_ggadmm(0.3, 0.85),
            ExecutionConfig::default()
                .with_churn(Some(churn))
                .with_staleness_bound(Some(4)),
        );
        let sink = crate::io::MemorySink::new();
        coord.start_event_log(Box::new(sink.clone()));
        for _ in 0..250 {
            coord.step();
        }
        assert!(
            coord.trace().last_gap() < 1e-4,
            "gap={:.3e}",
            coord.trace().last_gap()
        );
        let lines = sink.lines().join("\n");
        assert!(lines.contains(r#""event":"worker_leave""#), "{lines}");
        assert!(lines.contains(r#""event":"worker_join""#), "{lines}");
    }

    #[test]
    fn coordinated_mlp_matches_simulator_bit_for_bit() {
        // censored + quantized multi-block with a per-layer bit split:
        // partial commits must ship the exact spans the simulator hands
        // its neighbors, and both per-block ledgers must agree
        let topo = Topology::chain(4);
        let ds = synthetic::linear_dataset(48, 3, 8);
        let p = Problem::with_model(
            &ds,
            &topo,
            1.0,
            0.05,
            8,
            crate::config::ModelSpec::Mlp { hidden: 2 },
        )
        .expect("mlp problem");
        let spec = AlgSpec::cq_ggadmm(0.3, 0.85, 0.995, 4).with_bits_split(Some(vec![4, 2]));
        let mut run = crate::algs::Run::new(
            p.clone(),
            topo.clone(),
            spec.clone(),
            crate::algs::RunOptions::default(),
        );
        let mut coord = Coordinator::spawn(p, topo, spec, CoordinatorOptions::default());
        for _ in 0..25 {
            run.step();
            coord.step();
        }
        assert_eq!(run.trace(), coord.trace(), "multi-block engines diverged");
        assert_eq!(run.comm().total_bits, coord.comm().total_bits);
        assert_eq!(run.comm().block_bits, coord.comm().block_bits, "block ledgers diverged");
    }

    #[test]
    fn coordinated_qdgd_matches_simulator_bit_for_bit() {
        let topo = Topology::random_bipartite(6, 0.5, 9);
        let ds = synthetic::linear_dataset(72, 4, 9);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 9);
        let spec = AlgSpec::qdgd(0.995, 6);
        let mut run = crate::algs::Run::new(
            p.clone(),
            topo.clone(),
            spec.clone(),
            crate::algs::RunOptions::default(),
        );
        let mut coord = Coordinator::spawn(p, topo, spec, CoordinatorOptions::default());
        for _ in 0..20 {
            run.step();
            coord.step();
        }
        assert_eq!(run.trace(), coord.trace(), "qdgd engines diverged");
        assert_eq!(run.comm().total_bits, coord.comm().total_bits);
    }

    #[test]
    fn erasure_coordinator_still_converges() {
        let topo = Topology::random_bipartite(8, 0.5, 6);
        let ds = synthetic::linear_dataset(96, 4, 6);
        let p = Problem::new(&ds, &topo, 1.0, 0.0, 6);
        let coord = Coordinator::spawn(
            p,
            topo,
            AlgSpec::ggadmm(),
            CoordinatorOptions { drop_prob: 0.15, ..CoordinatorOptions::default() },
        );
        let trace = coord.run(300);
        assert!(trace.last_gap() < 1e-4, "gap={:.3e}", trace.last_gap());
    }
}
