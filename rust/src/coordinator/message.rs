//! Wire format of the sharded coordinator.
//!
//! Committed broadcasts cross the coordinator's medium — the in-process
//! simulated air or the TCP transport in [`crate::net`] — as encoded
//! bytes: a one-byte kind tag followed by either the bit-packed
//! quantized payload ([`crate::quant::codec`], exactly the `b*d + 64`
//! bits the paper counts) or the raw little-endian `f64` model.
//!
//! Full-precision payloads travel as `f64` — not the `f32` the paper's
//! 32-bit accounting suggests — so the coordinator reconstructs the
//! **exact** hats the sequential simulator holds and the two engines stay
//! locked bit-for-bit (`tests/coordinator_equivalence.rs`).  The
//! *accounting* still charges the paper's `32 d` bits per full-precision
//! broadcast ([`crate::comm::full_precision_bits`]); the tag byte and the
//! f32→f64 widening are framing, not counted payload — consistent with
//! the sequential engine, which has always simulated in `f64` while
//! charging 32-bit payloads.
//!
//! Encoding appends into persistent per-worker buffers and decoding
//! reconstructs straight into the receiver's stored slot
//! ([`crate::quant::codec::decode_reconstruct_into`]) — the broadcast
//! path allocates nothing after warm-up.

use crate::quant::codec;

/// Wire tag: raw little-endian `f64` model follows.
pub const TAG_FULL: u8 = 0;
/// Wire tag: bit-packed quantized message follows.
pub const TAG_QUANTIZED: u8 = 1;

/// Hard upper bound on the body of one length-prefixed frame (64 MiB).
///
/// Large enough for any payload the protocol produces (a full-precision
/// model frame is `8d + O(1)` bytes, a checkpoint export is a few
/// multiples of that), small enough that a corrupt or hostile length
/// prefix can never drive a multi-gigabyte allocation.  Both ends of the
/// TCP transport ([`crate::net`]) enforce it via [`parse_frame`].
pub const MAX_FRAME_LEN: usize = 1 << 26;

/// Reserve the 4-byte little-endian length slot of a frame in `out` and
/// return its offset; append the body, then call [`finish_frame`] with
/// that offset to patch the length in.  Appending into a persistent
/// buffer keeps the transport hot path allocation-free after warm-up.
pub fn begin_frame(out: &mut Vec<u8>) -> usize {
    let header = out.len();
    out.extend_from_slice(&[0u8; 4]);
    header
}

/// Patch the length prefix reserved by [`begin_frame`] at `header`.
/// Panics if the body outgrew [`MAX_FRAME_LEN`] — an encoder bug, not a
/// wire condition (decoders report it as an error instead).
pub fn finish_frame(out: &mut Vec<u8>, header: usize) {
    let body = out.len() - header - 4;
    assert!(body <= MAX_FRAME_LEN, "encoded frame body {body} bytes exceeds MAX_FRAME_LEN {MAX_FRAME_LEN}");
    out[header..header + 4].copy_from_slice(&(body as u32).to_le_bytes());
}

/// Parse the frame at the front of `buf` without copying.
///
/// - `Ok(None)`: the frame is incomplete — read more bytes and retry.
/// - `Ok(Some(body))`: one whole frame; the caller consumes exactly
///   `4 + body.len()` bytes.  The body borrows `buf` (no allocation) and
///   never reaches past the frame's declared length.
/// - `Err(..)`: the stream can never become valid (length prefix exceeds
///   [`MAX_FRAME_LEN`]) — the connection should be dropped with the
///   returned description.
pub fn parse_frame(buf: &[u8]) -> Result<Option<&[u8]>, String> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().expect("4-byte prefix")) as usize;
    if len > MAX_FRAME_LEN {
        return Err(format!(
            "frame length prefix {len} exceeds MAX_FRAME_LEN {MAX_FRAME_LEN} (corrupt stream)"
        ));
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    Ok(Some(&buf[4..4 + len]))
}

/// [`parse_frame`] for a stream that has ended (peer closed the socket):
/// leftover bytes that do not form a complete frame are an error — a
/// truncated length prefix or body must not be silently discarded.
pub fn parse_frame_eof(buf: &[u8]) -> Result<Option<&[u8]>, String> {
    match parse_frame(buf)? {
        Some(body) => Ok(Some(body)),
        None if buf.is_empty() => Ok(None),
        None => Err(format!(
            "stream ended mid-frame: {} trailing byte(s) do not form a complete frame",
            buf.len()
        )),
    }
}

/// Encode a full-precision model, appending to `out` (caller clears).
pub fn encode_full_into(theta: &[f64], out: &mut Vec<u8>) {
    out.reserve(1 + theta.len() * 8);
    out.push(TAG_FULL);
    for &v in theta {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode a quantized message from its parts, appending to `out`.
pub fn encode_quantized_into(radius: f64, bits: u32, codes: &[u32], out: &mut Vec<u8>) {
    out.push(TAG_QUANTIZED);
    codec::encode_parts_into(radius, bits, codes, out);
}

/// Decode one wire message into the receiver's stored slot for the
/// sender: full-precision payloads overwrite it, quantized payloads
/// reconstruct against it in place (eq. (20)).  Returns `false` on a
/// malformed message (wrong tag, wrong length, truncated stream) — the
/// slot may then hold a partial reconstruction, so callers treat `false`
/// as fatal.
pub fn decode_into_slot(bytes: &[u8], slot: &mut [f64]) -> bool {
    let Some((&tag, body)) = bytes.split_first() else {
        return false;
    };
    match tag {
        TAG_FULL => {
            if body.len() != slot.len() * 8 {
                return false;
            }
            for (v, chunk) in slot.iter_mut().zip(body.chunks_exact(8)) {
                *v = f64::from_le_bytes(chunk.try_into().expect("chunks_exact(8)"));
            }
            true
        }
        TAG_QUANTIZED => codec::decode_reconstruct_into(body, slot).is_some(),
        _ => false,
    }
}

/// Payload size in bits as the paper counts it, recovered from the wire
/// bytes (diagnostics; the engines account from the protocol core and
/// never re-derive this on the hot path).
pub fn counted_bits(bytes: &[u8], d: usize) -> Option<u64> {
    let (&tag, body) = bytes.split_first()?;
    match tag {
        TAG_FULL => (body.len() == d * 8).then(|| crate::comm::full_precision_bits(d)),
        TAG_QUANTIZED => codec::decode(body, d).map(|m| m.payload_bits()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantMessage;

    #[test]
    fn full_roundtrip_is_exact_f64() {
        let theta = vec![1.5, -2.25, 1.0e-17, std::f64::consts::PI];
        let mut wire = Vec::new();
        encode_full_into(&theta, &mut wire);
        assert_eq!(wire.len(), 1 + 4 * 8);
        let mut slot = vec![0.0; 4];
        assert!(decode_into_slot(&wire, &mut slot));
        // f64 on the wire: the decode is bit-exact, unlike the seed's f32
        for (a, b) in theta.iter().zip(&slot) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(counted_bits(&wire, 4), Some(32 * 4));
    }

    #[test]
    fn full_wrong_dimension_rejected() {
        let mut wire = Vec::new();
        encode_full_into(&[1.0, 2.0, 3.0], &mut wire);
        let mut slot = vec![0.0; 4];
        assert!(!decode_into_slot(&wire, &mut slot));
        assert_eq!(counted_bits(&wire, 4), None);
    }

    #[test]
    fn quantized_roundtrip_matches_reference_decode() {
        let msg = QuantMessage { codes: vec![1, 2, 3, 0], radius: 0.5, bits: 3 };
        let mut wire = Vec::new();
        encode_quantized_into(msg.radius, msg.bits, &msg.codes, &mut wire);
        assert_eq!(counted_bits(&wire, 4), Some(3 * 4 + 64));
        let reference = vec![0.25, -1.0, 2.0, 0.0];
        let mut slot = reference.clone();
        assert!(decode_into_slot(&wire, &mut slot));
        let expected = msg.reconstruct(&reference);
        for (a, b) in expected.iter().zip(&slot) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn garbage_rejected() {
        let mut slot = vec![0.0; 3];
        assert!(!decode_into_slot(&[], &mut slot));
        assert!(!decode_into_slot(&[7, 1, 2, 3], &mut slot));
        let msg = QuantMessage { codes: vec![1, 2, 3], radius: 0.5, bits: 4 };
        let mut wire = Vec::new();
        encode_quantized_into(msg.radius, msg.bits, &msg.codes, &mut wire);
        let cut = wire.len() - 1;
        assert!(!decode_into_slot(&wire[..cut], &mut slot));
    }

    #[test]
    fn frame_roundtrip_and_bounds() {
        let mut buf = Vec::new();
        let h = begin_frame(&mut buf);
        buf.extend_from_slice(b"hello");
        finish_frame(&mut buf, h);
        // a second frame back to back in the same buffer
        let h2 = begin_frame(&mut buf);
        buf.extend_from_slice(b"!");
        finish_frame(&mut buf, h2);

        let body = parse_frame(&buf).unwrap().unwrap();
        assert_eq!(body, b"hello");
        let consumed = 4 + body.len();
        let body2 = parse_frame(&buf[consumed..]).unwrap().unwrap();
        assert_eq!(body2, b"!");

        // incomplete prefixes and bodies wait for more bytes ...
        assert_eq!(parse_frame(&buf[..3]).unwrap(), None);
        assert_eq!(parse_frame(&buf[..6]).unwrap(), None);
        // ... unless the stream has ended, which is a descriptive error
        assert!(parse_frame_eof(&buf[..3]).unwrap_err().contains("mid-frame"));
        assert_eq!(parse_frame_eof(&[]).unwrap(), None);

        // an oversized length prefix is rejected, never allocated
        let huge = ((MAX_FRAME_LEN + 1) as u32).to_le_bytes();
        let err = parse_frame(&huge).unwrap_err();
        assert!(err.contains("MAX_FRAME_LEN"), "{err}");
    }

    #[test]
    fn fuzz_corrupted_streams_never_panic_or_over_read() {
        // Deterministic fuzz over three corruption families: pure noise,
        // bit-flipped valid frames, and truncations.  The contract under
        // test: `parse_frame` never panics and any body it yields lies
        // inside the input; `decode_into_slot` never panics on arbitrary
        // bytes and only ever reports success/failure.
        let mut rng = crate::util::rng::Pcg64::new(0xF4A2);
        let mut slot = vec![0.0_f64; 24];
        for round in 0..400 {
            let mut bytes: Vec<u8> = match round % 3 {
                0 => {
                    let len = (rng.next_u64() % 96) as usize;
                    (0..len).map(|_| rng.next_u64() as u8).collect()
                }
                _ => {
                    // start from a valid framed payload, then corrupt it
                    let mut v = Vec::new();
                    let h = begin_frame(&mut v);
                    if round % 2 == 0 {
                        let theta: Vec<f64> =
                            (0..24).map(|_| rng.next_u64() as i64 as f64 * 1e-9).collect();
                        encode_full_into(&theta, &mut v);
                    } else {
                        let codes: Vec<u32> = (0..24).map(|_| rng.next_u64() as u32 & 7).collect();
                        encode_quantized_into(0.5, 3, &codes, &mut v);
                    }
                    finish_frame(&mut v, h);
                    v
                }
            };
            if !bytes.is_empty() {
                for _ in 0..1 + (rng.next_u64() % 4) {
                    let at = (rng.next_u64() as usize) % bytes.len();
                    bytes[at] ^= 1 << (rng.next_u64() % 8);
                }
                let keep = (rng.next_u64() as usize) % (bytes.len() + 1);
                bytes.truncate(keep);
            }
            match parse_frame(&bytes) {
                Ok(Some(body)) => {
                    // never over-reads: the body lies strictly within the input
                    assert!(4 + body.len() <= bytes.len());
                    let _ = decode_into_slot(body, &mut slot);
                    let _ = counted_bits(body, slot.len());
                }
                Ok(None) => assert!(matches!(parse_frame_eof(&bytes), Ok(None) | Err(_))),
                Err(e) => assert!(!e.is_empty()),
            }
            // decoding the raw (unframed) corruption must not panic either
            let _ = decode_into_slot(&bytes, &mut slot);
            let _ = counted_bits(&bytes, slot.len());
            for v in &mut slot {
                if !v.is_finite() {
                    *v = 0.0;
                }
            }
        }
    }

    #[test]
    fn buffers_are_reusable() {
        // clear + re-encode must not reallocate once capacity is warm
        let theta = vec![1.0; 16];
        let mut wire = Vec::new();
        encode_full_into(&theta, &mut wire);
        let cap = wire.capacity();
        for _ in 0..4 {
            wire.clear();
            encode_full_into(&theta, &mut wire);
        }
        assert_eq!(wire.capacity(), cap);
    }
}
