//! Wire format of the sharded coordinator.
//!
//! Committed broadcasts cross the coordinator's medium — the in-process
//! simulated air or the TCP transport in [`crate::net`] — as encoded
//! bytes: a one-byte kind tag followed by either the bit-packed
//! quantized payload ([`crate::quant::codec`], exactly the `b*d + 64`
//! bits the paper counts) or the raw little-endian `f64` model.
//!
//! Full-precision payloads travel as `f64` — not the `f32` the paper's
//! 32-bit accounting suggests — so the coordinator reconstructs the
//! **exact** hats the sequential simulator holds and the two engines stay
//! locked bit-for-bit (`tests/coordinator_equivalence.rs`).  The
//! *accounting* still charges the paper's `32 d` bits per full-precision
//! broadcast ([`crate::comm::full_precision_bits`]); the tag byte and the
//! f32→f64 widening are framing, not counted payload — consistent with
//! the sequential engine, which has always simulated in `f64` while
//! charging 32-bit payloads.
//!
//! Encoding appends into persistent per-worker buffers and decoding
//! reconstructs straight into the receiver's stored slot
//! ([`crate::quant::codec::decode_reconstruct_into`]) — the broadcast
//! path allocates nothing after warm-up.

use crate::param::Blocks;
use crate::quant::codec;

/// Wire tag: raw little-endian `f64` model follows.
pub const TAG_FULL: u8 = 0;
/// Wire tag: bit-packed quantized message follows.
pub const TAG_QUANTIZED: u8 = 1;
/// Wire tag: per-block framed multi-block payload follows — a `u16`
/// block count, then per block a presence byte and (when present) a
/// `u32`-length-prefixed [`TAG_FULL`]/[`TAG_QUANTIZED`] sub-payload over
/// that block's slice.  Emitted only for multi-block models (`B > 1`);
/// flat models keep the original single-tag frames byte-for-byte.
pub const TAG_BLOCKS: u8 = 2;

/// Hard upper bound on the body of one length-prefixed frame (64 MiB).
///
/// Large enough for any payload the protocol produces (a full-precision
/// model frame is `8d + O(1)` bytes, a checkpoint export is a few
/// multiples of that), small enough that a corrupt or hostile length
/// prefix can never drive a multi-gigabyte allocation.  Both ends of the
/// TCP transport ([`crate::net`]) enforce it via [`parse_frame`].
pub const MAX_FRAME_LEN: usize = 1 << 26;

/// Reserve the 4-byte little-endian length slot of a frame in `out` and
/// return its offset; append the body, then call [`finish_frame`] with
/// that offset to patch the length in.  Appending into a persistent
/// buffer keeps the transport hot path allocation-free after warm-up.
pub fn begin_frame(out: &mut Vec<u8>) -> usize {
    let header = out.len();
    out.extend_from_slice(&[0u8; 4]);
    header
}

/// Patch the length prefix reserved by [`begin_frame`] at `header`.
/// Panics if the body outgrew [`MAX_FRAME_LEN`] — an encoder bug, not a
/// wire condition (decoders report it as an error instead).
pub fn finish_frame(out: &mut Vec<u8>, header: usize) {
    let body = out.len() - header - 4;
    assert!(body <= MAX_FRAME_LEN, "encoded frame body {body} bytes exceeds MAX_FRAME_LEN {MAX_FRAME_LEN}");
    out[header..header + 4].copy_from_slice(&(body as u32).to_le_bytes());
}

/// Parse the frame at the front of `buf` without copying.
///
/// - `Ok(None)`: the frame is incomplete — read more bytes and retry.
/// - `Ok(Some(body))`: one whole frame; the caller consumes exactly
///   `4 + body.len()` bytes.  The body borrows `buf` (no allocation) and
///   never reaches past the frame's declared length.
/// - `Err(..)`: the stream can never become valid (length prefix exceeds
///   [`MAX_FRAME_LEN`]) — the connection should be dropped with the
///   returned description.
pub fn parse_frame(buf: &[u8]) -> Result<Option<&[u8]>, String> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().expect("4-byte prefix")) as usize;
    if len > MAX_FRAME_LEN {
        return Err(format!(
            "frame length prefix {len} exceeds MAX_FRAME_LEN {MAX_FRAME_LEN} (corrupt stream)"
        ));
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    Ok(Some(&buf[4..4 + len]))
}

/// [`parse_frame`] for a stream that has ended (peer closed the socket):
/// leftover bytes that do not form a complete frame are an error — a
/// truncated length prefix or body must not be silently discarded.
pub fn parse_frame_eof(buf: &[u8]) -> Result<Option<&[u8]>, String> {
    match parse_frame(buf)? {
        Some(body) => Ok(Some(body)),
        None if buf.is_empty() => Ok(None),
        None => Err(format!(
            "stream ended mid-frame: {} trailing byte(s) do not form a complete frame",
            buf.len()
        )),
    }
}

/// Encode a full-precision model, appending to `out` (caller clears).
pub fn encode_full_into(theta: &[f64], out: &mut Vec<u8>) {
    out.reserve(1 + theta.len() * 8);
    out.push(TAG_FULL);
    for &v in theta {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode a quantized message from its parts, appending to `out`.
pub fn encode_quantized_into(radius: f64, bits: u32, codes: &[u32], out: &mut Vec<u8>) {
    out.push(TAG_QUANTIZED);
    codec::encode_parts_into(radius, bits, codes, out);
}

/// Decode one wire message into the receiver's stored slot for the
/// sender: full-precision payloads overwrite it, quantized payloads
/// reconstruct against it in place (eq. (20)).  Returns `false` on a
/// malformed message (wrong tag, wrong length, truncated stream) — the
/// slot may then hold a partial reconstruction, so callers treat `false`
/// as fatal.
pub fn decode_into_slot(bytes: &[u8], slot: &mut [f64]) -> bool {
    let Some((&tag, body)) = bytes.split_first() else {
        return false;
    };
    match tag {
        TAG_FULL => {
            if body.len() != slot.len() * 8 {
                return false;
            }
            for (v, chunk) in slot.iter_mut().zip(body.chunks_exact(8)) {
                *v = f64::from_le_bytes(chunk.try_into().expect("chunks_exact(8)"));
            }
            true
        }
        TAG_QUANTIZED => codec::decode_reconstruct_into(body, slot).is_some(),
        _ => false,
    }
}

/// Open a [`TAG_BLOCKS`] payload: tag + block count.  Follow with one
/// [`encode_absent_block_into`] or [`begin_block_into`]/
/// [`finish_block_into`] pair per block, in block order.
pub fn begin_blocks_into(nblocks: usize, out: &mut Vec<u8>) {
    assert!(
        (2..=u16::MAX as usize).contains(&nblocks),
        "TAG_BLOCKS frames multi-block payloads only (got {nblocks} blocks)"
    );
    out.push(TAG_BLOCKS);
    out.extend_from_slice(&(nblocks as u16).to_le_bytes());
}

/// A censored block transmits nothing: presence byte 0, no sub-payload.
pub fn encode_absent_block_into(out: &mut Vec<u8>) {
    out.push(0);
}

/// Open one transmitting block: presence byte 1 + reserved `u32` length
/// slot.  Append the sub-payload ([`encode_full_into`] or
/// [`encode_quantized_into`] over the block's slice), then patch the
/// length with [`finish_block_into`].
pub fn begin_block_into(out: &mut Vec<u8>) -> usize {
    out.push(1);
    let at = out.len();
    out.extend_from_slice(&[0u8; 4]);
    at
}

/// Patch the sub-payload length reserved by [`begin_block_into`].
pub fn finish_block_into(out: &mut Vec<u8>, at: usize) {
    let sub = out.len() - at - 4;
    out[at..at + 4].copy_from_slice(&(sub as u32).to_le_bytes());
}

/// Decode a [`TAG_BLOCKS`] wire message into the receiver's stored slot:
/// present blocks land in their spans (full precision overwrites,
/// quantized reconstructs in place against the span — the per-block
/// analogue of [`decode_into_slot`]); absent blocks leave their spans
/// untouched, exactly like the in-process engine's masked delivery.
/// Returns `false` on any malformed input (wrong tag or block count,
/// truncation, trailing bytes) — the slot may then be partially written,
/// so callers treat `false` as fatal.
pub fn decode_blocks_into_slot(bytes: &[u8], layout: &Blocks, slot: &mut [f64]) -> bool {
    let Some((&tag, rest)) = bytes.split_first() else {
        return false;
    };
    if tag != TAG_BLOCKS || rest.len() < 2 {
        return false;
    }
    let nb = u16::from_le_bytes([rest[0], rest[1]]) as usize;
    if nb != layout.count() || layout.d() != slot.len() {
        return false;
    }
    let mut body = &rest[2..];
    for b in 0..nb {
        let Some((&presence, tail)) = body.split_first() else {
            return false;
        };
        body = tail;
        match presence {
            0 => {}
            1 => {
                if body.len() < 4 {
                    return false;
                }
                let len = u32::from_le_bytes(body[..4].try_into().expect("4-byte prefix")) as usize;
                body = &body[4..];
                if body.len() < len {
                    return false;
                }
                let (sub, tail) = body.split_at(len);
                body = tail;
                if !decode_into_slot(sub, &mut slot[layout.range(b)]) {
                    return false;
                }
            }
            _ => return false,
        }
    }
    body.is_empty()
}

/// Per-block counted bits of a [`TAG_BLOCKS`] payload (absent blocks
/// count zero) — the wire-side mirror of the engines' per-block ledger
/// (diagnostics/tests; the hot path accounts from the protocol core).
pub fn counted_bits_per_block(bytes: &[u8], layout: &Blocks) -> Option<Vec<u64>> {
    let (&tag, rest) = bytes.split_first()?;
    if tag != TAG_BLOCKS || rest.len() < 2 {
        return None;
    }
    let nb = u16::from_le_bytes([rest[0], rest[1]]) as usize;
    if nb != layout.count() {
        return None;
    }
    let mut body = &rest[2..];
    let mut per = Vec::with_capacity(nb);
    for b in 0..nb {
        let (&presence, tail) = body.split_first()?;
        body = tail;
        match presence {
            0 => per.push(0),
            1 => {
                if body.len() < 4 {
                    return None;
                }
                let len = u32::from_le_bytes(body[..4].try_into().expect("4-byte prefix")) as usize;
                body = &body[4..];
                if body.len() < len {
                    return None;
                }
                let (sub, tail) = body.split_at(len);
                body = tail;
                per.push(counted_bits(sub, layout.len_of(b))?);
            }
            _ => return None,
        }
    }
    body.is_empty().then_some(per)
}

/// Payload size in bits as the paper counts it, recovered from the wire
/// bytes (diagnostics; the engines account from the protocol core and
/// never re-derive this on the hot path).
pub fn counted_bits(bytes: &[u8], d: usize) -> Option<u64> {
    let (&tag, body) = bytes.split_first()?;
    match tag {
        TAG_FULL => (body.len() == d * 8).then(|| crate::comm::full_precision_bits(d)),
        TAG_QUANTIZED => codec::decode(body, d).map(|m| m.payload_bits()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantMessage;

    #[test]
    fn full_roundtrip_is_exact_f64() {
        let theta = vec![1.5, -2.25, 1.0e-17, std::f64::consts::PI];
        let mut wire = Vec::new();
        encode_full_into(&theta, &mut wire);
        assert_eq!(wire.len(), 1 + 4 * 8);
        let mut slot = vec![0.0; 4];
        assert!(decode_into_slot(&wire, &mut slot));
        // f64 on the wire: the decode is bit-exact, unlike the seed's f32
        for (a, b) in theta.iter().zip(&slot) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(counted_bits(&wire, 4), Some(32 * 4));
    }

    #[test]
    fn full_wrong_dimension_rejected() {
        let mut wire = Vec::new();
        encode_full_into(&[1.0, 2.0, 3.0], &mut wire);
        let mut slot = vec![0.0; 4];
        assert!(!decode_into_slot(&wire, &mut slot));
        assert_eq!(counted_bits(&wire, 4), None);
    }

    #[test]
    fn quantized_roundtrip_matches_reference_decode() {
        let msg = QuantMessage { codes: vec![1, 2, 3, 0], radius: 0.5, bits: 3 };
        let mut wire = Vec::new();
        encode_quantized_into(msg.radius, msg.bits, &msg.codes, &mut wire);
        assert_eq!(counted_bits(&wire, 4), Some(3 * 4 + 64));
        let reference = vec![0.25, -1.0, 2.0, 0.0];
        let mut slot = reference.clone();
        assert!(decode_into_slot(&wire, &mut slot));
        let expected = msg.reconstruct(&reference);
        for (a, b) in expected.iter().zip(&slot) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn garbage_rejected() {
        let mut slot = vec![0.0; 3];
        assert!(!decode_into_slot(&[], &mut slot));
        assert!(!decode_into_slot(&[7, 1, 2, 3], &mut slot));
        let msg = QuantMessage { codes: vec![1, 2, 3], radius: 0.5, bits: 4 };
        let mut wire = Vec::new();
        encode_quantized_into(msg.radius, msg.bits, &msg.codes, &mut wire);
        let cut = wire.len() - 1;
        assert!(!decode_into_slot(&wire[..cut], &mut slot));
    }

    #[test]
    fn block_framing_round_trips_every_bit_width() {
        // quantized block at every width the codec supports, next to a
        // full-precision sibling: spans reconstruct exactly like the
        // single-tag messages do over the whole vector
        let layout = Blocks::from_lens(&[5, 3]);
        for bits in 1..=32u32 {
            let mask = if bits >= 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let codes: Vec<u32> = (0..5).map(|i| (11 * i as u32 + 1) & mask).collect();
            let mut wire = Vec::new();
            begin_blocks_into(2, &mut wire);
            let at = begin_block_into(&mut wire);
            encode_quantized_into(0.75, bits, &codes, &mut wire);
            finish_block_into(&mut wire, at);
            let at = begin_block_into(&mut wire);
            encode_full_into(&[1.0, -2.0, 3.5], &mut wire);
            finish_block_into(&mut wire, at);

            let reference: Vec<f64> = (0..8).map(|i| 0.25 * i as f64 - 1.0).collect();
            let mut slot = reference.clone();
            assert!(decode_blocks_into_slot(&wire, &layout, &mut slot), "bits={bits}");
            let expected = QuantMessage { codes: codes.clone(), radius: 0.75, bits }
                .reconstruct(&reference[..5]);
            for (a, b) in expected.iter().zip(&slot[..5]) {
                assert_eq!(a.to_bits(), b.to_bits(), "bits={bits}");
            }
            assert_eq!(&slot[5..], &[1.0, -2.0, 3.5]);
            let per = counted_bits_per_block(&wire, &layout).expect("counted");
            assert_eq!(per, vec![bits as u64 * 5 + 64, 32 * 3], "bits={bits}");
        }
    }

    #[test]
    fn absent_blocks_leave_their_spans_untouched() {
        let layout = Blocks::from_lens(&[2, 2]);
        let mut wire = Vec::new();
        begin_blocks_into(2, &mut wire);
        encode_absent_block_into(&mut wire);
        let at = begin_block_into(&mut wire);
        encode_full_into(&[9.0, 8.0], &mut wire);
        finish_block_into(&mut wire, at);
        let mut slot = vec![1.0, 2.0, 3.0, 4.0];
        assert!(decode_blocks_into_slot(&wire, &layout, &mut slot));
        assert_eq!(slot, vec![1.0, 2.0, 9.0, 8.0]);
        assert_eq!(counted_bits_per_block(&wire, &layout), Some(vec![0, 64]));
    }

    #[test]
    fn block_framing_rejects_malformed_input() {
        let layout = Blocks::from_lens(&[2, 2]);
        let mut wire = Vec::new();
        begin_blocks_into(2, &mut wire);
        let at = begin_block_into(&mut wire);
        encode_full_into(&[1.0, 2.0], &mut wire);
        finish_block_into(&mut wire, at);
        let at = begin_block_into(&mut wire);
        encode_full_into(&[3.0, 4.0], &mut wire);
        finish_block_into(&mut wire, at);
        let mut slot = vec![0.0; 4];
        assert!(decode_blocks_into_slot(&wire, &layout, &mut slot));

        // truncations never panic or accept
        for cut in 0..wire.len() {
            assert!(!decode_blocks_into_slot(&wire[..cut], &layout, &mut slot), "cut={cut}");
            assert_eq!(counted_bits_per_block(&wire[..cut], &layout), None, "cut={cut}");
        }
        // trailing garbage
        let mut longer = wire.clone();
        longer.push(0);
        assert!(!decode_blocks_into_slot(&longer, &layout, &mut slot));
        // wrong block count for the layout
        let three = Blocks::from_lens(&[2, 1, 1]);
        assert!(!decode_blocks_into_slot(&wire, &three, &mut slot));
        // bad presence byte
        let mut bad = wire.clone();
        bad[3] = 7;
        assert!(!decode_blocks_into_slot(&bad, &layout, &mut slot));
        // a flat-tag message is not a block message
        let mut flat = Vec::new();
        encode_full_into(&[1.0; 4], &mut flat);
        assert!(!decode_blocks_into_slot(&flat, &layout, &mut slot));
    }

    #[test]
    fn frame_roundtrip_and_bounds() {
        let mut buf = Vec::new();
        let h = begin_frame(&mut buf);
        buf.extend_from_slice(b"hello");
        finish_frame(&mut buf, h);
        // a second frame back to back in the same buffer
        let h2 = begin_frame(&mut buf);
        buf.extend_from_slice(b"!");
        finish_frame(&mut buf, h2);

        let body = parse_frame(&buf).unwrap().unwrap();
        assert_eq!(body, b"hello");
        let consumed = 4 + body.len();
        let body2 = parse_frame(&buf[consumed..]).unwrap().unwrap();
        assert_eq!(body2, b"!");

        // incomplete prefixes and bodies wait for more bytes ...
        assert_eq!(parse_frame(&buf[..3]).unwrap(), None);
        assert_eq!(parse_frame(&buf[..6]).unwrap(), None);
        // ... unless the stream has ended, which is a descriptive error
        assert!(parse_frame_eof(&buf[..3]).unwrap_err().contains("mid-frame"));
        assert_eq!(parse_frame_eof(&[]).unwrap(), None);

        // an oversized length prefix is rejected, never allocated
        let huge = ((MAX_FRAME_LEN + 1) as u32).to_le_bytes();
        let err = parse_frame(&huge).unwrap_err();
        assert!(err.contains("MAX_FRAME_LEN"), "{err}");
    }

    #[test]
    fn fuzz_corrupted_streams_never_panic_or_over_read() {
        // Deterministic fuzz over three corruption families: pure noise,
        // bit-flipped valid frames, and truncations.  The contract under
        // test: `parse_frame` never panics and any body it yields lies
        // inside the input; `decode_into_slot` never panics on arbitrary
        // bytes and only ever reports success/failure.
        let mut rng = crate::util::rng::Pcg64::new(0xF4A2);
        let mut slot = vec![0.0_f64; 24];
        for round in 0..400 {
            let mut bytes: Vec<u8> = match round % 3 {
                0 => {
                    let len = (rng.next_u64() % 96) as usize;
                    (0..len).map(|_| rng.next_u64() as u8).collect()
                }
                _ => {
                    // start from a valid framed payload, then corrupt it
                    let mut v = Vec::new();
                    let h = begin_frame(&mut v);
                    if round % 2 == 0 {
                        let theta: Vec<f64> =
                            (0..24).map(|_| rng.next_u64() as i64 as f64 * 1e-9).collect();
                        encode_full_into(&theta, &mut v);
                    } else {
                        let codes: Vec<u32> = (0..24).map(|_| rng.next_u64() as u32 & 7).collect();
                        encode_quantized_into(0.5, 3, &codes, &mut v);
                    }
                    finish_frame(&mut v, h);
                    v
                }
            };
            if !bytes.is_empty() {
                for _ in 0..1 + (rng.next_u64() % 4) {
                    let at = (rng.next_u64() as usize) % bytes.len();
                    bytes[at] ^= 1 << (rng.next_u64() % 8);
                }
                let keep = (rng.next_u64() as usize) % (bytes.len() + 1);
                bytes.truncate(keep);
            }
            match parse_frame(&bytes) {
                Ok(Some(body)) => {
                    // never over-reads: the body lies strictly within the input
                    assert!(4 + body.len() <= bytes.len());
                    let _ = decode_into_slot(body, &mut slot);
                    let _ = counted_bits(body, slot.len());
                }
                Ok(None) => assert!(matches!(parse_frame_eof(&bytes), Ok(None) | Err(_))),
                Err(e) => assert!(!e.is_empty()),
            }
            // decoding the raw (unframed) corruption must not panic either
            let _ = decode_into_slot(&bytes, &mut slot);
            let _ = counted_bits(&bytes, slot.len());
            for v in &mut slot {
                if !v.is_finite() {
                    *v = 0.0;
                }
            }
        }
    }

    #[test]
    fn buffers_are_reusable() {
        // clear + re-encode must not reallocate once capacity is warm
        let theta = vec![1.0; 16];
        let mut wire = Vec::new();
        encode_full_into(&theta, &mut wire);
        let cap = wire.capacity();
        for _ in 0..4 {
            wire.clear();
            encode_full_into(&theta, &mut wire);
        }
        assert_eq!(wire.capacity(), cap);
    }
}
