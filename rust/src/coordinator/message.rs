//! Wire messages of the threaded coordinator.
//!
//! Worker-to-worker model exchanges travel as *encoded bytes* (bit-packed
//! quantized payloads or raw f32 full-precision payloads) through the
//! leader, which plays the wireless medium: it forwards broadcasts to the
//! sender's neighbors and charges the energy model.  The byte sizes on
//! this path are exactly the payloads the paper counts.

use crate::quant::codec;
use crate::quant::QuantMessage;

/// Payload of one broadcast.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// 32-bit full precision (f32 little-endian), the unquantized schemes.
    Full(Vec<u8>),
    /// Bit-packed quantized message.
    Quantized(Vec<u8>),
}

impl Payload {
    /// Payload size in bits, as the paper counts it.
    pub fn bits(&self, d: usize) -> u64 {
        match self {
            Payload::Full(_) => 32 * d as u64,
            Payload::Quantized(bytes) => {
                // recover exact bit count from the header (b*d + 64)
                codec::decode(bytes, d)
                    .map(|m| m.payload_bits())
                    .unwrap_or((bytes.len() * 8) as u64)
            }
        }
    }
}

/// Encode a full-precision model.
pub fn encode_full(theta: &[f64]) -> Payload {
    let mut bytes = Vec::with_capacity(theta.len() * 4);
    for &v in theta {
        bytes.extend_from_slice(&(v as f32).to_le_bytes());
    }
    Payload::Full(bytes)
}

/// Decode a full-precision model.
pub fn decode_full(bytes: &[u8], d: usize) -> Option<Vec<f64>> {
    if bytes.len() != d * 4 {
        return None;
    }
    Some(
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64)
            .collect(),
    )
}

/// Encode a quantized message.
pub fn encode_quantized(msg: &QuantMessage) -> Payload {
    Payload::Quantized(codec::encode(msg))
}

/// Decode a quantized message.
pub fn decode_quantized(bytes: &[u8], d: usize) -> Option<QuantMessage> {
    codec::decode(bytes, d)
}

/// Leader -> worker commands.
#[derive(Debug)]
pub enum Command {
    /// Run the primal update + transmission decision for iteration `k`.
    Phase { k: u64 },
    /// Deliver a neighbor's broadcast.
    Deliver { from: usize, payload: Payload },
    /// Run the dual update for iteration `k` (both phases delivered).
    DualUpdate,
    /// Report local loss `f_n(theta_n)` and diagnostics.
    Report,
    /// Shut down.
    Stop,
}

/// Worker -> leader events.
#[derive(Debug)]
pub enum Event {
    /// The worker decided to broadcast.
    Broadcast { from: usize, payload: Payload },
    /// The worker finished its phase (after an optional broadcast).
    PhaseDone { worker: usize },
    /// Dual update finished.
    DualDone { worker: usize },
    /// Loss report.
    Loss { worker: usize, loss: f64, theta: Vec<f64> },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_roundtrip() {
        let theta = vec![1.5, -2.25, 0.0];
        let p = encode_full(&theta);
        assert_eq!(p.bits(3), 96);
        match &p {
            Payload::Full(bytes) => {
                assert_eq!(decode_full(bytes, 3).unwrap(), theta);
                assert!(decode_full(bytes, 4).is_none());
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn quantized_roundtrip_and_bits() {
        let msg = QuantMessage { codes: vec![1, 2, 3, 4], radius: 0.5, bits: 3 };
        let p = encode_quantized(&msg);
        assert_eq!(p.bits(4), 3 * 4 + 64);
        match &p {
            Payload::Quantized(bytes) => {
                assert_eq!(decode_quantized(bytes, 4).unwrap(), msg);
            }
            _ => unreachable!(),
        }
    }
}
