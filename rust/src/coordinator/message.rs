//! Wire format of the sharded coordinator.
//!
//! Committed broadcasts cross the coordinator's (simulated) air as
//! encoded bytes: a one-byte kind tag followed by either the bit-packed
//! quantized payload ([`crate::quant::codec`], exactly the `b*d + 64`
//! bits the paper counts) or the raw little-endian `f64` model.
//!
//! Full-precision payloads travel as `f64` — not the `f32` the paper's
//! 32-bit accounting suggests — so the coordinator reconstructs the
//! **exact** hats the sequential simulator holds and the two engines stay
//! locked bit-for-bit (`tests/coordinator_equivalence.rs`).  The
//! *accounting* still charges the paper's `32 d` bits per full-precision
//! broadcast ([`crate::comm::full_precision_bits`]); the tag byte and the
//! f32→f64 widening are framing, not counted payload — consistent with
//! the sequential engine, which has always simulated in `f64` while
//! charging 32-bit payloads.
//!
//! Encoding appends into persistent per-worker buffers and decoding
//! reconstructs straight into the receiver's stored slot
//! ([`crate::quant::codec::decode_reconstruct_into`]) — the broadcast
//! path allocates nothing after warm-up.

use crate::quant::codec;

/// Wire tag: raw little-endian `f64` model follows.
pub const TAG_FULL: u8 = 0;
/// Wire tag: bit-packed quantized message follows.
pub const TAG_QUANTIZED: u8 = 1;

/// Encode a full-precision model, appending to `out` (caller clears).
pub fn encode_full_into(theta: &[f64], out: &mut Vec<u8>) {
    out.reserve(1 + theta.len() * 8);
    out.push(TAG_FULL);
    for &v in theta {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode a quantized message from its parts, appending to `out`.
pub fn encode_quantized_into(radius: f64, bits: u32, codes: &[u32], out: &mut Vec<u8>) {
    out.push(TAG_QUANTIZED);
    codec::encode_parts_into(radius, bits, codes, out);
}

/// Decode one wire message into the receiver's stored slot for the
/// sender: full-precision payloads overwrite it, quantized payloads
/// reconstruct against it in place (eq. (20)).  Returns `false` on a
/// malformed message (wrong tag, wrong length, truncated stream) — the
/// slot may then hold a partial reconstruction, so callers treat `false`
/// as fatal.
pub fn decode_into_slot(bytes: &[u8], slot: &mut [f64]) -> bool {
    let Some((&tag, body)) = bytes.split_first() else {
        return false;
    };
    match tag {
        TAG_FULL => {
            if body.len() != slot.len() * 8 {
                return false;
            }
            for (v, chunk) in slot.iter_mut().zip(body.chunks_exact(8)) {
                *v = f64::from_le_bytes(chunk.try_into().expect("chunks_exact(8)"));
            }
            true
        }
        TAG_QUANTIZED => codec::decode_reconstruct_into(body, slot).is_some(),
        _ => false,
    }
}

/// Payload size in bits as the paper counts it, recovered from the wire
/// bytes (diagnostics; the engines account from the protocol core and
/// never re-derive this on the hot path).
pub fn counted_bits(bytes: &[u8], d: usize) -> Option<u64> {
    let (&tag, body) = bytes.split_first()?;
    match tag {
        TAG_FULL => (body.len() == d * 8).then(|| crate::comm::full_precision_bits(d)),
        TAG_QUANTIZED => codec::decode(body, d).map(|m| m.payload_bits()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantMessage;

    #[test]
    fn full_roundtrip_is_exact_f64() {
        let theta = vec![1.5, -2.25, 1.0e-17, std::f64::consts::PI];
        let mut wire = Vec::new();
        encode_full_into(&theta, &mut wire);
        assert_eq!(wire.len(), 1 + 4 * 8);
        let mut slot = vec![0.0; 4];
        assert!(decode_into_slot(&wire, &mut slot));
        // f64 on the wire: the decode is bit-exact, unlike the seed's f32
        for (a, b) in theta.iter().zip(&slot) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(counted_bits(&wire, 4), Some(32 * 4));
    }

    #[test]
    fn full_wrong_dimension_rejected() {
        let mut wire = Vec::new();
        encode_full_into(&[1.0, 2.0, 3.0], &mut wire);
        let mut slot = vec![0.0; 4];
        assert!(!decode_into_slot(&wire, &mut slot));
        assert_eq!(counted_bits(&wire, 4), None);
    }

    #[test]
    fn quantized_roundtrip_matches_reference_decode() {
        let msg = QuantMessage { codes: vec![1, 2, 3, 0], radius: 0.5, bits: 3 };
        let mut wire = Vec::new();
        encode_quantized_into(msg.radius, msg.bits, &msg.codes, &mut wire);
        assert_eq!(counted_bits(&wire, 4), Some(3 * 4 + 64));
        let reference = vec![0.25, -1.0, 2.0, 0.0];
        let mut slot = reference.clone();
        assert!(decode_into_slot(&wire, &mut slot));
        let expected = msg.reconstruct(&reference);
        for (a, b) in expected.iter().zip(&slot) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn garbage_rejected() {
        let mut slot = vec![0.0; 3];
        assert!(!decode_into_slot(&[], &mut slot));
        assert!(!decode_into_slot(&[7, 1, 2, 3], &mut slot));
        let msg = QuantMessage { codes: vec![1, 2, 3], radius: 0.5, bits: 4 };
        let mut wire = Vec::new();
        encode_quantized_into(msg.radius, msg.bits, &msg.codes, &mut wire);
        let cut = wire.len() - 1;
        assert!(!decode_into_slot(&wire[..cut], &mut slot));
    }

    #[test]
    fn buffers_are_reusable() {
        // clear + re-encode must not reallocate once capacity is warm
        let theta = vec![1.0; 16];
        let mut wire = Vec::new();
        encode_full_into(&theta, &mut wire);
        let cap = wire.capacity();
        for _ in 0..4 {
            wire.clear();
            encode_full_into(&theta, &mut wire);
        }
        assert_eq!(wire.capacity(), cap);
    }
}
