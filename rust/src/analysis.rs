//! Convergence diagnostics from the paper's analysis (§6).
//!
//! Tracks, alongside a [`Run`], the quantities the proofs reason about:
//! the primal residual `r_{n,m}^k = theta_n^k - theta_m^k` (eq. 28), the
//! dual residual `s_n^k = rho * sum_m (hat_m^k - hat_m^{k-1})` (eq. 29),
//! the total per-worker error `eps_n^k = theta_n^k - hat_n^k` (eq. 30),
//! and a Lyapunov-style potential `V^k` (eq. 92, with `lambda*` replaced
//! by the per-edge duals' distance to their final value being unknown —
//! we monitor the computable surrogate `rho * sum ||theta_m - theta*||^2
//! + 1/rho * sum ||alpha_n||^2`, which Theorem 2 drives to a constant).
//!
//! Theorem 2's statements are checked empirically in the tests below:
//! both residuals converge to zero in the (mean-)square sense.

use crate::algs::Run;
use crate::graph::Topology;

/// Per-iteration diagnostic sample.
#[derive(Clone, Copy, Debug)]
pub struct ResidualPoint {
    pub iteration: u64,
    /// max over edges of ||theta_n - theta_m|| (primal residual, eq. 28)
    pub primal_residual: f64,
    /// max over heads of ||rho sum_m (hat_m^k - hat_m^{k-1})|| (eq. 29)
    pub dual_residual: f64,
    /// max over workers of ||theta_n - hat_n|| (total error, eq. 30)
    pub total_error: f64,
    /// Lyapunov surrogate (see module docs)
    pub lyapunov: f64,
}

/// Residual tracker: call [`Tracker::sample`] after each `run.step()`.
pub struct Tracker {
    topo: Topology,
    prev_hats: Vec<Vec<f64>>,
    pub points: Vec<ResidualPoint>,
}

impl Tracker {
    pub fn new(run: &Run) -> Tracker {
        let topo = run.topology().clone();
        let prev_hats = (0..topo.n()).map(|i| run.snapshot(i).hat).collect();
        Tracker { topo, prev_hats, points: Vec::new() }
    }

    /// Record the residuals at the run's current state.
    pub fn sample(&mut self, run: &Run) {
        let n = self.topo.n();
        let snaps: Vec<_> = (0..n).map(|i| run.snapshot(i)).collect();
        let rho = run.problem().rho;
        let theta_star = &run.problem().theta_star;

        let mut primal: f64 = 0.0;
        for &(h, t) in self.topo.edges() {
            let d2: f64 = snaps[h]
                .theta
                .iter()
                .zip(&snaps[t].theta)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            primal = primal.max(d2.sqrt());
        }

        let mut dual: f64 = 0.0;
        for &h in &self.topo.heads() {
            let mut acc = vec![0.0; theta_star.len()];
            for &m in self.topo.neighbors(h) {
                for j in 0..acc.len() {
                    acc[j] += rho * (snaps[m].hat[j] - self.prev_hats[m][j]);
                }
            }
            dual = dual.max(crate::util::norm2(&acc));
        }

        let mut total_err: f64 = 0.0;
        let mut lyap = 0.0;
        for (i, s) in snaps.iter().enumerate() {
            let e: f64 = s
                .theta
                .iter()
                .zip(&s.hat)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            total_err = total_err.max(e.sqrt());
            let dist: f64 = s
                .theta
                .iter()
                .zip(theta_star)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            let anorm: f64 = s.alpha.iter().map(|a| a * a).sum();
            lyap += rho * dist + anorm / rho;
            self.prev_hats[i].copy_from_slice(&s.hat);
        }

        self.points.push(ResidualPoint {
            iteration: run.iteration(),
            primal_residual: primal,
            dual_residual: dual,
            total_error: total_err,
            lyapunov: lyap,
        });
    }

    /// Last sampled point.
    pub fn last(&self) -> Option<&ResidualPoint> {
        self.points.last()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algs::{AlgSpec, Problem, RunOptions};
    use crate::data::synthetic;

    fn tracked_run(spec: AlgSpec, iters: u64, seed: u64) -> Vec<ResidualPoint> {
        let topo = Topology::random_bipartite(8, 0.5, seed);
        let ds = synthetic::linear_dataset(96, 5, seed);
        let p = Problem::new(&ds, &topo, 5.0, 0.0, seed);
        let mut run = Run::new(p, topo, spec, RunOptions { seed, ..Default::default() });
        let mut tracker = Tracker::new(&run);
        for _ in 0..iters {
            run.step();
            tracker.sample(&run);
        }
        tracker.points
    }

    #[test]
    fn theorem2_residuals_vanish_for_ggadmm() {
        let pts = tracked_run(AlgSpec::ggadmm(), 150, 51);
        let last = pts.last().unwrap();
        assert!(last.primal_residual < 1e-7, "r = {:.3e}", last.primal_residual);
        assert!(last.dual_residual < 1e-7, "s = {:.3e}", last.dual_residual);
        // without censoring/quantization the total error is exactly zero
        assert_eq!(last.total_error, 0.0);
    }

    #[test]
    fn theorem2_residuals_vanish_for_cq_ggadmm() {
        let pts = tracked_run(AlgSpec::cq_ggadmm(0.2, 0.85, 0.99, 2), 250, 52);
        let last = pts.last().unwrap();
        assert!(last.primal_residual < 1e-4, "r = {:.3e}", last.primal_residual);
        assert!(last.dual_residual < 1e-4, "s = {:.3e}", last.dual_residual);
        // eps^k -> 0 (eq. 33: bounded by the decaying psi^k envelope)
        assert!(last.total_error < 1e-4, "eps = {:.3e}", last.total_error);
    }

    #[test]
    fn lyapunov_surrogate_stabilizes() {
        let pts = tracked_run(AlgSpec::ggadmm(), 200, 53);
        // after convergence the potential must stop moving
        let tail: Vec<f64> = pts[150..].iter().map(|p| p.lyapunov).collect();
        let spread = tail.iter().cloned().fold(f64::MIN, f64::max)
            - tail.iter().cloned().fold(f64::MAX, f64::min);
        let scale = tail[0].abs().max(1e-12);
        assert!(spread / scale < 1e-6, "relative spread {:.3e}", spread / scale);
    }

    #[test]
    fn total_error_bounded_by_censor_plus_quant_envelope() {
        // eq. (33): eps^2 <= 4 C0^2 psi^{2k}
        let tau0 = 0.3;
        let xi: f64 = 0.9;
        let omega: f64 = 0.99;
        let pts = tracked_run(AlgSpec::cq_ggadmm(tau0, xi, omega, 2), 120, 54);
        let psi = xi.max(omega);
        for p in pts.iter().skip(1) {
            // generous constant: C0 = max(tau0, sqrt(d) Delta0) with the
            // first-round radius bounded by the first model norm (~O(1))
            let envelope = 8.0 * psi.powi(p.iteration as i32 - 1);
            assert!(
                p.total_error <= envelope,
                "iter {}: eps {:.3e} > envelope {:.3e}",
                p.iteration,
                p.total_error,
                envelope
            );
        }
    }
}
