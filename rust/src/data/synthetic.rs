//! Synthetic datasets in the style of Chen et al. (2018) / LAG.
//!
//! * linear: `y = X theta0 + eps`, features N(0,1) with a mild planted
//!   covariance, noise sigma = 0.1 (strongly convex least squares).
//! * logistic: labels sampled from the true logistic model at a planted
//!   hyperplane, with 5% label noise — separable-ish but not degenerate.

use super::Dataset;
use crate::config::Task;
use crate::linalg::Mat;
use crate::util::rng::Pcg64;

/// Generate the ground-truth model used by both generators (unit-norm).
fn planted_theta(d: usize, rng: &mut Pcg64) -> Vec<f64> {
    let mut theta = rng.normal_vec(d);
    let norm = theta.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
    for t in theta.iter_mut() {
        *t /= norm;
    }
    theta
}

/// Feature matrix with mild column correlation: x_j = z_j + 0.3 * z_common.
fn features(n: usize, d: usize, rng: &mut Pcg64) -> Mat {
    let mut x = Mat::zeros(n, d);
    for i in 0..n {
        let common = rng.normal();
        let row = x.row_mut(i);
        for item in row.iter_mut().take(d) {
            *item = rng.normal() + 0.3 * common;
        }
    }
    x
}

/// Linear-regression dataset: `y = X theta0 + 0.1 N(0,1)`.
pub fn linear_dataset(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed ^ 0x5EED_0001);
    let theta0 = planted_theta(d, &mut rng);
    let x = features(n, d, &mut rng);
    let mut y = x.matvec(&theta0);
    for yi in y.iter_mut() {
        *yi += 0.1 * rng.normal();
    }
    Dataset {
        name: format!("synth-linear[n={n},d={d}]"),
        task: Task::Linear,
        x,
        y,
    }
}

/// Logistic-regression dataset: P(y=1|x) = sigmoid(2 x^T theta0), with 5%
/// label flips for realism.
pub fn logistic_dataset(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed ^ 0x5EED_0002);
    let theta0 = planted_theta(d, &mut rng);
    let x = features(n, d, &mut rng);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let z = 2.0 * crate::util::dot(x.row(i), &theta0);
        let p = 1.0 / (1.0 + (-z).exp());
        let mut label = if rng.uniform() < p { 1.0 } else { -1.0 };
        if rng.uniform() < 0.05 {
            label = -label;
        }
        y.push(label);
    }
    Dataset {
        name: format!("synth-logistic[n={n},d={d}]"),
        task: Task::Logistic,
        x,
        y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_labels_correlate_with_features() {
        let ds = linear_dataset(400, 10, 1);
        ds.validate().unwrap();
        // OLS on the data recovers a model with small residual
        let g = ds.x.gram().add_diag(1e-6);
        let rhs = ds.x.t_matvec(&ds.y);
        let theta = crate::linalg::Cholesky::new(&g).unwrap().solve(&rhs);
        let pred = ds.x.matvec(&theta);
        let resid: f64 = pred
            .iter()
            .zip(&ds.y)
            .map(|(p, y)| (p - y) * (p - y))
            .sum::<f64>()
            / ds.n() as f64;
        assert!(resid < 0.05, "residual mse {resid}");
    }

    #[test]
    fn logistic_labels_mostly_predictable() {
        let ds = logistic_dataset(600, 8, 2);
        ds.validate().unwrap();
        let pos = ds.y.iter().filter(|&&v| v == 1.0).count();
        // roughly balanced classes
        assert!(pos > 150 && pos < 450, "pos={pos}");
    }

    #[test]
    fn distinct_seeds_distinct_data() {
        let a = logistic_dataset(50, 5, 1);
        let b = logistic_dataset(50, 5, 2);
        assert_ne!(a.x.data(), b.x.data());
    }
}
