//! Datasets of Table 1 and their partitioning across workers.
//!
//! * [`synthetic`] regenerates the Chen et al. (2018)-style synthetic
//!   linear / logistic problems (d = 50, 1200 samples).
//! * [`real`] builds deterministic surrogates for the UCI *Body Fat* and
//!   *Dermatology* datasets (same n, d, realistic feature correlation and
//!   conditioning) — the sandbox has no network access to UCI; see
//!   DESIGN.md §Substitutions.
//! * [`partition`] splits samples uniformly across `N` workers (paper §7).

pub mod csv;
pub mod partition;
pub mod real;
pub mod synthetic;

pub use partition::{partition_uniform, Shard};

use crate::config::Task;
use crate::linalg::Mat;

/// A dense supervised dataset: features `x` (n x d) and targets `y`.
/// For logistic tasks the targets are in {-1, +1}.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub task: Task,
    pub x: Mat,
    pub y: Vec<f64>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn d(&self) -> usize {
        self.x.cols()
    }

    /// Consistency checks used by tests and loaders.
    pub fn validate(&self) -> Result<(), String> {
        if self.x.rows() != self.y.len() {
            return Err(format!(
                "rows {} != labels {}",
                self.x.rows(),
                self.y.len()
            ));
        }
        if self.task == Task::Logistic {
            for (i, &v) in self.y.iter().enumerate() {
                if v != 1.0 && v != -1.0 {
                    return Err(format!("logistic label {i} is {v}, not ±1"));
                }
            }
        }
        for (i, &v) in self.x.data().iter().enumerate() {
            if !v.is_finite() {
                return Err(format!("non-finite feature at flat index {i}"));
            }
        }
        Ok(())
    }
}

/// Build the named dataset of Table 1.
pub fn load(id: crate::config::DatasetId, seed: u64) -> Dataset {
    use crate::config::DatasetId::*;
    match id {
        SynthLinear => synthetic::linear_dataset(1200, 50, seed),
        SynthLogistic => synthetic::logistic_dataset(1200, 50, seed),
        BodyFat => real::bodyfat(seed),
        Derm => real::derm(seed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetId;

    #[test]
    fn table1_inventory_shapes() {
        // Table 1 of the paper: (task, d, n)
        let cases = [
            (DatasetId::SynthLinear, Task::Linear, 50, 1200),
            (DatasetId::BodyFat, Task::Linear, 14, 252),
            (DatasetId::SynthLogistic, Task::Logistic, 50, 1200),
            (DatasetId::Derm, Task::Logistic, 34, 358),
        ];
        for (id, task, d, n) in cases {
            let ds = load(id, 7);
            assert_eq!(ds.task, task, "{id:?}");
            assert_eq!(ds.d(), d, "{id:?}");
            assert_eq!(ds.n(), n, "{id:?}");
            ds.validate().unwrap();
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = load(DatasetId::SynthLinear, 3);
        let b = load(DatasetId::SynthLinear, 3);
        assert_eq!(a.x.data(), b.x.data());
        let c = load(DatasetId::SynthLinear, 4);
        assert_ne!(a.x.data(), c.x.data());
    }
}
