//! Deterministic surrogates for the UCI datasets of Table 1.
//!
//! The sandbox has no network access, so *Body Fat* (linear regression,
//! d = 14, 252 instances) and *Dermatology* (binary logistic, d = 34, 358
//! instances) are regenerated with the same shapes and realistic
//! statistical structure: strongly correlated anthropometric-style
//! features with heterogeneous scales for Body Fat, and blocky ordinal
//! clinical-score features for Derm.  See DESIGN.md §Substitutions — the
//! paper's figures depend on (n, d, conditioning, topology), all
//! preserved here.

use super::Dataset;
use crate::config::Task;
use crate::linalg::Mat;
use crate::util::rng::Pcg64;

/// Body Fat surrogate: 252 x 14 linear regression.
///
/// Mimics the real dataset's structure: one latent "body size" factor
/// drives most features (the real data's circumference measures correlate
/// > 0.8), features carry heterogeneous scales, and the target is a noisy
/// linear functional — producing the ill-conditioned Gram matrices that
/// make this dataset a standard small-but-nasty regression benchmark.
pub fn bodyfat(seed: u64) -> Dataset {
    let n = 252;
    let d = 14;
    let mut rng = Pcg64::new(seed ^ 0xB0D7_FA70);
    // per-feature scale (age, weight, height, 10 circumferences, density)
    let scales: [f64; 14] = [
        12.0, 25.0, 3.5, 8.0, 10.0, 9.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.5, 2.0, 0.02,
    ];
    let loadings: [f64; 14] = [
        0.2, 0.97, 0.3, 0.95, 0.96, 0.93, 0.9, 0.85, 0.8, 0.7, 0.6, 0.5, 0.4, -0.8,
    ];
    let mut x = Mat::zeros(n, d);
    for i in 0..n {
        let size = rng.normal(); // latent body-size factor
        for j in 0..d {
            let idio = rng.normal() * (1.0 - loadings[j] * loadings[j]).max(0.05).sqrt();
            x[(i, j)] = scales[j] * (loadings[j] * size + idio);
        }
    }
    // target: body-fat-% style linear functional + noise
    let mut beta = vec![0.0; d];
    for (j, b) in beta.iter_mut().enumerate() {
        *b = loadings[j] / scales[j] * 4.0;
    }
    let mut y = x.matvec(&beta);
    for yi in y.iter_mut() {
        *yi += 19.0 + 1.5 * rng.normal(); // mean ~19% body fat
    }
    // standardize (zero mean, unit variance) — the usual preprocessing for
    // this benchmark; the factor structure keeps the Gram ill-conditioned
    standardize(&mut x);
    standardize_vec(&mut y);
    Dataset {
        name: "bodyfat[n=252,d=14] (UCI surrogate)".into(),
        task: Task::Linear,
        x,
        y,
    }
}

/// Column-wise standardization to zero mean / unit variance.
fn standardize(x: &mut Mat) {
    let (n, d) = (x.rows(), x.cols());
    for j in 0..d {
        let mean: f64 = (0..n).map(|i| x[(i, j)]).sum::<f64>() / n as f64;
        let var: f64 =
            (0..n).map(|i| (x[(i, j)] - mean).powi(2)).sum::<f64>() / n as f64;
        let std = var.sqrt().max(1e-9);
        for i in 0..n {
            x[(i, j)] = (x[(i, j)] - mean) / std;
        }
    }
}

fn standardize_vec(y: &mut [f64]) {
    let n = y.len() as f64;
    let mean: f64 = y.iter().sum::<f64>() / n;
    let var: f64 = y.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n;
    let std = var.sqrt().max(1e-9);
    for v in y.iter_mut() {
        *v = (*v - mean) / std;
    }
}

/// Dermatology surrogate: 358 x 34 binary logistic.
///
/// The real dataset has 34 mostly-ordinal clinical/histopathological
/// scores in {0..3} organized in correlated symptom blocks, and is nearly
/// separable for the majority class.  We sample block-correlated ordinal
/// features and a near-separable label rule with a little noise.
pub fn derm(seed: u64) -> Dataset {
    let n = 358;
    let d = 34;
    let mut rng = Pcg64::new(seed ^ 0xDE2A_0001);
    let block_of = |j: usize| j / 6; // 6 symptom blocks
    let mut x = Mat::zeros(n, d);
    let mut w = vec![0.0; d];
    for (j, wj) in w.iter_mut().enumerate() {
        *wj = if block_of(j) % 2 == 0 { 0.6 } else { -0.4 } + 0.2 * rng.normal();
    }
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let class = rng.bernoulli(0.44); // positive class ~ erythemato-squamous
        let mut block_level = [0.0f64; 6];
        for (b, lvl) in block_level.iter_mut().enumerate() {
            let base = if class == (b % 2 == 0) { 2.0 } else { 0.7 };
            *lvl = (base + 0.8 * rng.normal()).clamp(0.0, 3.0);
        }
        for j in 0..d {
            let lvl = block_level[block_of(j).min(5)];
            // ordinal score in {0,1,2,3} around the block level
            let score = (lvl + 0.9 * rng.normal()).round().clamp(0.0, 3.0);
            x[(i, j)] = score;
        }
        let z: f64 = (0..d).map(|j| w[j] * x[(i, j)]).sum::<f64>() - 8.0 * 0.12;
        let p = 1.0 / (1.0 + (-1.5 * z).exp());
        let mut label = if rng.uniform() < p { 1.0 } else { -1.0 };
        if rng.uniform() < 0.03 {
            label = -label;
        }
        // tie labels loosely to the sampled class for block structure
        if rng.uniform() < 0.25 {
            label = if class { 1.0 } else { -1.0 };
        }
        y.push(label);
    }
    Dataset {
        name: "derm[n=358,d=34] (UCI surrogate)".into(),
        task: Task::Logistic,
        x,
        y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bodyfat_shape_and_conditioning() {
        let ds = bodyfat(1);
        ds.validate().unwrap();
        assert_eq!((ds.n(), ds.d()), (252, 14));
        // correlated features => ill-conditioned Gram (like the real data)
        let g = ds.x.gram();
        let eig = crate::linalg::symmetric_eigen(&g);
        let cond = eig[eig.len() - 1] / eig[0].max(1e-12);
        assert!(cond > 50.0, "expected ill-conditioning, cond={cond:.1e}");
    }

    #[test]
    fn derm_features_ordinal() {
        let ds = derm(2);
        ds.validate().unwrap();
        assert_eq!((ds.n(), ds.d()), (358, 34));
        for &v in ds.x.data() {
            assert!((0.0..=3.0).contains(&v) && v.fract() == 0.0, "v={v}");
        }
        let pos = ds.y.iter().filter(|&&v| v == 1.0).count();
        assert!(pos > 80 && pos < 280, "pos={pos}");
    }

    #[test]
    fn surrogates_deterministic() {
        assert_eq!(bodyfat(9).x.data(), bodyfat(9).x.data());
        assert_eq!(derm(9).y, derm(9).y);
    }
}
