//! Uniform sample partitioning across workers (paper §7: "the number of
//! samples are uniformly distributed across the N workers").

use super::Dataset;
use crate::linalg::Mat;
use crate::util::rng::Pcg64;

/// One worker's local shard.
#[derive(Clone, Debug)]
pub struct Shard {
    pub worker: usize,
    pub x: Mat,
    pub y: Vec<f64>,
}

impl Shard {
    pub fn s(&self) -> usize {
        self.x.rows()
    }
}

/// Shuffle the dataset (seeded) and split it as evenly as possible across
/// `workers` shards (first `n % workers` shards get one extra sample).
pub fn partition_uniform(ds: &Dataset, workers: usize, seed: u64) -> Vec<Shard> {
    assert!(workers >= 1);
    let n = ds.n();
    assert!(n >= workers, "fewer samples than workers");
    let d = ds.d();
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Pcg64::new(seed ^ 0x9A57_17D5);
    rng.shuffle(&mut order);
    let base = n / workers;
    let extra = n % workers;
    let mut shards = Vec::with_capacity(workers);
    let mut cursor = 0usize;
    for w in 0..workers {
        let count = base + usize::from(w < extra);
        let mut x = Mat::zeros(count, d);
        let mut y = Vec::with_capacity(count);
        for r in 0..count {
            let src = order[cursor];
            cursor += 1;
            x.row_mut(r).copy_from_slice(ds.x.row(src));
            y.push(ds.y[src]);
        }
        shards.push(Shard { worker: w, x, y });
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::linear_dataset;
    use crate::testing::prop::check;

    #[test]
    fn partition_covers_everything_once() {
        check("partition is a permutation of the dataset", 25, |g| {
            let n = g.usize_in(20, 200);
            let d = g.usize_in(1, 8);
            let workers = g.usize_in(1, n.min(24));
            let ds = linear_dataset(n, d, g.u64());
            let shards = partition_uniform(&ds, workers, g.u64());
            assert_eq!(shards.len(), workers);
            let total: usize = shards.iter().map(|s| s.s()).sum();
            assert_eq!(total, n);
            // sizes balanced within 1
            let min = shards.iter().map(|s| s.s()).min().unwrap();
            let max = shards.iter().map(|s| s.s()).max().unwrap();
            assert!(max - min <= 1);
            // every sample appears exactly once (match on y + first feature)
            let mut seen: Vec<(u64, u64)> = Vec::new();
            for sh in &shards {
                for r in 0..sh.s() {
                    seen.push((sh.y[r].to_bits(), sh.x.row(r)[0].to_bits()));
                }
            }
            seen.sort_unstable();
            let mut orig: Vec<(u64, u64)> = (0..n)
                .map(|i| (ds.y[i].to_bits(), ds.x.row(i)[0].to_bits()))
                .collect();
            orig.sort_unstable();
            assert_eq!(seen, orig);
        });
    }

    #[test]
    fn deterministic_per_seed() {
        let ds = linear_dataset(100, 4, 1);
        let a = partition_uniform(&ds, 7, 42);
        let b = partition_uniform(&ds, 7, 42);
        for (sa, sb) in a.iter().zip(&b) {
            assert_eq!(sa.y, sb.y);
        }
    }
}
