//! CSV dataset loader: run the framework on user-supplied data files.
//!
//! Format: numeric CSV with an optional header row; the **last column** is
//! the target.  For logistic tasks the targets must be ±1 (or 0/1, which
//! are remapped).  Pairs with `crate::io::CsvWriter` for round-trips.

use super::Dataset;
use crate::config::Task;
use crate::linalg::Mat;
use std::path::Path;

/// Parse one CSV line honoring quotes.
fn split_csv_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut field = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                field.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut field));
            }
            c => field.push(c),
        }
    }
    out.push(field);
    out
}

/// Parse CSV text into a dataset.
pub fn parse_csv(text: &str, name: &str, task: Task) -> Result<Dataset, String> {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut width = None;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields = split_csv_line(line);
        let parsed: Result<Vec<f64>, _> =
            fields.iter().map(|f| f.trim().parse::<f64>()).collect();
        let values = match parsed {
            Ok(v) => v,
            Err(_) if lineno == 0 => continue, // header row
            Err(_) => {
                return Err(format!("line {}: non-numeric field", lineno + 1));
            }
        };
        if let Some(w) = width {
            if values.len() != w {
                return Err(format!(
                    "line {}: {} fields, expected {}",
                    lineno + 1,
                    values.len(),
                    w
                ));
            }
        } else {
            if values.len() < 2 {
                return Err("need at least one feature column + target".into());
            }
            width = Some(values.len());
        }
        rows.push(values);
    }
    let w = width.ok_or("empty csv")?;
    let d = w - 1;
    let n = rows.len();
    let mut x = Mat::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for (i, row) in rows.iter().enumerate() {
        x.row_mut(i).copy_from_slice(&row[..d]);
        let mut label = row[d];
        if task == Task::Logistic && label == 0.0 {
            label = -1.0; // accept 0/1 labels
        }
        y.push(label);
    }
    let ds = Dataset { name: name.to_string(), task, x, y };
    ds.validate()?;
    Ok(ds)
}

/// Load a dataset from a CSV file.
pub fn load_csv(path: &Path, task: Task) -> Result<Dataset, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    parse_csv(&text, &path.display().to_string(), task)
}

/// Dump a dataset to CSV (features then target; round-trips with
/// [`parse_csv`]).
pub fn to_csv(ds: &Dataset) -> String {
    let mut out = String::new();
    let header: Vec<String> = (0..ds.d())
        .map(|j| format!("x{j}"))
        .chain(std::iter::once("y".to_string()))
        .collect();
    out.push_str(&header.join(","));
    out.push('\n');
    for i in 0..ds.n() {
        let mut fields: Vec<String> =
            ds.x.row(i).iter().map(|v| format!("{v}")).collect();
        fields.push(format!("{}", ds.y[i]));
        out.push_str(&fields.join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn roundtrip_linear_dataset() {
        let ds = synthetic::linear_dataset(40, 3, 1);
        let text = to_csv(&ds);
        let back = parse_csv(&text, "rt", Task::Linear).unwrap();
        assert_eq!(back.n(), 40);
        assert_eq!(back.d(), 3);
        for i in 0..40 {
            assert!((back.y[i] - ds.y[i]).abs() < 1e-12);
            for j in 0..3 {
                assert!((back.x[(i, j)] - ds.x[(i, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn header_row_skipped_and_zero_one_labels_mapped() {
        let text = "a,b,label\n1.0,2.0,0\n3.0,4.0,1\n";
        let ds = parse_csv(text, "t", Task::Logistic).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0]);
    }

    #[test]
    fn quoted_fields_and_blank_lines() {
        let text = "\"1.5\",2\n\n3,4\n";
        let ds = parse_csv(text, "t", Task::Linear).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.x[(0, 0)], 1.5);
    }

    #[test]
    fn errors_reported_with_lines() {
        assert!(parse_csv("", "t", Task::Linear).is_err());
        let e = parse_csv("1,2\n3\n", "t", Task::Linear).unwrap_err();
        assert!(e.contains("line 2"), "{e}");
        let e = parse_csv("1,2\nx,3\n", "t", Task::Linear).unwrap_err();
        assert!(e.contains("non-numeric"), "{e}");
        // bad logistic labels rejected by validation
        let e = parse_csv("1,2.5\n", "t", Task::Logistic).unwrap_err();
        assert!(e.contains("not ±1"), "{e}");
    }
}
