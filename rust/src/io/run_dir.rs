//! The versioned on-disk run layout every result emitter shares:
//!
//! ```text
//! <base>/
//!   0001-<slug>/
//!     manifest.toml    # provenance: the resolved configuration
//!     events.jsonl     # streaming event log (io::events schema)
//!     checkpoint.bin   # latest durable checkpoint (atomic replace)
//!     trace.csv        # final trace (metrics::Trace::to_csv)
//!     ...              # extra per-run artifacts (figure CSVs, tables)
//! ```
//!
//! Run ids are `NNNN-<slug>`: a zero-padded sequence number scanned from
//! the base directory (so concurrent sweeps under one base get distinct
//! dirs without a clock) plus a human-readable slug.  Single runs,
//! figure drivers and the topology matrix all emit through [`RunDir`],
//! so every result carries the same provenance scheme.

use super::checkpoint::{self, RunState};
use super::events::EventRecorder;
use std::path::{Path, PathBuf};

/// Handle to one run directory.
pub struct RunDir {
    root: PathBuf,
}

impl RunDir {
    /// Create the next `NNNN-<slug>` directory under `base`.
    pub fn create(base: &Path, slug: &str) -> std::io::Result<RunDir> {
        std::fs::create_dir_all(base)?;
        let mut next = 1u32;
        for entry in std::fs::read_dir(base)? {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(seq) = name.split('-').next().and_then(|s| s.parse::<u32>().ok()) {
                next = next.max(seq + 1);
            }
        }
        // race-safe: create_dir fails if a concurrent process took the id
        loop {
            let root = base.join(format!("{next:04}-{}", sanitize(slug)));
            match std::fs::create_dir(&root) {
                Ok(()) => return Ok(RunDir { root }),
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => next += 1,
                Err(e) => return Err(e),
            }
        }
    }

    /// Open an existing run directory (resume).
    pub fn open(root: &Path) -> std::io::Result<RunDir> {
        if !root.is_dir() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::NotFound,
                format!("run directory {} does not exist", root.display()),
            ));
        }
        Ok(RunDir { root: root.to_path_buf() })
    }

    pub fn path(&self) -> &Path {
        &self.root
    }

    pub fn manifest_path(&self) -> PathBuf {
        self.root.join("manifest.toml")
    }

    pub fn events_path(&self) -> PathBuf {
        self.root.join("events.jsonl")
    }

    pub fn checkpoint_path(&self) -> PathBuf {
        self.root.join("checkpoint.bin")
    }

    /// Path for an extra artifact (figure CSV, table, ...) inside the run.
    pub fn artifact(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    /// Write the provenance manifest (the resolved configuration).
    pub fn write_manifest(&self, contents: &str) -> std::io::Result<()> {
        std::fs::write(self.manifest_path(), contents)
    }

    /// Save the final trace as `trace.csv`.
    pub fn save_trace(&self, trace: &crate::metrics::Trace) -> std::io::Result<()> {
        trace.save_csv(&self.artifact("trace.csv"))
    }
}

fn sanitize(slug: &str) -> String {
    let s: String = slug
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.' { c } else { '_' })
        .collect();
    if s.is_empty() { "run".into() } else { s }
}

/// What a checkpointable engine exposes to the persistence driver.  Both
/// engines ([`crate::algs::Run`] and the sharded coordinator) implement
/// this, so checkpoint cadence and resume logic live in exactly one
/// place ([`run_with_persistence`]).
pub trait PersistableEngine {
    /// Advance one iteration (recording at the engine's cadence).
    fn step(&mut self);
    /// Completed iterations.
    fn iteration(&self) -> u64;
    /// Export the full durable state (iteration boundary).
    fn snapshot_state(&self) -> RunState;
    /// Overwrite state from a checkpoint (same problem/topology/spec).
    fn restore_state(&mut self, state: &RunState);
    /// The engine's event recorder, when streaming is enabled.
    fn recorder_mut(&mut self) -> Option<&mut EventRecorder>;
}

/// Drive an engine for `iters` further iterations with periodic durable
/// checkpoints (`checkpoint_every` in iterations; `0` = only the final
/// one).  A checkpoint always lands on the final iteration so a finished
/// run can seed follow-on runs.
pub fn run_with_persistence<E: PersistableEngine>(
    engine: &mut E,
    iters: u64,
    dir: &RunDir,
    checkpoint_every: u64,
) -> std::io::Result<()> {
    let path = dir.checkpoint_path();
    for i in 0..iters {
        engine.step();
        let last = i + 1 == iters;
        if last || (checkpoint_every > 0 && engine.iteration() % checkpoint_every == 0) {
            checkpoint::save_atomic(&engine.snapshot_state(), &path)?;
            let k = engine.iteration();
            if let Some(rec) = engine.recorder_mut() {
                rec.checkpoint(k, &path);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cq_rundir_{}_{tag}", std::process::id()))
    }

    #[test]
    fn sequential_ids_and_layout() {
        let base = scratch("seq");
        let _ = std::fs::remove_dir_all(&base);
        let a = RunDir::create(&base, "fig2").unwrap();
        let b = RunDir::create(&base, "fig2").unwrap();
        let an = a.path().file_name().unwrap().to_string_lossy().to_string();
        let bn = b.path().file_name().unwrap().to_string_lossy().to_string();
        assert_eq!(an, "0001-fig2");
        assert_eq!(bn, "0002-fig2");
        a.write_manifest("# test\n").unwrap();
        assert!(a.manifest_path().is_file());
        assert!(RunDir::open(a.path()).is_ok());
        assert!(RunDir::open(&base.join("missing")).is_err());
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn slug_is_sanitized() {
        let base = scratch("slug");
        let _ = std::fs::remove_dir_all(&base);
        let r = RunDir::create(&base, "a/b c!").unwrap();
        let name = r.path().file_name().unwrap().to_string_lossy().to_string();
        assert_eq!(name, "0001-a_b_c_");
        let _ = std::fs::remove_dir_all(&base);
    }
}
