//! Output writers for the experiment harness: CSV, JSON values and a
//! fixed-width table pretty-printer (what the bench harness prints so the
//! figure rows are human-checkable against the paper) — plus the run
//! persistence layer: the on-disk run layout ([`run_dir`]), versioned
//! binary checkpoints with bit-identical resume ([`checkpoint`]) and the
//! streaming JSONL event log ([`events`]).

pub mod checkpoint;
pub mod events;
pub mod run_dir;

pub use checkpoint::{MediumState, RunState};
pub use events::{EventRecorder, EventSink, JsonlSink, MemorySink, EVENT_SCHEMA_VERSION};
pub use run_dir::{run_with_persistence, PersistableEngine, RunDir};

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Minimal CSV writer (numbers + simple strings; quotes fields containing
/// separators).
pub struct CsvWriter {
    buf: String,
    cols: usize,
}

impl CsvWriter {
    pub fn new(header: &[&str]) -> Self {
        let mut w = CsvWriter { buf: String::new(), cols: header.len() };
        w.write_row_strs(header);
        w
    }

    fn escape(field: &str) -> String {
        if field.contains(',') || field.contains('"') || field.contains('\n') {
            format!("\"{}\"", field.replace('"', "\"\""))
        } else {
            field.to_string()
        }
    }

    fn write_row_strs(&mut self, row: &[&str]) {
        assert_eq!(row.len(), self.cols, "csv column count mismatch");
        let line: Vec<String> = row.iter().map(|f| Self::escape(f)).collect();
        let _ = writeln!(self.buf, "{}", line.join(","));
    }

    /// Append a row of f64 values (formatted with full precision).
    pub fn row_f64(&mut self, row: &[f64]) {
        let strs: Vec<String> = row.iter().map(|v| format!("{v:.10e}")).collect();
        let refs: Vec<&str> = strs.iter().map(|s| s.as_str()).collect();
        self.write_row_strs(&refs);
    }

    /// Append a row of preformatted fields.
    pub fn row(&mut self, row: &[&str]) {
        self.write_row_strs(row);
    }

    pub fn contents(&self) -> &str {
        &self.buf
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.buf.as_bytes())
    }
}

/// Minimal JSON value + serializer (we only *emit* JSON; the manifest
/// *parser* lives in `runtime::manifest`).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.render_into(&mut s);
        s
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).render_into(out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Fixed-width console table used by the bench harness output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table column mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let parts: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            parts.join("  ")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
        let _ = writeln!(
            out,
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  ")
        );
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        let _ = cols;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_escaping_and_rows() {
        let mut w = CsvWriter::new(&["a", "b,c"]);
        w.row(&["x", "y\"z"]);
        w.row_f64(&[1.0, 0.5]);
        let s = w.contents();
        assert!(s.starts_with("a,\"b,c\"\n"));
        assert!(s.contains("x,\"y\"\"z\"\n"));
        assert!(s.contains("1.0000000000e0,5.0000000000e-1"));
    }

    #[test]
    #[should_panic(expected = "csv column count mismatch")]
    fn csv_col_mismatch_panics() {
        let mut w = CsvWriter::new(&["a"]);
        w.row(&["x", "y"]);
    }

    #[test]
    fn json_rendering() {
        let j = Json::Obj(vec![
            ("name".into(), Json::Str("fig\"2\"".into())),
            ("n".into(), Json::Num(24.0)),
            ("vals".into(), Json::Arr(vec![Json::Num(0.5), Json::Null, Json::Bool(true)])),
        ]);
        assert_eq!(
            j.render(),
            r#"{"name":"fig\"2\"","n":24,"vals":[0.5,null,true]}"#
        );
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["alg", "rounds"]);
        t.row(&["CQ-GGADMM".into(), "120".into()]);
        t.row(&["C-ADMM".into(), "20000".into()]);
        let s = t.render();
        assert!(s.contains("alg"));
        assert!(s.lines().count() == 4);
    }
}
