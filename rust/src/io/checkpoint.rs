//! Versioned binary checkpoints of full run state, with **bit-identical**
//! resume: every `f64` travels as its IEEE-754 bit pattern
//! (little-endian `to_bits`), every RNG as its raw `(state, inc)` pair,
//! so a restored run replays the exact trajectory of an uninterrupted
//! one (`tests/persistence.rs` locks this across all six `AlgSpec`
//! variants and both engines).
//!
//! Layout: 8-byte magic `CQCKPT01`, `u32` format version, then
//! [`RunState`] — iteration, per-worker [`CoreState`]s, medium totals +
//! link-model state, the trace accumulator, and (since version 2) the
//! dynamic-network section: per-worker membership (`active`) and
//! staleness counters (`stale`).  Version 3 appends the multi-block
//! section — per-core block quantizer RNGs + per-block tx flags, the
//! per-(worker, block) staleness ages and the per-block bits ledger —
//! and is written **only** when any of that state is non-empty, so a
//! flat (single-block) model's checkpoint is byte-for-byte the version-2
//! file it always was.  Version-1 and -2 checkpoints still decode — the
//! absent sections default to everyone present / zero staleness / no
//! blocks.  Checkpoints are O(state), not O(history): the transmission
//! log is folded into its running totals
//! ([`crate::comm::CommLog::restore_totals`]).
//!
//! Writes are atomic (temp file + rename) so a crash mid-checkpoint
//! leaves the previous checkpoint intact.

use crate::comm::LinkState;
use crate::metrics::{Trace, TracePoint};
use crate::protocol::CoreState;
use crate::quant::QuantizerState;
use std::path::Path;

const MAGIC: &[u8; 8] = b"CQCKPT01";
const VERSION: u32 = 2;
/// Written instead of [`VERSION`] when the run carries multi-block state.
const VERSION_BLOCKS: u32 = 3;

/// Everything a resumed engine needs to continue bit-for-bit.
#[derive(Clone, Debug, PartialEq)]
pub struct RunState {
    /// Completed iterations.
    pub iteration: u64,
    /// Durable per-worker state, in worker order.
    pub cores: Vec<CoreState>,
    pub medium: MediumState,
    /// The trace accumulated so far (a resumed run appends to it, so the
    /// final trace equals an uninterrupted run's).
    pub trace: Trace,
    /// Per-worker membership under churn (all `true` on a static graph
    /// and in version-1 checkpoints).
    pub active: Vec<bool>,
    /// Per-worker consecutive-censored-round counters under the
    /// bounded-staleness policy (all zero without one, and in version-1
    /// checkpoints).
    pub stale: Vec<u64>,
    /// Per-(worker, block) staleness ages, flattened row-major by worker
    /// (multi-block models under a bounded-staleness policy; empty for
    /// flat models and pre-version-3 checkpoints).
    pub block_stale: Vec<u64>,
    /// Cumulative per-block transmitted bits
    /// ([`crate::comm::CommLog::block_bits`]; empty for flat models).
    pub block_bits: Vec<u64>,
}

/// The medium's durable state: checkpointed totals + link-model RNG.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MediumState {
    pub rounds: u64,
    pub total_bits: u64,
    pub total_energy_j: f64,
    pub sim_time_s: f64,
    pub link: LinkState,
}

// ---- encoder ---------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn vec_f64(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f64(x);
        }
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    fn core(&mut self, c: &CoreState) {
        self.vec_f64(&c.theta);
        self.vec_f64(&c.alpha);
        self.vec_f64(&c.hat_self);
        self.u64(c.hat_nbrs.len() as u64);
        for hat in &c.hat_nbrs {
            self.vec_f64(hat);
        }
        self.bool(c.transmitted_once);
        self.vec_f64(&c.nbr_sum);
        self.bool(c.nbr_stale);
        self.vec_f64(&c.dual_delta);
        self.bool(c.dual_stale);
        match &c.quantizer {
            None => self.u8(0),
            Some(q) => {
                self.u8(1);
                self.quant_state(q);
            }
        }
    }

    fn quant_state(&mut self, q: &QuantizerState) {
        match q.prev_radius {
            None => self.u8(0),
            Some(r) => {
                self.u8(1);
                self.f64(r);
            }
        }
        self.u32(q.prev_bits);
        self.u128(q.rng_state);
        self.u128(q.rng_inc);
    }

    /// The version-3 per-core block section: per-block quantizer RNGs +
    /// per-block transmitted-once flags.
    fn core_blocks(&mut self, c: &CoreState) {
        self.u64(c.block_quantizers.len() as u64);
        for q in &c.block_quantizers {
            self.quant_state(q);
        }
        self.u64(c.block_tx_once.len() as u64);
        for &t in &c.block_tx_once {
            self.bool(t);
        }
    }
}

/// Whether any multi-block state is present (selects version 3; a flat
/// model's checkpoint must stay the byte-identical version-2 file).
fn has_block_state(state: &RunState) -> bool {
    !state.block_stale.is_empty()
        || !state.block_bits.is_empty()
        || state
            .cores
            .iter()
            .any(|c| !c.block_quantizers.is_empty() || !c.block_tx_once.is_empty())
}

/// Serialize a [`RunState`] to the versioned binary format.
pub fn encode(state: &RunState) -> Vec<u8> {
    let blocks = has_block_state(state);
    let mut e = Enc { buf: Vec::new() };
    e.buf.extend_from_slice(MAGIC);
    e.u32(if blocks { VERSION_BLOCKS } else { VERSION });
    e.u64(state.iteration);
    e.u64(state.cores.len() as u64);
    for c in &state.cores {
        e.core(c);
    }
    e.u64(state.medium.rounds);
    e.u64(state.medium.total_bits);
    e.f64(state.medium.total_energy_j);
    e.f64(state.medium.sim_time_s);
    match state.medium.link {
        LinkState::Stateless => e.u8(0),
        LinkState::Rng { state: s, inc } => {
            e.u8(1);
            e.u128(s);
            e.u128(inc);
        }
    }
    e.str(&state.trace.algorithm);
    e.str(&state.trace.dataset);
    e.u64(state.trace.points.len() as u64);
    for p in &state.trace.points {
        e.u64(p.iteration);
        e.f64(p.loss_gap);
        e.f64(p.consensus_gap);
        e.u64(p.cum_rounds);
        e.u64(p.cum_bits);
        e.f64(p.cum_energy_j);
    }
    // version-2 dynamic-network section (last, so a v1 decoder's
    // trailing-bytes check would catch a version mismatch)
    e.u64(state.active.len() as u64);
    for &a in &state.active {
        e.bool(a);
    }
    e.u64(state.stale.len() as u64);
    for &s in &state.stale {
        e.u64(s);
    }
    if blocks {
        // version-3 multi-block section
        e.u64(state.cores.len() as u64);
        for c in &state.cores {
            e.core_blocks(c);
        }
        e.u64(state.block_stale.len() as u64);
        for &a in &state.block_stale {
            e.u64(a);
        }
        e.u64(state.block_bits.len() as u64);
        for &b in &state.block_bits {
            e.u64(b);
        }
    }
    e.buf
}

/// Serialize a single [`CoreState`] standalone (no magic/version header)
/// — the networked transport ships worker state in registration and
/// clean-shutdown frames using the exact checkpoint layout, so state that
/// crossed the wire is bit-identical to state that crossed a file.  The
/// multi-block section is appended only when non-empty, keeping flat
/// cores byte-identical to the pre-block encoding.
pub fn encode_core(core: &CoreState) -> Vec<u8> {
    let mut e = Enc { buf: Vec::new() };
    e.core(core);
    if !core.block_quantizers.is_empty() || !core.block_tx_once.is_empty() {
        e.core_blocks(core);
    }
    e.buf
}

/// Parse a [`CoreState`] produced by [`encode_core`]; rejects trailing
/// bytes like the full-checkpoint decoder.  Remaining bytes after the
/// flat fields are the optional multi-block section.
pub fn decode_core(bytes: &[u8]) -> Result<CoreState, String> {
    let mut d = Dec { buf: bytes, pos: 0 };
    let mut core = d.core()?;
    if d.pos != bytes.len() {
        let (bq, btx) = d.core_blocks()?;
        core.block_quantizers = bq;
        core.block_tx_once = btx;
    }
    if d.pos != bytes.len() {
        return Err(format!("core state corrupt: {} trailing bytes", bytes.len() - d.pos));
    }
    Ok(core)
}

// ---- decoder ---------------------------------------------------------

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "checkpoint truncated at byte {} (wanted {n} more of {})",
                self.pos,
                self.buf.len()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn u128(&mut self) -> Result<u128, String> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn len(&mut self, what: &str) -> Result<usize, String> {
        let n = self.u64()?;
        // a corrupt length must not trigger a huge allocation
        if n > (self.buf.len() as u64) {
            return Err(format!("checkpoint corrupt: {what} length {n} exceeds file size"));
        }
        Ok(n as usize)
    }
    fn vec_f64(&mut self, what: &str) -> Result<Vec<f64>, String> {
        let n = self.len(what)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f64()?);
        }
        Ok(v)
    }
    fn str(&mut self, what: &str) -> Result<String, String> {
        let n = self.len(what)?;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| format!("checkpoint corrupt: {what} is not UTF-8"))
    }
    fn bool(&mut self, what: &str) -> Result<bool, String> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(format!("checkpoint corrupt: {what} flag byte {b}")),
        }
    }

    fn core(&mut self) -> Result<CoreState, String> {
        let theta = self.vec_f64("theta")?;
        let alpha = self.vec_f64("alpha")?;
        let hat_self = self.vec_f64("hat_self")?;
        let deg = self.len("hat_nbrs")?;
        let mut hat_nbrs = Vec::with_capacity(deg);
        for _ in 0..deg {
            hat_nbrs.push(self.vec_f64("hat_nbr")?);
        }
        let transmitted_once = self.bool("transmitted_once")?;
        let nbr_sum = self.vec_f64("nbr_sum")?;
        let nbr_stale = self.bool("nbr_stale")?;
        let dual_delta = self.vec_f64("dual_delta")?;
        let dual_stale = self.bool("dual_stale")?;
        let quantizer = match self.u8()? {
            0 => None,
            1 => Some(self.quant_state()?),
            b => return Err(format!("checkpoint corrupt: quantizer flag byte {b}")),
        };
        Ok(CoreState {
            theta,
            alpha,
            hat_self,
            hat_nbrs,
            transmitted_once,
            nbr_sum,
            nbr_stale,
            dual_delta,
            dual_stale,
            quantizer,
            block_quantizers: Vec::new(),
            block_tx_once: Vec::new(),
        })
    }

    fn quant_state(&mut self) -> Result<QuantizerState, String> {
        let prev_radius = match self.u8()? {
            0 => None,
            1 => Some(self.f64()?),
            b => return Err(format!("checkpoint corrupt: radius flag byte {b}")),
        };
        Ok(QuantizerState {
            prev_radius,
            prev_bits: self.u32()?,
            rng_state: self.u128()?,
            rng_inc: self.u128()?,
        })
    }

    fn core_blocks(&mut self) -> Result<(Vec<QuantizerState>, Vec<bool>), String> {
        let nq = self.len("block quantizers")?;
        let mut bq = Vec::with_capacity(nq);
        for _ in 0..nq {
            bq.push(self.quant_state()?);
        }
        let nt = self.len("block tx_once")?;
        let mut btx = Vec::with_capacity(nt);
        for _ in 0..nt {
            btx.push(self.bool("block tx_once")?);
        }
        Ok((bq, btx))
    }
}

/// Parse a checkpoint produced by [`encode`].
pub fn decode(bytes: &[u8]) -> Result<RunState, String> {
    let mut d = Dec { buf: bytes, pos: 0 };
    if d.take(8)? != MAGIC {
        return Err("not a checkpoint file (bad magic)".into());
    }
    let version = d.u32()?;
    if version == 0 || version > VERSION_BLOCKS {
        return Err(format!(
            "unsupported checkpoint version {version} (expected 1..={VERSION_BLOCKS})"
        ));
    }
    let iteration = d.u64()?;
    let n = d.len("cores")?;
    let mut cores = Vec::with_capacity(n);
    for _ in 0..n {
        cores.push(d.core()?);
    }
    let medium = MediumState {
        rounds: d.u64()?,
        total_bits: d.u64()?,
        total_energy_j: d.f64()?,
        sim_time_s: d.f64()?,
        link: match d.u8()? {
            0 => LinkState::Stateless,
            1 => LinkState::Rng { state: d.u128()?, inc: d.u128()? },
            b => return Err(format!("checkpoint corrupt: link flag byte {b}")),
        },
    };
    let algorithm = d.str("algorithm")?;
    let dataset = d.str("dataset")?;
    let mut trace = Trace::new(&algorithm, &dataset);
    let npts = d.len("trace points")?;
    for _ in 0..npts {
        trace.push(TracePoint {
            iteration: d.u64()?,
            loss_gap: d.f64()?,
            consensus_gap: d.f64()?,
            cum_rounds: d.u64()?,
            cum_bits: d.u64()?,
            cum_energy_j: d.f64()?,
        });
    }
    let (active, stale) = if version >= 2 {
        let na = d.len("active")?;
        let mut active = Vec::with_capacity(na);
        for _ in 0..na {
            active.push(d.bool("active")?);
        }
        let ns = d.len("stale")?;
        let mut stale = Vec::with_capacity(ns);
        for _ in 0..ns {
            stale.push(d.u64()?);
        }
        (active, stale)
    } else {
        // v1 predates dynamic networks: everyone present, nothing stale
        (vec![true; n], vec![0u64; n])
    };
    let (block_stale, block_bits) = if version >= 3 {
        let nb = d.len("block cores")?;
        if nb != n {
            return Err(format!(
                "checkpoint corrupt: block section covers {nb} cores, expected {n}"
            ));
        }
        for c in cores.iter_mut() {
            let (bq, btx) = d.core_blocks()?;
            c.block_quantizers = bq;
            c.block_tx_once = btx;
        }
        let ns = d.len("block_stale")?;
        let mut block_stale = Vec::with_capacity(ns);
        for _ in 0..ns {
            block_stale.push(d.u64()?);
        }
        let nbits = d.len("block_bits")?;
        let mut block_bits = Vec::with_capacity(nbits);
        for _ in 0..nbits {
            block_bits.push(d.u64()?);
        }
        (block_stale, block_bits)
    } else {
        // pre-v3: flat models only, no per-block state
        (Vec::new(), Vec::new())
    };
    if d.pos != bytes.len() {
        return Err(format!("checkpoint corrupt: {} trailing bytes", bytes.len() - d.pos));
    }
    Ok(RunState { iteration, cores, medium, trace, active, stale, block_stale, block_bits })
}

/// Write a checkpoint atomically: temp file in the same directory, then
/// rename over the target, so a crash never clobbers the previous one.
pub fn save_atomic(state: &RunState, path: &Path) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, encode(state))?;
    std::fs::rename(&tmp, path)
}

/// Load and parse a checkpoint.
pub fn load(path: &Path) -> std::io::Result<RunState> {
    let bytes = std::fs::read(path)?;
    decode(&bytes).map_err(|e| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, format!("{}: {e}", path.display()))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state() -> RunState {
        let mut trace = Trace::new("cq_ggadmm", "synthetic");
        trace.push(TracePoint {
            iteration: 2,
            loss_gap: 0.125,
            consensus_gap: -0.0, // signed zero must survive (to_bits)
            cum_rounds: 7,
            cum_bits: 1234,
            cum_energy_j: 3.5e-4,
        });
        RunState {
            iteration: 2,
            cores: vec![
                CoreState {
                    theta: vec![1.0, f64::MIN_POSITIVE, -3.25],
                    alpha: vec![0.0, -0.5, 1e300],
                    hat_self: vec![0.25; 3],
                    hat_nbrs: vec![vec![0.5; 3], vec![-0.5; 3]],
                    transmitted_once: true,
                    nbr_sum: vec![0.0; 3],
                    nbr_stale: true,
                    dual_delta: vec![1.5; 3],
                    dual_stale: false,
                    quantizer: Some(QuantizerState {
                        prev_radius: Some(0.75),
                        prev_bits: 5,
                        rng_state: u128::MAX - 17,
                        rng_inc: 12345,
                    }),
                    block_quantizers: Vec::new(),
                    block_tx_once: Vec::new(),
                },
                CoreState {
                    theta: vec![2.0; 3],
                    alpha: vec![0.0; 3],
                    hat_self: vec![0.0; 3],
                    hat_nbrs: vec![vec![0.0; 3]],
                    transmitted_once: false,
                    nbr_sum: vec![0.0; 3],
                    nbr_stale: false,
                    dual_delta: vec![0.0; 3],
                    dual_stale: true,
                    quantizer: None,
                    block_quantizers: Vec::new(),
                    block_tx_once: Vec::new(),
                },
            ],
            medium: MediumState {
                rounds: 7,
                total_bits: 1234,
                total_energy_j: 3.5e-4,
                sim_time_s: 0.007,
                link: LinkState::Rng { state: 42, inc: 99 },
            },
            trace,
            active: vec![true, false],
            stale: vec![3, 0],
            block_stale: Vec::new(),
            block_bits: Vec::new(),
        }
    }

    /// Sample state with live multi-block sections on every core.
    fn sample_block_state() -> RunState {
        let mut s = sample_state();
        s.cores[0].block_quantizers = vec![
            QuantizerState {
                prev_radius: Some(1.5),
                prev_bits: 8,
                rng_state: 77,
                rng_inc: 3,
            },
            QuantizerState { prev_radius: None, prev_bits: 2, rng_state: 9, rng_inc: 11 },
        ];
        s.cores[0].block_tx_once = vec![true, false];
        s.cores[1].block_tx_once = vec![true, true];
        s.block_stale = vec![0, 4, 1, 0];
        s.block_bits = vec![4096, 640];
        s
    }

    #[test]
    fn round_trip_is_exact() {
        let s = sample_state();
        let decoded = decode(&encode(&s)).expect("decode");
        assert_eq!(decoded, s);
        // signed zero specifically: PartialEq on f64 treats -0.0 == 0.0,
        // so check the bit pattern directly
        assert_eq!(
            decoded.trace.points[0].consensus_gap.to_bits(),
            (-0.0f64).to_bits()
        );
    }

    #[test]
    fn flat_state_still_encodes_as_version_2() {
        // the multi-block refactor must not move a single byte of a flat
        // model's checkpoint (the pre-refactor format is locked)
        let bytes = encode(&sample_state());
        assert_eq!(u32::from_le_bytes(bytes[8..12].try_into().unwrap()), 2);
    }

    #[test]
    fn multi_block_state_round_trips_as_version_3() {
        let s = sample_block_state();
        let bytes = encode(&s);
        assert_eq!(u32::from_le_bytes(bytes[8..12].try_into().unwrap()), 3);
        let decoded = decode(&bytes).expect("decode v3");
        assert_eq!(decoded, s);
        assert!(decode(&bytes[..bytes.len() - 1]).unwrap_err().contains("truncated"));
        let mut longer = bytes;
        longer.push(0);
        assert!(decode(&longer).unwrap_err().contains("trailing"));
    }

    #[test]
    fn block_core_round_trips_standalone() {
        let s = sample_block_state();
        let flat_len = encode_core(&sample_state().cores[0]).len();
        let bytes = encode_core(&s.cores[0]);
        assert!(bytes.len() > flat_len, "block section must be appended");
        assert_eq!(decode_core(&bytes).expect("decode"), s.cores[0]);
        assert!(decode_core(&bytes[..bytes.len() - 1]).unwrap_err().contains("truncated"));
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let mut bytes = encode(&sample_state());
        assert!(decode(&bytes[..4]).is_err(), "truncated magic");
        bytes[0] ^= 0xFF;
        assert!(decode(&bytes).unwrap_err().contains("magic"));
        bytes[0] ^= 0xFF;
        bytes[8] = 99; // version
        assert!(decode(&bytes).unwrap_err().contains("version"));
    }

    #[test]
    fn decodes_version_1_with_default_dynamic_section() {
        let s = sample_state();
        let mut bytes = encode(&s);
        // strip the trailing dynamic section and stamp version 1: the
        // section is (len + n bools) + (len + n u64s) at the very end
        let n = s.cores.len();
        bytes.truncate(bytes.len() - (8 + n) - (8 + 8 * n));
        bytes[8..12].copy_from_slice(&1u32.to_le_bytes());
        let decoded = decode(&bytes).expect("v1 checkpoint must decode");
        assert_eq!(decoded.active, vec![true; n]);
        assert_eq!(decoded.stale, vec![0u64; n]);
        assert_eq!(decoded.cores, s.cores);
        assert_eq!(decoded.medium, s.medium);
    }

    #[test]
    fn rejects_truncation_and_trailing_garbage() {
        let bytes = encode(&sample_state());
        assert!(decode(&bytes[..bytes.len() - 1]).unwrap_err().contains("truncated"));
        let mut longer = bytes.clone();
        longer.push(0);
        assert!(decode(&longer).unwrap_err().contains("trailing"));
    }

    #[test]
    fn core_round_trip_standalone() {
        for core in sample_state().cores {
            let bytes = encode_core(&core);
            assert_eq!(decode_core(&bytes).expect("decode core"), core);
            // stray bytes after a flat core read as a (truncated) block
            // section — either way the decode must fail loudly
            let mut longer = bytes.clone();
            longer.push(7);
            assert!(decode_core(&longer).is_err());
            assert!(decode_core(&bytes[..bytes.len() - 1]).unwrap_err().contains("truncated"));
        }
    }

    #[test]
    fn save_atomic_then_load() {
        let dir = std::env::temp_dir().join(format!("cq_ckpt_test_{}", std::process::id()));
        let path = dir.join("checkpoint.bin");
        let s = sample_state();
        save_atomic(&s, &path).expect("save");
        assert_eq!(load(&path).expect("load"), s);
        // a second save replaces atomically
        save_atomic(&s, &path).expect("resave");
        assert_eq!(load(&path).expect("reload"), s);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
